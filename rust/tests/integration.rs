//! Integration tests over the AOT artifacts + runtime.
//!
//! These need artifacts: either the real export (`make artifacts`) or the
//! in-repo fixture set (`repro gen-artifacts`, which CI runs before
//! `cargo test`, making this suite a required gate). Every test still
//! skips gracefully (with a message) when artifacts/ is absent so a bare
//! `cargo test` stays green in a fresh checkout.
//!
//! The runtime picks its backend per artifact: PJRT when the client can
//! compile, the in-repo HLO interpreter otherwise — these tests pass
//! identically on both.

use std::collections::BTreeMap;

use tq::coordinator::calibrate::{calibrate, CalibCfg};
use tq::coordinator::{eval, Ctx};
use tq::data::{self, task_spec};
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy, SiteCfg};
use tq::model::Params;
use tq::quant::{Estimator, Granularity, RangeMethod};
use tq::runtime::{lit_f32, lit_i32, Runtime};

fn ctx() -> Option<Ctx> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Ctx::new("artifacts", "/tmp/tq_test_ckpt", "/tmp/tq_test_results").unwrap())
}

#[test]
fn manifest_matches_model_topology() {
    let Some(ctx) = ctx() else { return };
    let info = ctx.rt.manifest().model("base").unwrap();
    // paper proportions: 13 activation-quantizer sites per layer + 4
    assert_eq!(info.sites.len(), 13 * info.config.layers + 4);
    assert_eq!(info.config.d, 128);
    // offsets are contiguous
    let mut off = 0;
    for s in &info.sites {
        assert_eq!(s.offset, off);
        off += s.channels;
    }
    assert_eq!(off, info.total_scale_lanes);
    // fwd artifact signature: params + 3 quant tensors + 3 batch tensors
    let sig = ctx.rt.manifest().artifact("fwd_cls_b8").unwrap();
    assert_eq!(sig.inputs.len(), info.params.len() + 6);
}

#[test]
fn manifest_matches_vit_topology() {
    let Some(ctx) = ctx() else { return };
    let Ok(info) = ctx.rt.manifest().model("vit") else {
        eprintln!("SKIP: manifest has no vit model (regenerate artifacts)");
        return;
    };
    // the site table is architecture-shared: same 13-per-layer + 4 shape
    assert_eq!(info.sites.len(), 13 * info.config.layers + 4);
    assert_eq!(info.config.architecture(), tq::model::manifest::Architecture::Vit);
    // patch geometry: 4x4 patches over a 16px image, flattened to 16-dim
    // patch vectors over seq = (img/patch)^2 = 16 positions
    assert_eq!(info.config.patch_dim(), Some(16));
    assert_eq!(info.config.seq, 16);
    let mut off = 0;
    for s in &info.sites {
        assert_eq!(s.offset, off);
        off += s.channels;
    }
    assert_eq!(off, info.total_scale_lanes);
    // ViT fwd signature: params + 3 quant tensors + ONE pixels tensor
    // (no ids/token_type/mask — the frontends diverge at the input layer)
    let sig = ctx.rt.manifest().artifact("fwd_vit_cls_b8").unwrap();
    assert_eq!(sig.inputs.len(), info.params.len() + 4);
    let pixels = sig.inputs.last().unwrap();
    assert_eq!(pixels.name, "pixels");
    assert_eq!(pixels.shape, vec![8, info.config.seq, 16]);
}

#[test]
fn golden_fake_quant_bit_exact() {
    let Some(ctx) = ctx() else { return };
    let g = ctx.rt.manifest().golden_fake_quant.as_ref().unwrap();
    let grid = tq::quant::QGrid { qmin: g.qmin, qmax: g.qmax };
    let t = tq::tensor::Tensor::new(vec![g.rows, g.cols], g.x.clone()).unwrap();
    let params: Vec<tq::quant::QParams> = g
        .scale
        .iter()
        .zip(&g.zp)
        .map(|(&s, &z)| tq::quant::QParams { scale: s, zero_point: z })
        .collect();
    let out = tq::quant::qdq_per_lane(&t, &params, grid).unwrap();
    for (a, b) in out.data().iter().zip(&g.out) {
        assert_eq!(a, b, "Rust QDQ differs from the Pallas kernel");
    }
}

#[test]
fn forward_runs_and_quant_flags_work() {
    let Some(ctx) = ctx() else { return };
    let task = task_spec("mnli").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 3);
    let split = data::dev_split(&task, info.config.seq).unwrap();
    let batch = data::make_batch(&split, 0, 8, info.config.seq);

    let run = |policy: &QuantPolicy| -> Vec<f32> {
        let act = assemble_act_tensors(info, policy, &BTreeMap::new()).unwrap();
        let mut lits = Vec::new();
        for t in &params.tensors {
            lits.push(lit_f32(t.data(), t.shape()).unwrap());
        }
        lits.push(lit_f32(&act.scales, &[act.scales.len()]).unwrap());
        lits.push(lit_f32(&act.zps, &[act.zps.len()]).unwrap());
        lits.push(lit_f32(&act.cfg, &[info.sites.len(), 3]).unwrap());
        lits.push(lit_i32(&batch.ids, &[8, info.config.seq]).unwrap());
        lits.push(lit_i32(&batch.token_type, &[8, info.config.seq]).unwrap());
        lits.push(lit_f32(&batch.mask, &[8, info.config.seq]).unwrap());
        ctx.rt.run_lits("fwd_cls_b8", &lits).unwrap()[0].data().to_vec()
    };

    let fp32 = run(&QuantPolicy::fp32());
    assert!(fp32.iter().all(|x| x.is_finite()));
    let fp32_again = run(&QuantPolicy::fp32());
    assert_eq!(fp32, fp32_again, "executable must be deterministic");

    // enabling 2-bit everywhere must change logits but stay finite
    let crushed = run(&QuantPolicy::uniform(8, 2));
    assert!(crushed.iter().all(|x| x.is_finite()));
    assert_ne!(fp32, crushed);
}

#[test]
fn calibration_covers_every_site() {
    let Some(ctx) = ctx() else { return };
    let task = task_spec("rte").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 5);
    let calib = calibrate(&ctx, &task, &params, &CalibCfg {
        estimator: Estimator::RunningMinMax,
        batch_size: 1,
        num_batches: 2,
        collect_grams: true,
        seed: 0,
    })
    .unwrap();
    assert_eq!(calib.trackers.len(), info.sites.len());
    for (site, tr) in &calib.trackers {
        assert_eq!(tr.batches_seen(), 2, "{site}");
        let (lo, hi) = tr.lane_ranges();
        assert!(lo.iter().zip(&hi).all(|(l, h)| l <= h), "{site}");
    }
    // Grams exist for every linear-input site
    assert_eq!(
        calib.grams.len(),
        tq::coordinator::calibrate::gram_sites(info.config.layers).len()
    );
}

#[test]
fn eval_scores_in_range_and_policy_sensitivity() {
    let Some(ctx) = ctx() else { return };
    let task = task_spec("sst2").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 7);
    let calib = calibrate(&ctx, &task, &params, &CalibCfg {
        num_batches: 2,
        ..Default::default()
    })
    .unwrap();
    let act8 = assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers).unwrap();
    let s8 = eval::evaluate(&ctx, &task, &params, &act8).unwrap();
    assert!((0.0..=100.0).contains(&s8));

    // PEG policy assembles with the real topology and evaluates
    let peg = SiteCfg {
        granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
        ..Default::default()
    };
    let policy = QuantPolicy::uniform(8, 8).with_site_family(info, "res2_sum", peg);
    let actp = assemble_act_tensors(info, &policy, &calib.trackers).unwrap();
    let sp = eval::evaluate(&ctx, &task, &params, &actp).unwrap();
    assert!((0.0..=100.0).contains(&sp));
}

/// The dev split is rarely a multiple of the executable batch; the final
/// partial batch is padded (see `data::make_batch`) and its rows must be
/// ignored, never scored. Pinned by recomputing the same predictions with
/// the OLD wraparound tail (head examples duplicated into the padding
/// rows): tail content must not move the score by a single bit.
#[test]
fn eval_scores_ignore_padded_tail_rows() {
    let Some(ctx) = ctx() else { return };
    let task = task_spec("sst2").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 23);
    let act = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new()).unwrap();
    let seq = info.config.seq;
    let mut split = data::dev_split(&task, seq).unwrap();
    split.examples.truncate(20); // 2 full batches + a 4-row tail
    let score = eval::evaluate_split(&ctx, &task, &params, &act, &split).unwrap();

    let b = 8usize;
    let n = split.examples.len();
    let mut statics = Vec::new();
    for t in &params.tensors {
        statics.push(lit_f32(t.data(), t.shape()).unwrap());
    }
    statics.push(lit_f32(&act.scales, &[act.scales.len()]).unwrap());
    statics.push(lit_f32(&act.zps, &[act.zps.len()]).unwrap());
    statics.push(lit_f32(&act.cfg, &[info.sites.len(), 3]).unwrap());
    let mut preds = Vec::new();
    let mut golds = Vec::new();
    let mut start = 0usize;
    while start < n {
        // old-style wraparound batch: rows past the end duplicate the
        // head of the split
        let mut ids = Vec::new();
        let mut tt = Vec::new();
        let mut mask = Vec::new();
        for i in 0..b {
            let ex = &split.examples[(start + i) % n];
            ids.extend_from_slice(&ex.ids);
            tt.extend_from_slice(&ex.token_type);
            mask.extend_from_slice(&ex.mask);
        }
        let l_ids = lit_i32(&ids, &[b, seq]).unwrap();
        let l_tt = lit_i32(&tt, &[b, seq]).unwrap();
        let l_mask = lit_f32(&mask, &[b, seq]).unwrap();
        let mut lits: Vec<&xla::Literal> = statics.iter().collect();
        lits.push(&l_ids);
        lits.push(&l_tt);
        lits.push(&l_mask);
        let out = ctx.rt.run_lits_borrowed("fwd_cls_b8", &lits).unwrap();
        let logits = &out[0];
        let take = (n - start).min(b);
        for i in 0..take {
            let row = &logits.data()[i * info.config.n_out..(i + 1) * info.config.n_out];
            let pred = row[..2]
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.total_cmp(y.1))
                .map(|(j, _)| j)
                .unwrap();
            preds.push(pred);
            golds.push(split.examples[start + i].label);
        }
        start += b;
    }
    let want = tq::metrics::task_score("sst2", &preds, &golds, &[], &[]);
    assert_eq!(
        score.to_bits(),
        want.to_bits(),
        "padded tail leaked into the score: {score} vs {want}"
    );

    // an exact multiple of the batch takes the no-padding path and must
    // also produce the same per-example predictions
    let mut split16 = data::dev_split(&task, seq).unwrap();
    split16.examples.truncate(16);
    let s16 = eval::evaluate_split(&ctx, &task, &params, &act, &split16).unwrap();
    let want16 = tq::metrics::task_score("sst2", &preds[..16], &golds[..16], &[], &[]);
    assert_eq!(s16.to_bits(), want16.to_bits());
}

#[test]
fn pallas_and_jnp_forward_artifacts_agree() {
    let Some(ctx) = ctx() else { return };
    if ctx.rt.manifest().artifact("fwd_cls_b1_pallas").is_err() {
        eprintln!("SKIP: no pallas parity artifact");
        return;
    }
    let task = task_spec("mnli").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 11);
    let split = data::dev_split(&task, info.config.seq).unwrap();
    let batch = data::make_batch(&split, 0, 1, info.config.seq);
    let act = assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &BTreeMap::new()).unwrap();
    let mut lits = Vec::new();
    for t in &params.tensors {
        lits.push(lit_f32(t.data(), t.shape()).unwrap());
    }
    lits.push(lit_f32(&act.scales, &[act.scales.len()]).unwrap());
    lits.push(lit_f32(&act.zps, &[act.zps.len()]).unwrap());
    lits.push(lit_f32(&act.cfg, &[info.sites.len(), 3]).unwrap());
    lits.push(lit_i32(&batch.ids, &[1, info.config.seq]).unwrap());
    lits.push(lit_i32(&batch.token_type, &[1, info.config.seq]).unwrap());
    lits.push(lit_f32(&batch.mask, &[1, info.config.seq]).unwrap());
    let jnp = ctx.rt.run_lits("fwd_cls_b1", &lits).unwrap();
    let pal = ctx.rt.run_lits("fwd_cls_b1_pallas", &lits).unwrap();
    for (a, b) in jnp[0].data().iter().zip(pal[0].data()) {
        assert!((a - b).abs() < 1e-4, "pallas {b} vs jnp {a}");
    }
}

#[test]
fn runtime_rejects_bad_input_counts() {
    let Some(ctx) = ctx() else { return };
    let err = ctx.rt.run_lits("fwd_cls_b8", &[]);
    assert!(err.is_err());
    assert!(Runtime::new("/nonexistent").is_err());
}

#[test]
fn interpreter_matches_analytic_fixture_outputs() {
    // The gen-artifacts fixture ships `kernel_affine`: y = 2x + 1 plus
    // per-row sums and per-column maxima — closed-form outputs that pin
    // the execution backend (PJRT or interpreter) end to end.
    let Some(ctx) = ctx() else { return };
    if ctx.rt.manifest().artifact("kernel_affine").is_err() {
        // same "SKIP: artifacts" prefix the CI zero-skip gate greps for
        eprintln!("SKIP: artifacts lack the kernel_affine fixture (run `repro gen-artifacts`)");
        return;
    }
    let x: Vec<f32> = (0..12).map(|i| (i as f32) * 0.25 - 1.0).collect();
    let input = tq::tensor::Tensor::new(vec![4, 3], x.clone()).unwrap();
    let out = ctx
        .rt
        .run("kernel_affine", &[tq::runtime::Value::F32(input)])
        .unwrap();
    assert_eq!(out.len(), 3);
    for (a, b) in out[0].data().iter().zip(&x) {
        assert!((a - (2.0 * b + 1.0)).abs() < 1e-5, "{a} vs 2*{b}+1");
    }
    for (r, chunk) in out[1].data().iter().zip(x.chunks(3)) {
        let want: f32 = chunk.iter().sum();
        assert!((r - want).abs() < 1e-5, "{r} vs {want}");
    }
    // x is monotonically increasing, so column maxima sit in the last row
    assert_eq!(out[2].data(), &[x[9], x[10], x[11]]);
    assert!(ctx.rt.stats().executions >= 1);
}

#[test]
fn sweep_smoke_two_configs() {
    use tq::coordinator::sweep;
    use tq::util::pool::Pool;

    // The offline substrate sweep needs no artifacts and must always run.
    let data = sweep::synth_data(64, 32, 2, 3);
    let cfgs = sweep::grid(
        64,
        &[tq::model::manifest::Architecture::Bert],
        &[8],
        &[8],
        &[1, 8],
        &[Estimator::CurrentMinMax],
        &[RangeMethod::Auto],
    )
    .unwrap();
    assert_eq!(cfgs.len(), 2);
    let results = sweep::run_offline(&data, &cfgs, &Pool::new(2)).unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!(r.act_mse.is_finite() && r.act_mse >= 0.0, "{}", r.label);
        assert!(r.weight_mse.is_finite() && r.weight_mse >= 0.0, "{}", r.label);
        assert!(r.score.is_none(), "offline sweep must not fabricate scores");
    }
    let j = sweep::report_json(&results, 2, 1.0, 64, 3, &[tq::model::manifest::Architecture::Bert])
        .to_string();
    assert!(tq::util::json::Json::parse(&j).is_ok());

    // The runtime-backed pass skips gracefully when artifacts are absent.
    let Some(ctx) = ctx() else {
        eprintln!("SKIP: runtime-backed sweep (no artifacts)");
        return;
    };
    let task = task_spec("sst2").unwrap();
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 13);
    let scores = sweep::runtime_scores(&ctx, &task, &params, &cfgs, 1, &Pool::new(2));
    assert_eq!(scores.len(), 2);
    for s in scores {
        let s = s.unwrap();
        assert!((0.0..=100.0).contains(&s));
    }

    // A PEG cell with per-group MSE ranges runs the full runtime pipeline
    // too: calibrate (row-sampling trackers) → per-group search → eval.
    let peg_cfgs = sweep::grid(
        64,
        &[tq::model::manifest::Architecture::Bert],
        &[8],
        &[8],
        &[6],
        &[Estimator::CurrentMinMax],
        &[RangeMethod::MsePerGroup],
    )
    .unwrap();
    assert_eq!(peg_cfgs.len(), 1);
    assert!(peg_cfgs[0].label().contains("mse_group"), "{}", peg_cfgs[0].label());
    let peg_scores = sweep::runtime_scores(&ctx, &task, &params, &peg_cfgs, 1, &Pool::new(2));
    let s = peg_scores.into_iter().next().unwrap().unwrap();
    assert!((0.0..=100.0).contains(&s));
}
