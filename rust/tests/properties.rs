//! Property tests over the quantization substrate, driven by the
//! `util::prop` mini-driver (seeded, replayable with TQ_PROP_SEED):
//!
//! * `qparams_from_range` + `quantize_dequantize` invariants — round-trip
//!   error ≤ scale/2 on in-range inputs, exact zero representation, and
//!   clamping at the grid edges — for 2/4/8-bit symmetric and asymmetric
//!   grids.
//! * PEG invariants — the range permutation is a valid permutation sorted
//!   by range, `group_bounds` partitions `d` exactly for every dividing
//!   `K`, and the K=1 / K=d endpoints coincide with per-tensor /
//!   per-embedding parameters.
//! * `QuantSpec` serialization invariants — parse → serialize → parse is
//!   the identity for randomly generated specs, the canonical JSON is a
//!   fixed point, and `spec_id` is stable across round-trips while the
//!   cosmetic label never affects it.

use std::collections::BTreeMap;

use tq::model::qconfig::{SiteCfg, WeightCfg};
use tq::quant::peg::{group_bounds, lane_qparams, range_permutation};
use tq::quant::{
    qdq, qparams_from_range, qparams_symmetric, Estimator, Granularity, QGrid, QParams,
    RangeMethod,
};
use tq::spec::{AdaRoundSpec, CalibSpec, PolicySpec, QuantSpec, SiteRule, SiteSelector};
use tq::util::prop::{prop_assert, prop_check, vec_f32};
use tq::util::rng::Rng;

const BITS: [u32; 3] = [2, 4, 8];

#[test]
fn prop_roundtrip_error_bounded_asymmetric() {
    prop_check("asym |x - qdq(x)| <= s/2", 400, |rng| {
        let bits = BITS[rng.below(3)];
        let grid = QGrid::asymmetric(bits);
        let lo = rng.uniform(-50.0, 0.0);
        let hi = rng.uniform(0.1, 50.0);
        let p = qparams_from_range(lo, hi, grid);
        // in-range input (the derived range always covers [min(lo,0), max(hi,0)])
        let x = rng.uniform(lo.min(0.0), hi.max(0.0));
        let err = (x - qdq(x, p, grid)).abs();
        prop_assert(
            err <= p.scale / 2.0 + p.scale * 1e-3,
            format!("bits={bits} x={x} err={err} scale={}", p.scale),
        )
    });
}

#[test]
fn prop_roundtrip_error_bounded_symmetric() {
    prop_check("sym |x - qdq(x)| <= s/2", 400, |rng| {
        let bits = BITS[rng.below(3)];
        let grid = QGrid::symmetric(bits);
        let amax = rng.uniform(0.1, 50.0);
        let p = qparams_symmetric(amax, grid);
        let x = rng.uniform(-amax, amax);
        let err = (x - qdq(x, p, grid)).abs();
        prop_assert(
            err <= p.scale / 2.0 + p.scale * 1e-3,
            format!("bits={bits} x={x} err={err} scale={}", p.scale),
        )
    });
}

#[test]
fn prop_zero_exactly_representable() {
    prop_check("qdq(0) == 0", 400, |rng| {
        let bits = BITS[rng.below(3)];
        let (p, grid) = if rng.bool(0.5) {
            let grid = QGrid::asymmetric(bits);
            (qparams_from_range(rng.uniform(-30.0, 5.0), rng.uniform(-5.0, 30.0), grid), grid)
        } else {
            let grid = QGrid::symmetric(bits);
            (qparams_symmetric(rng.uniform(0.1, 30.0), grid), grid)
        };
        let z = qdq(0.0, p, grid);
        // zero must hit a grid point exactly (zero_point is integral)
        prop_assert(z == 0.0, format!("bits={bits} qdq(0)={z} p={p:?}"))
    });
}

#[test]
fn prop_clamps_at_grid_edges() {
    prop_check("clamp at edges", 300, |rng| {
        let bits = BITS[rng.below(3)];
        let grid = QGrid::asymmetric(bits);
        let lo = rng.uniform(-10.0, 0.0);
        let hi = rng.uniform(0.5, 10.0);
        let p = qparams_from_range(lo, hi, grid);
        // the largest/smallest representable values on this grid
        let top = p.scale * (grid.qmax - p.zero_point);
        let bottom = p.scale * (grid.qmin - p.zero_point);
        for mult in [2.0f32, 10.0, 1e4] {
            let up = qdq(hi * mult, p, grid);
            let down = qdq(lo.min(-0.01) * mult, p, grid);
            prop_assert(
                (up - top).abs() <= p.scale * 1e-3,
                format!("bits={bits} overflow {up} != top {top}"),
            )?;
            prop_assert(
                (down - bottom).abs() <= p.scale * 1e-3,
                format!("bits={bits} underflow {down} != bottom {bottom}"),
            )?;
        }
        // saturation: everything past the edge maps to the same value
        let a = qdq(hi * 3.0, p, grid);
        let b = qdq(hi * 300.0, p, grid);
        prop_assert(a == b, format!("saturation {a} vs {b}"))
    });
}

#[test]
fn prop_qdq_outputs_on_grid() {
    prop_check("outputs on grid", 300, |rng| {
        let bits = BITS[rng.below(3)];
        let grid = QGrid::asymmetric(bits);
        let p = qparams_from_range(rng.uniform(-8.0, 0.0), rng.uniform(0.1, 8.0), grid);
        for x in vec_f32(rng, 16, -12.0, 12.0) {
            let y = qdq(x, p, grid);
            let q = y / p.scale + p.zero_point;
            prop_assert(
                (q - q.round()).abs() < 1e-3 * (1.0 + q.abs()),
                format!("off-grid y={y} q={q}"),
            )?;
        }
        Ok(())
    });
}

// ---- PEG invariants ---------------------------------------------------

#[test]
fn prop_range_permutation_is_valid_and_sorted() {
    prop_check("range permutation", 300, |rng| {
        let d = 1 + rng.below(64);
        let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-20.0, 0.0)).collect();
        let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 20.0)).collect();
        let perm = range_permutation(&lo, &hi);
        // valid permutation: each index exactly once
        let mut seen = vec![false; d];
        for &j in &perm {
            prop_assert(j < d && !seen[j], format!("bad perm entry {j}"))?;
            seen[j] = true;
        }
        // sorted by ascending range
        for w in perm.windows(2) {
            let ra = hi[w[0]] - lo[w[0]];
            let rb = hi[w[1]] - lo[w[1]];
            prop_assert(ra <= rb, format!("not sorted: {ra} > {rb}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_group_bounds_partition_exactly() {
    prop_check("group bounds partition", 300, |rng| {
        let d = 1 + rng.below(256);
        // every dividing k
        for k in 1..=d {
            if d % k != 0 {
                continue;
            }
            let bounds = group_bounds(d, k);
            prop_assert(bounds.len() == k, format!("d={d} k={k}: {} groups", bounds.len()))?;
            let mut expected_start = 0usize;
            for &(g0, g1) in &bounds {
                prop_assert(
                    g0 == expected_start,
                    format!("d={d} k={k}: gap/overlap at {g0} (want {expected_start})"),
                )?;
                prop_assert(
                    g1 - g0 == d / k,
                    format!("d={d} k={k}: uneven group [{g0},{g1})"),
                )?;
                expected_start = g1;
            }
            prop_assert(expected_start == d, format!("d={d} k={k}: covers {expected_start}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_k1_matches_per_tensor_and_kd_matches_per_embedding() {
    prop_check("PEG endpoints", 200, |rng| {
        let d = [4usize, 8, 16, 32][rng.below(4)];
        let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-15.0, 0.0)).collect();
        let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 15.0)).collect();
        let grid = QGrid::asymmetric([2u32, 4, 8][rng.below(3)]);
        let permute = rng.bool(0.5);

        let (pt, _) = lane_qparams(&lo, &hi, &Granularity::PerTensor, grid).unwrap();
        let (k1, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 1, permute },
            grid,
        )
        .unwrap();
        prop_assert(pt == k1, format!("K=1 != per-tensor: {k1:?} vs {pt:?}"))?;
        // K=1 carries exactly one distinct parameter pair
        prop_assert(
            distinct_params(&k1) == 1,
            format!("K=1 has {} distinct params", distinct_params(&k1)),
        )?;

        let (pe, _) = lane_qparams(&lo, &hi, &Granularity::PerEmbedding, grid).unwrap();
        let (kd, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: d, permute },
            grid,
        )
        .unwrap();
        prop_assert(pe == kd, format!("K=d != per-embedding"))?;

        // intermediate K: at most K distinct parameter pairs
        let k = d / 2;
        let (km, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k, permute },
            grid,
        )
        .unwrap();
        prop_assert(
            distinct_params(&km) <= k,
            format!("K={k} has {} distinct params", distinct_params(&km)),
        )
    });
}

fn distinct_params(params: &[QParams]) -> usize {
    let mut keys: Vec<(u32, u32)> = params
        .iter()
        .map(|p| (p.scale.to_bits(), p.zero_point.to_bits()))
        .collect();
    keys.sort();
    keys.dedup();
    keys.len()
}

// ---- QuantSpec serialization invariants --------------------------------

const ESTIMATORS: [Estimator; 3] =
    [Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse];

fn rand_granularity(rng: &mut Rng) -> Granularity {
    match rng.below(4) {
        0 => Granularity::PerTensor,
        1 => Granularity::PerEmbedding,
        2 => Granularity::PerEmbeddingGroup { k: 2 + rng.below(15), permute: false },
        _ => Granularity::PerEmbeddingGroup { k: 2 + rng.below(15), permute: true },
    }
}

fn rand_site_cfg(rng: &mut Rng) -> SiteCfg {
    SiteCfg {
        bits: [2u32, 4, 8, 16][rng.below(4)],
        granularity: rand_granularity(rng),
        // mse_tensor is excluded: it only composes with per-tensor
        // granularity (the assembly rejects other pairings), and these
        // random specs exercise serialization, not assembly
        range_method: [
            RangeMethod::Auto,
            RangeMethod::CurrentMinMax,
            RangeMethod::MsePerGroup,
        ][rng.below(3)],
        enabled: rng.bool(0.8),
    }
}

fn rand_weight_cfg(rng: &mut Rng) -> WeightCfg {
    WeightCfg {
        bits: [2u32, 4, 6, 8][rng.below(4)],
        estimator: ESTIMATORS[rng.below(3)],
        per_channel_groups: if rng.bool(0.3) { Some(1 + rng.below(16)) } else { None },
        enabled: rng.bool(0.8),
    }
}

fn rand_selector(rng: &mut Rng) -> SiteSelector {
    let fam = ["res2_sum", "ln1_out", "ffn_out", "attn_scores"][rng.below(4)].to_string();
    match rng.below(3) {
        0 => SiteSelector::Exact(format!("layer{}.{fam}", rng.below(6))),
        1 => SiteSelector::Family(fam),
        _ => SiteSelector::FamilyLastLayers { suffix: fam, n: 1 + rng.below(3) },
    }
}

fn rand_spec(rng: &mut Rng) -> QuantSpec {
    let mut weight_overrides = BTreeMap::new();
    if rng.bool(0.5) {
        weight_overrides.insert("embed.tok".to_string(), rand_weight_cfg(rng));
    }
    let policy = PolicySpec {
        default_site: rand_site_cfg(rng),
        rules: (0..rng.below(4))
            .map(|_| SiteRule { select: rand_selector(rng), cfg: rand_site_cfg(rng) })
            .collect(),
        weights: rand_weight_cfg(rng),
        weight_overrides,
    };
    let mut spec = QuantSpec::new("prop", policy);
    spec.calib = CalibSpec {
        estimator: ESTIMATORS[rng.below(3)],
        batch_size: 1 + rng.below(4),
        num_batches: 1 + rng.below(16),
        collect_grams: rng.bool(0.2),
        seed: rng.next_u64() % 1_000_000,
    };
    spec.adaround = AdaRoundSpec {
        enabled: rng.bool(0.2),
        iters: 100 + rng.below(1000),
        lr: rng.uniform(1e-3, 1e-1),
    };
    spec.seeds = 1 + rng.below(5);
    if rng.bool(0.5) {
        spec.tasks = vec!["mnli".to_string(), "rte".to_string()];
    }
    spec
}

#[test]
fn prop_spec_json_roundtrip_is_identity() {
    prop_check("spec json roundtrip", 300, |rng| {
        let spec = rand_spec(rng);
        let text = spec.to_json().to_string();
        let back = match QuantSpec::parse(&text) {
            Ok(b) => b,
            Err(e) => return Err(format!("parse failed: {e}\n{text}")),
        };
        prop_assert(back == spec, format!("roundtrip changed the spec:\n{text}"))?;
        // canonical serialization is a fixed point (byte-for-byte)
        prop_assert(
            back.to_json().to_string() == text,
            "canonical JSON is not a serialization fixed point",
        )?;
        prop_assert(
            back.spec_id() == spec.spec_id(),
            "spec_id changed across a JSON roundtrip",
        )
    });
}

#[test]
fn prop_spec_id_is_label_blind_but_config_sensitive() {
    prop_check("spec_id semantics", 200, |rng| {
        let spec = rand_spec(rng);
        let id = spec.spec_id();

        let mut renamed = spec.clone();
        renamed.name = format!("renamed-{}", rng.below(100));
        prop_assert(renamed.spec_id() == id, "renaming changed spec_id")?;

        let mut changed = spec.clone();
        changed.seeds += 1;
        prop_assert(changed.spec_id() != id, "seed-count change kept spec_id")?;

        let mut reseeded = spec;
        reseeded.calib.seed += 1;
        prop_assert(reseeded.spec_id() != id, "calib-seed change kept spec_id")
    });
}

#[test]
fn prop_spec_id_is_stable_across_key_order() {
    // Re-serializing a parsed spec always emits sorted object keys, so a
    // file written with any key order hashes identically after parsing.
    prop_check("spec_id key order", 100, |rng| {
        let spec = rand_spec(rng);
        let j = spec.to_json();
        // hand-scramble the top-level key order in the JSON text
        let (name, policy, calib, adaround, seeds, tasks) = (
            j.get("name").unwrap(),
            j.get("policy").unwrap(),
            j.get("calib").unwrap(),
            j.get("adaround").unwrap(),
            j.get("seeds").unwrap(),
            j.get("tasks").unwrap(),
        );
        let scrambled = format!(
            r#"{{"tasks": {tasks}, "seeds": {seeds}, "policy": {policy},
                "name": {name}, "calib": {calib}, "adaround": {adaround}}}"#
        );
        let back = QuantSpec::parse(&scrambled).map_err(|e| format!("parse: {e}"))?;
        prop_assert(back == spec, "scrambled key order changed the spec")?;
        prop_assert(
            back.spec_id() == spec.spec_id(),
            "scrambled key order changed spec_id",
        )
    });
}
