//! Per-embedding-group (PEG) property & golden suite.
//!
//! Locks down the paper's headline mechanism end to end:
//! * `range_permutation` returns a valid permutation for ANY input —
//!   NaN/inf lanes included (a non-total comparator can make `sort_by`
//!   panic, so this is a real failure mode, not paranoia);
//! * `group_bounds(d, k)` partitions `0..d` exactly for every `k`,
//!   dividing or not;
//! * grouped qparams always cover each member lane's range;
//! * the synthetic-outlier golden fixture (one hot lane, paper §3):
//!   PEG-k strictly beats per-tensor at equal bit-width, and degrades
//!   gracefully to per-tensor at K=1 and per-lane at K=d.

use tq::model::qconfig::{site_lane_params_pool, SiteCfg};
use tq::quant::estimators::RangeTracker;
use tq::quant::peg::{group_bounds, lane_qparams, range_permutation, site_groups};
use tq::quant::{qdq_per_lane, Estimator, Granularity, QGrid, RangeMethod};
use tq::tensor::Tensor;
use tq::util::pool::Pool;
use tq::util::prop::{prop_assert, prop_check};
use tq::util::rng::Rng;

fn is_permutation(p: &[usize], d: usize) -> bool {
    let mut seen = vec![false; d];
    p.len() == d
        && p.iter().all(|&j| {
            if j < d && !seen[j] {
                seen[j] = true;
                true
            } else {
                false
            }
        })
}

#[test]
fn prop_range_permutation_is_valid_for_any_input() {
    prop_check("permutation total", 300, |rng| {
        let d = 1 + rng.below(32);
        let mut lo: Vec<f32> = (0..d).map(|_| rng.uniform(-50.0, 0.0)).collect();
        let mut hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 50.0)).collect();
        // poison a random subset of lanes with NaN / ±inf statistics
        for _ in 0..rng.below(d + 1) {
            let j = rng.below(d);
            match rng.below(4) {
                0 => lo[j] = f32::NAN,
                1 => hi[j] = f32::NAN,
                2 => lo[j] = f32::NEG_INFINITY,
                _ => hi[j] = f32::INFINITY,
            }
        }
        let p = range_permutation(&lo, &hi);
        prop_assert(is_permutation(&p, d), format!("invalid permutation {p:?} for d={d}"))
    });
}

#[test]
fn prop_group_bounds_partition_any_k() {
    prop_check("group bounds partition any k", 300, |rng| {
        let d = 1 + rng.below(200);
        let k = 1 + rng.below(d);
        let bounds = group_bounds(d, k);
        prop_assert(bounds.len() == k, format!("{} groups, wanted {k}", bounds.len()))?;
        prop_assert(bounds[0].0 == 0 && bounds[k - 1].1 == d, format!("ends {bounds:?}"))?;
        for w in bounds.windows(2) {
            prop_assert(
                w[0].1 == w[1].0,
                format!("gap/overlap between {:?} and {:?}", w[0], w[1]),
            )?;
        }
        let sizes: Vec<usize> = bounds.iter().map(|(a, b)| b - a).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        prop_assert(max - min <= 1, format!("uneven by >1: {sizes:?} (d={d} k={k})"))
    });
}

#[test]
fn prop_site_groups_cover_every_lane_once() {
    prop_check("site groups partition", 200, |rng| {
        let d = 1 + rng.below(40);
        let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-10.0, 0.0)).collect();
        let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 10.0)).collect();
        let gran = match rng.below(3) {
            0 => Granularity::PerTensor,
            1 => Granularity::PerEmbedding,
            _ => Granularity::PerEmbeddingGroup {
                k: 1 + rng.below(d + 4), // may exceed d: must clamp, not panic
                permute: rng.bool(0.5),
            },
        };
        let (groups, order) = site_groups(&lo, &hi, &gran).unwrap();
        prop_assert(is_permutation(&order, d), format!("order not a permutation: {order:?}"))?;
        let mut count = vec![0usize; d];
        for g in &groups {
            for &j in g {
                prop_assert(j < d, format!("lane {j} out of range"))?;
                count[j] += 1;
            }
        }
        prop_assert(
            count.iter().all(|&c| c == 1),
            format!("lanes not covered exactly once: {count:?} ({gran:?})"),
        )
    });
}

#[test]
fn prop_grouped_qparams_cover_member_lane_ranges() {
    prop_check("peg coverage", 200, |rng| {
        let d = 2 + rng.below(30);
        let k = 1 + rng.below(d); // any K, dividing or not
        let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-20.0, 0.0)).collect();
        let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 20.0)).collect();
        let grid = QGrid::asymmetric([4u32, 8][rng.below(2)]);
        let permute = rng.bool(0.5);
        let (params, _) =
            lane_qparams(&lo, &hi, &Granularity::PerEmbeddingGroup { k, permute }, grid)
                .unwrap();
        for j in 0..d {
            let covered = params[j].scale * grid.levels() + 1e-3;
            prop_assert(
                covered >= hi[j] - lo[j],
                format!(
                    "lane {j}: scale {} covers {covered} < {} (d={d} k={k} permute={permute})",
                    params[j].scale,
                    hi[j] - lo[j]
                ),
            )?;
        }
        Ok(())
    });
}

/// The golden fixture: rows of mostly-unit activations with ONE hot lane
/// (the paper §3 structured-outlier shape). Returns (tensor, tracker).
fn hot_lane_fixture(d: usize, rows: usize, hot: usize, seed: u64) -> (Tensor, RangeTracker) {
    let mut rng = Rng::new(seed);
    let t = Tensor::from_fn(&[rows, d], |i| {
        let lane = i % d;
        let mag = if lane == hot { 30.0 } else { 1.0 };
        rng.normal_f32(0.0, mag)
    });
    let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d);
    tr.observe(&t).unwrap();
    (t, tr)
}

#[test]
fn golden_peg_beats_per_tensor_and_degrades_gracefully() {
    let d = 16;
    let (t, tr) = hot_lane_fixture(d, 512, 11, 5);
    let (lo, hi) = tr.lane_ranges();
    let grid = QGrid::asymmetric(8);
    let err = |gran: &Granularity| -> f32 {
        let (params, _) = lane_qparams(&lo, &hi, gran, grid).unwrap();
        qdq_per_lane(&t, &params, grid).unwrap().mse(&t).unwrap()
    };

    let e_pt = err(&Granularity::PerTensor);
    let e_pe = err(&Granularity::PerEmbedding);
    // PEG-k (k>1, permuted) strictly beats per-tensor at the same bits:
    // the hot lane is isolated, every other group gets a tight scale.
    // With one hot lane out of d, a K-group split leaves ~d/K lanes
    // sharing the wide range, so the MSE shrinks roughly like 1/K.
    for k in [2usize, 4, 8] {
        let e_k = err(&Granularity::PerEmbeddingGroup { k, permute: true });
        assert!(
            e_k < e_pt * 0.75,
            "PEG-{k} MSE {e_k} not strictly below per-tensor {e_pt}"
        );
        // and never beats the per-lane floor (up to f32 noise)
        assert!(e_pe <= e_k * 1.01, "per-lane {e_pe} worse than PEG-{k} {e_k}");
    }
    let e_8 = err(&Granularity::PerEmbeddingGroup { k: 8, permute: true });
    assert!(e_8 < e_pt * 0.3, "PEG-8 {e_8} should approach the per-lane floor {e_pt}");

    // K=1 is exactly per-tensor, K=d exactly per-lane — bit for bit
    let (p_pt, _) = lane_qparams(&lo, &hi, &Granularity::PerTensor, grid).unwrap();
    let (p_k1, _) = lane_qparams(
        &lo,
        &hi,
        &Granularity::PerEmbeddingGroup { k: 1, permute: false },
        grid,
    )
    .unwrap();
    assert_eq!(p_pt, p_k1, "K=1 must equal per-tensor");
    let (p_pe, _) = lane_qparams(&lo, &hi, &Granularity::PerEmbedding, grid).unwrap();
    let (p_kd, _) = lane_qparams(
        &lo,
        &hi,
        &Granularity::PerEmbeddingGroup { k: d, permute: true },
        grid,
    )
    .unwrap();
    assert_eq!(p_pe.len(), p_kd.len());
    for (a, b) in p_pe.iter().zip(&p_kd) {
        assert_eq!(a.scale.to_bits(), b.scale.to_bits(), "K=d must equal per-lane");
        assert_eq!(a.zero_point.to_bits(), b.zero_point.to_bits());
    }
}

#[test]
fn golden_per_group_mse_refines_the_minmax_groups() {
    // same hot-lane structure, plus a single far outlier in the hot lane:
    // at 4 bits the mse_group search clips it, min-max grouping cannot
    let d = 16;
    let rows = 2000;
    let mut rng = Rng::new(9);
    let t = Tensor::from_fn(&[rows, d], |i| {
        let (row, lane) = (i / d, i % d);
        if lane == 11 {
            if row == 777 { 200.0 } else { rng.uniform(0.0, 10.0) }
        } else {
            rng.uniform(0.0, 1.0)
        }
    });
    let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
    tr.observe(&t).unwrap();
    let grid = QGrid::asymmetric(4);
    let pool = Pool::serial();
    let cfg = |m: RangeMethod| SiteCfg {
        bits: 4,
        granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
        range_method: m,
        enabled: true,
    };
    let err = |m: RangeMethod| -> f32 {
        let (params, _) = site_lane_params_pool(&tr, &cfg(m), grid, &pool).unwrap();
        qdq_per_lane(&t, &params, grid).unwrap().mse(&t).unwrap()
    };
    let e_minmax = err(RangeMethod::CurrentMinMax);
    let e_searched = err(RangeMethod::MsePerGroup);
    assert!(
        e_searched < e_minmax * 0.8,
        "per-group MSE {e_searched} not below min-max grouping {e_minmax}"
    );
}
