//! Parallel-vs-serial determinism: every pooled path must produce
//! bit-identical results with one worker and with
//! `available_parallelism()` workers, on seeded random tensors. This is
//! the contract that lets the sweep engine spend threads freely without
//! perturbing any paper reproduction.

use tq::coordinator::sweep::{grid, run_offline, synth_data};
use tq::quant::adaround::{adaround_with_gram_pool, AdaRoundCfg};
use tq::quant::estimators::{mse_search_pool, RangeTracker};
use tq::quant::{
    qdq_per_lane_pool, qdq_slice_pool, qdq_weight_per_channel_pool, qparams_from_range,
    qparams_symmetric, Estimator, QGrid, QParams,
};
use tq::tensor::Tensor;
use tq::util::pool::Pool;
use tq::util::rng::Rng;

fn pools() -> (Pool, Pool) {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (Pool::new(1), Pool::new(n.max(2)))
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn estimator_observe_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    for est in [Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse] {
        for lanes in [1usize, 96] {
            let mut rng = Rng::new(11);
            let mut a = RangeTracker::new(est, lanes);
            let mut b = RangeTracker::new(est, lanes);
            for _ in 0..4 {
                // big enough to cross the parallel thresholds
                let t = Tensor::randn(&[600, 96], 2.0, &mut rng);
                a.observe_pool(&t, &serial).unwrap();
                b.observe_pool(&t, &parallel).unwrap();
            }
            let (alo, ahi) = a.lane_ranges();
            let (blo, bhi) = b.lane_ranges();
            assert_eq!(bits(&alo), bits(&blo), "{est:?} lanes={lanes} lo");
            assert_eq!(bits(&ahi), bits(&bhi), "{est:?} lanes={lanes} hi");
            let grid8 = QGrid::asymmetric(8);
            let (al, ah) = a.tensor_range_pool(grid8, &serial);
            let (bl, bh) = b.tensor_range_pool(grid8, &parallel);
            assert_eq!(al.to_bits(), bl.to_bits(), "{est:?} range lo");
            assert_eq!(ah.to_bits(), bh.to_bits(), "{est:?} range hi");
        }
    }
}

#[test]
fn mse_search_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(5);
    let samples: Vec<f32> = (0..50_000).map(|_| rng.normal_f32(0.0, 3.0)).collect();
    for bits_w in [2u32, 4, 8] {
        let grid = QGrid::asymmetric(bits_w);
        let a = mse_search_pool(&samples, -9.0, 11.0, grid, &serial);
        let b = mse_search_pool(&samples, -9.0, 11.0, grid, &parallel);
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "bits={bits_w}");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "bits={bits_w}");
    }
}

#[test]
fn weight_qdq_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(9);
    let w = Tensor::randn(&[256, 384], 0.5, &mut rng);
    let grid = QGrid::symmetric(4);
    let p = qparams_symmetric(w.abs_max(), grid);

    let mut xs_a = w.data().to_vec();
    let mut xs_b = w.data().to_vec();
    qdq_slice_pool(&mut xs_a, p, grid, &serial);
    qdq_slice_pool(&mut xs_b, p, grid, &parallel);
    assert_eq!(bits(&xs_a), bits(&xs_b));

    let a = qdq_weight_per_channel_pool(&w, 4, 16, &serial).unwrap();
    let b = qdq_weight_per_channel_pool(&w, 4, 16, &parallel).unwrap();
    assert_eq!(bits(a.data()), bits(b.data()));
}

#[test]
fn per_lane_qdq_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(21);
    let d = 128;
    let t = Tensor::randn(&[512, d], 2.0, &mut rng);
    let grid = QGrid::asymmetric(8);
    let params: Vec<QParams> = (0..d)
        .map(|j| qparams_from_range(-1.0 - j as f32 * 0.01, 1.0 + j as f32 * 0.02, grid))
        .collect();
    let a = qdq_per_lane_pool(&t, &params, grid, &serial).unwrap();
    let b = qdq_per_lane_pool(&t, &params, grid, &parallel).unwrap();
    assert_eq!(bits(a.data()), bits(b.data()));
}

#[test]
fn adaround_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(33);
    // big enough that both the Gram matmul (96*384 = 36864 output elems)
    // and the Adam update (36864 lanes) cross their parallel thresholds
    let w = Tensor::randn(&[96, 384], 0.5, &mut rng);
    let z = Tensor::randn(&[128, 96], 1.0, &mut rng);
    let mix = Tensor::randn(&[96, 96], (1.0f32 / 96.0).sqrt(), &mut rng);
    let x = z.matmul(&mix).unwrap();
    let g = x.transpose2().unwrap().matmul(&x).unwrap();
    let grid = QGrid::symmetric(3);
    let p = qparams_symmetric(w.abs_max(), grid);
    let cfg = AdaRoundCfg { iters: 40, ..Default::default() };
    let n = x.shape()[0] as f32;

    let a = adaround_with_gram_pool(&w, &g, n, p, grid, &cfg, &serial).unwrap();
    let b = adaround_with_gram_pool(&w, &g, n, p, grid, &cfg, &parallel).unwrap();
    assert_eq!(bits(a.weight.data()), bits(b.weight.data()));
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.initial_loss.to_bits(), b.initial_loss.to_bits());
}

#[test]
fn offline_sweep_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let data = synth_data(128, 48, 4, 99);
    let cfgs = grid(
        128,
        &[8, 4],
        &[8],
        &[1, 8, 128],
        &[Estimator::CurrentMinMax, Estimator::Mse],
    )
    .unwrap();
    assert!(cfgs.len() >= 4, "sweep smoke needs >= 4 configs");
    let a = run_offline(&data, &cfgs, &serial).unwrap();
    let b = run_offline(&data, &cfgs, &parallel).unwrap();
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.label, rb.label);
        assert_eq!(ra.act_mse.to_bits(), rb.act_mse.to_bits(), "{}", ra.label);
        assert_eq!(ra.weight_mse.to_bits(), rb.weight_mse.to_bits(), "{}", ra.label);
    }
}
