//! Parallel-vs-serial determinism: every pooled path must produce
//! bit-identical results with one worker and with
//! `available_parallelism()` workers, on seeded random tensors. This is
//! the contract that lets the sweep engine spend threads freely without
//! perturbing any paper reproduction.
//!
//! The `calibrate_eval_*` test additionally pins the batch-parallel
//! executable hot loop (`Runtime::run_batch` through the persistent
//! pool): a full calibrate → evaluate run must score bit-identically on
//! a 1-thread and an 8-thread `Ctx` — the in-process equivalent of
//! `TQ_THREADS=1` vs `TQ_THREADS=8 repro smoke`.
//!
//! `planned_engine_matches_naive_across_thread_counts` extends that to
//! the interpreter engines: the preplanned execution engine (`hlo::plan`)
//! and the naive per-instruction interpreter must agree bit-for-bit at
//! every thread count.
//!
//! The `serve_*` tests pin the serving layer's contracts: the
//! continuous-batching dispatcher is bit-identical to direct `run_batch`
//! at every thread count and batch window, admission control sheds
//! explicitly at depth, shutdown drains every admitted request exactly
//! once, and the model cache's counters fold into `RuntimeStats`.

use std::sync::Arc;
use std::time::Duration;

use tq::coordinator::calibrate::{calibrate, calibrate_arch, calibrate_with, CalibCfg};
use tq::coordinator::sweep::{
    grid, merge_results, report_json, run_offline, shard_of, synth_data,
};
use tq::coordinator::{batch_input_lits, diagnostics, eval, Ctx, EVAL_BATCH};
use tq::data::{make_batch, task_spec, TaskSpec};
use tq::model::manifest::{Architecture, AttnVariant};
use tq::model::qconfig::{
    assemble_act_tensors, assemble_act_tensors_pool, site_lane_params_pool, QuantPolicy,
    SiteCfg,
};
use tq::model::Params;
use tq::quant::adaround::{adaround_with_gram_pool, AdaRoundCfg};
use tq::quant::estimators::{mse_search_pool, RangeTracker};
use tq::quant::{
    qdq_per_lane_pool, qdq_slice_pool, qdq_weight_per_channel_pool, qparams_from_range,
    qparams_symmetric, Estimator, Granularity, QGrid, QParams, RangeMethod,
};
use tq::serve::{
    CacheStats, ModelCache, ServeConfig, ServeModel, Server, SubmitError, Ticket,
};
use tq::spec::run::AssembledModel;
use tq::tensor::Tensor;
use tq::util::pool::Pool;
use tq::util::rng::Rng;

fn pools() -> (Pool, Pool) {
    let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (Pool::new(1), Pool::new(n.max(2)))
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn estimator_observe_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    for est in [Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse] {
        for lanes in [1usize, 96] {
            let mut rng = Rng::new(11);
            let mut a = RangeTracker::new(est, lanes);
            let mut b = RangeTracker::new(est, lanes);
            for _ in 0..4 {
                // big enough to cross the parallel thresholds
                let t = Tensor::randn(&[600, 96], 2.0, &mut rng);
                a.observe_pool(&t, &serial).unwrap();
                b.observe_pool(&t, &parallel).unwrap();
            }
            let (alo, ahi) = a.lane_ranges();
            let (blo, bhi) = b.lane_ranges();
            assert_eq!(bits(&alo), bits(&blo), "{est:?} lanes={lanes} lo");
            assert_eq!(bits(&ahi), bits(&bhi), "{est:?} lanes={lanes} hi");
            let grid8 = QGrid::asymmetric(8);
            let (al, ah) = a.tensor_range_pool(grid8, &serial);
            let (bl, bh) = b.tensor_range_pool(grid8, &parallel);
            assert_eq!(al.to_bits(), bl.to_bits(), "{est:?} range lo");
            assert_eq!(ah.to_bits(), bh.to_bits(), "{est:?} range hi");
        }
    }
}

/// Per-group MSE search (the PEG range pipeline: tracker → permutation →
/// groups → per-group grid search → lane qparams) must choose
/// bit-identical parameters on a serial and a many-worker pool.
#[test]
fn peg_group_mse_search_is_pool_size_independent() {
    let (serial, parallel) = pools();
    let d = 48;
    let cfg = SiteCfg {
        bits: 4,
        granularity: Granularity::PerEmbeddingGroup { k: 6, permute: true },
        range_method: RangeMethod::MsePerGroup,
        enabled: true,
    };
    for pool_pair in [(&serial, &serial), (&serial, &parallel), (&parallel, &serial)] {
        let mut rng = Rng::new(41);
        let mut a = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
        let mut b = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
        for _ in 0..3 {
            let t = Tensor::from_fn(&[400, d], |i| {
                let lane = i % d;
                let mag = if lane % 11 == 2 { 40.0 } else { 1.0 };
                rng.normal_f32(0.0, mag)
            });
            a.observe_pool(&t, pool_pair.0).unwrap();
            b.observe_pool(&t, pool_pair.1).unwrap();
        }
        let grid4 = QGrid::asymmetric(4);
        let (pa, perm_a) = site_lane_params_pool(&a, &cfg, grid4, pool_pair.0).unwrap();
        let (pb, perm_b) = site_lane_params_pool(&b, &cfg, grid4, pool_pair.1).unwrap();
        assert_eq!(perm_a, perm_b, "permutation diverged across pools");
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.scale.to_bits(), y.scale.to_bits(), "scale diverged");
            assert_eq!(x.zero_point.to_bits(), y.zero_point.to_bits(), "zp diverged");
        }
    }
}

#[test]
fn mse_search_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(5);
    let samples: Vec<f32> = (0..50_000).map(|_| rng.normal_f32(0.0, 3.0)).collect();
    for bits_w in [2u32, 4, 8] {
        let grid = QGrid::asymmetric(bits_w);
        let a = mse_search_pool(&samples, -9.0, 11.0, grid, &serial);
        let b = mse_search_pool(&samples, -9.0, 11.0, grid, &parallel);
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "bits={bits_w}");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "bits={bits_w}");
    }
}

#[test]
fn weight_qdq_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(9);
    let w = Tensor::randn(&[256, 384], 0.5, &mut rng);
    let grid = QGrid::symmetric(4);
    let p = qparams_symmetric(w.abs_max(), grid);

    let mut xs_a = w.data().to_vec();
    let mut xs_b = w.data().to_vec();
    qdq_slice_pool(&mut xs_a, p, grid, &serial);
    qdq_slice_pool(&mut xs_b, p, grid, &parallel);
    assert_eq!(bits(&xs_a), bits(&xs_b));

    let a = qdq_weight_per_channel_pool(&w, 4, 16, &serial).unwrap();
    let b = qdq_weight_per_channel_pool(&w, 4, 16, &parallel).unwrap();
    assert_eq!(bits(a.data()), bits(b.data()));
}

#[test]
fn per_lane_qdq_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(21);
    let d = 128;
    let t = Tensor::randn(&[512, d], 2.0, &mut rng);
    let grid = QGrid::asymmetric(8);
    let params: Vec<QParams> = (0..d)
        .map(|j| qparams_from_range(-1.0 - j as f32 * 0.01, 1.0 + j as f32 * 0.02, grid))
        .collect();
    let a = qdq_per_lane_pool(&t, &params, grid, &serial).unwrap();
    let b = qdq_per_lane_pool(&t, &params, grid, &parallel).unwrap();
    assert_eq!(bits(a.data()), bits(b.data()));
}

#[test]
fn adaround_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let mut rng = Rng::new(33);
    // big enough that both the Gram matmul (96*384 = 36864 output elems)
    // and the Adam update (36864 lanes) cross their parallel thresholds
    let w = Tensor::randn(&[96, 384], 0.5, &mut rng);
    let z = Tensor::randn(&[128, 96], 1.0, &mut rng);
    let mix = Tensor::randn(&[96, 96], (1.0f32 / 96.0).sqrt(), &mut rng);
    let x = z.matmul(&mix).unwrap();
    let g = x.transpose2().unwrap().matmul(&x).unwrap();
    let grid = QGrid::symmetric(3);
    let p = qparams_symmetric(w.abs_max(), grid);
    let cfg = AdaRoundCfg { iters: 40, ..Default::default() };
    let n = x.shape()[0] as f32;

    let a = adaround_with_gram_pool(&w, &g, n, p, grid, &cfg, &serial).unwrap();
    let b = adaround_with_gram_pool(&w, &g, n, p, grid, &cfg, &parallel).unwrap();
    assert_eq!(bits(a.weight.data()), bits(b.weight.data()));
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.initial_loss.to_bits(), b.initial_loss.to_bits());
}

/// Full executable-hot-loop bit-identity: calibrate → assemble → evaluate
/// on a 1-thread pool vs an 8-thread pool over the same artifacts. This
/// is the contract behind `TQ_THREADS=N repro smoke` printing the same
/// score bits for every N. Requires artifacts (CI generates them before
/// `cargo test`; a bare checkout skips).
#[test]
fn calibrate_eval_is_parallel_deterministic() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let mut runs: Vec<(Vec<u32>, u64)> = Vec::new();
    for threads in [1usize, 8] {
        let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
            .unwrap()
            .with_pool(Pool::new(threads));
        let info = ctx.model_info(&task).unwrap();
        let params = Params::init(info, 17);
        // batch_size 2 exercises the concat path; grams exercise the
        // pooled Gram fan-out
        let cfg = CalibCfg {
            num_batches: 4,
            batch_size: 2,
            collect_grams: true,
            ..Default::default()
        };
        let calib = calibrate(&ctx, &task, &params, &cfg).unwrap();
        // estimator state must be bit-identical lane by lane
        let mut range_bits = Vec::new();
        for tr in calib.trackers.values() {
            let (lo, hi) = tr.lane_ranges();
            range_bits.extend(bits(&lo));
            range_bits.extend(bits(&hi));
        }
        let act =
            assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers).unwrap();
        let mut split = tq::data::dev_split(&task, info.config.seq).unwrap();
        // a non-multiple of the executable batch: the padded tail rows
        // must not perturb the score either
        split.examples.truncate(20);
        let score = eval::evaluate_split(&ctx, &task, &params, &act, &split).unwrap();
        runs.push((range_bits, score.to_bits()));
    }
    assert_eq!(runs[0].0, runs[1].0, "estimator ranges diverged across thread counts");
    assert_eq!(
        runs[0].1, runs[1].1,
        "dev score diverged: {} vs {}",
        f64::from_bits(runs[0].1),
        f64::from_bits(runs[1].1)
    );
}

/// The same hot-loop contract for the ViT frontend: calibrate → assemble
/// → evaluate against the `vit`/`vit_reg` artifacts (patch-embed pixels
/// input instead of token ids) must be bit-identical at 1 and 8 threads.
/// Skips when the artifacts predate the ViT fixture family.
#[test]
fn vit_calibrate_eval_is_parallel_deterministic() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let mut runs: Vec<(Vec<u32>, u64)> = Vec::new();
    for threads in [1usize, 8] {
        let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
            .unwrap()
            .with_pool(Pool::new(threads));
        let Ok(info) = ctx.model_info_for(&task, Architecture::Vit) else {
            eprintln!("SKIP: artifacts lack the vit model (regenerate with `repro gen-artifacts`)");
            return;
        };
        let params = Params::init(info, 23);
        let cfg = CalibCfg { num_batches: 4, batch_size: 2, ..Default::default() };
        let calib = calibrate_arch(&ctx, &task, Architecture::Vit, &params, &cfg).unwrap();
        let mut range_bits = Vec::new();
        for tr in calib.trackers.values() {
            let (lo, hi) = tr.lane_ranges();
            range_bits.extend(bits(&lo));
            range_bits.extend(bits(&hi));
        }
        let act =
            assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers).unwrap();
        let mut split = tq::data::dev_split(&task, info.config.seq).unwrap();
        split.examples.truncate(20);
        let score = eval::evaluate_split_arch(
            &ctx,
            &task,
            Architecture::Vit,
            &params,
            &act,
            &split,
        )
        .unwrap();
        runs.push((range_bits, score.to_bits()));
    }
    assert_eq!(runs[0].0, runs[1].0, "vit estimator ranges diverged across thread counts");
    assert_eq!(
        runs[0].1, runs[1].1,
        "vit dev score diverged: {} vs {}",
        f64::from_bits(runs[0].1),
        f64::from_bits(runs[1].1)
    );
}

/// Engine × thread-count bit-identity: the preplanned execution engine
/// (`hlo::plan`, the default interpreter hot path) must score
/// bit-identically to the naive per-instruction interpreter at 1 and 8
/// threads — 4-way equality over calibrate → assemble → evaluate. This is
/// the determinism half of the plan rework's contract: fusion, liveness,
/// borrowed-parameter envs, and the dot fast paths may change *when* work
/// happens, never *what* f32 operations run in what accumulation order.
#[test]
fn planned_engine_matches_naive_across_thread_counts() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let mut runs: Vec<(String, Vec<u32>, u64)> = Vec::new();
    for threads in [1usize, 8] {
        for naive in [false, true] {
            let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
                .unwrap()
                .with_pool(Pool::new(threads));
            ctx.rt.set_naive_interp(naive);
            let info = ctx.model_info(&task).unwrap();
            let params = Params::init(info, 17);
            let cfg = CalibCfg { num_batches: 4, batch_size: 2, ..Default::default() };
            let calib = calibrate(&ctx, &task, &params, &cfg).unwrap();
            let mut range_bits = Vec::new();
            for tr in calib.trackers.values() {
                let (lo, hi) = tr.lane_ranges();
                range_bits.extend(bits(&lo));
                range_bits.extend(bits(&hi));
            }
            let act =
                assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers)
                    .unwrap();
            let mut split = tq::data::dev_split(&task, info.config.seq).unwrap();
            split.examples.truncate(20);
            let score = eval::evaluate_split(&ctx, &task, &params, &act, &split).unwrap();
            let label = format!("threads={threads} engine={}", if naive { "naive" } else { "planned" });
            runs.push((label, range_bits, score.to_bits()));
        }
    }
    let (ref label0, ref ranges0, score0) = runs[0];
    for (label, ranges, score) in &runs[1..] {
        assert_eq!(ranges0, ranges, "{label} estimator ranges diverged from {label0}");
        assert_eq!(
            score0,
            *score,
            "{label} score diverged from {label0}: {} vs {}",
            f64::from_bits(score0),
            f64::from_bits(*score)
        );
    }
}

/// PEG with per-group MSE ranges through the real pipeline: calibrate
/// (row-sampling trackers) → assemble (per-group grid search) → evaluate
/// must be bit-identical on a 1-thread and an 8-thread `Ctx` — the PEG
/// analogue of `calibrate_eval_is_parallel_deterministic`, covering the
/// range_method plumbing end to end.
#[test]
fn peg_mse_group_calibrate_eval_is_parallel_deterministic() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let peg = SiteCfg {
        bits: 8,
        granularity: Granularity::PerEmbeddingGroup { k: 6, permute: true },
        range_method: RangeMethod::MsePerGroup,
        enabled: true,
    };
    let mut runs: Vec<(Vec<u32>, u64)> = Vec::new();
    for threads in [1usize, 8] {
        let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
            .unwrap()
            .with_pool(Pool::new(threads));
        let info = ctx.model_info(&task).unwrap();
        let params = Params::init(info, 29);
        let policy = QuantPolicy::uniform(8, 8)
            .with_site_family(info, "res2_sum", peg.clone())
            .with_site_family(info, "ffn_out", peg.clone());
        let cfg = CalibCfg { num_batches: 4, batch_size: 2, ..Default::default() };
        let calib = calibrate_with(&ctx, &task, &params, &cfg, Some(&policy)).unwrap();
        // mse_group sites really did retain row samples
        let tr = &calib.trackers["layer0.res2_sum"];
        assert!(tr.has_row_samples());
        assert!(tr.row_samples().unwrap().1 > 0, "no rows retained");
        let act =
            assemble_act_tensors_pool(info, &policy, &calib.trackers, &ctx.pool).unwrap();
        assert!(act.permutations.contains_key("layer0.res2_sum"));
        let mut scale_bits = bits(&act.scales);
        scale_bits.extend(bits(&act.zps));
        let mut split = tq::data::dev_split(&task, info.config.seq).unwrap();
        split.examples.truncate(20);
        let score = eval::evaluate_split(&ctx, &task, &params, &act, &split).unwrap();
        runs.push((scale_bits, score.to_bits()));
    }
    assert_eq!(runs[0].0, runs[1].0, "PEG scales/zps diverged across thread counts");
    assert_eq!(
        runs[0].1, runs[1].1,
        "PEG dev score diverged: {} vs {}",
        f64::from_bits(runs[0].1),
        f64::from_bits(runs[1].1)
    );
}

/// Batched diagnostics taps (`collect_taps` through `Runtime::run_batch`,
/// ROADMAP follow-on from PR 4): tap order and content must be
/// bit-identical to the serial `run_diag` loop, at any thread count.
#[test]
fn diag_taps_batched_match_serial_run_diag() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let n_seqs = 6;
    let mut batched: Vec<Vec<(String, Vec<u32>)>> = Vec::new();
    for threads in [1usize, 8] {
        let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
            .unwrap()
            .with_pool(Pool::new(threads));
        let info = ctx.model_info(&task).unwrap();
        let params = Params::init(info, 31);
        let runs = diagnostics::collect_taps(&ctx, &task, &params, n_seqs).unwrap();
        assert_eq!(runs.per_seq.len(), n_seqs);
        assert_eq!(runs.examples.len(), n_seqs);
        batched.push(
            runs.per_seq
                .iter()
                .map(|taps| {
                    // BTreeMap iteration: site order is fixed and identical
                    taps.iter().map(|(s, t)| (s.clone(), bits(t.data()))).collect()
                })
                .collect::<Vec<Vec<_>>>()
                .concat(),
        );
    }
    assert_eq!(batched[0], batched[1], "taps diverged across thread counts");

    // and against the serial reference path (run_diag per example)
    let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
        .unwrap()
        .with_pool(Pool::new(1));
    let info = ctx.model_info(&task).unwrap();
    let params = Params::init(info, 31);
    let split = tq::data::dev_split(&task, info.config.seq).unwrap();
    let fp32 = assemble_act_tensors(
        info,
        &QuantPolicy::fp32(),
        &std::collections::BTreeMap::new(),
    )
    .unwrap();
    let artifact = format!("diag_{}_b1", ctx.head(&task));
    let mut serial: Vec<(String, Vec<u32>)> = Vec::new();
    for ex in split.examples.iter().take(n_seqs) {
        let taps = tq::coordinator::calibrate::run_diag(
            &ctx,
            &artifact,
            info,
            &params,
            &fp32.scales,
            &fp32.zps,
            &fp32.cfg,
            ex,
        )
        .unwrap();
        serial.extend(taps.iter().map(|(s, t)| (s.clone(), bits(t.data()))));
    }
    assert_eq!(batched[0], serial, "batched taps diverged from the serial run_diag loop");
}

/// The outlier-diagnostics pass (`repro diag --outliers` — streaming
/// ∞-norm / kurtosis / top-lane stats over batched `collect_taps_var`
/// tensors) must produce bit-identical statistics on a 1-thread and an
/// 8-thread `Ctx`, for the vanilla family of both architectures and for
/// the attention-variant families. Tap collection reassembles in
/// sequence order and the accumulator folds in strict element order, so
/// thread count must never leak into a single stat bit.
#[test]
fn outlier_stats_are_parallel_deterministic_across_families() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    for (arch, variant) in [
        (Architecture::Bert, AttnVariant::Vanilla),
        (Architecture::Bert, AttnVariant::ClippedSoftmax),
        (Architecture::Vit, AttnVariant::Vanilla),
        (Architecture::Vit, AttnVariant::Gated),
    ] {
        let mut per_thread: Vec<Vec<(String, u64, u32, u64, u64, usize)>> = Vec::new();
        for threads in [1usize, 8] {
            let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
                .unwrap()
                .with_pool(Pool::new(threads));
            let Ok(info) = ctx.model_info_var(&task, arch, variant) else {
                eprintln!(
                    "SKIP: artifacts lack the {arch:?}/{variant:?} family \
                     (regenerate with `repro gen-artifacts`)"
                );
                return;
            };
            let params = Params::init(info, 37);
            let run =
                diagnostics::collect_taps_var(&ctx, &task, arch, variant, &params, 5).unwrap();
            assert_eq!(run.per_seq.len(), 5);
            let stats = tq::analysis::outlier_stats(&run).unwrap();
            assert!(!stats.is_empty(), "{arch:?}/{variant:?}: no tap sites");
            per_thread.push(
                stats
                    .iter()
                    .map(|(site, s)| {
                        (
                            site.clone(),
                            s.kurtosis.to_bits(),
                            s.inf_norm.to_bits(),
                            s.mean.to_bits(),
                            s.top_share.to_bits(),
                            s.top_lane,
                        )
                    })
                    .collect(),
            );
        }
        assert_eq!(
            per_thread[0], per_thread[1],
            "{arch:?}/{variant:?}: outlier stats diverged across thread counts"
        );
    }
}

/// The persistent pool survives sustained small-batch traffic and
/// panicking jobs: a panic surfaces as a clean unwind on the submitter
/// (not a hung queue), and the same workers keep serving afterwards.
#[test]
fn pool_stress_many_small_jobs_and_panic_containment() {
    let pool = Pool::new(8);
    // thousands of tiny jobs across hundreds of batches on one worker set
    for round in 0..200u64 {
        let jobs: Vec<_> = (0..32u64).map(|i| move || i * i + round).collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..32u64).map(|i| i * i + round).collect::<Vec<_>>());
    }
    // mixed workloads on the same pool
    let items: Vec<u64> = (0..1000).collect();
    let doubled = pool.par_map(&items, |i, &x| {
        assert_eq!(i as u64, x);
        x * 2
    });
    assert_eq!(doubled[999], 1998);
    // a panicking job must propagate cleanly...
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run(
            (0..16)
                .map(|i| move || if i == 7 { panic!("job {i} failed") } else { i })
                .collect::<Vec<_>>(),
        )
    }));
    assert!(res.is_err(), "panic must reach the submitter");
    // ...and the queue must not be hung: the pool still works
    let after = pool.run((0..64).map(|i| move || i + 1).collect::<Vec<_>>());
    assert_eq!(after, (1..=64).collect::<Vec<_>>());
}

#[test]
fn offline_sweep_is_parallel_deterministic() {
    let (serial, parallel) = pools();
    let data = synth_data(128, 48, 4, 99);
    // K=6 does not divide 128: the near-even group path and the per-group
    // MSE search are pinned alongside the classic cells
    let cfgs = grid(
        128,
        &[Architecture::Bert],
        &[8, 4],
        &[8],
        &[1, 6, 8, 128],
        &[Estimator::CurrentMinMax, Estimator::Mse],
        &[RangeMethod::Auto, RangeMethod::MsePerGroup],
    )
    .unwrap();
    assert!(cfgs.len() >= 4, "sweep smoke needs >= 4 configs");
    let a = run_offline(&data, &cfgs, &serial).unwrap();
    let b = run_offline(&data, &cfgs, &parallel).unwrap();
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.label, rb.label);
        assert_eq!(ra.act_mse.to_bits(), rb.act_mse.to_bits(), "{}", ra.label);
        assert_eq!(ra.weight_mse.to_bits(), rb.weight_mse.to_bits(), "{}", ra.label);
        assert_eq!(ra.peg_overhead, rb.peg_overhead, "{}", ra.label);
    }
}

/// Sharded execution is a pure partition: for n ∈ {1, 2, 4}, running each
/// shard's cells separately and merging the shard maps back must produce
/// a report byte-identical to the unsharded sweep over the same grid
/// (timing columns normalised — they are wall-clock, not results). This
/// is the library-level contract behind `repro sweep --shard i/n` +
/// `--merge n`.
#[test]
fn shard_merge_is_byte_identical_to_unsharded() {
    let archs = [Architecture::Bert, Architecture::Vit];
    let data = synth_data(64, 32, 2, 5);
    let cfgs = grid(
        64,
        &archs,
        &[8, 4],
        &[8],
        &[1, 8],
        &[Estimator::CurrentMinMax, Estimator::Mse],
        &[RangeMethod::Auto],
    )
    .unwrap();
    let ids: Vec<String> = cfgs.iter().map(|c| c.to_spec("mnli", 1).spec_id()).collect();
    let labels: Vec<String> = cfgs.iter().map(|c| c.label()).collect();
    let pool = Pool::new(2);

    // unsharded reference, timing normalised
    let mut unsharded = run_offline(&data, &cfgs, &pool).unwrap();
    for (r, id) in unsharded.iter_mut().zip(&ids) {
        r.spec_id = id.clone();
        r.millis = 0.0;
    }
    let want = report_json(&unsharded, 2, 0.0, 64, 5, &archs).to_string();

    for n in [1usize, 2, 4] {
        let mut shards = Vec::new();
        for i in 0..n {
            let keep: Vec<usize> =
                (0..cfgs.len()).filter(|&x| shard_of(&ids[x], n) == i).collect();
            let shard_cfgs: Vec<_> = keep.iter().map(|&x| cfgs[x].clone()).collect();
            let mut res = run_offline(&data, &shard_cfgs, &pool).unwrap();
            let mut map = std::collections::BTreeMap::new();
            for (r, &x) in res.iter_mut().zip(&keep) {
                r.spec_id = ids[x].clone();
                r.millis = 0.0;
                map.insert(r.spec_id.clone(), r.clone());
            }
            shards.push(map);
        }
        let merged = merge_results(&shards, &ids, &labels).unwrap();
        let got = report_json(&merged, 2, 0.0, 64, 5, &archs).to_string();
        assert_eq!(got, want, "n={n}: merged report diverged from unsharded");
    }
}

/// A ready-to-serve model over the generated artifacts without the
/// checkpoint-loading assembly path: seeded `Params::init` weights plus
/// either a calibrated W8A8 policy or disabled (fp32) quantizers.
fn serve_model(ctx: &Ctx, task: &TaskSpec, spec_id: &str, quantized: bool) -> ServeModel {
    let info = ctx.model_info(task).unwrap();
    let params = Params::init(info, 17);
    let act = if quantized {
        let cfg = CalibCfg { num_batches: 2, batch_size: 2, ..Default::default() };
        let calib = calibrate(ctx, task, &params, &cfg).unwrap();
        assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers).unwrap()
    } else {
        assemble_act_tensors(info, &QuantPolicy::fp32(), &std::collections::BTreeMap::new())
            .unwrap()
    };
    ServeModel::from_assembled(AssembledModel {
        spec_id: spec_id.to_string(),
        task: task.name.to_string(),
        artifact: format!("fwd_{}_b{EVAL_BATCH}", ctx.head(task)),
        params,
        act,
        batch: EVAL_BATCH,
        seq: info.config.seq,
        n_out: info.config.n_out,
        n_sites: info.sites.len(),
    })
    .unwrap()
}

/// Serve-path bit-identity: the continuous-batching dispatcher must
/// return exactly the logit rows a direct `run_batch` over the same
/// split produces — at 1 and 8 threads and across batch windows that
/// coalesce very differently (immediate dispatch vs wide coalescing into
/// multiple executable batches). Re-batching only re-partitions rows
/// across padded executable batches; no forward op reduces over the
/// batch dimension, so each row's math is independent of which batch it
/// rode in.
#[test]
fn serve_queue_matches_direct_run_batch() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    for threads in [1usize, 8] {
        let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
            .unwrap()
            .with_pool(Pool::new(threads));
        let model = Arc::new(serve_model(&ctx, &task, "det-w8a8", true));
        let (b, seq, n_out) =
            (model.assembled.batch, model.assembled.seq, model.assembled.n_out);
        let mut split = tq::data::dev_split(&task, seq).unwrap();
        // 13 = 8 + 5: one full and one PAD-padded executable batch
        split.examples.truncate(13);
        let n = split.examples.len();

        let outs = ctx
            .rt
            .run_batch(
                &model.assembled.artifact,
                &model.statics,
                n.div_ceil(b),
                |i| batch_input_lits(&make_batch(&split, i * b, b, seq)),
                &ctx.pool,
            )
            .unwrap();
        let direct: Vec<Vec<u32>> = (0..n)
            .map(|r| bits(&outs[r / b][0].data()[(r % b) * n_out..(r % b + 1) * n_out]))
            .collect();
        assert_eq!(ctx.rt.stats().served, 0, "direct run_batch must not count as served");

        for window_us in [0u64, 500, 5000] {
            let served_before = ctx.rt.stats().served;
            let rows: Vec<Vec<u32>> = std::thread::scope(|scope| {
                let server = Server::start(
                    scope,
                    &ctx.rt,
                    &ctx.pool,
                    model.clone(),
                    ServeConfig {
                        max_batch: 32,
                        batch_window: Duration::from_micros(window_us),
                        queue_depth: 64,
                    },
                );
                let tickets: Vec<Ticket> = split
                    .examples
                    .iter()
                    .map(|ex| server.submit(ex.clone()).unwrap())
                    .collect();
                let stats = server.shutdown();
                assert_eq!(stats.accepted, n as u64, "threads={threads} window={window_us}");
                assert_eq!(stats.completed, n as u64, "threads={threads} window={window_us}");
                assert_eq!((stats.shed, stats.failed), (0, 0));
                tickets.into_iter().map(|t| bits(&t.wait().unwrap())).collect()
            });
            assert_eq!(rows, direct, "threads={threads} window={window_us}us");
            assert!(
                ctx.rt.stats().served > served_before,
                "serve path must bump the served counter"
            );
        }
    }
}

/// Admission control sheds — explicitly, without loss — when a burst
/// outruns a deliberately tiny queue: with depth 2 and a long batch
/// window the dispatcher is still coalescing while the 8-burst arrives,
/// so most of it must see `QueueFull`, and shutdown must still answer
/// every admitted request.
#[test]
fn serve_sheds_on_full_queue() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
        .unwrap()
        .with_pool(Pool::new(2));
    let model = Arc::new(serve_model(&ctx, &task, "det-shed", false));
    let mut split = tq::data::dev_split(&task, model.assembled.seq).unwrap();
    split.examples.truncate(8);
    std::thread::scope(|scope| {
        let server = Server::start(
            scope,
            &ctx.rt,
            &ctx.pool,
            model.clone(),
            ServeConfig {
                max_batch: 4,
                batch_window: Duration::from_millis(500),
                queue_depth: 2,
            },
        );
        let mut tickets = Vec::new();
        let mut shed = 0u64;
        for ex in &split.examples {
            match server.submit(ex.clone()) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::QueueFull { depth }) => {
                    assert_eq!(depth, 2);
                    shed += 1;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(shed >= 1, "a depth-2 queue must shed part of an 8-burst");
        let stats = server.shutdown();
        assert_eq!(stats.shed, shed);
        assert_eq!(stats.accepted, tickets.len() as u64);
        assert_eq!(stats.accepted + stats.shed, 8);
        assert_eq!(stats.completed, stats.accepted, "drain must answer every admitted request");
        assert_eq!(stats.failed, 0);
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), model.assembled.n_out);
        }
    });
}

/// Graceful drain: with a batch window far longer than the test, only
/// shutdown can dispatch — it must flush everything admitted, exactly
/// once, without sleeping out the window. 11 requests coalesce into one
/// drain of ceil(11/8) = 2 executable batches (fills 8 and 3), which
/// also pins the multi-batch split of one coalesced set.
#[test]
fn serve_drains_on_shutdown_without_loss() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
        .unwrap()
        .with_pool(Pool::new(2));
    let model = Arc::new(serve_model(&ctx, &task, "det-drain", false));
    let mut split = tq::data::dev_split(&task, model.assembled.seq).unwrap();
    split.examples.truncate(11);
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        let server = Server::start(
            scope,
            &ctx.rt,
            &ctx.pool,
            model.clone(),
            ServeConfig {
                max_batch: 256,
                batch_window: Duration::from_secs(3600),
                queue_depth: 1024,
            },
        );
        let tickets: Vec<Ticket> = split
            .examples
            .iter()
            .map(|ex| server.submit(ex.clone()).unwrap())
            .collect();
        let stats = server.shutdown();
        assert_eq!(stats.accepted, 11);
        assert_eq!(stats.completed, 11, "drain lost requests");
        assert_eq!((stats.shed, stats.failed), (0, 0));
        assert_eq!(stats.hist_string(), "3:1|8:1", "one full + one padded executable batch");
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), model.assembled.n_out);
        }
    });
    assert!(
        t0.elapsed() < Duration::from_secs(600),
        "drain must skip the batch window, not sleep it out"
    );
}

/// The model cache's hit/miss/eviction counters must fold into the
/// shared `RuntimeStats` exactly: driving a capacity-2 cache through a
/// known access pattern over three specs yields equal counters on the
/// cache and on the runtime, with LRU eviction picking the stalest id.
#[test]
fn model_cache_counters_fold_into_runtime_stats() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `repro gen-artifacts`)");
        return;
    }
    let task = task_spec("sst2").unwrap();
    let ctx = Ctx::new("artifacts", "/tmp/tq_det_ckpt", "/tmp/tq_det_results")
        .unwrap()
        .with_pool(Pool::new(2));
    let cache = ModelCache::new(2);
    for id in ["s1", "s2", "s3", "s1", "s3", "s2"] {
        let m = cache
            .get_or_build(&ctx.rt, id, || Ok(serve_model(&ctx, &task, id, false)))
            .unwrap();
        assert_eq!(m.spec_id(), id);
    }
    // s1, s2, s3 miss (s3 evicts s1), s1 misses again (evicts s2),
    // s3 hits, s2 misses (evicts s1)
    let want = CacheStats { hits: 1, misses: 5, evictions: 3 };
    assert_eq!(cache.stats(), want);
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.resident(), vec!["s3".to_string(), "s2".to_string()]);
    let rs = ctx.rt.stats();
    assert_eq!(
        (rs.model_cache_hits, rs.model_cache_misses, rs.model_cache_evictions),
        (want.hits, want.misses, want.evictions),
    );
}
