//! The `specs/` directory contract: every paper table row ships as a
//! checked-in JSON spec that parses to exactly the builder-constructed
//! preset, and every `spec_id` is pinned literally — a serialization
//! change that would silently invalidate cached sweeps, `--compare`
//! baselines or `--shard` partitions fails here first.

use std::collections::BTreeSet;
use std::path::PathBuf;

use tq::model::manifest::{Architecture, AttnVariant};
use tq::spec::{presets, QuantSpec};

fn specs_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("specs")
}

fn load(name: &str) -> QuantSpec {
    let path = specs_dir().join(format!("{name}.json"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    QuantSpec::parse(&text).unwrap_or_else(|e| panic!("parsing {}: {e:#}", path.display()))
}

/// (preset name, pinned spec_id). The hashes are the FNV-1a-64 of each
/// spec's canonical JSON (minus the cosmetic `name`) as of the PR that
/// introduced the `specs/` directory; the first 15 predate the
/// architecture/QAT spec sections and MUST stay stable forever — they key
/// resumable sweep caches and shard membership on disk.
const PINNED: [(&str, &str); 19] = [
    ("fp32", "f3233bd0e72c3350"),
    ("w8a8", "37410af9dda7ba42"),
    ("w32a8", "f4ed6664de27f84d"),
    ("w8a32", "7d876939a1a170e9"),
    ("mixed_precision", "8b2682861115c15e"),
    ("peg_k8_permute", "fe2eb2a94bf42bf7"),
    ("peg_k4_permute", "77fcb6f0c39f9213"),
    ("peg_k6_permute", "61594a09fd757511"),
    ("peg_k12_permute", "099f56946742efaa"),
    ("peg_k6_mse", "f5f8b28f921b9913"),
    ("w6a32", "49b7ebf8a8fc9fd3"),
    ("w4a32", "b2d905a4f68ca1c3"),
    ("w4a32_adaround", "976cb97ced04b0b7"),
    ("w8a32_embed4", "6b94928fb9c64e87"),
    ("w8a32_embed2", "4de3296112ea2101"),
    ("w8a8_qat", "32d74f75d392975d"),
    ("w4a32_qat", "efd2c267629447f7"),
    ("w4a8_qat", "d96925deb09128a5"),
    ("w4a8_embed2_qat", "abf08fc7d3ffe33d"),
];

/// ViT sweep cells: not presets (no builder counterpart), but their ids
/// key shard membership the same way, so they are pinned identically.
const PINNED_VIT: [(&str, &str); 4] = [
    ("vit_w8a8", "d30a4baf55d0b5c8"),
    ("vit_w32a8", "b55a2780a07e704b"),
    ("vit_w8a32", "322b128fdbdecfbf"),
    ("vit_peg_k8_permute", "799441697ba89a51"),
];

/// Attention-variant sweep cells (clipped softmax / gated attention, the
/// outlier-suppressing model variants): W8A8 per-tensor on each variant
/// family. Like the ViT cells these are not presets, but their ids key
/// shard membership and `--compare` baselines, so they are pinned.
const PINNED_VARIANT: [(&str, &str, Architecture, AttnVariant); 4] = [
    ("csoft_w8a8", "ef4997580d9b8457", Architecture::Bert, AttnVariant::ClippedSoftmax),
    ("gate_w8a8", "09b88fb708393c04", Architecture::Bert, AttnVariant::Gated),
    ("vit_csoft_w8a8", "58a6230501c2c391", Architecture::Vit, AttnVariant::ClippedSoftmax),
    ("vit_gate_w8a8", "3374c1028387e5b6", Architecture::Vit, AttnVariant::Gated),
];

#[test]
fn every_preset_has_a_spec_file_with_pinned_id() {
    assert_eq!(
        PINNED.len(),
        presets::preset_names().len(),
        "preset registry and specs/ pin table diverged"
    );
    for (name, want_id) in PINNED {
        let from_file = load(name);
        let built = presets::preset(name).unwrap();
        assert_eq!(from_file, built, "specs/{name}.json != preset({name:?})");
        assert_eq!(from_file.spec_id(), want_id, "spec_id drifted for {name}");
        assert_eq!(built.spec_id(), want_id, "builder spec_id drifted for {name}");
    }
}

#[test]
fn vit_cells_parse_target_vit_and_pin_their_ids() {
    for (name, want_id) in PINNED_VIT {
        let spec = load(name);
        assert_eq!(spec.architecture, Architecture::Vit, "{name}");
        assert_eq!(spec.spec_id(), want_id, "spec_id drifted for {name}");
        // the canonical form keeps the architecture key (non-default)
        let canon = spec.to_json().to_string();
        assert!(canon.contains("\"architecture\":\"vit\""), "{name}: {canon}");
    }
}

#[test]
fn variant_cells_parse_target_their_family_and_pin_their_ids() {
    for (name, want_id, arch, variant) in PINNED_VARIANT {
        let spec = load(name);
        assert_eq!(spec.architecture, arch, "{name}");
        assert_eq!(spec.variant, variant, "{name}");
        assert_eq!(spec.spec_id(), want_id, "spec_id drifted for {name}");
        // the canonical form keeps the variant key (non-default), and the
        // policy body is byte-identical to the vanilla w8a8 cell's — only
        // the model-family keys differ
        let canon = spec.to_json().to_string();
        assert!(
            canon.contains(&format!("\"variant\":\"{}\"", variant.name())),
            "{name}: {canon}"
        );
        let mut vanilla = spec.clone();
        vanilla.architecture = Architecture::Bert;
        vanilla.variant = AttnVariant::Vanilla;
        assert_eq!(
            vanilla.named("w8a8").spec_id(),
            "37410af9dda7ba42",
            "{name}: policy body drifted from the w8a8 baseline"
        );
    }
}

#[test]
fn specs_dir_is_exactly_the_pinned_set_and_round_trips() {
    let mut expect: BTreeSet<String> = PINNED
        .iter()
        .chain(PINNED_VIT.iter())
        .map(|(n, _)| format!("{n}.json"))
        .chain(PINNED_VARIANT.iter().map(|(n, _, _, _)| format!("{n}.json")))
        .collect();
    let mut ids = BTreeSet::new();
    for entry in std::fs::read_dir(specs_dir()).unwrap() {
        let entry = entry.unwrap();
        let fname = entry.file_name().to_string_lossy().into_owned();
        assert!(
            expect.remove(&fname),
            "unpinned file specs/{fname} — add it to the pin table"
        );
        let stem = fname.trim_end_matches(".json");
        let spec = load(stem);
        assert_eq!(spec.name, stem, "file name and spec name diverged");
        assert!(ids.insert(spec.spec_id()), "duplicate spec_id in specs/ ({fname})");
        // parse -> serialize -> parse is the identity
        let back = QuantSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec, "round-trip changed specs/{fname}");
    }
    assert!(expect.is_empty(), "missing spec files: {expect:?}");
}
