//! Quantization-substrate micro-benchmarks: QDQ throughput, range
//! estimators, PEG parameter assembly, AdaRound iteration cost.
//! (criterion is unavailable offline; rust/src/util/bench.rs provides the
//! harness. `cargo bench` runs this with --bench.)

use tq::quant::estimators::RangeTracker;
use tq::quant::peg::lane_qparams;
use tq::quant::{qdq_slice, qparams_from_range, Estimator, Granularity, QGrid};
use tq::tensor::Tensor;
use tq::util::bench::{append_csv, Bencher};
use tq::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let csv = "results/bench_quant.csv";
    let grid = QGrid::asymmetric(8);
    let p = qparams_from_range(-4.0, 4.0, grid);

    // QDQ throughput on a (64, 768) activation tensor
    let t = Tensor::randn(&[64, 768], 1.0, &mut rng);
    let mut buf = t.data().to_vec();
    let b = Bencher::default().throughput((64 * 768) as u64);
    let s = b.bench("qdq_per_tensor 64x768 (elems/s)", || {
        buf.copy_from_slice(t.data());
        qdq_slice(&mut buf, p, grid);
    });
    append_csv(csv, &s).ok();

    // range estimator observation cost
    for (name, est) in [
        ("observe current-min-max", Estimator::CurrentMinMax),
        ("observe running-min-max", Estimator::RunningMinMax),
        ("observe mse (reservoir)", Estimator::Mse),
    ] {
        let mut tr = RangeTracker::new(est, 768);
        let s = Bencher::default()
            .throughput((64 * 768) as u64)
            .bench(&format!("{name} 64x768"), || {
                tr.observe(&t).unwrap();
            });
        append_csv(csv, &s).ok();
    }

    // MSE grid search (40 candidate ranges over the reservoir)
    let mut tr = RangeTracker::new(Estimator::Mse, 768);
    tr.observe(&t).unwrap();
    let s = Bencher::default().bench("mse grid search (65k samples)", || {
        std::hint::black_box(tr.tensor_range(grid));
    });
    append_csv(csv, &s).ok();

    // PEG parameter assembly incl. range-based permutation, d=768
    let lo: Vec<f32> = (0..768).map(|_| rng.uniform(-8.0, 0.0)).collect();
    let hi: Vec<f32> = (0..768).map(|_| rng.uniform(0.0, 8.0)).collect();
    for k in [1usize, 3, 6, 768] {
        let gran = Granularity::PerEmbeddingGroup { k, permute: true };
        let s = Bencher::default().bench(&format!("peg lane_qparams d=768 K={k}"), || {
            std::hint::black_box(lane_qparams(&lo, &hi, &gran, grid).unwrap());
        });
        append_csv(csv, &s).ok();
    }

    // AdaRound single-layer optimisation (128x128, 200 iters)
    let w = Tensor::randn(&[128, 128], 0.05, &mut rng);
    let z = Tensor::randn(&[256, 128], 1.0, &mut rng);
    let mix = Tensor::randn(&[128, 128], (1.0f32 / 128.0).sqrt(), &mut rng);
    let x = z.matmul(&mix).unwrap();
    let sgrid = QGrid::symmetric(4);
    let wp = tq::quant::qparams_symmetric(w.abs_max(), sgrid);
    let cfg = tq::quant::adaround::AdaRoundCfg { iters: 200, ..Default::default() };
    let s = Bencher::quick().bench("adaround 128x128 W4 (200 iters)", || {
        std::hint::black_box(tq::quant::adaround::adaround(&w, &x, wp, sgrid, &cfg).unwrap());
    });
    append_csv(csv, &s).ok();
}
