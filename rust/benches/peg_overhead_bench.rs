//! PEG overhead vs K — both halves of the paper's §4 efficiency argument:
//!
//! 1. **Parameter-resolution cost** (always runs, no artifacts): the
//!    Rust-side PEG pipeline — tracker → permutation → groups →
//!    (per-group MSE search) → lane qparams — timed at d=768 for
//!    K = 1 / 3 / 6 / 12 / 768, with the paper's d + 2·3·K storage
//!    overhead recorded per row. This is what `repro sweep`'s K axis
//!    pays per cell.
//! 2. **Kernel latency** (needs artifacts): the standalone Pallas
//!    PEG-matmul artifacts (T=128, d=768, n=768) at K = 1 / 3 / 6 / 16
//!    on the PJRT CPU client, plus the fake-quant kernel.
//!
//! Everything appends to results/bench_peg.csv so CI can publish one
//! artifact.

use tq::model::qconfig::{site_lane_params_pool, SiteCfg};
use tq::quant::estimators::RangeTracker;
use tq::quant::peg::granularity_overhead_params;
use tq::quant::{Estimator, QGrid, RangeMethod};
use tq::runtime::{Runtime, Value};
use tq::tensor::Tensor;
use tq::util::bench::{append_csv, Bencher};
use tq::util::pool::Pool;
use tq::util::rng::Rng;

fn granularity_for(d: usize, k: usize) -> tq::quant::Granularity {
    tq::coordinator::sweep::granularity_for(d, k).unwrap()
}

fn bench_param_resolution(csv: &str) {
    let d = 768;
    let mut rng = Rng::new(5);
    let mut tracker = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
    for _ in 0..4 {
        let t = Tensor::from_fn(&[256, d], |i| {
            let lane = i % d;
            let mag = if lane % 127 == 3 { 30.0 } else { 1.0 };
            rng.normal_f32(0.0, mag)
        });
        tracker.observe(&t).unwrap();
    }
    let grid = QGrid::asymmetric(8);
    let pool = Pool::global();
    for k in [1usize, 3, 6, 12, 768] {
        for method in [RangeMethod::Auto, RangeMethod::MsePerGroup] {
            let cfg = SiteCfg {
                bits: 8,
                granularity: granularity_for(d, k),
                range_method: method,
                enabled: true,
            };
            let overhead = granularity_overhead_params(d, &cfg.granularity);
            let tag = match method {
                RangeMethod::MsePerGroup => "mse_group",
                _ => "minmax",
            };
            let s = Bencher::quick().bench(
                &format!("peg_param_resolution d=768 K={k} {tag} (overhead={overhead})"),
                || {
                    std::hint::black_box(
                        site_lane_params_pool(&tracker, &cfg, grid, pool).unwrap(),
                    );
                },
            );
            append_csv(csv, &s).ok();
        }
    }
}

fn main() {
    let csv = "results/bench_peg.csv";
    // half 1: parameter-resolution cost — always runs, artifacts or not
    bench_param_resolution(csv);

    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping peg kernel bench (no artifacts): {e}");
            return;
        }
    };
    let mut rng = Rng::new(3);

    let x = Tensor::randn(&[128, 768], 1.0, &mut rng);
    let w = Tensor::randn(&[768, 768], 0.05, &mut rng);

    for k in [1usize, 3, 6, 16] {
        let name = format!("kernel_peg_k{k}");
        if rt.manifest().artifact(&name).is_err() {
            continue;
        }
        let sx = Tensor::full(&[k], 0.05);
        let zx = Tensor::full(&[k], 128.0);
        let cfg = Tensor::new(vec![5], vec![0.01, 0.0, 255.0, -127.0, 127.0]).unwrap();
        // warm the executable cache before timing
        rt.run(&name, &[
            Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(sx.clone()),
            Value::F32(zx.clone()), Value::F32(cfg.clone()),
        ]).unwrap();
        let flops = 2u64 * 128 * 768 * 768;
        let s = Bencher::default().throughput(flops).bench(
            &format!("peg_matmul 128x768x768 K={k} (flop/s)"),
            || {
                rt.run(&name, &[
                    Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(sx.clone()),
                    Value::F32(zx.clone()), Value::F32(cfg.clone()),
                ])
                .unwrap();
            },
        );
        append_csv(csv, &s).ok();
    }

    // fake-quant kernel artifact
    let s = Tensor::full(&[768], 0.05);
    let z = Tensor::full(&[768], 128.0);
    let c = Tensor::new(vec![3], vec![0.0, 255.0, 1.0]).unwrap();
    rt.run("kernel_fq_d768", &[
        Value::F32(x.clone()), Value::F32(s.clone()), Value::F32(z.clone()), Value::F32(c.clone()),
    ]).unwrap();
    let st = Bencher::default().throughput((128 * 768) as u64).bench(
        "pallas fake_quant 128x768 (elems/s)",
        || {
            rt.run("kernel_fq_d768", &[
                Value::F32(x.clone()), Value::F32(s.clone()), Value::F32(z.clone()),
                Value::F32(c.clone()),
            ])
            .unwrap();
        },
    );
    append_csv(csv, &st).ok();
}
