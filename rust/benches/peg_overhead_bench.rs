//! PEG re-scaling overhead vs K — the paper's §4 efficiency argument:
//! per-embedding quantization needs d accumulator re-scalings per output,
//! PEG needs only K. We measure the end-to-end latency of the standalone
//! Pallas PEG-matmul artifacts (T=128, d=768, n=768) at K = 1 / 3 / 6 / 16
//! on the PJRT CPU client, plus the fake-quant kernel.

use tq::runtime::{Runtime, Value};
use tq::tensor::Tensor;
use tq::util::bench::{append_csv, Bencher};
use tq::util::rng::Rng;

fn main() {
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping peg_overhead_bench (no artifacts): {e}");
            return;
        }
    };
    let mut rng = Rng::new(3);
    let csv = "results/bench_peg.csv";

    let x = Tensor::randn(&[128, 768], 1.0, &mut rng);
    let w = Tensor::randn(&[768, 768], 0.05, &mut rng);

    for k in [1usize, 3, 6, 16] {
        let name = format!("kernel_peg_k{k}");
        if rt.manifest().artifact(&name).is_err() {
            continue;
        }
        let sx = Tensor::full(&[k], 0.05);
        let zx = Tensor::full(&[k], 128.0);
        let cfg = Tensor::new(vec![5], vec![0.01, 0.0, 255.0, -127.0, 127.0]).unwrap();
        // warm the executable cache before timing
        rt.run(&name, &[
            Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(sx.clone()),
            Value::F32(zx.clone()), Value::F32(cfg.clone()),
        ]).unwrap();
        let flops = 2u64 * 128 * 768 * 768;
        let s = Bencher::default().throughput(flops).bench(
            &format!("peg_matmul 128x768x768 K={k} (flop/s)"),
            || {
                rt.run(&name, &[
                    Value::F32(x.clone()), Value::F32(w.clone()), Value::F32(sx.clone()),
                    Value::F32(zx.clone()), Value::F32(cfg.clone()),
                ])
                .unwrap();
            },
        );
        append_csv(csv, &s).ok();
    }

    // fake-quant kernel artifact
    let s = Tensor::full(&[768], 0.05);
    let z = Tensor::full(&[768], 128.0);
    let c = Tensor::new(vec![3], vec![0.0, 255.0, 1.0]).unwrap();
    rt.run("kernel_fq_d768", &[
        Value::F32(x.clone()), Value::F32(s.clone()), Value::F32(z.clone()), Value::F32(c.clone()),
    ]).unwrap();
    let st = Bencher::default().throughput((128 * 768) as u64).bench(
        "pallas fake_quant 128x768 (elems/s)",
        || {
            rt.run("kernel_fq_d768", &[
                Value::F32(x.clone()), Value::F32(s.clone()), Value::F32(z.clone()),
                Value::F32(c.clone()),
            ])
            .unwrap();
        },
    );
    append_csv(csv, &st).ok();
}
