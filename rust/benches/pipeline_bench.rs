//! End-to-end pipeline benchmarks: quantized-inference latency per method
//! (the efficiency side of Tables 4/5/6), calibration throughput, and the
//! policy-assembly cost. Skips gracefully when checkpoints are missing.

use std::collections::BTreeMap;

use tq::coordinator::calibrate::{calibrate, CalibCfg};
use tq::coordinator::experiments::load_ckpt;
use tq::coordinator::Ctx;
use tq::data;
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy, SiteCfg};
use tq::quant::Granularity;
use tq::runtime::{lit_f32, lit_i32};
use tq::util::bench::{append_csv, Bencher};

fn main() {
    let ctx = match Ctx::new("artifacts", "checkpoints", "results") {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping pipeline_bench: {e}");
            return;
        }
    };
    let task = ctx.task("mnli").unwrap();
    let params = match load_ckpt(&ctx, &task) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping pipeline_bench (no checkpoint): {e}");
            return;
        }
    };
    let info = ctx.model_info(&task).unwrap();
    let csv = "results/bench_pipeline.csv";

    // calibration throughput (sequences/second through the diag graph)
    let s = Bencher::quick().throughput(4).bench("calibration (4 seqs, diag graph)", || {
        calibrate(&ctx, &task, &params, &CalibCfg {
            num_batches: 4,
            ..Default::default()
        })
        .unwrap();
    });
    append_csv(csv, &s).ok();

    let calib = calibrate(&ctx, &task, &params, &CalibCfg::default()).unwrap();

    // policy assembly cost (the L3 "hot" configuration path)
    let peg = SiteCfg {
        granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
        ..Default::default()
    };
    let mut policy = QuantPolicy::uniform(8, 8);
    for fam in ["ln1_out", "ffn_out", "res2_sum"] {
        policy = policy.with_site_family(info, fam, peg.clone());
    }
    let s = Bencher::default().bench("assemble_act_tensors (PEG policy, 82 sites)", || {
        std::hint::black_box(assemble_act_tensors(info, &policy, &calib.trackers).unwrap());
    });
    append_csv(csv, &s).ok();

    // quantized inference latency per method (batch-8 forward)
    let split = data::dev_split(&task, info.config.seq).unwrap();
    let batch = data::make_batch(&split, 0, 8, info.config.seq);
    for (name, pol) in [
        ("fp32", QuantPolicy::fp32()),
        ("w8a8 per-tensor", QuantPolicy::uniform(8, 8)),
        ("w8a8 peg k=8+P", policy.clone()),
    ] {
        let act = assemble_act_tensors(info, &pol, &calib.trackers).unwrap();
        let mut lits = Vec::new();
        for t in &params.tensors {
            lits.push(lit_f32(t.data(), t.shape()).unwrap());
        }
        lits.push(lit_f32(&act.scales, &[act.scales.len()]).unwrap());
        lits.push(lit_f32(&act.zps, &[act.zps.len()]).unwrap());
        lits.push(lit_f32(&act.cfg, &[info.sites.len(), 3]).unwrap());
        lits.push(lit_i32(&batch.ids, &[8, info.config.seq]).unwrap());
        lits.push(lit_i32(&batch.token_type, &[8, info.config.seq]).unwrap());
        lits.push(lit_f32(&batch.mask, &[8, info.config.seq]).unwrap());
        // warm
        ctx.rt.run_lits("fwd_cls_b8", &lits).unwrap();
        let s = Bencher::default().throughput(8).bench(
            &format!("fwd_cls_b8 inference [{name}] (seqs/s)"),
            || {
                ctx.rt.run_lits("fwd_cls_b8", &lits).unwrap();
            },
        );
        append_csv(csv, &s).ok();
    }
}
