//! Executable hot-loop throughput: the calibrate/eval batch-parallel
//! seam (`Runtime::run_batch` on the persistent pool) measured against
//! the pre-batching per-call serial loop, plus the pool-dispatch
//! comparison of spawn-per-call scoped threads vs persistent workers.
//! Results append to results/bench_exec.csv; CI runs this after
//! `gen-artifacts` so the numbers land in the job log.
//!
//! Rows (artifact-backed ones require `repro gen-artifacts`):
//!   * pool dispatch: N small jobs, spawn-per-call vs persistent workers
//!   * dev eval:  per-call serial loop  vs  run_batch n=1  vs  run_batch n=T
//!   * dev eval engines: naive per-instruction interpreter (the pre-plan
//!     baseline, forced via `Runtime::set_naive_interp`) vs the preplanned
//!     engine — naive rows also land in results/bench_exec_baseline.csv and
//!     both engines' per-phase nanos (from `RuntimeStats` deltas) in
//!     results/bench_exec_phases.csv
//!   * calibrate: per-call serial loop  vs  batch-parallel calibrate n=T
//!
//! With `TQ_PERF_GATE` set (non-empty, not "0") the process exits 1 if the
//! planned engine's eval throughput is below `TQ_PERF_MIN_SPEEDUP`
//! (default 1.5) times the naive engine's — the CI perf-regression step.

use std::sync::mpsc;

use tq::coordinator::calibrate::{calibrate, run_diag, CalibCfg};
use tq::coordinator::{eval, Ctx};
use tq::data::{self, task_spec, TaskKind};
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy};
use tq::model::Params;
use tq::runtime::{lit_f32, lit_i32};
use tq::util::bench::{append_csv, Bencher};
use tq::util::pool::Pool;

const CSV: &str = "results/bench_exec.csv";
const BASELINE_CSV: &str = "results/bench_exec_baseline.csv";
const PHASES_CSV: &str = "results/bench_exec_phases.csv";

/// Append one engine's per-phase nanos (a `RuntimeStats` delta over a
/// timed section) to the phases CSV.
fn append_phases(path: &str, engine: &str, st: &tq::runtime::RuntimeStats) -> std::io::Result<()> {
    use std::io::Write;
    let p = std::path::Path::new(path);
    if let Some(dir) = p.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let write_header = !p.exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(p)?;
    if write_header {
        writeln!(
            f,
            "engine,executions,input_prep_nanos,exec_nanos,output_fetch_nanos"
        )?;
    }
    writeln!(
        f,
        "{engine},{},{},{},{}",
        st.executions, st.input_prep_nanos, st.exec_nanos, st.output_fetch_nanos
    )
}

/// The PR-1-era pool dispatch: scoped threads spawned per call, results
/// restored by index over an mpsc channel. Kept here as the bench
/// baseline for the persistent-worker pool.
fn spawn_per_call_run<R, F>(jobs: Vec<F>, threads: usize) -> Vec<R>
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    let total = jobs.len();
    let n = threads.min(total.max(1));
    if n <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let queue = std::sync::Mutex::new(jobs.into_iter().enumerate().collect::<Vec<_>>());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|s| {
        for _ in 0..n {
            let tx = tx.clone();
            let queue = &queue;
            s.spawn(move || loop {
                let job = queue.lock().expect("bench queue").pop();
                match job {
                    Some((i, j)) => {
                        let _ = tx.send((i, j()));
                    }
                    None => break,
                }
            });
        }
    });
    drop(tx);
    let mut slots: Vec<Option<R>> = std::iter::repeat_with(|| None).take(total).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    slots.into_iter().map(|o| o.expect("bench slot")).collect()
}

/// The pre-PR eval hot loop: one `run_lits_borrowed` call per batch,
/// strictly serial, statics re-converted by the backend on every call.
fn evaluate_per_call(
    ctx: &Ctx,
    task: &data::TaskSpec,
    params: &Params,
    act: &tq::model::qconfig::ActQuantTensors,
    split: &data::Split,
) -> f64 {
    let info = ctx.model_info(task).unwrap();
    let b = 8usize;
    let seq = info.config.seq;
    let n = split.examples.len();
    let n_classes = match task.kind {
        TaskKind::Classification(c) => c,
        TaskKind::Regression => 1,
    };
    let mut statics = Vec::new();
    for t in &params.tensors {
        statics.push(lit_f32(t.data(), t.shape()).unwrap());
    }
    statics.push(lit_f32(&act.scales, &[act.scales.len()]).unwrap());
    statics.push(lit_f32(&act.zps, &[act.zps.len()]).unwrap());
    statics.push(lit_f32(&act.cfg, &[info.sites.len(), 3]).unwrap());
    let mut pred = Vec::new();
    let mut gold = Vec::new();
    let mut start = 0usize;
    while start < n {
        let batch = data::make_batch(split, start, b, seq);
        let l_ids = lit_i32(&batch.ids, &[b, seq]).unwrap();
        let l_tt = lit_i32(&batch.token_type, &[b, seq]).unwrap();
        let l_mask = lit_f32(&batch.mask, &[b, seq]).unwrap();
        let mut lits: Vec<&xla::Literal> = statics.iter().collect();
        lits.push(&l_ids);
        lits.push(&l_tt);
        lits.push(&l_mask);
        let out = ctx.rt.run_lits_borrowed("fwd_cls_b8", &lits).unwrap();
        let logits = &out[0];
        for i in 0..(n - start).min(b) {
            let row = &logits.data()[i * info.config.n_out..(i + 1) * info.config.n_out];
            let p = row[..n_classes]
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.total_cmp(y.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            pred.push(p);
            gold.push(split.examples[start + i].label);
        }
        start += b;
    }
    tq::metrics::task_score(task.name, &pred, &gold, &[], &[])
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = std::env::var("TQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n: &usize| n > 0)
        .unwrap_or(threads);

    // --- pool dispatch overhead: spawn-per-call vs persistent workers ---
    let persistent = Pool::new(threads);
    let dispatch_work = || {
        (0..64u64)
            .map(|i| move || (0..400u64).fold(i, |a, x| a.wrapping_mul(31).wrapping_add(x)))
            .collect::<Vec<_>>()
    };
    let s = Bencher::quick().throughput(64).bench(
        &format!("pool dispatch 64 jobs [spawn-per-call n={threads}]"),
        || {
            std::hint::black_box(spawn_per_call_run(dispatch_work(), threads));
        },
    );
    append_csv(CSV, &s).ok();
    let spawn_ns = s.mean_ns;
    let s = Bencher::quick().throughput(64).bench(
        &format!("pool dispatch 64 jobs [persistent n={threads}]"),
        || {
            std::hint::black_box(persistent.run(dispatch_work()));
        },
    );
    append_csv(CSV, &s).ok();
    if s.mean_ns > 0.0 {
        println!(
            "pool dispatch speedup (persistent vs spawn-per-call): {:.2}x",
            spawn_ns / s.mean_ns
        );
    }

    // --- artifact-backed rows: interpreter dev eval + calibration ---
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!(
            "(artifacts/manifest.json absent — run `repro gen-artifacts` \
             for the eval/calibrate rows)"
        );
        return;
    }
    let mk_ctx = |pool: Pool| {
        Ctx::new("artifacts", "checkpoints", "results").unwrap().with_pool(pool)
    };
    let ctx1 = mk_ctx(Pool::new(1));
    let ctxn = mk_ctx(Pool::new(threads));
    let task = task_spec("sst2").unwrap();
    let info = ctx1.model_info(&task).unwrap();
    let params = Params::init(info, 7);
    let act = assemble_act_tensors(info, &QuantPolicy::fp32(), &Default::default()).unwrap();
    let mut split = data::dev_split(&task, info.config.seq).unwrap();
    split.examples.truncate(64); // 8 executable batches

    // sanity + warmup (parses the artifact into each runtime's cache)
    let want = evaluate_per_call(&ctx1, &task, &params, &act, &split);
    let got = eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap();
    assert_eq!(
        want.to_bits(),
        got.to_bits(),
        "batch-parallel eval diverged from the per-call loop"
    );
    eval::evaluate_split(&ctx1, &task, &params, &act, &split).unwrap();

    let s = Bencher::quick().throughput(64).bench("dev eval 64 ex [per-call serial]", || {
        std::hint::black_box(evaluate_per_call(&ctx1, &task, &params, &act, &split));
    });
    append_csv(CSV, &s).ok();
    let percall_ns = s.mean_ns;
    let s = Bencher::quick().throughput(64).bench("dev eval 64 ex [run_batch n=1]", || {
        std::hint::black_box(eval::evaluate_split(&ctx1, &task, &params, &act, &split).unwrap());
    });
    append_csv(CSV, &s).ok();
    let batch1_ns = s.mean_ns;
    let s = Bencher::quick().throughput(64).bench(
        &format!("dev eval 64 ex [run_batch n={threads}]"),
        || {
            let r = eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap();
            std::hint::black_box(r);
        },
    );
    append_csv(CSV, &s).ok();
    if s.mean_ns > 0.0 {
        println!(
            "eval speedup: run_batch n={threads} vs per-call serial = {:.2}x \
             (statics hoisting alone: {:.2}x)",
            percall_ns / s.mean_ns,
            percall_ns / batch1_ns
        );
    }

    // --- engine comparison on the tiny-BERT fwd artifact: the naive
    // per-instruction interpreter (forced, the pre-PR baseline measured
    // in-tree so before/after share one machine and build) vs the
    // preplanned engine — same ctx, same pool, same inputs ---
    ctxn.rt.set_naive_interp(true);
    let naive_score = eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap();
    ctxn.rt.set_naive_interp(false);
    let plan_score = eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap();
    assert_eq!(
        naive_score.to_bits(),
        plan_score.to_bits(),
        "preplanned engine diverged from the naive interpreter"
    );

    ctxn.rt.set_naive_interp(true);
    ctxn.rt.reset_stats();
    let s_naive = Bencher::quick().throughput(64).bench(
        &format!("dev eval 64 ex [engine=naive n={threads}]"),
        || {
            std::hint::black_box(
                eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap(),
            );
        },
    );
    append_csv(CSV, &s_naive).ok();
    append_csv(BASELINE_CSV, &s_naive).ok();
    append_phases(PHASES_CSV, "naive", &ctxn.rt.stats()).ok();

    ctxn.rt.set_naive_interp(false);
    ctxn.rt.reset_stats();
    let s_plan = Bencher::quick().throughput(64).bench(
        &format!("dev eval 64 ex [engine=planned n={threads}]"),
        || {
            std::hint::black_box(
                eval::evaluate_split(&ctxn, &task, &params, &act, &split).unwrap(),
            );
        },
    );
    append_csv(CSV, &s_plan).ok();
    append_phases(PHASES_CSV, "planned", &ctxn.rt.stats()).ok();

    let engine_speedup = if s_plan.mean_ns > 0.0 { s_naive.mean_ns / s_plan.mean_ns } else { 0.0 };
    println!(
        "interp engine speedup (planned vs naive, n={threads}): {engine_speedup:.2}x"
    );
    let gate = std::env::var("TQ_PERF_GATE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    if gate {
        let min: f64 = std::env::var("TQ_PERF_MIN_SPEEDUP")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(1.5);
        if engine_speedup < min {
            eprintln!(
                "PERF GATE FAILED: planned vs naive eval speedup \
                 {engine_speedup:.2}x < required {min:.2}x"
            );
            std::process::exit(1);
        }
        println!("perf gate passed: {engine_speedup:.2}x >= {min:.2}x");
    }

    // calibration: identical work (execute + observe, nb=8 bs=2) on a
    // 1-thread vs a T-thread pool — an equal-work speedup ratio
    let ccfg = CalibCfg { num_batches: 8, batch_size: 2, ..Default::default() };
    let s = Bencher::quick().throughput(16).bench(
        "calibrate nb=8 bs=2 [run_batch n=1]",
        || {
            std::hint::black_box(calibrate(&ctx1, &task, &params, &ccfg).unwrap());
        },
    );
    append_csv(CSV, &s).ok();
    let serial_ns = s.mean_ns;
    let s = Bencher::quick().throughput(16).bench(
        &format!("calibrate nb=8 bs=2 [batch-parallel n={threads}]"),
        || {
            std::hint::black_box(calibrate(&ctxn, &task, &params, &ccfg).unwrap());
        },
    );
    append_csv(CSV, &s).ok();
    if s.mean_ns > 0.0 {
        println!(
            "calibrate speedup: batch-parallel n={threads} vs run_batch n=1 = {:.2}x",
            serial_ns / s.mean_ns
        );
    }
    // reference row, exec-only (no estimator work): the pre-PR per-call
    // diag loop — comparable to nothing above, recorded for the statics
    // conversion cost it re-pays on every call
    let fp32 = assemble_act_tensors(info, &QuantPolicy::fp32(), &Default::default()).unwrap();
    let tsplit = data::train_split(&task, info.config.seq).unwrap();
    let s = Bencher::quick().throughput(16).bench(
        "diag exec-only 16 seqs [per-call serial]",
        || {
            for k in 0..16usize {
                let ex = &tsplit.examples[k % tsplit.examples.len()];
                std::hint::black_box(
                    run_diag(
                        &ctx1,
                        "diag_cls_b1",
                        info,
                        &params,
                        &fp32.scales,
                        &fp32.zps,
                        &fp32.cfg,
                        ex,
                    )
                    .unwrap(),
                );
            }
        },
    );
    append_csv(CSV, &s).ok();
    println!("CSV appended to {CSV}");
}
