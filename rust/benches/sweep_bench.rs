//! Sweep-engine benchmark: the same ≥4-configuration grid executed with a
//! serial pool (n=1) and with all available workers, recording both to
//! results/bench_sweep.csv plus the measured speedup. The offline
//! substrate sweep is used so the bench runs (and the speedup is
//! reproducible) without AOT artifacts.

use tq::coordinator::sweep::{grid, run_offline, synth_data};
use tq::model::manifest::Architecture;
use tq::quant::{Estimator, RangeMethod};
use tq::util::bench::{append_csv, Bencher};
use tq::util::pool::Pool;

fn main() {
    let csv = "results/bench_sweep.csv";
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let data = synth_data(128, 64, 8, 42);
    // 2 act-bits x 3 granularities x 2 estimators = 12 configurations
    let cfgs = grid(
        128,
        &[Architecture::Bert],
        &[8, 4],
        &[8],
        &[1, 8, 128],
        &[Estimator::CurrentMinMax, Estimator::Mse],
        &[RangeMethod::Auto],
    )
    .unwrap();
    println!("sweep bench: {} configs, up to {threads} workers", cfgs.len());

    let mut means = Vec::new();
    for (name, pool) in [
        ("sweep 12 configs [serial n=1]".to_string(), Pool::new(1)),
        (format!("sweep 12 configs [parallel n={threads}]"), Pool::new(threads)),
    ] {
        let s = Bencher::quick().throughput(cfgs.len() as u64).bench(&name, || {
            std::hint::black_box(run_offline(&data, &cfgs, &pool).unwrap());
        });
        means.push(s.mean_ns);
        append_csv(csv, &s).ok();
    }
    if means.len() == 2 && means[1] > 0.0 {
        println!("parallel speedup: {:.2}x", means[0] / means[1]);
    }
}
