//! Offline stub of the `xla` PJRT binding used by `tq::runtime`.
//!
//! The crate snapshot in this environment does not include the real XLA
//! binding (it links the PJRT C++ runtime), so this stub provides the same
//! *types and signatures* with honest semantics:
//!
//! * [`Literal`] is a real host-side tensor container — `vec1`, `reshape`,
//!   `to_vec`, `element_count` behave exactly like the real crate, so all
//!   the literal-assembly plumbing in `tq::runtime` works and is testable.
//! * [`PjRtClient::cpu`] succeeds (it allocates nothing), but
//!   [`PjRtClient::compile`] returns an error stating that the PJRT
//!   backend is unavailable. `tq::runtime` treats that compile error as
//!   the signal to fall back to the in-repo HLO interpreter
//!   (`tq::hlo`), so artifacts still *execute* in offline containers —
//!   this stub only ever reports honestly that it cannot.
//!
//! Swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! binding to run artifacts on a real PJRT client; no `tq` source
//! changes are needed (the `ExecBackend` seam picks PJRT whenever
//! `compile` succeeds).
//!
//! All types are plain data, hence `Send + Sync` — which is what lets
//! `tq::runtime::Runtime` keep its compiled-executable cache behind a
//! `Mutex` and be shared across the sweep engine's worker threads.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error type (implements `std::error::Error` so `?` converts it into
/// `anyhow::Error` at the call sites).
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str = "XLA PJRT backend unavailable in this offline build \
     (vendor/xla-stub); tq::runtime falls back to the in-repo HLO \
     interpreter, or swap the `xla` path dependency for the real binding";

/// Element types a [`Literal`] can hold (the subset tq uses).
pub trait NativeType: Copy {
    fn make(data: &[Self], dims: Vec<i64>) -> Literal;
    fn take(lit: &Literal) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn make(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::F32 { data: data.to_vec(), dims }
    }

    fn take(lit: &Literal) -> Option<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn make(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::I32 { data: data.to_vec(), dims }
    }

    fn take(lit: &Literal) -> Option<Vec<Self>> {
        match lit {
            Literal::I32 { data, .. } => Some(data.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor value, matching the real crate's literal semantics for
/// the operations tq performs.
#[derive(Debug, Clone)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::make(data, vec![data.len() as i64])
    }

    /// Reinterpret the shape; errors when the element count differs
    /// (product of an empty dims list is 1, i.e. a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements cannot view as {dims:?}",
                self.element_count()
            )));
        }
        let mut out = self.clone();
        match &mut out {
            Literal::F32 { dims: d, .. } => *d = dims.to_vec(),
            Literal::I32 { dims: d, .. } => *d = dims.to_vec(),
            Literal::Tuple(_) => return Err(Error::new("reshape on tuple literal")),
        }
        Ok(out)
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    /// Dimensions of an array literal (empty for scalars AND for tuples —
    /// callers that may hold tuples should match on the variant instead).
    pub fn dims(&self) -> Vec<i64> {
        match self {
            Literal::F32 { dims, .. } => dims.clone(),
            Literal::I32 { dims, .. } => dims.clone(),
            Literal::Tuple(_) => Vec::new(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::take(self).ok_or_else(|| Error::new("literal element type mismatch"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module (the stub keeps the raw text so `from_text_file`
/// still validates that the artifact file exists and is readable).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        let path = path.as_ref();
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => Err(Error::new(format!("{}: {e}", path.display()))),
        }
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::new(UNAVAILABLE))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::new(UNAVAILABLE))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.dims(), vec![2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_reshape() {
        let l = Literal::vec1(&[5.0f32]).reshape(&[]).unwrap();
        assert_eq!(l.element_count(), 1);
    }

    #[test]
    fn int_literal() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn client_compiles_to_clear_error() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&XlaComputation).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn types_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Literal>();
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<Error>();
    }
}
