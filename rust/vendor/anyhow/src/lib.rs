//! Offline stand-in for the `anyhow` crate, covering exactly the API
//! surface this repository uses: `Error`, `Result`, the `anyhow!` /
//! `bail!` / `ensure!` macros, and the `Context` extension trait.
//!
//! The error is a rendered message string (the source chain is flattened
//! at conversion time), which keeps the shim dependency-free and `Send +
//! Sync` so errors can cross the `util::pool` thread boundaries.

use std::fmt;

/// A flattened, message-carrying error (real anyhow keeps the boxed chain;
/// we render it eagerly instead).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: convert from any std error, flattening the source
// chain into the message. `Error` itself deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

/// `.context(..)` / `.with_context(|| ..)` on results whose error converts
/// into [`Error`] (std errors via the blanket `From`, and `Error` itself).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{c}: {}", e.msg) }
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error { msg: format!("{}: {}", f(), e.msg) }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/file")?)
    }

    #[test]
    fn io_error_converts_and_contextualizes() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
        let e2 = fails_io().with_context(|| "loading config").unwrap_err();
        assert!(e2.to_string().starts_with("loading config: "));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope {x}", x = 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
        fn g(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok");
            Ok(3)
        }
        assert!(g(true).is_ok());
        assert!(g(false).is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Error>();
    }
}
