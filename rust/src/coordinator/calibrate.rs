//! Calibration runner: streams calibration sequences through the
//! diagnostic executable (quantizers disabled → FP32 taps at every site)
//! and feeds per-site range estimators; also accumulates the per-layer
//! Gram matrices AdaRound needs.
//!
//! Matches the paper's static range estimation (§2): a few batches of
//! calibration data, estimator ∈ {current min-max, running min-max, MSE},
//! batch size and batch count per Appendix B.2.
//!
//! Execution shape: the diag taps for one sequence do not depend on the
//! estimator state, so every sequence of every calibration batch is
//! independent — they fan out through
//! [`Runtime::run_batch`](crate::runtime::Runtime::run_batch) on
//! `ctx.pool`, one bounded window (a pool's worth of batches) at a time
//! so peak tap memory stays proportional to the window, not the whole
//! run. The estimators then observe the reassembled taps strictly in
//! batch order, which keeps order-sensitive estimators (running min-max,
//! the MSE reservoir) bit-identical to a serial run at any window or
//! thread count (pinned by tests/determinism.rs).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::{diag_artifact_var, example_input_lits, Ctx};
use crate::data::{self, TaskSpec};
use crate::model::manifest::{Architecture, AttnVariant};
use crate::model::qconfig::{assemble_act_tensors, QuantPolicy};
use crate::model::Params;
use crate::quant::estimators::RangeTracker;
use crate::quant::Estimator;
use crate::tensor::Tensor;
use crate::util::pool::Pool;

/// Calibration output: per-site trackers plus (optional) AdaRound Grams.
pub struct Calibration {
    pub trackers: BTreeMap<String, RangeTracker>,
    /// site name -> (G = XᵀX over token rows, row count) for sites that
    /// feed linear layers
    pub grams: BTreeMap<String, (Tensor, f32)>,
}

#[derive(Debug, Clone)]
pub struct CalibCfg {
    pub estimator: Estimator,
    /// batch size (sequences per estimator observation)
    pub batch_size: usize,
    /// number of observations
    pub num_batches: usize,
    pub collect_grams: bool,
    pub seed: u64,
}

impl Default for CalibCfg {
    fn default() -> Self {
        // paper Appendix B.2: running min-max with bs=1, nb=16 is the most
        // common best configuration
        CalibCfg {
            estimator: Estimator::RunningMinMax,
            batch_size: 1,
            num_batches: 16,
            collect_grams: false,
            seed: 0,
        }
    }
}

/// Sites whose taps are inputs of linear layers (for AdaRound).
pub fn gram_sites(layers: usize) -> Vec<String> {
    let mut v = vec!["embed_ln_out".to_string()];
    for i in 0..layers {
        v.push(format!("layer{i}.attn_ctx"));
        v.push(format!("layer{i}.ln1_out"));
        v.push(format!("layer{i}.ffn_hidden"));
        v.push(format!("layer{i}.ln2_out"));
    }
    v.push("pooled".to_string());
    v
}

/// Run calibration for `task` on FP32 `params` (BERT family).
pub fn calibrate(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    cfg: &CalibCfg,
) -> Result<Calibration> {
    calibrate_with_arch(ctx, task, Architecture::Bert, params, cfg, None)
}

/// [`calibrate`] against a specific architecture family's diag artifacts.
pub fn calibrate_arch(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    params: &Params,
    cfg: &CalibCfg,
) -> Result<Calibration> {
    calibrate_with_arch(ctx, task, arch, params, cfg, None)
}

/// True when a site's resolved config needs retained row samples at
/// calibration time — [`RangeMethod::needs_row_samples`] gated on the
/// site actually being quantized.
fn needs_row_samples(sc: &crate::model::qconfig::SiteCfg, estimator: Estimator) -> bool {
    sc.enabled && sc.range_method.needs_row_samples(estimator)
}

/// Policy-aware [`calibrate`]: when the resolved activation policy is
/// known up front, sites whose range method needs an MSE search beyond
/// what the calibration estimator retains get row-sampling trackers
/// ([`RangeTracker::with_row_samples`]) — so `mse_group` / `mse_tensor`
/// sites work under *any* calibration estimator. With `policy == None`
/// this is exactly the old behaviour.
pub fn calibrate_with(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    cfg: &CalibCfg,
    policy: Option<&QuantPolicy>,
) -> Result<Calibration> {
    calibrate_with_arch(ctx, task, Architecture::Bert, params, cfg, policy)
}

/// [`calibrate_with`], architecture-generic: the diag artifact, model
/// info, and per-example input literals all follow `arch`.
pub fn calibrate_with_arch(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    params: &Params,
    cfg: &CalibCfg,
    policy: Option<&QuantPolicy>,
) -> Result<Calibration> {
    calibrate_with_var(ctx, task, arch, AttnVariant::Vanilla, params, cfg, policy)
}

/// [`calibrate_with_arch`] for a specific attention variant: the diag
/// artifact and model info follow the (architecture, variant) family.
/// The site inventory is family-independent, so the same spec calibrates
/// any family.
#[allow(clippy::too_many_arguments)]
pub fn calibrate_with_var(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
    params: &Params,
    cfg: &CalibCfg,
    policy: Option<&QuantPolicy>,
) -> Result<Calibration> {
    let info = ctx.model_info_var(task, arch, variant)?;
    let artifact = diag_artifact_var(arch, variant, ctx.head(task));
    let seq = info.config.seq;
    // calibration data comes from the training split (paper: "passing a
    // few batches of calibration data")
    let split = data::train_split(task, seq)?;

    let mut trackers: BTreeMap<String, RangeTracker> = info
        .sites
        .iter()
        .map(|s| {
            let mut tr = RangeTracker::new(cfg.estimator, s.channels);
            if policy.is_some_and(|p| needs_row_samples(p.site_cfg(&s.name), cfg.estimator)) {
                tr = tr.with_row_samples();
            }
            (s.name.clone(), tr)
        })
        .collect();
    let gsites = gram_sites(info.config.layers);
    let mut grams: BTreeMap<String, (Tensor, f32)> = BTreeMap::new();

    // FP32 taps: quantizers disabled
    let fp32 = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
    if cfg.batch_size == 0 {
        bail!("calibration batch_size must be >= 1");
    }
    if split.examples.is_empty() {
        bail!("calibration split for {} has no examples", task.name);
    }
    let seq0 = (cfg.seed as usize) % split.examples.len();

    // Execute every calibration sequence batch-parallel: statics (params
    // + disabled quantizers) are shared, per-sequence literals are built
    // on the worker that runs them, and taps come back in sequence order.
    let n_sites = info.sites.len();
    let static_lits =
        super::static_input_lits(params, &fp32.scales, &fp32.zps, &fp32.cfg, n_sites)?;

    // Per-site statistics fan out across the pool too — every site's
    // tracker and Gram are independent, so site-level parallelism is
    // deterministic by construction.
    let pool = &ctx.pool;
    let serial = Pool::serial();
    // Fan out a bounded window of batches at a time: one pool's worth of
    // parallelism with peak tap memory bounded by `window × batch_size`
    // sequences, not the whole calibration run. Windows execute in batch
    // order and observations are fed strictly in batch order below, so
    // order-sensitive estimators stay bit-identical to a serial run.
    let window = pool.threads().max(1);
    for wb in (0..cfg.num_batches).step_by(window) {
        let n_b = window.min(cfg.num_batches - wb);
        let base = wb * cfg.batch_size;
        let mut outs = ctx.rt.run_batch(
            &artifact,
            &static_lits,
            n_b * cfg.batch_size,
            |k| {
                let ex = &split.examples[(seq0 + base + k) % split.examples.len()];
                example_input_lits(info, ex)
            },
            &ctx.pool,
        )?;
        for chunk in outs.chunks_mut(cfg.batch_size) {
            // emulate batch-size > 1 by concatenating per-sequence taps
            // before one estimator observation
            let mut site_batches: BTreeMap<String, Vec<Tensor>> = BTreeMap::new();
            for out in chunk.iter_mut() {
                // outputs: logits, then taps in site order
                let taps = out.split_off(1);
                for (s, t) in info.sites.iter().zip(taps) {
                    site_batches.entry(s.name.clone()).or_default().push(t);
                }
            }
            let joined: Vec<(String, Tensor)> = site_batches
                .into_iter()
                .map(|(site, parts)| concat_rows(&parts).map(|j| (site, j)))
                .collect::<Result<_>>()?;
            {
                let tensors: BTreeMap<&str, &Tensor> =
                    joined.iter().map(|(s, t)| (s.as_str(), t)).collect();
                let mut work: Vec<(&mut RangeTracker, &Tensor)> = trackers
                    .iter_mut()
                    .filter_map(|(name, tr)| tensors.get(name.as_str()).map(|t| (tr, *t)))
                    .collect();
                if work.len() != joined.len() {
                    bail!("calibration produced taps for sites without trackers");
                }
                let observed =
                    pool.par_iter_mut(&mut work, |_, w| w.0.observe_pool(w.1, &serial));
                for r in observed {
                    r?;
                }
            }
            if cfg.collect_grams {
                let gwork: Vec<&(String, Tensor)> =
                    joined.iter().filter(|(s, _)| gsites.contains(s)).collect();
                let computed = pool.par_map(&gwork, |_, item| gram_of(&item.1));
                for (item, res) in gwork.iter().zip(computed) {
                    let (g, rows) = res?;
                    merge_gram(&mut grams, &item.0, g, rows);
                }
            }
        }
    }
    Ok(Calibration { trackers, grams })
}

/// Execute the diagnostic artifact on one example; returns site -> tap.
#[allow(clippy::too_many_arguments)]
pub fn run_diag(
    ctx: &Ctx,
    artifact: &str,
    info: &crate::model::manifest::ModelInfo,
    params: &Params,
    act_scales: &[f32],
    act_zps: &[f32],
    act_cfg: &[f32],
    ex: &data::Example,
) -> Result<BTreeMap<String, Tensor>> {
    let n_sites = info.sites.len();
    let mut lits = super::static_input_lits(params, act_scales, act_zps, act_cfg, n_sites)?;
    lits.extend(example_input_lits(info, ex)?);
    let mut out = ctx.rt.run_lits(artifact, &lits)?;
    // outputs: logits, then taps in site order
    let taps = out.split_off(1);
    Ok(info
        .sites
        .iter()
        .map(|s| s.name.clone())
        .zip(taps)
        .collect())
}

/// Concatenate tensors along a new leading "rows" axis (flattening all but
/// the last axis). An empty slice is an error, not an index panic — it
/// can only mean a calibration batch produced no taps for a site.
fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
    let Some(first) = parts.first() else {
        bail!("concat_rows: no tensors to concatenate (empty calibration batch?)");
    };
    let d = first.last_dim();
    let mut data = Vec::new();
    let mut rows = 0usize;
    for p in parts {
        rows += p.rows();
        data.extend_from_slice(p.data());
    }
    Tensor::new(vec![rows, d], data)
}

/// G = XᵀX of the (rows, d)-flattened tap plus the row count.
fn gram_of(x: &Tensor) -> Result<(Tensor, f32)> {
    let d = x.last_dim();
    let rows = x.rows();
    let flat = Tensor::new(vec![rows, d], x.data().to_vec())?;
    let g = flat.transpose2()?.matmul(&flat)?;
    Ok((g, rows as f32))
}

/// Add one batch's Gram contribution into the per-site accumulator.
fn merge_gram(grams: &mut BTreeMap<String, (Tensor, f32)>, site: &str, g: Tensor, rows: f32) {
    match grams.get_mut(site) {
        Some((acc, n)) => {
            for (a, b) in acc.data_mut().iter_mut().zip(g.data()) {
                *a += b;
            }
            *n += rows;
        }
        None => {
            grams.insert(site.to_string(), (g, rows));
        }
    }
}

#[allow(dead_code)]
fn accumulate_gram(
    grams: &mut BTreeMap<String, (Tensor, f32)>,
    site: &str,
    x: &Tensor,
) -> Result<()> {
    let (g, rows) = gram_of(x)?;
    merge_gram(grams, site, g, rows);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_sites_cover_all_linear_inputs() {
        let g = gram_sites(6);
        assert_eq!(g.len(), 2 + 4 * 6);
        assert!(g.contains(&"layer5.ffn_hidden".to_string()));
        assert!(g.contains(&"embed_ln_out".to_string()));
    }

    #[test]
    fn concat_rows_shapes() {
        let a = Tensor::zeros(&[1, 4, 3]);
        let b = Tensor::zeros(&[1, 4, 3]);
        let c = concat_rows(&[a, b]).unwrap();
        assert_eq!(c.shape(), &[8, 3]);
    }

    #[test]
    fn concat_rows_empty_is_an_error_not_a_panic() {
        let err = concat_rows(&[]).unwrap_err();
        assert!(err.to_string().contains("concat_rows"), "{err}");
    }

    #[test]
    fn row_sampling_follows_the_range_method() {
        use crate::model::qconfig::SiteCfg;
        use crate::quant::RangeMethod;
        let mk = |m: RangeMethod, enabled: bool| SiteCfg {
            range_method: m,
            enabled,
            ..Default::default()
        };
        // mse_group always samples rows; mse_tensor only when the
        // estimator does not already keep an MSE reservoir
        assert!(needs_row_samples(&mk(RangeMethod::MsePerGroup, true), Estimator::Mse));
        assert!(needs_row_samples(
            &mk(RangeMethod::MsePerGroup, true),
            Estimator::RunningMinMax
        ));
        assert!(needs_row_samples(
            &mk(RangeMethod::MseTensor, true),
            Estimator::RunningMinMax
        ));
        assert!(!needs_row_samples(&mk(RangeMethod::MseTensor, true), Estimator::Mse));
        assert!(!needs_row_samples(&mk(RangeMethod::Auto, true), Estimator::RunningMinMax));
        assert!(!needs_row_samples(
            &mk(RangeMethod::CurrentMinMax, true),
            Estimator::RunningMinMax
        ));
        // disabled sites never pay the sample memory
        assert!(!needs_row_samples(
            &mk(RangeMethod::MsePerGroup, false),
            Estimator::RunningMinMax
        ));
    }

    #[test]
    fn gram_accumulation() {
        let mut grams = BTreeMap::new();
        let x = Tensor::new(vec![2, 2], vec![1., 0., 0., 1.]).unwrap();
        accumulate_gram(&mut grams, "s", &x).unwrap();
        accumulate_gram(&mut grams, "s", &x).unwrap();
        let (g, n) = &grams["s"];
        assert_eq!(*n, 4.0);
        assert_eq!(g.data(), &[2., 0., 0., 2.]); // 2 * I
    }
}
