//! Diagnostics powering the paper's figures:
//! * Fig. 2a — per-token ranges of FFN input/output in a deep layer
//! * Fig. 2b / 6-8 — outlier maps (>6σ) across embedding dims
//! * Fig. 5 — attention mass on [SEP] per head (the "no-op" pattern)
//! * Fig. 9-13 — per-sequence FFN ranges across architecture variants

use std::collections::BTreeMap;

use anyhow::Result;

use super::{diag_artifact_var, example_input_lits, Ctx};
use crate::data::{self, TaskSpec};
use crate::model::manifest::{Architecture, AttnVariant, ModelInfo};
use crate::model::qconfig::{assemble_act_tensors, QuantPolicy};
use crate::model::Params;
use crate::tensor::Tensor;

/// Taps for a handful of dev sequences, FP32.
pub struct DiagRun {
    /// per-sequence site -> tensor
    pub per_seq: Vec<BTreeMap<String, Tensor>>,
    pub examples: Vec<data::Example>,
}

pub fn collect_taps(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    n_seqs: usize,
) -> Result<DiagRun> {
    collect_taps_arch(ctx, task, Architecture::Bert, params, n_seqs)
}

/// [`collect_taps`] against a specific architecture family's artifacts.
pub fn collect_taps_arch(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    params: &Params,
    n_seqs: usize,
) -> Result<DiagRun> {
    collect_taps_var(ctx, task, arch, AttnVariant::Vanilla, params, n_seqs)
}

/// [`collect_taps_arch`] for a specific attention variant family — the
/// artifact and model-info resolution used by `repro diag --outliers`
/// when comparing vanilla against a clipped-softmax/gated model.
pub fn collect_taps_var(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
    params: &Params,
    n_seqs: usize,
) -> Result<DiagRun> {
    let info = ctx.model_info_var(task, arch, variant)?;
    collect_taps_with(
        ctx,
        &diag_artifact_var(arch, variant, ctx.head(task)),
        info,
        task,
        params,
        n_seqs,
    )
}

/// Variant-agnostic tap collection (used for Fig. 9-13 model sweeps where
/// the artifact name and model info differ).
///
/// The per-sequence diag executions are independent, so they fan out
/// through [`crate::runtime::Runtime::run_batch`] on `ctx.pool`: the
/// static inputs (params + disabled quantizers) are built once, each
/// sequence's literals are built on the worker that runs it, and the taps
/// are reassembled in sequence order — `per_seq[i]` is bit-identical to a
/// serial [`super::calibrate::run_diag`] loop at any thread count (pinned
/// by tests/determinism.rs).
pub fn collect_taps_with(
    ctx: &Ctx,
    artifact: &str,
    info: &ModelInfo,
    task: &TaskSpec,
    params: &Params,
    n_seqs: usize,
) -> Result<DiagRun> {
    let split = data::dev_split(task, info.config.seq)?;
    let fp32 = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
    let n = n_seqs.min(split.examples.len());
    let static_lits = super::static_input_lits(
        params,
        &fp32.scales,
        &fp32.zps,
        &fp32.cfg,
        info.sites.len(),
    )?;
    let outs = ctx.rt.run_batch(
        artifact,
        &static_lits,
        n,
        |i| example_input_lits(info, &split.examples[i]),
        &ctx.pool,
    )?;
    let mut per_seq = Vec::with_capacity(n);
    let mut examples = Vec::with_capacity(n);
    for (ex, mut out) in split.examples.iter().take(n).zip(outs) {
        // outputs: logits, then taps in site order
        let taps = out.split_off(1);
        per_seq.push(
            info.sites
                .iter()
                .map(|s| s.name.clone())
                .zip(taps)
                .collect::<BTreeMap<String, Tensor>>(),
        );
        examples.push(ex.clone());
    }
    Ok(DiagRun { per_seq, examples })
}

/// Fig. 2a: per-token min/max of one site for one sequence (masked tokens
/// excluded).
pub fn per_token_ranges(taps: &BTreeMap<String, Tensor>, site: &str, mask: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let t = &taps[site]; // (1, T, d)
    let (lo, hi) = t.row_min_max();
    let take = mask.iter().filter(|&&m| m == 1.0).count().min(lo.len());
    (lo[..take].to_vec(), hi[..take].to_vec())
}

/// Fig. 2b: boolean outlier mask over (token, dim): |x - mean| > 6σ of the
/// whole tensor (the paper's definition).
pub fn outlier_mask(taps: &BTreeMap<String, Tensor>, site: &str) -> (Vec<bool>, usize, usize) {
    let t = &taps[site]; // (1, T, d)
    let mean = t.mean();
    let std = t.std().max(1e-9);
    let d = t.last_dim();
    let rows = t.rows();
    let mask = t
        .data()
        .iter()
        .map(|&x| (x - mean).abs() > 6.0 * std)
        .collect();
    (mask, rows, d)
}

/// Dims that are outliers in at least `min_count` of the sequences —
/// the "few designated embedding dimensions" of Fig. 2b.
pub fn consistent_outlier_dims(runs: &DiagRun, site: &str, min_count: usize) -> Vec<usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for taps in &runs.per_seq {
        let (mask, rows, d) = outlier_mask(taps, site);
        let mut dims = vec![false; d];
        for r in 0..rows {
            for c in 0..d {
                if mask[r * d + c] {
                    dims[c] = true;
                }
            }
        }
        for (c, &hit) in dims.iter().enumerate() {
            if hit {
                *counts.entry(c).or_default() += 1;
            }
        }
    }
    counts
        .into_iter()
        .filter(|(_, n)| *n >= min_count)
        .map(|(c, _)| c)
        .collect()
}

/// Fig. 5: fraction of attention probability mass on [SEP] tokens, per
/// head, for one layer. Returns (heads,) means over real (unmasked) query
/// tokens.
pub fn attention_sep_mass(
    info: &ModelInfo,
    taps: &BTreeMap<String, Tensor>,
    ex: &data::Example,
    layer: usize,
) -> Vec<f32> {
    let probs = &taps[&format!("layer{layer}.attn_probs")]; // (1, h, T, T)
    let h = info.config.heads;
    let t_len = info.config.seq;
    // [SEP] is a BERT notion; for architectures without one (ViT) every
    // head reports zero mass rather than a bogus column
    let sep_cols: Vec<usize> = match info.config.arch.sep_id() {
        Some(sep) => ex
            .ids
            .iter()
            .enumerate()
            .filter(|(_, &id)| id == sep)
            .map(|(i, _)| i)
            .collect(),
        None => Vec::new(),
    };
    let real_rows: Vec<usize> = ex
        .mask
        .iter()
        .enumerate()
        .filter(|(_, &m)| m == 1.0)
        .map(|(i, _)| i)
        .collect();
    let mut out = vec![0f32; h];
    for head in 0..h {
        let mut acc = 0f32;
        for &r in &real_rows {
            let row0 = head * t_len * t_len + r * t_len;
            let mass: f32 = sep_cols.iter().map(|&c| probs.data()[row0 + c]).sum();
            acc += mass;
        }
        out[head] = acc / real_rows.len().max(1) as f32;
    }
    out
}

/// Fig. 9-13: per-sequence (min, max) of a site across several sequences.
pub fn per_sequence_ranges(runs: &DiagRun, site: &str) -> Vec<(f32, f32)> {
    runs.per_seq
        .iter()
        .map(|taps| {
            let t = &taps[site];
            (t.min(), t.max())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_taps(site: &str, t: Tensor) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert(site.to_string(), t);
        m
    }

    #[test]
    fn outlier_mask_flags_extremes() {
        let mut data = vec![0.0f32; 64];
        data[10] = 100.0;
        let taps = fake_taps("s", Tensor::new(vec![1, 8, 8], data).unwrap());
        let (mask, rows, d) = outlier_mask(&taps, "s");
        assert_eq!((rows, d), (8, 8));
        assert!(mask[10]);
        assert_eq!(mask.iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn per_token_ranges_respect_mask() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let taps = fake_taps("s", Tensor::new(vec![1, 4, 3], data).unwrap());
        let mask = vec![1.0, 1.0, 0.0, 0.0];
        let (lo, hi) = per_token_ranges(&taps, "s", &mask);
        assert_eq!(lo, vec![0.0, 3.0]);
        assert_eq!(hi, vec![2.0, 5.0]);
    }
}
