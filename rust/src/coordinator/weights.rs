//! Weight post-training quantization, applied Rust-side to the parameter
//! tensors before execution (the paper's simulated-quantization setup):
//! symmetric per-tensor with min-max or MSE ranges, Q-BERT-style group-wise
//! per-channel, and AdaRound with calibration Grams.

use std::collections::BTreeMap;

use anyhow::Result;

use super::calibrate::Calibration;
use crate::model::manifest::ModelInfo;
use crate::model::qconfig::QuantPolicy;
use crate::model::Params;
use crate::quant::adaround::adaround_with_gram;
use crate::quant::estimators::mse_search;
use crate::quant::{
    qdq_weight_per_channel, qparams_from_range, qparams_symmetric, Estimator, QGrid,
};
use crate::tensor::Tensor;

/// Which tap site feeds each quantized weight (for AdaRound's layer
/// reconstruction). `pool.w` consumes the last encoder output; `head.w`
/// the pooled vector; `embed.tok` has no activation input (falls back to
/// plain rounding on the table itself).
pub fn input_site_for_weight(info: &ModelInfo, name: &str) -> Option<String> {
    let layers = info.config.layers;
    if name == "pool.w" {
        return Some(format!("layer{}.ln2_out", layers - 1));
    }
    if name == "head.w" {
        return Some("pooled".to_string());
    }
    if let Some(rest) = name.strip_prefix("layer") {
        let (idx, field) = rest.split_once('.')?;
        let i: usize = idx.parse().ok()?;
        let site = match field {
            "q.w" | "k.w" | "v.w" => {
                if i == 0 {
                    "embed_ln_out".to_string()
                } else {
                    format!("layer{}.ln2_out", i - 1)
                }
            }
            "attn_out.w" => format!("layer{i}.attn_ctx"),
            "ffn1.w" => format!("layer{i}.ln1_out"),
            "ffn2.w" => format!("layer{i}.ffn_hidden"),
            _ => return None,
        };
        return Some(site);
    }
    None
}

/// Symmetric per-tensor QDQ with the chosen range estimator.
pub fn qdq_weight(t: &Tensor, bits: u32, estimator: Estimator) -> Tensor {
    let grid = QGrid::symmetric(bits);
    match estimator {
        Estimator::Mse => {
            let amax = t.abs_max();
            let (lo, hi) = mse_search(t.data(), -amax, amax, grid);
            // keep symmetric: use the larger magnitude
            let m = lo.abs().max(hi.abs());
            let p = qparams_symmetric(m, grid);
            crate::quant::qdq_tensor(t, p, grid)
        }
        _ => {
            let p = qparams_symmetric(t.abs_max(), grid);
            crate::quant::qdq_tensor(t, p, grid)
        }
    }
}

/// Options for AdaRound application.
#[derive(Debug, Clone, Default)]
pub struct AdaRoundOpts {
    pub enabled: bool,
    pub cfg: AdaRoundCfg2,
}

/// Serializable-ish AdaRound knobs (wraps quant::adaround::AdaRoundCfg).
#[derive(Debug, Clone)]
pub struct AdaRoundCfg2 {
    pub iters: usize,
    pub lr: f32,
}

impl Default for AdaRoundCfg2 {
    fn default() -> Self {
        AdaRoundCfg2 { iters: 1000, lr: 1e-2 }
    }
}

/// Quantize all weights of `params` per `policy`, returning new params and
/// a per-weight report of (bits, method).
pub fn quantize_weights(
    info: &ModelInfo,
    params: &Params,
    policy: &QuantPolicy,
    calib: Option<&Calibration>,
    ada: &AdaRoundOpts,
) -> Result<(Params, BTreeMap<String, String>)> {
    let mut out = params.clone();
    let mut report = BTreeMap::new();
    for name in &info.wq {
        let wc = policy.weight_cfg(name);
        if !wc.enabled {
            report.insert(name.clone(), "fp32".to_string());
            continue;
        }
        let t = params.get(name)?;
        let method;
        let quantized = if let Some(groups) = wc.per_channel_groups {
            method = format!("{}b per-channel x{groups}", wc.bits);
            qdq_weight_per_channel(t, wc.bits, groups)?
        } else if ada.enabled && t.shape().len() == 2 {
            // AdaRound needs the layer's input Gram; fall back to plain
            // rounding when unavailable (e.g. the embedding table)
            let site = input_site_for_weight(info, name);
            let gram = site
                .as_ref()
                .and_then(|s| calib.and_then(|c| c.grams.get(s)));
            match gram {
                Some((g, n)) => {
                    let grid = QGrid::symmetric(wc.bits);
                    let p = match wc.estimator {
                        Estimator::Mse => {
                            let amax = t.abs_max();
                            let (lo, hi) = mse_search(t.data(), -amax, amax, grid);
                            qparams_symmetric(lo.abs().max(hi.abs()), grid)
                        }
                        _ => qparams_symmetric(t.abs_max(), grid),
                    };
                    let r = adaround_with_gram(
                        t,
                        g,
                        *n,
                        p,
                        grid,
                        &crate::quant::adaround::AdaRoundCfg {
                            iters: ada.cfg.iters,
                            lr: ada.cfg.lr,
                            ..Default::default()
                        },
                    )?;
                    method = format!("{}b adaround", wc.bits);
                    r.weight
                }
                None => {
                    method = format!("{}b {:?} (no gram)", wc.bits, wc.estimator);
                    qdq_weight(t, wc.bits, wc.estimator)
                }
            }
        } else {
            method = format!("{}b {:?}", wc.bits, wc.estimator);
            qdq_weight(t, wc.bits, wc.estimator)
        };
        *out.get_mut(name)? = quantized;
        report.insert(name.clone(), method);
    }
    Ok((out, report))
}

/// Range for the zero-protected asymmetric activation used by tests.
#[allow(dead_code)]
pub fn act_params_for_range(lo: f32, hi: f32, bits: u32) -> crate::quant::QParams {
    qparams_from_range(lo, hi, QGrid::asymmetric(bits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;

    #[test]
    fn weight_site_mapping() {
        let mut info = tiny_model_info();
        info.config.layers = 3;
        assert_eq!(
            input_site_for_weight(&info, "layer0.q.w").unwrap(),
            "embed_ln_out"
        );
        assert_eq!(
            input_site_for_weight(&info, "layer2.k.w").unwrap(),
            "layer1.ln2_out"
        );
        assert_eq!(
            input_site_for_weight(&info, "layer1.ffn2.w").unwrap(),
            "layer1.ffn_hidden"
        );
        assert_eq!(input_site_for_weight(&info, "pool.w").unwrap(), "layer2.ln2_out");
        assert_eq!(input_site_for_weight(&info, "head.w").unwrap(), "pooled");
        assert!(input_site_for_weight(&info, "embed.tok").is_none());
    }

    #[test]
    fn qdq_weight_preserves_fp32_when_disabled() {
        let info = tiny_model_info();
        let p = Params::init(&info, 3);
        let policy = QuantPolicy::fp32();
        let (q, report) =
            quantize_weights(&info, &p, &policy, None, &AdaRoundOpts::default()).unwrap();
        assert_eq!(report["embed.tok"], "fp32");
        assert_eq!(q.get("embed.tok").unwrap(), p.get("embed.tok").unwrap());
    }

    #[test]
    fn qdq_weight_8bit_small_error() {
        let info = tiny_model_info();
        let p = Params::init(&info, 3);
        let policy = QuantPolicy::uniform(8, 8);
        let (q, _) =
            quantize_weights(&info, &p, &policy, None, &AdaRoundOpts::default()).unwrap();
        let a = p.get("layer0.ffn1.w").unwrap();
        let b = q.get("layer0.ffn1.w").unwrap();
        let rel = a.sub(b).unwrap().abs_max() / a.abs_max();
        assert!(rel < 0.01, "8-bit weight error {rel}");
        assert_ne!(a, b);
    }

    #[test]
    fn mse_weights_at_low_bits_not_worse() {
        let info = tiny_model_info();
        let p = Params::init(&info, 5);
        let w = p.get("layer0.ffn1.w").unwrap();
        let near = qdq_weight(w, 3, Estimator::CurrentMinMax);
        let mse = qdq_weight(w, 3, Estimator::Mse);
        assert!(mse.mse(w).unwrap() <= near.mse(w).unwrap() * 1.001);
    }
}
