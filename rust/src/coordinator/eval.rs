//! Dev-set evaluation through the batched forward executables, producing
//! the per-task GLUE scores of the paper's tables.

use anyhow::Result;

use super::Ctx;
use crate::data::{self, TaskKind, TaskSpec};
use crate::metrics;
use crate::model::qconfig::ActQuantTensors;
use crate::model::Params;
use crate::runtime::{lit_f32, lit_i32};

/// Evaluate `params` (already weight-QDQ'd if applicable) under the given
/// activation-quantizer tensors. Returns the task score ×100.
pub fn evaluate(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    act: &ActQuantTensors,
) -> Result<f64> {
    let info = ctx.model_info(task)?;
    let head = ctx.head(task);
    let artifact = format!("fwd_{head}_b8");
    let b = 8usize;
    let seq = info.config.seq;
    let n_sites = info.sites.len();
    let split = data::dev_split(task, seq)?;
    let n = split.examples.len();

    let n_classes = match task.kind {
        TaskKind::Classification(c) => c,
        TaskKind::Regression => 1,
    };

    let mut pred_cls = Vec::with_capacity(n);
    let mut gold_cls = Vec::with_capacity(n);
    let mut pred_reg = Vec::with_capacity(n);
    let mut gold_reg = Vec::with_capacity(n);

    // pre-build the static literals once per eval (params + quant policy)
    let mut static_lits = Vec::with_capacity(params.tensors.len() + 3);
    for t in &params.tensors {
        static_lits.push(lit_f32(t.data(), t.shape())?);
    }
    static_lits.push(lit_f32(&act.scales, &[act.scales.len()])?);
    static_lits.push(lit_f32(&act.zps, &[act.zps.len()])?);
    static_lits.push(lit_f32(&act.cfg, &[n_sites, 3])?);

    let mut start = 0usize;
    while start < n {
        let batch = data::make_batch(&split, start, b, seq);
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(static_lits.len() + 3);
        // Literal isn't Clone in the xla crate; rebuild per batch is the
        // checked `run` path. We re-create only the small batch literals
        // and re-create statics via references: execute takes Borrow<..>,
        // so mix owned + borrowed through a small enum.
        lits.push(lit_i32(&batch.ids, &[b, seq])?);
        lits.push(lit_i32(&batch.token_type, &[b, seq])?);
        lits.push(lit_f32(&batch.mask, &[b, seq])?);

        // assemble full borrow list
        let all: Vec<&xla::Literal> = static_lits.iter().chain(lits.iter()).collect();
        let out = ctx.rt.run_lits_borrowed(&artifact, &all)?;
        let logits = &out[0];

        let take = (n - start).min(b);
        for i in 0..take {
            let ex = &split.examples[start + i];
            match task.kind {
                TaskKind::Regression => {
                    pred_reg.push(logits.data()[i] as f64);
                    gold_reg.push(ex.target as f64);
                }
                TaskKind::Classification(_) => {
                    let row = &logits.data()[i * info.config.n_out..(i + 1) * info.config.n_out];
                    let pred = row[..n_classes]
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    pred_cls.push(pred);
                    gold_cls.push(ex.label);
                }
            }
        }
        start += b;
    }
    Ok(metrics::task_score(task.name, &pred_cls, &gold_cls, &pred_reg, &gold_reg))
}
