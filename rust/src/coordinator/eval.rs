//! Dev-set evaluation through the batched forward executables, producing
//! the per-task GLUE scores of the paper's tables.
//!
//! The per-batch executions are independent, so the hot loop fans out
//! over `ctx.pool` via [`Runtime::run_batch`](crate::runtime::Runtime::run_batch):
//! input-literal prep for one
//! batch overlaps execution of others, and logits are reassembled in
//! batch order, keeping the metric stream — and therefore the score —
//! bit-identical to a serial run (pinned by tests/determinism.rs).

use anyhow::Result;

use super::{batch_input_lits_for, fwd_artifact_var, Ctx, EVAL_BATCH};
use crate::data::{self, Split, TaskKind, TaskSpec};
use crate::metrics;
use crate::model::manifest::{Architecture, AttnVariant};
use crate::model::qconfig::ActQuantTensors;
use crate::model::Params;

/// NaN-safe argmax over a logit row. `f32::total_cmp` gives a total
/// order (NaN sorts above +inf), so a degenerate quantization config
/// that produces NaN logits yields a deterministic class instead of the
/// `partial_cmp(..).unwrap()` panic it used to.
pub(crate) fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(j, _)| j)
        .unwrap_or(0)
}

/// Evaluate `params` (already weight-QDQ'd if applicable) under the given
/// activation-quantizer tensors on the task's dev split. Returns the task
/// score ×100.
pub fn evaluate(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    act: &ActQuantTensors,
) -> Result<f64> {
    evaluate_arch(ctx, task, Architecture::Bert, params, act)
}

/// [`evaluate`] against a specific architecture family's artifacts. The
/// same synthetic dev split drives both families (ViT rasterises the
/// token ids through the pixel codebook in `batch_input_lits_for`).
pub fn evaluate_arch(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    params: &Params,
    act: &ActQuantTensors,
) -> Result<f64> {
    evaluate_var(ctx, task, arch, AttnVariant::Vanilla, params, act)
}

/// [`evaluate_arch`] for a specific attention variant family's artifacts.
pub fn evaluate_var(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
    params: &Params,
    act: &ActQuantTensors,
) -> Result<f64> {
    let info = ctx.model_info_var(task, arch, variant)?;
    let split = data::dev_split(task, info.config.seq)?;
    evaluate_split_var(ctx, task, arch, variant, params, act, &split)
}

/// [`evaluate`] over an explicit example split (exposed so tests and
/// benches can pin split sizes — including sizes that are not a multiple
/// of the executable batch, whose padded tail rows must be ignored).
pub fn evaluate_split(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    act: &ActQuantTensors,
    split: &Split,
) -> Result<f64> {
    evaluate_split_arch(ctx, task, Architecture::Bert, params, act, split)
}

/// [`evaluate_split`], architecture-generic.
pub fn evaluate_split_arch(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    params: &Params,
    act: &ActQuantTensors,
    split: &Split,
) -> Result<f64> {
    evaluate_split_var(ctx, task, arch, AttnVariant::Vanilla, params, act, split)
}

/// [`evaluate_split_arch`] for a specific attention variant family.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_split_var(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
    params: &Params,
    act: &ActQuantTensors,
    split: &Split,
) -> Result<f64> {
    let info = ctx.model_info_var(task, arch, variant)?;
    let head = ctx.head(task);
    let artifact = fwd_artifact_var(arch, variant, head, EVAL_BATCH);
    let b = EVAL_BATCH;
    let seq = info.config.seq;
    let n_sites = info.sites.len();
    let n = split.examples.len();

    let n_classes = match task.kind {
        TaskKind::Classification(c) => c,
        TaskKind::Regression => 1,
    };

    // static inputs, built once per eval: params + quant policy tensors
    let static_lits =
        super::static_input_lits(params, &act.scales, &act.zps, &act.cfg, n_sites)?;

    // batch-parallel execution: every batch is independent, results are
    // reassembled in batch order below
    let n_batches = n.div_ceil(b);
    let outs = ctx.rt.run_batch(
        &artifact,
        &static_lits,
        n_batches,
        |bi| batch_input_lits_for(info, &data::make_batch(split, bi * b, b, seq)),
        &ctx.pool,
    )?;

    let mut pred_cls = Vec::with_capacity(n);
    let mut gold_cls = Vec::with_capacity(n);
    let mut pred_reg = Vec::with_capacity(n);
    let mut gold_reg = Vec::with_capacity(n);
    for (bi, out) in outs.iter().enumerate() {
        let logits = &out[0];
        let start = bi * b;
        // a final partial batch is padded with PAD rows; their logits are
        // ignored, never scored (see data::make_batch)
        let take = (n - start).min(b);
        for i in 0..take {
            let ex = &split.examples[start + i];
            match task.kind {
                TaskKind::Regression => {
                    pred_reg.push(logits.data()[i] as f64);
                    gold_reg.push(ex.target as f64);
                }
                TaskKind::Classification(_) => {
                    let row = &logits.data()[i * info.config.n_out..(i + 1) * info.config.n_out];
                    pred_cls.push(argmax(&row[..n_classes]));
                    gold_cls.push(ex.label);
                }
            }
        }
    }
    Ok(metrics::task_score(task.name, &pred_cls, &gold_cls, &pred_reg, &gold_reg))
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_picks_largest_finite() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[-1.0, -3.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -7.5, f32::NEG_INFINITY]), 1);
    }

    #[test]
    fn argmax_is_nan_safe_and_deterministic() {
        // a degenerate quantization config can produce NaN logits; the
        // old partial_cmp(..).unwrap() panicked here
        let row = [f32::NAN, 1.0, f32::NEG_INFINITY];
        let p = argmax(&row);
        assert!(p < row.len());
        assert_eq!(p, argmax(&row), "must be deterministic");
        // all-NaN and empty rows still yield a valid index
        assert!(argmax(&[f32::NAN, f32::NAN]) < 2);
        assert_eq!(argmax(&[]), 0);
        // total_cmp orders -NaN below everything: finite values still win
        let neg_nan = f32::from_bits(0xFFC0_0000);
        assert!(neg_nan.is_nan() && neg_nan.is_sign_negative());
        assert_eq!(argmax(&[neg_nan, 0.5]), 1);
    }
}
