//! Parallel experiment-sweep engine.
//!
//! The paper's tables are grids — bit-widths × granularities × range
//! estimators — and every cell is independent, so the engine runs one
//! configuration per `util::pool` job. Every cell is a [`QuantSpec`]
//! (see `crate::spec`), keyed by its stable content hash `spec_id`:
//!
//! * **Resumable sweeps**: before running, configurations whose `spec_id`
//!   already appears in `results/sweep.json` are skipped and their cached
//!   row carried forward (`--fresh` forces a full rerun).
//! * **Regression gate**: `--compare baseline.json` diffs the new results
//!   against a prior report by `spec_id` and exits non-zero when a score
//!   (or, for offline-only runs, the quantization MSE) regresses beyond
//!   tolerance.
//!
//! Two execution layers:
//!
//! * **Offline substrate sweep** (always available): each configuration
//!   runs the full L3 statistics pipeline — estimator observation, MSE
//!   range search, PEG parameter assembly, activation QDQ, weight QDQ —
//!   on deterministic synthetic calibration data with installed outlier
//!   lanes, reporting quantization MSE per config. This is the
//!   benchmarkable hot path (benches/sweep_bench.rs) and needs no AOT
//!   artifacts.
//! * **Runtime-backed scores** (when `artifacts/manifest.json` and a task
//!   checkpoint exist): each config's spec is evaluated end-to-end via
//!   `spec::run::run_spec_on`; workers share the runtime's mutex-guarded
//!   compiled-executable cache, so each artifact compiles once for the
//!   whole sweep.
//!
//! Inside an *offline* sweep job all kernels run with a serial inner
//! pool — the parallelism budget is spent across configurations, and
//! results stay bit-identical to a serial sweep (see
//! tests/determinism.rs). The runtime-backed path reuses the shared spec
//! pipeline, whose batch-parallel eval/calibrate loops run on `ctx.pool`;
//! `cmd_sweep` points that at the same persistent pool the config jobs
//! use, so nested submissions share one worker set (the pool's
//! caller-participation design makes that deadlock-free) instead of
//! oversubscribing the machine.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::experiments;
use super::Ctx;
use crate::data::TaskSpec;
use crate::hlo::fixture;
use crate::model::manifest::{model_name, Architecture, AttnVariant};
use crate::model::qconfig::{site_lane_params_pool, SiteCfg};
use crate::model::Params;
use crate::quant::estimators::{mse_search_pool, RangeTracker};
use crate::quant::peg::granularity_overhead_params;
use crate::quant::{
    qdq_per_lane_pool, qdq_tensor_pool, qparams_symmetric, Estimator, Granularity, QGrid,
    QParams, RangeMethod,
};
use crate::report::{fmt_score, write_file, Table};
use crate::spec::{parse_estimator, parse_range_method, range_method_name, PolicySpec, QuantSpec};
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// One cell of the sweep grid.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// model family the cell runs against (task × architecture × config)
    pub arch: Architecture,
    /// attention variant of that family (vanilla / clipped softmax /
    /// gated — the outlier-suppressing model variants)
    pub variant: AttnVariant,
    pub act_bits: u32,
    pub weight_bits: u32,
    pub granularity: Granularity,
    pub estimator: Estimator,
    /// how site ranges are derived (the PEG per-group MSE axis)
    pub range_method: RangeMethod,
}

impl SweepConfig {
    pub fn label(&self) -> String {
        let g = match &self.granularity {
            Granularity::PerTensor => "pt".to_string(),
            Granularity::PerEmbedding => "pe".to_string(),
            Granularity::PerEmbeddingGroup { k, permute } => {
                format!("k{}{}", k, if *permute { "p" } else { "" })
            }
        };
        let e = crate::spec::estimator_name(self.estimator);
        let mut label = format!("a{}w{}-{}-{}", self.act_bits, self.weight_bits, g, e);
        if self.range_method != RangeMethod::Auto {
            label.push('-');
            label.push_str(range_method_name(self.range_method));
        }
        // BERT labels stay exactly what pre-architecture-axis sweeps
        // printed (their cached rows and baselines key off them)
        if self.arch != Architecture::Bert {
            label.push('-');
            label.push_str(self.arch.name());
        }
        // same rule for the variant axis: vanilla cells keep their
        // pre-axis labels, variant cells get the short family tag
        if self.variant != AttnVariant::Vanilla {
            label.push('-');
            label.push_str(self.variant.tag());
        }
        label
    }

    /// The cell as a full [`QuantSpec`] on one task — this is what the
    /// runtime-backed pass executes and what `spec_id`-keyed resume and
    /// baseline diffs hash. BERT cells serialize without an architecture
    /// key and vanilla cells without a variant key, so their spec_ids
    /// predate — and survive — both axes.
    pub fn to_spec(&self, task: &str, seeds: usize) -> QuantSpec {
        let mut policy = PolicySpec::uniform(self.weight_bits, self.act_bits);
        policy.default_site.granularity = self.granularity.clone();
        policy.default_site.range_method = self.range_method;
        policy.weights.estimator = self.estimator;
        let mut spec = QuantSpec::new(&self.label(), policy)
            .with_seeds(seeds.max(1))
            .with_architecture(self.arch)
            .with_variant(self.variant);
        spec.calib.estimator = self.estimator;
        spec.tasks = vec![task.to_string()];
        spec
    }
}

/// Result of one configuration (offline metrics, plus the dev score when
/// the runtime-backed pass ran).
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub label: String,
    /// content hash of the config's spec (empty when produced by the bare
    /// offline API without a task context)
    pub spec_id: String,
    pub act_bits: u32,
    pub weight_bits: u32,
    /// activation QDQ MSE on the held-out synthetic tensor
    pub act_mse: f32,
    /// weight QDQ MSE on the synthetic weight matrix
    pub weight_mse: f32,
    /// extra stored parameters per attention layer for the cell's
    /// granularity (the paper's §4 PEG accounting; 0 for per-tensor) —
    /// the accuracy-vs-overhead axis of the K sweep
    pub peg_overhead: usize,
    /// `peg_overhead` as a percentage of the reference model's total
    /// parameter count at this `d` (see [`reference_total_params`]) —
    /// the paper's "overhead is negligible" claim, made checkable
    pub peg_overhead_pct: f64,
    /// task dev score ×100 (runtime-backed pass only)
    pub score: Option<f64>,
    pub millis: f64,
}

/// Total parameter count of `arch`'s reference fixture model at
/// embedding dim `d` (`d_ff = 2d`, the shipped fixtures' ratio). This is
/// the denominator that puts `peg_overhead` in context: extra PEG
/// parameters as a fraction of the model they decorate, so the paper's
/// "overhead is negligible" framing shows up as a number in the table.
/// The count comes from the same `fixture::param_spec` that emits the
/// manifest, so it is per-model accounting, not a BERT-shaped constant:
/// a ViT cell is normalised against the ViT parameter budget (patch
/// projection + positions instead of token/type vocabularies).
pub fn reference_total_params_arch(d: usize, arch: Architecture) -> usize {
    let mut cfg = match arch {
        Architecture::Bert => fixture::base_config(),
        Architecture::Vit => fixture::vit_config(),
    };
    cfg.d = d;
    cfg.d_ff = 2 * d;
    fixture::param_spec(&cfg).iter().map(|(_, shape)| shape.iter().product::<usize>()).sum()
}

/// BERT convenience wrapper for [`reference_total_params_arch`].
pub fn reference_total_params(d: usize) -> usize {
    reference_total_params_arch(d, Architecture::Bert)
}

/// `overhead` extra parameters as a percentage of
/// [`reference_total_params_arch`] at embedding dim `d`.
pub fn overhead_pct_arch(overhead: usize, d: usize, arch: Architecture) -> f64 {
    100.0 * overhead as f64 / reference_total_params_arch(d, arch) as f64
}

/// BERT convenience wrapper for [`overhead_pct_arch`].
pub fn overhead_pct(overhead: usize, d: usize) -> f64 {
    overhead_pct_arch(overhead, d, Architecture::Bert)
}

/// Map a group count onto the paper's granularities for embedding dim
/// `d`: K=1 → per-tensor, K=d → per-embedding, otherwise K permuted
/// near-even groups (K need not divide d; `peg::group_bounds` splits with
/// group sizes differing by at most one, so the paper's K=6/K=12 rows
/// work at any d). K > d stays an error — a typo'd group count must not
/// silently collapse into a duplicate per-embedding cell.
pub fn granularity_for(d: usize, k: usize) -> Result<Granularity> {
    if k <= 1 {
        Ok(Granularity::PerTensor)
    } else if k == d {
        Ok(Granularity::PerEmbedding)
    } else if k < d {
        Ok(Granularity::PerEmbeddingGroup { k, permute: true })
    } else {
        bail!("K={k} exceeds d={d} (use K=d for per-embedding)")
    }
}

/// Cross product of the sweep axes — task is fixed per invocation, so
/// this is the architecture × config plane of the task × architecture ×
/// config grid. `archs` is the outermost axis (a BERT-only grid keeps its
/// pre-axis cell order). `mse_tensor` only composes with K=1
/// (per-tensor) cells — ask for `mse_group` on grouped cells instead —
/// so invalid pairs fail here, before any work is scheduled.
pub fn grid(
    d: usize,
    archs: &[Architecture],
    act_bits: &[u32],
    weight_bits: &[u32],
    groups: &[usize],
    estimators: &[Estimator],
    range_methods: &[RangeMethod],
) -> Result<Vec<SweepConfig>> {
    grid_var(d, archs, &[AttnVariant::Vanilla], act_bits, weight_bits, groups, estimators, range_methods)
}

/// [`grid`] with the attention-variant axis exposed: `variants` nests
/// just inside `archs`, so a vanilla-only grid keeps the exact cell
/// order [`grid`] always produced.
#[allow(clippy::too_many_arguments)]
pub fn grid_var(
    d: usize,
    archs: &[Architecture],
    variants: &[AttnVariant],
    act_bits: &[u32],
    weight_bits: &[u32],
    groups: &[usize],
    estimators: &[Estimator],
    range_methods: &[RangeMethod],
) -> Result<Vec<SweepConfig>> {
    let mut out = Vec::new();
    for &arch in archs {
        for &variant in variants {
            for &ab in act_bits {
                for &wb in weight_bits {
                    for &k in groups {
                        let gran = granularity_for(d, k)?;
                        for &est in estimators {
                            for &rm in range_methods {
                                if rm == RangeMethod::MseTensor && gran != Granularity::PerTensor {
                                    bail!(
                                        "range method mse_tensor needs K=1 (per-tensor); \
                                         use mse_group for K={k}"
                                    );
                                }
                                out.push(SweepConfig {
                                    arch,
                                    variant,
                                    act_bits: ab,
                                    weight_bits: wb,
                                    granularity: gran.clone(),
                                    estimator: est,
                                    range_method: rm,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Deterministic synthetic calibration workload shared by every config in
/// a sweep: activations with a few high-range outlier lanes (the paper's
/// Fig. 2 structure — this is what makes granularity matter) plus one
/// linear-layer weight matrix.
pub struct SweepData {
    pub calib: Vec<Tensor>,
    pub eval: Tensor,
    pub weight: Tensor,
}

pub fn synth_data(d: usize, rows: usize, batches: usize, seed: u64) -> SweepData {
    let mut rng = Rng::new(seed);
    let activations = |rng: &mut Rng| {
        Tensor::from_fn(&[rows, d], |i| {
            let lane = i % d;
            let mag = if lane % 17 == 3 { 30.0 } else { 1.0 };
            rng.normal_f32(0.0, mag)
        })
    };
    let calib: Vec<Tensor> = (0..batches.max(1)).map(|_| activations(&mut rng)).collect();
    let eval = activations(&mut rng);
    let weight = Tensor::randn(&[d, 4 * d], 0.05, &mut rng);
    SweepData { calib, eval, weight }
}

/// Run one configuration's offline substrate pipeline. `inner` is the
/// pool used *inside* the job (serial when jobs themselves run in
/// parallel).
pub fn run_config_offline(
    data: &SweepData,
    cfg: &SweepConfig,
    inner: &Pool,
) -> Result<SweepResult> {
    let t0 = Instant::now();
    let d = data.eval.last_dim();
    let agrid = QGrid::asymmetric(cfg.act_bits);

    // calibration: estimator observation over every batch, retaining row
    // samples when the range method needs them (the same predicate
    // calibrate_with consults)
    let mut tracker = RangeTracker::new(cfg.estimator, d);
    if cfg.range_method.needs_row_samples(cfg.estimator) {
        tracker = tracker.with_row_samples();
    }
    for batch in &data.calib {
        tracker.observe_pool(batch, inner)?;
    }

    // (granularity, range_method) -> per-lane parameters through the one
    // site-resolution path the runtime assembly uses too
    let site_cfg = SiteCfg {
        bits: cfg.act_bits,
        granularity: cfg.granularity.clone(),
        range_method: cfg.range_method,
        enabled: true,
    };
    let (params, _perm): (Vec<QParams>, _) =
        site_lane_params_pool(&tracker, &site_cfg, agrid, inner)?;
    let act_q = qdq_per_lane_pool(&data.eval, &params, agrid, inner)?;
    let act_mse = act_q.mse(&data.eval)?;

    // weight PTQ: symmetric per-tensor with the config's estimator
    let wgrid = QGrid::symmetric(cfg.weight_bits);
    let wp = match cfg.estimator {
        Estimator::Mse => {
            let amax = data.weight.abs_max();
            let (lo, hi) = mse_search_pool(data.weight.data(), -amax, amax, wgrid, inner);
            qparams_symmetric(lo.abs().max(hi.abs()), wgrid)
        }
        _ => qparams_symmetric(data.weight.abs_max(), wgrid),
    };
    let wq = qdq_tensor_pool(&data.weight, wp, wgrid, inner);
    let weight_mse = wq.mse(&data.weight)?;

    let peg_overhead = granularity_overhead_params(d, &cfg.granularity);
    Ok(SweepResult {
        label: cfg.label(),
        spec_id: String::new(),
        act_bits: cfg.act_bits,
        weight_bits: cfg.weight_bits,
        act_mse,
        weight_mse,
        peg_overhead,
        peg_overhead_pct: overhead_pct_arch(peg_overhead, d, cfg.arch),
        score: None,
        millis: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Offline sweep: one pool job per configuration, serial inner kernels.
/// Results are returned in grid order regardless of scheduling.
pub fn run_offline(
    data: &SweepData,
    cfgs: &[SweepConfig],
    pool: &Pool,
) -> Result<Vec<SweepResult>> {
    let inner = Pool::serial();
    let jobs: Vec<_> = cfgs
        .iter()
        .map(|cfg| {
            let inner = inner.clone();
            move || run_config_offline(data, cfg, &inner)
        })
        .collect();
    pool.run(jobs).into_iter().collect()
}

/// Runtime-backed scores for the same grid: each config becomes a
/// [`QuantSpec`] executed through the shared `spec::run` pipeline (full
/// calibrate -> quantize -> evaluate through the AOT executables).
/// Workers share `ctx.rt`'s compiled-executable cache (the runtime is
/// `Sync`), so a warm artifact never recompiles; on a cold cache,
/// workers racing on the same artifact may each compile it once (first
/// insert wins — see `Runtime::executable`).
///
/// Note: the eval pipeline's batch-parallel hot loop runs on `ctx.pool`;
/// when that is the same pool as `pool` (as in `cmd_sweep`), nested
/// batches queue onto the shared workers and the thread budget stays at
/// one pool's worth — `TQ_THREADS` caps it globally.
pub fn runtime_scores(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    cfgs: &[SweepConfig],
    seeds: usize,
    pool: &Pool,
) -> Vec<Result<f64>> {
    let jobs: Vec<_> = cfgs
        .iter()
        .map(|cfg| {
            move || -> Result<f64> {
                let spec = cfg.to_spec(task.name, seeds);
                crate::spec::run::run_spec_on(ctx, &spec, task, params)
            }
        })
        .collect();
    // per-config Results: one failing config must not discard the
    // successfully evaluated rest of the grid
    pool.run(jobs)
}

/// Canonical workload stamp for an architecture axis: sorted, deduped
/// family names, comma-joined ("bert", "bert,vit"). Order-insensitive so
/// `--arch vit,bert` and `--arch bert,vit` name the same workload.
pub fn arch_stamp(archs: &[Architecture]) -> String {
    let mut names: Vec<&str> = archs.iter().map(|a| a.name()).collect();
    names.sort_unstable();
    names.dedup();
    names.join(",")
}

/// Consolidated machine-readable report. `d`, `data_seed` and `archs`
/// identify the workload — cached rows are only valid against the same
/// one (see [`parse_results`] / resume in [`cmd_sweep`]).
pub fn report_json(
    results: &[SweepResult],
    threads: usize,
    total_ms: f64,
    d: usize,
    data_seed: u64,
    archs: &[Architecture],
) -> Json {
    let configs: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("label".to_string(), Json::Str(r.label.clone()));
            if !r.spec_id.is_empty() {
                m.insert("spec_id".to_string(), Json::Str(r.spec_id.clone()));
            }
            m.insert("act_bits".to_string(), Json::Num(r.act_bits as f64));
            m.insert("weight_bits".to_string(), Json::Num(r.weight_bits as f64));
            m.insert("act_mse".to_string(), Json::Num(r.act_mse as f64));
            m.insert("weight_mse".to_string(), Json::Num(r.weight_mse as f64));
            m.insert("peg_overhead".to_string(), Json::Num(r.peg_overhead as f64));
            m.insert("peg_overhead_pct".to_string(), Json::Num(r.peg_overhead_pct));
            if let Some(s) = r.score {
                m.insert("score".to_string(), Json::Num(s));
            }
            m.insert("millis".to_string(), Json::Num(r.millis));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("threads".to_string(), Json::Num(threads as f64));
    top.insert("total_ms".to_string(), Json::Num(total_ms));
    top.insert("d".to_string(), Json::Num(d as f64));
    top.insert("data_seed".to_string(), Json::Num(data_seed as f64));
    top.insert("archs".to_string(), Json::Str(arch_stamp(archs)));
    top.insert("configs".to_string(), Json::Arr(configs));
    Json::Obj(top)
}

/// The offline act/weight MSEs are computed on the synthetic workload, so
/// a report is only comparable/resumable against the same
/// `--d`/`--seed`/`--arch`. `archs` is an [`arch_stamp`]; reports written
/// before the architecture axis carry no stamp and read as BERT-only —
/// they stay valid for BERT sweeps and never match a ViT axis. Reports
/// from before the workload fields existed never match at all.
pub fn workload_matches(j: &Json, d: usize, data_seed: u64, archs: &str) -> bool {
    let jd = j.opt("d").and_then(|v| v.as_usize().ok());
    let js = j.opt("data_seed").and_then(|v| v.as_u64().ok());
    let ja = j
        .opt("archs")
        .and_then(|v| v.as_str().ok().map(str::to_string))
        .unwrap_or_else(|| Architecture::Bert.name().to_string());
    jd == Some(d) && js == Some(data_seed) && ja == archs
}

/// Parse a consolidated report back into per-`spec_id` results (used for
/// resume and `--compare`). Entries without a `spec_id` — reports written
/// before specs existed — are skipped.
pub fn parse_results(j: &Json) -> Result<BTreeMap<String, SweepResult>> {
    let mut out = BTreeMap::new();
    for c in j.get("configs")?.as_arr()? {
        let Some(id) = c.opt("spec_id") else { continue };
        let r = SweepResult {
            label: c.get("label")?.as_str()?.to_string(),
            spec_id: id.as_str()?.to_string(),
            act_bits: c.get("act_bits")?.as_usize()? as u32,
            weight_bits: c.get("weight_bits")?.as_usize()? as u32,
            act_mse: c.get("act_mse")?.as_f64()? as f32,
            weight_mse: c.get("weight_mse")?.as_f64()? as f32,
            // absent in reports written before the overhead columns
            peg_overhead: c
                .opt("peg_overhead")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(0),
            peg_overhead_pct: c
                .opt("peg_overhead_pct")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(0.0),
            score: c.opt("score").map(|v| v.as_f64()).transpose()?,
            millis: c.get("millis")?.as_f64()?,
        };
        out.insert(r.spec_id.clone(), r);
    }
    Ok(out)
}

fn load_cached(
    path: &Path,
    d: usize,
    data_seed: u64,
    archs: &str,
) -> Result<BTreeMap<String, SweepResult>> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    if !workload_matches(&j, d, data_seed, archs) {
        // different synthetic workload: the cached offline MSEs don't
        // transfer, so resume from scratch
        return Ok(BTreeMap::new());
    }
    parse_results(&j)
}

/// Which shard of `n` a cell belongs to: FNV-1a over its `spec_id`, the
/// same stable content hash that keys resume and baselines. Deterministic
/// across processes and machines, independent of grid order, and keyed by
/// the *cell* rather than its index — adding an axis reshuffles indices
/// but moves no existing cell between shards.
pub fn shard_of(spec_id: &str, n: usize) -> usize {
    (crate::spec::fnv1a64(spec_id.as_bytes()) % n.max(1) as u64) as usize
}

/// Parse a 1-based `--shard i/n` selector.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let parse = || -> Option<(usize, usize)> {
        let (i, n) = s.split_once('/')?;
        Some((i.trim().parse().ok()?, n.trim().parse().ok()?))
    };
    let (i, n) = parse().ok_or_else(|| anyhow!("--shard wants i/n (e.g. 1/2), got {s:?}"))?;
    if n == 0 || i == 0 || i > n {
        bail!("--shard {s}: need 1 <= i <= n");
    }
    Ok((i, n))
}

/// Do two rows describe the same computation outcome? `millis` is
/// wall-clock noise and excluded; everything else is deterministic.
fn same_cell(a: &SweepResult, b: &SweepResult) -> bool {
    a.label == b.label
        && a.act_bits == b.act_bits
        && a.weight_bits == b.weight_bits
        && a.act_mse == b.act_mse
        && a.weight_mse == b.weight_mse
        && a.peg_overhead == b.peg_overhead
        && a.peg_overhead_pct == b.peg_overhead_pct
        && a.score == b.score
}

/// Union shard result maps back into grid (`ids`) order. A spec_id
/// appearing in several shards must agree cell-for-cell (timing aside) —
/// conflicting duplicates mean the shards were not one partition of one
/// grid, and merging them would silently pick a winner. A grid cell
/// missing from every shard is likewise an error, not a hole.
pub fn merge_results(
    shards: &[BTreeMap<String, SweepResult>],
    ids: &[String],
    labels: &[String],
) -> Result<Vec<SweepResult>> {
    let mut merged: BTreeMap<&str, &SweepResult> = BTreeMap::new();
    for (si, shard) in shards.iter().enumerate() {
        for (id, r) in shard {
            if let Some(prev) = merged.get(id.as_str()) {
                if !same_cell(prev, r) {
                    bail!(
                        "--merge: shard {} disagrees with an earlier shard on cell {} \
                         ({id}) — the shard reports were not produced by one partition \
                         of one grid",
                        si + 1,
                        r.label
                    );
                }
            }
            merged.insert(id, r);
        }
    }
    ids.iter()
        .zip(labels)
        .map(|(id, label)| {
            merged.get(id.as_str()).map(|r| (*r).clone()).ok_or_else(|| {
                anyhow!("--merge: grid cell {label} ({id}) missing from every shard report")
            })
        })
        .collect()
}

/// One line of a `--compare` diff.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub label: String,
    pub spec_id: String,
    /// "score" when both runs have dev scores, else "act_mse"
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    pub regressed: bool,
}

/// Diff current results against a baseline report by `spec_id`. A config
/// regresses when its dev score drops more than `score_tol` points, when
/// the baseline had a score but the current run could not produce one
/// (a silently-broken runtime must not pass the gate), or — for
/// offline-only comparisons — when its activation QDQ MSE grows by more
/// than the relative `mse_rel_tol`. Configs absent from the baseline are
/// skipped (they are new, not regressions).
pub fn compare_to_baseline(
    current: &[SweepResult],
    baseline: &BTreeMap<String, SweepResult>,
    score_tol: f64,
    mse_rel_tol: f64,
) -> Vec<CompareRow> {
    current
        .iter()
        .filter_map(|r| {
            let base = baseline.get(&r.spec_id)?;
            let row = match (r.score, base.score) {
                (Some(cur), Some(b)) => CompareRow {
                    label: r.label.clone(),
                    spec_id: r.spec_id.clone(),
                    metric: "score",
                    baseline: b,
                    current: cur,
                    regressed: cur < b - score_tol,
                },
                (None, Some(b)) => CompareRow {
                    label: r.label.clone(),
                    spec_id: r.spec_id.clone(),
                    metric: "score-missing",
                    baseline: b,
                    current: f64::NAN,
                    regressed: true,
                },
                _ => CompareRow {
                    label: r.label.clone(),
                    spec_id: r.spec_id.clone(),
                    metric: "act_mse",
                    baseline: base.act_mse as f64,
                    current: r.act_mse as f64,
                    regressed: (r.act_mse as f64) > (base.act_mse as f64) * (1.0 + mse_rel_tol),
                },
            };
            Some(row)
        })
        .collect()
}

fn parse_u32_list(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<u32>().map_err(|_| anyhow!("bad bit-width {p:?}")))
        .collect()
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<usize>().map_err(|_| anyhow!("bad group count {p:?}")))
        .collect()
}

fn parse_estimators(s: &str) -> Result<Vec<Estimator>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(parse_estimator)
        .collect()
}

fn parse_range_methods(s: &str) -> Result<Vec<RangeMethod>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(parse_range_method)
        .collect()
}

/// Parse `--arch bert,vit`. Sorted and deduped so the grid order (and the
/// workload stamp) are independent of how the user spelled the list.
fn parse_archs(s: &str) -> Result<Vec<Architecture>> {
    let mut out: Vec<Architecture> = s
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(Architecture::parse)
        .collect::<Result<_>>()?;
    out.sort_unstable();
    out.dedup();
    if out.is_empty() {
        bail!("--arch wants a list of architectures (e.g. bert,vit)");
    }
    Ok(out)
}

/// Parse `--variants vanilla,clipped_softmax,gated`. Sorted and deduped
/// like the architecture axis so the grid order is spelling-independent.
fn parse_variants(s: &str) -> Result<Vec<AttnVariant>> {
    let mut out: Vec<AttnVariant> = s
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(AttnVariant::parse)
        .collect::<Result<_>>()?;
    out.sort_unstable();
    out.dedup();
    if out.is_empty() {
        bail!("--variants wants a list of attention variants (e.g. vanilla,clipped_softmax,gated)");
    }
    Ok(out)
}

/// `repro sweep` driver. Runs the offline substrate sweep (skipping
/// configurations already in `results/sweep.json` by `spec_id` unless
/// `--fresh`), adds runtime-backed dev scores when artifacts and a
/// checkpoint are present, writes one consolidated report (md + csv +
/// json) under results/, and optionally gates on `--compare baseline.json`.
///
/// Distribution: `--shard i/n` runs only the cells whose `spec_id` hashes
/// into shard `i` (see [`shard_of`]) and writes
/// `results/sweep_shard_{i}of{n}.*` so concurrent shards never clobber
/// each other; `--merge n` reads the `n` shard reports back, rejects
/// conflicting or missing cells, and writes the consolidated report a
/// single unsharded run would have produced (timing columns aside).
pub fn cmd_sweep(args: &Args) -> Result<()> {
    let d = args.get_usize("d", 128)?;
    let archs = parse_archs(args.get_or("arch", "bert"))?;
    let variants = parse_variants(args.get_or("variants", "vanilla"))?;
    let act_bits = parse_u32_list(args.get_or("bits", "8,4"))?;
    let weight_bits = parse_u32_list(args.get_or("wbits", "8"))?;
    let groups = parse_usize_list(args.get_or("groups", "1,8"))?;
    let estimators = parse_estimators(args.get_or("estimators", "current,mse"))?;
    let range_methods = parse_range_methods(args.get_or("range-methods", "auto"))?;
    let threads = args.get_usize("threads", 0)?;
    let seeds = args.get_usize("seeds", 1)?;
    let task_name = args.get_or("task", "mnli");
    let pool = if threads == 0 { Pool::global().clone() } else { Pool::new(threads) };

    let full = grid_var(
        d,
        &archs,
        &variants,
        &act_bits,
        &weight_bits,
        &groups,
        &estimators,
        &range_methods,
    )?;
    if full.is_empty() {
        bail!("sweep grid is empty");
    }
    // spec_id keys every cell (architecture + policy + calibration +
    // seeds + task); the report's d/data_seed/archs fields additionally
    // guard the workload, so a cached row is only reused for the
    // identical run
    let data_seed = args.get_u64("seed", 42)?;
    let stamp = arch_stamp(&archs);
    let full_ids: Vec<String> =
        full.iter().map(|c| c.to_spec(task_name, seeds).spec_id()).collect();

    let shard = args.get("shard").map(parse_shard).transpose()?;
    let merge_n = args.get_usize("merge", 0)?;
    if shard.is_some() && merge_n > 0 {
        bail!("--shard and --merge are mutually exclusive");
    }

    let results_dir = std::path::PathBuf::from(args.get_or("results", "results"));
    if merge_n > 0 {
        return merge_and_report(args, &results_dir, merge_n, &full, &full_ids, d, data_seed, &stamp, &pool);
    }

    // a shard run sees only its own cells, and reads/writes its own
    // report files — shard reports union back via --merge
    let (cfgs, ids): (Vec<SweepConfig>, Vec<String>) = match shard {
        Some((i, n)) => {
            let kept: Vec<usize> =
                (0..full.len()).filter(|&x| shard_of(&full_ids[x], n) == i - 1).collect();
            println!("shard {i}/{n}: {} of {} grid cells", kept.len(), full.len());
            // an empty shard is a legitimate outcome of the hash
            // partition on a small grid: it still writes its (empty)
            // report, because --merge reads all n shard files back
            (
                kept.iter().map(|&x| full[x].clone()).collect(),
                kept.iter().map(|&x| full_ids[x].clone()).collect(),
            )
        }
        None => (full, full_ids),
    };
    let stem = match shard {
        Some((i, n)) => format!("sweep_shard_{i}of{n}"),
        None => "sweep".to_string(),
    };
    let sweep_path = results_dir.join(format!("{stem}.json"));
    let cached: BTreeMap<String, SweepResult> = if args.flag("fresh") {
        BTreeMap::new()
    } else {
        load_cached(&sweep_path, d, data_seed, &stamp).unwrap_or_default()
    };
    let mut slots: Vec<Option<SweepResult>> = ids
        .iter()
        .zip(&cfgs)
        .map(|(id, cfg)| {
            cached.get(id).cloned().map(|mut r| {
                // cached rows may predate the overhead columns (parsed
                // as 0) or carry stale values; they derive from the cell
                // itself, so stamp them fresh like spec_id on new rows
                r.peg_overhead = granularity_overhead_params(d, &cfg.granularity);
                r.peg_overhead_pct = overhead_pct_arch(r.peg_overhead, d, cfg.arch);
                r
            })
        })
        .collect();
    let todo: Vec<usize> = slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_none())
        .map(|(i, _)| i)
        .collect();
    let n_cached = cfgs.len() - todo.len();
    println!(
        "sweep: {} configurations on {} worker thread(s){}",
        cfgs.len(),
        pool.threads(),
        if n_cached > 0 {
            format!(" ({n_cached} cached by spec_id in {}; --fresh reruns)", sweep_path.display())
        } else {
            String::new()
        }
    );

    let t0 = Instant::now();
    let todo_cfgs: Vec<SweepConfig> = todo.iter().map(|&i| cfgs[i].clone()).collect();
    if !todo_cfgs.is_empty() {
        let data = synth_data(d, 64, 8, data_seed);
        let fresh = run_offline(&data, &todo_cfgs, &pool)?;
        for (&slot, mut r) in todo.iter().zip(fresh) {
            r.spec_id = ids[slot].clone();
            slots[slot] = Some(r);
        }
    }

    // Runtime-backed pass over every cell still missing a dev score —
    // fresh cells and cached offline-only rows alike, so a sweep cached
    // before artifacts/checkpoints existed gains scores on the next run
    // instead of being frozen until --fresh.
    let unscored: Vec<usize> = slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.as_ref().is_some_and(|r| r.score.is_none()))
        .map(|(i, _)| i)
        .collect();
    if !unscored.is_empty() {
        let artifacts = args.get_or("artifacts", "artifacts");
        if Path::new(artifacts).join("manifest.json").exists() {
            // the spec pipeline's batch-parallel hot loop shares the
            // sweep's worker set — nested batches are deadlock-free by
            // the pool's caller-participation design
            let ctx = Ctx::new(
                artifacts,
                args.get_or("ckpt", "checkpoints"),
                args.get_or("results", "results"),
            )?
            .with_pool(pool.clone());
            let task = ctx.task(task_name)?;
            // each (architecture, variant) family evaluates against its
            // own checkpoint; a family whose checkpoint is missing
            // degrades that family's cells to offline metrics, not the
            // whole sweep
            for &arch in &archs {
                for &variant in &variants {
                    let unscored_fam: Vec<usize> = unscored
                        .iter()
                        .copied()
                        .filter(|&i| cfgs[i].arch == arch && cfgs[i].variant == variant)
                        .collect();
                    if unscored_fam.is_empty() {
                        continue;
                    }
                    match experiments::load_ckpt_var(&ctx, &task, arch, variant) {
                        Ok(params) => {
                            let unscored_cfgs: Vec<SweepConfig> =
                                unscored_fam.iter().map(|&i| cfgs[i].clone()).collect();
                            let scores =
                                runtime_scores(&ctx, &task, &params, &unscored_cfgs, seeds, &pool);
                            for (&slot, s) in unscored_fam.iter().zip(scores) {
                                match s {
                                    Ok(v) => {
                                        if let Some(r) = slots[slot].as_mut() {
                                            r.score = Some(v);
                                        }
                                    }
                                    Err(e) => {
                                        println!(
                                            "({}: runtime eval failed — {e})",
                                            cfgs[slot].label()
                                        )
                                    }
                                }
                            }
                        }
                        Err(e) => println!(
                            "({}: offline metrics only — {e})",
                            model_name(arch, variant, false)
                        ),
                    }
                }
            }
            let st = ctx.rt.stats();
            if st.interpreted > 0 {
                println!(
                    "(runtime pass executed on the in-repo HLO interpreter: \
                     {} of {} executions)",
                    st.interpreted, st.executions
                );
            }
        } else {
            println!("(artifacts/manifest.json absent; offline substrate metrics only)");
        }
    }
    let results: Vec<SweepResult> = slots
        .into_iter()
        .map(|s| s.ok_or_else(|| anyhow!("sweep slot left unfilled")))
        .collect::<Result<_>>()?;
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut table = Table::new(
        &format!("Quantization sweep ({} configs, {} threads)", results.len(), pool.threads()),
        &["config", "spec_id", "act MSE", "weight MSE", "overhead", "ovh %", "score", "ms"],
    );
    for r in &results {
        table.row(vec![
            r.label.clone(),
            r.spec_id.clone(),
            format!("{:.3e}", r.act_mse),
            format!("{:.3e}", r.weight_mse),
            format!("{}", r.peg_overhead),
            format!("{:.2}", r.peg_overhead_pct),
            r.score.map(fmt_score).unwrap_or_else(|| "-".to_string()),
            format!("{:.1}", r.millis),
        ]);
    }
    print!("{}", table.to_console());
    println!("sweep total: {total_ms:.0} ms ({} run, {n_cached} cached)", todo.len());

    write_file(results_dir.join(format!("{stem}.md")), &table.to_markdown())?;
    write_file(results_dir.join(format!("{stem}.csv")), &table.to_csv())?;
    // the JSON report keeps cached rows from *other* grids/tasks too, so
    // successive `repro sweep --task ...` invocations accumulate one
    // resumable result store instead of overwriting each other
    let mut store = results.clone();
    for (id, r) in &cached {
        if !ids.contains(id) {
            store.push(r.clone());
        }
    }
    write_file(
        &sweep_path,
        &report_json(&store, pool.threads(), total_ms, d, data_seed, &archs).to_string(),
    )?;

    compare_gate(args, &results_dir, &results, d, data_seed, &stamp)
}

/// The `--compare baseline.json` regression gate shared by normal, shard
/// and merge runs: diff by spec_id, write `sweep_compare.md`, exit
/// non-zero on any regression or on a vacuous comparison.
fn compare_gate(
    args: &Args,
    results_dir: &Path,
    results: &[SweepResult],
    d: usize,
    data_seed: u64,
    stamp: &str,
) -> Result<()> {
    if let Some(baseline_path) = args.get("compare") {
        let score_tol = args.get_f32("tolerance", 0.5)? as f64;
        let mse_rel_tol = args.get_f32("mse-tolerance", 0.10)? as f64;
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow!("cannot read baseline {baseline_path:?}: {e}"))?;
        let bj = Json::parse(&text)?;
        if !workload_matches(&bj, d, data_seed, stamp) {
            bail!(
                "baseline {baseline_path} was produced with a different offline \
                 workload (--d/--seed/--arch) — compare like-for-like sweeps"
            );
        }
        let baseline = parse_results(&bj)?;
        let rows = compare_to_baseline(results, &baseline, score_tol, mse_rel_tol);
        let mut diff = Table::new(
            &format!("Sweep vs baseline {baseline_path} (tol {score_tol} pts / {mse_rel_tol} rel MSE)"),
            &["config", "metric", "baseline", "current", "delta", "status"],
        );
        for row in &rows {
            diff.row(vec![
                row.label.clone(),
                row.metric.to_string(),
                format!("{:.4}", row.baseline),
                format!("{:.4}", row.current),
                format!("{:+.4}", row.current - row.baseline),
                if row.regressed { "REGRESSED".to_string() } else { "ok".to_string() },
            ]);
        }
        print!("{}", diff.to_console());
        write_file(results_dir.join("sweep_compare.md"), &diff.to_markdown())?;
        let unmatched = results.iter().filter(|r| !baseline.contains_key(&r.spec_id)).count();
        if unmatched > 0 {
            println!("({unmatched} config(s) not in baseline — skipped)");
        }
        if rows.is_empty() && !baseline.is_empty() {
            // every current cell missed the baseline: the gate would pass
            // without comparing anything — that is drift, not a pass
            bail!(
                "baseline {baseline_path} shares no spec_ids with this sweep \
                 ({} baseline entries, {} current configs) — the compare gate \
                 would be vacuous; regenerate the baseline for this grid",
                baseline.len(),
                results.len()
            );
        }
        let regressions = rows.iter().filter(|r| r.regressed).count();
        if regressions > 0 {
            bail!("{regressions} regression(s) vs baseline {baseline_path}");
        }
        println!("no regressions vs baseline {baseline_path} ({} compared)", rows.len());
    }
    Ok(())
}

/// `repro sweep --merge n`: union the `n` shard reports of this grid back
/// into the consolidated `results/sweep.{json,md,csv}` a single unsharded
/// run would have written. Every shard must have been produced from the
/// same workload (`--d`/`--seed`/`--arch`) and grid flags; conflicting
/// duplicate cells and cells missing from every shard are hard errors
/// (see [`merge_results`]).
#[allow(clippy::too_many_arguments)]
fn merge_and_report(
    args: &Args,
    results_dir: &Path,
    merge_n: usize,
    cfgs: &[SweepConfig],
    ids: &[String],
    d: usize,
    data_seed: u64,
    stamp: &str,
    pool: &Pool,
) -> Result<()> {
    let mut shards = Vec::with_capacity(merge_n);
    for i in 1..=merge_n {
        let p = results_dir.join(format!("sweep_shard_{i}of{merge_n}.json"));
        let text = std::fs::read_to_string(&p).map_err(|e| {
            anyhow!("--merge {merge_n}: cannot read shard report {}: {e}", p.display())
        })?;
        let j = Json::parse(&text)?;
        if !workload_matches(&j, d, data_seed, stamp) {
            bail!(
                "--merge: shard report {} was produced with a different workload \
                 (--d/--seed/--arch)",
                p.display()
            );
        }
        shards.push(parse_results(&j)?);
    }
    let labels: Vec<String> = cfgs.iter().map(|c| c.label()).collect();
    let results = merge_results(&shards, ids, &labels)?;
    println!(
        "merged {merge_n} shard report(s): {} grid cells, {} scored",
        results.len(),
        results.iter().filter(|r| r.score.is_some()).count()
    );

    let mut table = Table::new(
        &format!("Quantization sweep ({} configs, merged from {merge_n} shards)", results.len()),
        &["config", "spec_id", "act MSE", "weight MSE", "overhead", "ovh %", "score", "ms"],
    );
    for r in &results {
        table.row(vec![
            r.label.clone(),
            r.spec_id.clone(),
            format!("{:.3e}", r.act_mse),
            format!("{:.3e}", r.weight_mse),
            format!("{}", r.peg_overhead),
            format!("{:.2}", r.peg_overhead_pct),
            r.score.map(fmt_score).unwrap_or_else(|| "-".to_string()),
            format!("{:.1}", r.millis),
        ]);
    }
    print!("{}", table.to_console());

    let sweep_path = results_dir.join("sweep.json");
    // mirror the unsharded store: keep cached rows from other grids/tasks
    let cached: BTreeMap<String, SweepResult> = if args.flag("fresh") {
        BTreeMap::new()
    } else {
        load_cached(&sweep_path, d, data_seed, stamp).unwrap_or_default()
    };
    let archs: Vec<Architecture> = {
        let mut a: Vec<Architecture> = cfgs.iter().map(|c| c.arch).collect();
        a.sort_unstable();
        a.dedup();
        a
    };
    write_file(results_dir.join("sweep.md"), &table.to_markdown())?;
    write_file(results_dir.join("sweep.csv"), &table.to_csv())?;
    let mut store = results.clone();
    for (id, r) in &cached {
        if !ids.contains(id) {
            store.push(r.clone());
        }
    }
    let total_ms: f64 = results.iter().map(|r| r.millis).sum();
    write_file(
        &sweep_path,
        &report_json(&store, pool.threads(), total_ms, d, data_seed, &archs).to_string(),
    )?;

    compare_gate(args, results_dir, &results, d, data_seed, stamp)
}

#[allow(dead_code)]
fn assert_shareable() {
    fn is_sync<T: Sync>() {}
    is_sync::<Ctx>();
    is_sync::<crate::runtime::Runtime>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;
    use crate::model::qconfig::QuantPolicy;

    const BERT: &[Architecture] = &[Architecture::Bert];

    #[test]
    fn grid_is_full_cross_product() {
        let cfgs = grid(
            128,
            BERT,
            &[8, 4],
            &[8],
            &[1, 8, 128],
            &[Estimator::CurrentMinMax, Estimator::Mse],
            &[RangeMethod::Auto, RangeMethod::MsePerGroup],
        )
        .unwrap();
        assert_eq!(cfgs.len(), 2 * 1 * 3 * 2 * 2);
        // mse_tensor only composes with per-tensor cells
        assert!(grid(128, BERT, &[8], &[8], &[8], &[Estimator::Mse], &[RangeMethod::MseTensor])
            .is_err());
        assert!(grid(128, BERT, &[8], &[8], &[1], &[Estimator::Mse], &[RangeMethod::MseTensor])
            .is_ok());
    }

    #[test]
    fn architecture_axis_crosses_the_grid() {
        let archs = [Architecture::Bert, Architecture::Vit];
        let cfgs = grid(
            128,
            &archs,
            &[8],
            &[8],
            &[1, 8],
            &[Estimator::Mse],
            &[RangeMethod::Auto],
        )
        .unwrap();
        assert_eq!(cfgs.len(), 2 * 2);
        // arch is the outermost axis: BERT cells first, in pre-axis order
        assert!(cfgs[..2].iter().all(|c| c.arch == Architecture::Bert));
        assert!(cfgs[2..].iter().all(|c| c.arch == Architecture::Vit));
        // BERT labels are exactly the pre-axis labels; ViT cells are marked
        assert_eq!(cfgs[0].label(), "a8w8-pt-mse");
        assert_eq!(cfgs[2].label(), "a8w8-pt-mse-vit");
        // the axis is part of the spec identity (and only for non-BERT)
        let b = cfgs[0].to_spec("mnli", 1);
        let v = cfgs[2].to_spec("mnli", 1);
        assert_ne!(b.spec_id(), v.spec_id());
        assert!(!b.to_json().to_string().contains("architecture"));
        assert!(v.to_json().to_string().contains("\"architecture\":\"vit\""));
    }

    #[test]
    fn variant_axis_crosses_the_grid() {
        let variants =
            [AttnVariant::Vanilla, AttnVariant::ClippedSoftmax, AttnVariant::Gated];
        let cfgs = grid_var(
            128,
            &[Architecture::Bert, Architecture::Vit],
            &variants,
            &[8],
            &[8],
            &[1],
            &[Estimator::Mse],
            &[RangeMethod::Auto],
        )
        .unwrap();
        assert_eq!(cfgs.len(), 2 * 3);
        // variant nests inside arch; vanilla cells keep pre-axis labels
        assert_eq!(cfgs[0].label(), "a8w8-pt-mse");
        assert_eq!(cfgs[1].label(), "a8w8-pt-mse-csoft");
        assert_eq!(cfgs[2].label(), "a8w8-pt-mse-gate");
        assert_eq!(cfgs[3].label(), "a8w8-pt-mse-vit");
        assert_eq!(cfgs[4].label(), "a8w8-pt-mse-vit-csoft");
        assert_eq!(cfgs[5].label(), "a8w8-pt-mse-vit-gate");
        // the variant is part of the spec identity, and only when
        // non-vanilla — vanilla cells keep their pre-axis spec_ids
        let vanilla = cfgs[0].to_spec("mnli", 1);
        let csoft = cfgs[1].to_spec("mnli", 1);
        let gate = cfgs[2].to_spec("mnli", 1);
        assert!(!vanilla.to_json().to_string().contains("variant"));
        assert!(csoft.to_json().to_string().contains("\"variant\":\"clipped_softmax\""));
        assert!(gate.to_json().to_string().contains("\"variant\":\"gated\""));
        let ids = [vanilla.spec_id(), csoft.spec_id(), gate.spec_id()];
        assert_ne!(ids[0], ids[1]);
        assert_ne!(ids[0], ids[2]);
        assert_ne!(ids[1], ids[2]);
        // grid() is exactly the vanilla plane of grid_var()
        let plain = grid(
            128,
            &[Architecture::Bert, Architecture::Vit],
            &[8],
            &[8],
            &[1],
            &[Estimator::Mse],
            &[RangeMethod::Auto],
        )
        .unwrap();
        assert_eq!(plain.len(), 2);
        assert_eq!(plain[0].label(), cfgs[0].label());
        assert_eq!(plain[1].label(), cfgs[3].label());
        assert!(plain.iter().all(|c| c.variant == AttnVariant::Vanilla));
    }

    #[test]
    fn shards_partition_the_grid() {
        let cfgs = grid(
            128,
            &[Architecture::Bert, Architecture::Vit],
            &[8, 4],
            &[8],
            &[1, 8],
            &[Estimator::CurrentMinMax, Estimator::Mse],
            &[RangeMethod::Auto],
        )
        .unwrap();
        let ids: Vec<String> = cfgs.iter().map(|c| c.to_spec("mnli", 1).spec_id()).collect();
        for n in [1usize, 2, 4] {
            let mut seen = 0;
            for i in 0..n {
                let shard: Vec<&String> =
                    ids.iter().filter(|id| shard_of(id, n) == i).collect();
                seen += shard.len();
            }
            // shards are disjoint by construction (shard_of is a function
            // of the id); together they must cover the grid exactly
            assert_eq!(seen, ids.len(), "n={n}");
        }
        // assignment is stable — same id, same shard, every time
        assert_eq!(shard_of(&ids[0], 4), shard_of(&ids[0], 4));
        assert!(parse_shard("1/2").unwrap() == (1, 2));
        assert!(parse_shard("2/2").unwrap() == (2, 2));
        assert!(parse_shard("0/2").is_err());
        assert!(parse_shard("3/2").is_err());
        assert!(parse_shard("x").is_err());
    }

    #[test]
    fn merge_unions_shards_and_rejects_conflicts() {
        let mk = |id: &str, score: Option<f64>| SweepResult {
            label: format!("cfg-{id}"),
            spec_id: id.to_string(),
            act_bits: 8,
            weight_bits: 8,
            act_mse: 1e-3,
            weight_mse: 1e-4,
            peg_overhead: 0,
            peg_overhead_pct: 0.0,
            score,
            millis: 1.0,
        };
        let ids = vec!["a".to_string(), "b".to_string()];
        let labels = vec!["cfg-a".to_string(), "cfg-b".to_string()];
        let s1: BTreeMap<String, SweepResult> =
            [("a".to_string(), mk("a", Some(80.0)))].into_iter().collect();
        let s2: BTreeMap<String, SweepResult> =
            [("b".to_string(), mk("b", None))].into_iter().collect();
        let merged = merge_results(&[s1.clone(), s2.clone()], &ids, &labels).unwrap();
        assert_eq!(merged.len(), 2);
        // grid order, not shard order
        assert_eq!(merged[0].spec_id, "a");
        assert_eq!(merged[0].score, Some(80.0));
        assert_eq!(merged[1].spec_id, "b");
        // duplicate ids must agree (timing aside) ...
        let mut dup = mk("a", Some(80.0));
        dup.millis = 99.0;
        let s2_dup: BTreeMap<String, SweepResult> =
            [("a".to_string(), dup), ("b".to_string(), mk("b", None))].into_iter().collect();
        assert!(merge_results(&[s1.clone(), s2_dup], &ids, &labels).is_ok());
        // ... and a conflicting duplicate is an error, not a pick-a-winner
        let s2_bad: BTreeMap<String, SweepResult> =
            [("a".to_string(), mk("a", Some(10.0))), ("b".to_string(), mk("b", None))]
                .into_iter()
                .collect();
        assert!(merge_results(&[s1.clone(), s2_bad], &ids, &labels).is_err());
        // a grid cell no shard ran is a hole, and holes are errors
        assert!(merge_results(&[s1], &ids, &labels).is_err());
    }

    #[test]
    fn granularity_mapping() {
        assert_eq!(granularity_for(128, 1).unwrap(), Granularity::PerTensor);
        assert_eq!(granularity_for(128, 128).unwrap(), Granularity::PerEmbedding);
        assert_eq!(
            granularity_for(128, 8).unwrap(),
            Granularity::PerEmbeddingGroup { k: 8, permute: true }
        );
        // non-dividing K: near-even permuted groups (paper K=6/12 at any d)
        assert_eq!(
            granularity_for(128, 6).unwrap(),
            Granularity::PerEmbeddingGroup { k: 6, permute: true }
        );
        // K beyond d is a typo, not a silent duplicate per-embedding cell
        assert!(granularity_for(128, 1000).is_err());
    }

    #[test]
    fn offline_sweep_runs_and_finer_granularity_wins() {
        let data = synth_data(64, 32, 4, 7);
        let cfgs = grid(
            64,
            BERT,
            &[8],
            &[8],
            &[1, 64],
            &[Estimator::CurrentMinMax],
            &[RangeMethod::Auto],
        )
        .unwrap();
        let res = run_offline(&data, &cfgs, &Pool::new(2)).unwrap();
        assert_eq!(res.len(), 2);
        for r in &res {
            assert!(r.act_mse.is_finite() && r.weight_mse.is_finite());
        }
        // with installed outlier lanes, per-embedding must beat per-tensor
        assert!(
            res[1].act_mse < res[0].act_mse,
            "pe {} !< pt {}",
            res[1].act_mse,
            res[0].act_mse
        );
        // the overhead column follows the paper's accounting
        assert_eq!(res[0].peg_overhead, 0);
        assert_eq!(res[1].peg_overhead, 6 * 64);
        // ...and the % column is the same count over the reference
        // model's total parameters at this d
        assert_eq!(res[0].peg_overhead_pct, 0.0);
        let want = 100.0 * (6 * 64) as f64 / reference_total_params(64) as f64;
        assert!((res[1].peg_overhead_pct - want).abs() < 1e-12);
        assert!(res[1].peg_overhead_pct > 0.0 && res[1].peg_overhead_pct < 100.0);
    }

    #[test]
    fn reference_params_scale_with_d() {
        // the denominator must grow with the model it normalises against,
        // keeping the % meaningful across --d settings
        let small = reference_total_params(64);
        let big = reference_total_params(128);
        assert!(small > 0);
        assert!(big > 2 * small, "{big} !> 2*{small}");
        // per-embedding overhead (6d) stays a small fraction of the model
        assert!(overhead_pct(6 * 128, 128) < 5.0);
    }

    #[test]
    fn reference_params_are_per_architecture() {
        // the ViT fixture has no token/type vocabularies, so the same
        // overhead normalises against a different (smaller) budget — the
        // per-model accounting the table's "ovh %" column promises
        let bert = reference_total_params_arch(128, Architecture::Bert);
        let vit = reference_total_params_arch(128, Architecture::Vit);
        assert!(bert > 0 && vit > 0);
        assert_ne!(bert, vit);
        assert!(vit < bert, "vit {vit} !< bert {bert}");
        assert!(
            overhead_pct_arch(6 * 128, 128, Architecture::Vit)
                > overhead_pct_arch(6 * 128, 128, Architecture::Bert)
        );
        // the BERT wrappers stay the BERT numbers
        assert_eq!(reference_total_params(128), bert);
    }

    #[test]
    fn offline_mse_group_cells_run_and_report_overhead() {
        let data = synth_data(64, 32, 4, 7);
        // K=6 does not divide d=64: the near-even uneven-group path runs
        // through the row-sampling per-group search
        let cfgs = grid(
            64,
            BERT,
            &[8],
            &[8],
            &[1, 6, 64],
            &[Estimator::CurrentMinMax],
            &[RangeMethod::Auto, RangeMethod::MsePerGroup],
        )
        .unwrap();
        assert_eq!(cfgs.len(), 6);
        let res = run_offline(&data, &cfgs, &Pool::new(2)).unwrap();
        for r in &res {
            assert!(r.act_mse.is_finite(), "{}", r.label);
        }
        // K=6 permuted groups: d + 2*3*K extra parameters
        let k6 = res.iter().find(|r| r.label.contains("k6p")).unwrap();
        assert_eq!(k6.peg_overhead, 64 + 36);
    }

    #[test]
    fn sweep_labels_are_unique() {
        let cfgs = grid(
            128,
            &[Architecture::Bert, Architecture::Vit],
            &[8, 4],
            &[8, 4],
            &[1, 8, 128],
            &[Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse],
            &[RangeMethod::Auto, RangeMethod::CurrentMinMax, RangeMethod::MsePerGroup],
        )
        .unwrap();
        let mut labels: Vec<String> = cfgs.iter().map(|c| c.label()).collect();
        labels.sort();
        let before = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), before);
    }

    #[test]
    fn to_spec_reproduces_the_hard_coded_policy() {
        // the exact QuantPolicy the pre-spec runtime pass built
        let cfg = SweepConfig {
            arch: Architecture::Bert,
            variant: AttnVariant::Vanilla,
            act_bits: 4,
            weight_bits: 8,
            granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
            estimator: Estimator::Mse,
            range_method: RangeMethod::Auto,
        };
        let spec = cfg.to_spec("mnli", 2);
        let mut old = QuantPolicy::uniform(8, 4);
        old.default.granularity = Granularity::PerEmbeddingGroup { k: 8, permute: true };
        old.weights.estimator = Estimator::Mse;
        assert_eq!(spec.policy.resolve(&tiny_model_info()), old);
        assert_eq!(spec.calib.estimator, Estimator::Mse);
        assert_eq!(spec.seeds, 2);
        assert_eq!(spec.tasks, vec!["mnli".to_string()]);
        assert_eq!(spec.name, cfg.label());
        // the range method is part of the spec (and so of its identity)
        let mse = SweepConfig { range_method: RangeMethod::MsePerGroup, ..cfg.clone() };
        assert_eq!(
            mse.to_spec("mnli", 2).policy.default_site.range_method,
            RangeMethod::MsePerGroup
        );
        assert_ne!(mse.to_spec("mnli", 2).spec_id(), spec.spec_id());
    }

    #[test]
    fn spec_ids_key_the_whole_cell() {
        let cfgs = grid(
            128,
            &[Architecture::Bert, Architecture::Vit],
            &[8, 4],
            &[8],
            &[1, 8],
            &[Estimator::CurrentMinMax, Estimator::Mse],
            &[RangeMethod::Auto, RangeMethod::MsePerGroup],
        )
        .unwrap();
        let mut ids: Vec<String> =
            cfgs.iter().map(|c| c.to_spec("mnli", 1).spec_id()).collect();
        let before = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), before, "grid cells must hash distinctly");
        // task and seed count are part of the identity
        let c = &cfgs[0];
        assert_ne!(c.to_spec("mnli", 1).spec_id(), c.to_spec("rte", 1).spec_id());
        assert_ne!(c.to_spec("mnli", 1).spec_id(), c.to_spec("mnli", 3).spec_id());
        // and re-hashing is stable
        assert_eq!(c.to_spec("mnli", 1).spec_id(), c.to_spec("mnli", 1).spec_id());
    }

    #[test]
    fn report_json_roundtrips() {
        let data = synth_data(32, 16, 2, 1);
        let cfgs =
            grid(32, BERT, &[8], &[4], &[1], &[Estimator::Mse], &[RangeMethod::Auto]).unwrap();
        let res = run_offline(&data, &cfgs, &Pool::serial()).unwrap();
        let j = report_json(&res, 4, 12.5, 32, 1, BERT);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("threads").unwrap().as_usize().unwrap(), 4);
        let arr = parsed.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("label").unwrap().as_str().unwrap(),
            res[0].label
        );
        // the offline workload guards cache reuse across --d/--seed/--arch
        assert!(workload_matches(&parsed, 32, 1, "bert"));
        assert!(!workload_matches(&parsed, 64, 1, "bert"));
        assert!(!workload_matches(&parsed, 32, 2, "bert"));
        assert!(!workload_matches(&parsed, 32, 1, "bert,vit"));
        // pre-spec reports (no workload fields) never match
        assert!(!workload_matches(&Json::parse("{}").unwrap(), 32, 1, "bert"));
    }

    #[test]
    fn workload_keys_on_architecture() {
        // the stamp is order-insensitive and deduped
        assert_eq!(arch_stamp(&[Architecture::Vit, Architecture::Bert]), "bert,vit");
        assert_eq!(
            arch_stamp(&[Architecture::Bert, Architecture::Bert]),
            "bert"
        );
        let j = report_json(&[], 1, 0.0, 32, 1, &[Architecture::Vit, Architecture::Bert]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert!(workload_matches(&parsed, 32, 1, "bert,vit"));
        assert!(!workload_matches(&parsed, 32, 1, "bert"));
        assert!(!workload_matches(&parsed, 32, 1, "vit"));
        // reports written before the axis existed read as BERT-only:
        // still resumable for BERT sweeps, never for a ViT axis
        let legacy = Json::parse(r#"{"d": 32, "data_seed": 1, "configs": []}"#).unwrap();
        assert!(workload_matches(&legacy, 32, 1, "bert"));
        assert!(!workload_matches(&legacy, 32, 1, "bert,vit"));
    }

    #[test]
    fn cached_results_roundtrip_by_spec_id() {
        let data = synth_data(32, 16, 2, 1);
        let cfgs =
            grid(32, BERT, &[8, 4], &[4], &[1], &[Estimator::Mse], &[RangeMethod::Auto])
                .unwrap();
        let mut res = run_offline(&data, &cfgs, &Pool::serial()).unwrap();
        for (r, c) in res.iter_mut().zip(&cfgs) {
            r.spec_id = c.to_spec("mnli", 1).spec_id();
        }
        res[0].score = Some(81.25);
        let j = report_json(&res, 2, 5.0, 32, 1, BERT);
        let cached = parse_results(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(cached.len(), 2);
        let r0 = &cached[&res[0].spec_id];
        assert_eq!(r0.label, res[0].label);
        assert_eq!(r0.score, Some(81.25));
        assert_eq!(r0.act_mse, res[0].act_mse);
        assert_eq!(r0.peg_overhead_pct, res[0].peg_overhead_pct);
        assert_eq!(cached[&res[1].spec_id].score, None);
        // entries without spec_id (pre-spec reports) are skipped
        let legacy = report_json(
            &[SweepResult { spec_id: String::new(), ..res[0].clone() }],
            1,
            1.0,
            32,
            1,
            BERT,
        );
        assert!(parse_results(&Json::parse(&legacy.to_string()).unwrap())
            .unwrap()
            .is_empty());
    }

    #[test]
    fn parse_tolerates_reports_without_overhead_columns() {
        // results files written before the overhead / % columns existed
        // must still load (resume keys off spec_id, not schema version)
        let text = r#"{"configs": [{"label": "a8w8-pt-current", "spec_id": "id1",
            "act_bits": 8, "weight_bits": 8, "act_mse": 0.001,
            "weight_mse": 0.0001, "millis": 1.5}]}"#;
        let cached = parse_results(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cached["id1"].peg_overhead, 0);
        assert_eq!(cached["id1"].peg_overhead_pct, 0.0);
        assert_eq!(cached["id1"].score, None);
    }

    #[test]
    fn compare_flags_score_and_mse_regressions() {
        let mk = |id: &str, score: Option<f64>, act_mse: f32| SweepResult {
            label: format!("cfg-{id}"),
            spec_id: id.to_string(),
            act_bits: 8,
            weight_bits: 8,
            act_mse,
            weight_mse: 1e-4,
            peg_overhead: 0,
            peg_overhead_pct: 0.0,
            score,
            millis: 1.0,
        };
        let baseline: BTreeMap<String, SweepResult> = [
            ("a".to_string(), mk("a", Some(80.0), 1e-3)),
            ("b".to_string(), mk("b", Some(80.0), 1e-3)),
            ("c".to_string(), mk("c", None, 1e-3)),
            ("d".to_string(), mk("d", None, 1e-3)),
            ("f".to_string(), mk("f", Some(80.0), 1e-3)),
        ]
        .into_iter()
        .collect();
        let current = vec![
            mk("a", Some(79.8), 1e-3), // within tolerance
            mk("b", Some(78.0), 1e-3), // score regression
            mk("c", None, 1.04e-3),    // within relative MSE tolerance
            mk("d", None, 2e-3),       // MSE regression
            mk("e", Some(50.0), 1e-3), // not in baseline: skipped
            mk("f", None, 1e-3),       // baseline scored, current didn't
        ];
        let rows = compare_to_baseline(&current, &baseline, 0.5, 0.10);
        assert_eq!(rows.len(), 5);
        assert!(!rows[0].regressed);
        assert!(rows[1].regressed);
        assert_eq!(rows[1].metric, "score");
        assert!(!rows[2].regressed);
        assert!(rows[3].regressed);
        assert_eq!(rows[3].metric, "act_mse");
        // a lost score must fail the gate, not silently downgrade to MSE
        assert!(rows[4].regressed);
        assert_eq!(rows[4].metric, "score-missing");
    }
}
