//! Parallel experiment-sweep engine.
//!
//! The paper's tables are grids — bit-widths × granularities × range
//! estimators — and every cell is independent, so the engine runs one
//! configuration per `util::pool` job. Two layers:
//!
//! * **Offline substrate sweep** (always available): each configuration
//!   runs the full L3 statistics pipeline — estimator observation, MSE
//!   range search, PEG parameter assembly, activation QDQ, weight QDQ —
//!   on deterministic synthetic calibration data with installed outlier
//!   lanes, reporting quantization MSE per config. This is the
//!   benchmarkable hot path (benches/sweep_bench.rs) and needs no AOT
//!   artifacts.
//! * **Runtime-backed scores** (when `artifacts/manifest.json` and a task
//!   checkpoint exist): the same grid is evaluated end-to-end via
//!   `experiments::eval_config`; workers share the runtime's
//!   mutex-guarded compiled-executable cache, so each artifact compiles
//!   once for the whole sweep.
//!
//! Inside an *offline* sweep job all kernels run with a serial inner
//! pool — the parallelism budget is spent across configurations, and
//! results stay bit-identical to a serial sweep (see
//! tests/determinism.rs). The runtime-backed path reuses the existing
//! eval pipeline, whose inner kernels use `Pool::global()`; cap
//! oversubscription there with `TQ_THREADS` or `--threads` if needed.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::experiments::{self, EvalConfig};
use super::Ctx;
use crate::data::TaskSpec;
use crate::model::qconfig::QuantPolicy;
use crate::model::Params;
use crate::quant::estimators::{mse_search_pool, RangeTracker};
use crate::quant::peg::lane_qparams;
use crate::quant::{
    qdq_per_lane_pool, qdq_tensor_pool, qparams_from_range, qparams_symmetric, Estimator,
    Granularity, QGrid, QParams,
};
use crate::report::{fmt_score, write_file, Table};
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// One cell of the sweep grid.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub act_bits: u32,
    pub weight_bits: u32,
    pub granularity: Granularity,
    pub estimator: Estimator,
}

impl SweepConfig {
    pub fn label(&self) -> String {
        let g = match &self.granularity {
            Granularity::PerTensor => "pt".to_string(),
            Granularity::PerEmbedding => "pe".to_string(),
            Granularity::PerEmbeddingGroup { k, permute } => {
                format!("k{}{}", k, if *permute { "p" } else { "" })
            }
        };
        let e = match self.estimator {
            Estimator::CurrentMinMax => "current",
            Estimator::RunningMinMax => "running",
            Estimator::Mse => "mse",
        };
        format!("a{}w{}-{}-{}", self.act_bits, self.weight_bits, g, e)
    }
}

/// Result of one configuration (offline metrics, plus the dev score when
/// the runtime-backed pass ran).
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub label: String,
    pub act_bits: u32,
    pub weight_bits: u32,
    /// activation QDQ MSE on the held-out synthetic tensor
    pub act_mse: f32,
    /// weight QDQ MSE on the synthetic weight matrix
    pub weight_mse: f32,
    /// task dev score ×100 (runtime-backed pass only)
    pub score: Option<f64>,
    pub millis: f64,
}

/// Map a group count onto the paper's granularities for embedding dim `d`.
pub fn granularity_for(d: usize, k: usize) -> Result<Granularity> {
    if k <= 1 {
        Ok(Granularity::PerTensor)
    } else if k == d {
        Ok(Granularity::PerEmbedding)
    } else if d % k == 0 {
        Ok(Granularity::PerEmbeddingGroup { k, permute: true })
    } else {
        bail!("K={k} does not divide d={d}")
    }
}

/// Cross product of the sweep axes.
pub fn grid(
    d: usize,
    act_bits: &[u32],
    weight_bits: &[u32],
    groups: &[usize],
    estimators: &[Estimator],
) -> Result<Vec<SweepConfig>> {
    let mut out = Vec::new();
    for &ab in act_bits {
        for &wb in weight_bits {
            for &k in groups {
                let gran = granularity_for(d, k)?;
                for &est in estimators {
                    out.push(SweepConfig {
                        act_bits: ab,
                        weight_bits: wb,
                        granularity: gran.clone(),
                        estimator: est,
                    });
                }
            }
        }
    }
    Ok(out)
}

/// Deterministic synthetic calibration workload shared by every config in
/// a sweep: activations with a few high-range outlier lanes (the paper's
/// Fig. 2 structure — this is what makes granularity matter) plus one
/// linear-layer weight matrix.
pub struct SweepData {
    pub calib: Vec<Tensor>,
    pub eval: Tensor,
    pub weight: Tensor,
}

pub fn synth_data(d: usize, rows: usize, batches: usize, seed: u64) -> SweepData {
    let mut rng = Rng::new(seed);
    let activations = |rng: &mut Rng| {
        Tensor::from_fn(&[rows, d], |i| {
            let lane = i % d;
            let mag = if lane % 17 == 3 { 30.0 } else { 1.0 };
            rng.normal_f32(0.0, mag)
        })
    };
    let calib: Vec<Tensor> = (0..batches.max(1)).map(|_| activations(&mut rng)).collect();
    let eval = activations(&mut rng);
    let weight = Tensor::randn(&[d, 4 * d], 0.05, &mut rng);
    SweepData { calib, eval, weight }
}

/// Run one configuration's offline substrate pipeline. `inner` is the
/// pool used *inside* the job (serial when jobs themselves run in
/// parallel).
pub fn run_config_offline(
    data: &SweepData,
    cfg: &SweepConfig,
    inner: &Pool,
) -> Result<SweepResult> {
    let t0 = Instant::now();
    let d = data.eval.last_dim();
    let agrid = QGrid::asymmetric(cfg.act_bits);

    // calibration: estimator observation over every batch
    let mut tracker = RangeTracker::new(cfg.estimator, d);
    for batch in &data.calib {
        tracker.observe_pool(batch, inner)?;
    }

    // granularity -> per-lane parameters (PEG permutation included)
    let params: Vec<QParams> = match &cfg.granularity {
        Granularity::PerTensor => {
            let (lo, hi) = tracker.tensor_range_pool(agrid, inner);
            vec![qparams_from_range(lo, hi, agrid); d]
        }
        g => {
            let (lo, hi) = tracker.lane_ranges();
            let (params, _perm) = lane_qparams(&lo, &hi, g, agrid)?;
            params
        }
    };
    let act_q = qdq_per_lane_pool(&data.eval, &params, agrid, inner)?;
    let act_mse = act_q.mse(&data.eval)?;

    // weight PTQ: symmetric per-tensor with the config's estimator
    let wgrid = QGrid::symmetric(cfg.weight_bits);
    let wp = match cfg.estimator {
        Estimator::Mse => {
            let amax = data.weight.abs_max();
            let (lo, hi) = mse_search_pool(data.weight.data(), -amax, amax, wgrid, inner);
            qparams_symmetric(lo.abs().max(hi.abs()), wgrid)
        }
        _ => qparams_symmetric(data.weight.abs_max(), wgrid),
    };
    let wq = qdq_tensor_pool(&data.weight, wp, wgrid, inner);
    let weight_mse = wq.mse(&data.weight)?;

    Ok(SweepResult {
        label: cfg.label(),
        act_bits: cfg.act_bits,
        weight_bits: cfg.weight_bits,
        act_mse,
        weight_mse,
        score: None,
        millis: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Offline sweep: one pool job per configuration, serial inner kernels.
/// Results are returned in grid order regardless of scheduling.
pub fn run_offline(
    data: &SweepData,
    cfgs: &[SweepConfig],
    pool: &Pool,
) -> Result<Vec<SweepResult>> {
    let inner = Pool::serial();
    let jobs: Vec<_> = cfgs
        .iter()
        .map(|cfg| {
            let inner = inner.clone();
            move || run_config_offline(data, cfg, &inner)
        })
        .collect();
    pool.run(jobs).into_iter().collect()
}

/// Runtime-backed scores for the same grid: each config becomes a full
/// calibrate -> quantize -> evaluate pass through the AOT executables.
/// Workers share `ctx.rt`'s compiled-executable cache (the runtime is
/// `Sync`), so a warm artifact never recompiles; on a cold cache,
/// workers racing on the same artifact may each compile it once (first
/// insert wins — see `Runtime::executable`).
///
/// Note: the eval pipeline's inner kernels use `Pool::global()`, so with
/// P config workers the CPU kernels can momentarily oversubscribe; the
/// hot cost here is PJRT execution (serial per call), and `TQ_THREADS`
/// caps the global pool when that matters.
pub fn runtime_scores(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    cfgs: &[SweepConfig],
    seeds: usize,
    pool: &Pool,
) -> Vec<Result<f64>> {
    let jobs: Vec<_> = cfgs
        .iter()
        .map(|cfg| {
            move || -> Result<f64> {
                let mut policy = QuantPolicy::uniform(cfg.weight_bits, cfg.act_bits);
                policy.default.granularity = cfg.granularity.clone();
                policy.weights.estimator = cfg.estimator;
                let mut ec = EvalConfig::new(policy);
                ec.calib.estimator = cfg.estimator;
                experiments::eval_config(ctx, task, params, &ec, seeds)
            }
        })
        .collect();
    // per-config Results: one failing config must not discard the
    // successfully evaluated rest of the grid
    pool.run(jobs)
}

/// Consolidated machine-readable report.
pub fn report_json(results: &[SweepResult], threads: usize, total_ms: f64) -> Json {
    let configs: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("label".to_string(), Json::Str(r.label.clone()));
            m.insert("act_bits".to_string(), Json::Num(r.act_bits as f64));
            m.insert("weight_bits".to_string(), Json::Num(r.weight_bits as f64));
            m.insert("act_mse".to_string(), Json::Num(r.act_mse as f64));
            m.insert("weight_mse".to_string(), Json::Num(r.weight_mse as f64));
            if let Some(s) = r.score {
                m.insert("score".to_string(), Json::Num(s));
            }
            m.insert("millis".to_string(), Json::Num(r.millis));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("threads".to_string(), Json::Num(threads as f64));
    top.insert("total_ms".to_string(), Json::Num(total_ms));
    top.insert("configs".to_string(), Json::Arr(configs));
    Json::Obj(top)
}

fn parse_u32_list(s: &str) -> Result<Vec<u32>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<u32>().map_err(|_| anyhow!("bad bit-width {p:?}")))
        .collect()
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<usize>().map_err(|_| anyhow!("bad group count {p:?}")))
        .collect()
}

fn parse_estimators(s: &str) -> Result<Vec<Estimator>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| match p {
            "current" | "minmax" => Ok(Estimator::CurrentMinMax),
            "running" | "ema" => Ok(Estimator::RunningMinMax),
            "mse" => Ok(Estimator::Mse),
            other => bail!("unknown estimator {other:?} (current|running|mse)"),
        })
        .collect()
}

/// `repro sweep` driver. Runs the offline substrate sweep always, adds
/// runtime-backed dev scores when artifacts and a checkpoint are present,
/// and writes one consolidated report (md + csv + json) under results/.
pub fn cmd_sweep(args: &Args) -> Result<()> {
    let d = args.get_usize("d", 128)?;
    let act_bits = parse_u32_list(args.get_or("bits", "8,4"))?;
    let weight_bits = parse_u32_list(args.get_or("wbits", "8"))?;
    let groups = parse_usize_list(args.get_or("groups", "1,8"))?;
    let estimators = parse_estimators(args.get_or("estimators", "current,mse"))?;
    let threads = args.get_usize("threads", 0)?;
    let pool = if threads == 0 { Pool::global().clone() } else { Pool::new(threads) };

    let cfgs = grid(d, &act_bits, &weight_bits, &groups, &estimators)?;
    if cfgs.is_empty() {
        bail!("sweep grid is empty");
    }
    println!(
        "sweep: {} configurations on {} worker thread(s)",
        cfgs.len(),
        pool.threads()
    );

    let t0 = Instant::now();
    let data = synth_data(d, 64, 8, args.get_u64("seed", 42)?);
    let mut results = run_offline(&data, &cfgs, &pool)?;

    let artifacts = args.get_or("artifacts", "artifacts");
    let task_name = args.get_or("task", "mnli");
    if std::path::Path::new(artifacts).join("manifest.json").exists() {
        let ctx = Ctx::new(
            artifacts,
            args.get_or("ckpt", "checkpoints"),
            args.get_or("results", "results"),
        )?;
        let task = ctx.task(task_name)?;
        match experiments::load_ckpt(&ctx, &task) {
            Ok(params) => {
                let seeds = args.get_usize("seeds", 1)?;
                let scores = runtime_scores(&ctx, &task, &params, &cfgs, seeds, &pool);
                for (r, s) in results.iter_mut().zip(scores) {
                    match s {
                        Ok(v) => r.score = Some(v),
                        Err(e) => println!("({}: runtime eval failed — {e})", r.label),
                    }
                }
            }
            Err(e) => println!("(offline metrics only — {e})"),
        }
    } else {
        println!("(artifacts/manifest.json absent; offline substrate metrics only)");
    }
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut table = Table::new(
        &format!("Quantization sweep ({} configs, {} threads)", results.len(), pool.threads()),
        &["config", "act MSE", "weight MSE", "score", "ms"],
    );
    for r in &results {
        table.row(vec![
            r.label.clone(),
            format!("{:.3e}", r.act_mse),
            format!("{:.3e}", r.weight_mse),
            r.score.map(fmt_score).unwrap_or_else(|| "-".to_string()),
            format!("{:.1}", r.millis),
        ]);
    }
    print!("{}", table.to_console());
    println!("sweep total: {total_ms:.0} ms");

    let results_dir = std::path::PathBuf::from(args.get_or("results", "results"));
    write_file(results_dir.join("sweep.md"), &table.to_markdown())?;
    write_file(results_dir.join("sweep.csv"), &table.to_csv())?;
    write_file(
        results_dir.join("sweep.json"),
        &report_json(&results, pool.threads(), total_ms).to_string(),
    )?;
    Ok(())
}

#[allow(dead_code)]
fn assert_shareable() {
    fn is_sync<T: Sync>() {}
    is_sync::<Ctx>();
    is_sync::<crate::runtime::Runtime>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_full_cross_product() {
        let cfgs = grid(
            128,
            &[8, 4],
            &[8],
            &[1, 8, 128],
            &[Estimator::CurrentMinMax, Estimator::Mse],
        )
        .unwrap();
        assert_eq!(cfgs.len(), 2 * 1 * 3 * 2);
        assert!(grid(10, &[8], &[8], &[3], &[Estimator::Mse]).is_err());
    }

    #[test]
    fn granularity_mapping() {
        assert_eq!(granularity_for(128, 1).unwrap(), Granularity::PerTensor);
        assert_eq!(granularity_for(128, 128).unwrap(), Granularity::PerEmbedding);
        assert_eq!(
            granularity_for(128, 8).unwrap(),
            Granularity::PerEmbeddingGroup { k: 8, permute: true }
        );
        assert!(granularity_for(128, 7).is_err());
    }

    #[test]
    fn offline_sweep_runs_and_finer_granularity_wins() {
        let data = synth_data(64, 32, 4, 7);
        let cfgs = grid(64, &[8], &[8], &[1, 64], &[Estimator::CurrentMinMax]).unwrap();
        let res = run_offline(&data, &cfgs, &Pool::new(2)).unwrap();
        assert_eq!(res.len(), 2);
        for r in &res {
            assert!(r.act_mse.is_finite() && r.weight_mse.is_finite());
        }
        // with installed outlier lanes, per-embedding must beat per-tensor
        assert!(
            res[1].act_mse < res[0].act_mse,
            "pe {} !< pt {}",
            res[1].act_mse,
            res[0].act_mse
        );
    }

    #[test]
    fn sweep_labels_are_unique() {
        let cfgs = grid(
            128,
            &[8, 4],
            &[8, 4],
            &[1, 8, 128],
            &[Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse],
        )
        .unwrap();
        let mut labels: Vec<String> = cfgs.iter().map(|c| c.label()).collect();
        labels.sort();
        let before = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), before);
    }

    #[test]
    fn report_json_roundtrips() {
        let data = synth_data(32, 16, 2, 1);
        let cfgs = grid(32, &[8], &[4], &[1], &[Estimator::Mse]).unwrap();
        let res = run_offline(&data, &cfgs, &Pool::serial()).unwrap();
        let j = report_json(&res, 4, 12.5);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("threads").unwrap().as_usize().unwrap(), 4);
        let arr = parsed.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(
            arr[0].get("label").unwrap().as_str().unwrap(),
            res[0].label
        );
    }
}
