//! Experiment registry: one driver per paper table/figure (DESIGN.md §5).
//!
//! Every PTQ driver is now a *list of [`QuantSpec`]s plus a formatter*:
//! the rows name their configurations declaratively and
//! [`crate::spec::run::run_spec`] owns the calibrate → weight-QDQ →
//! assemble → eval pipeline, so `repro table1` and
//! `repro run --preset w8a8` are literally the same experiment. Only the
//! QAT rows (which train) remain imperative.
//!
//! Every driver prints the paper-shaped table to stdout and writes
//! markdown + CSV under `results/`. Absolute scores differ from the paper
//! (synthetic benchmark, tiny model — DESIGN.md §2); the claims under test
//! are the *deltas between quantization configurations*.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::calibrate::{calibrate, CalibCfg, Calibration};
use super::diagnostics as diag;
use super::eval::evaluate;
use super::train::{finetune, TrainCfg};
use super::weights::{quantize_weights, AdaRoundOpts};
use super::Ctx;
use crate::data::{TaskSpec, TASKS};
use crate::metrics::{glue_score, median};
use crate::model::manifest::{Architecture, AttnVariant};
use crate::model::qconfig::{
    assemble_act_tensors, ActQuantTensors, QuantPolicy, SiteCfg, WeightCfg,
};
use crate::model::{checkpoint, Params};
use crate::quant::{Estimator, Granularity};
use crate::report::{fmt_score, write_file, Table};
use crate::spec::run::run_spec;
use crate::spec::{presets, CalibSpec, PolicySpec, QuantSpec, SiteSelector};

/// Shared experiment options from the CLI.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// number of calibration seeds for PTQ medians (paper uses 5)
    pub seeds: usize,
    /// restrict to a subset of tasks (empty = all 8)
    pub tasks: Vec<String>,
    /// smaller calibration / fewer iterations for smoke runs
    pub quick: bool,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts { seeds: 3, tasks: vec![], quick: false }
    }
}

impl ExpOpts {
    fn tasks(&self) -> Vec<TaskSpec> {
        TASKS
            .iter()
            .filter(|t| self.tasks.is_empty() || self.tasks.iter().any(|n| n == t.name))
            .copied()
            .collect()
    }

    fn hard_tasks(&self) -> Vec<TaskSpec> {
        // the paper's four "problematic" tasks
        TASKS
            .iter()
            .filter(|t| ["stsb", "mnli", "qnli", "rte"].contains(&t.name))
            .filter(|t| self.tasks.is_empty() || self.tasks.iter().any(|n| n == t.name))
            .copied()
            .collect()
    }
}

/// Load (or complain about) the fine-tuned FP32 checkpoint for a task.
pub fn load_ckpt(ctx: &Ctx, task: &TaskSpec) -> Result<Params> {
    load_ckpt_arch(ctx, task, Architecture::Bert)
}

/// [`load_ckpt`] for a specific architecture family (`{task}.ckpt` /
/// `vit_{task}.ckpt`). ViT checkpoints come from `repro gen-artifacts`;
/// BERT ones from `repro finetune`.
pub fn load_ckpt_arch(ctx: &Ctx, task: &TaskSpec, arch: Architecture) -> Result<Params> {
    load_ckpt_var(ctx, task, arch, AttnVariant::Vanilla)
}

/// [`load_ckpt_arch`] for a specific attention variant
/// (`csoft_{task}.ckpt`, `vit_gate_{task}.ckpt`, ...). All variant
/// checkpoints come from `repro gen-artifacts`; only the BERT-vanilla
/// family is refreshed by `repro finetune`.
pub fn load_ckpt_var(
    ctx: &Ctx,
    task: &TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
) -> Result<Params> {
    let path = ctx.ckpt_path_var(task.name, arch, variant);
    checkpoint::load(&path).map_err(|_| {
        anyhow!(
            "missing checkpoint {} — run `repro {}` first",
            path.display(),
            match (arch, variant) {
                (Architecture::Bert, AttnVariant::Vanilla) => "finetune --all",
                _ => "gen-artifacts",
            }
        )
    })
}

/// `repro finetune [--all | --task t] [--epochs n]`
pub fn cmd_finetune(ctx: &Ctx, opts: &ExpOpts, epochs: usize, lr: f32) -> Result<()> {
    let mut summary = Table::new(
        "FP32 fine-tuning (synthetic GLUE)",
        &["task", "steps", "first loss", "last loss", "dev score"],
    );
    for task in opts.tasks() {
        let t0 = std::time::Instant::now();
        let cfg = TrainCfg { epochs, lr, ..Default::default() };
        let res = finetune(ctx, &task, &cfg)?;
        checkpoint::save(&res.params, ctx.ckpt_path(task.name))?;
        let info = ctx.model_info(&task)?;
        let act = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
        let score = evaluate(ctx, &task, &res.params, &act)?;
        println!(
            "[{}] {} steps in {:.1}s -> dev {score:.2}",
            task.name,
            res.losses.len(),
            t0.elapsed().as_secs_f32()
        );
        summary.row(vec![
            task.name.to_string(),
            res.losses.len().to_string(),
            format!("{:.4}", res.losses.first().unwrap_or(&f32::NAN)),
            format!("{:.4}", res.losses.last().unwrap_or(&f32::NAN)),
            fmt_score(score),
        ]);
        // loss curve for EXPERIMENTS.md (end-to-end validation)
        let curve: String = res
            .losses
            .iter()
            .enumerate()
            .map(|(i, l)| format!("{i},{l}\n"))
            .collect();
        write_file(ctx.results_dir.join(format!("loss_curve_{}.csv", task.name)), &curve)?;
    }
    print!("{}", summary.to_console());
    write_file(ctx.results_dir.join("finetune.md"), &summary.to_markdown())?;
    Ok(())
}

/// Quantization "configuration" = weight policy + activation policy +
/// calibration settings, evaluated with median over seeds.
///
/// Retained only because `examples/{quickstart,end_to_end}.rs` build
/// policies imperatively; everything in this crate routes through
/// [`crate::spec::run`], which is the canonical pipeline (note: unlike
/// `run_spec_on`, this ignores `calib.seed` and uses `seed_index * 97`
/// directly — the pre-spec behavior). Do not add new callers.
pub struct EvalConfig {
    pub policy: QuantPolicy,
    pub calib: CalibCfg,
    pub adaround: AdaRoundOpts,
}

impl EvalConfig {
    pub fn new(policy: QuantPolicy) -> EvalConfig {
        EvalConfig {
            policy,
            calib: CalibCfg::default(),
            adaround: AdaRoundOpts::default(),
        }
    }
}

/// Evaluate a config on a task: calibrate -> quantize weights -> assemble
/// activation tensors -> dev eval. Median over `seeds` calibration seeds.
pub fn eval_config(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    cfg: &EvalConfig,
    seeds: usize,
) -> Result<f64> {
    let info = ctx.model_info(task)?;
    let mut scores = Vec::with_capacity(seeds);
    for seed in 0..seeds {
        let calib_cfg = CalibCfg { seed: seed as u64 * 97, ..cfg.calib.clone() };
        let calib = calibrate(ctx, task, params, &calib_cfg)?;
        let (qp, _) = quantize_weights(info, params, &cfg.policy, Some(&calib), &cfg.adaround)?;
        let act = assemble_act_tensors(info, &cfg.policy, &calib.trackers)?;
        scores.push(evaluate(ctx, task, &qp, &act)?);
    }
    Ok(median(&scores))
}

/// Shared formatter: run one spec per row over `tasks`, print the
/// paper-shaped table and write markdown + CSV under `results/`. The
/// first column shows each spec's label (`QuantSpec::name`).
fn spec_table(
    ctx: &Ctx,
    name: &str,
    title: &str,
    first_col: &str,
    tasks: &[TaskSpec],
    specs: Vec<QuantSpec>,
    include_glue: bool,
) -> Result<()> {
    let task_names: Vec<String> = tasks.iter().map(|t| t.name.to_string()).collect();
    let mut header: Vec<&str> = vec![first_col];
    header.extend(tasks.iter().map(|t| t.name));
    if include_glue {
        header.push("GLUE");
    }
    let mut table = Table::new(title, &header);
    for spec in specs {
        let spec = spec.with_tasks(&task_names);
        let report = run_spec(ctx, &spec)?;
        let mut row = vec![spec.name.clone()];
        row.extend(report.scores.iter().map(|&s| fmt_score(s)));
        if include_glue {
            row.push(fmt_score(report.glue));
        }
        table.row(row);
    }
    finish(ctx, name, &table)
}

/// Table 1: standard 8-bit PTQ (W8A8 / W32A8 / W8A32) vs FP32 on all tasks.
pub fn table1(ctx: &Ctx, opts: &ExpOpts) -> Result<()> {
    let specs = [
        ("fp32", "FP32"),
        ("w8a8", "W8A8"),
        ("w32a8", "W32A8"),
        ("w8a32", "W8A32"),
    ]
    .into_iter()
    .map(|(p, label)| Ok(presets::preset(p)?.named(label).with_seeds(opts.seeds)))
    .collect::<Result<Vec<_>>>()?;
    spec_table(
        ctx,
        "table1",
        "Table 1: post-training quantization (synthetic-GLUE dev)",
        "Configuration",
        &opts.tasks(),
        specs,
        true,
    )
}

/// Table 2: leave-one-out ablation of activation quantizers on the four
/// problematic tasks (weights FP32, current min-max bs=1).
pub fn table2(ctx: &Ctx, opts: &ExpOpts) -> Result<()> {
    let calib = CalibSpec {
        estimator: Estimator::CurrentMinMax,
        batch_size: 1,
        num_batches: 1,
        ..Default::default()
    };
    let off = SiteCfg { enabled: false, ..Default::default() };
    let base = |label: &str| {
        let mut spec = QuantSpec::new(label, PolicySpec::acts_only(8)).with_seeds(opts.seeds);
        spec.calib = calib.clone();
        spec
    };

    let mut specs = vec![presets::preset("fp32")?.named("none (FP32 model)")];
    specs.push(base("all"));
    for (label, family) in [
        ("all, except softmax input", "attn_scores"),
        ("all, except sum of embeddings", "embed_sum"),
        ("all, except self-attention output", "attn_out"),
        ("all, except softmax output", "attn_probs"),
        ("all, except residual sum after FFN", "res2_sum"),
    ] {
        specs.push(base(label).with_family(family, off.clone()));
    }
    specs.push(base("same, but last 2 layers only").with_rule(
        SiteSelector::FamilyLastLayers { suffix: "res2_sum".to_string(), n: 2 },
        off,
    ));
    spec_table(
        ctx,
        "table2",
        "Table 2: leave-one-out activation-quantizer ablation (W FP32)",
        "Quantized activations",
        &opts.hard_tasks(),
        specs,
        false,
    )
}

/// Table 4: mixed-precision PTQ — progressively keep problematic tensors
/// in 16 bits.
pub fn table4(ctx: &Ctx, opts: &ExpOpts) -> Result<()> {
    let a16 = SiteCfg { bits: 16, ..Default::default() };
    let stage = |label: &str, n: usize| {
        let mut spec = QuantSpec::new(label, PolicySpec::uniform(8, 8)).with_seeds(opts.seeds);
        if n >= 2 {
            spec = spec.with_family("res2_sum", a16.clone());
        }
        if n >= 3 {
            spec = spec
                .with_family("ln1_out", a16.clone())
                .with_family("ffn_out", a16.clone());
        }
        if n >= 4 {
            spec = spec
                .with_exact("head_out", a16.clone())
                .with_exact("pooled", a16.clone());
        }
        spec
    };
    let specs = vec![
        presets::preset("fp32")?.named("FP32"),
        stage("W8A8 PTQ", 1),
        stage("MP-PTQ (16b FFN residual sum)", 2),
        stage("MP-PTQ (+16b FFN in/out)", 3),
        stage("MP-PTQ (+16b final output)", 4),
    ];
    spec_table(
        ctx,
        "table4",
        "Table 4: mixed-precision PTQ (16-bit on problematic activations)",
        "Method",
        &opts.hard_tasks(),
        specs,
        false,
    )
}

/// Table 5: per-embedding-group PTQ vs number of groups K ± permutation.
/// With d=128 we map the paper's K ∈ {768, 6, 3} to {128 (=per-embd), 8, 4}.
pub fn table5(ctx: &Ctx, opts: &ExpOpts) -> Result<()> {
    let ffn_sites = ["ln1_out", "ffn_out", "res2_sum"];
    let mk = |label: &str, g: Granularity, only_ffn: bool| {
        let mut policy = PolicySpec::uniform(8, 8);
        if !only_ffn {
            policy.default_site.granularity = g.clone();
        }
        let mut spec = QuantSpec::new(label, policy).with_seeds(opts.seeds);
        if only_ffn {
            for fam in ffn_sites {
                spec = spec.with_family(
                    fam,
                    SiteCfg { granularity: g.clone(), ..Default::default() },
                );
            }
        }
        spec
    };
    let k = |k, permute| Granularity::PerEmbeddingGroup { k, permute };
    let specs = vec![
        presets::preset("fp32")?.named("FP32"),
        mk("1 (= per-tensor)", Granularity::PerTensor, false),
        mk("128 (= per-embd.)", Granularity::PerEmbedding, false),
        mk("128 (only FFN)", Granularity::PerEmbedding, true),
        mk("8 (only FFN)", k(8, false), true),
        mk("4 (only FFN)", k(4, false), true),
        mk("4 + P (only FFN)", k(4, true), true),
        mk("8 + P (only FFN)", k(8, true), true),
        // the paper's literal K values — near-even groups since 6,3 ∤ 128
        mk("3 + P (only FFN)", k(3, true), true),
        mk("6 + P (only FFN)", k(6, true), true),
    ];
    spec_table(
        ctx,
        "table5",
        "Table 5: per-embedding-group PTQ (d=128; incl. paper K=3,6 rows)",
        "#groups K",
        &opts.hard_tasks(),
        specs,
        false,
    )
}

/// Table 6: all methods compared on all 8 tasks (incl. W8A8 QAT).
pub fn table6(ctx: &Ctx, opts: &ExpOpts) -> Result<()> {
    let tasks = opts.tasks();
    let task_names: Vec<String> = tasks.iter().map(|t| t.name.to_string()).collect();
    let mut table = Table::new(
        "Table 6: 8-bit quantization methods",
        &["Method"]
            .into_iter()
            .chain(tasks.iter().map(|t| t.name))
            .chain(["GLUE"])
            .collect::<Vec<_>>(),
    );
    // every row — including QAT — is a preset spec; run_spec dispatches
    // the QAT rows to the training pipeline off their `qat` section
    let rows: Vec<(&str, &str)> = vec![
        ("FP32 baseline", "fp32"),
        ("W8A8 PTQ", "w8a8"),
        ("W8A{8,16} MP-PTQ", "mixed_precision"),
        ("W8A8 PEG-PTQ (K=8+P)", "peg_k8_permute"),
        ("W8A8 QAT", "w8a8_qat"),
    ];
    for (label, preset_name) in rows {
        let mut row = vec![label.to_string()];
        let mut spec = presets::preset(preset_name)?.named(label).with_tasks(&task_names);
        if spec.qat.is_none() {
            spec = spec.with_seeds(opts.seeds);
        }
        tune_qat_epochs(&mut spec, opts);
        let report = run_spec(ctx, &spec)?;
        row.extend(report.scores.iter().map(|&s| fmt_score(s)));
        row.push(fmt_score(report.glue));
        table.row(row);
    }
    finish(ctx, "table6", &table)
}

/// Full runs train the QAT rows for 2 epochs (the old hard-coded drivers'
/// value); `--quick` drops to 1.
fn tune_qat_epochs(spec: &mut QuantSpec, opts: &ExpOpts) {
    if let Some(q) = spec.qat.as_mut() {
        q.epochs = if opts.quick { 1 } else { 2 };
    }
}

/// Table 7 (+ Table 12 detail): low-bit weights & token embeddings.
pub fn table7(ctx: &Ctx, opts: &ExpOpts, detailed: bool) -> Result<()> {
    let tasks = opts.tasks();
    let task_names: Vec<String> = tasks.iter().map(|t| t.name.to_string()).collect();
    let mut header: Vec<&str> = vec!["Method", "Mem"];
    let names: Vec<&str> = tasks.iter().map(|t| t.name).collect();
    if detailed {
        header.extend(names.iter());
    }
    header.push("GLUE");
    let mut table = Table::new(
        "Table 7: low-bit weight & token-embedding quantization",
        &header,
    );

    struct Row {
        label: &'static str,
        wb: u32,
        eb: u32,
        est: Estimator,
        ada: bool,
        qat: bool,
        act8: bool,
        act_off: bool,
        w_off: bool,
    }
    let rows = vec![
        Row { label: "FP32 baseline", wb: 32, eb: 32, est: Estimator::CurrentMinMax, ada: false, qat: false, act8: false, act_off: true, w_off: true },
        Row { label: "W8A32, 6-bit embd. PTQ", wb: 8, eb: 6, est: Estimator::Mse, ada: false, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W8A32, 4-bit embd. PTQ", wb: 8, eb: 4, est: Estimator::Mse, ada: false, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W8A32, 2-bit embd. PTQ", wb: 8, eb: 2, est: Estimator::Mse, ada: false, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W6A32 PTQ", wb: 6, eb: 6, est: Estimator::Mse, ada: false, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W4A32 PTQ", wb: 4, eb: 4, est: Estimator::Mse, ada: false, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W4A32 AdaRound (PTQ)", wb: 4, eb: 4, est: Estimator::Mse, ada: true, qat: false, act8: false, act_off: true, w_off: false },
        Row { label: "W4A32 QAT", wb: 4, eb: 4, est: Estimator::Mse, ada: false, qat: true, act8: false, act_off: true, w_off: false },
        Row { label: "W4A8 QAT", wb: 4, eb: 4, est: Estimator::Mse, ada: false, qat: true, act8: true, act_off: false, w_off: false },
        Row { label: "W4A8, 2-bit embd. QAT", wb: 4, eb: 2, est: Estimator::Mse, ada: false, qat: true, act8: true, act_off: false, w_off: false },
    ];

    // memory ratios come from one checkpoint load up front — parameter
    // sizes are task-independent, so per-row reloads would be waste
    let mem_basis = match tasks.first() {
        Some(task) => Some((load_ckpt(ctx, task)?, ctx.model_info(task)?)),
        None => None,
    };
    for r in rows {
        let mem = match &mem_basis {
            Some((params, info)) => {
                let fp32 = params.size_bytes(info, 32, 32) as f64;
                let q = params.size_bytes(info, r.wb.min(32), r.eb.min(32)) as f64;
                format!("x{:.2}", fp32 / q)
            }
            None => String::new(),
        };
        let scores: Vec<f64> = if r.qat {
            // QAT rows are preset specs too — run_spec dispatches them to
            // the training pipeline off their `qat` section
            let preset_name = match (r.act8, r.eb) {
                (false, _) => "w4a32_qat",
                (true, 2) => "w4a8_embed2_qat",
                (true, _) => "w4a8_qat",
            };
            let mut spec =
                presets::preset(preset_name)?.named(r.label).with_tasks(&task_names);
            tune_qat_epochs(&mut spec, opts);
            run_spec(ctx, &spec)?.scores
        } else {
            let mut policy = if r.act_off && r.w_off {
                PolicySpec::fp32()
            } else {
                let mut p = if r.act_off {
                    PolicySpec::weights_only(8)
                } else {
                    PolicySpec::uniform(8, 8)
                };
                p.weights = WeightCfg { bits: r.wb, estimator: r.est, ..Default::default() };
                p
            };
            if !r.w_off {
                policy.weight_overrides.insert(
                    "embed.tok".to_string(),
                    WeightCfg { bits: r.eb, estimator: Estimator::Mse, ..Default::default() },
                );
            }
            let mut spec = QuantSpec::new(r.label, policy)
                .with_seeds(if r.ada { 1 } else { opts.seeds })
                .with_tasks(&task_names);
            spec.calib.collect_grams = r.ada;
            spec.adaround.enabled = r.ada;
            if opts.quick {
                spec.adaround.iters = 200;
            }
            run_spec(ctx, &spec)?.scores
        };
        let mut row = vec![r.label.to_string(), mem];
        if detailed {
            row.extend(scores.iter().map(|&s| fmt_score(s)));
        }
        row.push(fmt_score(glue_score(&scores)));
        table.row(row);
    }
    finish(ctx, if detailed { "table12" } else { "table7" }, &table)
}

/// Fig. 2: FFN input/output per-token ranges + outlier maps (deep layer).
pub fn fig2(ctx: &Ctx, _opts: &ExpOpts) -> Result<()> {
    let task = ctx.task("mnli")?;
    let params = load_ckpt(ctx, &task)?;
    let info = ctx.model_info(&task)?;
    let layer = info.config.layers - 1;
    let runs = diag::collect_taps(ctx, &task, &params, 10)?;

    let mut out = String::new();
    out.push_str(&format!(
        "# Fig. 2 reproduction — layer {layer} FFN input vs output (task mnli-sim)\n\n"
    ));
    // (a) per-token ranges, first sequence
    let ex = &runs.examples[0];
    for (name, site) in [("FFN input", format!("layer{layer}.ln1_out")),
                         ("FFN output", format!("layer{layer}.ffn_out"))] {
        let (lo, hi) = diag::per_token_ranges(&runs.per_seq[0], &site, &ex.mask);
        let ranges: Vec<f32> = lo.iter().zip(&hi).map(|(l, h)| h - l).collect();
        let labels: Vec<String> = ex
            .ids
            .iter()
            .take(ranges.len())
            .enumerate()
            .map(|(i, &id)| {
                if info.config.arch.sep_id() == Some(id) {
                    format!("[SEP]{i:>3}")
                } else if info.config.arch.cls_id() == Some(id) {
                    format!("[CLS]{i:>3}")
                } else {
                    format!("{i:>8}")
                }
            })
            .collect();
        out.push_str(&format!(
            "## (a) {name} per-token range  (tensor range [{:.2}, {:.2}])\n```\n{}```\n",
            runs.per_seq[0][&site].min(),
            runs.per_seq[0][&site].max(),
            crate::report::bar_chart(&ranges, 48, Some(&labels)),
        ));
    }
    // (b) outlier maps over 10 sequences
    for (name, site) in [("FFN input", format!("layer{layer}.ln1_out")),
                         ("FFN output", format!("layer{layer}.ffn_out"))] {
        out.push_str(&format!("## (b) {name} >6σ outlier map (rows=tokens, cols=dims)\n"));
        for (s, taps) in runs.per_seq.iter().enumerate() {
            let (mask, rows, d) = diag::outlier_mask(taps, &site);
            let n_out = mask.iter().filter(|&&b| b).count();
            out.push_str(&format!("```\nseq {s} ({n_out} outliers)\n{}```\n",
                crate::report::bool_heatmap(&mask, rows, d, 128)));
        }
        let dims = diag::consistent_outlier_dims(&runs, &site, 6);
        out.push_str(&format!(
            "consistent outlier dims (>=6/10 seqs): {dims:?} (installed: {:?})\n\n",
            info.config.outlier_dims
        ));
    }
    println!("{out}");
    write_file(ctx.results_dir.join("fig2.md"), &out)?;
    Ok(())
}

/// Fig. 5: attention-to-[SEP] mass per head in the deepest layers.
pub fn fig5(ctx: &Ctx, _opts: &ExpOpts) -> Result<()> {
    let task = ctx.task("mnli")?;
    let params = load_ckpt(ctx, &task)?;
    let info = ctx.model_info(&task)?;
    let runs = diag::collect_taps(ctx, &task, &params, 4)?;

    let mut table = Table::new(
        "Fig. 5: mean attention mass on [SEP] per head (4 dev sequences)",
        &["layer"]
            .into_iter()
            .chain((0..info.config.heads).map(|_h| "head"))
            .collect::<Vec<_>>(),
    );
    for layer in 0..info.config.layers {
        let mut acc = vec![0f32; info.config.heads];
        for (taps, ex) in runs.per_seq.iter().zip(&runs.examples) {
            let m = diag::attention_sep_mass(info, taps, ex, layer);
            for (a, b) in acc.iter_mut().zip(m) {
                *a += b;
            }
        }
        let row: Vec<String> = std::iter::once(format!("{layer}"))
            .chain(acc.iter().map(|&x| format!("{:.3}", x / runs.per_seq.len() as f32)))
            .collect();
        table.row(row);
    }
    finish(ctx, "fig5", &table)
}

/// Fig. 6-8: outlier maps for every layer (we render the FFN output site).
pub fn fig6(ctx: &Ctx, _opts: &ExpOpts) -> Result<()> {
    let mut out = String::new();
    for tname in ["mnli", "stsb", "mrpc"] {
        let task = ctx.task(tname)?;
        let params = load_ckpt(ctx, &task)?;
        let info = ctx.model_info(&task)?;
        let runs = diag::collect_taps(ctx, &task, &params, 10)?;
        out.push_str(&format!("# Fig. 6-8 reproduction — task {tname}\n"));
        for layer in 0..info.config.layers {
            for (label, site) in [("in", format!("layer{layer}.ln1_out")),
                                  ("out", format!("layer{layer}.ffn_out"))] {
                let dims = diag::consistent_outlier_dims(&runs, &site, 6);
                out.push_str(&format!("layer {layer} FFN {label}: consistent outlier dims {dims:?}\n"));
            }
        }
        out.push('\n');
    }
    println!("{out}");
    write_file(ctx.results_dir.join("fig6.md"), &out)?;
    Ok(())
}

/// Fig. 9-13: FFN in/out ranges across architecture variants. Variants are
/// fine-tuned briefly on mnli-sim via their own train artifacts when
/// available, else evaluated at init (documented in the output).
pub fn fig9(ctx: &Ctx, _opts: &ExpOpts) -> Result<()> {
    let task = ctx.task("mnli")?;
    let mut out = String::new();
    out.push_str("# Fig. 9-13 reproduction — FFN input/output ranges across architectures\n\n");
    for variant in ["base", "large", "distil", "mobile"] {
        let info = ctx.rt.manifest().model(variant)?;
        let artifact = format!("diag_{}_b1", if variant == "base" { "cls".into() } else { variant.to_string() });
        // base uses the fine-tuned mnli checkpoint; variants fine-tune via
        // their own train artifact if present (train_fp32_<variant>_b16)
        let params = if variant == "base" {
            load_ckpt(ctx, &task)?
        } else {
            match super::train::finetune_variant(ctx, variant, &task, 1) {
                Ok(p) => p,
                Err(e) => {
                    out.push_str(&format!("({variant}: using init params — {e})\n"));
                    Params::init(info, 1)
                }
            }
        };
        let layer = info.config.layers.saturating_sub(2);
        let runs = diag::collect_taps_with(ctx, &artifact, info, &task, &params, 5)?;
        for (label, site) in [("input", format!("layer{layer}.ln1_out")),
                              ("output", format!("layer{layer}.ffn_out"))] {
            let ranges = diag::per_sequence_ranges(&runs, &site);
            let spans: Vec<f32> = ranges.iter().map(|(lo, hi)| hi - lo).collect();
            out.push_str(&format!(
                "{variant:>7} layer {layer} FFN {label:>6}: per-seq ranges {:?}\n",
                spans.iter().map(|s| format!("{s:.1}")).collect::<Vec<_>>()
            ));
        }
        out.push('\n');
    }
    println!("{out}");
    write_file(ctx.results_dir.join("fig9.md"), &out)?;
    Ok(())
}

/// Appendix Tables 8-11: the hyper-parameter search spaces (documentation
/// tables, emitted for completeness).
pub fn hparams(ctx: &Ctx) -> Result<()> {
    let mut t8 = Table::new(
        "Table 8 (analog): FP32 fine-tuning hyper-parameters",
        &["Task", "LR", "Batch", "Epochs", "aux λ", "aux target"],
    );
    for task in &TASKS {
        t8.row(vec![
            task.name.into(),
            "1e-3".into(),
            "16".into(),
            "3".into(),
            "1.0".into(),
            "12.0".into(),
        ]);
    }
    let mut t10 = Table::new(
        "Table 10 (analog): W8A8 QAT hyper-parameters",
        &["Task", "LR", "LR(scales)", "Batch", "Epochs"],
    );
    for task in &TASKS {
        t10.row(vec![task.name.into(), "1e-4".into(), "1e-5".into(), "16".into(), "2".into()]);
    }
    print!("{}", t8.to_console());
    print!("{}", t10.to_console());
    write_file(
        ctx.results_dir.join("hparams.md"),
        &format!("{}\n{}", t8.to_markdown(), t10.to_markdown()),
    )?;
    Ok(())
}

fn finish(ctx: &Ctx, name: &str, table: &Table) -> Result<()> {
    print!("{}", table.to_console());
    write_file(ctx.results_dir.join(format!("{name}.md")), &table.to_markdown())?;
    write_file(ctx.results_dir.join(format!("{name}.csv")), &table.to_csv())?;
    Ok(())
}

/// Re-export for examples: a full PTQ pass on one task returning
/// (fp32, w8a8, peg, mp) scores — each a preset spec routed through
/// `run_spec`.
pub fn quick_compare(ctx: &Ctx, task_name: &str, seeds: usize) -> Result<[f64; 4]> {
    let tasks = vec![task_name.to_string()];
    let mut out = [0.0f64; 4];
    for (slot, name) in out
        .iter_mut()
        .zip(["fp32", "w8a8", "peg_k8_permute", "mixed_precision"])
    {
        let spec = presets::preset(name)?.with_seeds(seeds).with_tasks(&tasks);
        let report = run_spec(ctx, &spec)?;
        *slot = report.scores[0];
    }
    Ok(out)
}

/// Calibration+assembly helper reused by examples/benches.
pub fn ptq_act_tensors(
    ctx: &Ctx,
    task: &TaskSpec,
    params: &Params,
    policy: &QuantPolicy,
) -> Result<(ActQuantTensors, Calibration)> {
    let calib = calibrate(ctx, task, params, &CalibCfg::default())?;
    let info = ctx.model_info(task)?;
    let act = assemble_act_tensors(info, policy, &calib.trackers)?;
    Ok((act, calib))
}
