//! The coordinator: the paper's quantization pipeline as a Rust system.
//!
//! Sub-modules:
//! * [`train`]       — FP32 fine-tuning (with the outlier-inducing aux loss)
//!                     and QAT, driving the AOT train-step executables.
//! * [`calibrate`]   — calibration runner: streams sequences through the
//!                     diagnostic executable and feeds range estimators.
//! * [`eval`]        — dev-set evaluation via the forward executables.
//! * [`weights`]     — Rust-side weight PTQ: min-max/MSE/per-channel/
//!                     AdaRound quantize-dequantize of parameter tensors.
//! * [`diagnostics`] — paper Fig. 2/5/6-13 data extraction.
//! * [`experiments`] — `repro table1` ... drivers regenerating every paper
//!                     table & figure; each PTQ driver is a list of
//!                     `crate::spec::QuantSpec`s plus a formatter.
//! * [`sweep`]       — parallel experiment-sweep engine: bits ×
//!                     granularity × estimator grids executed concurrently
//!                     on the `util::pool` workers, keyed by `spec_id` for
//!                     resumable runs and `--compare` regression gating.
//!
//! The calibrate → weight-QDQ → assemble → eval sequence itself lives in
//! `crate::spec::run` so every surface (tables, sweeps, `repro run`)
//! executes configurations identically.

pub mod calibrate;
pub mod diagnostics;
pub mod eval;
pub mod experiments;
pub mod sweep;
pub mod train;
pub mod weights;

use std::path::PathBuf;

use anyhow::Result;

use crate::data::{task_spec, Batch, TaskKind, TaskSpec};
use crate::model::manifest::ModelInfo;
use crate::model::Params;
use crate::runtime::{lit_f32, lit_i32, Runtime};
use crate::util::pool::Pool;

/// Executable batch capacity of the forward artifacts (`fwd_*_b8`) — the
/// row count every forward batch is padded to. One constant shared by
/// eval and the serving layer so both address the same artifacts.
pub const EVAL_BATCH: usize = 8;

/// Build the static input literals every forward/diag artifact shares, in
/// signature order: parameter tensors, then activation-quantizer scales,
/// zero-points, and the per-site `[qmin, qmax, enabled]` cfg rows. The
/// signature order is a cross-file contract with the AOT graphs — keep
/// every caller on this one builder.
pub fn static_input_lits(
    params: &Params,
    scales: &[f32],
    zps: &[f32],
    cfg: &[f32],
    n_sites: usize,
) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::with_capacity(params.tensors.len() + 3);
    for t in &params.tensors {
        lits.push(lit_f32(t.data(), t.shape())?);
    }
    lits.push(lit_f32(scales, &[scales.len()])?);
    lits.push(lit_f32(zps, &[zps.len()])?);
    lits.push(lit_f32(cfg, &[n_sites, 3])?);
    Ok(lits)
}

/// Build one forward batch's per-call input literals, in signature order
/// after the statics: token ids, token types, attention mask. The other
/// half of the forward-input contract next to [`static_input_lits`] —
/// dev-set eval and the serving layer assemble batches through this one
/// builder, which is what makes serve-vs-direct bit-identity structural
/// (tests/determinism.rs pins it).
pub fn batch_input_lits(batch: &Batch) -> Result<Vec<xla::Literal>> {
    let (b, seq) = (batch.batch, batch.seq);
    Ok(vec![
        lit_i32(&batch.ids, &[b, seq])?,
        lit_i32(&batch.token_type, &[b, seq])?,
        lit_f32(&batch.mask, &[b, seq])?,
    ])
}

/// Shared context for all pipeline stages.
pub struct Ctx {
    pub rt: Runtime,
    pub ckpt_dir: PathBuf,
    pub results_dir: PathBuf,
    /// Worker pool for the executable hot loop (batch-parallel
    /// calibrate/eval via `Runtime::run_batch`) and the per-site
    /// statistics kernels. Defaults to the shared persistent
    /// [`Pool::global`]; tests pin it with [`Ctx::with_pool`] to compare
    /// serial vs parallel runs bit-for-bit in one process.
    pub pool: Pool,
}

impl Ctx {
    pub fn new(artifacts_dir: &str, ckpt_dir: &str, results_dir: &str) -> Result<Ctx> {
        Ok(Ctx {
            rt: Runtime::new(artifacts_dir)?,
            ckpt_dir: PathBuf::from(ckpt_dir),
            results_dir: PathBuf::from(results_dir),
            pool: Pool::global().clone(),
        })
    }

    /// Replace the hot-loop pool (builder style).
    pub fn with_pool(mut self, pool: Pool) -> Ctx {
        self.pool = pool;
        self
    }

    /// Head kind string for artifact names: "cls" or "reg".
    pub fn head(&self, task: &TaskSpec) -> &'static str {
        match task.kind {
            TaskKind::Regression => "reg",
            TaskKind::Classification(_) => "cls",
        }
    }

    /// Model info for a task's head (regression heads have n_out = 1).
    pub fn model_info(&self, task: &TaskSpec) -> Result<&ModelInfo> {
        match task.kind {
            TaskKind::Regression => self.rt.manifest().model("base_reg"),
            _ => self.rt.manifest().model("base"),
        }
    }

    pub fn task(&self, name: &str) -> Result<TaskSpec> {
        task_spec(name)
    }

    pub fn ckpt_path(&self, task: &str) -> PathBuf {
        self.ckpt_dir.join(format!("{task}.ckpt"))
    }
}
