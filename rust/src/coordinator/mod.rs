//! The coordinator: the paper's quantization pipeline as a Rust system.
//!
//! Sub-modules:
//! * [`train`]       — FP32 fine-tuning (with the outlier-inducing aux loss)
//!                     and QAT, driving the AOT train-step executables.
//! * [`calibrate`]   — calibration runner: streams sequences through the
//!                     diagnostic executable and feeds range estimators.
//! * [`eval`]        — dev-set evaluation via the forward executables.
//! * [`weights`]     — Rust-side weight PTQ: min-max/MSE/per-channel/
//!                     AdaRound quantize-dequantize of parameter tensors.
//! * [`diagnostics`] — paper Fig. 2/5/6-13 data extraction.
//! * [`experiments`] — `repro table1` ... drivers regenerating every paper
//!                     table & figure; each PTQ driver is a list of
//!                     `crate::spec::QuantSpec`s plus a formatter.
//! * [`sweep`]       — parallel experiment-sweep engine: bits ×
//!                     granularity × estimator grids executed concurrently
//!                     on the `util::pool` workers, keyed by `spec_id` for
//!                     resumable runs and `--compare` regression gating.
//!
//! The calibrate → weight-QDQ → assemble → eval sequence itself lives in
//! `crate::spec::run` so every surface (tables, sweeps, `repro run`)
//! executes configurations identically.

pub mod calibrate;
pub mod diagnostics;
pub mod eval;
pub mod experiments;
pub mod sweep;
pub mod train;
pub mod weights;

use std::path::PathBuf;

use anyhow::Result;

use crate::data::{pixels_for_ids, task_spec, Batch, TaskKind, TaskSpec};
use crate::model::manifest::{family_prefix, model_name, Architecture, AttnVariant, ModelInfo};
use crate::model::Params;
use crate::runtime::{lit_f32, lit_i32, Runtime};
use crate::util::pool::Pool;

/// Executable batch capacity of the forward artifacts (`fwd_*_b8`) — the
/// row count every forward batch is padded to. One constant shared by
/// eval and the serving layer so both address the same artifacts.
pub const EVAL_BATCH: usize = 8;

/// Build the static input literals every forward/diag artifact shares, in
/// signature order: parameter tensors, then activation-quantizer scales,
/// zero-points, and the per-site `[qmin, qmax, enabled]` cfg rows. The
/// signature order is a cross-file contract with the AOT graphs — keep
/// every caller on this one builder.
pub fn static_input_lits(
    params: &Params,
    scales: &[f32],
    zps: &[f32],
    cfg: &[f32],
    n_sites: usize,
) -> Result<Vec<xla::Literal>> {
    let mut lits = Vec::with_capacity(params.tensors.len() + 3);
    for t in &params.tensors {
        lits.push(lit_f32(t.data(), t.shape())?);
    }
    lits.push(lit_f32(scales, &[scales.len()])?);
    lits.push(lit_f32(zps, &[zps.len()])?);
    lits.push(lit_f32(cfg, &[n_sites, 3])?);
    Ok(lits)
}

/// Build one forward batch's per-call input literals, in signature order
/// after the statics: token ids, token types, attention mask. The other
/// half of the forward-input contract next to [`static_input_lits`] —
/// dev-set eval and the serving layer assemble batches through this one
/// builder, which is what makes serve-vs-direct bit-identity structural
/// (tests/determinism.rs pins it). BERT graphs only; arch-dispatching
/// callers go through [`batch_input_lits_for`].
pub fn batch_input_lits(batch: &Batch) -> Result<Vec<xla::Literal>> {
    let (b, seq) = (batch.batch, batch.seq);
    Ok(vec![
        lit_i32(&batch.ids, &[b, seq])?,
        lit_i32(&batch.token_type, &[b, seq])?,
        lit_f32(&batch.mask, &[b, seq])?,
    ])
}

/// Architecture-dispatching batch-literal builder: BERT graphs take the
/// three token tensors; ViT graphs take one pixel tensor, rasterised from
/// the same token ids through the fixed `data::pixel_codebook`. Keyed off
/// the manifest's architecture descriptor so calibrate/eval never
/// hard-code a frontend.
pub fn batch_input_lits_for(info: &ModelInfo, batch: &Batch) -> Result<Vec<xla::Literal>> {
    match info.config.architecture() {
        Architecture::Bert => batch_input_lits(batch),
        Architecture::Vit => {
            let pd = info
                .config
                .patch_dim()
                .ok_or_else(|| anyhow::anyhow!("vit model {} lacks a patch size", info.name))?;
            let px = pixels_for_ids(&batch.ids, pd);
            Ok(vec![lit_f32(&px, &[batch.batch, batch.seq, pd])?])
        }
    }
}

/// Batch-1 input literals for one example — the batch-1 sibling of
/// [`batch_input_lits_for`], used by the diag executables (calibration,
/// diagnostics). Dispatches on the manifest's architecture descriptor.
pub fn example_input_lits(
    info: &ModelInfo,
    ex: &crate::data::Example,
) -> Result<Vec<xla::Literal>> {
    let seq = info.config.seq;
    match info.config.architecture() {
        Architecture::Bert => Ok(vec![
            lit_i32(&ex.ids, &[1, seq])?,
            lit_i32(&ex.token_type, &[1, seq])?,
            lit_f32(&ex.mask, &[1, seq])?,
        ]),
        Architecture::Vit => {
            let pd = info
                .config
                .patch_dim()
                .ok_or_else(|| anyhow::anyhow!("vit model {} lacks a patch size", info.name))?;
            Ok(vec![lit_f32(&pixels_for_ids(&ex.ids, pd), &[1, seq, pd])?])
        }
    }
}

/// Artifact name of the batch-`b` forward executable for an architecture
/// and head kind — the naming contract with `repro gen-artifacts`
/// (`fwd_cls_b8`, `fwd_vit_cls_b8`, ...). Vanilla-attention shorthand for
/// [`fwd_artifact_var`].
pub fn fwd_artifact(arch: Architecture, head: &str, b: usize) -> String {
    fwd_artifact_var(arch, AttnVariant::Vanilla, head, b)
}

/// [`fwd_artifact`] for a specific attention variant: the family prefix
/// covers both axes (`fwd_csoft_cls_b8`, `fwd_vit_gate_cls_b8`, ...).
pub fn fwd_artifact_var(
    arch: Architecture,
    variant: AttnVariant,
    head: &str,
    b: usize,
) -> String {
    format!("fwd_{}{head}_b{b}", family_prefix(arch, variant))
}

/// Artifact name of the tapped diagnostic executable (batch 1).
pub fn diag_artifact(arch: Architecture, head: &str) -> String {
    diag_artifact_var(arch, AttnVariant::Vanilla, head)
}

/// [`diag_artifact`] for a specific attention variant.
pub fn diag_artifact_var(arch: Architecture, variant: AttnVariant, head: &str) -> String {
    format!("diag_{}{head}_b1", family_prefix(arch, variant))
}

/// Shared context for all pipeline stages.
pub struct Ctx {
    pub rt: Runtime,
    pub ckpt_dir: PathBuf,
    pub results_dir: PathBuf,
    /// Worker pool for the executable hot loop (batch-parallel
    /// calibrate/eval via `Runtime::run_batch`) and the per-site
    /// statistics kernels. Defaults to the shared persistent
    /// [`Pool::global`]; tests pin it with [`Ctx::with_pool`] to compare
    /// serial vs parallel runs bit-for-bit in one process.
    pub pool: Pool,
}

impl Ctx {
    pub fn new(artifacts_dir: &str, ckpt_dir: &str, results_dir: &str) -> Result<Ctx> {
        Ok(Ctx {
            rt: Runtime::new(artifacts_dir)?,
            ckpt_dir: PathBuf::from(ckpt_dir),
            results_dir: PathBuf::from(results_dir),
            pool: Pool::global().clone(),
        })
    }

    /// Replace the hot-loop pool (builder style).
    pub fn with_pool(mut self, pool: Pool) -> Ctx {
        self.pool = pool;
        self
    }

    /// Head kind string for artifact names: "cls" or "reg".
    pub fn head(&self, task: &TaskSpec) -> &'static str {
        match task.kind {
            TaskKind::Regression => "reg",
            TaskKind::Classification(_) => "cls",
        }
    }

    /// Model info for a task's head (regression heads have n_out = 1),
    /// BERT family. Arch-generic callers use [`Ctx::model_info_for`].
    pub fn model_info(&self, task: &TaskSpec) -> Result<&ModelInfo> {
        self.model_info_for(task, Architecture::Bert)
    }

    /// Model info for a task's head in a given architecture family — the
    /// manifest naming contract with `repro gen-artifacts` ("base",
    /// "base_reg", "vit", "vit_reg").
    pub fn model_info_for(&self, task: &TaskSpec, arch: Architecture) -> Result<&ModelInfo> {
        self.model_info_var(task, arch, AttnVariant::Vanilla)
    }

    /// [`Ctx::model_info_for`] for a specific attention variant
    /// ("bert_csoft", "vit_gate_reg", ... — see
    /// [`crate::model::manifest::model_name`]).
    pub fn model_info_var(
        &self,
        task: &TaskSpec,
        arch: Architecture,
        variant: AttnVariant,
    ) -> Result<&ModelInfo> {
        let regression = matches!(task.kind, TaskKind::Regression);
        self.rt.manifest().model(&model_name(arch, variant, regression))
    }

    pub fn task(&self, name: &str) -> Result<TaskSpec> {
        task_spec(name)
    }

    pub fn ckpt_path(&self, task: &str) -> PathBuf {
        self.ckpt_path_for(task, Architecture::Bert)
    }

    /// Checkpoint path for a task in a given architecture family
    /// (`{task}.ckpt` / `vit_{task}.ckpt` — the gen-artifacts contract).
    pub fn ckpt_path_for(&self, task: &str, arch: Architecture) -> PathBuf {
        self.ckpt_path_var(task, arch, AttnVariant::Vanilla)
    }

    /// [`Ctx::ckpt_path_for`] for a specific attention variant
    /// (`csoft_{task}.ckpt`, `vit_gate_{task}.ckpt`, ...).
    pub fn ckpt_path_var(
        &self,
        task: &str,
        arch: Architecture,
        variant: AttnVariant,
    ) -> PathBuf {
        self.ckpt_dir
            .join(format!("{}{task}.ckpt", family_prefix(arch, variant)))
    }
}
