//! Training loops: FP32 fine-tuning (paper Appendix B.1, plus the
//! outlier-inducing auxiliary loss of DESIGN.md §2) and quantization-aware
//! training (paper §4) — both executed step-by-step through the AOT
//! train-step executables; the Rust side owns batching, the LR schedule
//! (linear warmup 10% → linear decay, as in the paper), Adam bias
//! correction, and checkpointing.

use anyhow::{bail, Result};

use super::Ctx;
use crate::data::{self, TaskKind, TaskSpec};
use crate::model::manifest::ModelInfo;
use crate::model::qconfig::ActQuantTensors;
use crate::model::Params;
use crate::quant::QGrid;
use crate::runtime::{lit_f32, lit_i32, lit_scalar};
use crate::util::rng::Rng;

const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;

/// Hyper-parameters for a fine-tuning run.
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub lr: f32,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
    /// outlier-inducing auxiliary loss weight & target (FP32 only)
    pub aux_lambda: f32,
    pub aux_target: f32,
    pub log_every: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            lr: 2e-3,
            epochs: 5,
            batch: 16,
            seed: 1,
            aux_lambda: 0.01,
            aux_target: 10.0,
            log_every: 100,
        }
    }
}

/// Outlier-inducing aux weight schedule: off for the first 40% of
/// training (pure task learning), linear ramp over the next 15%, then
/// sustained — the model keeps the last 45% of the schedule to re-adapt
/// around the installed outliers (tuned in EXPERIMENTS.md §Setup).
pub fn aux_lambda_at(max_lambda: f32, step: usize, total: usize) -> f32 {
    let frac = step as f32 / total.max(1) as f32;
    if frac < 0.4 {
        0.0
    } else {
        max_lambda * ((frac - 0.4) / 0.15).min(1.0)
    }
}

/// LR schedule value at `step` of `total`: linear warmup over the first
/// 10%, then linear decay to zero (paper Appendix B.1), multiplied by the
/// Adam bias correction for step t (1-based).
pub fn lr_eff(base: f32, step: usize, total: usize) -> f32 {
    let warmup = (total as f32 * 0.1).max(1.0);
    let s = step as f32;
    let sched = if s < warmup {
        (s + 1.0) / warmup
    } else {
        ((total as f32 - s) / (total as f32 - warmup)).max(0.0)
    };
    let t = (step + 1) as i32;
    let bias = ((1.0 - ADAM_B2.powi(t)) as f32).sqrt() / (1.0 - ADAM_B1.powi(t)) as f32;
    base * sched * bias
}

pub struct TrainResult {
    pub params: Params,
    pub losses: Vec<f32>,
}

/// FP32 fine-tune `task` from scratch; returns trained parameters and the
/// per-step loss curve.
pub fn finetune(ctx: &Ctx, task: &TaskSpec, cfg: &TrainCfg) -> Result<TrainResult> {
    let info = ctx.model_info(task)?;
    let artifact = format!("train_fp32_{}_b16", ctx.head(task));
    finetune_with(ctx, info, &artifact, task, cfg)
}

/// Fine-tune an architecture variant (large/distil/mobile) on a
/// classification task via its own train artifact, caching the checkpoint.
/// Used by the Fig. 9-13 architecture sweep.
pub fn finetune_variant(
    ctx: &Ctx,
    variant: &str,
    task: &TaskSpec,
    epochs: usize,
) -> Result<Params> {
    if matches!(task.kind, TaskKind::Regression) {
        bail!("variant fine-tuning supports classification tasks only");
    }
    let path = ctx.ckpt_dir.join(format!("{}_{}.ckpt", variant, task.name));
    if let Ok(p) = crate::model::checkpoint::load(&path) {
        return Ok(p);
    }
    let info = ctx.rt.manifest().model(variant)?;
    let artifact = format!("train_fp32_{variant}_b16");
    // ensure the artifact exists before training
    ctx.rt.manifest().artifact(&artifact)?;
    let cfg = TrainCfg { epochs, ..Default::default() };
    let res = finetune_with(ctx, info, &artifact, task, &cfg)?;
    crate::model::checkpoint::save(&res.params, &path)?;
    Ok(res.params)
}

fn finetune_with(
    ctx: &Ctx,
    info: &ModelInfo,
    artifact: &str,
    task: &TaskSpec,
    cfg: &TrainCfg,
) -> Result<TrainResult> {
    if cfg.batch != 16 {
        bail!("train artifacts are lowered at batch 16");
    }
    let seq = info.config.seq;
    let split = data::train_split(task, seq)?;

    let mut p = Params::init(info, cfg.seed);
    let mut m = p.zeros_like();
    let mut v = p.zeros_like();
    let np = p.tensors.len();

    let steps_per_epoch = split.examples.len() / cfg.batch;
    let total = steps_per_epoch * cfg.epochs;
    let mut losses = Vec::with_capacity(total);
    let mut order: Vec<usize> = (0..split.examples.len()).collect();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);

    let regression = matches!(task.kind, TaskKind::Regression);
    let mut step = 0usize;
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for chunk in order.chunks_exact(cfg.batch) {
            let batch = gather_batch(&split, chunk, seq);
            // the train step itself is inherently serial (step k+1 needs
            // step k's params), but the per-step literal assembly — one
            // memcpy per param/optimizer tensor — is independent per
            // tensor, so it fans out across the pool deterministically
            let pmv: Vec<_> =
                p.tensors.iter().chain(&m.tensors).chain(&v.tensors).collect();
            let mut lits: Vec<xla::Literal> = ctx
                .pool
                .par_map(&pmv, |_, t| lit_f32(t.data(), t.shape()))
                .into_iter()
                .collect::<Result<_>>()?;
            lits.reserve(7);
            lits.push(lit_i32(&batch.ids, &[cfg.batch, seq])?);
            lits.push(lit_i32(&batch.token_type, &[cfg.batch, seq])?);
            lits.push(lit_f32(&batch.mask, &[cfg.batch, seq])?);
            if regression {
                lits.push(lit_f32(&batch.labels_reg, &[cfg.batch])?);
            } else {
                lits.push(lit_i32(&batch.labels_cls, &[cfg.batch])?);
            }
            lits.push(lit_scalar(lr_eff(cfg.lr, step, total))?);
            lits.push(lit_scalar(aux_lambda_at(cfg.aux_lambda, step, total))?);
            lits.push(lit_scalar(cfg.aux_target)?);

            let mut out = ctx.rt.run_lits(artifact, &lits)?;
            let loss = out.pop().expect("loss output").data()[0];
            losses.push(loss);
            // outputs: params, m, v (in spec order), then loss (popped)
            let vs = out.split_off(2 * np);
            let ms = out.split_off(np);
            p.tensors = out;
            m.tensors = ms;
            v.tensors = vs;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                println!(
                    "  [{}] step {step}/{total} loss {loss:.4} lr_eff {:.2e}",
                    task.name,
                    lr_eff(cfg.lr, step, total)
                );
            }
            step += 1;
            if !loss.is_finite() {
                bail!("{}: loss diverged at step {step}", task.name);
            }
        }
    }
    Ok(TrainResult { params: p, losses })
}

/// QAT hyper-parameters (paper Appendix B.3).
#[derive(Debug, Clone)]
pub struct QatCfg {
    pub lr: f32,
    /// learning rate for the quantizer scales (LSQ)
    pub lr_scales: f32,
    pub epochs: usize,
    pub batch: usize,
    pub seed: u64,
    pub weight_bits: u32,
    pub embed_bits: u32,
    pub act_enabled: bool,
    pub log_every: usize,
}

impl Default for QatCfg {
    fn default() -> Self {
        QatCfg {
            lr: 1e-4,
            lr_scales: 1e-5,
            epochs: 1,
            batch: 16,
            seed: 1,
            weight_bits: 8,
            embed_bits: 8,
            act_enabled: true,
            log_every: 50,
        }
    }
}

pub struct QatResult {
    pub params: Params,
    /// learned activation scales (flat lanes vector)
    pub act_scales: Vec<f32>,
    /// learned per-weight-tensor scales
    pub wq_scales: Vec<f32>,
    pub losses: Vec<f32>,
}

/// Quantization-aware training from a PTQ-initialised state (paper §4:
/// "we initialize all quantization parameters from the PTQ setup").
pub fn qat(
    ctx: &Ctx,
    task: &TaskSpec,
    init: &Params,
    act: &ActQuantTensors,
    cfg: &QatCfg,
) -> Result<QatResult> {
    let info = ctx.model_info(task)?;
    let artifact = format!("train_qat_{}_b16", ctx.head(task));
    let seq = info.config.seq;
    let split = data::train_split(task, seq)?;
    let regression = matches!(task.kind, TaskKind::Regression);
    let np = init.tensors.len();
    let s_lanes = info.total_scale_lanes;
    let n_sites = info.sites.len();
    let n_wq = info.wq.len();

    let mut p = init.clone();
    let mut m = p.zeros_like();
    let mut v = p.zeros_like();

    // activation scales: PTQ init (but strictly positive)
    let mut a_s: Vec<f32> = act.scales.iter().map(|&s| s.max(1e-6)).collect();
    let a_z = act.zps.clone();
    let mut a_cfg = act.cfg.clone();
    if !cfg.act_enabled {
        for c in a_cfg.chunks_exact_mut(3) {
            c[2] = 0.0;
        }
    }
    let mut msv = vec![0f32; s_lanes];
    let mut vsv = vec![0f32; s_lanes];

    // weight scales: symmetric min-max init per tensor
    let mut w_s = Vec::with_capacity(n_wq);
    let mut w_cfg = Vec::with_capacity(n_wq * 3);
    for name in &info.wq {
        let t = p.get(name)?;
        let bits = if name == "embed.tok" { cfg.embed_bits } else { cfg.weight_bits };
        let grid = QGrid::symmetric(bits);
        w_s.push((t.abs_max() / grid.qmax).max(1e-6));
        w_cfg.extend_from_slice(&[grid.qmin, grid.qmax, 1.0]);
    }
    let mut mwv = vec![0f32; n_wq];
    let mut vwv = vec![0f32; n_wq];

    let steps_per_epoch = split.examples.len() / cfg.batch;
    let total = (steps_per_epoch * cfg.epochs).max(1);
    let mut losses = Vec::with_capacity(total);
    let mut order: Vec<usize> = (0..split.examples.len()).collect();
    let mut rng = Rng::new(cfg.seed ^ 0x9A7);

    let mut step = 0usize;
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for chunk in order.chunks_exact(cfg.batch) {
            let batch = gather_batch(&split, chunk, seq);
            // see finetune_with: literal assembly is per-tensor
            // independent, so it runs on the pool
            let pmv: Vec<_> =
                p.tensors.iter().chain(&m.tensors).chain(&v.tensors).collect();
            let mut lits: Vec<xla::Literal> = ctx
                .pool
                .par_map(&pmv, |_, t| lit_f32(t.data(), t.shape()))
                .into_iter()
                .collect::<Result<_>>()?;
            lits.reserve(15);
            lits.push(lit_f32(&a_s, &[s_lanes])?);
            lits.push(lit_f32(&msv, &[s_lanes])?);
            lits.push(lit_f32(&vsv, &[s_lanes])?);
            lits.push(lit_f32(&a_z, &[s_lanes])?);
            lits.push(lit_f32(&a_cfg, &[n_sites, 3])?);
            lits.push(lit_f32(&w_s, &[n_wq])?);
            lits.push(lit_f32(&mwv, &[n_wq])?);
            lits.push(lit_f32(&vwv, &[n_wq])?);
            lits.push(lit_f32(&w_cfg, &[n_wq, 3])?);
            lits.push(lit_i32(&batch.ids, &[cfg.batch, seq])?);
            lits.push(lit_i32(&batch.token_type, &[cfg.batch, seq])?);
            lits.push(lit_f32(&batch.mask, &[cfg.batch, seq])?);
            if regression {
                lits.push(lit_f32(&batch.labels_reg, &[cfg.batch])?);
            } else {
                lits.push(lit_i32(&batch.labels_cls, &[cfg.batch])?);
            }
            lits.push(lit_scalar(lr_eff(cfg.lr, step, total))?);
            lits.push(lit_scalar(lr_eff(cfg.lr_scales, step, total))?);

            let mut out = ctx.rt.run_lits(&artifact, &lits)?;
            // outputs: p, m, v, a_s, msv, vsv, w_s, mwv, vwv, loss
            let loss = out.pop().expect("loss").data()[0];
            losses.push(loss);
            vwv = out.pop().expect("v_wq").into_data();
            mwv = out.pop().expect("m_wq").into_data();
            w_s = out.pop().expect("wq_scales").into_data();
            vsv = out.pop().expect("v_scales").into_data();
            msv = out.pop().expect("m_scales").into_data();
            a_s = out.pop().expect("act_scales").into_data();
            let vs = out.split_off(2 * np);
            let ms = out.split_off(np);
            p.tensors = out;
            m.tensors = ms;
            v.tensors = vs;

            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                println!("  [qat:{}] step {step}/{total} loss {loss:.4}", task.name);
            }
            step += 1;
            if !loss.is_finite() {
                bail!("qat {}: loss diverged at step {step}", task.name);
            }
        }
    }
    Ok(QatResult { params: p, act_scales: a_s, wq_scales: w_s, losses })
}

/// Gather specific example indices into a flat batch.
fn gather_batch(split: &data::Split, idx: &[usize], seq: usize) -> data::Batch {
    let b = idx.len();
    let mut out = data::Batch {
        ids: Vec::with_capacity(b * seq),
        token_type: Vec::with_capacity(b * seq),
        mask: Vec::with_capacity(b * seq),
        labels_cls: Vec::with_capacity(b),
        labels_reg: Vec::with_capacity(b),
        batch: b,
        seq,
        real: b,
    };
    for &i in idx {
        let ex = &split.examples[i];
        out.ids.extend_from_slice(&ex.ids);
        out.token_type.extend_from_slice(&ex.token_type);
        out.mask.extend_from_slice(&ex.mask);
        out.labels_cls.push(ex.label as i32);
        out.labels_reg.push(ex.target);
    }
    out
}

/// Evaluate the QAT state: returns params with weight QDQ applied using the
/// learned per-tensor scales, plus the learned activation tensors.
pub fn qat_deployed_params(
    info: &ModelInfo,
    res: &QatResult,
    weight_bits: u32,
    embed_bits: u32,
) -> Result<(Params, ActQuantTensors)> {
    let mut p = res.params.clone();
    for (j, name) in info.wq.iter().enumerate() {
        let bits = if name == "embed.tok" { embed_bits } else { weight_bits };
        let grid = QGrid::symmetric(bits);
        let s = res.wq_scales[j].max(1e-8);
        let t = p.get_mut(name)?;
        for x in t.data_mut().iter_mut() {
            let q = (*x / s).round().clamp(grid.qmin, grid.qmax);
            *x = s * q;
        }
    }
    // re-assemble act tensors with the learned scales
    let mut cfg = Vec::with_capacity(info.sites.len() * 3);
    // keep the same per-site grid that QAT trained with (8-bit asymmetric)
    for _ in &info.sites {
        let g = QGrid::asymmetric(8);
        cfg.extend_from_slice(&[g.qmin, g.qmax, 1.0]);
    }
    let act = ActQuantTensors {
        scales: res.act_scales.clone(),
        zps: vec![0.0; res.act_scales.len()],
        cfg,
        permutations: Default::default(),
    };
    Ok((p, act))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 100;
        // warmup rises
        assert!(lr_eff(1.0, 0, total) < lr_eff(1.0, 9, total));
        // decays after warmup (compare pure schedule by stripping bias at
        // late steps where bias ~ 1)
        assert!(lr_eff(1.0, 50, total) > lr_eff(1.0, 90, total));
        // ends near zero
        assert!(lr_eff(1.0, 99, total) < 0.02);
        // scales linearly with base
        let r = lr_eff(2.0, 42, total) / lr_eff(1.0, 42, total);
        assert!((r - 2.0).abs() < 1e-5);
    }

    #[test]
    fn lr_bias_correction_large_early() {
        // Adam bias correction amplifies early steps: at t=1 it is
        // sqrt(1-b2)/(1-b1) ≈ 0.316
        let warmup_sched = 1.0 / 10.0; // step 0 of total 100
        let expected = 0.1 * ((1.0f32 - 0.999).sqrt() / (1.0 - 0.9));
        let got = lr_eff(1.0, 0, 100);
        assert!((got - expected * (warmup_sched / 0.1)).abs() < 1e-4, "{got} vs {expected}");
    }
}
