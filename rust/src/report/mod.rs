//! Reporting: markdown/console tables, CSV emission, and ASCII heatmaps for
//! the figure reproductions (paper Fig. 2/5/6-13).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple table with a header row; renders to aligned console text and
/// GitHub markdown.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let cols = self.header.len();
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                if let Some(cell) = row.get(c) {
                    w[c] = w[c].max(cell.len());
                }
            }
        }
        w
    }

    pub fn to_console(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &w));
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &w));
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// Format a score like the paper (2 decimals).
pub fn fmt_score(x: f64) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.2}")
    }
}

/// Write a report file, creating parent dirs.
pub fn write_file(path: impl AsRef<Path>, content: &str) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

/// ASCII heatmap over a (rows, cols) boolean mask — used for the Fig. 2b /
/// Fig. 6-8 outlier maps ('#' = outlier, '.' = normal). Columns are
/// downsampled to at most `max_cols` by OR-reduction.
pub fn bool_heatmap(mask: &[bool], rows: usize, cols: usize, max_cols: usize) -> String {
    assert_eq!(mask.len(), rows * cols);
    let stride = cols.div_ceil(max_cols).max(1);
    let out_cols = cols.div_ceil(stride);
    let mut out = String::with_capacity(rows * (out_cols + 1));
    for r in 0..rows {
        for oc in 0..out_cols {
            let any = (oc * stride..((oc + 1) * stride).min(cols))
                .any(|c| mask[r * cols + c]);
            out.push(if any { '#' } else { '.' });
        }
        out.push('\n');
    }
    out
}

/// ASCII bar chart for per-index scalar series (paper Fig. 2a per-token
/// ranges, Fig. 9-13 per-sequence ranges).
pub fn bar_chart(values: &[f32], width: usize, labels: Option<&[String]>) -> String {
    let max = values.iter().copied().fold(f32::MIN, f32::max).max(1e-9);
    let mut out = String::new();
    for (i, &v) in values.iter().enumerate() {
        let n = ((v / max) * width as f32).round().max(0.0) as usize;
        let label = labels
            .and_then(|l| l.get(i))
            .cloned()
            .unwrap_or_else(|| format!("{i:>4}"));
        let _ = writeln!(out, "{label:>10} |{} {v:.2}", "█".repeat(n.min(width)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_formats() {
        let mut t = Table::new("Demo", &["task", "score"]);
        t.row(vec!["cola".into(), "57.27".into()]);
        t.row(vec!["sst2".into(), "93.12".into()]);
        let c = t.to_console();
        assert!(c.contains("Demo") && c.contains("57.27"));
        let md = t.to_markdown();
        assert!(md.starts_with("### Demo"));
        // header + separator + 2 data rows, each with 3 pipes
        assert_eq!(md.lines().filter(|l| l.starts_with('|')).count(), 4);
        assert!(md.contains("---"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn heatmap_downsamples() {
        let mask = vec![false, true, false, false, true, false, false, false];
        let hm = bool_heatmap(&mask, 2, 4, 2);
        // row0: cols {0,1}->#, {2,3}->. ; row1: {0,1}->#, {2,3}->.
        assert_eq!(hm, "#.\n#.\n");
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(&[1.0, 2.0], 10, None);
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].matches('█').count() == 10);
        assert!(lines[0].matches('█').count() == 5);
    }

    #[test]
    fn fmt_score_handles_nan() {
        assert_eq!(fmt_score(f64::NAN), "-");
        assert_eq!(fmt_score(83.057), "83.06");
    }
}
