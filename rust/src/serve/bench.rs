//! `repro serve-bench` — closed- and open-loop load generation against
//! the serving layer.
//!
//! * **Closed loop**: N client threads each submit → wait → repeat, so
//!   concurrency (not rate) is the control variable; measures the
//!   latency/throughput the engine sustains under back-pressure.
//! * **Open loop**: a generator submits at a target offered QPS on a
//!   fixed schedule regardless of completions — the arrival pattern a
//!   real front end produces — so queueing delay and admission shed
//!   become visible when offered load exceeds capacity.
//!
//! Both replay real task dev-set examples, sweep the dispatcher's
//! batch-window, and report p50/p95/p99 latency (µs, measured submit →
//! completion), sustained QPS, the batch-size histogram, and shed rate
//! per row of `results/bench_serve.csv`. A separate cache section
//! exercises the spec-addressed model cache at each `--cache-caps`
//! capacity (two passes over the bench spec set: capacities below the
//! spec count churn, capacities at/above it hit). `--fail-on-shed`
//! makes any shed row fatal — the CI smoke gate.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::cache::{ModelCache, ServeModel};
use super::queue::{ServeConfig, Server, SubmitError};
use crate::coordinator::Ctx;
use crate::data::{dev_split, Example, TaskSpec};
use crate::report::{write_file, Table};
use crate::runtime::Runtime;
use crate::spec::{PolicySpec, QuantSpec};
use crate::util::cli::Args;
use crate::util::pool::Pool;

/// One load run's raw outcome.
struct LoadResult {
    completed: u64,
    shed: u64,
    wall: Duration,
    /// sorted submit→completion latencies of successful requests, µs
    latencies_us: Vec<u64>,
    hist: String,
}

/// Nearest-rank percentile over a sorted sample (0 when empty).
fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn parse_usize_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| Ok(t.parse()?))
        .collect()
}

fn parse_u64_list(s: &str) -> Result<Vec<u64>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| Ok(t.parse()?))
        .collect()
}

/// The bench's spec set: fp32 pass-through plus two PTQ configs, all
/// with a deliberately small calibration budget (assembly cost is what
/// the cache sweep measures, not accuracy).
fn bench_specs(task: &TaskSpec) -> Vec<QuantSpec> {
    let mut specs = vec![
        QuantSpec::new("fp32", PolicySpec::fp32()),
        QuantSpec::new("w8a8", PolicySpec::uniform(8, 8)),
        QuantSpec::new("w4a8", PolicySpec::uniform(4, 8)),
    ];
    for s in &mut specs {
        s.tasks = vec![task.name.to_string()];
        s.seeds = 1;
        s.calib.num_batches = 2;
        s.calib.batch_size = 2;
    }
    specs
}

/// Closed loop: `clients` threads in lock-step submit → wait → repeat
/// until the deadline, then the server drains.
fn run_closed(
    rt: &Runtime,
    pool: &Pool,
    model: Arc<ServeModel>,
    cfg: ServeConfig,
    clients: usize,
    duration: Duration,
    examples: &[Example],
) -> LoadResult {
    std::thread::scope(|s| {
        let server = Server::start(s, rt, pool, model, cfg);
        let lat = Mutex::new(Vec::<u64>::new());
        let t0 = Instant::now();
        std::thread::scope(|cs| {
            for c in 0..clients {
                let server = &server;
                let lat = &lat;
                cs.spawn(move || {
                    let mut i = c;
                    while t0.elapsed() < duration {
                        match server.submit(examples[i % examples.len()].clone()) {
                            Ok(ticket) => {
                                let (res, latency) = ticket.wait_timed();
                                if res.is_ok() {
                                    lat.lock()
                                        .expect("bench latencies")
                                        .push(latency.as_micros() as u64);
                                }
                            }
                            Err(SubmitError::QueueFull { .. }) => {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(_) => break,
                        }
                        i += clients;
                    }
                });
            }
        });
        let stats = server.shutdown();
        let wall = t0.elapsed();
        let mut lats = lat.into_inner().expect("bench latencies");
        lats.sort_unstable();
        LoadResult {
            completed: stats.completed,
            shed: stats.shed,
            wall,
            latencies_us: lats,
            hist: stats.hist_string(),
        }
    })
}

/// Open loop: submit on a fixed `1/qps` schedule until the deadline
/// (sheds allowed), drain, then collect the completion-time latencies
/// recorded in each ticket.
fn run_open(
    rt: &Runtime,
    pool: &Pool,
    model: Arc<ServeModel>,
    cfg: ServeConfig,
    qps: f64,
    duration: Duration,
    examples: &[Example],
) -> LoadResult {
    std::thread::scope(|s| {
        let server = Server::start(s, rt, pool, model, cfg);
        let interval = Duration::from_secs_f64(1.0 / qps.max(1e-9));
        let t0 = Instant::now();
        let mut next = t0;
        let mut tickets = Vec::new();
        let mut i = 0usize;
        while t0.elapsed() < duration {
            let now = Instant::now();
            if now < next {
                std::thread::sleep(next - now);
            }
            match server.submit(examples[i % examples.len()].clone()) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::QueueFull { .. }) => {}
                Err(_) => break,
            }
            i += 1;
            next += interval;
        }
        let stats = server.shutdown();
        let wall = t0.elapsed();
        // every ticket completed during the drain; latency was stamped
        // at completion, so collecting late does not skew it
        let mut lats: Vec<u64> = tickets
            .into_iter()
            .filter_map(|t| {
                let (res, latency) = t.wait_timed();
                res.ok().map(|_| latency.as_micros() as u64)
            })
            .collect();
        lats.sort_unstable();
        LoadResult {
            completed: stats.completed,
            shed: stats.shed,
            wall,
            latencies_us: lats,
            hist: stats.hist_string(),
        }
    })
}

const CSV_HEADER: [&str; 17] = [
    "mode",
    "window_us",
    "cache_cap",
    "clients",
    "offered_qps",
    "duration_ms",
    "completed",
    "shed",
    "shed_rate",
    "sustained_qps",
    "p50_us",
    "p95_us",
    "p99_us",
    "batch_hist",
    "cache_hits",
    "cache_misses",
    "cache_evictions",
];

/// `repro serve-bench` entry point.
pub fn cmd_serve_bench(args: &Args) -> Result<()> {
    let ctx = Ctx::new(
        args.get_or("artifacts", "artifacts"),
        args.get_or("ckpt", "checkpoints"),
        args.get_or("results", "results"),
    )?;
    let task = ctx.task(args.get_or("task", "sst2"))?;
    let duration = Duration::from_millis(args.get_u64("duration-ms", 2000)?);
    let qps = f64::from(args.get_f32("qps", 100.0)?);
    let clients = args.get_usize("clients", 4)?.max(1);
    let depth = args.get_usize("depth", 256)?;
    let max_batch = args.get_usize("max-batch", 32)?;
    let windows_us = parse_u64_list(args.get_or("windows", "0,2000"))?;
    let caps = parse_usize_list(args.get_or("cache-caps", "2"))?;
    let fail_on_shed = args.flag("fail-on-shed");
    if windows_us.is_empty() {
        bail!("--windows needs at least one batch-window setting (µs)");
    }

    let info = ctx.model_info(&task)?;
    let mut split = dev_split(&task, info.config.seq)?;
    split.examples.truncate(args.get_usize("examples", 256)?.max(1));
    let examples = split.examples;
    let specs = bench_specs(&task);

    let mut table = Table::new("serve-bench", &CSV_HEADER);

    // Model-cache sweep: two passes over the spec set per capacity.
    // Below the spec count the second pass still misses (LRU churn);
    // at/above it, it hits every spec.
    for &cap in &caps {
        let cache = ModelCache::new(cap);
        for _pass in 0..2 {
            for spec in &specs {
                cache.get_or_assemble(&ctx, spec, &task)?;
            }
        }
        let st = cache.stats();
        println!(
            "cache cap {cap}: {} hits / {} misses / {} evictions over 2 passes of {} specs",
            st.hits,
            st.misses,
            st.evictions,
            specs.len()
        );
        let mut row = vec!["cache".to_string(), "-".to_string(), cap.to_string()];
        row.extend(vec!["-".to_string(); 11]);
        row.extend([st.hits.to_string(), st.misses.to_string(), st.evictions.to_string()]);
        table.row(row);
    }

    // Serving sweep: the quantized spec from a warm cache, per window.
    let cache = ModelCache::new(caps.iter().copied().max().unwrap_or(2));
    let model = cache.get_or_assemble(&ctx, &specs[1], &task)?;
    let mut total_shed = 0u64;
    for &window in &windows_us {
        let cfg = ServeConfig {
            max_batch,
            batch_window: Duration::from_micros(window),
            queue_depth: depth,
        };
        for mode in ["closed", "open"] {
            let r = if mode == "closed" {
                run_closed(
                    &ctx.rt,
                    &ctx.pool,
                    model.clone(),
                    cfg.clone(),
                    clients,
                    duration,
                    &examples,
                )
            } else {
                run_open(&ctx.rt, &ctx.pool, model.clone(), cfg.clone(), qps, duration, &examples)
            };
            let offered = r.completed + r.shed;
            let shed_rate =
                if offered == 0 { 0.0 } else { r.shed as f64 / offered as f64 };
            let sustained = r.completed as f64 / r.wall.as_secs_f64().max(1e-9);
            total_shed += r.shed;
            println!(
                "{mode} window={window}us: {} ok, {} shed, {sustained:.1} qps sustained, \
                 p50={}us p95={}us p99={}us, batches {}",
                r.completed,
                r.shed,
                percentile(&r.latencies_us, 0.50),
                percentile(&r.latencies_us, 0.95),
                percentile(&r.latencies_us, 0.99),
                r.hist
            );
            table.row(vec![
                mode.to_string(),
                window.to_string(),
                cache.capacity().to_string(),
                if mode == "closed" { clients.to_string() } else { "1".to_string() },
                if mode == "open" { format!("{qps:.0}") } else { "-".to_string() },
                duration.as_millis().to_string(),
                r.completed.to_string(),
                r.shed.to_string(),
                format!("{shed_rate:.4}"),
                format!("{sustained:.1}"),
                percentile(&r.latencies_us, 0.50).to_string(),
                percentile(&r.latencies_us, 0.95).to_string(),
                percentile(&r.latencies_us, 0.99).to_string(),
                r.hist,
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
    }

    print!("{}", table.to_console());
    let results_dir = PathBuf::from(args.get_or("results", "results"));
    write_file(results_dir.join("bench_serve.csv"), &table.to_csv())?;

    let st = ctx.rt.stats();
    println!(
        "runtime: {} executions ({} served, {} interpreted); model cache \
         {} hits / {} misses / {} evictions",
        st.executions,
        st.served,
        st.interpreted,
        st.model_cache_hits,
        st.model_cache_misses,
        st.model_cache_evictions
    );
    if fail_on_shed && total_shed > 0 {
        bail!("serve-bench shed {total_shed} request(s) at smoke load (--fail-on-shed)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.99), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&xs, 0.0), 1);
        // nearest-rank: (99 * 0.5).round() = 50 -> xs[50] = 51
        assert_eq!(percentile(&xs, 0.50), 51);
        assert_eq!(percentile(&xs, 0.99), 99);
        assert_eq!(percentile(&xs, 1.0), 100);
    }

    #[test]
    fn list_parsing() {
        assert_eq!(parse_u64_list("0,2000").unwrap(), vec![0, 2000]);
        assert_eq!(parse_usize_list(" 1, 2 ,3 ").unwrap(), vec![1, 2, 3]);
        assert!(parse_u64_list("1,x").is_err());
    }
}
