//! Spec-addressed model cache: `spec_id` → fully assembled serving
//! artifact, with LRU eviction under a capacity knob.
//!
//! A cache entry ([`ServeModel`]) is everything a dispatched batch needs
//! beyond its per-batch inputs: the QDQ'd parameters and calibrated
//! activation quantizers from `spec::run::assemble_for_serving`,
//! pre-rendered into the static input literals, with the forward
//! executable warmed in the runtime's own cache (parse + `hlo::Plan`).
//! Assembly is the expensive path (checkpoint load + calibration +
//! weight QDQ), so the cache is what makes multi-spec serving viable.
//!
//! Hit/miss/eviction counters are kept per cache (for tests and the
//! bench report) and folded into the shared `RuntimeStats` via
//! `Runtime::note_model_cache` on every `get_or_build`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::{static_input_lits, Ctx};
use crate::data::TaskSpec;
use crate::runtime::Runtime;
use crate::spec::run::{assemble_for_serving, AssembledModel};
use crate::spec::QuantSpec;

/// A cached, ready-to-dispatch model: the assembled artifact plus its
/// static input literals, built once at insert time and shared by every
/// batch the dispatcher executes against it.
pub struct ServeModel {
    pub assembled: AssembledModel,
    /// parameter + activation-quantizer literals in signature order
    /// (`coordinator::static_input_lits`)
    pub statics: Vec<xla::Literal>,
}

impl ServeModel {
    /// Assemble a spec for serving and pre-build its runtime state: the
    /// static input literals, and the executable warmed in the runtime
    /// cache so the first request never pays for parse + plan.
    pub fn build(ctx: &Ctx, spec: &QuantSpec, task: &TaskSpec) -> Result<ServeModel> {
        let assembled = assemble_for_serving(ctx, spec, task)?;
        ctx.rt.executable(&assembled.artifact)?;
        ServeModel::from_assembled(assembled)
    }

    /// Wrap an already-assembled model. Tests use this to feed the cache
    /// and dispatcher without the checkpoint-loading assembly path.
    pub fn from_assembled(assembled: AssembledModel) -> Result<ServeModel> {
        let statics = static_input_lits(
            &assembled.params,
            &assembled.act.scales,
            &assembled.act.zps,
            &assembled.act.cfg,
            assembled.n_sites,
        )?;
        Ok(ServeModel { assembled, statics })
    }

    pub fn spec_id(&self) -> &str {
        &self.assembled.spec_id
    }
}

/// Cache counters. `hits + misses` equals the number of lookups;
/// `evictions` counts entries displaced by inserts at capacity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

struct CacheInner {
    map: BTreeMap<String, Arc<ServeModel>>,
    /// spec_ids in recency order: index 0 is least recently used
    order: Vec<String>,
    stats: CacheStats,
}

/// The spec-addressed LRU cache. All methods take `&self`; the interior
/// mutex makes it shareable between the dispatcher and warm-up callers.
pub struct ModelCache {
    cap: usize,
    inner: Mutex<CacheInner>,
}

impl ModelCache {
    /// A cache holding at most `capacity` models (clamped to ≥ 1).
    pub fn new(capacity: usize) -> ModelCache {
        ModelCache {
            cap: capacity.max(1),
            inner: Mutex::new(CacheInner {
                map: BTreeMap::new(),
                order: Vec::new(),
                stats: CacheStats::default(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("model cache").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().expect("model cache").stats
    }

    /// Resident spec_ids, least recently used first.
    pub fn resident(&self) -> Vec<String> {
        self.inner.lock().expect("model cache").order.clone()
    }

    /// Look up a spec_id: a hit refreshes its recency, a miss only
    /// counts. (Callers wanting the build-on-miss path use
    /// [`ModelCache::get_or_build`].)
    pub fn lookup(&self, spec_id: &str) -> Option<Arc<ServeModel>> {
        let mut inner = self.inner.lock().expect("model cache");
        match inner.map.get(spec_id).cloned() {
            Some(m) => {
                inner.stats.hits += 1;
                touch(&mut inner.order, spec_id);
                Some(m)
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a model, evicting the least-recently-used
    /// entry when a new key arrives at capacity. Returns the evicted
    /// spec_id, if any.
    pub fn insert(&self, model: Arc<ServeModel>) -> Option<String> {
        let id = model.spec_id().to_string();
        let mut inner = self.inner.lock().expect("model cache");
        let mut evicted = None;
        if !inner.map.contains_key(&id) && inner.map.len() >= self.cap {
            let lru = inner.order.remove(0);
            inner.map.remove(&lru);
            inner.stats.evictions += 1;
            evicted = Some(lru);
        }
        inner.map.insert(id.clone(), model);
        touch(&mut inner.order, &id);
        evicted
    }

    /// The serving-path entry: return the cached model for `spec_id` or
    /// build and insert it. The counter delta (one hit, or one miss plus
    /// at most one eviction) is folded into the runtime's shared stats.
    pub fn get_or_build<F>(
        &self,
        rt: &Runtime,
        spec_id: &str,
        build: F,
    ) -> Result<Arc<ServeModel>>
    where
        F: FnOnce() -> Result<ServeModel>,
    {
        if let Some(m) = self.lookup(spec_id) {
            rt.note_model_cache(1, 0, 0);
            return Ok(m);
        }
        let model = Arc::new(build()?);
        let evicted = self.insert(model.clone());
        rt.note_model_cache(0, 1, u64::from(evicted.is_some()));
        Ok(model)
    }

    /// [`ModelCache::get_or_build`] over the standard assembly pipeline.
    pub fn get_or_assemble(
        &self,
        ctx: &Ctx,
        spec: &QuantSpec,
        task: &TaskSpec,
    ) -> Result<Arc<ServeModel>> {
        self.get_or_build(&ctx.rt, &spec.spec_id(), || ServeModel::build(ctx, spec, task))
    }

    /// Warm-up preloading: assemble `specs` in order so steady-state
    /// traffic starts hot. With more specs than capacity, the last
    /// `capacity` of them survive (LRU).
    pub fn warm_up(&self, ctx: &Ctx, specs: &[QuantSpec], task: &TaskSpec) -> Result<()> {
        for spec in specs {
            self.get_or_assemble(ctx, spec, task)?;
        }
        Ok(())
    }
}

/// Move `id` to the most-recently-used position (appending if absent).
fn touch(order: &mut Vec<String>, id: &str) {
    if let Some(i) = order.iter().position(|x| x == id) {
        order.remove(i);
    }
    order.push(id.to_string());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;
    use crate::model::qconfig::assemble_act_tensors;
    use crate::model::Params;
    use crate::quant::QuantPolicy;

    /// A ServeModel with real tensors but no artifacts/checkpoints behind
    /// it — enough for cache-policy tests.
    fn dummy_model(spec_id: &str) -> Arc<ServeModel> {
        let info = tiny_model_info();
        let params = Params::init(&info, 7);
        let act = assemble_act_tensors(&info, &QuantPolicy::fp32(), &BTreeMap::new()).unwrap();
        let assembled = AssembledModel {
            spec_id: spec_id.to_string(),
            task: "sst2".to_string(),
            artifact: "fwd_cls_b8".to_string(),
            params,
            act,
            batch: 8,
            seq: info.config.seq,
            n_out: info.config.n_out,
            n_sites: info.sites.len(),
        };
        Arc::new(ServeModel::from_assembled(assembled).unwrap())
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cache = ModelCache::new(2);
        assert!(cache.is_empty());
        assert!(cache.insert(dummy_model("a")).is_none());
        assert!(cache.insert(dummy_model("b")).is_none());
        // touch "a" so "b" becomes the LRU entry
        assert!(cache.lookup("a").is_some());
        let evicted = cache.insert(dummy_model("c"));
        assert_eq!(evicted.as_deref(), Some("b"));
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup("b").is_none());
        assert!(cache.lookup("a").is_some());
        assert!(cache.lookup("c").is_some());
        assert_eq!(cache.resident(), vec!["a".to_string(), "c".to_string()]);
        let st = cache.stats();
        assert_eq!(st.hits, 3);
        assert_eq!(st.misses, 1);
        assert_eq!(st.evictions, 1);
    }

    #[test]
    fn reinsert_refreshes_without_evicting() {
        let cache = ModelCache::new(2);
        cache.insert(dummy_model("a"));
        cache.insert(dummy_model("b"));
        // refreshing a resident key must not evict anything
        assert!(cache.insert(dummy_model("a")).is_none());
        assert_eq!(cache.len(), 2);
        // ...but it does move "a" to MRU: inserting "c" now evicts "b"
        assert_eq!(cache.insert(dummy_model("c")).as_deref(), Some("b"));
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn capacity_clamps_to_one() {
        let cache = ModelCache::new(0);
        assert_eq!(cache.capacity(), 1);
        cache.insert(dummy_model("a"));
        assert_eq!(cache.insert(dummy_model("b")).as_deref(), Some("a"));
        assert_eq!(cache.len(), 1);
    }
}
