//! Async request queue with continuous batching.
//!
//! Callers [`Server::submit`] single examples and block on the returned
//! [`Ticket`]. A dedicated dispatcher thread coalesces queued requests
//! under the batch-window/max-batch policy in [`ServeConfig`], assembles
//! them into PAD-padded executable batches with the same
//! `coordinator::batch_input_lits` + `data::make_batch` builders the
//! batch-eval path uses, executes them via `Runtime::run_batch_served`
//! on the persistent `util::pool` workers, and routes each logit row
//! back to its submitter by index.
//!
//! Admission control is a bounded queue: past `queue_depth`
//! undispatched requests, submissions fail fast with
//! [`SubmitError::QueueFull`] (counted as shed) instead of growing the
//! queue without bound. Shutdown is a graceful drain: the flag stops
//! admission, the dispatcher flushes everything already admitted
//! (skipping further batch-window waits), and every ticket is answered
//! exactly once — completion slots only accept the first result.
//!
//! The dispatcher runs as a *scoped* thread (`std::thread::scope`), so
//! it can borrow the runtime and pool directly from the caller's stack —
//! no `Arc<Runtime>` rework of the coordinator — at the price that a
//! `Server` lives inside a `thread::scope` block. Dropping the server
//! performs the same drain as [`Server::shutdown`].

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{Scope, ScopedJoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::cache::ServeModel;
use crate::coordinator::batch_input_lits;
use crate::data::{self, Example, Split};
use crate::runtime::Runtime;
use crate::util::pool::Pool;

/// Batching and admission policy for one [`Server`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max examples coalesced into one dispatch. May exceed the
    /// executable batch capacity: the dispatcher splits a coalesced set
    /// of `k` requests into `ceil(k/b)` padded executable batches and
    /// fans them out over the pool in one `run_batch_served` call.
    pub max_batch: usize,
    /// Batch window: once the queue is non-empty, how long the
    /// dispatcher waits for more arrivals before dispatching a partial
    /// batch. Zero dispatches whatever is queued immediately.
    pub batch_window: Duration,
    /// Admission bound: submissions beyond this many queued,
    /// not-yet-dispatched requests shed with [`SubmitError::QueueFull`].
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 32,
            batch_window: Duration::from_micros(200),
            queue_depth: 256,
        }
    }
}

/// Why a submission was rejected at the door.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control: the queue is at its configured depth; the
    /// request was shed, not enqueued.
    QueueFull { depth: usize },
    /// The server is draining (shutdown started) — nothing new admitted.
    ShuttingDown,
    /// The example's rows are not packed at the model's sequence length.
    BadShape { want_seq: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { depth } => {
                write!(f, "request shed: serve queue at depth {depth}")
            }
            SubmitError::ShuttingDown => write!(f, "serve queue is shutting down"),
            SubmitError::BadShape { want_seq } => {
                write!(f, "example must be packed at seq length {want_seq}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Counters for one server, snapshot via [`Server::stats`] (or returned
/// by [`Server::shutdown`], at which point `accepted == completed +
/// failed` — the drain guarantee).
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// requests admitted to the queue
    pub accepted: u64,
    /// requests rejected by admission control (`QueueFull`)
    pub shed: u64,
    /// requests answered with a logit row
    pub completed: u64,
    /// requests answered with an execution error
    pub failed: u64,
    /// `batches[s]` = executable batches dispatched with `s` real rows
    /// (index 0 unused); the batch-size histogram of the bench report
    pub batches: Vec<u64>,
}

impl ServeStats {
    pub fn dispatched_batches(&self) -> u64 {
        self.batches.iter().sum()
    }

    /// Histogram as `"1:12|3:2|8:40"` (fill-size:count, zero counts
    /// omitted); `"-"` when nothing was dispatched.
    pub fn hist_string(&self) -> String {
        let parts: Vec<String> = self
            .batches
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(s, c)| format!("{s}:{c}"))
            .collect();
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join("|")
        }
    }
}

/// One request's completion slot: result + queue-to-completion latency,
/// written exactly once by the dispatcher.
struct TicketState {
    submitted: Instant,
    done: Mutex<Option<(Result<Vec<f32>, String>, Duration)>>,
    cv: Condvar,
}

impl TicketState {
    fn new() -> TicketState {
        TicketState {
            submitted: Instant::now(),
            done: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    /// Deliver a result. Only the first delivery lands (the
    /// answered-exactly-once guarantee); later calls are no-ops.
    fn complete(&self, result: Result<Vec<f32>, String>) {
        let latency = self.submitted.elapsed();
        let mut slot = self.done.lock().expect("serve ticket");
        if slot.is_none() {
            *slot = Some((result, latency));
        }
        drop(slot);
        self.cv.notify_all();
    }
}

/// Handle to one submitted request; consume it to block for the logits.
pub struct Ticket(Arc<TicketState>);

impl Ticket {
    /// Block until the dispatcher answers: the example's logit row.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.wait_timed().0
    }

    /// [`Ticket::wait`] plus the submit-to-completion latency, measured
    /// at completion time (not at this call) so slow consumers don't
    /// inflate the bench percentiles.
    pub fn wait_timed(self) -> (Result<Vec<f32>>, Duration) {
        let mut slot = self.0.done.lock().expect("serve ticket");
        loop {
            if let Some((r, latency)) = slot.take() {
                return (r.map_err(|e| anyhow!("serve: {e}")), latency);
            }
            slot = self.0.cv.wait(slot).expect("serve ticket");
        }
    }
}

struct Pending {
    example: Example,
    ticket: Arc<TicketState>,
}

struct QueueState {
    pending: VecDeque<Pending>,
    shutdown: bool,
    accepted: u64,
    shed: u64,
    completed: u64,
    failed: u64,
    batches: Vec<u64>,
}

struct Shared {
    cfg: ServeConfig,
    state: Mutex<QueueState>,
    /// signalled on submit and on shutdown
    work: Condvar,
}

/// The serving front end: submission API plus the scoped dispatcher
/// thread. Create inside a `std::thread::scope` via [`Server::start`].
pub struct Server<'scope> {
    shared: Arc<Shared>,
    want_seq: usize,
    handle: Option<ScopedJoinHandle<'scope, ()>>,
}

impl<'scope> Server<'scope> {
    /// Spawn the dispatcher on `scope` serving `model` on `rt`/`pool`.
    pub fn start(
        scope: &'scope Scope<'scope, '_>,
        rt: &'scope Runtime,
        pool: &'scope Pool,
        model: Arc<ServeModel>,
        cfg: ServeConfig,
    ) -> Server<'scope> {
        let cfg = ServeConfig { max_batch: cfg.max_batch.max(1), ..cfg };
        let want_seq = model.assembled.seq;
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                shutdown: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                batches: vec![0; model.assembled.batch + 1],
            }),
            work: Condvar::new(),
            cfg,
        });
        let dispatcher_shared = shared.clone();
        let handle =
            scope.spawn(move || dispatcher(&dispatcher_shared, rt, pool, &model));
        Server { shared, want_seq, handle: Some(handle) }
    }

    /// Submit one example (packed at the model's seq length). Returns a
    /// [`Ticket`] to block on, or an explicit admission error.
    pub fn submit(&self, example: Example) -> Result<Ticket, SubmitError> {
        let seq = self.want_seq;
        if example.ids.len() != seq
            || example.token_type.len() != seq
            || example.mask.len() != seq
        {
            return Err(SubmitError::BadShape { want_seq: seq });
        }
        let mut st = self.shared.state.lock().expect("serve queue");
        if st.shutdown {
            return Err(SubmitError::ShuttingDown);
        }
        if st.pending.len() >= self.shared.cfg.queue_depth {
            st.shed += 1;
            return Err(SubmitError::QueueFull { depth: self.shared.cfg.queue_depth });
        }
        let ticket = Arc::new(TicketState::new());
        st.pending.push_back(Pending { example, ticket: ticket.clone() });
        st.accepted += 1;
        drop(st);
        self.shared.work.notify_all();
        Ok(Ticket(ticket))
    }

    /// Counter snapshot (consistent: taken under the queue lock).
    pub fn stats(&self) -> ServeStats {
        let st = self.shared.state.lock().expect("serve queue");
        ServeStats {
            accepted: st.accepted,
            shed: st.shed,
            completed: st.completed,
            failed: st.failed,
            batches: st.batches.clone(),
        }
    }

    /// Graceful drain: stop admitting, let the dispatcher flush every
    /// queued request (without further batch-window waits), join it, and
    /// return the final counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.finish();
        self.stats()
    }

    fn finish(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("serve queue");
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        let panicked = match self.handle.take() {
            Some(handle) => handle.join().is_err(),
            None => false,
        };
        // Normally empty: the dispatcher drains before exiting. If it
        // died, answer the leftovers (as failures, keeping `accepted ==
        // completed + failed`) so no waiter hangs forever.
        let leftovers: Vec<Pending> = {
            let mut st = self.shared.state.lock().expect("serve queue");
            let left: Vec<Pending> = st.pending.drain(..).collect();
            st.failed += left.len() as u64;
            left
        };
        for p in &leftovers {
            p.ticket.complete(Err("serve dispatcher terminated before this request".into()));
        }
        if panicked {
            eprintln!("[serve] dispatcher panicked; drained {} leftovers", leftovers.len());
        }
    }
}

impl Drop for Server<'_> {
    fn drop(&mut self) {
        self.finish();
    }
}

/// The dispatcher loop: sleep until work arrives, coalesce under the
/// batch window, dispatch, repeat; on shutdown, drain without waiting.
fn dispatcher(shared: &Shared, rt: &Runtime, pool: &Pool, model: &ServeModel) {
    loop {
        let drained: Vec<Pending> = {
            let mut st = shared.state.lock().expect("serve queue");
            while st.pending.is_empty() && !st.shutdown {
                st = shared.work.wait(st).expect("serve queue");
            }
            if st.pending.is_empty() {
                return; // shutdown and fully drained
            }
            // Batch window, measured from the first queued request: wait
            // for more arrivals up to the deadline, dispatching early
            // when the coalescing cap is reached. A drain skips the wait.
            let deadline = Instant::now() + shared.cfg.batch_window;
            while st.pending.len() < shared.cfg.max_batch && !st.shutdown {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .work
                    .wait_timeout(st, deadline - now)
                    .expect("serve queue");
                st = guard;
            }
            let k = st.pending.len().min(shared.cfg.max_batch);
            st.pending.drain(..k).collect()
        };
        execute_coalesced(shared, rt, pool, model, drained);
    }
}

/// Execute one coalesced set of requests as `ceil(k/b)` padded
/// executable batches fanned out on the pool, and route logit row `r`
/// back to submitter `r` — the same `make_batch` padding and
/// `batch_input_lits` assembly as batch eval, which is why re-batching
/// is bit-transparent.
fn execute_coalesced(
    shared: &Shared,
    rt: &Runtime,
    pool: &Pool,
    model: &ServeModel,
    drained: Vec<Pending>,
) {
    let k = drained.len();
    let b = model.assembled.batch;
    let seq = model.assembled.seq;
    let n_out = model.assembled.n_out;
    let (examples, tickets): (Vec<Example>, Vec<Arc<TicketState>>) =
        drained.into_iter().map(|p| (p.example, p.ticket)).unzip();
    let split = Split { examples };
    let n_exec = k.div_ceil(b);
    let result = rt.run_batch_served(
        &model.assembled.artifact,
        &model.statics,
        n_exec,
        |i| batch_input_lits(&data::make_batch(&split, i * b, b, seq)),
        pool,
    );
    match result {
        Ok(outs) => {
            for (r, ticket) in tickets.iter().enumerate() {
                let logits = &outs[r / b][0];
                let row = logits.data()[(r % b) * n_out..(r % b + 1) * n_out].to_vec();
                ticket.complete(Ok(row));
            }
            let mut st = shared.state.lock().expect("serve queue");
            st.completed += k as u64;
            for i in 0..n_exec {
                let fill = (k - i * b).min(b);
                st.batches[fill] += 1;
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for ticket in &tickets {
                ticket.complete(Err(msg.clone()));
            }
            shared.state.lock().expect("serve queue").failed += k as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_error_display() {
        assert_eq!(
            SubmitError::QueueFull { depth: 4 }.to_string(),
            "request shed: serve queue at depth 4"
        );
        assert!(SubmitError::ShuttingDown.to_string().contains("shutting down"));
        assert!(SubmitError::BadShape { want_seq: 24 }.to_string().contains("24"));
    }

    #[test]
    fn ticket_completes_exactly_once() {
        let state = Arc::new(TicketState::new());
        state.complete(Ok(vec![1.0, 2.0]));
        state.complete(Ok(vec![9.0])); // must not overwrite
        state.complete(Err("late error".into())); // must not overwrite
        let (row, latency) = Ticket(state).wait_timed();
        assert_eq!(row.unwrap(), vec![1.0, 2.0]);
        // latency was measured at first completion, long before any wait
        assert!(latency < Duration::from_secs(3600));
    }

    fn packed_example(seq: usize) -> Example {
        Example {
            ids: vec![1; seq],
            token_type: vec![0; seq],
            mask: vec![1.0; seq],
            label: 0,
            target: 0.0,
        }
    }

    /// Admission control in isolation: a dispatcher-less `Server` (no
    /// handle) exercises the submit-side checks without a runtime.
    #[test]
    fn admission_checks_shape_depth_and_shutdown() {
        let shared = Arc::new(Shared {
            cfg: ServeConfig {
                max_batch: 4,
                batch_window: Duration::ZERO,
                queue_depth: 1,
            },
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                shutdown: false,
                accepted: 0,
                shed: 0,
                completed: 0,
                failed: 0,
                batches: vec![0; 9],
            }),
            work: Condvar::new(),
        });
        let server = Server { shared: shared.clone(), want_seq: 4, handle: None };
        assert_eq!(
            server.submit(packed_example(3)).err(),
            Some(SubmitError::BadShape { want_seq: 4 })
        );
        let admitted = server.submit(packed_example(4)).unwrap();
        assert_eq!(
            server.submit(packed_example(4)).err(),
            Some(SubmitError::QueueFull { depth: 1 })
        );
        shared.state.lock().unwrap().shutdown = true;
        assert_eq!(server.submit(packed_example(4)).err(), Some(SubmitError::ShuttingDown));
        let stats = server.stats();
        assert_eq!((stats.accepted, stats.shed), (1, 1));
        // dropping the server answers the stranded request as a failure,
        // preserving accepted == completed + failed
        drop(server);
        assert!(admitted.wait().is_err());
        let st = shared.state.lock().unwrap();
        assert_eq!((st.completed, st.failed), (0, 1));
    }

    #[test]
    fn hist_string_formats() {
        let mut st = ServeStats::default();
        assert_eq!(st.hist_string(), "-");
        st.batches = vec![0, 12, 0, 2, 0, 0, 0, 0, 40];
        assert_eq!(st.hist_string(), "1:12|3:2|8:40");
        assert_eq!(st.dispatched_batches(), 54);
    }
}
