//! `serve` — the quantized-inference serving layer (DESIGN.md §12).
//!
//! Turns the batch-job engine (`Runtime::run_batch` on the persistent
//! `util::pool` workers) into an online server:
//!
//! * [`queue`] — async request queue with continuous batching: callers
//!   submit single examples and block on a [`queue::Ticket`]; a
//!   dispatcher thread coalesces queued requests under a configurable
//!   batch-window (max wait) and max-batch-size, fans the coalesced set
//!   out over the pool as padded executable batches, and routes each
//!   logit row back to its submitter by index. Admission is bounded:
//!   past the configured queue depth, submissions shed with an explicit
//!   error instead of growing without bound, and shutdown drains every
//!   admitted request exactly once.
//! * [`cache`] — spec-addressed model cache: `spec_id` → fully assembled
//!   artifact (checkpoint + weight QDQ + calibrated activation
//!   quantizers + pre-built static input literals, with the executable's
//!   `hlo::Plan` warmed in the runtime cache). LRU eviction under a
//!   capacity knob, warm-up preloading, and hit/miss/eviction counters
//!   folded into `RuntimeStats`.
//! * [`bench`] — `repro serve-bench`: closed- and open-loop load
//!   generation over real task examples, reporting p50/p95/p99 latency,
//!   sustained QPS, batch-size histogram, and shed rate per
//!   batch-window and cache-capacity setting.
//!
//! Re-batching preserves bit-identity with direct `run_batch` calls:
//! the forward graphs never reduce over the batch dimension (every op
//! is per-row there), batches are assembled by the same
//! `coordinator::batch_input_lits` builder with the same PAD-row
//! padding, and each real row's math is therefore independent of which
//! batch it rode in — the property tests/determinism.rs pins.

pub mod bench;
pub mod cache;
pub mod queue;

pub use cache::{CacheStats, ModelCache, ServeModel};
pub use queue::{ServeConfig, ServeStats, Server, SubmitError, Ticket};
