//! `repro` — CLI for the transformer-quantization reproduction.
//!
//! Usage:
//!     repro finetune [--all | --tasks a,b] [--epochs 3] [--lr 1e-3]
//!     repro table1|table2|table4|table5|table6|table7 [--seeds 3] [--quick]
//!     repro table7 --detailed        (appendix Table 12)
//!     repro fig2|fig5|fig6|fig9
//!     repro hparams                  (appendix Tables 8-11)
//!     repro eval --task mnli
//!     repro run --spec FILE.json | --preset NAME [--dump-spec] [--explain]
//!                                    (run any quantization spec; presets
//!                                    name the paper's configurations;
//!                                    --explain prints the resolved
//!                                    per-site policy without running)
//!     repro diag --outliers [--task NAME] [--seqs N] [--arch bert,vit]
//!                 [--variants vanilla,clipped_softmax,gated] [--json]
//!                                    (per-site activation outlier stats —
//!                                    inf-norm / kurtosis / top-lane share —
//!                                    comparing the vanilla model against
//!                                    the clipped-softmax and gated-attention
//!                                    variants; see analysis::outliers)
//!     repro smoke                    (runtime sanity: load + run artifacts)
//!     repro gen-artifacts [--no-ckpt]
//!                                    (emit the fixture artifacts/ + init
//!                                    checkpoints so every runtime surface
//!                                    works in-container — see hlo::fixture)
//!     repro sweep [--arch bert,vit] [--variants vanilla,clipped_softmax,gated]
//!                 [--bits 8,4] [--wbits 8] [--groups 1,8]
//!                 [--range-methods auto,mse_group] [--threads N]
//!                 [--shard i/n | --merge n]
//!                 [--fresh] [--compare baseline.json]
//!                                    (parallel task x architecture x config
//!                                    sweep, resumable by spec_id; --shard
//!                                    runs one hash-partition of the grid,
//!                                    --merge unions the shard reports back
//!                                    into the report an unsharded run
//!                                    writes — see coordinator::sweep)
//!     repro lint [--spec FILE.json | --preset NAME] [--json]
//!                                    (static verifier over every manifest
//!                                    artifact + quantization-hazard linter
//!                                    over spec x topology x forward graph;
//!                                    exits non-zero on any deny finding —
//!                                    see analysis::lint for the TQ codes)
//!     repro serve-bench [--task sst2] [--duration-ms 2000] [--qps 100]
//!                 [--clients 4] [--windows 0,2000] [--cache-caps 2]
//!                 [--depth 256] [--max-batch 32] [--fail-on-shed]
//!                                    (closed+open-loop load bench against
//!                                    the continuous-batching serving layer;
//!                                    writes results/bench_serve.csv —
//!                                    see serve::bench)
//!
//! Common flags: --artifacts DIR (default artifacts), --ckpt DIR
//! (default checkpoints), --results DIR (default results).

use anyhow::{bail, Result};

use tq::coordinator::experiments::{self, ExpOpts};
use tq::coordinator::Ctx;
use tq::report::{fmt_score, write_file, Table};
use tq::spec::run::run_spec;
use tq::spec::{presets, QuantSpec};
use tq::util::cli::Args;
use tq::util::json::Json;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    if args.subcommand.is_empty() {
        print_help();
        return Ok(());
    }
    // `sweep` and `run` manage their own (optional) runtime so they work
    // without artifacts (offline sweep, `run --dump-spec`); everything
    // else needs the Ctx up front.
    if args.subcommand == "sweep" {
        let t0 = std::time::Instant::now();
        tq::coordinator::sweep::cmd_sweep(&args)?;
        eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
        return Ok(());
    }
    if args.subcommand == "run" {
        let t0 = std::time::Instant::now();
        cmd_run(&args)?;
        eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
        return Ok(());
    }
    if args.subcommand == "gen-artifacts" {
        let t0 = std::time::Instant::now();
        tq::hlo::fixture::cmd_gen_artifacts(&args)?;
        eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
        return Ok(());
    }
    if args.subcommand == "serve-bench" {
        let t0 = std::time::Instant::now();
        tq::serve::bench::cmd_serve_bench(&args)?;
        eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
        return Ok(());
    }
    if args.subcommand == "lint" {
        let t0 = std::time::Instant::now();
        let r = tq::analysis::cmd_lint(&args);
        eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
        return r;
    }
    let ctx = Ctx::new(
        args.get_or("artifacts", "artifacts"),
        args.get_or("ckpt", "checkpoints"),
        args.get_or("results", "results"),
    )?;
    let opts = ExpOpts {
        seeds: args.get_usize("seeds", 3)?,
        tasks: args
            .get("tasks")
            .map(|t| t.split(',').map(String::from).collect())
            .or_else(|| args.get("task").map(|t| vec![t.to_string()]))
            .unwrap_or_default(),
        quick: args.flag("quick"),
    };

    let t0 = std::time::Instant::now();
    match args.subcommand.as_str() {
        "finetune" => {
            let epochs = args.get_usize("epochs", 3)?;
            let lr = args.get_f32("lr", 1e-3)?;
            experiments::cmd_finetune(&ctx, &opts, epochs, lr)?;
        }
        "table1" => experiments::table1(&ctx, &opts)?,
        "table2" => experiments::table2(&ctx, &opts)?,
        "table4" => experiments::table4(&ctx, &opts)?,
        "table5" => experiments::table5(&ctx, &opts)?,
        "table6" => experiments::table6(&ctx, &opts)?,
        "table7" => experiments::table7(&ctx, &opts, args.flag("detailed"))?,
        "table12" => experiments::table7(&ctx, &opts, true)?,
        "fig2" => experiments::fig2(&ctx, &opts)?,
        "fig5" => experiments::fig5(&ctx, &opts)?,
        "fig6" => experiments::fig6(&ctx, &opts)?,
        "fig9" => experiments::fig9(&ctx, &opts)?,
        "hparams" => experiments::hparams(&ctx)?,
        "diag" => tq::analysis::cmd_diag(&ctx, &args)?,
        "eval" => cmd_eval(&ctx, &args, &opts)?,
        "smoke" => cmd_smoke(&ctx)?,
        other => {
            print_help();
            bail!("unknown subcommand {other:?}");
        }
    }
    eprintln!("[done in {:.1}s]", t0.elapsed().as_secs_f32());
    Ok(())
}

/// `repro run`: execute one serialized quantization spec end-to-end.
///
/// The spec comes from `--spec FILE.json` or `--preset NAME`; `--tasks`
/// and `--seeds` override the spec's own eval targets / seed count.
/// `--dump-spec` prints the canonical JSON to stdout (and only the JSON,
/// so it can be redirected into a file and fed back via `--spec`) without
/// running anything.
fn cmd_run(args: &Args) -> Result<()> {
    let mut spec = if let Some(path) = args.get("spec") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read spec {path:?}: {e}"))?;
        QuantSpec::parse(&text)?
    } else if let Some(name) = args.get("preset") {
        presets::preset(name)?
    } else {
        bail!(
            "repro run needs --spec FILE.json or --preset NAME\npresets:\n{}",
            presets::PRESETS
                .iter()
                .map(|(n, d)| format!("  {n:<18} {d}"))
                .collect::<Vec<_>>()
                .join("\n")
        );
    };
    if let Some(t) = args.get("tasks").or_else(|| args.get("task")) {
        spec.tasks = t
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect();
    }
    if let Some(s) = args.get("seeds") {
        spec.seeds = s.parse()?;
        if spec.seeds == 0 {
            // keep the dump/run round-trip closed: from_json rejects 0
            bail!("--seeds must be >= 1");
        }
    }
    if args.flag("dump-spec") {
        println!("{}", spec.to_json());
        return Ok(());
    }

    let ctx = Ctx::new(
        args.get_or("artifacts", "artifacts"),
        args.get_or("ckpt", "checkpoints"),
        args.get_or("results", "results"),
    )?;
    if args.flag("explain") {
        return explain_spec(&ctx, &spec);
    }
    let report = run_spec(&ctx, &spec)?;
    let mut header: Vec<&str> = vec!["spec"];
    header.extend(report.tasks.iter().map(String::as_str));
    header.push("GLUE");
    let mut table = Table::new(
        &format!("spec {} ({})", spec.display_name(), report.spec_id),
        &header,
    );
    let mut row = vec![spec.display_name()];
    row.extend(report.scores.iter().map(|&s| fmt_score(s)));
    row.push(fmt_score(report.glue));
    table.row(row);
    print!("{}", table.to_console());

    let results_dir = std::path::PathBuf::from(args.get_or("results", "results"));
    write_file(
        results_dir.join(format!("run_{}.md", report.spec_id)),
        &table.to_markdown(),
    )?;
    let mut out = report.to_json();
    if let Json::Obj(m) = &mut out {
        m.insert("spec".to_string(), spec.to_json());
    }
    write_file(
        results_dir.join(format!("run_{}.json", report.spec_id)),
        &out.to_string(),
    )?;
    Ok(())
}

/// `repro run --explain`: resolve the spec against each target task's
/// model topology and print the per-site policy — bits, granularity,
/// range method, enabled — plus the PEG parameter overhead, without
/// executing anything. This is the spec-diff surface: two specs can be
/// compared site by site before spending a calibration run.
fn explain_spec(ctx: &Ctx, spec: &QuantSpec) -> Result<()> {
    use tq::quant::peg::site_overhead_params;
    use tq::spec::{granularity_name, range_method_name};
    let tasks = tq::spec::run::spec_tasks(spec)?;
    println!("spec {} ({})", spec.display_name(), spec.spec_id());
    // tasks share a topology per head kind; explain each distinct one
    let mut seen = std::collections::BTreeSet::new();
    for task in &tasks {
        if !seen.insert(ctx.head(task)) {
            continue;
        }
        let info = ctx.model_info(task)?;
        let policy = spec.policy.resolve(info);
        let mut table = Table::new(
            &format!(
                "resolved activation sites ({} head, d={}, task {})",
                ctx.head(task),
                info.config.d,
                task.name
            ),
            &["site", "lanes", "bits", "granularity", "range_method", "enabled", "overhead"],
        );
        let mut total_overhead = 0usize;
        for s in &info.sites {
            let c = policy.site_cfg(&s.name);
            let overhead = if !c.enabled || s.channels <= 1 {
                0
            } else {
                site_overhead_params(s.channels, &c.granularity)
            };
            total_overhead += overhead;
            table.row(vec![
                s.name.clone(),
                format!("{}", s.channels),
                format!("{}", c.bits),
                granularity_name(&c.granularity),
                range_method_name(c.range_method).to_string(),
                if c.enabled { "yes".to_string() } else { "no".to_string() },
                format!("{overhead}"),
            ]);
        }
        print!("{}", table.to_console());
        println!(
            "total activation-quantizer overhead: {total_overhead} extra parameters"
        );
        // dead/shadowed/redundant rule visibility (same findings as
        // `repro lint`, scoped to this topology)
        for d in tq::analysis::lint_spec_rules(&spec.policy, info) {
            println!("  {d}");
        }
    }
    println!(
        "weights: {} bits, estimator {}, per-channel groups {:?}, enabled {}",
        spec.policy.weights.bits,
        tq::spec::estimator_name(spec.policy.weights.estimator),
        spec.policy.weights.per_channel_groups,
        spec.policy.weights.enabled,
    );
    for (name, w) in &spec.policy.weight_overrides {
        println!("  weight override {name}: {} bits, enabled {}", w.bits, w.enabled);
    }
    Ok(())
}

fn cmd_eval(ctx: &Ctx, args: &Args, opts: &ExpOpts) -> Result<()> {
    let task = args.get("task").unwrap_or("mnli");
    let [fp32, w8a8, peg, mp] = experiments::quick_compare(ctx, task, opts.seeds)?;
    println!("task {task}:");
    println!("  FP32          {fp32:.2}");
    println!("  W8A8 PTQ      {w8a8:.2}");
    println!("  PEG-PTQ K=8+P {peg:.2}");
    println!("  MP-PTQ        {mp:.2}");
    Ok(())
}

/// Runtime sanity: compile every artifact and run the kernel ones.
fn cmd_smoke(ctx: &Ctx) -> Result<()> {
    use tq::runtime::Value;
    use tq::tensor::Tensor;
    let names: Vec<String> = ctx.rt.manifest().artifacts.keys().cloned().collect();
    println!("{} artifacts in manifest", names.len());
    // golden cross-layer check: Rust quant sim == Pallas kernel output
    if let Some(g) = &ctx.rt.manifest().golden_fake_quant {
        let grid = tq::quant::QGrid { qmin: g.qmin, qmax: g.qmax };
        let t = Tensor::new(vec![g.rows, g.cols], g.x.clone())?;
        let params: Vec<tq::quant::QParams> = g
            .scale
            .iter()
            .zip(&g.zp)
            .map(|(&s, &z)| tq::quant::QParams { scale: s, zero_point: z })
            .collect();
        let out = tq::quant::qdq_per_lane(&t, &params, grid)?;
        let want = Tensor::new(vec![g.rows, g.cols], g.out.clone())?;
        let diff = out.sub(&want)?.abs_max();
        println!("golden fake-quant max |Δ| = {diff:e}");
        if diff > 1e-6 {
            bail!("golden fake-quant mismatch: {diff}");
        }
    }
    // run the standalone fq kernel artifact
    let sig = ctx.rt.manifest().artifact("kernel_fq_d768")?;
    let t = Tensor::full(&[sig.inputs[0].shape[0], sig.inputs[0].shape[1]], 0.5);
    let s = Tensor::full(&[768], 0.01);
    let z = Tensor::full(&[768], 128.0);
    let c = Tensor::new(vec![3], vec![0.0, 255.0, 1.0])?;
    let out = ctx.rt.run(
        "kernel_fq_d768",
        &[Value::F32(t), Value::F32(s), Value::F32(z), Value::F32(c)],
    )?;
    println!("kernel_fq_d768 -> {:?}, first = {}", out[0].shape(), out[0].data()[0]);
    // compile-check the rest
    for n in &names {
        let exe = ctx.rt.executable(n)?;
        println!("  compiled {n} [{}]", exe.backend_name());
    }
    // Runtime-backed eval through the batch-parallel hot loop: calibrate
    // → quantize activations → score a dev subset. The score is printed
    // with its exact bit pattern so driver runs under different
    // TQ_THREADS settings can diff the output — the pool contract says
    // they must match bit-for-bit.
    if ctx.rt.manifest().model("base").is_ok() {
        use tq::coordinator::calibrate::{calibrate, CalibCfg};
        use tq::coordinator::eval;
        use tq::model::qconfig::{assemble_act_tensors, QuantPolicy};
        let task = ctx.task("sst2")?;
        let info = ctx.model_info(&task)?;
        let params = tq::coordinator::experiments::load_ckpt(&ctx, &task)
            .unwrap_or_else(|_| tq::model::Params::init(info, 0));
        let cfg = CalibCfg { num_batches: 4, batch_size: 2, ..Default::default() };
        let calib = calibrate(&ctx, &task, &params, &cfg)?;
        let act = assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers)?;
        let mut split = tq::data::dev_split(&task, info.config.seq)?;
        split.examples.truncate(128);
        let score = eval::evaluate_split(&ctx, &task, &params, &act, &split)?;
        eprintln!("[smoke eval ran on {} pool thread(s)]", ctx.pool.threads());
        println!(
            "eval sst2 (128 dev examples, W8A8 activations-only) score = {score} \
             [bits {:016x}]",
            score.to_bits()
        );
    }
    let st = ctx.rt.stats();
    if st.interpreted > 0 {
        println!(
            "(executed via the in-repo HLO interpreter: {} of {} runs)",
            st.interpreted, st.executions
        );
    }
    println!("smoke OK");
    Ok(())
}

fn print_help() {
    println!(
        "repro — 'Understanding and Overcoming the Challenges of Efficient \
         Transformer Quantization' (EMNLP 2021) reproduction\n\n\
         subcommands:\n  finetune [--tasks a,b] [--epochs N] [--lr F]\n  \
         table1 table2 table4 table5 table6 table7 [--detailed] table12\n  \
         fig2 fig5 fig6 fig9  hparams\n  eval --task NAME\n  \
         diag --outliers [--task NAME] [--seqs N] [--arch bert,vit] \
         [--variants vanilla,clipped_softmax,gated] [--json]\n  \
         run --spec FILE.json | --preset NAME [--tasks a,b] [--seeds N] \
         [--dump-spec] [--explain]\n  smoke\n  gen-artifacts [--no-ckpt]\n  \
         lint [--spec FILE.json | --preset NAME] [--json]\n  \
         sweep [--arch bert,vit] [--variants vanilla,clipped_softmax,gated] \
         [--bits 8,4] [--wbits 8] [--groups 1,8] \
         [--estimators current,mse] [--range-methods auto,mse_group] \
         [--threads N] [--task NAME] [--seeds N] [--shard i/n | --merge n] \
         [--fresh] [--compare baseline.json] [--tolerance PTS]\n  \
         serve-bench [--task NAME] [--duration-ms N] [--qps F] \
         [--clients N] [--windows us,us] [--cache-caps n,m] [--depth N] \
         [--max-batch N] [--fail-on-shed]\n\n\
         `run` executes one serialized QuantSpec (see DESIGN.md §7); \
         `run --preset NAME --dump-spec > f.json` writes a starting point; \
         `run --preset NAME --explain` prints the resolved per-site policy \
         (bits, granularity, range_method, PEG overhead); specs with a \
         `qat` section (the *_qat presets, Tables 6/7) fine-tune through \
         the quantized train-step graph before evaluating. The specs/ \
         directory ships every paper table row as a checked-in spec file.\n\
         presets: {}\n\n\
         flags: --artifacts DIR --ckpt DIR --results DIR --seeds N --quick",
        presets::preset_names().join(" ")
    );
}
