//! Declarative quantization specs: every paper configuration as *data*.
//!
//! A [`QuantSpec`] captures everything that defines one quantization
//! experiment — the activation/weight policy (including PEG, mixed
//! precision and per-channel groups), the range-estimator and calibration
//! settings, AdaRound knobs, the number of calibration seeds and the eval
//! targets — in a fully serializable form:
//!
//! * JSON round-trip via [`crate::util::json::Json`] (`to_json` /
//!   `from_json`; parse → serialize → parse is the identity),
//! * a stable content hash [`QuantSpec::spec_id`] (FNV-1a 64 over the
//!   canonical JSON, label excluded) that keys resumable sweeps and
//!   baseline diffs,
//! * a preset registry ([`presets`]) naming the paper's configurations
//!   (`w8a8`, `mixed_precision`, `peg_k8_permute`, …),
//! * one pipeline ([`run::run_spec`]) that owns calibrate → weight-QDQ →
//!   assemble → eval for every driver (`repro table*`, `repro sweep`,
//!   `repro run --spec FILE.json`).
//!
//! Site overrides are declarative [`SiteRule`]s (exact name, layer-family
//! suffix, or last-N-layers family) resolved against a concrete
//! [`ModelInfo`] into the imperative [`QuantPolicy`] the assembly layer
//! consumes — so one spec file applies to any model topology.

pub mod presets;
pub mod run;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::model::manifest::{Architecture, AttnVariant, ModelInfo};
use crate::model::qconfig::{QuantPolicy, SiteCfg, WeightCfg};
use crate::quant::{Estimator, Granularity, RangeMethod};
use crate::util::json::{obj, Json};

/// How a [`SiteRule`] picks activation-quantizer sites.
#[derive(Debug, Clone, PartialEq)]
pub enum SiteSelector {
    /// One site by exact name (e.g. `"head_out"`).
    Exact(String),
    /// Every site whose name ends with the suffix, across layers
    /// (e.g. `"res2_sum"` hits `layer0.res2_sum` .. `layerN.res2_sum`).
    Family(String),
    /// The family restricted to the last `n` layers — resolves to
    /// `layer{L-n}.{suffix}` .. `layer{L-1}.{suffix}` (Table 2's
    /// "last 2 layers only" row).
    FamilyLastLayers { suffix: String, n: usize },
}

impl SiteSelector {
    /// Site names this selector covers in `info`'s topology, in site
    /// order. [`PolicySpec::resolve`] installs `Exact` and
    /// `FamilyLastLayers` override entries *unconditionally* — a name
    /// that is not a real site is silently dead there; this helper
    /// restricts to real sites, which is exactly what the lint layer
    /// (`analysis::lint`, TQ003) uses to flag dead rules.
    pub fn matching_sites(&self, info: &ModelInfo) -> Vec<String> {
        match self {
            SiteSelector::Exact(name) => info
                .sites
                .iter()
                .filter(|s| s.name == *name)
                .map(|s| s.name.clone())
                .collect(),
            SiteSelector::Family(suffix) => info
                .sites
                .iter()
                .filter(|s| s.name.ends_with(suffix.as_str()))
                .map(|s| s.name.clone())
                .collect(),
            SiteSelector::FamilyLastLayers { suffix, n } => {
                let layers = info.config.layers;
                (layers.saturating_sub(*n)..layers)
                    .map(|i| format!("layer{i}.{suffix}"))
                    .filter(|name| info.sites.iter().any(|s| s.name == *name))
                    .collect()
            }
        }
    }

    /// Short human description for diagnostics (`exact:head_out`,
    /// `family:res2_sum`, `last2:res2_sum`).
    pub fn describe(&self) -> String {
        match self {
            SiteSelector::Exact(name) => format!("exact:{name}"),
            SiteSelector::Family(suffix) => format!("family:{suffix}"),
            SiteSelector::FamilyLastLayers { suffix, n } => format!("last{n}:{suffix}"),
        }
    }
}

/// One site override: selector + the configuration it installs.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteRule {
    pub select: SiteSelector,
    pub cfg: SiteCfg,
}

/// Serializable activation + weight policy. Resolved against a
/// [`ModelInfo`] into the [`QuantPolicy`] the assembly layer consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    /// default config for sites not hit by any rule
    pub default_site: SiteCfg,
    /// applied in order; later rules overwrite earlier ones per site
    pub rules: Vec<SiteRule>,
    pub weights: WeightCfg,
    /// per-weight-name overrides (e.g. 2-bit token embeddings)
    pub weight_overrides: BTreeMap<String, WeightCfg>,
}

impl PolicySpec {
    /// Everything FP32 (baseline).
    pub fn fp32() -> PolicySpec {
        PolicySpec {
            default_site: SiteCfg { enabled: false, ..Default::default() },
            rules: Vec::new(),
            weights: WeightCfg { enabled: false, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Uniform W{wb}A{ab} per-tensor policy (the paper's W8A8 baseline).
    pub fn uniform(wb: u32, ab: u32) -> PolicySpec {
        PolicySpec {
            default_site: SiteCfg { bits: ab, ..Default::default() },
            rules: Vec::new(),
            weights: WeightCfg { bits: wb, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Activations-only quantization (weights stay FP32) — Table 1 W32A8.
    pub fn acts_only(ab: u32) -> PolicySpec {
        PolicySpec {
            default_site: SiteCfg { bits: ab, ..Default::default() },
            rules: Vec::new(),
            weights: WeightCfg { enabled: false, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Weights-only quantization (activations stay FP32) — Table 1 W8A32.
    pub fn weights_only(wb: u32) -> PolicySpec {
        PolicySpec {
            default_site: SiteCfg { enabled: false, ..Default::default() },
            rules: Vec::new(),
            weights: WeightCfg { bits: wb, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Compile the declarative rules into the imperative per-site policy
    /// for one concrete model topology.
    pub fn resolve(&self, info: &ModelInfo) -> QuantPolicy {
        let mut overrides = BTreeMap::new();
        for rule in &self.rules {
            match &rule.select {
                SiteSelector::Exact(name) => {
                    overrides.insert(name.clone(), rule.cfg.clone());
                }
                SiteSelector::Family(suffix) => {
                    for s in &info.sites {
                        if s.name.ends_with(suffix.as_str()) {
                            overrides.insert(s.name.clone(), rule.cfg.clone());
                        }
                    }
                }
                SiteSelector::FamilyLastLayers { suffix, n } => {
                    let layers = info.config.layers;
                    for i in layers.saturating_sub(*n)..layers {
                        overrides.insert(format!("layer{i}.{suffix}"), rule.cfg.clone());
                    }
                }
            }
        }
        QuantPolicy {
            default: self.default_site.clone(),
            overrides,
            weights: self.weights.clone(),
            weight_overrides: self.weight_overrides.clone(),
        }
    }
}

/// Calibration settings (paper §2 / Appendix B.2), mirroring
/// `coordinator::calibrate::CalibCfg` in serializable form.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibSpec {
    pub estimator: Estimator,
    /// sequences per estimator observation
    pub batch_size: usize,
    /// number of observations
    pub num_batches: usize,
    pub collect_grams: bool,
    /// base data seed; seed index `i` of a multi-seed run calibrates with
    /// `seed + 97 * i`
    pub seed: u64,
}

impl Default for CalibSpec {
    fn default() -> Self {
        // paper Appendix B.2: running min-max with bs=1, nb=16 is the most
        // common best configuration (same default as CalibCfg)
        CalibSpec {
            estimator: Estimator::RunningMinMax,
            batch_size: 1,
            num_batches: 16,
            collect_grams: false,
            seed: 0,
        }
    }
}

/// AdaRound knobs (paper Table 7), mirroring
/// `coordinator::weights::AdaRoundOpts` in serializable form.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaRoundSpec {
    pub enabled: bool,
    pub iters: usize,
    pub lr: f32,
}

impl Default for AdaRoundSpec {
    fn default() -> Self {
        AdaRoundSpec { enabled: false, iters: 1000, lr: 1e-2 }
    }
}

/// QAT settings (paper Tables 6/7), mirroring
/// `coordinator::train::QatCfg` in serializable form. A spec with
/// `qat: Some(..)` runs quantization-aware fine-tuning between
/// calibration and evaluation instead of plain PTQ assembly.
#[derive(Debug, Clone, PartialEq)]
pub struct QatSpec {
    /// parameter learning rate
    pub lr: f32,
    /// quantizer-scale learning rate (scales learn slower)
    pub lr_scales: f32,
    pub epochs: usize,
    /// train batch size; the fixture lowers train graphs at batch 16
    pub batch: usize,
    /// shuffling seed for the train split
    pub seed: u64,
    /// weight-quantizer bit-width during and after training
    pub weight_bits: u32,
    /// embedding-table override (the paper's 2/4-bit embedding rows)
    pub embed_bits: u32,
    /// freeze flag for activation quantizers: false trains/deploys with
    /// activations in FP32 (the W{n}A32 QAT rows)
    pub act_enabled: bool,
}

impl Default for QatSpec {
    fn default() -> Self {
        // same defaults as coordinator::train::QatCfg
        QatSpec {
            lr: 1e-4,
            lr_scales: 1e-5,
            epochs: 1,
            batch: 16,
            seed: 1,
            weight_bits: 8,
            embed_bits: 8,
            act_enabled: true,
        }
    }
}

/// One fully-described quantization experiment. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    /// human label (presets use their registry name); NOT part of
    /// [`QuantSpec::spec_id`], so renaming never invalidates cached results
    pub name: String,
    pub policy: PolicySpec,
    pub calib: CalibSpec,
    pub adaround: AdaRoundSpec,
    /// calibration seeds; the reported score is the median over seeds
    pub seeds: usize,
    /// eval targets by task name; empty = all benchmark tasks
    pub tasks: Vec<String>,
    /// model architecture family the spec targets (selects the fixture
    /// model/artifact/checkpoint family); serialized only when non-BERT
    /// so pre-existing specs keep their `spec_id`
    pub architecture: Architecture,
    /// attention-block variant the spec targets (vanilla, clipped
    /// softmax, gated — the outlier-suppressing model variants);
    /// serialized only when non-vanilla so pre-existing specs keep their
    /// `spec_id`
    pub variant: AttnVariant,
    /// QAT settings; `None` (omitted in JSON) = plain PTQ
    pub qat: Option<QatSpec>,
}

impl QuantSpec {
    pub fn new(name: &str, policy: PolicySpec) -> QuantSpec {
        QuantSpec {
            name: name.to_string(),
            policy,
            calib: CalibSpec::default(),
            adaround: AdaRoundSpec::default(),
            seeds: 3,
            tasks: Vec::new(),
            architecture: Architecture::Bert,
            variant: AttnVariant::Vanilla,
            qat: None,
        }
    }

    /// Append one site rule (builder style).
    pub fn with_rule(mut self, select: SiteSelector, cfg: SiteCfg) -> QuantSpec {
        self.policy.rules.push(SiteRule { select, cfg });
        self
    }

    /// Override every site of a layer family (name suffix match).
    pub fn with_family(self, suffix: &str, cfg: SiteCfg) -> QuantSpec {
        self.with_rule(SiteSelector::Family(suffix.to_string()), cfg)
    }

    /// Override one site by exact name.
    pub fn with_exact(self, name: &str, cfg: SiteCfg) -> QuantSpec {
        self.with_rule(SiteSelector::Exact(name.to_string()), cfg)
    }

    pub fn with_seeds(mut self, seeds: usize) -> QuantSpec {
        self.seeds = seeds;
        self
    }

    /// Relabel the spec (the label is cosmetic — see [`QuantSpec::spec_id`]).
    pub fn named(mut self, name: &str) -> QuantSpec {
        self.name = name.to_string();
        self
    }

    /// Restrict the eval targets.
    pub fn with_tasks(mut self, tasks: &[String]) -> QuantSpec {
        self.tasks = tasks.to_vec();
        self
    }

    /// Target a non-default architecture family.
    pub fn with_architecture(mut self, arch: Architecture) -> QuantSpec {
        self.architecture = arch;
        self
    }

    /// Target a non-default attention variant family.
    pub fn with_variant(mut self, variant: AttnVariant) -> QuantSpec {
        self.variant = variant;
        self
    }

    /// Run QAT between calibration and evaluation.
    pub fn with_qat(mut self, qat: QatSpec) -> QuantSpec {
        self.qat = Some(qat);
        self
    }

    /// True when the spec quantizes nothing anywhere — `run_spec` then
    /// skips calibration entirely (single FP32 eval, like the old
    /// hard-coded FP32 rows).
    pub fn is_fp32(&self) -> bool {
        !self.policy.default_site.enabled
            && self.policy.rules.iter().all(|r| !r.cfg.enabled)
            && !self.policy.weights.enabled
            && self.policy.weight_overrides.values().all(|w| !w.enabled)
    }

    /// Label for progress lines and tables: the name, else a spec-id
    /// prefix.
    pub fn display_name(&self) -> String {
        if self.name.is_empty() {
            format!("spec-{}", &self.spec_id()[..8])
        } else {
            self.name.clone()
        }
    }

    /// Stable content hash of the canonical JSON with the cosmetic `name`
    /// removed. Identical across serialization round-trips and JSON key
    /// order (objects serialize in sorted key order).
    pub fn spec_id(&self) -> String {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("name");
        }
        format!("{:016x}", fnv1a64(j.to_string().as_bytes()))
    }

    // -- JSON --------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("policy", policy_to_json(&self.policy)),
            ("calib", calib_to_json(&self.calib)),
            ("adaround", adaround_to_json(&self.adaround)),
            ("seeds", Json::Num(self.seeds as f64)),
            (
                "tasks",
                Json::Arr(self.tasks.iter().map(|t| Json::Str(t.clone())).collect()),
            ),
        ];
        // all three fields follow the range_method omission rule: the
        // default (BERT, vanilla attention, no QAT) serializes with NO
        // key, so every pre-existing spec is byte-identical to what older
        // code wrote and its spec_id (which keys resumable sweeps and
        // --compare baselines) is stable
        if self.architecture != Architecture::Bert {
            fields.push((
                "architecture",
                Json::Str(self.architecture.name().to_string()),
            ));
        }
        if self.variant != AttnVariant::Vanilla {
            fields.push(("variant", Json::Str(self.variant.name().to_string())));
        }
        if let Some(q) = &self.qat {
            fields.push(("qat", qat_to_json(q)));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<QuantSpec> {
        let seeds = j.get("seeds")?.as_usize()?;
        if seeds == 0 {
            bail!("spec: seeds must be >= 1");
        }
        Ok(QuantSpec {
            name: j.get("name")?.as_str()?.to_string(),
            policy: policy_from_json(j.get("policy")?)?,
            calib: calib_from_json(j.get("calib")?)?,
            adaround: adaround_from_json(j.get("adaround")?)?,
            seeds,
            tasks: j
                .get("tasks")?
                .as_arr()?
                .iter()
                .map(|t| Ok(t.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            // absent in specs written before the architecture axis / QAT
            // section existed
            architecture: match j.opt("architecture") {
                Some(v) => Architecture::parse(v.as_str()?)?,
                None => Architecture::Bert,
            },
            // absent in specs written before the variant axis existed
            variant: match j.opt("variant") {
                Some(v) => AttnVariant::parse(v.as_str()?)?,
                None => AttnVariant::Vanilla,
            },
            qat: match j.opt("qat") {
                Some(v) => Some(qat_from_json(v)?),
                None => None,
            },
        })
    }

    /// Parse a spec from JSON text (e.g. a `--spec` file).
    pub fn parse(text: &str) -> Result<QuantSpec> {
        QuantSpec::from_json(&Json::parse(text)?)
    }
}

// -- enum <-> string codecs ---------------------------------------------

pub fn estimator_name(e: Estimator) -> &'static str {
    match e {
        Estimator::CurrentMinMax => "current",
        Estimator::RunningMinMax => "running",
        Estimator::Mse => "mse",
    }
}

pub fn parse_estimator(s: &str) -> Result<Estimator> {
    match s {
        "current" | "minmax" => Ok(Estimator::CurrentMinMax),
        "running" | "ema" => Ok(Estimator::RunningMinMax),
        "mse" => Ok(Estimator::Mse),
        other => bail!("unknown estimator {other:?} (current|running|mse)"),
    }
}

pub fn granularity_name(g: &Granularity) -> String {
    match g {
        Granularity::PerTensor => "per_tensor".to_string(),
        Granularity::PerEmbedding => "per_embedding".to_string(),
        Granularity::PerEmbeddingGroup { k, permute } => {
            if *permute {
                format!("group:{k}:permute")
            } else {
                format!("group:{k}")
            }
        }
    }
}

pub fn parse_granularity(s: &str) -> Result<Granularity> {
    match s {
        "per_tensor" => return Ok(Granularity::PerTensor),
        "per_embedding" => return Ok(Granularity::PerEmbedding),
        _ => {}
    }
    if let Some(rest) = s.strip_prefix("group:") {
        let (k_str, permute) = match rest.strip_suffix(":permute") {
            Some(k) => (k, true),
            None => (rest, false),
        };
        let k: usize = k_str
            .parse()
            .map_err(|_| anyhow::anyhow!("bad group count in granularity {s:?}"))?;
        if k < 2 {
            bail!("granularity {s:?}: group count must be >= 2");
        }
        return Ok(Granularity::PerEmbeddingGroup { k, permute });
    }
    bail!("unknown granularity {s:?} (per_tensor|per_embedding|group:K[:permute])")
}

pub fn range_method_name(m: RangeMethod) -> &'static str {
    match m {
        RangeMethod::Auto => "auto",
        RangeMethod::CurrentMinMax => "current",
        RangeMethod::MseTensor => "mse_tensor",
        RangeMethod::MsePerGroup => "mse_group",
    }
}

pub fn parse_range_method(s: &str) -> Result<RangeMethod> {
    match s {
        "auto" => Ok(RangeMethod::Auto),
        "current" | "minmax" => Ok(RangeMethod::CurrentMinMax),
        "mse_tensor" => Ok(RangeMethod::MseTensor),
        "mse_group" | "mse_per_group" => Ok(RangeMethod::MsePerGroup),
        other => bail!("unknown range method {other:?} (auto|current|mse_tensor|mse_group)"),
    }
}

fn check_bits(bits: usize, what: &str) -> Result<u32> {
    if !(2..=32).contains(&bits) {
        bail!("{what}: bits must be in 2..=32, got {bits}");
    }
    Ok(bits as u32)
}

// -- component codecs ----------------------------------------------------

fn site_cfg_to_json(c: &SiteCfg) -> Json {
    let mut fields = vec![
        ("bits", Json::Num(c.bits as f64)),
        ("granularity", Json::Str(granularity_name(&c.granularity))),
        ("enabled", Json::Bool(c.enabled)),
    ];
    // Auto (the pre-range_method behaviour) is omitted so specs that do
    // not use the feature serialize byte-identically to pre-PR5 files —
    // keeping their spec_id stable, which keys resumable sweeps and
    // --compare baselines
    if c.range_method != RangeMethod::Auto {
        fields.push((
            "range_method",
            Json::Str(range_method_name(c.range_method).to_string()),
        ));
    }
    obj(fields)
}

fn site_cfg_from_json(j: &Json) -> Result<SiteCfg> {
    Ok(SiteCfg {
        bits: check_bits(j.get("bits")?.as_usize()?, "site cfg")?,
        granularity: parse_granularity(j.get("granularity")?.as_str()?)?,
        // absent in specs written before range_method existed
        range_method: match j.opt("range_method") {
            Some(v) => parse_range_method(v.as_str()?)?,
            None => RangeMethod::Auto,
        },
        enabled: j.get("enabled")?.as_bool()?,
    })
}

fn weight_cfg_to_json(c: &WeightCfg) -> Json {
    obj(vec![
        ("bits", Json::Num(c.bits as f64)),
        ("estimator", Json::Str(estimator_name(c.estimator).to_string())),
        (
            "per_channel_groups",
            match c.per_channel_groups {
                Some(g) => Json::Num(g as f64),
                None => Json::Null,
            },
        ),
        ("enabled", Json::Bool(c.enabled)),
    ])
}

fn weight_cfg_from_json(j: &Json) -> Result<WeightCfg> {
    let groups = match j.get("per_channel_groups")? {
        Json::Null => None,
        v => Some(v.as_usize()?),
    };
    Ok(WeightCfg {
        bits: check_bits(j.get("bits")?.as_usize()?, "weight cfg")?,
        estimator: parse_estimator(j.get("estimator")?.as_str()?)?,
        per_channel_groups: groups,
        enabled: j.get("enabled")?.as_bool()?,
    })
}

fn selector_to_json(s: &SiteSelector) -> Json {
    match s {
        SiteSelector::Exact(name) => obj(vec![("exact", Json::Str(name.clone()))]),
        SiteSelector::Family(suffix) => obj(vec![("family", Json::Str(suffix.clone()))]),
        SiteSelector::FamilyLastLayers { suffix, n } => obj(vec![(
            "family_last_layers",
            obj(vec![
                ("suffix", Json::Str(suffix.clone())),
                ("n", Json::Num(*n as f64)),
            ]),
        )]),
    }
}

fn selector_from_json(j: &Json) -> Result<SiteSelector> {
    if let Some(v) = j.opt("exact") {
        return Ok(SiteSelector::Exact(v.as_str()?.to_string()));
    }
    if let Some(v) = j.opt("family") {
        return Ok(SiteSelector::Family(v.as_str()?.to_string()));
    }
    if let Some(v) = j.opt("family_last_layers") {
        return Ok(SiteSelector::FamilyLastLayers {
            suffix: v.get("suffix")?.as_str()?.to_string(),
            n: v.get("n")?.as_usize()?,
        });
    }
    bail!("site rule needs one of: exact, family, family_last_layers")
}

fn policy_to_json(p: &PolicySpec) -> Json {
    obj(vec![
        ("default_site", site_cfg_to_json(&p.default_site)),
        (
            "rules",
            Json::Arr(
                p.rules
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("select", selector_to_json(&r.select)),
                            ("cfg", site_cfg_to_json(&r.cfg)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("weights", weight_cfg_to_json(&p.weights)),
        (
            "weight_overrides",
            Json::Obj(
                p.weight_overrides
                    .iter()
                    .map(|(k, v)| (k.clone(), weight_cfg_to_json(v)))
                    .collect(),
            ),
        ),
    ])
}

fn policy_from_json(j: &Json) -> Result<PolicySpec> {
    Ok(PolicySpec {
        default_site: site_cfg_from_json(j.get("default_site")?)?,
        rules: j
            .get("rules")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(SiteRule {
                    select: selector_from_json(r.get("select")?)?,
                    cfg: site_cfg_from_json(r.get("cfg")?)?,
                })
            })
            .collect::<Result<_>>()?,
        weights: weight_cfg_from_json(j.get("weights")?)?,
        weight_overrides: j
            .get("weight_overrides")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Ok((k.clone(), weight_cfg_from_json(v)?)))
            .collect::<Result<_>>()?,
    })
}

fn calib_to_json(c: &CalibSpec) -> Json {
    obj(vec![
        ("estimator", Json::Str(estimator_name(c.estimator).to_string())),
        ("batch_size", Json::Num(c.batch_size as f64)),
        ("num_batches", Json::Num(c.num_batches as f64)),
        ("collect_grams", Json::Bool(c.collect_grams)),
        ("seed", Json::Num(c.seed as f64)),
    ])
}

fn calib_from_json(j: &Json) -> Result<CalibSpec> {
    Ok(CalibSpec {
        estimator: parse_estimator(j.get("estimator")?.as_str()?)?,
        batch_size: j.get("batch_size")?.as_usize()?.max(1),
        num_batches: j.get("num_batches")?.as_usize()?.max(1),
        collect_grams: j.get("collect_grams")?.as_bool()?,
        seed: j.get("seed")?.as_u64()?,
    })
}

fn adaround_to_json(a: &AdaRoundSpec) -> Json {
    obj(vec![
        ("enabled", Json::Bool(a.enabled)),
        ("iters", Json::Num(a.iters as f64)),
        ("lr", Json::Num(a.lr as f64)),
    ])
}

fn adaround_from_json(j: &Json) -> Result<AdaRoundSpec> {
    Ok(AdaRoundSpec {
        enabled: j.get("enabled")?.as_bool()?,
        iters: j.get("iters")?.as_usize()?,
        lr: j.get("lr")?.as_f64()? as f32,
    })
}

fn qat_to_json(q: &QatSpec) -> Json {
    obj(vec![
        ("lr", Json::Num(q.lr as f64)),
        ("lr_scales", Json::Num(q.lr_scales as f64)),
        ("epochs", Json::Num(q.epochs as f64)),
        ("batch", Json::Num(q.batch as f64)),
        ("seed", Json::Num(q.seed as f64)),
        ("weight_bits", Json::Num(q.weight_bits as f64)),
        ("embed_bits", Json::Num(q.embed_bits as f64)),
        ("act_enabled", Json::Bool(q.act_enabled)),
    ])
}

fn qat_from_json(j: &Json) -> Result<QatSpec> {
    Ok(QatSpec {
        lr: j.get("lr")?.as_f64()? as f32,
        lr_scales: j.get("lr_scales")?.as_f64()? as f32,
        epochs: j.get("epochs")?.as_usize()?,
        batch: j.get("batch")?.as_usize()?,
        seed: j.get("seed")?.as_u64()?,
        weight_bits: check_bits(j.get("weight_bits")?.as_usize()?, "qat")?,
        embed_bits: check_bits(j.get("embed_bits")?.as_usize()?, "qat")?,
        act_enabled: j.get("act_enabled")?.as_bool()?,
    })
}

/// FNV-1a 64-bit — tiny, stable, dependency-free content hash. Also keys
/// the sweep's deterministic `--shard i/n` partition (over `spec_id`).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;

    fn kitchen_sink() -> QuantSpec {
        let mut spec = QuantSpec::new("sink", PolicySpec::uniform(4, 8))
            .with_family(
                "res2_sum",
                SiteCfg {
                    bits: 8,
                    granularity: Granularity::PerEmbeddingGroup { k: 4, permute: true },
                    range_method: RangeMethod::MsePerGroup,
                    enabled: true,
                },
            )
            .with_exact("head_out", SiteCfg { bits: 16, ..Default::default() })
            .with_rule(
                SiteSelector::FamilyLastLayers { suffix: "ffn_out".into(), n: 2 },
                SiteCfg { enabled: false, ..Default::default() },
            )
            .with_seeds(5);
        spec.policy.weights.estimator = Estimator::Mse;
        spec.policy.weights.per_channel_groups = Some(16);
        spec.policy.weight_overrides.insert(
            "embed.tok".into(),
            WeightCfg { bits: 2, estimator: Estimator::Mse, ..Default::default() },
        );
        spec.calib = CalibSpec {
            estimator: Estimator::CurrentMinMax,
            batch_size: 2,
            num_batches: 4,
            collect_grams: true,
            seed: 7,
        };
        spec.adaround = AdaRoundSpec { enabled: true, iters: 250, lr: 2e-2 };
        spec.tasks = vec!["mnli".into(), "rte".into()];
        spec
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let spec = kitchen_sink();
        let text = spec.to_json().to_string();
        let back = QuantSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        // canonical serialization is a fixed point
        assert_eq!(back.to_json().to_string(), text);
        assert_eq!(back.spec_id(), spec.spec_id());
    }

    #[test]
    fn spec_id_ignores_key_order_and_name() {
        let spec = kitchen_sink();
        // scrambled key order parses to the same spec (objects are maps)
        let scrambled = format!(
            r#"{{"tasks": ["mnli", "rte"], "seeds": 5, "name": "sink",
                "adaround": {}, "calib": {}, "policy": {}}}"#,
            adaround_to_json(&spec.adaround),
            calib_to_json(&spec.calib),
            policy_to_json(&spec.policy),
        );
        let back = QuantSpec::parse(&scrambled).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.spec_id(), spec.spec_id());

        // the label is cosmetic
        let mut renamed = spec.clone();
        renamed.name = "something else".into();
        assert_eq!(renamed.spec_id(), spec.spec_id());

        // ... but the policy is not
        let mut changed = spec.clone();
        changed.policy.weights.bits = 8;
        assert_ne!(changed.spec_id(), spec.spec_id());
        let mut reseeded = spec;
        reseeded.calib.seed = 8;
        assert_ne!(reseeded.spec_id(), changed.spec_id());
    }

    #[test]
    fn resolve_applies_rules_in_order() {
        let info = tiny_model_info(); // sites: embed_sum, layer0.res2_sum, head_out
        let spec = kitchen_sink();
        let policy = spec.policy.resolve(&info);
        // family rule hit layer0.res2_sum
        assert_eq!(
            policy.site_cfg("layer0.res2_sum").granularity,
            Granularity::PerEmbeddingGroup { k: 4, permute: true }
        );
        // exact rule hit head_out
        assert_eq!(policy.site_cfg("head_out").bits, 16);
        // last-layers rule synthesized layer names even off-topology
        assert!(!policy.site_cfg("layer0.ffn_out").enabled);
        // untouched sites use the default
        assert_eq!(policy.site_cfg("embed_sum").bits, 8);
        assert!(policy.site_cfg("embed_sum").enabled);
        assert_eq!(policy.weight_cfg("embed.tok").bits, 2);
        assert_eq!(policy.weight_cfg("layer0.ffn1.w").bits, 4);
    }

    #[test]
    fn later_rules_overwrite_earlier() {
        let info = tiny_model_info();
        let spec = QuantSpec::new("o", PolicySpec::uniform(8, 8))
            .with_family("res2_sum", SiteCfg { bits: 16, ..Default::default() })
            .with_exact("layer0.res2_sum", SiteCfg { enabled: false, ..Default::default() });
        let policy = spec.policy.resolve(&info);
        assert!(!policy.site_cfg("layer0.res2_sum").enabled);
    }

    #[test]
    fn is_fp32_detection() {
        assert!(QuantSpec::new("f", PolicySpec::fp32()).is_fp32());
        assert!(!QuantSpec::new("q", PolicySpec::uniform(8, 8)).is_fp32());
        assert!(!QuantSpec::new("a", PolicySpec::acts_only(8)).is_fp32());
        assert!(!QuantSpec::new("w", PolicySpec::weights_only(8)).is_fp32());
        // a disabled-everything rule set still counts as fp32
        let off = QuantSpec::new("o", PolicySpec::fp32())
            .with_family("res2_sum", SiteCfg { enabled: false, ..Default::default() });
        assert!(off.is_fp32());
        // one enabled rule flips it
        let on = QuantSpec::new("o", PolicySpec::fp32())
            .with_family("res2_sum", SiteCfg::default());
        assert!(!on.is_fp32());
    }

    #[test]
    fn granularity_codec_roundtrip() {
        for g in [
            Granularity::PerTensor,
            Granularity::PerEmbedding,
            Granularity::PerEmbeddingGroup { k: 8, permute: false },
            Granularity::PerEmbeddingGroup { k: 4, permute: true },
        ] {
            assert_eq!(parse_granularity(&granularity_name(&g)).unwrap(), g);
        }
        assert!(parse_granularity("group:1").is_err());
        assert!(parse_granularity("group:x").is_err());
        assert!(parse_granularity("per_token").is_err());
    }

    #[test]
    fn estimator_codec_roundtrip() {
        for e in [Estimator::CurrentMinMax, Estimator::RunningMinMax, Estimator::Mse] {
            assert_eq!(parse_estimator(estimator_name(e)).unwrap(), e);
        }
        assert!(parse_estimator("median").is_err());
    }

    #[test]
    fn range_method_codec_roundtrip_and_back_compat() {
        for m in [
            RangeMethod::Auto,
            RangeMethod::CurrentMinMax,
            RangeMethod::MseTensor,
            RangeMethod::MsePerGroup,
        ] {
            assert_eq!(parse_range_method(range_method_name(m)).unwrap(), m);
        }
        assert!(parse_range_method("mse").is_err());
        // a pre-range_method site cfg (no key) parses as Auto
        let legacy = Json::parse(
            r#"{"bits": 8, "granularity": "per_tensor", "enabled": true}"#,
        )
        .unwrap();
        let cfg = site_cfg_from_json(&legacy).unwrap();
        assert_eq!(cfg.range_method, RangeMethod::Auto);
        assert_eq!(cfg, SiteCfg::default());
        // and the reverse: Auto serializes with NO range_method key, so a
        // spec that does not use the feature is byte-identical to what
        // pre-range_method code wrote — its spec_id (which keys resumable
        // sweeps and --compare baselines) must not churn
        let auto_json = site_cfg_to_json(&SiteCfg::default()).to_string();
        assert!(!auto_json.contains("range_method"), "{auto_json}");
        assert_eq!(auto_json, legacy.to_string());
        let non_auto = SiteCfg { range_method: RangeMethod::MsePerGroup, ..Default::default() };
        assert!(site_cfg_to_json(&non_auto).to_string().contains("mse_group"));
    }

    #[test]
    fn architecture_and_qat_codec_roundtrip_and_back_compat() {
        // the default (BERT, no QAT) serializes with NEITHER key, so every
        // spec written before the architecture/qat sections existed is
        // byte-identical to what current code writes — its spec_id (which
        // keys resumable sweeps and --compare baselines) must not churn
        let plain = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8));
        let plain_json = plain.to_json().to_string();
        assert!(!plain_json.contains("architecture"), "{plain_json}");
        assert!(!plain_json.contains("qat"), "{plain_json}");
        let reparsed = QuantSpec::parse(&plain_json).unwrap();
        assert_eq!(reparsed.architecture, Architecture::Bert);
        assert!(reparsed.qat.is_none());
        assert_eq!(reparsed.spec_id(), plain.spec_id());

        // non-default values round-trip and change the identity
        let vit_qat = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8))
            .with_architecture(Architecture::Vit)
            .with_qat(QatSpec { epochs: 2, act_enabled: false, ..Default::default() });
        let j = vit_qat.to_json().to_string();
        assert!(j.contains("\"architecture\":\"vit\""), "{j}");
        assert!(j.contains("\"act_enabled\":false"), "{j}");
        let rt = QuantSpec::parse(&j).unwrap();
        assert_eq!(rt.architecture, Architecture::Vit);
        assert_eq!(rt.qat.as_ref().unwrap().epochs, 2);
        assert!(!rt.qat.as_ref().unwrap().act_enabled);
        assert_eq!(rt.spec_id(), vit_qat.spec_id());
        assert_ne!(vit_qat.spec_id(), plain.spec_id());

        // qat is hashed: same policy, different qat => different spec_id
        let qat_default = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8))
            .with_qat(QatSpec::default());
        assert_ne!(qat_default.spec_id(), plain.spec_id());
        assert_ne!(qat_default.spec_id(), vit_qat.spec_id());

        // malformed values are rejected
        assert!(Architecture::parse("rnn").is_err());
        let bad = j.replace("\"weight_bits\":8", "\"weight_bits\":64");
        assert!(QuantSpec::parse(&bad).is_err());
    }

    #[test]
    fn variant_codec_roundtrip_and_back_compat() {
        // vanilla (the default) serializes with NO "variant" key, so every
        // spec written before the variant axis existed is byte-identical
        // to what current code writes — its spec_id must not churn
        let plain = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8));
        let plain_json = plain.to_json().to_string();
        assert!(!plain_json.contains("variant"), "{plain_json}");
        let reparsed = QuantSpec::parse(&plain_json).unwrap();
        assert_eq!(reparsed.variant, AttnVariant::Vanilla);
        assert_eq!(reparsed.spec_id(), plain.spec_id());

        // non-default variants round-trip and change the identity
        for (v, name) in [
            (AttnVariant::ClippedSoftmax, "clipped_softmax"),
            (AttnVariant::Gated, "gated"),
        ] {
            let spec = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8)).with_variant(v);
            let j = spec.to_json().to_string();
            assert!(j.contains(&format!("\"variant\":\"{name}\"")), "{j}");
            let rt = QuantSpec::parse(&j).unwrap();
            assert_eq!(rt.variant, v);
            assert_eq!(rt.spec_id(), spec.spec_id());
            assert_ne!(spec.spec_id(), plain.spec_id());
        }

        // the two axes compose: a ViT clipped-softmax spec differs from
        // both single-axis specs
        let vit_csoft = QuantSpec::new("w8a8", PolicySpec::uniform(8, 8))
            .with_architecture(Architecture::Vit)
            .with_variant(AttnVariant::ClippedSoftmax);
        let j = vit_csoft.to_json().to_string();
        assert!(j.contains("\"architecture\":\"vit\""), "{j}");
        assert!(j.contains("\"variant\":\"clipped_softmax\""), "{j}");
        let rt = QuantSpec::parse(&j).unwrap();
        assert_eq!(rt.variant, AttnVariant::ClippedSoftmax);
        assert_eq!(rt.architecture, Architecture::Vit);

        // malformed variants are rejected
        assert!(AttnVariant::parse("softclip").is_err());
        let bad = j.replace("clipped_softmax", "softclip");
        assert!(QuantSpec::parse(&bad).is_err());
    }

    #[test]
    fn rejects_malformed_specs() {
        // missing keys
        assert!(QuantSpec::parse("{}").is_err());
        // bad bits
        let mut spec = QuantSpec::new("b", PolicySpec::uniform(8, 8));
        spec.policy.default_site.bits = 64;
        let j = spec.to_json().to_string();
        // 64 survives serialization, parsing rejects it
        assert!(QuantSpec::parse(&j).is_err());
        // zero seeds
        let mut z = QuantSpec::new("z", PolicySpec::uniform(8, 8));
        z.seeds = 0;
        assert!(QuantSpec::parse(&z.to_json().to_string()).is_err());
    }

    #[test]
    fn display_name_falls_back_to_id() {
        let mut spec = QuantSpec::new("", PolicySpec::uniform(8, 8));
        assert!(spec.display_name().starts_with("spec-"));
        spec.name = "w8a8".into();
        assert_eq!(spec.display_name(), "w8a8");
    }
}
