//! Named presets: the paper's quantization configurations as specs.
//!
//! Each preset reproduces *exactly* the `QuantPolicy` the pre-spec
//! hard-coded drivers built (asserted in the tests below), so
//! `repro run --preset w8a8` and the Table 1 W8A8 row are the same
//! experiment.

use anyhow::{bail, Result};

use super::{AdaRoundSpec, PolicySpec, QatSpec, QuantSpec};
use crate::model::qconfig::{SiteCfg, WeightCfg};
use crate::quant::{Estimator, Granularity, RangeMethod};

/// (name, description) for every registered preset.
pub const PRESETS: [(&str, &str); 19] = [
    ("fp32", "FP32 baseline, no quantization"),
    ("w8a8", "standard W8A8 per-tensor PTQ (Table 1)"),
    ("w32a8", "8-bit activations only, FP32 weights (Table 1)"),
    ("w8a32", "8-bit weights only, FP32 activations (Table 1)"),
    ("mixed_precision", "W8A{8,16} MP-PTQ, 16-bit on problematic activations (Table 4 best)"),
    ("peg_k8_permute", "W8A8 PEG-PTQ, K=8 + permutation on FFN sites (Tables 5/6 best)"),
    ("peg_k4_permute", "W8A8 PEG-PTQ, K=4 + permutation on FFN sites (Table 5)"),
    ("peg_k6_permute", "W8A8 PEG-PTQ, K=6 + permutation on FFN sites (paper Table 3/5 row)"),
    ("peg_k12_permute", "W8A8 PEG-PTQ, K=12 + permutation on FFN sites (paper Table 3 row)"),
    ("peg_k6_mse", "W8A8 PEG-PTQ, K=6 + permutation with per-group MSE ranges (mse_group)"),
    ("w6a32", "6-bit MSE weights + 6-bit embeddings (Table 7)"),
    ("w4a32", "4-bit MSE weights + 4-bit embeddings (Table 7)"),
    ("w4a32_adaround", "4-bit AdaRound weights (Table 7)"),
    ("w8a32_embed4", "8-bit weights, 4-bit token embeddings (Table 7)"),
    ("w8a32_embed2", "8-bit weights, 2-bit token embeddings (Table 7)"),
    ("w8a8_qat", "W8A8 quantization-aware finetuning (Table 6)"),
    ("w4a32_qat", "W4A32 QAT, activations FP32 (Table 7)"),
    ("w4a8_qat", "W4A8 QAT (Table 7)"),
    ("w4a8_embed2_qat", "W4A8 QAT with 2-bit token embeddings (Table 7)"),
];

pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, _)| *n).collect()
}

/// Build a preset spec by registry name.
pub fn preset(name: &str) -> Result<QuantSpec> {
    let spec = match name {
        "fp32" => QuantSpec::new("fp32", PolicySpec::fp32()),
        "w8a8" => QuantSpec::new("w8a8", PolicySpec::uniform(8, 8)),
        "w32a8" => QuantSpec::new("w32a8", PolicySpec::acts_only(8)),
        "w8a32" => QuantSpec::new("w8a32", PolicySpec::weights_only(8)),
        "mixed_precision" => mixed_precision(),
        "peg_k8_permute" => peg_ffn(8, true, RangeMethod::Auto, "peg_k8_permute"),
        "peg_k4_permute" => peg_ffn(4, true, RangeMethod::Auto, "peg_k4_permute"),
        "peg_k6_permute" => peg_ffn(6, true, RangeMethod::Auto, "peg_k6_permute"),
        "peg_k12_permute" => peg_ffn(12, true, RangeMethod::Auto, "peg_k12_permute"),
        "peg_k6_mse" => peg_ffn(6, true, RangeMethod::MsePerGroup, "peg_k6_mse"),
        "w6a32" => low_bit_weights("w6a32", 6, 6, false),
        "w4a32" => low_bit_weights("w4a32", 4, 4, false),
        "w4a32_adaround" => low_bit_weights("w4a32_adaround", 4, 4, true),
        "w8a32_embed4" => low_bit_weights("w8a32_embed4", 8, 4, false),
        "w8a32_embed2" => low_bit_weights("w8a32_embed2", 8, 2, false),
        "w8a8_qat" => qat_preset("w8a8_qat", 8, 8, true),
        "w4a32_qat" => qat_preset("w4a32_qat", 4, 4, false),
        "w4a8_qat" => qat_preset("w4a8_qat", 4, 4, true),
        "w4a8_embed2_qat" => qat_preset("w4a8_embed2_qat", 4, 2, true),
        other => bail!(
            "unknown preset {other:?} (available: {})",
            preset_names().join(", ")
        ),
    };
    Ok(spec)
}

/// The best mixed-precision policy from Table 4: everything the paper's
/// footnotes list kept at 16 bits.
fn mixed_precision() -> QuantSpec {
    let a16 = SiteCfg { bits: 16, ..Default::default() };
    QuantSpec::new("mixed_precision", PolicySpec::uniform(8, 8))
        .with_family("res2_sum", a16.clone())
        .with_family("ln1_out", a16.clone())
        .with_family("ffn_out", a16.clone())
        .with_exact("head_out", a16.clone())
        .with_exact("pooled", a16)
}

/// The paper's chosen PEG config: K groups (+ permutation) on the FFN
/// input/output/residual-sum sites, ranges per `method` (`Auto` = the
/// tracked estimator bounds, `MsePerGroup` = one grid search per group).
/// K need not divide the embedding dim — groups split near-evenly, so
/// the paper's K=6/K=12 rows work at any d.
fn peg_ffn(k: usize, permute: bool, method: RangeMethod, name: &str) -> QuantSpec {
    let peg = SiteCfg {
        bits: 8,
        granularity: Granularity::PerEmbeddingGroup { k, permute },
        range_method: method,
        enabled: true,
    };
    QuantSpec::new(name, PolicySpec::uniform(8, 8))
        .with_family("res2_sum", peg.clone())
        .with_family("ln1_out", peg.clone())
        .with_family("ffn_out", peg)
}

/// Table 7 rows: W{wb}A32 with MSE weight ranges and a {eb}-bit MSE
/// token-embedding override, optionally with AdaRound.
fn low_bit_weights(name: &str, wb: u32, eb: u32, adaround: bool) -> QuantSpec {
    let mut policy = PolicySpec::weights_only(8);
    policy.weights = WeightCfg { bits: wb, estimator: Estimator::Mse, ..Default::default() };
    policy.weight_overrides.insert(
        "embed.tok".to_string(),
        WeightCfg { bits: eb, estimator: Estimator::Mse, ..Default::default() },
    );
    let mut spec = QuantSpec::new(name, policy);
    if adaround {
        spec.calib.collect_grams = true;
        spec.adaround = AdaRoundSpec { enabled: true, ..Default::default() };
        spec.seeds = 1;
    }
    spec
}

/// Tables 6/7 QAT rows as data: the `qat` section carries the training
/// hyper-parameters (bit widths, LRs, epochs — what the old hard-coded
/// `run_qat_eval` drivers passed to `QatCfg`); the policy mirrors the
/// deployed numeric format for memory accounting and display. Epochs stay
/// at the `QatSpec` default — the table drivers raise them for full runs.
/// Single-seed: QAT's own `seed` pins the data order and init.
fn qat_preset(name: &str, wb: u32, eb: u32, act: bool) -> QuantSpec {
    let mut policy = if act { PolicySpec::uniform(wb, 8) } else { PolicySpec::weights_only(wb) };
    policy.weights.estimator = Estimator::Mse;
    policy.weight_overrides.insert(
        "embed.tok".to_string(),
        WeightCfg { bits: eb, estimator: Estimator::Mse, ..Default::default() },
    );
    QuantSpec::new(name, policy)
        .with_qat(QatSpec { weight_bits: wb, embed_bits: eb, act_enabled: act, ..Default::default() })
        .with_seeds(1)
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;
    use std::collections::BTreeSet;

    use super::*;
    use crate::model::manifest::tests::tiny_model_info;
    use crate::model::qconfig::QuantPolicy;

    // -- the exact policies the pre-spec hard-coded drivers built --------

    fn old_w32a8(bits: u32) -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { bits, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { enabled: false, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    fn old_w8a32() -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { enabled: false, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { bits: 8, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    fn old_best_mp(info: &crate::model::manifest::ModelInfo) -> QuantPolicy {
        let a16 = SiteCfg { bits: 16, ..Default::default() };
        QuantPolicy::uniform(8, 8)
            .with_site_family(info, "res2_sum", a16.clone())
            .with_site_family(info, "ln1_out", a16.clone())
            .with_site_family(info, "ffn_out", a16.clone())
            .with_sites(&["head_out", "pooled"], a16)
    }

    fn old_best_peg(info: &crate::model::manifest::ModelInfo) -> QuantPolicy {
        let peg = SiteCfg {
            granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
            ..Default::default()
        };
        QuantPolicy::uniform(8, 8)
            .with_site_family(info, "res2_sum", peg.clone())
            .with_site_family(info, "ln1_out", peg.clone())
            .with_site_family(info, "ffn_out", peg)
    }

    fn old_table7_ptq(wb: u32, eb: u32) -> QuantPolicy {
        let mut p = old_w8a32();
        p.weights = WeightCfg { bits: wb, estimator: Estimator::Mse, ..Default::default() };
        p.weight_overrides.insert(
            "embed.tok".into(),
            WeightCfg { bits: eb, estimator: Estimator::Mse, ..Default::default() },
        );
        p
    }

    #[test]
    fn presets_reproduce_the_hard_coded_policies() {
        let info = tiny_model_info();
        let cases: Vec<(&str, QuantPolicy)> = vec![
            ("fp32", QuantPolicy::fp32()),
            ("w8a8", QuantPolicy::uniform(8, 8)),
            ("w32a8", old_w32a8(8)),
            ("w8a32", old_w8a32()),
            ("mixed_precision", old_best_mp(&info)),
            ("peg_k8_permute", old_best_peg(&info)),
            ("w6a32", old_table7_ptq(6, 6)),
            ("w4a32", old_table7_ptq(4, 4)),
            ("w4a32_adaround", old_table7_ptq(4, 4)),
            ("w8a32_embed4", old_table7_ptq(8, 4)),
            ("w8a32_embed2", old_table7_ptq(8, 2)),
        ];
        for (name, old) in cases {
            let spec = preset(name).unwrap();
            assert_eq!(spec.policy.resolve(&info), old, "preset {name}");
        }
    }

    #[test]
    fn old_mp_exact_sites_and_preset_agree_per_site() {
        // with_sites() inserted head_out/pooled unconditionally; the
        // preset's Exact rules must do the same
        let info = tiny_model_info();
        let mp = preset("mixed_precision").unwrap().policy.resolve(&info);
        assert_eq!(mp.site_cfg("head_out").bits, 16);
        assert_eq!(mp.site_cfg("pooled").bits, 16);
        assert_eq!(mp.site_cfg("layer0.res2_sum").bits, 16);
        assert_eq!(mp.site_cfg("embed_sum").bits, 8);
    }

    #[test]
    fn adaround_preset_sets_calibration_knobs() {
        let spec = preset("w4a32_adaround").unwrap();
        assert!(spec.adaround.enabled);
        assert!(spec.calib.collect_grams);
        assert_eq!(spec.seeds, 1);
        let plain = preset("w4a32").unwrap();
        assert!(!plain.adaround.enabled);
        assert_ne!(spec.spec_id(), plain.spec_id());
    }

    #[test]
    fn every_preset_loads_and_ids_are_unique() {
        let mut ids = BTreeSet::new();
        for name in preset_names() {
            let spec = preset(name).unwrap();
            assert_eq!(spec.name, name);
            assert!(ids.insert(spec.spec_id()), "duplicate spec_id for {name}");
            // and every preset survives the JSON round-trip
            let back = QuantSpec::parse(&spec.to_json().to_string()).unwrap();
            assert_eq!(back, spec);
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn qat_presets_mirror_the_hard_coded_qat_cfg() {
        use super::super::QatSpec;
        // (name, weight_bits, embed_bits, act_enabled) — the exact QatCfg
        // fields the old run_qat_eval{,_a32} drivers hard-coded
        for (name, wb, eb, act) in [
            ("w8a8_qat", 8u32, 8u32, true),
            ("w4a32_qat", 4, 4, false),
            ("w4a8_qat", 4, 4, true),
            ("w4a8_embed2_qat", 4, 2, true),
        ] {
            let spec = preset(name).unwrap();
            let q = spec.qat.as_ref().unwrap_or_else(|| panic!("{name}: no qat section"));
            assert_eq!((q.weight_bits, q.embed_bits, q.act_enabled), (wb, eb, act), "{name}");
            // training hyper-parameters inherit the QatCfg defaults
            let d = QatSpec::default();
            assert_eq!((q.lr, q.lr_scales, q.epochs, q.batch, q.seed), (d.lr, d.lr_scales, d.epochs, d.batch, d.seed), "{name}");
            assert_eq!(spec.seeds, 1, "{name}");
        }
        // non-QAT presets carry no qat section (their spec_ids predate it)
        for name in ["fp32", "w8a8", "peg_k8_permute", "w4a32"] {
            assert!(preset(name).unwrap().qat.is_none(), "{name}");
        }
    }

    #[test]
    fn fp32_preset_is_fp32() {
        assert!(preset("fp32").unwrap().is_fp32());
        assert!(!preset("w8a8").unwrap().is_fp32());
        assert!(!preset("w8a32").unwrap().is_fp32());
    }

    #[test]
    fn peg_presets_cover_the_paper_k_rows() {
        use crate::quant::RangeMethod;
        let info = tiny_model_info();
        for (name, k) in [("peg_k6_permute", 6usize), ("peg_k12_permute", 12)] {
            let policy = preset(name).unwrap().policy.resolve(&info);
            let cfg = policy.site_cfg("layer0.res2_sum");
            assert_eq!(
                cfg.granularity,
                Granularity::PerEmbeddingGroup { k, permute: true },
                "{name}"
            );
            assert_eq!(cfg.range_method, RangeMethod::Auto, "{name}");
            // non-FFN sites stay per-tensor
            assert_eq!(policy.site_cfg("embed_sum").granularity, Granularity::PerTensor);
        }
        // the mse_group preset differs from its Auto twin only in the
        // range method — and hashes distinctly
        let auto = preset("peg_k6_permute").unwrap();
        let mse = preset("peg_k6_mse").unwrap();
        let mse_policy = mse.policy.resolve(&info);
        assert_eq!(
            mse_policy.site_cfg("layer0.res2_sum").range_method,
            RangeMethod::MsePerGroup
        );
        assert_ne!(auto.spec_id(), mse.spec_id());
    }
}
