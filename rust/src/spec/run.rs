//! The one pipeline every driver routes through: resolve a [`QuantSpec`]
//! against the task's model, then calibrate → weight-QDQ → assemble
//! activation tensors → dev-eval, median over calibration seeds.
//!
//! `repro table*`, `repro sweep` and `repro run --spec` all call into
//! here, so a configuration behaves identically no matter which surface
//! launched it.

use std::collections::BTreeMap;

use anyhow::Result;

use anyhow::bail;

use super::QuantSpec;
use crate::coordinator::calibrate::{calibrate_with_var, CalibCfg};
use crate::coordinator::eval::evaluate_var;
use crate::coordinator::experiments::load_ckpt_var;
use crate::coordinator::train::{qat, qat_deployed_params, QatCfg};
use crate::coordinator::weights::{quantize_weights, AdaRoundCfg2, AdaRoundOpts};
use crate::coordinator::{fwd_artifact_var, Ctx};
use crate::data::{task_spec, TaskSpec, TASKS};
use crate::metrics::{glue_score, median};
use crate::model::manifest::{Architecture, AttnVariant};
use crate::model::qconfig::{
    assemble_act_tensors, assemble_act_tensors_pool, ActQuantTensors, QuantPolicy,
};
use crate::model::Params;
use crate::util::json::Json;

/// Result of running one spec: per-task scores in eval order plus the
/// GLUE-style average, keyed by the spec's content hash.
#[derive(Debug, Clone)]
pub struct SpecReport {
    pub spec_id: String,
    pub name: String,
    /// task names in eval order
    pub tasks: Vec<String>,
    /// dev scores ×100, parallel to `tasks`
    pub scores: Vec<f64>,
    /// macro average over the evaluated tasks
    pub glue: f64,
}

impl SpecReport {
    pub fn score_for(&self, task: &str) -> Option<f64> {
        self.tasks
            .iter()
            .position(|t| t == task)
            .map(|i| self.scores[i])
    }

    pub fn to_json(&self) -> Json {
        let mut scores = BTreeMap::new();
        for (t, s) in self.tasks.iter().zip(&self.scores) {
            scores.insert(t.clone(), Json::Num(*s));
        }
        let mut m = BTreeMap::new();
        m.insert("spec_id".to_string(), Json::Str(self.spec_id.clone()));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("scores".to_string(), Json::Obj(scores));
        m.insert("glue".to_string(), Json::Num(self.glue));
        Json::Obj(m)
    }
}

/// Resolve a spec's eval targets (empty = every benchmark task).
pub fn spec_tasks(spec: &QuantSpec) -> Result<Vec<TaskSpec>> {
    if spec.tasks.is_empty() {
        Ok(TASKS.to_vec())
    } else {
        spec.tasks.iter().map(|n| task_spec(n)).collect()
    }
}

/// Run a spec end-to-end over its eval targets, loading each task's
/// checkpoint for the spec's architecture family.
pub fn run_spec(ctx: &Ctx, spec: &QuantSpec) -> Result<SpecReport> {
    let tasks = spec_tasks(spec)?;
    let label = spec.display_name();
    let mut names = Vec::with_capacity(tasks.len());
    let mut scores = Vec::with_capacity(tasks.len());
    for task in &tasks {
        let params = load_ckpt_var(ctx, task, spec.architecture, spec.variant)?;
        let score = run_spec_on(ctx, spec, task, &params)?;
        println!("  [{label}] {}: {score:.2}", task.name);
        names.push(task.name.to_string());
        scores.push(score);
    }
    Ok(SpecReport {
        spec_id: spec.spec_id(),
        name: spec.name.clone(),
        glue: glue_score(&scores),
        tasks: names,
        scores,
    })
}

/// The core pipeline on one task with the given (FP32) parameters:
/// calibrate → quantize weights → assemble activation tensors → dev eval,
/// median over `spec.seeds` calibration seeds. FP32 specs skip
/// calibration and evaluate once.
pub fn run_spec_on(
    ctx: &Ctx,
    spec: &QuantSpec,
    task: &TaskSpec,
    params: &Params,
) -> Result<f64> {
    if spec.qat.is_some() {
        return run_qat_spec_on(ctx, spec, task, params);
    }
    if spec.is_fp32() {
        let (qp, act) = assemble_once(ctx, spec, task, params, 0)?;
        return evaluate_var(ctx, task, spec.architecture, spec.variant, &qp, &act);
    }
    let seeds = spec.seeds.max(1);
    let mut scores = Vec::with_capacity(seeds);
    for seed in 0..seeds {
        let (qp, act) = assemble_once(ctx, spec, task, params, seed)?;
        scores.push(evaluate_var(ctx, task, spec.architecture, spec.variant, &qp, &act)?);
    }
    Ok(median(&scores))
}

/// The QAT pipeline for specs carrying a `qat` section (paper Tables
/// 6/7): PTQ-init calibration → straight-through QAT → deploy-eval with
/// the learned quantizers. Reproduces the old hard-coded
/// `run_qat_eval{,_a32}` drivers exactly: the activation-range init is
/// always the uniform-8-bit policy (both drivers did this, even for the
/// W{n}A32 rows), and `act_enabled: false` evaluates under FP32
/// activations. The train-step artifacts only exist for the BERT
/// frontend, so ViT QAT is rejected, not silently skipped.
fn run_qat_spec_on(
    ctx: &Ctx,
    spec: &QuantSpec,
    task: &TaskSpec,
    params: &Params,
) -> Result<f64> {
    let q = spec.qat.as_ref().expect("caller checked spec.qat");
    if spec.architecture != Architecture::Bert {
        bail!(
            "spec {}: QAT requires train-step artifacts, which exist only for the BERT frontend (got {})",
            spec.display_name(),
            spec.architecture.name()
        );
    }
    if spec.variant != AttnVariant::Vanilla {
        bail!(
            "spec {}: QAT requires train-step artifacts, which exist only for the vanilla attention variant (got {})",
            spec.display_name(),
            spec.variant.name()
        );
    }
    let info = ctx.model_info(task)?;
    let calib = calibrate_with_var(
        ctx,
        task,
        spec.architecture,
        spec.variant,
        params,
        &CalibCfg::default(),
        None,
    )?;
    let act = assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers)?;
    let cfg = QatCfg {
        lr: q.lr,
        lr_scales: q.lr_scales,
        epochs: q.epochs,
        batch: q.batch,
        seed: q.seed,
        weight_bits: q.weight_bits,
        embed_bits: q.embed_bits,
        act_enabled: q.act_enabled,
        ..Default::default()
    };
    let res = qat(ctx, task, params, &act, &cfg)?;
    let (qp, qact) = qat_deployed_params(info, &res, q.weight_bits, q.embed_bits)?;
    if q.act_enabled {
        evaluate_var(ctx, task, spec.architecture, spec.variant, &qp, &qact)
    } else {
        let fp32_act = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
        evaluate_var(ctx, task, spec.architecture, spec.variant, &qp, &fp32_act)
    }
}

/// One calibration seed's assembly, without the eval: calibrate →
/// weight-QDQ → activation-quantizer tensors. Returns the (possibly
/// QDQ'd) parameters plus the flat activation tensors — everything a
/// forward executable needs beyond the per-batch inputs. FP32 specs skip
/// calibration and return the parameters unchanged with quantization
/// disabled at every site. [`run_spec_on`] medians evals of this over
/// seeds; the serving layer caches its output per spec_id.
pub fn assemble_once(
    ctx: &Ctx,
    spec: &QuantSpec,
    task: &TaskSpec,
    params: &Params,
    seed: usize,
) -> Result<(Params, ActQuantTensors)> {
    let info = ctx.model_info_var(task, spec.architecture, spec.variant)?;
    let policy = spec.policy.resolve(info);
    if spec.is_fp32() {
        let act = assemble_act_tensors(info, &policy, &BTreeMap::new())?;
        return Ok((params.clone(), act));
    }
    let ada = AdaRoundOpts {
        enabled: spec.adaround.enabled,
        cfg: AdaRoundCfg2 { iters: spec.adaround.iters, lr: spec.adaround.lr },
    };
    let calib_cfg = CalibCfg {
        estimator: spec.calib.estimator,
        batch_size: spec.calib.batch_size,
        num_batches: spec.calib.num_batches,
        collect_grams: spec.calib.collect_grams || spec.adaround.enabled,
        seed: spec.calib.seed + seed as u64 * 97,
    };
    // the resolved policy rides along so mse_group / mse_tensor sites
    // get row-sampling trackers under any calibration estimator
    let calib = calibrate_with_var(
        ctx,
        task,
        spec.architecture,
        spec.variant,
        params,
        &calib_cfg,
        Some(&policy),
    )?;
    let (qp, _) = quantize_weights(info, params, &policy, Some(&calib), &ada)?;
    let act = assemble_act_tensors_pool(info, &policy, &calib.trackers, &ctx.pool)?;
    Ok((qp, act))
}

/// A fully assembled, ready-to-serve model for one (spec, task): the
/// spec-addressed artifact the serving layer's cache stores. `params`
/// already carry the weight QDQ, `act` the calibrated activation
/// quantizers (calibration seed 0 — online serving has one model, not a
/// seed ensemble).
#[derive(Debug, Clone)]
pub struct AssembledModel {
    /// content hash of the spec ([`QuantSpec::spec_id`]) — the cache key
    pub spec_id: String,
    pub task: String,
    /// forward artifact name (`fwd_{head}_b{batch}`)
    pub artifact: String,
    pub params: Params,
    pub act: ActQuantTensors,
    /// executable batch capacity (rows per execution)
    pub batch: usize,
    pub seq: usize,
    pub n_out: usize,
    pub n_sites: usize,
}

/// Assemble a spec for serving on one task, keyed by its spec_id: load
/// the task checkpoint, run one calibration-seed-0 assembly, and resolve
/// the forward artifact it will execute under.
pub fn assemble_for_serving(
    ctx: &Ctx,
    spec: &QuantSpec,
    task: &TaskSpec,
) -> Result<AssembledModel> {
    let params = load_ckpt_var(ctx, task, spec.architecture, spec.variant)?;
    let (qp, act) = assemble_once(ctx, spec, task, &params, 0)?;
    let info = ctx.model_info_var(task, spec.architecture, spec.variant)?;
    let b = crate::coordinator::EVAL_BATCH;
    Ok(AssembledModel {
        spec_id: spec.spec_id(),
        task: task.name.to_string(),
        artifact: fwd_artifact_var(spec.architecture, spec.variant, ctx.head(task), b),
        params: qp,
        act,
        batch: b,
        seq: info.config.seq,
        n_out: info.config.n_out,
        n_sites: info.sites.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::PolicySpec;

    #[test]
    fn spec_tasks_empty_means_all() {
        let spec = QuantSpec::new("x", PolicySpec::uniform(8, 8));
        assert_eq!(spec_tasks(&spec).unwrap().len(), TASKS.len());
        let some = spec.clone().with_tasks(&["mnli".into(), "rte".into()]);
        let tasks = spec_tasks(&some).unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0].name, "mnli");
        let bad = spec.with_tasks(&["not_a_task".into()]);
        assert!(spec_tasks(&bad).is_err());
    }

    #[test]
    fn report_json_and_lookup() {
        let r = SpecReport {
            spec_id: "abc".into(),
            name: "w8a8".into(),
            tasks: vec!["mnli".into(), "rte".into()],
            scores: vec![80.0, 70.0],
            glue: 75.0,
        };
        assert_eq!(r.score_for("rte"), Some(70.0));
        assert_eq!(r.score_for("cola"), None);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("spec_id").unwrap().as_str().unwrap(), "abc");
        assert_eq!(
            j.get("scores").unwrap().get("mnli").unwrap().as_f64().unwrap(),
            80.0
        );
    }
}
