//! Static shape/dtype verifier for parsed HLO modules.
//!
//! One full inference pass over every computation in an [`HloModule`]:
//! for each instruction the verifier re-derives the output shape and
//! element type from the operand *declarations* and the instruction's
//! attributes, then demands the declaration match. Because every
//! instruction is checked, "declared" and "inferred" operand shapes are
//! interchangeable — a single linear pass gives whole-module soundness.
//!
//! The verifier runs at the three graph choke points (executable-cache
//! admission in `runtime`, [`crate::hlo::Plan::build`], and
//! `repro gen-artifacts`), which is what lets the interpreter's and the
//! planned engine's per-execution shape checks retreat behind
//! `debug_assertions`: a module that reaches execution has already been
//! proven shape/dtype-consistent.
//!
//! Diagnostics carry stable codes (see DESIGN.md §13 for the catalog):
//!
//! | code  | meaning                                                    |
//! |-------|------------------------------------------------------------|
//! | TQ101 | duplicate instruction name inside a computation            |
//! | TQ102 | operand undefined or not defined before use                |
//! | TQ103 | operand arity wrong for the opcode                         |
//! | TQ104 | unsupported opcode                                         |
//! | TQ105 | declared shape/dtype differs from the inferred one         |
//! | TQ106 | attribute missing, malformed, or inconsistent with shapes  |
//! | TQ107 | operand element type / kind unsupported for the op         |
//!
//! (TQ100 is reserved for parse failures and emitted by `repro lint`.)

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

use super::parser::{parse_literal_numbers, parse_slice_ranges, Computation, HloModule, Inst};
use super::{DType, Shape};

/// One verifier finding. All verifier findings are deny-severity: a
/// module that produces any cannot be admitted for execution.
#[derive(Debug, Clone)]
pub struct VerifyDiag {
    /// stable diagnostic code (`TQ101`..`TQ107`)
    pub code: &'static str,
    /// computation the instruction lives in
    pub comp: String,
    /// instruction name (no leading `%`)
    pub inst: String,
    pub msg: String,
}

impl fmt::Display for VerifyDiag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] %{}/%{}: {}", self.code, self.comp, self.inst, self.msg)
    }
}

/// Verify every computation in the module; returns all findings (empty
/// means the module is statically shape/dtype-consistent).
pub fn verify_module(m: &HloModule) -> Vec<VerifyDiag> {
    let mut out = Vec::new();
    for c in &m.computations {
        verify_computation(m, c, &mut out);
    }
    out
}

/// [`verify_module`] as a hard gate: `Err` lists the findings.
pub fn verify(m: &HloModule) -> Result<()> {
    let diags = verify_module(m);
    if diags.is_empty() {
        return Ok(());
    }
    const SHOW: usize = 12;
    let mut lines: Vec<String> = diags.iter().take(SHOW).map(|d| format!("  {d}")).collect();
    if diags.len() > SHOW {
        lines.push(format!("  ... and {} more", diags.len() - SHOW));
    }
    bail!(
        "module {}: {} verifier finding(s):\n{}",
        m.name,
        diags.len(),
        lines.join("\n")
    );
}

/// Inference failure local to one instruction: code + message, located
/// by the caller.
struct Fail {
    code: &'static str,
    msg: String,
}

fn fail(code: &'static str, msg: impl Into<String>) -> Fail {
    Fail { code, msg: msg.into() }
}

type IResult = std::result::Result<Shape, Fail>;

fn verify_computation(m: &HloModule, c: &Computation, out: &mut Vec<VerifyDiag>) {
    let push = |out: &mut Vec<VerifyDiag>, inst: &Inst, f: Fail| {
        out.push(VerifyDiag {
            code: f.code,
            comp: c.name.clone(),
            inst: inst.name.clone(),
            msg: f.msg,
        });
    };

    // duplicate names: the parser rejects these in text, but modules can
    // be built programmatically, so re-check against the name index.
    let mut seen: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, inst) in c.insts.iter().enumerate() {
        if let Some(first) = seen.insert(inst.name.as_str(), i) {
            push(
                out,
                inst,
                fail(
                    "TQ101",
                    format!("duplicate instruction name (first defined at index {first})"),
                ),
            );
        }
    }

    for (i, inst) in c.insts.iter().enumerate() {
        // def-before-use over the name index
        let mut operands_ok = true;
        for op in &inst.operands {
            match c.index.get(op) {
                Some(&j) if j < i => {}
                Some(_) => {
                    operands_ok = false;
                    push(out, inst, fail("TQ102", format!("operand %{op} used before definition")));
                }
                None => {
                    operands_ok = false;
                    push(out, inst, fail("TQ102", format!("operand %{op} is not defined")));
                }
            }
        }
        if !operands_ok {
            continue;
        }
        if let Err(f) = check_arity(inst) {
            push(out, inst, f);
            continue;
        }
        let ops: Vec<&Shape> = inst.operands.iter().map(|o| &c.insts[c.index[o]].shape).collect();
        match infer(m, inst, &ops) {
            Ok(inferred) => {
                if inferred != inst.shape {
                    push(
                        out,
                        inst,
                        fail(
                            "TQ105",
                            format!(
                                "declared {} but inferred {}",
                                shape_str(&inst.shape),
                                shape_str(&inferred)
                            ),
                        ),
                    );
                }
            }
            Err(f) => push(out, inst, f),
        }
    }
}

fn shape_str(s: &Shape) -> String {
    match s {
        Shape::Array { dtype, dims } => {
            let d: Vec<String> = dims.iter().map(usize::to_string).collect();
            format!("{}[{}]", dtype.name(), d.join(","))
        }
        Shape::Tuple(parts) => {
            let p: Vec<String> = parts.iter().map(shape_str).collect();
            format!("({})", p.join(", "))
        }
    }
}

const UNARY_OPS: &[&str] = &[
    "exp",
    "exponential",
    "tanh",
    "logistic",
    "rsqrt",
    "sqrt",
    "log",
    "negate",
    "abs",
    "floor",
    "ceil",
    "round-nearest-afz",
];

const BINARY_OPS: &[&str] =
    &["add", "subtract", "multiply", "divide", "maximum", "minimum", "power"];

/// (min, max) operand count per opcode; `None` = unsupported opcode.
fn arity_of(opcode: &str) -> Option<(usize, usize)> {
    if UNARY_OPS.contains(&opcode) {
        return Some((1, 1));
    }
    if BINARY_OPS.contains(&opcode) {
        return Some((2, 2));
    }
    Some(match opcode {
        "parameter" | "constant" | "iota" => (0, 0),
        "broadcast" | "reshape" | "transpose" | "slice" | "convert" | "get-tuple-element" => (1, 1),
        "dot" | "dot-general" | "compare" | "reduce" | "gather" => (2, 2),
        "clamp" | "select" => (3, 3),
        "concatenate" => (1, usize::MAX),
        "tuple" => (0, usize::MAX),
        _ => return None,
    })
}

fn check_arity(inst: &Inst) -> std::result::Result<(), Fail> {
    match arity_of(&inst.opcode) {
        None => Err(fail("TQ104", format!("unsupported opcode {:?}", inst.opcode))),
        Some((lo, hi)) => {
            let n = inst.operands.len();
            if n < lo || n > hi {
                let want = if lo == hi {
                    format!("{lo}")
                } else if hi == usize::MAX {
                    format!("at least {lo}")
                } else {
                    format!("{lo}..{hi}")
                };
                Err(fail(
                    "TQ103",
                    format!("{} takes {want} operand(s), got {n}", inst.opcode),
                ))
            } else {
                Ok(())
            }
        }
    }
}

/// Array-shape accessor: tuple operands are a kind error for every op
/// except `tuple`/`get-tuple-element`, which handle tuples themselves.
fn arr<'a>(s: &'a Shape, what: &str) -> std::result::Result<(DType, &'a [usize]), Fail> {
    match s {
        Shape::Array { dtype, dims } => Ok((*dtype, dims)),
        Shape::Tuple(_) => Err(fail("TQ107", format!("{what} operand is a tuple, expected an array"))),
    }
}

fn numeric(dt: DType, what: &str) -> std::result::Result<(), Fail> {
    match dt {
        DType::F32 | DType::S32 => Ok(()),
        DType::Pred => Err(fail("TQ107", format!("{what} must be f32 or s32, got pred"))),
    }
}

fn elems(dims: &[usize]) -> usize {
    dims.iter().product()
}

fn attr_err(e: anyhow::Error) -> Fail {
    fail("TQ106", format!("{e:#}"))
}

/// Infer the output shape of `inst` from its operand shapes. Every rule
/// mirrors the corresponding kernel in [`crate::hlo::interp`] (this
/// module is deliberately *no weaker*; where noted it is slightly
/// stricter than the interpreter's length-based checks, and everything
/// the builder emits satisfies the stricter rule).
fn infer(m: &HloModule, inst: &Inst, ops: &[&Shape]) -> IResult {
    match inst.opcode.as_str() {
        "parameter" => {
            inst.payload
                .as_deref()
                .unwrap_or("")
                .trim()
                .parse::<usize>()
                .map_err(|_| fail("TQ106", format!("bad parameter payload {:?}", inst.payload)))?;
            Ok(inst.shape.clone())
        }
        "constant" => {
            let (_, dims) = arr(&inst.shape, "constant")?;
            let lit = parse_literal_numbers(inst.payload.as_deref().unwrap_or(""))
                .map_err(attr_err)?;
            if lit.len() != elems(dims) {
                return Err(fail(
                    "TQ106",
                    format!("literal has {} element(s), shape wants {}", lit.len(), elems(dims)),
                ));
            }
            Ok(inst.shape.clone())
        }
        "broadcast" => {
            let (dt, idims) = arr(ops[0], "broadcast")?;
            let (odt, odims) = arr(&inst.shape, "broadcast output")?;
            if odt != dt {
                return Err(fail(
                    "TQ105",
                    format!("broadcast changes dtype {} -> {}", dt.name(), odt.name()),
                ));
            }
            let map = inst.attr_dims_or("dimensions", &[]).map_err(attr_err)?;
            if map.len() != idims.len() {
                return Err(fail(
                    "TQ106",
                    format!("dimensions has {} entries for rank-{} operand", map.len(), idims.len()),
                ));
            }
            for (k, &d) in map.iter().enumerate() {
                if d >= odims.len() {
                    return Err(fail(
                        "TQ106",
                        format!("dimensions[{k}]={d} out of range for rank-{} output", odims.len()),
                    ));
                }
                if odims[d] != idims[k] {
                    return Err(fail(
                        "TQ106",
                        format!(
                            "operand dim {k} (size {}) maps to output dim {d} (size {})",
                            idims[k], odims[d]
                        ),
                    ));
                }
            }
            Ok(inst.shape.clone())
        }
        "reshape" => {
            let (dt, idims) = arr(ops[0], "reshape")?;
            let (odt, odims) = arr(&inst.shape, "reshape output")?;
            if odt != dt {
                return Err(fail(
                    "TQ105",
                    format!("reshape changes dtype {} -> {}", dt.name(), odt.name()),
                ));
            }
            if elems(idims) != elems(odims) {
                return Err(fail(
                    "TQ106",
                    format!(
                        "element count changes {} -> {}",
                        elems(idims),
                        elems(odims)
                    ),
                ));
            }
            Ok(inst.shape.clone())
        }
        "transpose" => {
            let (dt, idims) = arr(ops[0], "transpose")?;
            let perm = inst.attr_dims("dimensions").map_err(attr_err)?;
            if perm.len() != idims.len() {
                return Err(fail(
                    "TQ106",
                    format!("permutation has {} entries for rank {}", perm.len(), idims.len()),
                ));
            }
            let mut hit = vec![false; idims.len()];
            for &p in &perm {
                if p >= idims.len() || hit[p] {
                    return Err(fail("TQ106", format!("dimensions={perm:?} is not a permutation")));
                }
                hit[p] = true;
            }
            let odims: Vec<usize> = perm.iter().map(|&p| idims[p]).collect();
            Ok(Shape::Array { dtype: dt, dims: odims })
        }
        "slice" => {
            let (dt, idims) = arr(ops[0], "slice")?;
            let ranges =
                parse_slice_ranges(inst.attr_str("slice").map_err(attr_err)?).map_err(attr_err)?;
            if ranges.len() != idims.len() {
                return Err(fail(
                    "TQ106",
                    format!("slice has {} ranges for rank {}", ranges.len(), idims.len()),
                ));
            }
            let mut odims = Vec::with_capacity(idims.len());
            for (d, &(lo, hi, st)) in ranges.iter().enumerate() {
                if st == 0 {
                    return Err(fail("TQ106", format!("slice dim {d}: zero stride")));
                }
                if lo > hi || hi > idims[d] {
                    return Err(fail(
                        "TQ106",
                        format!("slice dim {d}: [{lo}:{hi}] out of range for size {}", idims[d]),
                    ));
                }
                odims.push((hi - lo).div_ceil(st));
            }
            Ok(Shape::Array { dtype: dt, dims: odims })
        }
        "concatenate" => {
            let (dt0, d0) = arr(ops[0], "concatenate")?;
            numeric(dt0, "concatenate")?;
            let dims_attr = inst.attr_dims("dimensions").map_err(attr_err)?;
            let [axis] = dims_attr[..] else {
                return Err(fail(
                    "TQ106",
                    format!("dimensions={dims_attr:?}, expected exactly one axis"),
                ));
            };
            if axis >= d0.len() {
                return Err(fail(
                    "TQ106",
                    format!("axis {axis} out of range for rank {}", d0.len()),
                ));
            }
            let mut odims = d0.to_vec();
            let mut total = d0[axis];
            for (k, s) in ops.iter().enumerate().skip(1) {
                let (dt, d) = arr(s, "concatenate")?;
                if dt != dt0 {
                    return Err(fail("TQ107", "concatenate operand dtypes differ".to_string()));
                }
                if d.len() != d0.len() {
                    return Err(fail("TQ106", format!("operand {k} rank differs")));
                }
                for (ax, (&a, &b)) in d0.iter().zip(d).enumerate() {
                    if ax != axis && a != b {
                        return Err(fail(
                            "TQ106",
                            format!("operand {k} dim {ax}: {b} != {a} (non-axis dims must match)"),
                        ));
                    }
                }
                total += d[axis];
            }
            odims[axis] = total;
            Ok(Shape::Array { dtype: dt0, dims: odims })
        }
        "dot" | "dot-general" => {
            let (ldt, ldims) = arr(ops[0], "dot lhs")?;
            let (rdt, rdims) = arr(ops[1], "dot rhs")?;
            if ldt != DType::F32 || rdt != DType::F32 {
                return Err(fail("TQ107", "dot operands must be f32".to_string()));
            }
            let lb = inst.attr_dims_or("lhs_batch_dims", &[]).map_err(attr_err)?;
            let rb = inst.attr_dims_or("rhs_batch_dims", &[]).map_err(attr_err)?;
            let lc = inst.attr_dims_or("lhs_contracting_dims", &[]).map_err(attr_err)?;
            let rc = inst.attr_dims_or("rhs_contracting_dims", &[]).map_err(attr_err)?;
            if lb.len() != rb.len() {
                return Err(fail("TQ106", "lhs/rhs batch dim counts differ".to_string()));
            }
            if lc.len() != rc.len() {
                return Err(fail("TQ106", "lhs/rhs contracting dim counts differ".to_string()));
            }
            for (&d, side, rank) in lb
                .iter()
                .map(|d| (d, "lhs_batch", ldims.len()))
                .chain(rb.iter().map(|d| (d, "rhs_batch", rdims.len())))
                .chain(lc.iter().map(|d| (d, "lhs_contracting", ldims.len())))
                .chain(rc.iter().map(|d| (d, "rhs_contracting", rdims.len())))
            {
                if d >= rank {
                    return Err(fail(
                        "TQ106",
                        format!("{side} dim {d} out of range for rank {rank}"),
                    ));
                }
            }
            for (k, (&l, &r)) in lb.iter().zip(&rb).enumerate() {
                if ldims[l] != rdims[r] {
                    return Err(fail(
                        "TQ106",
                        format!("batch dim {k}: lhs size {} != rhs size {}", ldims[l], rdims[r]),
                    ));
                }
            }
            for (k, (&l, &r)) in lc.iter().zip(&rc).enumerate() {
                if ldims[l] != rdims[r] {
                    return Err(fail(
                        "TQ106",
                        format!(
                            "contracting dim {k}: lhs size {} != rhs size {}",
                            ldims[l], rdims[r]
                        ),
                    ));
                }
            }
            let mut odims: Vec<usize> = lb.iter().map(|&d| ldims[d]).collect();
            for (d, &s) in ldims.iter().enumerate() {
                if !lb.contains(&d) && !lc.contains(&d) {
                    odims.push(s);
                }
            }
            for (d, &s) in rdims.iter().enumerate() {
                if !rb.contains(&d) && !rc.contains(&d) {
                    odims.push(s);
                }
            }
            Ok(Shape::Array { dtype: DType::F32, dims: odims })
        }
        op if BINARY_OPS.contains(&op) => {
            let (adt, adims) = arr(ops[0], op)?;
            let (bdt, bdims) = arr(ops[1], op)?;
            if adt != bdt {
                return Err(fail(
                    "TQ107",
                    format!("{op} operand dtypes differ: {} vs {}", adt.name(), bdt.name()),
                ));
            }
            numeric(adt, op)?;
            if op == "power" && adt == DType::S32 {
                return Err(fail("TQ107", "power is not defined on s32".to_string()));
            }
            if adims != bdims {
                return Err(fail(
                    "TQ106",
                    format!("{op} operand dims differ: {adims:?} vs {bdims:?}"),
                ));
            }
            Ok(ops[0].clone())
        }
        op if UNARY_OPS.contains(&op) => {
            let (dt, _) = arr(ops[0], op)?;
            match dt {
                DType::F32 => {}
                DType::S32 if matches!(op, "negate" | "abs") => {}
                other => {
                    return Err(fail(
                        "TQ107",
                        format!("{op} is not defined on {}", other.name()),
                    ))
                }
            }
            Ok(ops[0].clone())
        }
        "clamp" => {
            let (xdt, xdims) = arr(ops[1], "clamp value")?;
            if xdt != DType::F32 {
                return Err(fail("TQ107", "clamp value must be f32".to_string()));
            }
            for (s, what) in [(ops[0], "clamp lo"), (ops[2], "clamp hi")] {
                let (dt, dims) = arr(s, what)?;
                if dt != DType::F32 {
                    return Err(fail("TQ107", format!("{what} must be f32")));
                }
                // stricter than the interpreter's element-count check:
                // bounds are a scalar or exactly the value's shape
                if elems(dims) != 1 && dims != xdims {
                    return Err(fail(
                        "TQ106",
                        format!("{what} dims {dims:?} are neither scalar nor {xdims:?}"),
                    ));
                }
            }
            Ok(ops[1].clone())
        }
        "select" => {
            let (pdt, pdims) = arr(ops[0], "select pred")?;
            if pdt != DType::Pred {
                return Err(fail("TQ107", "select predicate must be pred".to_string()));
            }
            let (tdt, tdims) = arr(ops[1], "select on-true")?;
            let (fdt, fdims) = arr(ops[2], "select on-false")?;
            if tdt != fdt {
                return Err(fail("TQ107", "select branch dtypes differ".to_string()));
            }
            numeric(tdt, "select branches")?;
            if tdims != fdims {
                return Err(fail(
                    "TQ106",
                    format!("select branch dims differ: {tdims:?} vs {fdims:?}"),
                ));
            }
            if elems(pdims) != 1 && pdims != tdims {
                return Err(fail(
                    "TQ106",
                    format!("select pred dims {pdims:?} are neither scalar nor {tdims:?}"),
                ));
            }
            Ok(ops[1].clone())
        }
        "compare" => {
            let dir = inst.attr_str("direction").map_err(attr_err)?;
            if !matches!(dir, "EQ" | "NE" | "LT" | "LE" | "GT" | "GE") {
                return Err(fail("TQ106", format!("unknown compare direction {dir:?}")));
            }
            let (adt, adims) = arr(ops[0], "compare")?;
            let (bdt, bdims) = arr(ops[1], "compare")?;
            if adt != bdt {
                return Err(fail("TQ107", "compare operand dtypes differ".to_string()));
            }
            numeric(adt, "compare")?;
            if adims != bdims {
                return Err(fail(
                    "TQ106",
                    format!("compare operand dims differ: {adims:?} vs {bdims:?}"),
                ));
            }
            Ok(Shape::Array { dtype: DType::Pred, dims: adims.to_vec() })
        }
        "convert" => {
            let (idt, idims) = arr(ops[0], "convert")?;
            let (odt, odims) = arr(&inst.shape, "convert output")?;
            let ok = matches!(
                (idt, odt),
                (DType::F32, DType::S32)
                    | (DType::S32, DType::F32)
                    | (DType::Pred, DType::F32)
                    | (DType::Pred, DType::S32)
                    | (DType::F32, DType::F32)
                    | (DType::S32, DType::S32)
            );
            if !ok {
                return Err(fail(
                    "TQ107",
                    format!("convert {} -> {} is unsupported", idt.name(), odt.name()),
                ));
            }
            if idims != odims {
                return Err(fail(
                    "TQ106",
                    format!("convert changes dims {idims:?} -> {odims:?}"),
                ));
            }
            Ok(inst.shape.clone())
        }
        "iota" => {
            let (dt, dims) = arr(&inst.shape, "iota output")?;
            numeric(dt, "iota")?;
            let d = inst.attr_usize("iota_dimension").map_err(attr_err)?;
            if d >= dims.len() {
                return Err(fail(
                    "TQ106",
                    format!("iota_dimension {d} out of range for rank {}", dims.len()),
                ));
            }
            Ok(inst.shape.clone())
        }
        "reduce" => {
            let (ddt, ddims) = arr(ops[0], "reduce data")?;
            if ddt != DType::F32 {
                return Err(fail("TQ107", "reduce data must be f32".to_string()));
            }
            let (idt, idims) = arr(ops[1], "reduce init")?;
            if idt != DType::F32 || elems(idims) != 1 {
                return Err(fail("TQ107", "reduce init must be a scalar f32".to_string()));
            }
            let rdims = inst.attr_dims("dimensions").map_err(attr_err)?;
            let mut hit = vec![false; ddims.len()];
            for &d in &rdims {
                if d >= ddims.len() {
                    return Err(fail(
                        "TQ106",
                        format!("reduce dim {d} out of range for rank {}", ddims.len()),
                    ));
                }
                if hit[d] {
                    return Err(fail("TQ106", format!("reduce dim {d} repeated")));
                }
                hit[d] = true;
            }
            let apply = inst
                .attr_str("to_apply")
                .map_err(attr_err)?
                .trim_start_matches('%');
            let comb = m
                .computations
                .iter()
                .find(|c| c.name == apply)
                .ok_or_else(|| fail("TQ106", format!("to_apply=%{apply}: no such computation")))?;
            let root_op = comb.insts[comb.root].opcode.as_str();
            if !matches!(root_op, "add" | "maximum" | "minimum" | "multiply") {
                return Err(fail(
                    "TQ106",
                    format!("to_apply=%{apply}: unsupported combinator {root_op:?}"),
                ));
            }
            let odims: Vec<usize> = ddims
                .iter()
                .enumerate()
                .filter(|(d, _)| !hit[*d])
                .map(|(_, &s)| s)
                .collect();
            Ok(Shape::Array { dtype: DType::F32, dims: odims })
        }
        "tuple" => {
            let Shape::Tuple(parts) = &inst.shape else {
                return Err(fail("TQ105", "tuple output declared as an array".to_string()));
            };
            if parts.len() != ops.len() {
                return Err(fail(
                    "TQ105",
                    format!("declared arity {} but {} operand(s)", parts.len(), ops.len()),
                ));
            }
            Ok(Shape::Tuple(ops.iter().map(|s| (*s).clone()).collect()))
        }
        "get-tuple-element" => {
            let Shape::Tuple(parts) = ops[0] else {
                return Err(fail("TQ107", "get-tuple-element operand is not a tuple".to_string()));
            };
            let idx = inst.attr_usize("index").map_err(attr_err)?;
            let part = parts.get(idx).ok_or_else(|| {
                fail("TQ106", format!("index {idx} out of range for arity {}", parts.len()))
            })?;
            Ok(part.clone())
        }
        "gather" => {
            let (odt, odims) = arr(ops[0], "gather operand")?;
            if odt != DType::F32 {
                return Err(fail("TQ107", "gather operand must be f32".to_string()));
            }
            let (idt, idims) = arr(ops[1], "gather indices")?;
            if idt != DType::S32 {
                return Err(fail("TQ107", "gather indices must be s32".to_string()));
            }
            let offset_dims = inst.attr_dims("offset_dims").map_err(attr_err)?;
            let collapsed = inst.attr_dims_or("collapsed_slice_dims", &[]).map_err(attr_err)?;
            let start_map = inst.attr_dims("start_index_map").map_err(attr_err)?;
            let ivd = inst.attr_usize("index_vector_dim").map_err(attr_err)?;
            let slice_sizes = inst.attr_dims("slice_sizes").map_err(attr_err)?;
            if slice_sizes.len() != odims.len() {
                return Err(fail(
                    "TQ106",
                    format!(
                        "slice_sizes has {} entries for rank-{} operand",
                        slice_sizes.len(),
                        odims.len()
                    ),
                ));
            }
            for (d, (&sz, &lim)) in slice_sizes.iter().zip(odims).enumerate() {
                if sz > lim {
                    return Err(fail(
                        "TQ106",
                        format!("slice_sizes[{d}]={sz} exceeds operand dim {lim}"),
                    ));
                }
            }
            for &d in start_map.iter().chain(&collapsed) {
                if d >= odims.len() {
                    return Err(fail(
                        "TQ106",
                        format!("operand dim {d} out of range for rank {}", odims.len()),
                    ));
                }
            }
            if ivd > idims.len() {
                return Err(fail(
                    "TQ106",
                    format!("index_vector_dim {ivd} out of range for rank {}", idims.len()),
                ));
            }
            let index_len = if ivd == idims.len() { 1 } else { idims[ivd] };
            if index_len != start_map.len() {
                return Err(fail(
                    "TQ106",
                    format!(
                        "start_index_map has {} entries but index vectors have {index_len}",
                        start_map.len()
                    ),
                ));
            }
            let batch: Vec<usize> = idims
                .iter()
                .enumerate()
                .filter(|(d, _)| *d != ivd)
                .map(|(_, &s)| s)
                .collect();
            let kept: Vec<usize> = (0..odims.len()).filter(|d| !collapsed.contains(d)).collect();
            if kept.len() != offset_dims.len() {
                return Err(fail(
                    "TQ106",
                    format!(
                        "offset_dims has {} entries but {} slice dim(s) survive collapsing",
                        offset_dims.len(),
                        kept.len()
                    ),
                ));
            }
            let out_rank = batch.len() + offset_dims.len();
            let mut slots: Vec<Option<usize>> = vec![None; out_rank];
            for (k, &d) in offset_dims.iter().enumerate() {
                if d >= out_rank || slots[d].is_some() {
                    return Err(fail(
                        "TQ106",
                        format!("offset_dims={offset_dims:?} invalid for output rank {out_rank}"),
                    ));
                }
                slots[d] = Some(slice_sizes[kept[k]]);
            }
            let mut batch_it = batch.into_iter();
            let out: Vec<usize> = slots
                .into_iter()
                .map(|s| s.unwrap_or_else(|| batch_it.next().unwrap_or(0)))
                .collect();
            Ok(Shape::Array { dtype: DType::F32, dims: out })
        }
        other => Err(fail("TQ104", format!("unsupported opcode {other:?}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    /// Build a module with the standard reduce combinators plus an entry
    /// whose params/body come from the test.
    fn module(params: &[&str], body: &[&str]) -> HloModule {
        let mut text = String::from("HloModule vtest\n\n");
        text.push_str(
            "%red_add (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %r = f32[] add(f32[] %a, f32[] %b)\n}\n\n",
        );
        text.push_str("ENTRY %main () -> f32[] {\n");
        for p in params {
            text.push_str("  ");
            text.push_str(p);
            text.push('\n');
        }
        for b in body {
            text.push_str("  ");
            text.push_str(b);
            text.push('\n');
        }
        text.push_str("}\n");
        parse_module(&text).unwrap()
    }

    fn accept(params: &[&str], body: &[&str]) {
        let m = module(params, body);
        let diags = verify_module(&m);
        assert!(diags.is_empty(), "expected clean, got: {diags:?}");
    }

    fn reject(params: &[&str], body: &[&str], code: &str) {
        let m = module(params, body);
        let diags = verify_module(&m);
        assert!(
            diags.iter().any(|d| d.code == code),
            "expected a {code} finding, got: {diags:?}"
        );
    }

    #[test]
    fn parameter_constant_accept_reject() {
        accept(&["%x = f32[2] parameter(0)"], &["ROOT %c = f32[2] abs(f32[2] %x)"]);
        // constant literal count must match the shape
        reject(&[], &["ROOT %c = f32[3] constant({1, 2})"], "TQ106");
        accept(&[], &["ROOT %c = f32[2] constant({1, 2})"]);
    }

    #[test]
    fn attention_variant_fragments_accept_reject() {
        // the gated-attention epilogue: sigmoid gate broadcast over the
        // per-head context, elementwise product
        accept(
            &["%l = f32[2,4] parameter(0)", "%ctx = f32[2,4,8] parameter(1)"],
            &[
                "%g = f32[2,4] logistic(f32[2,4] %l)",
                "%gb = f32[2,4,8] broadcast(f32[2,4] %g), dimensions={0,1}",
                "ROOT %o = f32[2,4,8] multiply(f32[2,4,8] %ctx, f32[2,4,8] %gb)",
            ],
        );
        // the clipped-softmax epilogue: affine stretch then clamp to [0,1]
        accept(
            &["%p = f32[2,4] parameter(0)"],
            &[
                "%sc = f32[] constant(1.006)",
                "%scb = f32[2,4] broadcast(f32[] %sc), dimensions={}",
                "%m = f32[2,4] multiply(f32[2,4] %p, f32[2,4] %scb)",
                "%ga = f32[] constant(-0.003)",
                "%gab = f32[2,4] broadcast(f32[] %ga), dimensions={}",
                "%sh = f32[2,4] add(f32[2,4] %m, f32[2,4] %gab)",
                "%lo = f32[] constant(0)",
                "%hi = f32[] constant(1)",
                "ROOT %c = f32[2,4] clamp(f32[] %lo, f32[2,4] %sh, f32[] %hi)",
            ],
        );
        // logistic is real-valued only: s32 gate logits are malformed
        reject(
            &["%l = s32[2,4] parameter(0)"],
            &["ROOT %g = s32[2,4] logistic(s32[2,4] %l)"],
            "TQ107",
        );
        // a gate whose broadcast drops the head axis cannot multiply into
        // the [b,h,t,dh] context
        reject(
            &["%l = f32[2,4] parameter(0)", "%ctx = f32[2,4,8] parameter(1)"],
            &[
                "%g = f32[2,4] logistic(f32[2,4] %l)",
                "%gb = f32[2,4,4] broadcast(f32[2,4] %g), dimensions={0,1}",
                "ROOT %o = f32[2,4,8] multiply(f32[2,4,8] %ctx, f32[2,4,4] %gb)",
            ],
            "TQ106",
        );
    }

    #[test]
    fn def_before_use_and_duplicates() {
        reject(
            &["%x = f32[2] parameter(0)"],
            &["ROOT %r = f32[2] add(f32[2] %x, f32[2] %nope)"],
            "TQ102",
        );
        // used-before-defined (operand defined later in the body)
        reject(
            &["%x = f32[2] parameter(0)"],
            &[
                "%a = f32[2] add(f32[2] %x, f32[2] %b)",
                "%b = f32[2] abs(f32[2] %x)",
                "ROOT %r = f32[2] add(f32[2] %a, f32[2] %b)",
            ],
            "TQ102",
        );
    }

    #[test]
    fn arity_and_unknown_opcode() {
        reject(&["%x = f32[2] parameter(0)"], &["ROOT %r = f32[2] add(f32[2] %x)"], "TQ103");
        reject(
            &["%x = f32[2] parameter(0)"],
            &["ROOT %r = f32[2] frobnicate(f32[2] %x)"],
            "TQ104",
        );
    }

    #[test]
    fn broadcast_accept_reject() {
        accept(
            &["%x = f32[3] parameter(0)"],
            &["ROOT %b = f32[2,3] broadcast(f32[3] %x), dimensions={1}"],
        );
        // mapped output dim has the wrong size
        reject(
            &["%x = f32[3] parameter(0)"],
            &["ROOT %b = f32[2,4] broadcast(f32[3] %x), dimensions={1}"],
            "TQ106",
        );
    }

    #[test]
    fn reshape_accept_reject() {
        accept(&["%x = f32[6] parameter(0)"], &["ROOT %r = f32[2,3] reshape(f32[6] %x)"]);
        reject(&["%x = f32[6] parameter(0)"], &["ROOT %r = f32[2,4] reshape(f32[6] %x)"], "TQ106");
    }

    #[test]
    fn transpose_accept_reject() {
        accept(
            &["%x = f32[2,3] parameter(0)"],
            &["ROOT %t = f32[3,2] transpose(f32[2,3] %x), dimensions={1,0}"],
        );
        reject(
            &["%x = f32[2,3] parameter(0)"],
            &["ROOT %t = f32[3,2] transpose(f32[2,3] %x), dimensions={1,1}"],
            "TQ106",
        );
    }

    #[test]
    fn slice_accept_reject() {
        accept(
            &["%x = f32[4,6] parameter(0)"],
            &["ROOT %s = f32[2,3] slice(f32[4,6] %x), slice={[0:2], [0:6:2]}"],
        );
        reject(
            &["%x = f32[4,6] parameter(0)"],
            &["ROOT %s = f32[2,3] slice(f32[4,6] %x), slice={[0:2], [0:7:2]}"],
            "TQ106",
        );
    }

    #[test]
    fn concatenate_accept_reject() {
        accept(
            &["%x = f32[2,3] parameter(0)", "%y = f32[2,2] parameter(1)"],
            &["ROOT %c = f32[2,5] concatenate(f32[2,3] %x, f32[2,2] %y), dimensions={1}"],
        );
        // non-axis dims must match
        reject(
            &["%x = f32[2,3] parameter(0)", "%y = f32[3,2] parameter(1)"],
            &["ROOT %c = f32[2,5] concatenate(f32[2,3] %x, f32[3,2] %y), dimensions={1}"],
            "TQ106",
        );
    }

    #[test]
    fn dot_accept_reject() {
        accept(
            &["%a = f32[2,3] parameter(0)", "%b = f32[3,4] parameter(1)"],
            &[
                "ROOT %d = f32[2,4] dot(f32[2,3] %a, f32[3,4] %b), \
                 lhs_contracting_dims={1}, rhs_contracting_dims={0}",
            ],
        );
        // contracting sizes disagree: the canonical "bad dot dims" case
        reject(
            &["%a = f32[2,3] parameter(0)", "%b = f32[4,5] parameter(1)"],
            &[
                "ROOT %d = f32[2,5] dot(f32[2,3] %a, f32[4,5] %b), \
                 lhs_contracting_dims={1}, rhs_contracting_dims={0}",
            ],
            "TQ106",
        );
        // batched
        accept(
            &["%a = f32[5,2,3] parameter(0)", "%b = f32[5,3,4] parameter(1)"],
            &[
                "ROOT %d = f32[5,2,4] dot(f32[5,2,3] %a, f32[5,3,4] %b), \
                 lhs_batch_dims={0}, rhs_batch_dims={0}, \
                 lhs_contracting_dims={2}, rhs_contracting_dims={1}",
            ],
        );
    }

    #[test]
    fn elementwise_accept_reject() {
        accept(
            &["%x = f32[4] parameter(0)", "%y = f32[4] parameter(1)"],
            &["ROOT %r = f32[4] multiply(f32[4] %x, f32[4] %y)"],
        );
        reject(
            &["%x = f32[4] parameter(0)", "%y = f32[3] parameter(1)"],
            &["ROOT %r = f32[4] multiply(f32[4] %x, f32[3] %y)"],
            "TQ106",
        );
        // s32 power is a kind error
        reject(
            &["%x = s32[4] parameter(0)", "%y = s32[4] parameter(1)"],
            &["ROOT %r = s32[4] power(s32[4] %x, s32[4] %y)"],
            "TQ107",
        );
        accept(&["%x = f32[4] parameter(0)"], &["ROOT %r = f32[4] tanh(f32[4] %x)"]);
        reject(&["%x = s32[4] parameter(0)"], &["ROOT %r = s32[4] tanh(s32[4] %x)"], "TQ107");
    }

    #[test]
    fn clamp_select_compare_accept_reject() {
        accept(
            &["%lo = f32[] parameter(0)", "%x = f32[4] parameter(1)", "%hi = f32[] parameter(2)"],
            &["ROOT %c = f32[4] clamp(f32[] %lo, f32[4] %x, f32[] %hi)"],
        );
        reject(
            &["%lo = f32[2] parameter(0)", "%x = f32[4] parameter(1)", "%hi = f32[] parameter(2)"],
            &["ROOT %c = f32[4] clamp(f32[2] %lo, f32[4] %x, f32[] %hi)"],
            "TQ106",
        );
        accept(
            &["%x = f32[4] parameter(0)", "%y = f32[4] parameter(1)"],
            &[
                "%z = f32[] constant(0)",
                "%zb = f32[4] broadcast(f32[] %z), dimensions={}",
                "%p = pred[4] compare(f32[4] %x, f32[4] %zb), direction=GT",
                "ROOT %s = f32[4] select(pred[4] %p, f32[4] %x, f32[4] %y)",
            ],
        );
        // select predicate must be pred-typed
        reject(
            &["%p = f32[4] parameter(0)", "%x = f32[4] parameter(1)", "%y = f32[4] parameter(2)"],
            &["ROOT %s = f32[4] select(f32[4] %p, f32[4] %x, f32[4] %y)"],
            "TQ107",
        );
        // unknown compare direction
        reject(
            &["%x = f32[4] parameter(0)", "%y = f32[4] parameter(1)"],
            &["ROOT %p = pred[4] compare(f32[4] %x, f32[4] %y), direction=XX"],
            "TQ106",
        );
        // compare output must be pred
        reject(
            &["%x = f32[4] parameter(0)", "%y = f32[4] parameter(1)"],
            &["ROOT %p = f32[4] compare(f32[4] %x, f32[4] %y), direction=GT"],
            "TQ105",
        );
    }

    #[test]
    fn convert_iota_accept_reject() {
        accept(&["%x = s32[4] parameter(0)"], &["ROOT %c = f32[4] convert(s32[4] %x)"]);
        reject(&["%x = f32[4] parameter(0)"], &["ROOT %c = pred[4] convert(f32[4] %x)"], "TQ107");
        accept(&[], &["ROOT %i = s32[3,4] iota(), iota_dimension=1"]);
        reject(&[], &["ROOT %i = s32[3,4] iota(), iota_dimension=2"], "TQ106");
    }

    #[test]
    fn reduce_accept_reject() {
        accept(
            &["%x = f32[2,4] parameter(0)"],
            &[
                "%z = f32[] constant(0)",
                "ROOT %r = f32[2] reduce(f32[2,4] %x, f32[] %z), dimensions={1}, \
                 to_apply=%red_add",
            ],
        );
        // wrong kept-dims shape
        reject(
            &["%x = f32[2,4] parameter(0)"],
            &[
                "%z = f32[] constant(0)",
                "ROOT %r = f32[4] reduce(f32[2,4] %x, f32[] %z), dimensions={1}, \
                 to_apply=%red_add",
            ],
            "TQ105",
        );
        // missing combinator computation
        reject(
            &["%x = f32[2,4] parameter(0)"],
            &[
                "%z = f32[] constant(0)",
                "ROOT %r = f32[2] reduce(f32[2,4] %x, f32[] %z), dimensions={1}, \
                 to_apply=%red_nope",
            ],
            "TQ106",
        );
    }

    #[test]
    fn tuple_accept_reject() {
        accept(
            &["%x = f32[2] parameter(0)", "%y = s32[3] parameter(1)"],
            &["ROOT %t = (f32[2], s32[3]) tuple(f32[2] %x, s32[3] %y)"],
        );
        // element shape mismatch
        reject(
            &["%x = f32[4] parameter(0)"],
            &["ROOT %t = (f32[2]) tuple(f32[4] %x)"],
            "TQ105",
        );
        accept(
            &["%x = f32[2] parameter(0)", "%y = s32[3] parameter(1)"],
            &[
                "%t = (f32[2], s32[3]) tuple(f32[2] %x, s32[3] %y)",
                "ROOT %g = s32[3] get-tuple-element((f32[2], s32[3]) %t), index=1",
            ],
        );
        reject(
            &["%x = f32[2] parameter(0)"],
            &[
                "%t = (f32[2]) tuple(f32[2] %x)",
                "ROOT %g = f32[2] get-tuple-element((f32[2]) %t), index=1",
            ],
            "TQ106",
        );
    }

    #[test]
    fn gather_accept_reject() {
        accept(
            &["%tbl = f32[5,3] parameter(0)", "%ids = s32[2,1] parameter(1)"],
            &[
                "ROOT %g = f32[2,3] gather(f32[5,3] %tbl, s32[2,1] %ids), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1,3}",
            ],
        );
        // slice_sizes must cover every operand dim
        reject(
            &["%tbl = f32[5,3] parameter(0)", "%ids = s32[2,1] parameter(1)"],
            &[
                "ROOT %g = f32[2,3] gather(f32[5,3] %tbl, s32[2,1] %ids), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1}",
            ],
            "TQ106",
        );
        // wrong declared output dims
        reject(
            &["%tbl = f32[5,3] parameter(0)", "%ids = s32[2,1] parameter(1)"],
            &[
                "ROOT %g = f32[2,4] gather(f32[5,3] %tbl, s32[2,1] %ids), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1,3}",
            ],
            "TQ105",
        );
    }

    #[test]
    fn declared_dtype_must_match_inferred() {
        // declared s32 out of an f32 add
        reject(
            &["%x = f32[2] parameter(0)", "%y = f32[2] parameter(1)"],
            &["ROOT %r = s32[2] add(f32[2] %x, f32[2] %y)"],
            "TQ105",
        );
    }

    #[test]
    fn builder_emitted_module_verifies() {
        use crate::hlo::builder::GraphBuilder;
        let mut b = GraphBuilder::new("vb");
        let x = b.param(DType::F32, &[4, 8]);
        let w = b.param(DType::F32, &[8, 2]);
        let d = b.dot_general(&x, &w, &[], &[], &[1], &[0]).unwrap();
        let t = b.tanh(&d);
        let text = b.finish(&[t]);
        let m = parse_module(&text).unwrap();
        let diags = verify_module(&m);
        assert!(diags.is_empty(), "builder module must verify: {diags:?}");
    }
}
