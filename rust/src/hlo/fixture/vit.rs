//! ViT frontend for the fixture generator: a patch-embed encoder. The
//! input is a flat pixel-patch tensor `[b, seq, patch*patch]` (the host
//! side rasterises synthetic examples into patches via
//! `data::pixels_for_ids`); the embedding is a learned linear patch
//! projection plus learned position embeddings. Attention runs unmasked —
//! every patch attends to the full grid — and the pooler reads position 0
//! (the first patch), mirroring the BERT [CLS] slot so the shared
//! pooler/head lowering applies unchanged.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::super::builder::{GraphBuilder, Op};
use super::super::DType;
use super::{sig, FixtureConfig, SigEntry};
use crate::model::manifest::ArchParams;

/// The fixture "vit" model: same d/heads/d_ff as the BERT base so PEG
/// group counts and site families transfer, with a 4×4 patch grid over a
/// 16×16 image (seq 16). `vocab` sizes the deterministic pixel codebook
/// the data layer rasterises token ids through.
pub fn vit_config() -> FixtureConfig {
    FixtureConfig {
        name: "vit".to_string(),
        vocab: 64,
        d: 128,
        heads: 4,
        layers: 1,
        d_ff: 256,
        seq: 16,
        n_out: 3,
        outlier_dims: vec![17, 89, 101],
        arch: ArchParams::Vit { patch: 4, img: 16 },
        variant: crate::model::manifest::AttnVariant::Vanilla,
    }
}

/// Embedding parameters (precede the shared `embed.ln.*` entries): the
/// patch projection and learned position embeddings.
pub(crate) fn embed_params(cfg: &FixtureConfig) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d;
    let p = cfg.arch.patch().expect("vit config");
    vec![
        ("embed.patch.w".into(), vec![p * p, d]),
        ("embed.patch.b".into(), vec![d]),
        ("embed.pos".into(), vec![cfg.seq, d]),
    ]
}

/// Lower the ViT data input and embedding sum. Returns the pre-LN
/// embedding `[b, t, d]`; ViT has no attention bias (no PAD positions).
pub(crate) fn embed(
    g: &mut GraphBuilder,
    cfg: &FixtureConfig,
    b: usize,
    p: &BTreeMap<String, Op>,
    inputs: &mut Vec<SigEntry>,
) -> Result<(Op, Option<Op>)> {
    let (t, d) = (cfg.seq, cfg.d);
    let (patch, img) = match cfg.arch {
        ArchParams::Vit { patch, img } => (patch, img),
        _ => bail!("vit::embed on a non-ViT config"),
    };
    let grid = img / patch;
    if grid * patch != img || grid * grid != t {
        bail!("vit config: img {img} / patch {patch} grid inconsistent with seq {t}");
    }
    let pd = patch * patch;
    let pixels = g.param(DType::F32, &[b, t, pd]);
    inputs.push(sig("pixels", &[b, t, pd], "f32"));

    // patch projection + learned position embeddings
    let proj = g.matmul_bias(&pixels, &p["embed.patch.w"], &p["embed.patch.b"])?;
    let pos = g.broadcast(&p["embed.pos"], &[b, t, d], &[1, 2])?;
    let x0 = g.add(&proj, &pos)?;
    Ok((x0, None))
}
