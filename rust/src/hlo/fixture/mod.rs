//! `repro gen-artifacts`: a self-consistent fixture `artifacts/`.
//!
//! The module is an architecture-neutral core plus per-architecture
//! frontends: [`bert`] lowers the original token-embedding encoder
//! (same topology family as python/compile/model.py: 13 activation-
//! quantizer sites per layer + 4, runtime-parameterised fake-quant at
//! every site), [`vit`] lowers a ViT-style patch-embed encoder (patch
//! projection + learned position embeddings feeding the *same*
//! attention/FFN/residual blocks and site inventory). Both lower to HLO
//! text with [`crate::hlo::builder`] and share one `manifest.json`
//! contract — artifact signatures, model topology (including the
//! `architecture` discriminant), golden fake-quant vectors. The generated
//! modules execute on the in-repo interpreter (or a real PJRT client), so
//! integration tests, `repro smoke` and the sweep's runtime pass run in
//! any container without Python or XLA.
//!
//! The fixture models are deliberately small (1 layer, short sequences)
//! so a full dev-set evaluation interprets in seconds, but keep `d = 128`
//! and the per-layer site inventory of the real export so
//! topology-sensitive code paths (PEG grouping, site families, mixed
//! precision) exercise realistically. Deterministic: every run emits
//! byte-identical artifacts.

pub mod bert;
pub mod vit;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::builder::{GraphBuilder, Op};
use super::DType;
use crate::data::{TaskKind, TASKS};
use crate::model::checkpoint;
use crate::model::manifest::{
    ArchParams, Architecture, AttnVariant, ModelConfig, ModelInfo, ParamSpec, SiteSpec,
};
use crate::model::Params;
use crate::quant::{qdq_per_lane, QGrid, QParams};
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

pub use bert::base_config;
pub use vit::vit_config;

/// Additive attention-mask bias (mirrors model.py MASK_BIAS).
pub(crate) const MASK_BIAS: f32 = -30.0;

/// Clipped-softmax stretch parameters (the follow-up paper's ζ/γ): the
/// softmax output is mapped through `(ζ−γ)·p + γ` and clamped to [0,1],
/// so attention probabilities within |γ| of the ends land on *exact* 0
/// (a head attending to nothing) or exact 1 — the "do nothing" escape
/// hatch that removes the outlier-generating incentive.
pub const CSOFT_ZETA: f32 = 1.003;
pub const CSOFT_GAMMA: f32 = -0.003;

/// Architecture of the fixture model. `arch` selects the embedding
/// frontend (and the per-architecture manifest fields); everything from
/// `embed.ln` through the encoder stack to the pooler/head is shared.
#[derive(Debug, Clone)]
pub struct FixtureConfig {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub n_out: usize,
    pub outlier_dims: Vec<usize>,
    pub arch: ArchParams,
    /// attention-block variant lowered by [`build_forward`]
    pub variant: AttnVariant,
}

/// Ordered (name, shape) parameter signature: per-architecture embedding
/// parameters, then the shared embed-LN / encoder-layer / pooler / head
/// inventory (mirrors model.py for the BERT frontend).
pub fn param_spec(cfg: &FixtureConfig) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d;
    let mut spec = match cfg.arch.architecture() {
        Architecture::Bert => bert::embed_params(cfg),
        Architecture::Vit => vit::embed_params(cfg),
    };
    spec.push(("embed.ln.g".into(), vec![d]));
    spec.push(("embed.ln.b".into(), vec![d]));
    for i in 0..cfg.layers {
        let p = format!("layer{i}.");
        spec.push((format!("{p}q.w"), vec![d, d]));
        spec.push((format!("{p}q.b"), vec![d]));
        spec.push((format!("{p}k.w"), vec![d, d]));
        spec.push((format!("{p}k.b"), vec![d]));
        spec.push((format!("{p}v.w"), vec![d, d]));
        spec.push((format!("{p}v.b"), vec![d]));
        if cfg.variant == AttnVariant::Gated {
            // per-head gate projection G(x) = sigmoid(x @ gate.w + gate.b);
            // tiny [d, heads] — kept fp32 and deliberately out of wq_spec,
            // like the LayerNorm parameters
            spec.push((format!("{p}gate.w"), vec![d, cfg.heads]));
            spec.push((format!("{p}gate.b"), vec![cfg.heads]));
        }
        spec.push((format!("{p}attn_out.w"), vec![d, d]));
        spec.push((format!("{p}attn_out.b"), vec![d]));
        spec.push((format!("{p}ln1.g"), vec![d]));
        spec.push((format!("{p}ln1.b"), vec![d]));
        spec.push((format!("{p}ffn1.w"), vec![d, cfg.d_ff]));
        spec.push((format!("{p}ffn1.b"), vec![cfg.d_ff]));
        spec.push((format!("{p}ffn2.w"), vec![cfg.d_ff, d]));
        spec.push((format!("{p}ffn2.b"), vec![d]));
        spec.push((format!("{p}ln2.g"), vec![d]));
        spec.push((format!("{p}ln2.b"), vec![d]));
    }
    spec.push(("pool.w".into(), vec![d, d]));
    spec.push(("pool.b".into(), vec![d]));
    spec.push(("head.w".into(), vec![d, cfg.n_out]));
    spec.push(("head.b".into(), vec![cfg.n_out]));
    spec
}

/// Ordered (site, channels) activation-quantizer inventory — 13 per layer
/// plus 4 (mirrors model.py `site_spec`). The inventory is
/// architecture-independent: both frontends feed the same encoder stack,
/// so specs and presets transfer across architectures unchanged.
pub fn site_spec(cfg: &FixtureConfig) -> Vec<(String, usize)> {
    let d = cfg.d;
    let mut sites: Vec<(String, usize)> =
        vec![("embed_sum".into(), d), ("embed_ln_out".into(), d)];
    for i in 0..cfg.layers {
        let p = format!("layer{i}.");
        sites.push((format!("{p}q"), d));
        sites.push((format!("{p}k"), d));
        sites.push((format!("{p}v"), d));
        sites.push((format!("{p}attn_scores"), 1));
        sites.push((format!("{p}attn_probs"), 1));
        sites.push((format!("{p}attn_ctx"), d));
        sites.push((format!("{p}attn_out"), d));
        sites.push((format!("{p}res1_sum"), d));
        sites.push((format!("{p}ln1_out"), d));
        sites.push((format!("{p}ffn_hidden"), cfg.d_ff));
        sites.push((format!("{p}ffn_out"), d));
        sites.push((format!("{p}res2_sum"), d));
        sites.push((format!("{p}ln2_out"), d));
    }
    sites.push(("pooled".into(), d));
    sites.push(("head_out".into(), 1));
    sites
}

pub(crate) fn wq_spec(cfg: &FixtureConfig) -> Vec<String> {
    let embed_w = match cfg.arch.architecture() {
        Architecture::Bert => "embed.tok",
        Architecture::Vit => "embed.patch.w",
    };
    let mut names = vec![embed_w.to_string()];
    for i in 0..cfg.layers {
        let p = format!("layer{i}.");
        for w in ["q.w", "k.w", "v.w", "attn_out.w", "ffn1.w", "ffn2.w"] {
            names.push(format!("{p}{w}"));
        }
    }
    names.push("pool.w".to_string());
    names.push("head.w".to_string());
    names
}

pub(crate) fn site_offsets(cfg: &FixtureConfig) -> (Vec<usize>, usize) {
    let mut offs = Vec::new();
    let mut total = 0usize;
    for (_, c) in site_spec(cfg) {
        offs.push(total);
        total += c;
    }
    (offs, total)
}

/// The fixture model as a [`ModelInfo`] (used for checkpoint init and for
/// serialising the manifest's `models` section).
pub fn model_info(cfg: &FixtureConfig) -> ModelInfo {
    let (offs, total) = site_offsets(cfg);
    ModelInfo {
        config: ModelConfig {
            name: cfg.name.clone(),
            vocab: cfg.vocab,
            d: cfg.d,
            heads: cfg.heads,
            layers: cfg.layers,
            d_ff: cfg.d_ff,
            seq: cfg.seq,
            n_out: cfg.n_out,
            outlier_dims: cfg.outlier_dims.clone(),
            arch: cfg.arch.clone(),
            variant: cfg.variant,
        },
        params: param_spec(cfg)
            .into_iter()
            .map(|(name, shape)| ParamSpec { name, shape })
            .collect(),
        sites: site_spec(cfg)
            .into_iter()
            .zip(&offs)
            .map(|((name, channels), &offset)| SiteSpec { name, channels, offset })
            .collect(),
        total_scale_lanes: total,
        wq: wq_spec(cfg),
    }
}

// ---------------------------------------------------------------------------
// graph construction
// ---------------------------------------------------------------------------

/// Per-site fake-quant state threaded through the forward build: enforces
/// the canonical site order and collects diag taps.
struct SiteQuant {
    sites: Vec<(String, usize)>,
    offsets: Vec<usize>,
    next: usize,
    diag: bool,
    taps: Vec<(String, Op)>,
    act_scales: Op,
    act_zps: Op,
    act_cfg: Op,
}

impl SiteQuant {
    fn apply(&mut self, g: &mut GraphBuilder, name: &str, x: &Op) -> Result<Op> {
        let (want, channels) = self
            .sites
            .get(self.next)
            .cloned()
            .ok_or_else(|| anyhow!("more quant sites than site_spec entries"))?;
        if want != name {
            bail!("site order mismatch: expected {want:?}, got {name:?}");
        }
        let offset = self.offsets[self.next];
        let idx = self.next;
        self.next += 1;
        if self.diag {
            self.taps.push((name.to_string(), x.clone()));
        }
        let dims = x.dims.clone();
        let rank = dims.len();
        // per-lane scale / zero-point, broadcast to x's shape
        let (sb, zb) = if channels == 1 {
            let s = g.slice(&self.act_scales, &[(offset, offset + 1)])?;
            let s0 = g.reshape(&s, &[])?;
            let z = g.slice(&self.act_zps, &[(offset, offset + 1)])?;
            let z0 = g.reshape(&z, &[])?;
            (g.splat(&s0, &dims)?, g.splat(&z0, &dims)?)
        } else {
            if dims[rank - 1] != channels {
                bail!("site {name}: {channels} lanes vs last dim {}", dims[rank - 1]);
            }
            let s = g.slice(&self.act_scales, &[(offset, offset + channels)])?;
            let z = g.slice(&self.act_zps, &[(offset, offset + channels)])?;
            (
                g.broadcast(&s, &dims, &[rank - 1])?,
                g.broadcast(&z, &dims, &[rank - 1])?,
            )
        };
        // cfg row [qmin, qmax, enable]
        let row = g.slice(&self.act_cfg, &[(idx, idx + 1), (0, 3)])?;
        let scalar = |g: &mut GraphBuilder, row: &Op, j: usize| -> Result<Op> {
            let c = g.slice(row, &[(0, 1), (j, j + 1)])?;
            g.reshape(&c, &[])
        };
        let qmin = scalar(g, &row, 0)?;
        let qmax = scalar(g, &row, 1)?;
        let enable = scalar(g, &row, 2)?;
        let qmin_b = g.splat(&qmin, &dims)?;
        let qmax_b = g.splat(&qmax, &dims)?;
        // y = (clamp(round(x/s) + z, qmin, qmax) - z) * s  (== quant::qdq)
        let t = g.div(x, &sb)?;
        let r = g.round(&t);
        let q = g.add(&r, &zb)?;
        let qc = g.clamp(&qmin_b, &q, &qmax_b);
        let dq = {
            let c = g.sub(&qc, &zb)?;
            g.mul(&c, &sb)?
        };
        // select(enable > 0.5, y, x)
        let half = g.const_f32(0.5);
        let pred = g.compare("GT", &enable, &half)?;
        let pred_b = g.splat(&pred, &dims)?;
        g.select(&pred_b, &dq, x)
    }
}

/// Input/output signature entry for the manifest.
#[derive(Debug, Clone)]
pub(crate) struct SigEntry {
    pub(crate) name: String,
    pub(crate) shape: Vec<usize>,
    pub(crate) dtype: &'static str,
}

pub(crate) fn sig(name: impl Into<String>, shape: &[usize], dtype: &'static str) -> SigEntry {
    SigEntry { name: name.into(), shape: shape.to_vec(), dtype }
}

pub(crate) struct Artifact {
    pub(crate) text: String,
    pub(crate) inputs: Vec<SigEntry>,
    pub(crate) outputs: Vec<SigEntry>,
}

/// Lower the forward (or diagnostic) graph for `cfg` at batch size `b`.
///
/// The core builds the parameter and quantizer inputs, dispatches to the
/// architecture frontend for the data inputs + embedding sum (+ optional
/// additive attention bias), then lowers the shared encoder stack and
/// pooler/head with the canonical site order.
pub(crate) fn build_forward(
    cfg: &FixtureConfig,
    b: usize,
    diag: bool,
    module: &str,
) -> Result<Artifact> {
    let (t, d, h) = (cfg.seq, cfg.d, cfg.heads);
    let dh = d / h;
    if dh * h != d {
        bail!("heads {h} must divide d {d}");
    }
    let (offsets, total) = site_offsets(cfg);
    let sites = site_spec(cfg);
    let n_sites = sites.len();

    let mut g = GraphBuilder::new(module);
    let mut inputs = Vec::new();
    let mut p: BTreeMap<String, Op> = BTreeMap::new();
    for (name, shape) in param_spec(cfg) {
        let op = g.param(DType::F32, &shape);
        inputs.push(sig(format!("param.{name}"), &shape, "f32"));
        p.insert(name, op);
    }
    let act_scales = g.param(DType::F32, &[total]);
    inputs.push(sig("act_scales", &[total], "f32"));
    let act_zps = g.param(DType::F32, &[total]);
    inputs.push(sig("act_zps", &[total], "f32"));
    let act_cfg = g.param(DType::F32, &[n_sites, 3]);
    inputs.push(sig("act_cfg", &[n_sites, 3], "f32"));

    // architecture frontend: data inputs + embedding sum (+ attention bias)
    let (x0, bias4) = match cfg.arch.architecture() {
        Architecture::Bert => bert::embed(&mut g, cfg, b, &p, &mut inputs)?,
        Architecture::Vit => vit::embed(&mut g, cfg, b, &p, &mut inputs)?,
    };

    let mut q = SiteQuant {
        sites,
        offsets,
        next: 0,
        diag,
        taps: Vec::new(),
        act_scales,
        act_zps,
        act_cfg,
    };

    let x0 = q.apply(&mut g, "embed_sum", &x0)?;
    let x0 = g.layernorm(&x0, &p["embed.ln.g"], &p["embed.ln.b"])?;
    let mut x = q.apply(&mut g, "embed_ln_out", &x0)?;

    for i in 0..cfg.layers {
        let pf = format!("layer{i}.");
        let wq = g.matmul_bias(&x, &p[&format!("{pf}q.w")], &p[&format!("{pf}q.b")])?;
        let wq = q.apply(&mut g, &format!("{pf}q"), &wq)?;
        let wk = g.matmul_bias(&x, &p[&format!("{pf}k.w")], &p[&format!("{pf}k.b")])?;
        let wk = q.apply(&mut g, &format!("{pf}k"), &wk)?;
        let wv = g.matmul_bias(&x, &p[&format!("{pf}v.w")], &p[&format!("{pf}v.b")])?;
        let wv = q.apply(&mut g, &format!("{pf}v"), &wv)?;
        // [b, t, d] -> [b, h, t, dh]
        let heads = |g: &mut GraphBuilder, v: &Op| -> Result<Op> {
            let r = g.reshape(v, &[b, t, h, dh])?;
            g.transpose(&r, &[0, 2, 1, 3])
        };
        let qh = heads(&mut g, &wq)?;
        let kh = heads(&mut g, &wk)?;
        let vh = heads(&mut g, &wv)?;
        // gated attention: per-head sigmoid gate from the block input,
        // G(x) = logistic(x @ gate.w + gate.b) with shape [b, t, h] —
        // a head whose gate saturates at 0 contributes nothing, so it
        // never needs the outlier trick to cancel itself
        let gate = match cfg.variant {
            AttnVariant::Gated => {
                let gl = g.matmul_bias(
                    &x,
                    &p[&format!("{pf}gate.w")],
                    &p[&format!("{pf}gate.b")],
                )?;
                Some(g.logistic(&gl))
            }
            _ => None,
        };
        let scores = g.dot_general(&qh, &kh, &[0, 1], &[0, 1], &[3], &[3])?;
        let mut scores = g.scale(&scores, 1.0 / (dh as f32).sqrt())?;
        // BERT masks PAD positions; ViT attends over the full patch grid
        if let Some(bias4) = &bias4 {
            scores = g.add(&scores, bias4)?;
        }
        let scores = q.apply(&mut g, &format!("{pf}attn_scores"), &scores)?;
        let probs = g.softmax(&scores)?;
        // clipped softmax: stretch the softmax output to [γ, ζ] and clamp
        // back to [0,1], so probabilities can hit exact 0/1 without the
        // extreme score magnitudes the vanilla block needs (its outlier
        // mechanism)
        let probs = match cfg.variant {
            AttnVariant::ClippedSoftmax => {
                let st = g.scale(&probs, CSOFT_ZETA - CSOFT_GAMMA)?;
                let st = g.offset(&st, CSOFT_GAMMA)?;
                let dims = st.dims.clone();
                let zero = g.const_f32(0.0);
                let lo = g.splat(&zero, &dims)?;
                let one = g.const_f32(1.0);
                let hi = g.splat(&one, &dims)?;
                g.clamp(&lo, &st, &hi)
            }
            _ => probs,
        };
        let probs = q.apply(&mut g, &format!("{pf}attn_probs"), &probs)?;
        let ctx = g.dot_general(&probs, &vh, &[0, 1], &[0, 1], &[3], &[2])?;
        // the gate multiplies the per-head context while it is still
        // [b, h, t, dh], before heads merge back into the model dim
        let ctx = match &gate {
            Some(gate) => {
                let gt = g.transpose(gate, &[0, 2, 1])?;
                let gb = g.broadcast(&gt, &[b, h, t, dh], &[0, 1, 2])?;
                g.mul(&ctx, &gb)?
            }
            None => ctx,
        };
        let ctx = g.transpose(&ctx, &[0, 2, 1, 3])?;
        let ctx = g.reshape(&ctx, &[b, t, d])?;
        let ctx = q.apply(&mut g, &format!("{pf}attn_ctx"), &ctx)?;
        let ao =
            g.matmul_bias(&ctx, &p[&format!("{pf}attn_out.w")], &p[&format!("{pf}attn_out.b")])?;
        let ao = q.apply(&mut g, &format!("{pf}attn_out"), &ao)?;
        let res1 = g.add(&x, &ao)?;
        let res1 = q.apply(&mut g, &format!("{pf}res1_sum"), &res1)?;
        let ln1 = g.layernorm(&res1, &p[&format!("{pf}ln1.g")], &p[&format!("{pf}ln1.b")])?;
        let ln1 = q.apply(&mut g, &format!("{pf}ln1_out"), &ln1)?;
        let hdn = g.matmul_bias(&ln1, &p[&format!("{pf}ffn1.w")], &p[&format!("{pf}ffn1.b")])?;
        let hdn = g.gelu(&hdn)?;
        let hdn = q.apply(&mut g, &format!("{pf}ffn_hidden"), &hdn)?;
        let fo = g.matmul_bias(&hdn, &p[&format!("{pf}ffn2.w")], &p[&format!("{pf}ffn2.b")])?;
        let fo = q.apply(&mut g, &format!("{pf}ffn_out"), &fo)?;
        let res2 = g.add(&ln1, &fo)?;
        let res2 = q.apply(&mut g, &format!("{pf}res2_sum"), &res2)?;
        let ln2 = g.layernorm(&res2, &p[&format!("{pf}ln2.g")], &p[&format!("{pf}ln2.b")])?;
        x = q.apply(&mut g, &format!("{pf}ln2_out"), &ln2)?;
    }

    // pooler over position 0 ([CLS] token / first patch) + head
    let cls = g.slice(&x, &[(0, b), (0, 1), (0, d)])?;
    let cls = g.reshape(&cls, &[b, d])?;
    let pooled = g.matmul_bias(&cls, &p["pool.w"], &p["pool.b"])?;
    let pooled = g.tanh(&pooled);
    let pooled = q.apply(&mut g, "pooled", &pooled)?;
    let logits = g.matmul_bias(&pooled, &p["head.w"], &p["head.b"])?;
    let logits = q.apply(&mut g, "head_out", &logits)?;

    if q.next != q.sites.len() {
        bail!("forward quantized {} of {} sites", q.next, q.sites.len());
    }

    let mut outputs = vec![sig("logits", &[b, cfg.n_out], "f32")];
    let mut roots = vec![logits];
    for (name, tap) in &q.taps {
        outputs.push(sig(format!("tap.{name}"), &tap.dims, "f32"));
        roots.push(tap.clone());
    }
    Ok(Artifact { text: g.finish(&roots), inputs, outputs })
}

/// Standalone per-lane fake-quant kernel (smoke-test artifact; same
/// signature as aot.py's `kernel_fq_d768`).
fn build_kernel_fq(rows: usize, d: usize, module: &str) -> Result<Artifact> {
    let mut g = GraphBuilder::new(module);
    let x = g.param(DType::F32, &[rows, d]);
    let s = g.param(DType::F32, &[d]);
    let z = g.param(DType::F32, &[d]);
    let c = g.param(DType::F32, &[3]);
    let dims = vec![rows, d];
    let sb = g.broadcast(&s, &dims, &[1])?;
    let zb = g.broadcast(&z, &dims, &[1])?;
    let scalar = |g: &mut GraphBuilder, c: &Op, j: usize| -> Result<Op> {
        let v = g.slice(c, &[(j, j + 1)])?;
        g.reshape(&v, &[])
    };
    let qmin = scalar(&mut g, &c, 0)?;
    let qmax = scalar(&mut g, &c, 1)?;
    let enable = scalar(&mut g, &c, 2)?;
    let qmin_b = g.splat(&qmin, &dims)?;
    let qmax_b = g.splat(&qmax, &dims)?;
    let t = g.div(&x, &sb)?;
    let r = g.round(&t);
    let q = g.add(&r, &zb)?;
    let qc = g.clamp(&qmin_b, &q, &qmax_b);
    let dq = {
        let c2 = g.sub(&qc, &zb)?;
        g.mul(&c2, &sb)?
    };
    let half = g.const_f32(0.5);
    let pred = g.compare("GT", &enable, &half)?;
    let pred_b = g.splat(&pred, &dims)?;
    let out = g.select(&pred_b, &dq, &x)?;
    Ok(Artifact {
        text: g.finish(&[out]),
        inputs: vec![
            sig("x", &[rows, d], "f32"),
            sig("scale", &[d], "f32"),
            sig("zp", &[d], "f32"),
            sig("cfg", &[3], "f32"),
        ],
        outputs: vec![sig("out", &[rows, d], "f32")],
    })
}

/// Tiny module with analytically-known outputs: `y = 2x + 1`, per-row
/// sums, per-column maxima. The integration suite checks the interpreter
/// against the closed form.
fn build_kernel_affine(module: &str) -> Result<Artifact> {
    let (rows, cols) = (4, 3);
    let mut g = GraphBuilder::new(module);
    let x = g.param(DType::F32, &[rows, cols]);
    let y = {
        let s = g.scale(&x, 2.0)?;
        g.offset(&s, 1.0)?
    };
    let rowsum = g.reduce_add(&x, &[1])?;
    let colmax = g.reduce_max(&x, &[0])?;
    Ok(Artifact {
        text: g.finish(&[y, rowsum, colmax]),
        inputs: vec![sig("x", &[rows, cols], "f32")],
        outputs: vec![
            sig("y", &[rows, cols], "f32"),
            sig("rowsum", &[rows], "f32"),
            sig("colmax", &[cols], "f32"),
        ],
    })
}

// ---------------------------------------------------------------------------
// manifest serialisation
// ---------------------------------------------------------------------------

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

fn num_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| num(x)).collect())
}

fn f32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn sig_json(entries: &[SigEntry]) -> Json {
    Json::Arr(
        entries
            .iter()
            .map(|e| {
                obj(vec![
                    ("name", Json::Str(e.name.clone())),
                    ("shape", num_arr(&e.shape)),
                    ("dtype", Json::Str(e.dtype.to_string())),
                ])
            })
            .collect(),
    )
}

fn model_json(info: &ModelInfo) -> Json {
    let c = &info.config;
    let mut config_fields = vec![
        ("name", Json::Str(c.name.clone())),
        ("architecture", Json::Str(c.architecture().name().to_string())),
        ("vocab", num(c.vocab)),
        ("d", num(c.d)),
        ("heads", num(c.heads)),
        ("layers", num(c.layers)),
        ("d_ff", num(c.d_ff)),
        ("seq", num(c.seq)),
        ("n_out", num(c.n_out)),
        ("outlier_dims", num_arr(&c.outlier_dims)),
    ];
    match &c.arch {
        ArchParams::Bert { pad_id, cls_id, sep_id } => {
            config_fields.push(("pad_id", num(*pad_id as usize)));
            config_fields.push(("cls_id", num(*cls_id as usize)));
            config_fields.push(("sep_id", num(*sep_id as usize)));
        }
        ArchParams::Vit { patch, img } => {
            config_fields.push(("patch", num(*patch)));
            config_fields.push(("img", num(*img)));
        }
    }
    // the "variant" key appears only for non-vanilla rows, so vanilla
    // model rows serialise byte-for-byte as before the variant axis
    if c.variant != AttnVariant::Vanilla {
        config_fields.push(("variant", Json::Str(c.variant.name().to_string())));
    }
    obj(vec![
        ("config", obj(config_fields)),
        (
            "params",
            Json::Arr(
                info.params
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("name", Json::Str(p.name.clone())),
                            ("shape", num_arr(&p.shape)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "sites",
            Json::Arr(
                info.sites
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("name", Json::Str(s.name.clone())),
                            ("channels", num(s.channels)),
                            ("offset", num(s.offset)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("total_scale_lanes", num(info.total_scale_lanes)),
        (
            "wq",
            Json::Arr(info.wq.iter().map(|w| Json::Str(w.clone())).collect()),
        ),
    ])
}

/// Golden fake-quant vectors, computed with the crate's own QDQ kernel so
/// the cross-layer check in `repro smoke` / integration is exact by
/// construction (mirrors aot.py `golden_fake_quant`).
fn golden_fake_quant() -> Result<Json> {
    let (rows, cols) = (5usize, 8usize);
    let mut rng = Rng::new(1234);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.uniform(-4.0, 4.0)).collect();
    let scale: Vec<f32> = (0..cols).map(|_| rng.uniform(0.01, 0.3)).collect();
    let zp: Vec<f32> = (0..cols).map(|_| rng.below(255) as f32).collect();
    let grid = QGrid { qmin: 0.0, qmax: 255.0 };
    let params: Vec<QParams> = scale
        .iter()
        .zip(&zp)
        .map(|(&s, &z)| QParams { scale: s, zero_point: z })
        .collect();
    let t = Tensor::new(vec![rows, cols], x.clone())?;
    let out = qdq_per_lane(&t, &params, grid)?;
    Ok(obj(vec![
        ("x", f32_arr(&x)),
        ("scale", f32_arr(&scale)),
        ("zp", f32_arr(&zp)),
        ("qmin", Json::Num(0.0)),
        ("qmax", Json::Num(255.0)),
        ("rows", num(rows)),
        ("cols", num(cols)),
        ("out", f32_arr(out.data())),
    ]))
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

/// Bake Fig. 2-style structured outliers into a vanilla checkpoint: the
/// config's `outlier_dims` lanes of the *last* layer's FFN-output bias
/// get large alternating-sign offsets — the deterministic stand-in for
/// what outlier-prone finetuning produces (cf. `hlo/train_graph.rs`'s
/// aux loss, which pulls exactly these lanes toward a large target).
/// Every downstream residual tap (`ffn_out`, `res2_sum`) then carries a
/// per-tensor range an order of magnitude above the typical lane, which
/// is what plain per-tensor W8A8 breaks on and PEG / the outlier-free
/// variants survive. Variant-family configs ship empty `outlier_dims`,
/// so their checkpoints stay clean — the comparison endpoint.
pub fn install_outliers(params: &mut Params, info: &ModelInfo) -> Result<()> {
    if info.config.outlier_dims.is_empty() {
        return Ok(());
    }
    let name = format!("layer{}.ffn2.b", info.config.layers - 1);
    let t = params.get_mut(&name)?;
    let data = t.data_mut();
    for (j, &dim) in info.config.outlier_dims.iter().enumerate() {
        if dim >= data.len() {
            bail!("outlier dim {dim} out of range for {name} ({})", data.len());
        }
        let sign = if j % 2 == 0 { 1.0 } else { -1.0 };
        data[dim] = sign * (16.0 + 4.0 * j as f32);
    }
    Ok(())
}

/// `repro gen-artifacts [--artifacts DIR] [--ckpt DIR] [--no-ckpt]`
pub fn cmd_gen_artifacts(args: &Args) -> Result<()> {
    let out = args.get_or("artifacts", "artifacts");
    let ckpt = args.get_or("ckpt", "checkpoints");
    let ckpt_dir = if args.flag("no-ckpt") { None } else { Some(Path::new(ckpt)) };
    generate(Path::new(out), ckpt_dir)
}

/// Emit the fixture artifact set for both architecture families: HLO
/// modules + one manifest.json (+ per-task deterministic init checkpoints
/// unless `ckpt_dir` is None).
pub fn generate(out_dir: &Path, ckpt_dir: Option<&Path>) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let base = base_config();
    let mut reg = base.clone();
    reg.name = "base_reg".to_string();
    reg.n_out = 1;
    let vit = vit_config();
    let mut vit_reg = vit.clone();
    vit_reg.name = "vit_reg".to_string();
    vit_reg.n_out = 1;

    // outlier-aware variant twins of each vanilla family: same topology,
    // clipped-softmax / gated attention blocks, and *no* outlier dims —
    // these rows are the clean endpoint `repro diag --outliers` and the
    // sweep's variant axis compare the vanilla families against
    let variant_of = |cfg: &FixtureConfig, variant: AttnVariant, regression: bool| {
        let mut v = cfg.clone();
        v.name = crate::model::manifest::model_name(cfg.arch.architecture(), variant, regression);
        v.variant = variant;
        v.outlier_dims = Vec::new();
        v
    };
    let variant_cfgs: Vec<FixtureConfig> = [(&base, &reg), (&vit, &vit_reg)]
        .into_iter()
        .flat_map(|(cls, rg)| {
            [AttnVariant::ClippedSoftmax, AttnVariant::Gated]
                .into_iter()
                .flat_map(move |v| [variant_of(cls, v, false), variant_of(rg, v, true)])
        })
        .collect();

    let mut jobs: Vec<(String, Artifact)> = Vec::new();
    for (head, cfg) in [("cls", &base), ("reg", &reg)] {
        for b in [1usize, 8] {
            let name = format!("fwd_{head}_b{b}");
            jobs.push((name.clone(), build_forward(cfg, b, false, &name)?));
        }
        let name = format!("diag_{head}_b1");
        jobs.push((name.clone(), build_forward(cfg, 1, true, &name)?));
        // train-step graphs (forward + backward + Adam) at the batch the
        // coordinator trains with
        let regression = *head == "reg";
        for (kind, qat) in [("fp32", false), ("qat", true)] {
            let name = format!("train_{kind}_{head}_b16");
            jobs.push((
                name.clone(),
                super::train_graph::build_train_step(cfg, regression, qat, 16, &name)?,
            ));
        }
    }
    // ViT family: forward + diag only (the train-graph builder's
    // gather-based embedding backward is BERT-specific; ViT QAT is a
    // follow-on once the patch-projection backward lands)
    for (head, cfg) in [("cls", &vit), ("reg", &vit_reg)] {
        for b in [1usize, 8] {
            let name = format!("fwd_vit_{head}_b{b}");
            jobs.push((name.clone(), build_forward(cfg, b, false, &name)?));
        }
        let name = format!("diag_vit_{head}_b1");
        jobs.push((name.clone(), build_forward(cfg, 1, true, &name)?));
    }
    // variant families: forward + diag per head (no train graphs — the
    // QAT train-step builder lowers the vanilla attention block only)
    for cfg in &variant_cfgs {
        let head = if cfg.n_out == 1 { "reg" } else { "cls" };
        let prefix = crate::model::manifest::family_prefix(cfg.arch.architecture(), cfg.variant);
        for b in [1usize, 8] {
            let name = format!("fwd_{prefix}{head}_b{b}");
            jobs.push((name.clone(), build_forward(cfg, b, false, &name)?));
        }
        let name = format!("diag_{prefix}{head}_b1");
        jobs.push((name.clone(), build_forward(cfg, 1, true, &name)?));
    }
    // parity artifact: the fixture has one lowering, so the "pallas" twin
    // is the same graph (the agreement test then checks interpreter
    // determinism end to end)
    jobs.push((
        "fwd_cls_b1_pallas".to_string(),
        build_forward(&base, 1, false, "fwd_cls_b1_pallas")?,
    ));
    jobs.push(("kernel_fq_d768".to_string(), build_kernel_fq(8, 768, "kernel_fq_d768")?));
    jobs.push(("kernel_affine".to_string(), build_kernel_affine("kernel_affine")?));

    let mut artifacts = BTreeMap::new();
    for (name, art) in &jobs {
        // every emitted module must pass the static verifier before it is
        // written: gen-artifacts never ships a graph the runtime's cache
        // admission gate would then reject
        let module = super::parse_module(&art.text)
            .with_context(|| format!("parsing generated artifact {name}"))?;
        super::verify::verify(&module)
            .with_context(|| format!("verifying generated artifact {name}"))?;
        let fname = format!("{name}.hlo.txt");
        std::fs::write(out_dir.join(&fname), &art.text)?;
        artifacts.insert(
            name.clone(),
            obj(vec![
                ("file", Json::Str(fname)),
                ("inputs", sig_json(&art.inputs)),
                ("outputs", sig_json(&art.outputs)),
            ]),
        );
        println!(
            "  lowered {name}: {} inputs, {} outputs, {} KiB",
            art.inputs.len(),
            art.outputs.len(),
            art.text.len() / 1024
        );
    }

    let base_info = model_info(&base);
    let reg_info = model_info(&reg);
    let vit_info = model_info(&vit);
    let vit_reg_info = model_info(&vit_reg);
    let variant_infos: Vec<ModelInfo> = variant_cfgs.iter().map(model_info).collect();
    let mut models = BTreeMap::new();
    models.insert("base".to_string(), model_json(&base_info));
    models.insert("base_reg".to_string(), model_json(&reg_info));
    models.insert("vit".to_string(), model_json(&vit_info));
    models.insert("vit_reg".to_string(), model_json(&vit_reg_info));
    for info in &variant_infos {
        models.insert(info.config.name.clone(), model_json(info));
    }

    let manifest = obj(vec![
        ("artifacts", Json::Obj(artifacts)),
        ("models", Json::Obj(models)),
        ("golden", obj(vec![("fake_quant", golden_fake_quant()?)])),
    ]);
    std::fs::write(out_dir.join("manifest.json"), manifest.to_string())?;
    println!("wrote manifest with {} artifacts to {}", jobs.len(), out_dir.display());

    if let Some(dir) = ckpt_dir {
        // every family (arch × variant) gets a per-task checkpoint from a
        // distinct seed base so families never share weights by accident;
        // vanilla checkpoints additionally get the structured outliers
        // baked in (see install_outliers) — the trained endpoint the
        // variants are compared against
        let families: Vec<(&ModelInfo, &ModelInfo, String, u64)> = {
            let mut f = vec![
                (&base_info, &reg_info, String::new(), 1000u64),
                (&vit_info, &vit_reg_info, "vit_".to_string(), 2000),
            ];
            for (k, pair) in variant_infos.chunks(2).enumerate() {
                let (cls, rg) = (&pair[0], &pair[1]);
                let prefix = crate::model::manifest::family_prefix(
                    cls.config.architecture(),
                    cls.config.variant,
                );
                f.push((cls, rg, prefix, 3000 + 1000 * k as u64));
            }
            f
        };
        let mut n_ckpts = 0usize;
        for (cls_info, reg_info, prefix, seed_base) in &families {
            for (i, task) in TASKS.iter().enumerate() {
                let info = match task.kind {
                    TaskKind::Regression => reg_info,
                    TaskKind::Classification(_) => cls_info,
                };
                let mut params = Params::init(info, seed_base + i as u64);
                install_outliers(&mut params, info)?;
                checkpoint::save(&params, dir.join(format!("{prefix}{}.ckpt", task.name)))?;
                n_ckpts += 1;
            }
        }
        println!("wrote {n_ckpts} fixture checkpoints to {}", dir.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{interpret, parse_module, Value};

    /// A micro config that keeps unit tests fast; d stays divisible by
    /// heads and by the PEG group counts the qconfig tests use.
    fn micro() -> FixtureConfig {
        FixtureConfig {
            name: "micro".to_string(),
            vocab: 8,
            d: 8,
            heads: 2,
            layers: 1,
            d_ff: 16,
            seq: 4,
            n_out: 3,
            outlier_dims: vec![1],
            arch: ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
            variant: AttnVariant::Vanilla,
        }
    }

    /// ViT twin of [`micro`]: 2×2 patches over a 4×4 image → seq 4.
    fn micro_vit() -> FixtureConfig {
        FixtureConfig {
            name: "micro_vit".to_string(),
            vocab: 8,
            d: 8,
            heads: 2,
            layers: 1,
            d_ff: 16,
            seq: 4,
            n_out: 3,
            outlier_dims: vec![1],
            arch: ArchParams::Vit { patch: 2, img: 4 },
            variant: AttnVariant::Vanilla,
        }
    }

    fn forward_inputs(cfg: &FixtureConfig, b: usize, enable: f32) -> Vec<Value> {
        let info = model_info(cfg);
        let params = Params::init(&info, 42);
        let mut vals: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| Value::F32 { dims: t.shape().to_vec(), data: t.data().to_vec() })
            .collect();
        let s = info.total_scale_lanes;
        vals.push(Value::F32 { dims: vec![s], data: vec![1.0; s] });
        vals.push(Value::F32 { dims: vec![s], data: vec![0.0; s] });
        let n_sites = info.sites.len();
        let mut cfg3 = Vec::with_capacity(n_sites * 3);
        for _ in 0..n_sites {
            cfg3.extend_from_slice(&[0.0, 255.0, enable]);
        }
        vals.push(Value::F32 { dims: vec![n_sites, 3], data: cfg3 });
        let t = cfg.seq;
        match cfg.arch.architecture() {
            Architecture::Bert => {
                let ids: Vec<i32> = (0..b * t).map(|i| (i % cfg.vocab) as i32).collect();
                vals.push(Value::S32 { dims: vec![b, t], data: ids });
                vals.push(Value::S32 { dims: vec![b, t], data: vec![0; b * t] });
                vals.push(Value::F32 { dims: vec![b, t], data: vec![1.0; b * t] });
            }
            Architecture::Vit => {
                let p = info.config.patch_dim().unwrap();
                let px: Vec<f32> =
                    (0..b * t * p).map(|i| ((i % 7) as f32) * 0.3 - 0.9).collect();
                vals.push(Value::F32 { dims: vec![b, t, p], data: px });
            }
        }
        vals
    }

    #[test]
    fn topology_matches_paper_proportions() {
        let info = model_info(&base_config());
        assert_eq!(info.sites.len(), 13 * info.config.layers + 4);
        assert_eq!(info.config.d, 128);
        let mut off = 0;
        for s in &info.sites {
            assert_eq!(s.offset, off);
            off += s.channels;
        }
        assert_eq!(off, info.total_scale_lanes);
        // fwd signature: params + 3 quant tensors + 3 batch tensors
        let art = build_forward(&base_config(), 1, false, "t").unwrap();
        assert_eq!(art.inputs.len(), info.params.len() + 6);
    }

    #[test]
    fn vit_topology_shares_the_site_inventory() {
        let vit = vit_config();
        let info = model_info(&vit);
        assert_eq!(info.config.architecture(), Architecture::Vit);
        // identical site inventory to the BERT family at the same depth:
        // specs and presets transfer across architectures unchanged
        let bert_sites = site_spec(&base_config());
        assert_eq!(site_spec(&vit), bert_sites);
        // the patch grid must be consistent with seq
        let (patch, img) =
            (info.config.arch.patch().unwrap(), info.config.arch.img().unwrap());
        assert_eq!(info.config.seq, (img / patch) * (img / patch));
        // patch projection replaces the three token-embedding tables
        assert!(info.params.iter().any(|p| p.name == "embed.patch.w"));
        assert!(info.params.iter().all(|p| p.name != "embed.tok"));
        assert_eq!(info.wq[0], "embed.patch.w");
        // fwd signature: params + 3 quant tensors + 1 pixel tensor
        let art = build_forward(&vit, 1, false, "t").unwrap();
        assert_eq!(art.inputs.len(), info.params.len() + 4);
        assert_eq!(art.inputs.last().unwrap().name, "pixels");
        assert_eq!(
            art.inputs.last().unwrap().shape,
            vec![1, info.config.seq, info.config.patch_dim().unwrap()]
        );
    }

    #[test]
    fn forward_is_finite_deterministic_and_quant_sensitive() {
        let cfg = micro();
        let art = build_forward(&cfg, 2, false, "micro_fwd").unwrap();
        let m = parse_module(&art.text).unwrap();
        let run = |enable: f32| -> Vec<f32> {
            let out = interpret(&m, &forward_inputs(&cfg, 2, enable)).unwrap();
            out[0].f32s().unwrap().to_vec()
        };
        let fp32 = run(0.0);
        assert_eq!(fp32.len(), 2 * cfg.n_out);
        assert!(fp32.iter().all(|v| v.is_finite()));
        assert_eq!(fp32, run(0.0), "interpreter must be deterministic");
        // crushing activations to the [0,255] grid at scale 1 changes the
        // logits but keeps them finite
        let crushed = run(1.0);
        assert!(crushed.iter().all(|v| v.is_finite()));
        assert_ne!(fp32, crushed);
    }

    #[test]
    fn vit_forward_is_finite_deterministic_and_quant_sensitive() {
        let cfg = micro_vit();
        let art = build_forward(&cfg, 2, false, "micro_vit_fwd").unwrap();
        let m = parse_module(&art.text).unwrap();
        let run = |enable: f32| -> Vec<f32> {
            let out = interpret(&m, &forward_inputs(&cfg, 2, enable)).unwrap();
            out[0].f32s().unwrap().to_vec()
        };
        let fp32 = run(0.0);
        assert_eq!(fp32.len(), 2 * cfg.n_out);
        assert!(fp32.iter().all(|v| v.is_finite()));
        assert_eq!(fp32, run(0.0), "interpreter must be deterministic");
        let crushed = run(1.0);
        assert!(crushed.iter().all(|v| v.is_finite()));
        assert_ne!(fp32, crushed);
    }

    #[test]
    fn diag_taps_cover_every_site_in_order() {
        for cfg in [micro(), micro_vit()] {
            let art = build_forward(&cfg, 1, true, "micro_diag").unwrap();
            let info = model_info(&cfg);
            assert_eq!(art.outputs.len(), 1 + info.sites.len(), "{}", cfg.name);
            for (o, s) in art.outputs[1..].iter().zip(&info.sites) {
                assert_eq!(o.name, format!("tap.{}", s.name));
                if s.channels > 1 {
                    assert_eq!(*o.shape.last().unwrap(), s.channels, "{}", s.name);
                }
            }
            let m = parse_module(&art.text).unwrap();
            let out = interpret(&m, &forward_inputs(&cfg, 1, 0.0)).unwrap();
            assert_eq!(out.len(), 1 + info.sites.len());
            for v in &out {
                assert!(v.f32s().unwrap().iter().all(|x| x.is_finite()));
            }
        }
    }

    #[test]
    fn kernel_fq_matches_rust_qdq() {
        let art = build_kernel_fq(2, 4, "fq_test").unwrap();
        let m = parse_module(&art.text).unwrap();
        let x = [0.3f32, -1.2, 2.7, 0.05, 1.11, -0.4, 0.0, 3.9];
        let scale = [0.02f32, 0.05, 0.1, 0.2];
        let zp = [128.0f32, 3.0, 0.0, 17.0];
        let out = interpret(&m, &[
            Value::F32 { dims: vec![2, 4], data: x.to_vec() },
            Value::F32 { dims: vec![4], data: scale.to_vec() },
            Value::F32 { dims: vec![4], data: zp.to_vec() },
            Value::F32 { dims: vec![3], data: vec![0.0, 255.0, 1.0] },
        ])
        .unwrap();
        let got = out[0].f32s().unwrap();
        let grid = QGrid { qmin: 0.0, qmax: 255.0 };
        for (i, (&g, &v)) in got.iter().zip(&x).enumerate() {
            let p = QParams { scale: scale[i % 4], zero_point: zp[i % 4] };
            let want = crate::quant::qdq(v, p, grid);
            assert!((g - want).abs() < 1e-5, "lane {i}: {g} vs {want}");
        }
        // enable = 0 passes through untouched
        let out = interpret(&m, &[
            Value::F32 { dims: vec![2, 4], data: x.to_vec() },
            Value::F32 { dims: vec![4], data: scale.to_vec() },
            Value::F32 { dims: vec![4], data: zp.to_vec() },
            Value::F32 { dims: vec![3], data: vec![0.0, 255.0, 0.0] },
        ])
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &x);
    }

    #[test]
    fn kernel_affine_analytic_values() {
        let art = build_kernel_affine("affine_test").unwrap();
        let m = parse_module(&art.text).unwrap();
        let x: Vec<f32> = (0..12).map(|i| i as f32 - 5.0).collect();
        let out = interpret(&m, &[Value::F32 { dims: vec![4, 3], data: x.clone() }])
            .unwrap();
        let y = out[0].f32s().unwrap();
        for (a, b) in y.iter().zip(&x) {
            assert!((a - (2.0 * b + 1.0)).abs() < 1e-6);
        }
        let rowsum = out[1].f32s().unwrap();
        for (r, chunk) in rowsum.iter().zip(x.chunks(3)) {
            assert!((r - chunk.iter().sum::<f32>()).abs() < 1e-6);
        }
        let colmax = out[2].f32s().unwrap();
        assert_eq!(colmax, &[x[9], x[10], x[11]]);
    }

    #[test]
    fn generate_writes_loadable_artifacts() {
        let dir = std::env::temp_dir().join("tq_fixture_gen_test");
        std::fs::remove_dir_all(&dir).ok();
        // micro-speed: no checkpoints in the unit test
        generate(&dir, None).unwrap();
        let manifest = crate::model::manifest::Manifest::load(&dir).unwrap();
        assert!(manifest.artifacts.len() >= 19);
        assert!(manifest.artifact("fwd_cls_b8").is_ok());
        assert!(manifest.artifact("diag_reg_b1").is_ok());
        // ViT family: forward + diag for both heads
        for name in
            ["fwd_vit_cls_b1", "fwd_vit_cls_b8", "fwd_vit_reg_b8", "diag_vit_cls_b1", "diag_vit_reg_b1"]
        {
            assert!(manifest.artifact(name).is_ok(), "{name}");
        }
        // train-step artifacts for both heads and both variants
        for name in
            ["train_fp32_cls_b16", "train_qat_cls_b16", "train_fp32_reg_b16", "train_qat_reg_b16"]
        {
            let art = manifest.artifact(name).unwrap();
            assert_eq!(art.outputs.last().unwrap().name, "loss", "{name}");
        }
        assert!(manifest.model("base").is_ok());
        assert!(manifest.model("base_reg").is_ok());
        let vit = manifest.model("vit").unwrap();
        assert_eq!(vit.config.architecture(), Architecture::Vit);
        assert_eq!(manifest.model("vit_reg").unwrap().config.n_out, 1);
        // variant families: forward + diag per head, for both
        // architectures, plus their model rows tagged with the variant
        for prefix in ["csoft_", "gate_", "vit_csoft_", "vit_gate_"] {
            for name in [
                format!("fwd_{prefix}cls_b1"),
                format!("fwd_{prefix}cls_b8"),
                format!("diag_{prefix}cls_b1"),
                format!("fwd_{prefix}reg_b8"),
                format!("diag_{prefix}reg_b1"),
            ] {
                assert!(manifest.artifact(&name).is_ok(), "{name}");
            }
        }
        for (model, variant) in [
            ("bert_csoft", AttnVariant::ClippedSoftmax),
            ("bert_gate", AttnVariant::Gated),
            ("vit_csoft", AttnVariant::ClippedSoftmax),
            ("vit_gate", AttnVariant::Gated),
        ] {
            let info = manifest.model(model).unwrap();
            assert_eq!(info.config.variant, variant, "{model}");
            // the clean comparison endpoint: no installed outlier lanes
            assert!(info.config.outlier_dims.is_empty(), "{model}");
            let reg = manifest.model(&format!("{model}_reg")).unwrap();
            assert_eq!(reg.config.n_out, 1, "{model}_reg");
            assert_eq!(reg.config.variant, variant, "{model}_reg");
        }
        // the gated families carry the extra gate parameters; vanilla and
        // clipped-softmax share the vanilla parameter inventory
        let n_base = manifest.model("base").unwrap().params.len();
        assert_eq!(manifest.model("bert_csoft").unwrap().params.len(), n_base);
        assert!(manifest.model("bert_gate").unwrap().params.len() > n_base);
        assert!(manifest
            .model("bert_gate")
            .unwrap()
            .params
            .iter()
            .any(|p| p.name.contains("gate")));
        assert!(manifest.golden_fake_quant.is_some());
        // golden gate: every artifact file parses AND passes the static
        // verifier — gen-artifacts must never ship a module the runtime's
        // cache-admission check would reject
        for a in manifest.artifacts.values() {
            let text = std::fs::read_to_string(&a.file).unwrap();
            let m = parse_module(&text).unwrap();
            crate::hlo::verify(&m).unwrap_or_else(|e| panic!("{}: {e:#}", a.name));
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
