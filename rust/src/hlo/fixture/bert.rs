//! BERT frontend for the fixture generator: token/position/type
//! embeddings over integer ids plus the additive PAD attention-mask bias.
//! Everything downstream of the embedding sum lives in the
//! architecture-neutral core (`super`).

use std::collections::BTreeMap;

use anyhow::Result;

use super::super::builder::{GraphBuilder, Op};
use super::super::DType;
use super::{sig, FixtureConfig, SigEntry, MASK_BIAS};
use crate::model::manifest::ArchParams;

/// The fixture "base" model: d = 128 like the real export (integration
/// tests and PEG group counts depend on it), but 1 layer / seq 24 so the
/// interpreter evaluates a full dev split in seconds.
pub fn base_config() -> FixtureConfig {
    FixtureConfig {
        name: "base".to_string(),
        vocab: 64,
        d: 128,
        heads: 4,
        layers: 1,
        d_ff: 256,
        seq: 24,
        n_out: 3,
        outlier_dims: vec![17, 89, 101],
        arch: ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
        variant: crate::model::manifest::AttnVariant::Vanilla,
    }
}

/// Embedding-table parameters (precede the shared `embed.ln.*` entries).
pub(crate) fn embed_params(cfg: &FixtureConfig) -> Vec<(String, Vec<usize>)> {
    let d = cfg.d;
    vec![
        ("embed.tok".into(), vec![cfg.vocab, d]),
        ("embed.pos".into(), vec![cfg.seq, d]),
        ("embed.type".into(), vec![2, d]),
    ]
}

/// Lower the BERT data inputs and embedding sum. Returns the pre-LN
/// embedding `[b, t, d]` and the additive attention-mask bias
/// `[b, h, t, t]` (PAD positions get [`MASK_BIAS`]).
pub(crate) fn embed(
    g: &mut GraphBuilder,
    cfg: &FixtureConfig,
    b: usize,
    p: &BTreeMap<String, Op>,
    inputs: &mut Vec<SigEntry>,
) -> Result<(Op, Option<Op>)> {
    let (t, d, h) = (cfg.seq, cfg.d, cfg.heads);
    let ids = g.param(DType::S32, &[b, t]);
    inputs.push(sig("input_ids", &[b, t], "i32"));
    let tt = g.param(DType::S32, &[b, t]);
    inputs.push(sig("token_type", &[b, t], "i32"));
    let mask = g.param(DType::F32, &[b, t]);
    inputs.push(sig("attn_mask", &[b, t], "f32"));

    // embeddings: tok[ids] + pos + type[token_type]
    let ids_flat = g.reshape(&ids, &[b * t])?;
    let tok = g.gather_rows(&p["embed.tok"], &ids_flat)?;
    let tok = g.reshape(&tok, &[b, t, d])?;
    let pos = g.broadcast(&p["embed.pos"], &[b, t, d], &[1, 2])?;
    let tt_flat = g.reshape(&tt, &[b * t])?;
    let typ = g.gather_rows(&p["embed.type"], &tt_flat)?;
    let typ = g.reshape(&typ, &[b, t, d])?;
    let x0 = g.add(&tok, &pos)?;
    let x0 = g.add(&x0, &typ)?;

    // additive attention-mask bias, broadcast to [b, h, t, t]
    let one = g.const_f32(1.0);
    let ones = g.splat(&one, &[b, t])?;
    let inv_mask = g.sub(&ones, &mask)?;
    let bias2 = g.scale(&inv_mask, MASK_BIAS)?;
    let bias4 = g.broadcast(&bias2, &[b, h, t, t], &[0, 3])?;
    Ok((x0, Some(bias4)))
}
