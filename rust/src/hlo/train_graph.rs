//! Train-step graphs: the fixture forward plus a hand-derived backward
//! pass and an in-graph Adam update, lowered to the same HLO-text dialect
//! as the inference artifacts so `coordinator::train` runs end-to-end on
//! the in-repo interpreter.
//!
//! Two variants, matching the signatures `coordinator/train.rs` feeds:
//!
//! * `train_fp32_{head}_b16` — plain fine-tuning with the outlier-inducing
//!   auxiliary loss (DESIGN.md §2) on the last layer's `ffn_out`.
//! * `train_qat_{head}_b16` — quantization-aware training: every
//!   activation site carries the runtime-parameterised fake-quant of the
//!   forward graphs, every `wq` weight is fake-quantised per-tensor, and
//!   the backward pass applies the straight-through estimator for inputs
//!   plus the LSQ gradient `(q_c - z) - u·1[in-range]` for the scales.
//!
//! The forward emits the *same op sequence* as `fixture::build_forward`,
//! so with quantizers disabled the train graph's logits are bit-identical
//! to the inference graph's — pinned in the tests below, together with a
//! finite-difference check of the analytic gradients (recovered exactly
//! from the first-step Adam moment output: `g = m' / (1 - β1)`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::builder::{GraphBuilder, Op};
use super::fixture::{
    param_spec, sig, site_offsets, site_spec, wq_spec, Artifact, FixtureConfig, SigEntry,
    MASK_BIAS,
};
use super::DType;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

fn splat_c(g: &mut GraphBuilder, v: f32, dims: &[usize]) -> Result<Op> {
    let c = g.const_f32(v);
    g.splat(&c, dims)
}

fn row_scalar(g: &mut GraphBuilder, row: &Op, j: usize) -> Result<Op> {
    let c = g.slice(row, &[(0, 1), (j, j + 1)])?;
    g.reshape(&c, &[])
}

// ---------------------------------------------------------------------------
// fake-quant forward/backward (activation sites + weight tensors)
// ---------------------------------------------------------------------------

/// Saved per-site state for the STE/LSQ backward.
struct ActTape {
    channels: usize,
    zb: Op,
    u: Op,
    q: Op,
    qc: Op,
    qmin_b: Op,
    qmax_b: Op,
    pred_b: Op,
}

/// Walks the canonical site order (like `fixture::SiteQuant`), emitting
/// QDQ in QAT mode and collecting per-site scale gradients.
struct SiteCtx {
    sites: Vec<(String, usize)>,
    offsets: Vec<usize>,
    next: usize,
    qat: bool,
    a_s: Option<Op>,
    a_z: Option<Op>,
    a_cfg: Option<Op>,
    tapes: Vec<Option<ActTape>>,
    grads: Vec<Option<Op>>,
}

impl SiteCtx {
    fn idx_of(&self, name: &str) -> Result<usize> {
        self.sites
            .iter()
            .position(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("unknown quant site {name:?}"))
    }

    fn apply(&mut self, g: &mut GraphBuilder, name: &str, x: &Op) -> Result<Op> {
        let (want, channels) = self
            .sites
            .get(self.next)
            .cloned()
            .ok_or_else(|| anyhow!("more quant sites than site_spec entries"))?;
        if want != name {
            bail!("site order mismatch: expected {want:?}, got {name:?}");
        }
        let offset = self.offsets[self.next];
        let idx = self.next;
        self.next += 1;
        if !self.qat {
            return Ok(x.clone());
        }
        let (a_s, a_z, a_cfg) = (
            self.a_s.clone().unwrap(),
            self.a_z.clone().unwrap(),
            self.a_cfg.clone().unwrap(),
        );
        let dims = x.dims.clone();
        let rank = dims.len();
        let (sb, zb) = if channels == 1 {
            let s = g.slice(&a_s, &[(offset, offset + 1)])?;
            let s0 = g.reshape(&s, &[])?;
            let z = g.slice(&a_z, &[(offset, offset + 1)])?;
            let z0 = g.reshape(&z, &[])?;
            (g.splat(&s0, &dims)?, g.splat(&z0, &dims)?)
        } else {
            if dims[rank - 1] != channels {
                bail!("site {name}: {channels} lanes vs last dim {}", dims[rank - 1]);
            }
            let s = g.slice(&a_s, &[(offset, offset + channels)])?;
            let z = g.slice(&a_z, &[(offset, offset + channels)])?;
            (
                g.broadcast(&s, &dims, &[rank - 1])?,
                g.broadcast(&z, &dims, &[rank - 1])?,
            )
        };
        let row = g.slice(&a_cfg, &[(idx, idx + 1), (0, 3)])?;
        let qmin = row_scalar(g, &row, 0)?;
        let qmax = row_scalar(g, &row, 1)?;
        let enable = row_scalar(g, &row, 2)?;
        let qmin_b = g.splat(&qmin, &dims)?;
        let qmax_b = g.splat(&qmax, &dims)?;
        let u = g.div(x, &sb)?;
        let r = g.round(&u);
        let q = g.add(&r, &zb)?;
        let qc = g.clamp(&qmin_b, &q, &qmax_b);
        let dq = {
            let c = g.sub(&qc, &zb)?;
            g.mul(&c, &sb)?
        };
        let half = g.const_f32(0.5);
        let pred = g.compare("GT", &enable, &half)?;
        let pred_b = g.splat(&pred, &dims)?;
        let y = g.select(&pred_b, &dq, x)?;
        self.tapes[idx] =
            Some(ActTape { channels, zb, u, q, qc, qmin_b, qmax_b, pred_b });
        Ok(y)
    }

    /// STE input gradient + LSQ scale gradient, reduced to the site's
    /// lanes and stashed for the final concatenation.
    fn backward(&mut self, g: &mut GraphBuilder, name: &str, dy: &Op) -> Result<Op> {
        let idx = self.idx_of(name)?;
        if !self.qat {
            return Ok(dy.clone());
        }
        let t = self.tapes[idx]
            .take()
            .ok_or_else(|| anyhow!("site {name:?} backward before forward"))?;
        let dims = dy.dims.clone();
        let ones = splat_c(g, 1.0, &dims)?;
        let zeros = splat_c(g, 0.0, &dims)?;
        let ge = g.compare("GE", &t.q, &t.qmin_b)?;
        let mge = g.select(&ge, &ones, &zeros)?;
        let le = g.compare("LE", &t.q, &t.qmax_b)?;
        let mle = g.select(&le, &ones, &zeros)?;
        let mask = g.mul(&mge, &mle)?;
        let dxm = g.mul(dy, &mask)?;
        let dx = g.select(&t.pred_b, &dxm, dy)?;
        // LSQ: in-range rows give round(u) - u, clamped rows qmin/qmax - z
        let qz = g.sub(&t.qc, &t.zb)?;
        let um = g.mul(&t.u, &mask)?;
        let gs = g.sub(&qz, &um)?;
        let dgs = g.mul(dy, &gs)?;
        let dse = g.select(&t.pred_b, &dgs, &zeros)?;
        let rank = dims.len();
        let grad = if t.channels == 1 {
            let all: Vec<usize> = (0..rank).collect();
            let s = g.reduce_add(&dse, &all)?;
            g.reshape(&s, &[1])?
        } else {
            let lead: Vec<usize> = (0..rank - 1).collect();
            g.reduce_add(&dse, &lead)?
        };
        self.grads[idx] = Some(grad);
        Ok(dx)
    }
}

/// Saved per-weight-tensor QDQ state (symmetric, zero-point 0).
#[derive(Clone)]
struct WTape {
    j: usize,
    sb: Op,
    u: Op,
    q: Op,
    qc: Op,
    qmin_b: Op,
    qmax_b: Op,
    pred_b: Op,
}

fn wqdq_fwd(
    g: &mut GraphBuilder,
    w: &Op,
    j: usize,
    w_s: &Op,
    w_cfg: &Op,
) -> Result<(Op, WTape)> {
    let dims = w.dims.clone();
    let s = g.slice(w_s, &[(j, j + 1)])?;
    let s0 = g.reshape(&s, &[])?;
    let sb = g.splat(&s0, &dims)?;
    let row = g.slice(w_cfg, &[(j, j + 1), (0, 3)])?;
    let qmin = row_scalar(g, &row, 0)?;
    let qmax = row_scalar(g, &row, 1)?;
    let enable = row_scalar(g, &row, 2)?;
    let qmin_b = g.splat(&qmin, &dims)?;
    let qmax_b = g.splat(&qmax, &dims)?;
    let u = g.div(w, &sb)?;
    let q = g.round(&u);
    let qc = g.clamp(&qmin_b, &q, &qmax_b);
    let dq = g.mul(&qc, &sb)?;
    let half = g.const_f32(0.5);
    let pred = g.compare("GT", &enable, &half)?;
    let pred_b = g.splat(&pred, &dims)?;
    let y = g.select(&pred_b, &dq, w)?;
    Ok((y, WTape { j, sb, u, q, qc, qmin_b, qmax_b, pred_b }))
}

// ---------------------------------------------------------------------------
// gradient accumulation
// ---------------------------------------------------------------------------

struct GradSink {
    grads: BTreeMap<String, Op>,
    wtapes: BTreeMap<String, WTape>,
    ws_grads: Vec<Option<Op>>,
}

impl GradSink {
    fn add(&mut self, g: &mut GraphBuilder, name: &str, grad: Op) -> Result<()> {
        if let Some(prev) = self.grads.remove(name) {
            let merged = g.add(&prev, &grad)?;
            self.grads.insert(name.to_string(), merged);
        } else {
            self.grads.insert(name.to_string(), grad);
        }
        Ok(())
    }

    /// Gradient w.r.t. a weight *as used* in the forward: routed through
    /// the weight QDQ backward in QAT mode (STE + per-tensor LSQ grad).
    fn weight(&mut self, g: &mut GraphBuilder, name: &str, dwq: Op) -> Result<()> {
        let Some(t) = self.wtapes.get(name).cloned() else {
            return self.add(g, name, dwq);
        };
        let dims = dwq.dims.clone();
        let ones = splat_c(g, 1.0, &dims)?;
        let zeros = splat_c(g, 0.0, &dims)?;
        let ge = g.compare("GE", &t.q, &t.qmin_b)?;
        let mge = g.select(&ge, &ones, &zeros)?;
        let le = g.compare("LE", &t.q, &t.qmax_b)?;
        let mle = g.select(&le, &ones, &zeros)?;
        let mask = g.mul(&mge, &mle)?;
        let dxm = g.mul(&dwq, &mask)?;
        let dw = g.select(&t.pred_b, &dxm, &dwq)?;
        let um = g.mul(&t.u, &mask)?;
        let gs = g.sub(&t.qc, &um)?;
        let dgs = g.mul(&dwq, &gs)?;
        let dse = g.select(&t.pred_b, &dgs, &zeros)?;
        let all: Vec<usize> = (0..dims.len()).collect();
        let s = g.reduce_add(&dse, &all)?;
        let sv = g.reshape(&s, &[1])?;
        let slot = &mut self.ws_grads[t.j];
        *slot = Some(match slot.take() {
            Some(prev) => g.add(&prev, &sv)?,
            None => sv,
        });
        self.add(g, name, dw)
    }
}

// ---------------------------------------------------------------------------
// differentiable composites
// ---------------------------------------------------------------------------

/// LayerNorm emitting the identical op sequence to `builder::layernorm`,
/// returning what the backward needs (x-hat, broadcast inv-std, gain).
struct LnTape {
    norm: Op,
    invb: Op,
    gb: Op,
}

fn ln_fwd(g: &mut GraphBuilder, x: &Op, gain: &Op, bias: &Op) -> Result<(Op, LnTape)> {
    let rank = x.dims.len();
    let last = rank - 1;
    let d = x.dims[last];
    let keep: Vec<usize> = (0..rank - 1).collect();
    let sum = g.reduce_add(x, &[last])?;
    let mean = g.scale(&sum, 1.0 / d as f32)?;
    let mb = g.broadcast(&mean, &x.dims.clone(), &keep)?;
    let xc = g.sub(x, &mb)?;
    let sq = g.mul(&xc, &xc)?;
    let var_sum = g.reduce_add(&sq, &[last])?;
    let var = g.scale(&var_sum, 1.0 / d as f32)?;
    let var_eps = g.offset(&var, 1e-5)?;
    let inv = g.rsqrt(&var_eps);
    let invb = g.broadcast(&inv, &x.dims.clone(), &keep)?;
    let norm = g.mul(&xc, &invb)?;
    let gb = g.broadcast(gain, &x.dims.clone(), &[last])?;
    let bb = g.broadcast(bias, &x.dims.clone(), &[last])?;
    let scaled = g.mul(&norm, &gb)?;
    let y = g.add(&scaled, &bb)?;
    Ok((y, LnTape { norm, invb, gb }))
}

/// dx = σ̂·(dx̂ − mean(dx̂) − x̂·mean(dx̂·x̂)) over the last axis.
fn ln_bwd(g: &mut GraphBuilder, t: &LnTape, dy: &Op) -> Result<(Op, Op, Op)> {
    let rank = dy.dims.len();
    let last = rank - 1;
    let d = dy.dims[last];
    let keep: Vec<usize> = (0..last).collect();
    let dnorm = g.mul(dy, &t.gb)?;
    let dyn_ = g.mul(dy, &t.norm)?;
    let dg = g.reduce_add(&dyn_, &keep)?;
    let db = g.reduce_add(dy, &keep)?;
    let s1 = g.reduce_add(&dnorm, &[last])?;
    let m1 = g.scale(&s1, 1.0 / d as f32)?;
    let m1b = g.broadcast(&m1, &dy.dims.clone(), &keep)?;
    let dn_n = g.mul(&dnorm, &t.norm)?;
    let s2 = g.reduce_add(&dn_n, &[last])?;
    let m2 = g.scale(&s2, 1.0 / d as f32)?;
    let m2b = g.broadcast(&m2, &dy.dims.clone(), &keep)?;
    let nm2 = g.mul(&t.norm, &m2b)?;
    let inner = g.sub(&dnorm, &m1b)?;
    let inner = g.sub(&inner, &nm2)?;
    let dx = g.mul(&inner, &t.invb)?;
    Ok((dx, dg, db))
}

/// `builder::gelu`'s exact op sequence, also returning tanh(inner).
fn gelu_fwd(g: &mut GraphBuilder, x: &Op) -> Result<(Op, Op)> {
    let x2 = g.mul(x, x)?;
    let x3 = g.mul(&x2, x)?;
    let c = g.scale(&x3, 0.044715)?;
    let s = g.add(x, &c)?;
    let inner = g.scale(&s, 0.797_884_6)?;
    let t = g.tanh(&inner);
    let one = g.offset(&t, 1.0)?;
    let half = g.scale(&one, 0.5)?;
    let y = g.mul(x, &half)?;
    Ok((y, t))
}

/// g'(x) = ½(1+t) + ½x(1−t²)·c·(1+3a·x²), t = tanh(c(x+ax³)).
fn gelu_bwd(g: &mut GraphBuilder, x: &Op, t: &Op, dy: &Op) -> Result<Op> {
    let t2 = g.mul(t, t)?;
    let nt2 = g.scale(&t2, -1.0)?;
    let om = g.offset(&nt2, 1.0)?;
    let x2 = g.mul(x, x)?;
    let poly = {
        let p = g.scale(&x2, 3.0 * 0.044715)?;
        g.offset(&p, 1.0)?
    };
    let half_term = {
        let o = g.offset(t, 1.0)?;
        g.scale(&o, 0.5)?
    };
    let term2 = {
        let a = g.mul(x, &om)?;
        let b = g.mul(&a, &poly)?;
        g.scale(&b, 0.5 * 0.797_884_6)?
    };
    let deriv = g.add(&half_term, &term2)?;
    g.mul(dy, &deriv)
}

/// dS = P ∘ (dP − Σ_last(dP ∘ P)).
fn softmax_bwd(g: &mut GraphBuilder, probs: &Op, dp: &Op) -> Result<Op> {
    let rank = dp.dims.len();
    let last = rank - 1;
    let keep: Vec<usize> = (0..last).collect();
    let pd = g.mul(dp, probs)?;
    let s = g.reduce_add(&pd, &[last])?;
    let sb = g.broadcast(&s, &dp.dims.clone(), &keep)?;
    let inner = g.sub(dp, &sb)?;
    g.mul(probs, &inner)
}

/// dx = dy·wᵀ, dw = xᵀ·dy, db = Σ_lead dy for `y = x@w + b`.
fn matmul_bias_bwd(
    g: &mut GraphBuilder,
    x: &Op,
    w: &Op,
    dy: &Op,
) -> Result<(Op, Op, Op)> {
    let rank = dy.dims.len();
    let lead: Vec<usize> = (0..rank - 1).collect();
    let dx = g.dot_general(dy, w, &[], &[], &[rank - 1], &[1])?;
    let dw = g.dot_general(x, dy, &[], &[], &lead, &lead)?;
    let db = g.reduce_add(dy, &lead)?;
    Ok((dx, dw, db))
}

/// `[n, v]` one-hot rows from s32 indices (iota + compare EQ + select —
/// no scatter needed; the table gradient is then a plain dot).
fn one_hot(g: &mut GraphBuilder, idx: &Op, v: usize) -> Result<Op> {
    let n = idx.dims[0];
    let io = g.iota(DType::F32, &[n, v], 1)?;
    let f = g.convert(idx, DType::F32);
    let fb = g.broadcast(&f, &[n, v], &[0])?;
    let pr = g.compare("EQ", &io, &fb)?;
    let ones = splat_c(g, 1.0, &[n, v])?;
    let zeros = splat_c(g, 0.0, &[n, v])?;
    g.select(&pr, &ones, &zeros)
}

/// One Adam step: m' = β1·m + (1−β1)g, v' = β2·v + (1−β2)g²,
/// p' = p − lr·m'/(√v' + ε). Bias correction stays host-side in `lr_eff`.
fn adam_update(
    g: &mut GraphBuilder,
    p: &Op,
    m: &Op,
    v: &Op,
    grad: &Op,
    lr: &Op,
) -> Result<(Op, Op, Op)> {
    let m1 = g.scale(m, ADAM_B1)?;
    let g1 = g.scale(grad, 1.0 - ADAM_B1)?;
    let m_new = g.add(&m1, &g1)?;
    let v1 = g.scale(v, ADAM_B2)?;
    let g2 = g.mul(grad, grad)?;
    let g2s = g.scale(&g2, 1.0 - ADAM_B2)?;
    let v_new = g.add(&v1, &g2s)?;
    let sq = g.sqrt(&v_new);
    let denom = g.offset(&sq, ADAM_EPS)?;
    let lrb = g.splat(lr, &p.dims.clone())?;
    let num = g.mul(&lrb, &m_new)?;
    let step = g.div(&num, &denom)?;
    let p_new = g.sub(p, &step)?;
    Ok((p_new, m_new, v_new))
}

// ---------------------------------------------------------------------------
// the train step
// ---------------------------------------------------------------------------

/// QAT quantizer-state parameters (scales are trained; zero-points and
/// cfg rows are fixed inputs).
struct QState {
    a_s: Op,
    msv: Op,
    vsv: Op,
    a_z: Op,
    a_cfg: Op,
    w_s: Op,
    mwv: Op,
    vwv: Op,
    w_cfg: Op,
}

struct LayerTape {
    x_in: Op,
    qh: Op,
    kh: Op,
    vh: Op,
    probs: Op,
    probs_q: Op,
    ctx_q: Op,
    ln1_tape: LnTape,
    ln1_q: Op,
    h_lin: Op,
    gelu_t: Op,
    h_q: Op,
    fo: Op,
    ln2_tape: LnTape,
}

/// Lower one train step for `cfg` at batch `b`. Input/output ordering is
/// the `coordinator/train.rs` contract:
///
/// fp32 in:  p…, m…, v…, ids, token_type, mask, labels, lr, aux_λ, aux_t
/// fp32 out: p'…, m'…, v'…, loss
/// qat  in:  p…, m…, v…, a_s, m_s, v_s, a_z, a_cfg, w_s, m_w, v_w, w_cfg,
///           ids, token_type, mask, labels, lr, lr_scales
/// qat  out: p'…, m'…, v'…, a_s', m_s', v_s', w_s', m_w', v_w', loss
pub(crate) fn build_train_step(
    cfg: &FixtureConfig,
    regression: bool,
    qat: bool,
    b: usize,
    module: &str,
) -> Result<Artifact> {
    if cfg.arch.architecture() != crate::model::manifest::Architecture::Bert {
        // the embedding backward below is gather/scatter over token
        // tables; the ViT patch-projection backward is a follow-on
        bail!("train-step lowering only supports the BERT frontend (got {})",
            cfg.arch.architecture().name());
    }
    let (t, d, h) = (cfg.seq, cfg.d, cfg.heads);
    let dh = d / h;
    if dh * h != d {
        bail!("heads {h} must divide d {d}");
    }
    let (offsets, total) = site_offsets(cfg);
    let sites = site_spec(cfg);
    let n_sites = sites.len();
    let wq_names = wq_spec(cfg);
    let n_wq = wq_names.len();
    let pspec = param_spec(cfg);
    let np = pspec.len();

    let mut g = GraphBuilder::new(module);
    let mut inputs: Vec<SigEntry> = Vec::new();

    let mut p: BTreeMap<String, Op> = BTreeMap::new();
    let mut p_ord = Vec::with_capacity(np);
    for (name, shape) in &pspec {
        let op = g.param(DType::F32, shape);
        inputs.push(sig(format!("param.{name}"), shape, "f32"));
        p.insert(name.clone(), op.clone());
        p_ord.push(op);
    }
    let mut m_ord = Vec::with_capacity(np);
    for (name, shape) in &pspec {
        m_ord.push(g.param(DType::F32, shape));
        inputs.push(sig(format!("m.{name}"), shape, "f32"));
    }
    let mut v_ord = Vec::with_capacity(np);
    for (name, shape) in &pspec {
        v_ord.push(g.param(DType::F32, shape));
        inputs.push(sig(format!("v.{name}"), shape, "f32"));
    }

    // QAT quantizer state (scales are trained, z / cfg are fixed inputs)
    let mut qstate: Option<QState> = None;
    if qat {
        let a_s = g.param(DType::F32, &[total]);
        inputs.push(sig("act_scales", &[total], "f32"));
        let msv = g.param(DType::F32, &[total]);
        inputs.push(sig("m_scales", &[total], "f32"));
        let vsv = g.param(DType::F32, &[total]);
        inputs.push(sig("v_scales", &[total], "f32"));
        let a_z = g.param(DType::F32, &[total]);
        inputs.push(sig("act_zps", &[total], "f32"));
        let a_cfg = g.param(DType::F32, &[n_sites, 3]);
        inputs.push(sig("act_cfg", &[n_sites, 3], "f32"));
        let w_s = g.param(DType::F32, &[n_wq]);
        inputs.push(sig("wq_scales", &[n_wq], "f32"));
        let mwv = g.param(DType::F32, &[n_wq]);
        inputs.push(sig("m_wq", &[n_wq], "f32"));
        let vwv = g.param(DType::F32, &[n_wq]);
        inputs.push(sig("v_wq", &[n_wq], "f32"));
        let w_cfg = g.param(DType::F32, &[n_wq, 3]);
        inputs.push(sig("wq_cfg", &[n_wq, 3], "f32"));
        qstate = Some(QState { a_s, msv, vsv, a_z, a_cfg, w_s, mwv, vwv, w_cfg });
    }

    let ids = g.param(DType::S32, &[b, t]);
    inputs.push(sig("input_ids", &[b, t], "i32"));
    let tt_in = g.param(DType::S32, &[b, t]);
    inputs.push(sig("token_type", &[b, t], "i32"));
    let mask = g.param(DType::F32, &[b, t]);
    inputs.push(sig("attn_mask", &[b, t], "f32"));
    let labels = if regression {
        let l = g.param(DType::F32, &[b]);
        inputs.push(sig("labels", &[b], "f32"));
        l
    } else {
        let l = g.param(DType::S32, &[b]);
        inputs.push(sig("labels", &[b], "i32"));
        l
    };
    let lr = g.param(DType::F32, &[]);
    inputs.push(sig("lr", &[], "f32"));
    let mut aux_lambda = None;
    let mut aux_target = None;
    let mut lr_scales = None;
    if qat {
        let l = g.param(DType::F32, &[]);
        inputs.push(sig("lr_scales", &[], "f32"));
        lr_scales = Some(l);
    } else {
        let l = g.param(DType::F32, &[]);
        inputs.push(sig("aux_lambda", &[], "f32"));
        aux_lambda = Some(l);
        let tg = g.param(DType::F32, &[]);
        inputs.push(sig("aux_target", &[], "f32"));
        aux_target = Some(tg);
    }

    // weight fake-quant (QAT): wq-listed tensors as used by the forward
    let mut sink = GradSink {
        grads: BTreeMap::new(),
        wtapes: BTreeMap::new(),
        ws_grads: vec![None; n_wq],
    };
    let mut used: BTreeMap<String, Op> = p.clone();
    if let Some(q) = &qstate {
        for (j, name) in wq_names.iter().enumerate() {
            let (y, tape) = wqdq_fwd(&mut g, &p[name], j, &q.w_s, &q.w_cfg)?;
            used.insert(name.clone(), y);
            sink.wtapes.insert(name.clone(), tape);
        }
    }

    let mut sc = SiteCtx {
        sites,
        offsets,
        next: 0,
        qat,
        a_s: qstate.as_ref().map(|q| q.a_s.clone()),
        a_z: qstate.as_ref().map(|q| q.a_z.clone()),
        a_cfg: qstate.as_ref().map(|q| q.a_cfg.clone()),
        tapes: (0..n_sites).map(|_| None).collect(),
        grads: (0..n_sites).map(|_| None).collect(),
    };

    // -- forward (op-for-op the fixture forward, with intermediates saved)
    let ids_flat = g.reshape(&ids, &[b * t])?;
    let tok = g.gather_rows(&used["embed.tok"], &ids_flat)?;
    let tok3 = g.reshape(&tok, &[b, t, d])?;
    let pos = g.broadcast(&p["embed.pos"], &[b, t, d], &[1, 2])?;
    let tt_flat = g.reshape(&tt_in, &[b * t])?;
    let typ = g.gather_rows(&p["embed.type"], &tt_flat)?;
    let typ3 = g.reshape(&typ, &[b, t, d])?;
    let x0 = g.add(&tok3, &pos)?;
    let x0 = g.add(&x0, &typ3)?;
    let x0q = sc.apply(&mut g, "embed_sum", &x0)?;
    let (eln, eln_tape) = ln_fwd(&mut g, &x0q, &p["embed.ln.g"], &p["embed.ln.b"])?;
    let mut x = sc.apply(&mut g, "embed_ln_out", &eln)?;

    let one = g.const_f32(1.0);
    let ones_bt = g.splat(&one, &[b, t])?;
    let inv_mask = g.sub(&ones_bt, &mask)?;
    let bias2 = g.scale(&inv_mask, MASK_BIAS)?;
    let bias4 = g.broadcast(&bias2, &[b, h, t, t], &[0, 3])?;

    let heads_of = |g: &mut GraphBuilder, v: &Op| -> Result<Op> {
        let r = g.reshape(v, &[b, t, h, dh])?;
        g.transpose(&r, &[0, 2, 1, 3])
    };
    let unheads = |g: &mut GraphBuilder, v: &Op| -> Result<Op> {
        let r = g.transpose(v, &[0, 2, 1, 3])?;
        g.reshape(&r, &[b, t, d])
    };

    let mut tapes: Vec<LayerTape> = Vec::with_capacity(cfg.layers);
    for i in 0..cfg.layers {
        let pf = format!("layer{i}.");
        let x_in = x.clone();
        let wq_l = g.matmul_bias(&x, &used[&format!("{pf}q.w")], &p[&format!("{pf}q.b")])?;
        let wq_q = sc.apply(&mut g, &format!("{pf}q"), &wq_l)?;
        let wk_l = g.matmul_bias(&x, &used[&format!("{pf}k.w")], &p[&format!("{pf}k.b")])?;
        let wk_q = sc.apply(&mut g, &format!("{pf}k"), &wk_l)?;
        let wv_l = g.matmul_bias(&x, &used[&format!("{pf}v.w")], &p[&format!("{pf}v.b")])?;
        let wv_q = sc.apply(&mut g, &format!("{pf}v"), &wv_l)?;
        let qh = heads_of(&mut g, &wq_q)?;
        let kh = heads_of(&mut g, &wk_q)?;
        let vh = heads_of(&mut g, &wv_q)?;
        let scores = g.dot_general(&qh, &kh, &[0, 1], &[0, 1], &[3], &[3])?;
        let scores = g.scale(&scores, 1.0 / (dh as f32).sqrt())?;
        let scores = g.add(&scores, &bias4)?;
        let scores_q = sc.apply(&mut g, &format!("{pf}attn_scores"), &scores)?;
        let probs = g.softmax(&scores_q)?;
        let probs_q = sc.apply(&mut g, &format!("{pf}attn_probs"), &probs)?;
        let ctx = g.dot_general(&probs_q, &vh, &[0, 1], &[0, 1], &[3], &[2])?;
        let ctx = g.transpose(&ctx, &[0, 2, 1, 3])?;
        let ctx = g.reshape(&ctx, &[b, t, d])?;
        let ctx_q = sc.apply(&mut g, &format!("{pf}attn_ctx"), &ctx)?;
        let ao = g.matmul_bias(
            &ctx_q,
            &used[&format!("{pf}attn_out.w")],
            &p[&format!("{pf}attn_out.b")],
        )?;
        let ao_q = sc.apply(&mut g, &format!("{pf}attn_out"), &ao)?;
        let res1 = g.add(&x, &ao_q)?;
        let res1_q = sc.apply(&mut g, &format!("{pf}res1_sum"), &res1)?;
        let (ln1, ln1_tape) =
            ln_fwd(&mut g, &res1_q, &p[&format!("{pf}ln1.g")], &p[&format!("{pf}ln1.b")])?;
        let ln1_q = sc.apply(&mut g, &format!("{pf}ln1_out"), &ln1)?;
        let h_lin = g.matmul_bias(
            &ln1_q,
            &used[&format!("{pf}ffn1.w")],
            &p[&format!("{pf}ffn1.b")],
        )?;
        let (h_act, gelu_t) = gelu_fwd(&mut g, &h_lin)?;
        let h_q = sc.apply(&mut g, &format!("{pf}ffn_hidden"), &h_act)?;
        let fo = g.matmul_bias(
            &h_q,
            &used[&format!("{pf}ffn2.w")],
            &p[&format!("{pf}ffn2.b")],
        )?;
        let fo_q = sc.apply(&mut g, &format!("{pf}ffn_out"), &fo)?;
        let res2 = g.add(&ln1_q, &fo_q)?;
        let res2_q = sc.apply(&mut g, &format!("{pf}res2_sum"), &res2)?;
        let (ln2, ln2_tape) =
            ln_fwd(&mut g, &res2_q, &p[&format!("{pf}ln2.g")], &p[&format!("{pf}ln2.b")])?;
        x = sc.apply(&mut g, &format!("{pf}ln2_out"), &ln2)?;
        tapes.push(LayerTape {
            x_in,
            qh,
            kh,
            vh,
            probs,
            probs_q,
            ctx_q,
            ln1_tape,
            ln1_q,
            h_lin,
            gelu_t,
            h_q,
            fo,
            ln2_tape,
        });
    }

    let cls_s = g.slice(&x, &[(0, b), (0, 1), (0, d)])?;
    let cls = g.reshape(&cls_s, &[b, d])?;
    let pooled_lin = g.matmul_bias(&cls, &used["pool.w"], &p["pool.b"])?;
    let pooled_t = g.tanh(&pooled_lin);
    let pooled_q = sc.apply(&mut g, "pooled", &pooled_t)?;
    let logits_lin = g.matmul_bias(&pooled_q, &used["head.w"], &p["head.b"])?;
    let logits = sc.apply(&mut g, "head_out", &logits_lin)?;
    if sc.next != n_sites {
        bail!("forward quantized {} of {n_sites} sites", sc.next);
    }

    // -- loss + dL/dlogits
    let n_out = cfg.n_out;
    let (task_loss, dlogits) = if regression {
        let pred = g.reshape(&logits, &[b])?;
        let diff = g.sub(&pred, &labels)?;
        let sq = g.mul(&diff, &diff)?;
        let tot = g.reduce_add(&sq, &[0])?;
        let loss = g.scale(&tot, 1.0 / b as f32)?;
        let dpred = g.scale(&diff, 2.0 / b as f32)?;
        (loss, g.reshape(&dpred, &[b, 1])?)
    } else {
        let oh = one_hot(&mut g, &labels, n_out)?;
        let mx = g.reduce_max(&logits, &[1])?;
        let mxb = g.broadcast(&mx, &[b, n_out], &[0])?;
        let zc = g.sub(&logits, &mxb)?;
        let e = g.exp(&zc);
        let ssum = g.reduce_add(&e, &[1])?;
        let lsum = g.log(&ssum);
        let lsb = g.broadcast(&lsum, &[b, n_out], &[0])?;
        let logp = g.sub(&zc, &lsb)?;
        let picked = g.mul(&oh, &logp)?;
        let rows = g.reduce_add(&picked, &[1])?;
        let tot = g.reduce_add(&rows, &[0])?;
        let loss = g.scale(&tot, -1.0 / b as f32)?;
        let ssb = g.broadcast(&ssum, &[b, n_out], &[0])?;
        let psm = g.div(&e, &ssb)?;
        let dlog = g.sub(&psm, &oh)?;
        (loss, g.scale(&dlog, 1.0 / b as f32)?)
    };

    // outlier-inducing aux loss on the last layer's ffn_out (fp32 only)
    let mut aux_dfo: Option<Op> = None;
    let loss = if let (Some(lam), Some(targ)) = (&aux_lambda, &aux_target) {
        let kn = cfg.outlier_dims.len().max(1);
        let iota_d = g.iota(DType::F32, &[d], 0)?;
        let ones_d = splat_c(&mut g, 1.0, &[d])?;
        let zeros_d = splat_c(&mut g, 0.0, &[d])?;
        let mut mask_d = zeros_d.clone();
        for &k in &cfg.outlier_dims {
            let kc = g.const_f32(k as f32);
            let kb = g.splat(&kc, &[d])?;
            let pr = g.compare("EQ", &iota_d, &kb)?;
            let onek = g.select(&pr, &ones_d, &zeros_d)?;
            mask_d = g.add(&mask_d, &onek)?;
        }
        let maskb = g.broadcast(&mask_d, &[b, t, d], &[2])?;
        let targb = g.splat(targ, &[b, t, d])?;
        let aux_x = &tapes.last().ok_or_else(|| anyhow!("no layers"))?.fo;
        let dxm = g.sub(aux_x, &targb)?;
        let xm = g.mul(&dxm, &maskb)?;
        let sq = g.mul(&xm, &xm)?;
        let s3 = g.reduce_add(&sq, &[0, 1, 2])?;
        let mean = g.scale(&s3, 1.0 / (b * t * kn) as f32)?;
        let aux = g.mul(lam, &mean)?;
        let coef = g.scale(lam, 2.0 / (b * t * kn) as f32)?;
        let coefb = g.splat(&coef, &[b, t, d])?;
        aux_dfo = Some(g.mul(&coefb, &xm)?);
        g.add(&task_loss, &aux)?
    } else {
        task_loss
    };

    // -- backward
    let d_logits_lin = sc.backward(&mut g, "head_out", &dlogits)?;
    let (d_pooled_q, dwh, dbh) = matmul_bias_bwd(&mut g, &pooled_q, &used["head.w"], &d_logits_lin)?;
    sink.weight(&mut g, "head.w", dwh)?;
    sink.add(&mut g, "head.b", dbh)?;
    let d_pooled_t = sc.backward(&mut g, "pooled", &d_pooled_q)?;
    let d_pooled_lin = {
        let y2 = g.mul(&pooled_t, &pooled_t)?;
        let ny2 = g.scale(&y2, -1.0)?;
        let om = g.offset(&ny2, 1.0)?;
        g.mul(&d_pooled_t, &om)?
    };
    let (d_cls, dwp, dbp) = matmul_bias_bwd(&mut g, &cls, &used["pool.w"], &d_pooled_lin)?;
    sink.weight(&mut g, "pool.w", dwp)?;
    sink.add(&mut g, "pool.b", dbp)?;
    let d_cls3 = g.reshape(&d_cls, &[b, 1, d])?;
    let mut d_x = if t > 1 {
        let zrest = splat_c(&mut g, 0.0, &[b, t - 1, d])?;
        g.concatenate(&[d_cls3, zrest], 1)?
    } else {
        d_cls3
    };

    for (i, tape) in tapes.iter().enumerate().rev() {
        let pf = format!("layer{i}.");
        let d_ln2 = sc.backward(&mut g, &format!("{pf}ln2_out"), &d_x)?;
        let (d_res2q, dg2, db2) = ln_bwd(&mut g, &tape.ln2_tape, &d_ln2)?;
        sink.add(&mut g, &format!("{pf}ln2.g"), dg2)?;
        sink.add(&mut g, &format!("{pf}ln2.b"), db2)?;
        let d_res2 = sc.backward(&mut g, &format!("{pf}res2_sum"), &d_res2q)?;
        // res2 = ln1_q + fo_q: gradient fans out to both
        let mut d_fo = sc.backward(&mut g, &format!("{pf}ffn_out"), &d_res2)?;
        if let Some(aux) = aux_dfo.as_ref().filter(|_| i + 1 == cfg.layers) {
            d_fo = g.add(&d_fo, aux)?;
        }
        let (d_hq, dw2, db2f) =
            matmul_bias_bwd(&mut g, &tape.h_q, &used[&format!("{pf}ffn2.w")], &d_fo)?;
        sink.weight(&mut g, &format!("{pf}ffn2.w"), dw2)?;
        sink.add(&mut g, &format!("{pf}ffn2.b"), db2f)?;
        let d_hact = sc.backward(&mut g, &format!("{pf}ffn_hidden"), &d_hq)?;
        let d_hlin = gelu_bwd(&mut g, &tape.h_lin, &tape.gelu_t, &d_hact)?;
        let (d_ln1q_2, dw1, db1f) =
            matmul_bias_bwd(&mut g, &tape.ln1_q, &used[&format!("{pf}ffn1.w")], &d_hlin)?;
        sink.weight(&mut g, &format!("{pf}ffn1.w"), dw1)?;
        sink.add(&mut g, &format!("{pf}ffn1.b"), db1f)?;
        let d_ln1q = g.add(&d_res2, &d_ln1q_2)?;
        let d_ln1 = sc.backward(&mut g, &format!("{pf}ln1_out"), &d_ln1q)?;
        let (d_res1q, dg1, db1) = ln_bwd(&mut g, &tape.ln1_tape, &d_ln1)?;
        sink.add(&mut g, &format!("{pf}ln1.g"), dg1)?;
        sink.add(&mut g, &format!("{pf}ln1.b"), db1)?;
        let d_res1 = sc.backward(&mut g, &format!("{pf}res1_sum"), &d_res1q)?;
        let d_ao = sc.backward(&mut g, &format!("{pf}attn_out"), &d_res1)?;
        let (d_ctxq, dwo, dbo) =
            matmul_bias_bwd(&mut g, &tape.ctx_q, &used[&format!("{pf}attn_out.w")], &d_ao)?;
        sink.weight(&mut g, &format!("{pf}attn_out.w"), dwo)?;
        sink.add(&mut g, &format!("{pf}attn_out.b"), dbo)?;
        let d_ctxr = sc.backward(&mut g, &format!("{pf}attn_ctx"), &d_ctxq)?;
        let d_ctx4 = g.reshape(&d_ctxr, &[b, t, h, dh])?;
        let d_ctx = g.transpose(&d_ctx4, &[0, 2, 1, 3])?;
        let d_probs_q =
            g.dot_general(&d_ctx, &tape.vh, &[0, 1], &[0, 1], &[3], &[3])?;
        let d_vh = g.dot_general(&tape.probs_q, &d_ctx, &[0, 1], &[0, 1], &[2], &[2])?;
        let d_probs = sc.backward(&mut g, &format!("{pf}attn_probs"), &d_probs_q)?;
        let d_scores_q = softmax_bwd(&mut g, &tape.probs, &d_probs)?;
        let d_scores2 = sc.backward(&mut g, &format!("{pf}attn_scores"), &d_scores_q)?;
        let d_scores0 = g.scale(&d_scores2, 1.0 / (dh as f32).sqrt())?;
        let d_qh = g.dot_general(&d_scores0, &tape.kh, &[0, 1], &[0, 1], &[3], &[2])?;
        let d_kh = g.dot_general(&d_scores0, &tape.qh, &[0, 1], &[0, 1], &[2], &[2])?;
        let d_wqq = unheads(&mut g, &d_qh)?;
        let d_wkq = unheads(&mut g, &d_kh)?;
        let d_wvq = unheads(&mut g, &d_vh)?;
        let mut d_xin = d_res1.clone();
        for (site, dv, wn, bn) in [
            ("q", &d_wqq, "q.w", "q.b"),
            ("k", &d_wkq, "k.w", "k.b"),
            ("v", &d_wvq, "v.w", "v.b"),
        ] {
            let d_lin = sc.backward(&mut g, &format!("{pf}{site}"), dv)?;
            let (dxp, dw, db) =
                matmul_bias_bwd(&mut g, &tape.x_in, &used[&format!("{pf}{wn}")], &d_lin)?;
            sink.weight(&mut g, &format!("{pf}{wn}"), dw)?;
            sink.add(&mut g, &format!("{pf}{bn}"), db)?;
            d_xin = g.add(&d_xin, &dxp)?;
        }
        d_x = d_xin;
    }

    // embeddings backward
    let d_eln = sc.backward(&mut g, "embed_ln_out", &d_x)?;
    let (d_x0q, dge, dbe) = ln_bwd(&mut g, &eln_tape, &d_eln)?;
    sink.add(&mut g, "embed.ln.g", dge)?;
    sink.add(&mut g, "embed.ln.b", dbe)?;
    let d_x0 = sc.backward(&mut g, "embed_sum", &d_x0q)?;
    let d_pos = g.reduce_add(&d_x0, &[0])?;
    sink.add(&mut g, "embed.pos", d_pos)?;
    let d_flat = g.reshape(&d_x0, &[b * t, d])?;
    let oh_tok = one_hot(&mut g, &ids_flat, cfg.vocab)?;
    let d_tok_tbl = g.dot_general(&oh_tok, &d_flat, &[], &[], &[0], &[0])?;
    sink.weight(&mut g, "embed.tok", d_tok_tbl)?;
    let oh_typ = one_hot(&mut g, &tt_flat, 2)?;
    let d_typ_tbl = g.dot_general(&oh_typ, &d_flat, &[], &[], &[0], &[0])?;
    sink.add(&mut g, "embed.type", d_typ_tbl)?;

    // -- Adam updates & outputs
    let mut p_out = Vec::with_capacity(np);
    let mut m_out = Vec::with_capacity(np);
    let mut v_out = Vec::with_capacity(np);
    for (i, (name, _)) in pspec.iter().enumerate() {
        let grad = sink
            .grads
            .remove(name)
            .ok_or_else(|| anyhow!("missing gradient for param {name:?}"))?;
        let (pn, mn, vn) = adam_update(&mut g, &p_ord[i], &m_ord[i], &v_ord[i], &grad, &lr)?;
        p_out.push(pn);
        m_out.push(mn);
        v_out.push(vn);
    }

    let mut outputs: Vec<SigEntry> = Vec::new();
    for (name, shape) in &pspec {
        outputs.push(sig(format!("out.param.{name}"), shape, "f32"));
    }
    for (name, shape) in &pspec {
        outputs.push(sig(format!("out.m.{name}"), shape, "f32"));
    }
    for (name, shape) in &pspec {
        outputs.push(sig(format!("out.v.{name}"), shape, "f32"));
    }
    let mut roots: Vec<Op> = Vec::new();
    roots.extend(p_out);
    roots.extend(m_out);
    roots.extend(v_out);

    if let Some(q) = &qstate {
        let lr_s = lr_scales.as_ref().expect("qat lr_scales");
        let parts: Vec<Op> = sc
            .grads
            .iter()
            .enumerate()
            .map(|(i, o)| o.clone().ok_or_else(|| anyhow!("site {i} grad missing")))
            .collect::<Result<_>>()?;
        let gs_all = g.concatenate(&parts, 0)?;
        let (asn, msn, vsn) =
            adam_update(&mut g, &q.a_s, &q.msv, &q.vsv, &gs_all, lr_s)?;
        let wparts: Vec<Op> = sink
            .ws_grads
            .iter()
            .enumerate()
            .map(|(j, o)| o.clone().ok_or_else(|| anyhow!("wq {j} grad missing")))
            .collect::<Result<_>>()?;
        let gw_all = g.concatenate(&wparts, 0)?;
        let (wsn, mwn, vwn) =
            adam_update(&mut g, &q.w_s, &q.mwv, &q.vwv, &gw_all, lr_s)?;
        roots.extend([asn, msn, vsn, wsn, mwn, vwn]);
        outputs.push(sig("out.act_scales", &[total], "f32"));
        outputs.push(sig("out.m_scales", &[total], "f32"));
        outputs.push(sig("out.v_scales", &[total], "f32"));
        outputs.push(sig("out.wq_scales", &[n_wq], "f32"));
        outputs.push(sig("out.m_wq", &[n_wq], "f32"));
        outputs.push(sig("out.v_wq", &[n_wq], "f32"));
    }
    roots.push(loss);
    outputs.push(sig("loss", &[], "f32"));

    Ok(Artifact { text: g.finish(&roots), inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::fixture::{build_forward, model_info};
    use crate::hlo::{interpret, parse_module, HloModule, Plan, Value};
    use crate::model::Params;

    fn micro() -> FixtureConfig {
        FixtureConfig {
            name: "micro".to_string(),
            vocab: 8,
            d: 8,
            heads: 2,
            layers: 1,
            d_ff: 16,
            seq: 4,
            n_out: 3,
            outlier_dims: vec![1],
            arch: crate::model::manifest::ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
            variant: crate::model::manifest::AttnVariant::Vanilla,
        }
    }

    fn f32v(dims: &[usize], data: Vec<f32>) -> Value {
        Value::F32 { dims: dims.to_vec(), data }
    }

    /// Batch tensors shared by every variant: ids cycling the vocab, all
    /// token-type 0, full attention mask, labels i % n_out (or 0.5·i).
    fn batch_inputs(cfg: &FixtureConfig, b: usize, regression: bool) -> Vec<Value> {
        let t = cfg.seq;
        let ids: Vec<i32> = (0..b * t).map(|i| (i % cfg.vocab) as i32).collect();
        let mut vals = vec![
            Value::S32 { dims: vec![b, t], data: ids },
            Value::S32 { dims: vec![b, t], data: vec![0; b * t] },
            f32v(&[b, t], vec![1.0; b * t]),
        ];
        if regression {
            vals.push(f32v(&[b], (0..b).map(|i| 0.5 * i as f32).collect()));
        } else {
            vals.push(Value::S32 {
                dims: vec![b],
                data: (0..b).map(|i| (i % cfg.n_out) as i32).collect(),
            });
        }
        vals
    }

    /// fp32 train inputs: params + zero moments + batch + lr/aux scalars.
    fn fp32_inputs(
        cfg: &FixtureConfig,
        b: usize,
        regression: bool,
        lr: f32,
        lam: f32,
        targ: f32,
    ) -> Vec<Value> {
        let info = model_info(cfg);
        let params = Params::init(&info, 42);
        let mut vals: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| f32v(t.shape(), t.data().to_vec()))
            .collect();
        for t in &params.tensors {
            vals.push(f32v(t.shape(), vec![0.0; t.data().len()]));
        }
        for t in &params.tensors {
            vals.push(f32v(t.shape(), vec![0.0; t.data().len()]));
        }
        vals.extend(batch_inputs(cfg, b, regression));
        vals.push(Value::scalar_f32(lr));
        vals.push(Value::scalar_f32(lam));
        vals.push(Value::scalar_f32(targ));
        vals
    }

    /// QAT train inputs; `enable` switches every activation/weight
    /// quantizer on or off via the cfg rows.
    fn qat_inputs(cfg: &FixtureConfig, b: usize, lr: f32, enable: f32) -> Vec<Value> {
        let info = model_info(cfg);
        let params = Params::init(&info, 42);
        let lanes = info.total_scale_lanes;
        let n_sites = info.sites.len();
        let n_wq = info.wq.len();
        let mut vals: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| f32v(t.shape(), t.data().to_vec()))
            .collect();
        for _ in 0..2 {
            for t in &params.tensors {
                vals.push(f32v(t.shape(), vec![0.0; t.data().len()]));
            }
        }
        vals.push(f32v(&[lanes], vec![0.05; lanes])); // act_scales
        vals.push(f32v(&[lanes], vec![0.0; lanes])); // m_scales
        vals.push(f32v(&[lanes], vec![0.0; lanes])); // v_scales
        vals.push(f32v(&[lanes], vec![128.0; lanes])); // act_zps
        let mut acfg = Vec::with_capacity(n_sites * 3);
        for _ in 0..n_sites {
            acfg.extend_from_slice(&[0.0, 255.0, enable]);
        }
        vals.push(f32v(&[n_sites, 3], acfg));
        let w_s: Vec<f32> = info
            .wq
            .iter()
            .map(|name| {
                let i = info.params.iter().position(|p| &p.name == name).unwrap();
                let amax =
                    params.tensors[i].data().iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                (amax / 127.0).max(1e-6)
            })
            .collect();
        vals.push(f32v(&[n_wq], w_s));
        vals.push(f32v(&[n_wq], vec![0.0; n_wq])); // m_wq
        vals.push(f32v(&[n_wq], vec![0.0; n_wq])); // v_wq
        let mut wcfg = Vec::with_capacity(n_wq * 3);
        for _ in 0..n_wq {
            wcfg.extend_from_slice(&[-127.0, 127.0, enable]);
        }
        vals.push(f32v(&[n_wq, 3], wcfg));
        vals.extend(batch_inputs(cfg, b, false));
        vals.push(Value::scalar_f32(lr));
        vals.push(Value::scalar_f32(lr)); // lr_scales
        vals
    }

    fn train_module(cfg: &FixtureConfig, regression: bool, qat: bool, b: usize) -> HloModule {
        let art = build_train_step(cfg, regression, qat, b, "t").unwrap();
        parse_module(&art.text).unwrap()
    }

    fn run(m: &HloModule, inputs: &[Value]) -> Vec<Value> {
        interpret(m, inputs).unwrap()
    }

    /// Host-f64 cross-entropy of the forward graph's logits — the train
    /// graph emits the identical forward op sequence, so its loss must
    /// agree closely.
    #[test]
    fn fp32_loss_matches_forward_cross_entropy() {
        let cfg = micro();
        let (b, n_out) = (2usize, cfg.n_out);
        let m = train_module(&cfg, false, false, b);
        let inputs = fp32_inputs(&cfg, b, false, 0.0, 0.0, 0.0);
        let out = run(&m, &inputs);
        let np = param_spec(&cfg).len();
        assert_eq!(out.len(), 3 * np + 1);
        let loss = out[3 * np].f32s().unwrap()[0];

        // forward graph at enable=0, same params
        let fwd = build_forward(&cfg, b, false, "fwd").unwrap();
        let fm = parse_module(&fwd.text).unwrap();
        let info = model_info(&cfg);
        let params = Params::init(&info, 42);
        let mut fin: Vec<Value> = params
            .tensors
            .iter()
            .map(|t| f32v(t.shape(), t.data().to_vec()))
            .collect();
        let lanes = info.total_scale_lanes;
        fin.push(f32v(&[lanes], vec![1.0; lanes]));
        fin.push(f32v(&[lanes], vec![0.0; lanes]));
        let n_sites = info.sites.len();
        let mut acfg = Vec::new();
        for _ in 0..n_sites {
            acfg.extend_from_slice(&[0.0, 255.0, 0.0]);
        }
        fin.push(f32v(&[n_sites, 3], acfg));
        fin.extend(batch_inputs(&cfg, b, false).into_iter().take(3));
        let fout = run(&fm, &fin);
        let logits = fout[0].f32s().unwrap();
        let mut want = 0.0f64;
        for i in 0..b {
            let row: Vec<f64> =
                logits[i * n_out..(i + 1) * n_out].iter().map(|&v| v as f64).collect();
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = mx + row.iter().map(|v| (v - mx).exp()).sum::<f64>().ln();
            want -= row[i % n_out] - lse;
        }
        want /= b as f64;
        assert!(
            (loss as f64 - want).abs() < 1e-4,
            "train loss {loss} vs host CE {want}"
        );
    }

    /// With lr = 0 the updated params are bit-identical to the inputs
    /// (p' = p − 0·step), while the moments pick up the gradients.
    #[test]
    fn zero_lr_keeps_params_bitwise() {
        let cfg = micro();
        let b = 2;
        let m = train_module(&cfg, false, false, b);
        let inputs = fp32_inputs(&cfg, b, false, 0.0, 0.0, 0.0);
        let out = run(&m, &inputs);
        let np = param_spec(&cfg).len();
        for i in 0..np {
            assert_eq!(
                out[i].f32s().unwrap(),
                inputs[i].f32s().unwrap(),
                "param {i} moved at lr=0"
            );
        }
        // at m = 0, m' = (1-β1)·g — some gradient must be nonzero
        let any_grad = (0..np)
            .any(|i| out[np + i].f32s().unwrap().iter().any(|&v| v != 0.0));
        assert!(any_grad, "all first-moment outputs are zero");
    }

    /// Central-difference check of the analytic gradients. At m = 0 the
    /// first-moment output is (1−β1)·g, so g = 10·m'. The finite
    /// difference runs the same train graph at p ± h (loss is computed
    /// before the update, so lr is irrelevant).
    #[test]
    fn gradients_match_finite_differences() {
        let cfg = micro();
        let b = 2;
        let m = train_module(&cfg, false, false, b);
        let inputs = fp32_inputs(&cfg, b, false, 0.0, 0.0, 0.0);
        let out = run(&m, &inputs);
        let pspec = param_spec(&cfg);
        let np = pspec.len();
        let probe = [
            ("head.b", 0usize),
            ("pool.w", 3),
            ("layer0.ffn1.w", 5),
            ("embed.ln.g", 2),
            ("embed.tok", 10),
        ];
        let h = 1e-2f32;
        for (name, elem) in probe {
            let pi = pspec.iter().position(|(n, _)| n == name).unwrap();
            let analytic = out[np + pi].f32s().unwrap()[elem] * 10.0;
            let loss_at = |delta: f32| -> f64 {
                let mut shifted = inputs.clone();
                if let Value::F32 { data, .. } = &mut shifted[pi] {
                    data[elem] += delta;
                }
                run(&m, &shifted)[3 * np].f32s().unwrap()[0] as f64
            };
            let fd = ((loss_at(h) - loss_at(-h)) / (2.0 * h as f64)) as f32;
            let tol = 0.05 * fd.abs().max(analytic.abs()) + 2e-3;
            assert!(
                (fd - analytic).abs() < tol,
                "{name}[{elem}]: fd {fd} vs analytic {analytic}"
            );
        }
    }

    /// The fp32-only auxiliary loss adds λ·mean over the outlier lanes;
    /// switching λ on must move the loss by a finite, positive amount and
    /// still produce finite updates.
    #[test]
    fn aux_loss_shifts_total_loss() {
        let cfg = micro();
        let b = 2;
        let m = train_module(&cfg, false, false, b);
        let np = param_spec(&cfg).len();
        let base = run(&m, &fp32_inputs(&cfg, b, false, 0.01, 0.0, 0.0));
        let aux = run(&m, &fp32_inputs(&cfg, b, false, 0.01, 0.5, 2.0));
        let l0 = base[3 * np].f32s().unwrap()[0];
        let l1 = aux[3 * np].f32s().unwrap()[0];
        assert!(l0.is_finite() && l1.is_finite());
        assert!(l1 > l0, "aux loss should add a positive penalty: {l1} vs {l0}");
        for v in &aux {
            assert!(v.f32s().unwrap().iter().all(|x| x.is_finite()));
        }
    }

    /// Repeated steps on one batch must descend.
    #[test]
    fn fp32_training_reduces_loss() {
        let cfg = micro();
        let b = 2;
        let m = train_module(&cfg, false, false, b);
        let np = param_spec(&cfg).len();
        let mut inputs = fp32_inputs(&cfg, b, false, 0.001, 0.0, 0.0);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..8 {
            let out = run(&m, &inputs);
            last = out[3 * np].f32s().unwrap()[0];
            first.get_or_insert(last);
            for (i, v) in out.into_iter().take(3 * np).enumerate() {
                inputs[i] = v;
            }
        }
        let first = first.unwrap();
        assert!(
            last < first,
            "loss did not decrease over 8 steps: {first} -> {last}"
        );
    }

    /// The regression head trains too: finite loss, lr=0 keeps params.
    #[test]
    fn regression_variant_runs() {
        let cfg = micro();
        let b = 2;
        let m = train_module(&cfg, true, false, b);
        let np = param_spec(&cfg).len();
        let inputs = fp32_inputs(&cfg, b, true, 0.0, 0.0, 0.0);
        let out = run(&m, &inputs);
        assert_eq!(out.len(), 3 * np + 1);
        assert!(out[3 * np].f32s().unwrap()[0].is_finite());
        assert_eq!(out[0].f32s().unwrap(), inputs[0].f32s().unwrap());
    }

    /// With every quantizer disabled the QAT graph is bit-identical to
    /// fp32 (λ = 0): the QDQ select returns its fp32 operand exactly, and
    /// the zero LSQ gradients leave the scale moments at exactly 0.
    #[test]
    fn qat_disabled_is_bitwise_fp32() {
        let cfg = micro();
        let b = 2;
        let info = model_info(&cfg);
        let np = info.params.len();
        let fp = run(&train_module(&cfg, false, false, b), &fp32_inputs(&cfg, b, false, 0.01, 0.0, 0.0));
        let qt = run(&train_module(&cfg, false, true, b), &qat_inputs(&cfg, b, 0.01, 0.0));
        // p'/m'/v' agree bitwise
        for i in 0..3 * np {
            assert_eq!(fp[i].f32s().unwrap(), qt[i].f32s().unwrap(), "slot {i}");
        }
        // loss (last output of both) agrees bitwise
        let lf = fp[3 * np].f32s().unwrap()[0];
        let lq = qt.last().unwrap().f32s().unwrap()[0];
        assert_eq!(lf.to_bits(), lq.to_bits(), "loss {lf} vs {lq}");
        // scale moments stay exactly zero (gradients are hard zeros)
        let msv = qt[3 * np + 1].f32s().unwrap();
        let mwv = qt[3 * np + 4].f32s().unwrap();
        assert!(msv.iter().all(|&v| v == 0.0));
        assert!(mwv.iter().all(|&v| v == 0.0));
    }

    /// Enabled quantizers: loss stays finite and the LSQ gradients move
    /// the learned scales.
    #[test]
    fn qat_enabled_trains_scales() {
        let cfg = micro();
        let b = 2;
        let info = model_info(&cfg);
        let np = info.params.len();
        let m = train_module(&cfg, false, true, b);
        let inputs = qat_inputs(&cfg, b, 0.01, 1.0);
        let out = run(&m, &inputs);
        assert_eq!(out.len(), 3 * np + 7);
        for v in &out {
            assert!(v.f32s().unwrap().iter().all(|x| x.is_finite()));
        }
        let loss = out.last().unwrap().f32s().unwrap()[0];
        assert!(loss.is_finite());
        let a_s_in = inputs[3 * np].f32s().unwrap();
        let a_s_out = out[3 * np].f32s().unwrap();
        assert_eq!(a_s_in.len(), a_s_out.len());
        assert!(
            a_s_in.iter().zip(a_s_out).any(|(a, b)| a != b),
            "no activation scale moved"
        );
        let ws_in = inputs[3 * np + 5].f32s().unwrap();
        let ws_out = out[3 * np + 3].f32s().unwrap();
        assert!(
            ws_in.iter().zip(ws_out).any(|(a, b)| a != b),
            "no weight scale moved"
        );
    }

    /// Preplanned execution is bit-identical to the reference interpreter
    /// on both train variants.
    #[test]
    fn plan_matches_interp_on_train_graphs() {
        let cfg = micro();
        let b = 2;
        for (qat, inputs) in [
            (false, fp32_inputs(&cfg, b, false, 0.01, 0.3, 1.5)),
            (true, qat_inputs(&cfg, b, 0.01, 1.0)),
        ] {
            let art = build_train_step(&cfg, false, qat, b, "t").unwrap();
            let m = parse_module(&art.text).unwrap();
            let want = interpret(&m, &inputs).unwrap();
            let plan = Plan::build(&m).unwrap();
            let refs: Vec<&Value> = inputs.iter().collect();
            let got = plan.execute(&refs).unwrap();
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(&got) {
                let (w, g) = (w.f32s().unwrap(), g.f32s().unwrap());
                assert_eq!(w.len(), g.len());
                for (a, b) in w.iter().zip(g) {
                    assert_eq!(a.to_bits(), b.to_bits(), "qat={qat}");
                }
            }
        }
    }
}
