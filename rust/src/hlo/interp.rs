//! Host-side HLO interpreter.
//!
//! Evaluates a parsed [`HloModule`] on [`Value`] inputs, covering the op
//! set BERT-style forward/diag graphs need: `parameter`, `constant`,
//! `broadcast`, `reshape`, `transpose`, `slice`, `concatenate`,
//! `dot`/`dot-general`, the elementwise arithmetic ops, `exp`, `tanh`,
//! `rsqrt`, `sqrt`, `log`, `negate`, `abs`, `floor`, `ceil`,
//! `round-nearest-afz`, `clamp`, `select`, `compare`, `convert`, `iota`,
//! `reduce` (add/max/min/mul combinators), `gather`, `tuple` and
//! `get-tuple-element`.
//!
//! Instructions are evaluated in program order (HLO text is topologically
//! sorted); each instruction's computed dims are checked against its
//! declared shape, so a malformed module fails loudly instead of producing
//! silently misshapen tensors. Everything here is plain data and pure
//! functions — `Send + Sync` — which is what lets `runtime::Runtime` share
//! interpreted executables across sweep workers exactly like compiled
//! ones.

use anyhow::{anyhow, bail, Context, Result};

use super::parser::{parse_literal_numbers, parse_slice_ranges, Computation, HloModule, Inst};
use super::{strides, DType, Shape, Value};

/// Run the module's ENTRY computation. The root is usually a tuple (all
/// our graphs lower with `return_tuple=True`); its elements are returned
/// in order. A non-tuple root comes back as a single-element vec.
pub fn interpret(module: &HloModule, inputs: &[Value]) -> Result<Vec<Value>> {
    let refs: Vec<&Value> = inputs.iter().collect();
    interpret_refs(module, &refs)
}

/// Like [`interpret`], but over borrowed inputs — lets callers keep
/// expensive static inputs (parameter tensors) converted once and share
/// them across many executions (see `Runtime::run_batch`).
pub fn interpret_refs(module: &HloModule, inputs: &[&Value]) -> Result<Vec<Value>> {
    let root = eval_computation(module, module.entry(), inputs)?;
    match root {
        Value::Tuple(parts) => Ok(parts),
        other => Ok(vec![other]),
    }
}

fn eval_computation(module: &HloModule, comp: &Computation, args: &[&Value]) -> Result<Value> {
    if args.len() != comp.params.len() {
        bail!(
            "computation {}: {} arguments given, wants {}",
            comp.name,
            args.len(),
            comp.params.len()
        );
    }
    let mut env: Vec<Option<Value>> = Vec::with_capacity(comp.insts.len());
    for _ in 0..comp.insts.len() {
        env.push(None);
    }
    for (i, inst) in comp.insts.iter().enumerate() {
        let v = eval_inst(module, comp, &env, inst, args)
            .with_context(|| format!("in %{} = {}(..)", inst.name, inst.opcode))?;
        // statically proven for any module admitted through the runtime
        // cache or `Plan::build` (see `hlo::verify`); debug-only re-check
        if cfg!(debug_assertions) {
            check_dims(inst, &v)?;
        }
        env[i] = Some(v);
    }
    env[comp.root]
        .take()
        .ok_or_else(|| anyhow!("computation {}: root not evaluated", comp.name))
}

/// Declared vs computed dims must agree; tuples are checked recursively,
/// element by element, so a malformed root tuple fails loudly too.
fn check_dims(inst: &Inst, v: &Value) -> Result<()> {
    check_shape(&inst.shape, v).with_context(|| format!("%{}", inst.name))
}

/// Recursive declared-shape vs computed-value check, shared with the
/// preplanned engine (`hlo::plan`).
pub(crate) fn check_shape(shape: &Shape, v: &Value) -> Result<()> {
    match (shape, v) {
        (Shape::Tuple(shapes), Value::Tuple(parts)) => {
            if shapes.len() != parts.len() {
                bail!(
                    "declared tuple arity {} != computed {}",
                    shapes.len(),
                    parts.len()
                );
            }
            for (k, (s, p)) in shapes.iter().zip(parts).enumerate() {
                check_shape(s, p).with_context(|| format!("tuple element {k}"))?;
            }
            Ok(())
        }
        (Shape::Array { dims, .. }, v) => {
            if v.dims() != &dims[..] {
                bail!("declared dims {:?} != computed {:?}", dims, v.dims());
            }
            Ok(())
        }
        _ => bail!("declared/computed shape kind mismatch"),
    }
}

fn operand<'a>(
    comp: &Computation,
    env: &'a [Option<Value>],
    inst: &Inst,
    k: usize,
) -> Result<&'a Value> {
    let name = inst
        .operands
        .get(k)
        .ok_or_else(|| anyhow!("%{}: missing operand {k}", inst.name))?;
    let idx = comp
        .index
        .get(name)
        .ok_or_else(|| anyhow!("%{}: unknown operand %{name}", inst.name))?;
    env[*idx]
        .as_ref()
        .ok_or_else(|| anyhow!("%{}: operand %{name} not yet evaluated", inst.name))
}

fn eval_inst(
    module: &HloModule,
    comp: &Computation,
    env: &[Option<Value>],
    inst: &Inst,
    args: &[&Value],
) -> Result<Value> {
    let op = inst.opcode.as_str();
    match op {
        "parameter" => {
            let i: usize = inst
                .payload
                .as_deref()
                .unwrap_or("")
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad parameter payload"))?;
            let v = *args
                .get(i)
                .ok_or_else(|| anyhow!("parameter({i}) out of range"))?;
            if v.len() != inst.shape.elems() {
                bail!(
                    "parameter({i}): argument has {} elements, shape wants {}",
                    v.len(),
                    inst.shape.elems()
                );
            }
            Ok(v.clone())
        }
        "constant" => constant_value(inst),
        "broadcast" => {
            let x = operand(comp, env, inst, 0)?;
            let out_dims = inst.shape.dims()?;
            let map = inst.attr_dims_or("dimensions", &[])?;
            broadcast_value(x, out_dims, &map)
        }
        "reshape" => {
            let x = operand(comp, env, inst, 0)?;
            let dims = inst.shape.dims()?.to_vec();
            let want: usize = dims.iter().product();
            if x.len() != want {
                bail!("reshape: {} elements cannot view as {dims:?}", x.len());
            }
            Ok(with_dims(x.clone(), dims))
        }
        "transpose" => {
            let x = operand(comp, env, inst, 0)?;
            let perm = inst.attr_dims("dimensions")?;
            transpose_value(x, &perm)
        }
        "slice" => {
            let x = operand(comp, env, inst, 0)?;
            let ranges = parse_slice_ranges(inst.attr_str("slice")?)?;
            slice_value(x, &ranges)
        }
        "concatenate" => {
            let dim = *inst
                .attr_dims("dimensions")?
                .first()
                .ok_or_else(|| anyhow!("concatenate without dimension"))?;
            let parts: Vec<&Value> = (0..inst.operands.len())
                .map(|k| operand(comp, env, inst, k))
                .collect::<Result<_>>()?;
            concat_values(&parts, dim)
        }
        "dot" | "dot-general" => {
            let a = operand(comp, env, inst, 0)?;
            let b = operand(comp, env, inst, 1)?;
            let lb = inst.attr_dims_or("lhs_batch_dims", &[])?;
            let rb = inst.attr_dims_or("rhs_batch_dims", &[])?;
            let lc = inst.attr_dims_or("lhs_contracting_dims", &[])?;
            let rc = inst.attr_dims_or("rhs_contracting_dims", &[])?;
            dot_general(a, b, &lb, &rb, &lc, &rc)
        }
        "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power" => {
            let a = operand(comp, env, inst, 0)?;
            let b = operand(comp, env, inst, 1)?;
            binary(op, a, b)
        }
        "exp" | "exponential" | "tanh" | "logistic" | "rsqrt" | "sqrt" | "log" | "negate"
        | "abs" | "floor" | "ceil" | "round-nearest-afz" => {
            let x = operand(comp, env, inst, 0)?;
            unary(op, x)
        }
        "clamp" => {
            let lo = operand(comp, env, inst, 0)?;
            let x = operand(comp, env, inst, 1)?;
            let hi = operand(comp, env, inst, 2)?;
            clamp_value(lo, x, hi)
        }
        "select" => {
            let p = operand(comp, env, inst, 0)?;
            let t = operand(comp, env, inst, 1)?;
            let f = operand(comp, env, inst, 2)?;
            select_value(p, t, f)
        }
        "compare" => {
            let a = operand(comp, env, inst, 0)?;
            let b = operand(comp, env, inst, 1)?;
            compare_value(inst.attr_str("direction")?, a, b)
        }
        "convert" => {
            let x = operand(comp, env, inst, 0)?;
            convert_value(x, inst.shape.dtype()?)
        }
        "iota" => {
            let dims = inst.shape.dims()?.to_vec();
            let d = inst.attr_usize("iota_dimension")?;
            iota_value(&dims, d, inst.shape.dtype()?)
        }
        "reduce" => {
            let x = operand(comp, env, inst, 0)?;
            let init = operand(comp, env, inst, 1)?;
            let dims = inst.attr_dims("dimensions")?;
            let apply = inst.attr_str("to_apply")?.trim_start_matches('%');
            let comb = combinator_of(module, apply)?;
            reduce_value(x, init, &dims, comb)
        }
        "tuple" => {
            let parts: Vec<Value> = (0..inst.operands.len())
                .map(|k| operand(comp, env, inst, k).cloned())
                .collect::<Result<_>>()?;
            Ok(Value::Tuple(parts))
        }
        "get-tuple-element" => {
            let x = operand(comp, env, inst, 0)?;
            let i = inst.attr_usize("index")?;
            match x {
                Value::Tuple(parts) => parts
                    .get(i)
                    .cloned()
                    .ok_or_else(|| anyhow!("tuple index {i} out of range")),
                _ => bail!("get-tuple-element on non-tuple"),
            }
        }
        "gather" => {
            let x = operand(comp, env, inst, 0)?;
            let idx = operand(comp, env, inst, 1)?;
            let spec = GatherSpec::from_inst(inst)?;
            gather_value(&spec, x, idx)
        }
        other => bail!("unsupported opcode {other:?}"),
    }
}

/// Materialise a `constant` instruction's literal — at eval time for the
/// naive engine, once per module at plan-build time for `hlo::plan`.
pub(crate) fn constant_value(inst: &Inst) -> Result<Value> {
    let nums = parse_literal_numbers(inst.payload.as_deref().unwrap_or(""))?;
    let dims = inst.shape.dims()?.to_vec();
    let want: usize = dims.iter().product();
    if nums.len() != want {
        bail!("constant has {} values, shape wants {want}", nums.len());
    }
    match inst.shape.dtype()? {
        DType::F32 => Ok(Value::F32 {
            dims,
            data: nums.iter().map(|&x| x as f32).collect(),
        }),
        DType::S32 => Ok(Value::S32 {
            dims,
            data: nums.iter().map(|&x| x as i32).collect(),
        }),
        DType::Pred => Ok(Value::Pred {
            dims,
            data: nums.iter().map(|&x| x != 0.0).collect(),
        }),
    }
}

pub(crate) fn with_dims(v: Value, dims: Vec<usize>) -> Value {
    match v {
        Value::F32 { data, .. } => Value::F32 { dims, data },
        Value::S32 { data, .. } => Value::S32 { dims, data },
        Value::Pred { data, .. } => Value::Pred { dims, data },
        Value::Tuple(parts) => Value::Tuple(parts),
    }
}

// ---------------------------------------------------------------------------
// data movement
// ---------------------------------------------------------------------------

fn broadcast_map<T: Copy>(
    data: &[T],
    in_dims: &[usize],
    out_dims: &[usize],
    map: &[usize],
) -> Result<Vec<T>> {
    if map.len() != in_dims.len() {
        bail!("broadcast dimensions {map:?} do not match operand rank {}", in_dims.len());
    }
    for (k, &od) in map.iter().enumerate() {
        if od >= out_dims.len() || out_dims[od] != in_dims[k] {
            bail!("broadcast: operand dim {k} ({}) does not fit output dim {od}", in_dims[k]);
        }
    }
    let out_n: usize = out_dims.iter().product();
    let in_strides = strides(in_dims);
    let out_strides = strides(out_dims);
    let mut out = Vec::with_capacity(out_n);
    for oi in 0..out_n {
        let mut src = 0usize;
        for (k, &od) in map.iter().enumerate() {
            let coord = (oi / out_strides[od]) % out_dims[od];
            src += coord * in_strides[k];
        }
        out.push(data[src]);
    }
    Ok(out)
}

pub(crate) fn broadcast_value(x: &Value, out_dims: &[usize], map: &[usize]) -> Result<Value> {
    let dims = out_dims.to_vec();
    match x {
        Value::F32 { dims: id, data } => Ok(Value::F32 {
            data: broadcast_map(data, id, out_dims, map)?,
            dims,
        }),
        Value::S32 { dims: id, data } => Ok(Value::S32 {
            data: broadcast_map(data, id, out_dims, map)?,
            dims,
        }),
        Value::Pred { dims: id, data } => Ok(Value::Pred {
            data: broadcast_map(data, id, out_dims, map)?,
            dims,
        }),
        Value::Tuple(_) => bail!("broadcast on tuple"),
    }
}

fn transpose_map<T: Copy>(data: &[T], in_dims: &[usize], perm: &[usize]) -> Result<(Vec<usize>, Vec<T>)> {
    if perm.len() != in_dims.len() {
        bail!("transpose permutation {perm:?} invalid for rank {}", in_dims.len());
    }
    let mut seen = vec![false; in_dims.len()];
    for &p in perm {
        if p >= in_dims.len() || seen[p] {
            bail!("transpose dimensions {perm:?} are not a permutation");
        }
        seen[p] = true;
    }
    let out_dims: Vec<usize> = perm.iter().map(|&p| in_dims[p]).collect();
    let in_strides = strides(in_dims);
    let out_n: usize = out_dims.iter().product();
    let out_strides = strides(&out_dims);
    let mut out = Vec::with_capacity(out_n);
    for oi in 0..out_n {
        let mut src = 0usize;
        for (j, &p) in perm.iter().enumerate() {
            let coord = (oi / out_strides[j]) % out_dims[j];
            src += coord * in_strides[p];
        }
        out.push(data[src]);
    }
    Ok((out_dims, out))
}

pub(crate) fn transpose_value(x: &Value, perm: &[usize]) -> Result<Value> {
    match x {
        Value::F32 { dims, data } => {
            let (dims, data) = transpose_map(data, dims, perm)?;
            Ok(Value::F32 { dims, data })
        }
        Value::S32 { dims, data } => {
            let (dims, data) = transpose_map(data, dims, perm)?;
            Ok(Value::S32 { dims, data })
        }
        Value::Pred { dims, data } => {
            let (dims, data) = transpose_map(data, dims, perm)?;
            Ok(Value::Pred { dims, data })
        }
        Value::Tuple(_) => bail!("transpose on tuple"),
    }
}

fn slice_map<T: Copy>(
    data: &[T],
    in_dims: &[usize],
    ranges: &[(usize, usize, usize)],
) -> Result<(Vec<usize>, Vec<T>)> {
    if ranges.len() != in_dims.len() {
        bail!("slice ranges {ranges:?} rank mismatch with {in_dims:?}");
    }
    let mut out_dims = Vec::with_capacity(ranges.len());
    for (d, &(lo, hi, st)) in ranges.iter().enumerate() {
        if st == 0 || lo > hi || hi > in_dims[d] {
            bail!("bad slice [{lo}:{hi}:{st}] for dim {d} of size {}", in_dims[d]);
        }
        out_dims.push((hi - lo).div_ceil(st));
    }
    let in_strides = strides(in_dims);
    let out_strides = strides(&out_dims);
    let out_n: usize = out_dims.iter().product();
    let mut out = Vec::with_capacity(out_n);
    for oi in 0..out_n {
        let mut src = 0usize;
        for d in 0..out_dims.len() {
            let coord = (oi / out_strides[d]) % out_dims[d];
            src += (ranges[d].0 + coord * ranges[d].2) * in_strides[d];
        }
        out.push(data[src]);
    }
    Ok((out_dims, out))
}

pub(crate) fn slice_value(x: &Value, ranges: &[(usize, usize, usize)]) -> Result<Value> {
    match x {
        Value::F32 { dims, data } => {
            let (dims, data) = slice_map(data, dims, ranges)?;
            Ok(Value::F32 { dims, data })
        }
        Value::S32 { dims, data } => {
            let (dims, data) = slice_map(data, dims, ranges)?;
            Ok(Value::S32 { dims, data })
        }
        Value::Pred { dims, data } => {
            let (dims, data) = slice_map(data, dims, ranges)?;
            Ok(Value::Pred { dims, data })
        }
        Value::Tuple(_) => bail!("slice on tuple"),
    }
}

pub(crate) fn concat_values(parts: &[&Value], dim: usize) -> Result<Value> {
    let first = parts
        .first()
        .ok_or_else(|| anyhow!("concatenate with no operands"))?;
    let base = first.dims().to_vec();
    if dim >= base.len() {
        bail!("concatenate dim {dim} out of range for {base:?}");
    }
    let mut out_dims = base.clone();
    out_dims[dim] = 0;
    for p in parts {
        let d = p.dims();
        if d.len() != base.len() {
            bail!("concatenate rank mismatch");
        }
        for (k, (&a, &b)) in d.iter().zip(&base).enumerate() {
            if k != dim && a != b {
                bail!("concatenate non-concat dim {k} mismatch: {a} vs {b}");
            }
        }
        out_dims[dim] += d[dim];
    }
    let outer: usize = base[..dim].iter().product();
    let inner: usize = base[dim + 1..].iter().product();
    match first {
        Value::F32 { .. } => {
            let mut out: Vec<f32> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for p in parts {
                    let chunk = p.dims()[dim] * inner;
                    let data = p.f32s()?;
                    out.extend_from_slice(&data[o * chunk..(o + 1) * chunk]);
                }
            }
            Ok(Value::F32 { dims: out_dims, data: out })
        }
        Value::S32 { .. } => {
            let mut out: Vec<i32> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for p in parts {
                    let chunk = p.dims()[dim] * inner;
                    let data = p.i32s()?;
                    out.extend_from_slice(&data[o * chunk..(o + 1) * chunk]);
                }
            }
            Ok(Value::S32 { dims: out_dims, data: out })
        }
        _ => bail!("concatenate supports f32/s32"),
    }
}

// ---------------------------------------------------------------------------
// arithmetic
// ---------------------------------------------------------------------------

/// Elementwise binary op, shared between the naive evaluator and the
/// preplanned engine's fused kernels so both compute bit-identical f32
/// results by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
}

impl BinOp {
    pub(crate) fn parse(op: &str) -> Option<BinOp> {
        Some(match op {
            "add" => BinOp::Add,
            "subtract" => BinOp::Sub,
            "multiply" => BinOp::Mul,
            "divide" => BinOp::Div,
            "maximum" => BinOp::Max,
            "minimum" => BinOp::Min,
            "power" => BinOp::Pow,
            _ => return None,
        })
    }

    #[inline(always)]
    pub(crate) fn f32(self, a: f32, b: f32) -> f32 {
        match self {
            BinOp::Add => a + b,
            BinOp::Sub => a - b,
            BinOp::Mul => a * b,
            BinOp::Div => a / b,
            BinOp::Max => a.max(b),
            BinOp::Min => a.min(b),
            BinOp::Pow => a.powf(b),
        }
    }

    /// s32 semantics: wrapping arithmetic; division is checked so a zero
    /// divisor (or `i32::MIN / -1`) is a loud interpreter error instead of
    /// a process abort.
    #[inline(always)]
    fn s32(self, a: i32, b: i32) -> Result<i32> {
        Ok(match self {
            BinOp::Add => a.wrapping_add(b),
            BinOp::Sub => a.wrapping_sub(b),
            BinOp::Mul => a.wrapping_mul(b),
            BinOp::Div => a
                .checked_div(b)
                .ok_or_else(|| anyhow!("s32 divide: {a} / {b} is undefined"))?,
            BinOp::Max => a.max(b),
            BinOp::Min => a.min(b),
            BinOp::Pow => bail!("power on s32 unsupported"),
        })
    }
}

/// Elementwise unary op, shared with the fused kernels like [`BinOp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum UnOp {
    Exp,
    Tanh,
    Logistic,
    Rsqrt,
    Sqrt,
    Log,
    Neg,
    Abs,
    Floor,
    Ceil,
    Round,
}

impl UnOp {
    pub(crate) fn parse(op: &str) -> Option<UnOp> {
        Some(match op {
            "exp" | "exponential" => UnOp::Exp,
            "tanh" => UnOp::Tanh,
            "logistic" => UnOp::Logistic,
            "rsqrt" => UnOp::Rsqrt,
            "sqrt" => UnOp::Sqrt,
            "log" => UnOp::Log,
            "negate" => UnOp::Neg,
            "abs" => UnOp::Abs,
            "floor" => UnOp::Floor,
            "ceil" => UnOp::Ceil,
            "round-nearest-afz" => UnOp::Round,
            _ => return None,
        })
    }

    #[inline(always)]
    pub(crate) fn f32(self, v: f32) -> f32 {
        match self {
            UnOp::Exp => v.exp(),
            UnOp::Tanh => v.tanh(),
            // numerically stable two-branch sigmoid: never exponentiates a
            // large positive argument, so +inf -> 1, -inf -> 0, NaN -> NaN
            UnOp::Logistic => {
                if v >= 0.0 {
                    1.0 / (1.0 + (-v).exp())
                } else {
                    let e = v.exp();
                    e / (1.0 + e)
                }
            }
            UnOp::Rsqrt => 1.0 / v.sqrt(),
            UnOp::Sqrt => v.sqrt(),
            UnOp::Log => v.ln(),
            UnOp::Neg => -v,
            UnOp::Abs => v.abs(),
            UnOp::Floor => v.floor(),
            UnOp::Ceil => v.ceil(),
            UnOp::Round => v.round(),
        }
    }
}

pub(crate) fn binary(op: &str, a: &Value, b: &Value) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("{op}: shape mismatch {:?} vs {:?}", a.dims(), b.dims());
    }
    let bin = BinOp::parse(op).ok_or_else(|| anyhow!("unknown binary op {op:?}"))?;
    match (a, b) {
        (Value::F32 { dims, data: x }, Value::F32 { data: y, .. }) => Ok(Value::F32 {
            dims: dims.clone(),
            data: x.iter().zip(y).map(|(&u, &v)| bin.f32(u, v)).collect(),
        }),
        (Value::S32 { dims, data: x }, Value::S32 { data: y, .. }) => Ok(Value::S32 {
            dims: dims.clone(),
            data: x
                .iter()
                .zip(y)
                .map(|(&u, &v)| bin.s32(u, v))
                .collect::<Result<_>>()?,
        }),
        _ => bail!("{op}: operand dtype mismatch"),
    }
}

pub(crate) fn unary(op: &str, x: &Value) -> Result<Value> {
    let un = UnOp::parse(op).ok_or_else(|| anyhow!("unknown unary op {op:?}"))?;
    match x {
        Value::F32 { dims, data } => Ok(Value::F32 {
            dims: dims.clone(),
            data: data.iter().map(|&v| un.f32(v)).collect(),
        }),
        Value::S32 { dims, data } => match un {
            UnOp::Neg => Ok(Value::S32 {
                dims: dims.clone(),
                data: data.iter().map(|&v| v.wrapping_neg()).collect(),
            }),
            UnOp::Abs => Ok(Value::S32 {
                dims: dims.clone(),
                data: data.iter().map(|&v| v.wrapping_abs()).collect(),
            }),
            _ => bail!("{op} on s32 unsupported"),
        },
        _ => bail!("{op}: unsupported operand dtype"),
    }
}

/// Element of a maybe-scalar operand (HLO allows scalar min/max in clamp).
fn at_f32(v: &Value, i: usize) -> Result<f32> {
    let d = v.f32s()?;
    if d.len() == 1 {
        return Ok(d[0]);
    }
    d.get(i)
        .copied()
        .ok_or_else(|| anyhow!("clamp bound operand too short"))
}

pub(crate) fn clamp_value(lo: &Value, x: &Value, hi: &Value) -> Result<Value> {
    let data = x.f32s()?;
    let mut out = Vec::with_capacity(data.len());
    for (i, &v) in data.iter().enumerate() {
        out.push(v.max(at_f32(lo, i)?).min(at_f32(hi, i)?));
    }
    Ok(Value::F32 { dims: x.dims().to_vec(), data: out })
}

pub(crate) fn select_value(p: &Value, t: &Value, f: &Value) -> Result<Value> {
    let preds = p.preds()?;
    if t.dims() != f.dims() {
        bail!("select: branch shape mismatch");
    }
    if preds.len() != 1 && preds.len() != t.len() {
        bail!("select: pred has {} elements, branches have {}", preds.len(), t.len());
    }
    let pick = |i: usize| -> bool {
        if preds.len() == 1 {
            preds[0]
        } else {
            preds[i]
        }
    };
    match (t, f) {
        (Value::F32 { dims, data: a }, Value::F32 { data: b, .. }) => Ok(Value::F32 {
            dims: dims.clone(),
            data: (0..a.len()).map(|i| if pick(i) { a[i] } else { b[i] }).collect(),
        }),
        (Value::S32 { dims, data: a }, Value::S32 { data: b, .. }) => Ok(Value::S32 {
            dims: dims.clone(),
            data: (0..a.len()).map(|i| if pick(i) { a[i] } else { b[i] }).collect(),
        }),
        _ => bail!("select: unsupported branch dtypes"),
    }
}

/// Comparison direction, shared with the fused kernels like [`BinOp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpDir {
    pub(crate) fn parse(direction: &str) -> Option<CmpDir> {
        Some(match direction {
            "EQ" => CmpDir::Eq,
            "NE" => CmpDir::Ne,
            "LT" => CmpDir::Lt,
            "LE" => CmpDir::Le,
            "GT" => CmpDir::Gt,
            "GE" => CmpDir::Ge,
            _ => return None,
        })
    }

    #[inline(always)]
    fn of_ordering(self, o: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        match self {
            CmpDir::Eq => o == Equal,
            CmpDir::Ne => o != Equal,
            CmpDir::Lt => o == Less,
            CmpDir::Le => o != Greater,
            CmpDir::Gt => o == Greater,
            CmpDir::Ge => o != Less,
        }
    }
}

/// XLA (totalorder-free) float comparison: the default comparison type for
/// f32 operands treats NaN as unordered, which makes every direction false
/// — *except* `NE`, which is true whenever either side is NaN.
#[inline(always)]
pub(crate) fn cmp_f32(dir: CmpDir, u: f32, v: f32) -> bool {
    match u.partial_cmp(&v) {
        Some(o) => dir.of_ordering(o),
        None => dir == CmpDir::Ne,
    }
}

pub(crate) fn compare_value(direction: &str, a: &Value, b: &Value) -> Result<Value> {
    if a.dims() != b.dims() {
        bail!("compare: shape mismatch");
    }
    let dims = a.dims().to_vec();
    let dir = CmpDir::parse(direction)
        .ok_or_else(|| anyhow!("compare: unknown direction {direction:?}"))?;
    let data: Vec<bool> = match (a, b) {
        (Value::F32 { data: x, .. }, Value::F32 { data: y, .. }) => {
            x.iter().zip(y).map(|(&u, &v)| cmp_f32(dir, u, v)).collect()
        }
        (Value::S32 { data: x, .. }, Value::S32 { data: y, .. }) => x
            .iter()
            .zip(y)
            .map(|(&u, &v)| dir.of_ordering(u.cmp(&v)))
            .collect(),
        _ => bail!("compare: dtype mismatch"),
    };
    Ok(Value::Pred { dims, data })
}

pub(crate) fn convert_value(x: &Value, to: DType) -> Result<Value> {
    let dims = x.dims().to_vec();
    match (x, to) {
        (Value::F32 { data, .. }, DType::S32) => Ok(Value::S32 {
            dims,
            data: data.iter().map(|&v| v as i32).collect(),
        }),
        (Value::S32 { data, .. }, DType::F32) => Ok(Value::F32 {
            dims,
            data: data.iter().map(|&v| v as f32).collect(),
        }),
        (Value::Pred { data, .. }, DType::F32) => Ok(Value::F32 {
            dims,
            data: data.iter().map(|&v| if v { 1.0 } else { 0.0 }).collect(),
        }),
        (Value::Pred { data, .. }, DType::S32) => Ok(Value::S32 {
            dims,
            data: data.iter().map(|&v| i32::from(v)).collect(),
        }),
        (Value::F32 { data, .. }, DType::F32) => {
            Ok(Value::F32 { dims, data: data.clone() })
        }
        (Value::S32 { data, .. }, DType::S32) => {
            Ok(Value::S32 { dims, data: data.clone() })
        }
        _ => bail!("convert: unsupported conversion"),
    }
}

pub(crate) fn iota_value(dims: &[usize], along: usize, dtype: DType) -> Result<Value> {
    if along >= dims.len() {
        bail!("iota dimension {along} out of range for {dims:?}");
    }
    let st = strides(dims);
    let n: usize = dims.iter().product();
    let coord = |i: usize| (i / st[along]) % dims[along];
    match dtype {
        DType::F32 => Ok(Value::F32 {
            dims: dims.to_vec(),
            data: (0..n).map(|i| coord(i) as f32).collect(),
        }),
        DType::S32 => Ok(Value::S32 {
            dims: dims.to_vec(),
            data: (0..n).map(|i| coord(i) as i32).collect(),
        }),
        DType::Pred => bail!("iota on pred"),
    }
}

// ---------------------------------------------------------------------------
// contractions & reductions
// ---------------------------------------------------------------------------

/// Linear offsets of every coordinate combination over the selected dims
/// (row-major over `sel`'s order).
fn offset_table(dims: &[usize], st: &[usize], sel: &[usize]) -> Vec<usize> {
    let n: usize = sel.iter().map(|&d| dims[d]).product();
    let mut out = Vec::with_capacity(n);
    let mut coords = vec![0usize; sel.len()];
    for _ in 0..n {
        let mut off = 0usize;
        for (c, &d) in coords.iter().zip(sel) {
            off += c * st[d];
        }
        out.push(off);
        for j in (0..sel.len()).rev() {
            coords[j] += 1;
            if coords[j] < dims[sel[j]] {
                break;
            }
            coords[j] = 0;
        }
    }
    out
}

/// Validated offset tables and output dims for one dot-general call —
/// shared between the naive kernel and the fast paths so both walk exactly
/// the same element sequences.
struct DotPrep {
    lb_off: Vec<usize>,
    lm_off: Vec<usize>,
    lk_off: Vec<usize>,
    rb_off: Vec<usize>,
    rn_off: Vec<usize>,
    rk_off: Vec<usize>,
    out_dims: Vec<usize>,
}

fn dot_prep(
    ldims: &[usize],
    rdims: &[usize],
    lb: &[usize],
    rb: &[usize],
    lc: &[usize],
    rc: &[usize],
) -> Result<DotPrep> {
    if lb.len() != rb.len() || lc.len() != rc.len() {
        bail!("dot: batch/contracting dim count mismatch");
    }
    for &d in lb.iter().chain(lc) {
        if d >= ldims.len() {
            bail!("dot: lhs dim {d} out of range for {ldims:?}");
        }
    }
    for &d in rb.iter().chain(rc) {
        if d >= rdims.len() {
            bail!("dot: rhs dim {d} out of range for {rdims:?}");
        }
    }
    for (&l, &r) in lb.iter().zip(rb) {
        if ldims[l] != rdims[r] {
            bail!("dot: batch dim size mismatch ({} vs {})", ldims[l], rdims[r]);
        }
    }
    for (&l, &r) in lc.iter().zip(rc) {
        if ldims[l] != rdims[r] {
            bail!("dot: contracting dim size mismatch ({} vs {})", ldims[l], rdims[r]);
        }
    }
    let l_free: Vec<usize> = (0..ldims.len())
        .filter(|d| !lb.contains(d) && !lc.contains(d))
        .collect();
    let r_free: Vec<usize> = (0..rdims.len())
        .filter(|d| !rb.contains(d) && !rc.contains(d))
        .collect();
    let lst = strides(ldims);
    let rst = strides(rdims);
    let mut out_dims: Vec<usize> = lb.iter().map(|&d| ldims[d]).collect();
    out_dims.extend(l_free.iter().map(|&d| ldims[d]));
    out_dims.extend(r_free.iter().map(|&d| rdims[d]));
    Ok(DotPrep {
        lb_off: offset_table(ldims, &lst, lb),
        lm_off: offset_table(ldims, &lst, &l_free),
        lk_off: offset_table(ldims, &lst, lc),
        rb_off: offset_table(rdims, &rst, rb),
        rn_off: offset_table(rdims, &rst, &r_free),
        rk_off: offset_table(rdims, &rst, rc),
        out_dims,
    })
}

fn dot_operands<'v>(a: &'v Value, b: &'v Value) -> Result<(&'v [usize], &'v [f32], &'v [usize], &'v [f32])> {
    let (ldims, ldata) = match a {
        Value::F32 { dims, data } => (dims, data),
        _ => bail!("dot: lhs must be f32"),
    };
    let (rdims, rdata) = match b {
        Value::F32 { dims, data } => (dims, data),
        _ => bail!("dot: rhs must be f32"),
    };
    Ok((ldims, ldata, rdims, rdata))
}

pub(crate) fn dot_general(
    a: &Value,
    b: &Value,
    lb: &[usize],
    rb: &[usize],
    lc: &[usize],
    rc: &[usize],
) -> Result<Value> {
    let (ldims, ldata, rdims, rdata) = dot_operands(a, b)?;
    let p = dot_prep(ldims, rdims, lb, rb, lc, rc)?;
    let (nb, m, n, kk) = (p.lb_off.len(), p.lm_off.len(), p.rn_off.len(), p.lk_off.len());
    let mut out = vec![0.0f32; nb * m * n];
    for bi in 0..nb {
        for mi in 0..m {
            let lbase = p.lb_off[bi] + p.lm_off[mi];
            let row = &mut out[(bi * m + mi) * n..(bi * m + mi + 1) * n];
            for (ni, slot) in row.iter_mut().enumerate() {
                let rbase = p.rb_off[bi] + p.rn_off[ni];
                let mut acc = 0.0f32;
                for k in 0..kk {
                    acc += ldata[lbase + p.lk_off[k]] * rdata[rbase + p.rk_off[k]];
                }
                *slot = acc;
            }
        }
    }
    Ok(Value::F32 { dims: p.out_dims, data: out })
}

/// `Some(s)` when `off` is the arithmetic sequence `0, s, 2s, ..` — i.e.
/// the selected dims walk memory with one fixed stride.
fn fixed_stride(off: &[usize]) -> Option<usize> {
    if off.len() < 2 {
        return None;
    }
    let s = off[1];
    for (k, &o) in off.iter().enumerate() {
        if o != k * s {
            return None;
        }
    }
    Some(s)
}

/// Columns-per-block for the ikj fast path: bounds the live output span to
/// ~L1 size so `out_row += a * b_row` stays cache-resident for every k.
const DOT_N_BLOCK: usize = 4096;

/// dot-general with contiguous-contracting-dim fast paths, used by the
/// preplanned engine. Every path accumulates each output element's
/// products in ascending-k order starting from 0.0 — the exact sequence
/// of f32 additions the naive kernel performs — so results are
/// bit-identical to [`dot_general`] by construction (the invariant the
/// determinism suite pins across thread counts and engines).
pub(crate) fn dot_general_fast(
    a: &Value,
    b: &Value,
    lb: &[usize],
    rb: &[usize],
    lc: &[usize],
    rc: &[usize],
) -> Result<Value> {
    let (ldims, ldata, rdims, rdata) = dot_operands(a, b)?;
    let p = dot_prep(ldims, rdims, lb, rb, lc, rc)?;
    let (nb, m, n, kk) = (p.lb_off.len(), p.lm_off.len(), p.rn_off.len(), p.lk_off.len());
    let ls = fixed_stride(&p.lk_off);
    let rs = fixed_stride(&p.rk_off);
    let ns = fixed_stride(&p.rn_off);
    let mut out = vec![0.0f32; nb * m * n];
    if ls == Some(1) && rs == Some(1) {
        // Both contracting walks are unit-stride: each output element is a
        // plain dot of two contiguous slices (k ascending, as naive).
        for bi in 0..nb {
            for mi in 0..m {
                let lbase = p.lb_off[bi] + p.lm_off[mi];
                let lrow = &ldata[lbase..lbase + kk];
                let row = &mut out[(bi * m + mi) * n..(bi * m + mi + 1) * n];
                for (ni, slot) in row.iter_mut().enumerate() {
                    let rbase = p.rb_off[bi] + p.rn_off[ni];
                    let rrow = &rdata[rbase..rbase + kk];
                    let mut acc = 0.0f32;
                    for (&u, &v) in lrow.iter().zip(rrow) {
                        acc += u * v;
                    }
                    *slot = acc;
                }
            }
        }
    } else if ls == Some(1) && ns == Some(1) && rs == Some(n) && kk >= 2 {
        // rhs is a row-major [K, N] block: stream it row by row (ikj
        // order), accumulating into the zero-initialised output row. Each
        // out[ni] still receives its products in ascending-k order, so the
        // f32 sum per element is unchanged — only the interleaving across
        // *different* output elements differs, and those never mix.
        for bi in 0..nb {
            for mi in 0..m {
                let lbase = p.lb_off[bi] + p.lm_off[mi];
                let lrow = &ldata[lbase..lbase + kk];
                let rb0 = p.rb_off[bi];
                let row = &mut out[(bi * m + mi) * n..(bi * m + mi + 1) * n];
                let mut n0 = 0usize;
                while n0 < n {
                    let n1 = (n0 + DOT_N_BLOCK).min(n);
                    let block = &mut row[n0..n1];
                    for (k, &u) in lrow.iter().enumerate() {
                        let rrow = &rdata[rb0 + k * n + n0..rb0 + k * n + n1];
                        for (slot, &v) in block.iter_mut().zip(rrow) {
                            *slot += u * v;
                        }
                    }
                    n0 = n1;
                }
            }
        }
    } else {
        // generic layout: same offset-table walk as the naive kernel
        for bi in 0..nb {
            for mi in 0..m {
                let lbase = p.lb_off[bi] + p.lm_off[mi];
                let row = &mut out[(bi * m + mi) * n..(bi * m + mi + 1) * n];
                for (ni, slot) in row.iter_mut().enumerate() {
                    let rbase = p.rb_off[bi] + p.rn_off[ni];
                    let mut acc = 0.0f32;
                    for k in 0..kk {
                        acc += ldata[lbase + p.lk_off[k]] * rdata[rbase + p.rk_off[k]];
                    }
                    *slot = acc;
                }
            }
        }
    }
    Ok(Value::F32 { dims: p.out_dims, data: out })
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Combinator {
    Add,
    Max,
    Min,
    Mul,
}

/// A reduction sub-computation must be a single binary op over its two
/// parameters; its opcode names the combinator.
pub(crate) fn combinator_of(module: &HloModule, name: &str) -> Result<Combinator> {
    let comp = module.computation(name)?;
    let root = &comp.insts[comp.root];
    match root.opcode.as_str() {
        "add" => Ok(Combinator::Add),
        "maximum" => Ok(Combinator::Max),
        "minimum" => Ok(Combinator::Min),
        "multiply" => Ok(Combinator::Mul),
        other => bail!("unsupported reduce combinator {other:?} in %{name}"),
    }
}

pub(crate) fn reduce_value(x: &Value, init: &Value, rdims: &[usize], comb: Combinator) -> Result<Value> {
    let (dims, data) = match x {
        Value::F32 { dims, data } => (dims, data),
        _ => bail!("reduce supports f32 operands"),
    };
    let init = *init
        .f32s()?
        .first()
        .ok_or_else(|| anyhow!("reduce: empty init"))?;
    for &d in rdims {
        if d >= dims.len() {
            bail!("reduce dim {d} out of range for {dims:?}");
        }
    }
    let keep: Vec<usize> = (0..dims.len()).filter(|d| !rdims.contains(d)).collect();
    let st = strides(dims);
    let k_off = offset_table(dims, &st, rdims);
    let o_off = offset_table(dims, &st, &keep);
    let f: fn(f32, f32) -> f32 = match comb {
        Combinator::Add => |a, b| a + b,
        Combinator::Max => f32::max,
        Combinator::Min => f32::min,
        Combinator::Mul => |a, b| a * b,
    };
    let mut out = Vec::with_capacity(o_off.len());
    for &o in &o_off {
        let mut acc = init;
        for &k in &k_off {
            acc = f(acc, data[o + k]);
        }
        out.push(acc);
    }
    let out_dims: Vec<usize> = keep.iter().map(|&d| dims[d]).collect();
    Ok(Value::F32 { dims: out_dims, data: out })
}

// ---------------------------------------------------------------------------
// gather
// ---------------------------------------------------------------------------

/// Gather attributes, parsed once per instruction (at plan-build time for
/// the preplanned engine) instead of once per execution.
#[derive(Debug, Clone)]
pub(crate) struct GatherSpec {
    pub(crate) offset_dims: Vec<usize>,
    pub(crate) collapsed: Vec<usize>,
    pub(crate) start_map: Vec<usize>,
    pub(crate) ivd: usize,
    pub(crate) slice_sizes: Vec<usize>,
}

impl GatherSpec {
    pub(crate) fn from_inst(inst: &Inst) -> Result<GatherSpec> {
        Ok(GatherSpec {
            offset_dims: inst.attr_dims("offset_dims")?,
            collapsed: inst.attr_dims_or("collapsed_slice_dims", &[])?,
            start_map: inst.attr_dims("start_index_map")?,
            ivd: inst.attr_usize("index_vector_dim")?,
            slice_sizes: inst.attr_dims("slice_sizes")?,
        })
    }
}

pub(crate) fn gather_value(spec: &GatherSpec, x: &Value, idx: &Value) -> Result<Value> {
    let (odims, odata) = match x {
        Value::F32 { dims, data } => (dims, data),
        _ => bail!("gather supports f32 operands"),
    };
    let indices = idx.i32s()?;
    let sdims = idx.dims();

    let GatherSpec { offset_dims, collapsed, start_map, ivd, slice_sizes } = spec;
    let ivd = *ivd;
    if slice_sizes.len() != odims.len() {
        bail!("gather: slice_sizes rank mismatch");
    }
    if start_map.iter().any(|&d| d >= odims.len())
        || collapsed.iter().any(|&d| d >= odims.len())
        || slice_sizes.iter().zip(odims).any(|(&s, &d)| s > d)
        || ivd > sdims.len()
    {
        bail!("gather: dimension attributes out of range");
    }

    // start_indices batch dims (all but the index-vector dim)
    let sbatch: Vec<usize> = (0..sdims.len()).filter(|&d| d != ivd).collect();
    let index_len = if ivd < sdims.len() { sdims[ivd] } else { 1 };
    if index_len != start_map.len() {
        bail!("gather: index vector length {} != start_index_map {}", index_len, start_map.len());
    }

    // output dims: batch dims (in order) with offset dims interleaved at
    // the positions named by offset_dims
    let out_rank = sbatch.len() + offset_dims.len();
    let kept_slice: Vec<usize> =
        (0..odims.len()).filter(|d| !collapsed.contains(d)).collect();
    if kept_slice.len() != offset_dims.len() {
        bail!("gather: offset_dims arity mismatch");
    }
    let mut out_dims = vec![0usize; out_rank];
    for (k, &od) in offset_dims.iter().enumerate() {
        if od >= out_rank {
            bail!("gather: offset dim {od} out of range");
        }
        out_dims[od] = slice_sizes[kept_slice[k]];
    }
    let mut bpos = 0usize;
    let batch_out_dims: Vec<usize> =
        (0..out_rank).filter(|d| !offset_dims.contains(d)).collect();
    for &d in &batch_out_dims {
        out_dims[d] = sdims[sbatch[bpos]];
        bpos += 1;
    }

    let s_strides = strides(sdims);
    let o_strides = strides(odims);
    let out_strides = strides(&out_dims);
    let out_n: usize = out_dims.iter().product();
    let mut out = Vec::with_capacity(out_n);
    for oi in 0..out_n {
        // decompose the output index
        let coord = |d: usize| (oi / out_strides[d]) % out_dims[d];
        // start-index vector for this output element
        let mut sbase = 0usize;
        for (b, &sd) in sbatch.iter().enumerate() {
            sbase += coord(batch_out_dims[b]) * s_strides[sd];
        }
        // operand coordinates: clamped start + in-slice offset
        let mut src = 0usize;
        for (k, &kd) in kept_slice.iter().enumerate() {
            src += coord(offset_dims[k]) * o_strides[kd];
        }
        for (k, &om) in start_map.iter().enumerate() {
            let raw = if ivd < sdims.len() {
                indices[sbase + k * s_strides[ivd]]
            } else {
                indices[sbase]
            };
            let max_start = odims[om] - slice_sizes[om];
            let s = (raw.max(0) as usize).min(max_start);
            src += s * o_strides[om];
        }
        out.push(odata[src]);
    }
    Ok(Value::F32 { dims: out_dims, data: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    /// Build a one-entry module around instruction lines, run it on
    /// inputs, and return the (flattened) outputs.
    fn run(params: &[&str], body: &[&str], inputs: &[Value]) -> Result<Vec<Value>> {
        let mut text = String::from("HloModule t\n\n");
        text.push_str(
            "%red_add (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %r = f32[] add(f32[] %a, f32[] %b)\n}\n\n",
        );
        text.push_str(
            "%red_max (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %r = f32[] maximum(f32[] %a, f32[] %b)\n}\n\n",
        );
        text.push_str("ENTRY %main () -> f32[] {\n");
        for p in params {
            text.push_str("  ");
            text.push_str(p);
            text.push('\n');
        }
        for b in body {
            text.push_str("  ");
            text.push_str(b);
            text.push('\n');
        }
        text.push_str("}\n");
        let m = parse_module(&text)?;
        let naive = interpret(&m, inputs);
        // Every golden doubles as a plan-vs-naive bit-identity check: the
        // preplanned engine must agree with the naive evaluation outcome
        // (same bits on success, an error of its own on failure).
        match crate::hlo::Plan::build(&m) {
            Ok(plan) => {
                let refs: Vec<&Value> = inputs.iter().collect();
                match (&naive, plan.execute(&refs)) {
                    (Ok(a), Ok(b)) => crate::hlo::plan::assert_bits_eq(a, &b),
                    (Err(_), Err(_)) => {}
                    (Ok(_), Err(e)) => {
                        panic!("planned engine failed where naive succeeded: {e:#}")
                    }
                    (Err(e), Ok(_)) => {
                        panic!("planned engine succeeded where naive failed: {e:#}")
                    }
                }
            }
            Err(e) => {
                assert!(naive.is_err(), "plan build failed but naive engine ran: {e:#}")
            }
        }
        naive
    }

    fn f32v(dims: &[usize], data: &[f32]) -> Value {
        Value::F32 { dims: dims.to_vec(), data: data.to_vec() }
    }

    fn s32v(dims: &[usize], data: &[i32]) -> Value {
        Value::S32 { dims: dims.to_vec(), data: data.to_vec() }
    }

    #[test]
    fn golden_elementwise() {
        let out = run(
            &["%p0 = f32[4] parameter(0)", "%p1 = f32[4] parameter(1)"],
            &[
                "%s = f32[4] add(f32[4] %p0, f32[4] %p1)",
                "%m = f32[4] multiply(f32[4] %s, f32[4] %p1)",
                "ROOT %d = f32[4] subtract(f32[4] %m, f32[4] %p0)",
            ],
            &[f32v(&[4], &[1., 2., 3., 4.]), f32v(&[4], &[10., 20., 30., 40.])],
        )
        .unwrap();
        // ((p0+p1)*p1) - p0
        assert_eq!(out[0].f32s().unwrap(), &[109., 438., 987., 1756.]);
    }

    #[test]
    fn golden_unary_and_clamp() {
        let out = run(
            &["%p0 = f32[3] parameter(0)"],
            &[
                "%e = f32[3] exp(f32[3] %p0)",
                "%t = f32[3] tanh(f32[3] %e)",
                "%c0 = f32[] constant(0.25)",
                "%c1 = f32[] constant(0.75)",
                "ROOT %c = f32[3] clamp(f32[] %c0, f32[3] %t, f32[] %c1)",
            ],
            &[f32v(&[3], &[-10.0, 0.0, 10.0])],
        )
        .unwrap();
        let got = out[0].f32s().unwrap();
        let want = [
            ((-10.0f32).exp().tanh()).clamp(0.25, 0.75),
            (1.0f32.tanh()).clamp(0.25, 0.75),
            (10.0f32.exp().tanh()).clamp(0.25, 0.75),
        ];
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn golden_round_rsqrt() {
        let out = run(
            &["%p0 = f32[4] parameter(0)"],
            &["ROOT %r = f32[4] round-nearest-afz(f32[4] %p0)"],
            &[f32v(&[4], &[1.4, 1.5, -1.5, 2.6])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1.0, 2.0, -2.0, 3.0]);

        let out = run(
            &["%p0 = f32[2] parameter(0)"],
            &["ROOT %r = f32[2] rsqrt(f32[2] %p0)"],
            &[f32v(&[2], &[4.0, 16.0])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[0.5, 0.25]);
    }

    #[test]
    fn golden_broadcast_and_iota() {
        let out = run(
            &["%p0 = f32[3] parameter(0)"],
            &["ROOT %b = f32[2,3] broadcast(f32[3] %p0), dimensions={1}"],
            &[f32v(&[3], &[1., 2., 3.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1., 2., 3., 1., 2., 3.]);
        assert_eq!(out[0].dims(), &[2, 3]);

        let out = run(&[], &["ROOT %i = s32[2,3] iota(), iota_dimension=1"], &[]).unwrap();
        assert_eq!(out[0].i32s().unwrap(), &[0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn golden_transpose_slice_concat() {
        let out = run(
            &["%p0 = f32[2,3] parameter(0)"],
            &["ROOT %t = f32[3,2] transpose(f32[2,3] %p0), dimensions={1,0}"],
            &[f32v(&[2, 3], &[1., 2., 3., 4., 5., 6.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1., 4., 2., 5., 3., 6.]);

        let out = run(
            &["%p0 = f32[2,3] parameter(0)"],
            &["ROOT %s = f32[1,2] slice(f32[2,3] %p0), slice={[1:2], [0:3:2]}"],
            &[f32v(&[2, 3], &[1., 2., 3., 4., 5., 6.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[4., 6.]);

        let out = run(
            &["%p0 = f32[1,2] parameter(0)", "%p1 = f32[1,2] parameter(1)"],
            &["ROOT %c = f32[2,2] concatenate(f32[1,2] %p0, f32[1,2] %p1), dimensions={0}"],
            &[f32v(&[1, 2], &[1., 2.]), f32v(&[1, 2], &[3., 4.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn golden_dot_plain_and_batched() {
        // (2,3) x (3,2)
        let out = run(
            &["%p0 = f32[2,3] parameter(0)", "%p1 = f32[3,2] parameter(1)"],
            &[
                "ROOT %d = f32[2,2] dot(f32[2,3] %p0, f32[3,2] %p1), \
                 lhs_contracting_dims={2}, rhs_contracting_dims={0}"
                    .trim_start_matches(' '),
            ],
            &[
                f32v(&[2, 3], &[1., 2., 3., 4., 5., 6.]),
                f32v(&[3, 2], &[1., 0., 0., 1., 1., 1.]),
            ],
        );
        // lhs_contracting_dims={2} is out of range for rank 2 -> must error
        assert!(out.is_err());

        let out = run(
            &["%p0 = f32[2,3] parameter(0)", "%p1 = f32[3,2] parameter(1)"],
            &[
                "ROOT %d = f32[2,2] dot(f32[2,3] %p0, f32[3,2] %p1), \
                 lhs_contracting_dims={1}, rhs_contracting_dims={0}",
            ],
            &[
                f32v(&[2, 3], &[1., 2., 3., 4., 5., 6.]),
                f32v(&[3, 2], &[1., 0., 0., 1., 1., 1.]),
            ],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[4., 5., 10., 11.]);

        // batched: (2,2,2) x (2,2,2) over batch dim 0
        let out = run(
            &["%p0 = f32[2,2,2] parameter(0)", "%p1 = f32[2,2,2] parameter(1)"],
            &[
                "ROOT %d = f32[2,2,2] dot(f32[2,2,2] %p0, f32[2,2,2] %p1), \
                 lhs_batch_dims={0}, rhs_batch_dims={0}, \
                 lhs_contracting_dims={2}, rhs_contracting_dims={1}",
            ],
            &[
                f32v(&[2, 2, 2], &[1., 2., 3., 4., 5., 6., 7., 8.]),
                f32v(&[2, 2, 2], &[1., 0., 0., 1., 1., 0., 0., 1.]),
            ],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1., 2., 3., 4., 5., 6., 7., 8.]);
    }

    #[test]
    fn golden_reduce() {
        let out = run(
            &["%p0 = f32[2,3] parameter(0)"],
            &[
                "%z = f32[] constant(0)",
                "ROOT %r = f32[2] reduce(f32[2,3] %p0, f32[] %z), dimensions={1}, \
                 to_apply=%red_add",
            ],
            &[f32v(&[2, 3], &[1., 2., 3., 4., 5., 6.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[6., 15.]);

        let out = run(
            &["%p0 = f32[2,3] parameter(0)"],
            &[
                "%z = f32[] constant(-inf)",
                "ROOT %r = f32[3] reduce(f32[2,3] %p0, f32[] %z), dimensions={0}, \
                 to_apply=%red_max",
            ],
            &[f32v(&[2, 3], &[1., 7., 3., 4., 5., 6.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[4., 7., 6.]);
    }

    #[test]
    fn golden_compare_select_convert() {
        let out = run(
            &["%p0 = f32[4] parameter(0)", "%p1 = f32[4] parameter(1)"],
            &[
                "%c = pred[4] compare(f32[4] %p0, f32[4] %p1), direction=GT",
                "ROOT %s = f32[4] select(pred[4] %c, f32[4] %p0, f32[4] %p1)",
            ],
            &[f32v(&[4], &[1., 5., 2., 8.]), f32v(&[4], &[3., 4., 7., 6.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[3., 5., 7., 8.]);

        let out = run(
            &["%p0 = s32[3] parameter(0)"],
            &["ROOT %c = f32[3] convert(s32[3] %p0)"],
            &[s32v(&[3], &[1, -2, 7])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[1.0, -2.0, 7.0]);
    }

    #[test]
    fn golden_gather_embedding_lookup() {
        // table [4,2], indices [3,1] -> rows [3,2]
        let out = run(
            &["%p0 = f32[4,2] parameter(0)", "%p1 = s32[3,1] parameter(1)"],
            &[
                "ROOT %g = f32[3,2] gather(f32[4,2] %p0, s32[3,1] %p1), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1,2}",
            ],
            &[
                f32v(&[4, 2], &[0., 1., 10., 11., 20., 21., 30., 31.]),
                s32v(&[3, 1], &[2, 0, 3]),
            ],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[20., 21., 0., 1., 30., 31.]);

        // out-of-range indices clamp (XLA semantics)
        let out = run(
            &["%p0 = f32[4,2] parameter(0)", "%p1 = s32[1,1] parameter(1)"],
            &[
                "ROOT %g = f32[1,2] gather(f32[4,2] %p0, s32[1,1] %p1), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1,2}",
            ],
            &[f32v(&[4, 2], &[0., 1., 10., 11., 20., 21., 30., 31.]), s32v(&[1, 1], &[99])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[30., 31.]);
    }

    #[test]
    fn golden_tuple_roundtrip() {
        let out = run(
            &["%p0 = f32[2] parameter(0)"],
            &[
                "%t = (f32[2], f32[2]) tuple(f32[2] %p0, f32[2] %p0)",
                "%g = f32[2] get-tuple-element((f32[2], f32[2]) %t), index=1",
                "ROOT %r = f32[2] add(f32[2] %g, f32[2] %p0)",
            ],
            &[f32v(&[2], &[1., 2.])],
        )
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[2., 4.]);
    }

    #[test]
    fn declared_shape_is_checked() {
        let err = run(
            &["%p0 = f32[4] parameter(0)"],
            &["ROOT %r = f32[3] abs(f32[4] %p0)"],
            &[f32v(&[4], &[1., 2., 3., 4.])],
        );
        assert!(err.is_err());
    }

    #[test]
    fn softmax_composed_from_primitives() {
        // softmax over the last axis of a [1,3] row, the way the fixture
        // graphs lower it: max -> subtract -> exp -> sum -> divide
        let out = run(
            &["%p0 = f32[1,3] parameter(0)"],
            &[
                "%ninf = f32[] constant(-inf)",
                "%m = f32[1] reduce(f32[1,3] %p0, f32[] %ninf), dimensions={1}, \
                 to_apply=%red_max",
                "%mb = f32[1,3] broadcast(f32[1] %m), dimensions={0}",
                "%c = f32[1,3] subtract(f32[1,3] %p0, f32[1,3] %mb)",
                "%e = f32[1,3] exp(f32[1,3] %c)",
                "%z = f32[] constant(0)",
                "%s = f32[1] reduce(f32[1,3] %e, f32[] %z), dimensions={1}, \
                 to_apply=%red_add",
                "%sb = f32[1,3] broadcast(f32[1] %s), dimensions={0}",
                "ROOT %p = f32[1,3] divide(f32[1,3] %e, f32[1,3] %sb)",
            ],
            &[f32v(&[1, 3], &[1.0, 2.0, 3.0])],
        )
        .unwrap();
        let got = out[0].f32s().unwrap();
        let e: Vec<f32> = [1.0f32, 2.0, 3.0].iter().map(|x| (x - 3.0).exp()).collect();
        let s: f32 = e.iter().sum();
        for (g, w) in got.iter().zip(e.iter().map(|x| x / s)) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
        let total: f32 = got.iter().sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn compare_nan_semantics_per_direction() {
        // XLA float compare treats NaN as unordered: every direction is
        // false except NE, which is true when either side is NaN.
        let x = f32v(&[3], &[f32::NAN, 1.0, f32::NAN]);
        let y = f32v(&[3], &[1.0, f32::NAN, f32::NAN]);
        for (dir, want) in [
            ("EQ", [false, false, false]),
            ("NE", [true, true, true]),
            ("LT", [false, false, false]),
            ("LE", [false, false, false]),
            ("GT", [false, false, false]),
            ("GE", [false, false, false]),
        ] {
            let line = format!(
                "ROOT %c = pred[3] compare(f32[3] %p0, f32[3] %p1), direction={dir}"
            );
            let out = run(
                &["%p0 = f32[3] parameter(0)", "%p1 = f32[3] parameter(1)"],
                &[line.as_str()],
                &[x.clone(), y.clone()],
            )
            .unwrap();
            assert_eq!(out[0].preds().unwrap(), &want, "direction {dir}");
        }
        // ordered lanes still compare normally alongside NaN lanes
        let out = run(
            &["%p0 = f32[3] parameter(0)", "%p1 = f32[3] parameter(1)"],
            &["ROOT %c = pred[3] compare(f32[3] %p0, f32[3] %p1), direction=LT"],
            &[f32v(&[3], &[1.0, f32::NAN, 2.0]), f32v(&[3], &[2.0, 2.0, 1.0])],
        )
        .unwrap();
        assert_eq!(out[0].preds().unwrap(), &[true, false, false]);
    }

    #[test]
    fn s32_divide_returns_error_not_abort() {
        // division by zero must be an interpreter error, not a process
        // abort (`a / b` on i32 panics on 0 and on MIN / -1)
        let err = run(
            &["%p0 = s32[2] parameter(0)", "%p1 = s32[2] parameter(1)"],
            &["ROOT %d = s32[2] divide(s32[2] %p0, s32[2] %p1)"],
            &[s32v(&[2], &[1, 2]), s32v(&[2], &[1, 0])],
        );
        assert!(err.is_err(), "divide by zero must error");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("divide"), "error should name the op: {msg}");

        let err = run(
            &["%p0 = s32[1] parameter(0)", "%p1 = s32[1] parameter(1)"],
            &["ROOT %d = s32[1] divide(s32[1] %p0, s32[1] %p1)"],
            &[s32v(&[1], &[i32::MIN]), s32v(&[1], &[-1])],
        );
        assert!(err.is_err(), "i32::MIN / -1 must error");

        // plain division still works
        let out = run(
            &["%p0 = s32[2] parameter(0)", "%p1 = s32[2] parameter(1)"],
            &["ROOT %d = s32[2] divide(s32[2] %p0, s32[2] %p1)"],
            &[s32v(&[2], &[7, -9]), s32v(&[2], &[2, 3])],
        )
        .unwrap();
        assert_eq!(out[0].i32s().unwrap(), &[3, -3]);
    }

    #[test]
    fn tuple_element_dims_are_checked() {
        // a root tuple whose declared element shape disagrees with the
        // computed element must fail loudly (previously only the arity
        // was checked)
        let err = run(
            &["%p0 = f32[4] parameter(0)"],
            &[
                "%e = f32[4] exp(f32[4] %p0)",
                "ROOT %t = (f32[4], f32[2]) tuple(f32[4] %e, f32[4] %p0)",
            ],
            &[f32v(&[4], &[1.0, 2.0, 3.0, 4.0])],
        );
        assert!(err.is_err(), "mis-declared tuple element dims must be rejected");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("tuple element 1"), "error names the element: {msg}");

        // the arity check still fires too
        let err = run(
            &["%p0 = f32[4] parameter(0)"],
            &["ROOT %t = (f32[4], f32[4]) tuple(f32[4] %p0)"],
            &[f32v(&[4], &[1.0, 2.0, 3.0, 4.0])],
        );
        assert!(err.is_err(), "tuple arity mismatch must be rejected");
    }
}
