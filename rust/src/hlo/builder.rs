//! HLO-text graph builder.
//!
//! Emits the same textual dialect [`crate::hlo::parser`] reads (and real
//! XLA prints), so graphs built here execute on the in-repo interpreter
//! today and on a real PJRT client when one is available. Used by the
//! fixture generator (`repro gen-artifacts`) to lower the tiny BERT
//! forward/diag graphs without any Python in the loop.
//!
//! The builder is deliberately low-level — one method per HLO op, each
//! returning an opaque [`Op`] handle carrying the result dtype/dims — with
//! a few composite helpers (`matmul_bias`, `softmax`, `layernorm`) where
//! the lowering is always the same shape.

use anyhow::{bail, Result};

use super::DType;

/// Handle to an emitted instruction: its SSA name plus result type.
#[derive(Debug, Clone)]
pub struct Op {
    id: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl Op {
    fn shape_str(&self) -> String {
        shape_str(self.dtype, &self.dims)
    }

    /// `f32[2,3] %v17` — operand reference text.
    fn as_ref(&self) -> String {
        format!("{} %{}", self.shape_str(), self.id)
    }
}

fn shape_str(dtype: DType, dims: &[usize]) -> String {
    let body: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("{}[{}]", dtype.name(), body.join(","))
}

fn dims_attr(dims: &[usize]) -> String {
    let body: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("{{{}}}", body.join(","))
}

/// Builds one module: optional reduce sub-computations + one ENTRY.
pub struct GraphBuilder {
    module_name: String,
    params: Vec<Op>,
    body: Vec<String>,
    subs: Vec<String>,
    have_red_add: bool,
    have_red_max: bool,
    n: usize,
}

impl GraphBuilder {
    pub fn new(module_name: &str) -> GraphBuilder {
        GraphBuilder {
            module_name: module_name.to_string(),
            params: Vec::new(),
            body: Vec::new(),
            subs: Vec::new(),
            have_red_add: false,
            have_red_max: false,
            n: 0,
        }
    }

    fn fresh(&mut self, dtype: DType, dims: &[usize]) -> Op {
        let id = format!("v{}", self.n);
        self.n += 1;
        Op { id, dtype, dims: dims.to_vec() }
    }

    fn push(&mut self, op: &Op, text: String) {
        self.body.push(format!("  %{} = {} {}", op.id, op.shape_str(), text));
    }

    // -- leaf ops ----------------------------------------------------------

    pub fn param(&mut self, dtype: DType, dims: &[usize]) -> Op {
        let op = self.fresh(dtype, dims);
        let k = self.params.len();
        self.push(&op, format!("parameter({k})"));
        self.params.push(op.clone());
        op
    }

    pub fn const_f32(&mut self, v: f32) -> Op {
        let op = self.fresh(DType::F32, &[]);
        self.push(&op, format!("constant({v:?})"));
        op
    }

    // -- elementwise -------------------------------------------------------

    fn binary(&mut self, opcode: &str, a: &Op, b: &Op) -> Result<Op> {
        if a.dims != b.dims || a.dtype != b.dtype {
            bail!("{opcode}: operand shape mismatch {:?} vs {:?}", a.dims, b.dims);
        }
        let op = self.fresh(a.dtype, &a.dims);
        self.push(&op, format!("{opcode}({}, {})", a.as_ref(), b.as_ref()));
        Ok(op)
    }

    pub fn add(&mut self, a: &Op, b: &Op) -> Result<Op> {
        self.binary("add", a, b)
    }

    pub fn sub(&mut self, a: &Op, b: &Op) -> Result<Op> {
        self.binary("subtract", a, b)
    }

    pub fn mul(&mut self, a: &Op, b: &Op) -> Result<Op> {
        self.binary("multiply", a, b)
    }

    pub fn div(&mut self, a: &Op, b: &Op) -> Result<Op> {
        self.binary("divide", a, b)
    }

    fn unary(&mut self, opcode: &str, a: &Op) -> Op {
        let op = self.fresh(a.dtype, &a.dims);
        self.push(&op, format!("{opcode}({})", a.as_ref()));
        op
    }

    pub fn exp(&mut self, a: &Op) -> Op {
        self.unary("exp", a)
    }

    pub fn tanh(&mut self, a: &Op) -> Op {
        self.unary("tanh", a)
    }

    /// Elementwise sigmoid (the HLO `logistic` opcode).
    pub fn logistic(&mut self, a: &Op) -> Op {
        self.unary("logistic", a)
    }

    pub fn rsqrt(&mut self, a: &Op) -> Op {
        self.unary("rsqrt", a)
    }

    pub fn sqrt(&mut self, a: &Op) -> Op {
        self.unary("sqrt", a)
    }

    pub fn log(&mut self, a: &Op) -> Op {
        self.unary("log", a)
    }

    pub fn neg(&mut self, a: &Op) -> Op {
        self.unary("negate", a)
    }

    pub fn round(&mut self, a: &Op) -> Op {
        self.unary("round-nearest-afz", a)
    }

    pub fn clamp(&mut self, lo: &Op, x: &Op, hi: &Op) -> Op {
        let op = self.fresh(x.dtype, &x.dims);
        self.push(
            &op,
            format!("clamp({}, {}, {})", lo.as_ref(), x.as_ref(), hi.as_ref()),
        );
        op
    }

    pub fn select(&mut self, pred: &Op, t: &Op, f: &Op) -> Result<Op> {
        if t.dims != f.dims {
            bail!("select: branch shape mismatch");
        }
        let op = self.fresh(t.dtype, &t.dims);
        self.push(
            &op,
            format!("select({}, {}, {})", pred.as_ref(), t.as_ref(), f.as_ref()),
        );
        Ok(op)
    }

    pub fn compare(&mut self, direction: &str, a: &Op, b: &Op) -> Result<Op> {
        if a.dims != b.dims {
            bail!("compare: shape mismatch");
        }
        let op = self.fresh(DType::Pred, &a.dims);
        self.push(
            &op,
            format!("compare({}, {}), direction={direction}", a.as_ref(), b.as_ref()),
        );
        Ok(op)
    }

    /// Elementwise dtype cast (f32 <-> s32, pred -> f32/s32).
    pub fn convert(&mut self, a: &Op, dtype: DType) -> Op {
        let op = self.fresh(dtype, &a.dims);
        self.push(&op, format!("convert({})", a.as_ref()));
        op
    }

    /// `out[..., i, ...] = i` along dimension `along`.
    pub fn iota(&mut self, dtype: DType, dims: &[usize], along: usize) -> Result<Op> {
        if along >= dims.len() {
            bail!("iota dimension {along} out of range for {dims:?}");
        }
        let op = self.fresh(dtype, dims);
        self.push(&op, format!("iota(), iota_dimension={along}"));
        Ok(op)
    }

    // -- data movement -----------------------------------------------------

    /// Concatenate along `dim`; all other dims must agree.
    pub fn concatenate(&mut self, parts: &[Op], dim: usize) -> Result<Op> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("concatenate: no operands"))?;
        if dim >= first.dims.len() {
            bail!("concatenate dim {dim} out of range for {:?}", first.dims);
        }
        let mut out = first.dims.clone();
        out[dim] = 0;
        for p in parts {
            if p.dims.len() != first.dims.len() || p.dtype != first.dtype {
                bail!("concatenate: rank/dtype mismatch");
            }
            for (k, (&a, &b)) in p.dims.iter().zip(&first.dims).enumerate() {
                if k != dim && a != b {
                    bail!("concatenate: non-concat dim {k} mismatch: {a} vs {b}");
                }
            }
            out[dim] += p.dims[dim];
        }
        let refs: Vec<String> = parts.iter().map(Op::as_ref).collect();
        let op = self.fresh(first.dtype, &out);
        self.push(
            &op,
            format!("concatenate({}), dimensions={{{dim}}}", refs.join(", ")),
        );
        Ok(op)
    }

    pub fn broadcast(&mut self, a: &Op, out_dims: &[usize], map: &[usize]) -> Result<Op> {
        if map.len() != a.dims.len() {
            bail!("broadcast: dimensions arity mismatch");
        }
        for (k, &od) in map.iter().enumerate() {
            if od >= out_dims.len() || out_dims[od] != a.dims[k] {
                bail!("broadcast: dim {k} does not fit output {out_dims:?}");
            }
        }
        let op = self.fresh(a.dtype, out_dims);
        self.push(
            &op,
            format!("broadcast({}), dimensions={}", a.as_ref(), dims_attr(map)),
        );
        Ok(op)
    }

    /// Broadcast a scalar to `out_dims`.
    pub fn splat(&mut self, a: &Op, out_dims: &[usize]) -> Result<Op> {
        if !a.dims.is_empty() {
            bail!("splat wants a scalar operand");
        }
        self.broadcast(a, out_dims, &[])
    }

    pub fn reshape(&mut self, a: &Op, dims: &[usize]) -> Result<Op> {
        let want: usize = dims.iter().product();
        let have: usize = a.dims.iter().product();
        if want != have {
            bail!("reshape {:?} -> {dims:?}: element count mismatch", a.dims);
        }
        let op = self.fresh(a.dtype, dims);
        self.push(&op, format!("reshape({})", a.as_ref()));
        Ok(op)
    }

    pub fn transpose(&mut self, a: &Op, perm: &[usize]) -> Result<Op> {
        if perm.len() != a.dims.len() {
            bail!("transpose: rank mismatch");
        }
        let out: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
        let op = self.fresh(a.dtype, &out);
        self.push(
            &op,
            format!("transpose({}), dimensions={}", a.as_ref(), dims_attr(perm)),
        );
        Ok(op)
    }

    pub fn slice(&mut self, a: &Op, ranges: &[(usize, usize)]) -> Result<Op> {
        if ranges.len() != a.dims.len() {
            bail!("slice: rank mismatch");
        }
        let mut out = Vec::with_capacity(ranges.len());
        let mut attr = Vec::with_capacity(ranges.len());
        for (d, &(lo, hi)) in ranges.iter().enumerate() {
            if lo > hi || hi > a.dims[d] {
                bail!("slice [{lo}:{hi}] out of range for dim {d} of {:?}", a.dims);
            }
            out.push(hi - lo);
            attr.push(format!("[{lo}:{hi}]"));
        }
        let op = self.fresh(a.dtype, &out);
        self.push(
            &op,
            format!("slice({}), slice={{{}}}", a.as_ref(), attr.join(", ")),
        );
        Ok(op)
    }

    /// Canonical embedding-table lookup: `table[V,d][indices[N]] -> [N,d]`.
    pub fn gather_rows(&mut self, table: &Op, indices: &Op) -> Result<Op> {
        if table.dims.len() != 2 || indices.dims.len() != 1 {
            bail!("gather_rows wants table [V,d] and indices [N]");
        }
        let d = table.dims[1];
        let n = indices.dims[0];
        let idx2 = self.reshape(indices, &[n, 1])?;
        let op = self.fresh(table.dtype, &[n, d]);
        self.push(
            &op,
            format!(
                "gather({}, {}), offset_dims={{1}}, collapsed_slice_dims={{0}}, \
                 start_index_map={{0}}, index_vector_dim=1, slice_sizes={{1,{d}}}",
                table.as_ref(),
                idx2.as_ref()
            ),
        );
        Ok(op)
    }

    // -- contractions & reductions -----------------------------------------

    #[allow(clippy::too_many_arguments)]
    pub fn dot_general(
        &mut self,
        a: &Op,
        b: &Op,
        lb: &[usize],
        rb: &[usize],
        lc: &[usize],
        rc: &[usize],
    ) -> Result<Op> {
        let l_free: Vec<usize> = (0..a.dims.len())
            .filter(|d| !lb.contains(d) && !lc.contains(d))
            .collect();
        let r_free: Vec<usize> = (0..b.dims.len())
            .filter(|d| !rb.contains(d) && !rc.contains(d))
            .collect();
        let mut out: Vec<usize> = lb.iter().map(|&d| a.dims[d]).collect();
        out.extend(l_free.iter().map(|&d| a.dims[d]));
        out.extend(r_free.iter().map(|&d| b.dims[d]));
        let op = self.fresh(DType::F32, &out);
        let mut attrs = Vec::new();
        if !lb.is_empty() {
            attrs.push(format!("lhs_batch_dims={}", dims_attr(lb)));
            attrs.push(format!("rhs_batch_dims={}", dims_attr(rb)));
        }
        attrs.push(format!("lhs_contracting_dims={}", dims_attr(lc)));
        attrs.push(format!("rhs_contracting_dims={}", dims_attr(rc)));
        self.push(
            &op,
            format!("dot({}, {}), {}", a.as_ref(), b.as_ref(), attrs.join(", ")),
        );
        Ok(op)
    }

    fn ensure_red_add(&mut self) -> &'static str {
        if !self.have_red_add {
            self.subs.push(
                "%red_add (ra: f32[], rb: f32[]) -> f32[] {\n  %ra = f32[] parameter(0)\n  \
                 %rb = f32[] parameter(1)\n  ROOT %rr = f32[] add(f32[] %ra, f32[] %rb)\n}"
                    .to_string(),
            );
            self.have_red_add = true;
        }
        "red_add"
    }

    fn ensure_red_max(&mut self) -> &'static str {
        if !self.have_red_max {
            self.subs.push(
                "%red_max (ra: f32[], rb: f32[]) -> f32[] {\n  %ra = f32[] parameter(0)\n  \
                 %rb = f32[] parameter(1)\n  ROOT %rr = f32[] maximum(f32[] %ra, f32[] %rb)\n}"
                    .to_string(),
            );
            self.have_red_max = true;
        }
        "red_max"
    }

    fn reduce(&mut self, a: &Op, rdims: &[usize], init: f32, apply: &str) -> Result<Op> {
        for &d in rdims {
            if d >= a.dims.len() {
                bail!("reduce dim {d} out of range");
            }
        }
        let init = self.const_f32(init);
        let out: Vec<usize> = (0..a.dims.len())
            .filter(|d| !rdims.contains(d))
            .map(|d| a.dims[d])
            .collect();
        let op = self.fresh(DType::F32, &out);
        self.push(
            &op,
            format!(
                "reduce({}, {}), dimensions={}, to_apply=%{apply}",
                a.as_ref(),
                init.as_ref(),
                dims_attr(rdims)
            ),
        );
        Ok(op)
    }

    pub fn reduce_add(&mut self, a: &Op, rdims: &[usize]) -> Result<Op> {
        let apply = self.ensure_red_add();
        self.reduce(a, rdims, 0.0, apply)
    }

    pub fn reduce_max(&mut self, a: &Op, rdims: &[usize]) -> Result<Op> {
        let apply = self.ensure_red_max();
        self.reduce(a, rdims, f32::NEG_INFINITY, apply)
    }

    // -- composite helpers -------------------------------------------------

    /// Scale every element by a compile-time scalar.
    pub fn scale(&mut self, a: &Op, s: f32) -> Result<Op> {
        let c = self.const_f32(s);
        let cb = self.splat(&c, &a.dims.clone())?;
        self.mul(a, &cb)
    }

    /// Add a compile-time scalar to every element.
    pub fn offset(&mut self, a: &Op, s: f32) -> Result<Op> {
        let c = self.const_f32(s);
        let cb = self.splat(&c, &a.dims.clone())?;
        self.add(a, &cb)
    }

    /// `x @ w + b` for `x [.., k]`, `w [k, n]`, `b [n]` (bias broadcast
    /// over the leading axes).
    pub fn matmul_bias(&mut self, x: &Op, w: &Op, b: &Op) -> Result<Op> {
        let rank = x.dims.len();
        if rank < 1 || w.dims.len() != 2 || b.dims.len() != 1 {
            bail!("matmul_bias wants x [..,k], w [k,n], b [n]");
        }
        let y = self.dot_general(x, w, &[], &[], &[rank - 1], &[0])?;
        let bb = self.broadcast(b, &y.dims.clone(), &[y.dims.len() - 1])?;
        self.add(&y, &bb)
    }

    /// Numerically-stable softmax over the last axis.
    pub fn softmax(&mut self, x: &Op) -> Result<Op> {
        let rank = x.dims.len();
        let last = rank - 1;
        let m = self.reduce_max(x, &[last])?;
        let keep: Vec<usize> = (0..rank - 1).collect();
        let mb = self.broadcast(&m, &x.dims.clone(), &keep)?;
        let c = self.sub(x, &mb)?;
        let e = self.exp(&c);
        let s = self.reduce_add(&e, &[last])?;
        let sb = self.broadcast(&s, &x.dims.clone(), &keep)?;
        self.div(&e, &sb)
    }

    /// LayerNorm over the last axis with gain `g` and bias `b` (both
    /// `[d]`), eps 1e-5 — mirrors `kernels.layernorm`.
    pub fn layernorm(&mut self, x: &Op, g: &Op, b: &Op) -> Result<Op> {
        let rank = x.dims.len();
        let last = rank - 1;
        let d = x.dims[last];
        let keep: Vec<usize> = (0..rank - 1).collect();
        let sum = self.reduce_add(x, &[last])?;
        let mean = self.scale(&sum, 1.0 / d as f32)?;
        let mb = self.broadcast(&mean, &x.dims.clone(), &keep)?;
        let xc = self.sub(x, &mb)?;
        let sq = self.mul(&xc, &xc)?;
        let var_sum = self.reduce_add(&sq, &[last])?;
        let var = self.scale(&var_sum, 1.0 / d as f32)?;
        let var_eps = self.offset(&var, 1e-5)?;
        let inv = self.rsqrt(&var_eps);
        let invb = self.broadcast(&inv, &x.dims.clone(), &keep)?;
        let norm = self.mul(&xc, &invb)?;
        let gb = self.broadcast(g, &x.dims.clone(), &[last])?;
        let bb = self.broadcast(b, &x.dims.clone(), &[last])?;
        let scaled = self.mul(&norm, &gb)?;
        self.add(&scaled, &bb)
    }

    /// tanh-approximation GELU (matches jax.nn.gelu(approximate=True)).
    pub fn gelu(&mut self, x: &Op) -> Result<Op> {
        let x3 = {
            let x2 = self.mul(x, x)?;
            self.mul(&x2, x)?
        };
        let inner = {
            let c = self.scale(&x3, 0.044715)?;
            let s = self.add(x, &c)?;
            self.scale(&s, 0.797_884_6)? // sqrt(2/pi)
        };
        let t = self.tanh(&inner);
        let one = self.offset(&t, 1.0)?;
        let half = self.scale(&one, 0.5)?;
        self.mul(x, &half)
    }

    // -- finalisation ------------------------------------------------------

    /// Set the ROOT tuple and render the module text.
    pub fn finish(mut self, roots: &[Op]) -> String {
        let shapes: Vec<String> = roots.iter().map(Op::shape_str).collect();
        let refs: Vec<String> = roots.iter().map(Op::as_ref).collect();
        let tuple_shape = format!("({})", shapes.join(", "));
        let id = format!("v{}", self.n);
        self.body.push(format!(
            "  ROOT %{id} = {tuple_shape} tuple({})",
            refs.join(", ")
        ));

        let header: Vec<String> = self
            .params
            .iter()
            .enumerate()
            .map(|(k, p)| format!("a{k}: {}", p.shape_str()))
            .collect();
        let mut out = String::new();
        out.push_str(&format!("HloModule {}\n\n", self.module_name));
        for s in &self.subs {
            out.push_str(s);
            out.push_str("\n\n");
        }
        out.push_str(&format!(
            "ENTRY %main ({}) -> {tuple_shape} {{\n",
            header.join(", ")
        ));
        for line in &self.body {
            out.push_str(line);
            out.push('\n');
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::{interpret, parse_module, Value};

    #[test]
    fn builder_emits_parseable_module() {
        let mut g = GraphBuilder::new("tiny");
        let x = g.param(DType::F32, &[2, 3]);
        let w = g.param(DType::F32, &[3, 2]);
        let b = g.param(DType::F32, &[2]);
        let y = g.matmul_bias(&x, &w, &b).unwrap();
        let sm = g.softmax(&y).unwrap();
        let text = g.finish(&[y.clone(), sm]);
        let m = parse_module(&text).unwrap();
        assert_eq!(m.entry().params.len(), 3);

        let xs = Value::F32 { dims: vec![2, 3], data: vec![1., 0., 0., 0., 1., 0.] };
        let ws = Value::F32 { dims: vec![3, 2], data: vec![1., 2., 3., 4., 5., 6.] };
        let bs = Value::F32 { dims: vec![2], data: vec![0.5, -0.5] };
        let out = interpret(&m, &[xs, ws, bs]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].f32s().unwrap(), &[1.5, 1.5, 3.5, 3.5]);
        let sm = out[1].f32s().unwrap();
        assert!((sm[0] - 0.5).abs() < 1e-6 && (sm[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn layernorm_matches_reference() {
        let mut g = GraphBuilder::new("ln");
        let x = g.param(DType::F32, &[1, 4]);
        let gain = g.param(DType::F32, &[4]);
        let bias = g.param(DType::F32, &[4]);
        let y = g.layernorm(&x, &gain, &bias).unwrap();
        let text = g.finish(&[y]);
        let m = parse_module(&text).unwrap();
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let out = interpret(&m, &[
            Value::F32 { dims: vec![1, 4], data: data.to_vec() },
            Value::F32 { dims: vec![4], data: vec![1.0; 4] },
            Value::F32 { dims: vec![4], data: vec![0.0; 4] },
        ])
        .unwrap();
        let got = out[0].f32s().unwrap();
        let mean = 2.5f32;
        let var = data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (g, x) in got.iter().zip(&data) {
            let want = (x - mean) * inv;
            assert!((g - want).abs() < 1e-5, "{g} vs {want}");
        }
    }

    #[test]
    fn gelu_matches_reference() {
        let mut g = GraphBuilder::new("gelu");
        let x = g.param(DType::F32, &[3]);
        let y = g.gelu(&x).unwrap();
        let text = g.finish(&[y]);
        let m = parse_module(&text).unwrap();
        let data = [-1.0f32, 0.0, 2.0];
        let out = interpret(&m, &[Value::F32 { dims: vec![3], data: data.to_vec() }])
            .unwrap();
        let got = out[0].f32s().unwrap();
        for (g, &x) in got.iter().zip(&data) {
            let want =
                0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044715 * x * x * x)).tanh());
            assert!((g - want).abs() < 1e-6, "{g} vs {want}");
        }
    }

    #[test]
    fn gather_rows_and_slice() {
        let mut g = GraphBuilder::new("gr");
        let table = g.param(DType::F32, &[4, 2]);
        let idx = g.param(DType::S32, &[3]);
        let rows = g.gather_rows(&table, &idx).unwrap();
        let first = g.slice(&rows, &[(0, 1), (0, 2)]).unwrap();
        let text = g.finish(&[rows, first]);
        let m = parse_module(&text).unwrap();
        let out = interpret(&m, &[
            Value::F32 {
                dims: vec![4, 2],
                data: vec![0., 1., 10., 11., 20., 21., 30., 31.],
            },
            Value::S32 { dims: vec![3], data: vec![3, 1, 0] },
        ])
        .unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[30., 31., 10., 11., 0., 1.]);
        assert_eq!(out[1].f32s().unwrap(), &[30., 31.]);
    }

    #[test]
    fn builder_validates_shapes() {
        let mut g = GraphBuilder::new("bad");
        let a = g.param(DType::F32, &[2]);
        let b = g.param(DType::F32, &[3]);
        assert!(g.add(&a, &b).is_err());
        assert!(g.reshape(&a, &[5]).is_err());
        assert!(g.slice(&a, &[(0, 9)]).is_err());
        assert!(g.broadcast(&a, &[2, 2], &[5]).is_err());
    }
}
