//! In-repo HLO-text toolchain: parser, host interpreter, and graph
//! builder.
//!
//! The AOT artifacts ship as HLO *text* (python/compile/aot.py). In
//! environments without the real PJRT binding, `runtime::Runtime` falls
//! back to interpreting that text directly on the host (see the
//! `ExecBackend` seam in `crate::runtime`), so every end-to-end surface —
//! `repro smoke`, dev-set evaluation, the sweep's runtime pass — executes
//! in-container instead of dead-ending in `vendor/xla-stub`'s compile
//! error.
//!
//! Sub-modules:
//! * [`parser`]  — HLO text -> [`HloModule`] (module / computations /
//!                 instructions with shapes, literals, operands, attrs).
//! * [`interp`]  — reference evaluator for the op set BERT-style
//!                 forward/diag graphs need (dot-general, reduce, gather,
//!                 elementwise, control ops). Plain data + pure functions,
//!                 hence `Send + Sync` — the runtime's shared executable
//!                 cache works unchanged.
//! * [`plan`]    — once-per-module execution planning (operand slot
//!                 resolution, constant materialisation, last-use
//!                 liveness, elementwise fusion, borrowed parameters);
//!                 the runtime's interpreted hot path. Bit-identical to
//!                 [`interp`] by construction.
//! * [`verify`](mod@verify) — static whole-module shape/dtype verifier
//!                 (TQ1xx diagnostics); runs before plan build and cache
//!                 admission so dynamic per-op checks in [`interp`] and
//!                 [`plan`] can retreat behind `debug_assertions`.
//! * [`builder`] — emits HLO text (the same dialect the parser reads);
//!                 used by the fixture generator.
//! * [`fixture`] — `repro gen-artifacts`: a small self-consistent
//!                 `artifacts/` (manifest.json + tiny BERT *and* ViT
//!                 forward/diag modules + kernel graphs + per-task init
//!                 checkpoints) so integration tests and CI run without
//!                 `make artifacts`.

pub mod builder;
pub mod fixture;
pub mod interp;
pub mod parser;
pub mod plan;
pub(crate) mod train_graph;
pub mod verify;

use anyhow::{bail, Result};

pub use interp::{interpret, interpret_refs};
pub use parser::{parse_module, Computation, HloModule, Inst};
pub use plan::Plan;
pub use verify::{verify, verify_module, VerifyDiag};

/// Element types the toolchain supports (the subset tq's graphs use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    Pred,
}

impl DType {
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::S32 => "s32",
            DType::Pred => "pred",
        }
    }
}

/// An HLO shape: a dense array shape or a tuple of shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array { dtype: DType, dims: Vec<usize> },
    Tuple(Vec<Shape>),
}

impl Shape {
    pub fn f32(dims: &[usize]) -> Shape {
        Shape::Array { dtype: DType::F32, dims: dims.to_vec() }
    }

    pub fn s32(dims: &[usize]) -> Shape {
        Shape::Array { dtype: DType::S32, dims: dims.to_vec() }
    }

    pub fn dims(&self) -> Result<&[usize]> {
        match self {
            Shape::Array { dims, .. } => Ok(dims),
            Shape::Tuple(_) => bail!("tuple shape has no array dims"),
        }
    }

    pub fn dtype(&self) -> Result<DType> {
        match self {
            Shape::Array { dtype, .. } => Ok(*dtype),
            Shape::Tuple(_) => bail!("tuple shape has no element type"),
        }
    }

    pub fn elems(&self) -> usize {
        match self {
            Shape::Array { dims, .. } => dims.iter().product(),
            Shape::Tuple(parts) => parts.iter().map(Shape::elems).sum(),
        }
    }
}

/// A host-side runtime value.
#[derive(Debug, Clone)]
pub enum Value {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    S32 { dims: Vec<usize>, data: Vec<i32> },
    Pred { dims: Vec<usize>, data: Vec<bool> },
    Tuple(Vec<Value>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32 { dims: Vec::new(), data: vec![x] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Value::F32 { dims, .. } => dims,
            Value::S32 { dims, .. } => dims,
            Value::Pred { dims, .. } => dims,
            Value::Tuple(_) => &[],
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32 { data, .. } => data.len(),
            Value::S32 { data, .. } => data.len(),
            Value::Pred { data, .. } => data.len(),
            Value::Tuple(parts) => parts.iter().map(Value::len).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Result<DType> {
        match self {
            Value::F32 { .. } => Ok(DType::F32),
            Value::S32 { .. } => Ok(DType::S32),
            Value::Pred { .. } => Ok(DType::Pred),
            Value::Tuple(_) => bail!("tuple value has no element type"),
        }
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("value is not f32"),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match self {
            Value::S32 { data, .. } => Ok(data),
            _ => bail!("value is not s32"),
        }
    }

    pub fn preds(&self) -> Result<&[bool]> {
        match self {
            Value::Pred { data, .. } => Ok(data),
            _ => bail!("value is not pred"),
        }
    }
}

/// Row-major strides for `dims` (stride of the last axis is 1).
pub(crate) fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_helpers() {
        let s = Shape::f32(&[2, 3]);
        assert_eq!(s.dims().unwrap(), &[2, 3]);
        assert_eq!(s.dtype().unwrap(), DType::F32);
        assert_eq!(s.elems(), 6);
        let t = Shape::Tuple(vec![Shape::f32(&[2]), Shape::s32(&[])]);
        assert_eq!(t.elems(), 3);
        assert!(t.dims().is_err());
        assert!(t.dtype().is_err());
    }

    #[test]
    fn value_helpers() {
        let v = Value::F32 { dims: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        assert_eq!(v.dims(), &[2, 2]);
        assert_eq!(v.len(), 4);
        assert!(v.f32s().is_ok());
        assert!(v.i32s().is_err());
        let s = Value::scalar_f32(5.0);
        assert_eq!(s.dims(), &[] as &[usize]);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn stride_math() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }
}
