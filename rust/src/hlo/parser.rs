//! HLO-text parser.
//!
//! Parses the textual HLO dialect the AOT artifacts ship in (and the
//! [`crate::hlo::builder`] emits): a module header, optional reduction
//! sub-computations, and an `ENTRY` computation whose instructions are one
//! per line:
//!
//! ```text
//! HloModule jit_fn
//!
//! %red_add (a: f32[], b: f32[]) -> f32[] {
//!   %a = f32[] parameter(0)
//!   %b = f32[] parameter(1)
//!   ROOT %r = f32[] add(f32[] %a, f32[] %b)
//! }
//!
//! ENTRY %main (p0: f32[2,3]) -> (f32[2]) {
//!   %p0 = f32[2,3]{1,0} parameter(0)
//!   %c = f32[] constant(0)
//!   %r = f32[2]{0} reduce(f32[2,3]{1,0} %p0, f32[] %c), dimensions={1}, to_apply=%red_add
//!   ROOT %t = (f32[2]{0}) tuple(f32[2]{0} %r)
//! }
//! ```
//!
//! Layout suffixes (`{1,0}`) and unknown attributes (`metadata=...`,
//! `sharding=...`, `frontend_attributes=...`) are accepted and ignored, so
//! real XLA-printed modules parse as well as builder-emitted ones.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{DType, Shape};

/// One parsed instruction.
#[derive(Debug, Clone)]
pub struct Inst {
    pub name: String,
    pub shape: Shape,
    pub opcode: String,
    /// operand instruction names (no leading `%`)
    pub operands: Vec<String>,
    /// raw payload for `constant` (the literal text) and `parameter` (the
    /// parameter index)
    pub payload: Option<String>,
    /// raw attribute text keyed by attribute name
    pub attrs: BTreeMap<String, String>,
    pub is_root: bool,
}

impl Inst {
    /// `dimensions={0,2}`-style attribute as a usize list (empty when the
    /// attribute is `{}`).
    pub fn attr_dims(&self, key: &str) -> Result<Vec<usize>> {
        let raw = self
            .attrs
            .get(key)
            .ok_or_else(|| anyhow!("{}: missing attribute {key}", self.name))?;
        parse_brace_list(raw).with_context(|| format!("{}: attribute {key}", self.name))
    }

    /// Like [`Inst::attr_dims`] but `{}`/absent maps to the default.
    pub fn attr_dims_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.attrs.get(key) {
            None => Ok(default.to_vec()),
            Some(raw) => {
                parse_brace_list(raw).with_context(|| format!("{}: attribute {key}", self.name))
            }
        }
    }

    /// Scalar integer attribute (e.g. `index=0`, `iota_dimension=1`).
    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        let raw = self
            .attrs
            .get(key)
            .ok_or_else(|| anyhow!("{}: missing attribute {key}", self.name))?;
        raw.trim()
            .parse::<usize>()
            .map_err(|_| anyhow!("{}: attribute {key}={raw:?} is not an integer", self.name))
    }

    pub fn attr_str(&self, key: &str) -> Result<&str> {
        self.attrs
            .get(key)
            .map(String::as_str)
            .ok_or_else(|| anyhow!("{}: missing attribute {key}", self.name))
    }
}

/// One computation: instructions in program order plus lookup tables.
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub insts: Vec<Inst>,
    /// instruction index by name
    pub index: BTreeMap<String, usize>,
    /// instruction index of parameter `i`
    pub params: Vec<usize>,
    /// index of the ROOT instruction
    pub root: usize,
}

impl Computation {
    pub fn inst(&self, name: &str) -> Result<&Inst> {
        let i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("computation {}: no instruction %{name}", self.name))?;
        Ok(&self.insts[*i])
    }
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub computations: Vec<Computation>,
    /// index of the ENTRY computation
    pub entry: usize,
}

impl HloModule {
    pub fn entry(&self) -> &Computation {
        &self.computations[self.entry]
    }

    pub fn computation(&self, name: &str) -> Result<&Computation> {
        self.computations
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("no computation %{name} in module {}", self.name))
    }

    /// Shapes of the entry computation's parameters, in parameter order.
    pub fn entry_param_shapes(&self) -> Vec<&Shape> {
        let e = self.entry();
        e.params.iter().map(|&i| &e.insts[i].shape).collect()
    }
}

/// Parse an HLO-text module.
pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut module_name = String::from("module");
    let mut computations: Vec<Computation> = Vec::new();
    let mut entry: Option<usize> = None;

    let mut lines = text.lines().enumerate();
    while let Some((ln0, raw)) = lines.next() {
        let lineno = ln0 + 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            let rest = rest.trim();
            let end = rest
                .find(|c: char| c == ',' || c == ' ')
                .unwrap_or(rest.len());
            if end > 0 {
                module_name = rest[..end].to_string();
            }
            continue;
        }
        if line.ends_with('{') && line.contains("->") {
            let is_entry = line.starts_with("ENTRY");
            let header = line.trim_start_matches("ENTRY").trim();
            let name_end = header.find(' ').unwrap_or(header.len());
            let comp_name = header[..name_end].trim_start_matches('%').to_string();
            let mut body: Vec<(usize, String)> = Vec::new();
            let mut closed = false;
            for (bln0, body_raw) in lines.by_ref() {
                let body_line = body_raw.trim();
                if body_line == "}" {
                    closed = true;
                    break;
                }
                if !body_line.is_empty() {
                    body.push((bln0 + 1, body_line.to_string()));
                }
            }
            if !closed {
                bail!(
                    "computation %{comp_name} (opened at line {lineno}): \
                     truncated module, missing closing `}}`"
                );
            }
            let comp = parse_computation(comp_name, &body)?;
            if is_entry {
                entry = Some(computations.len());
            }
            computations.push(comp);
            continue;
        }
        bail!("line {lineno}: unrecognised line outside a computation: {line:?}");
    }
    let entry = match entry {
        Some(e) => e,
        // modules printed without ENTRY keep the last computation as entry
        None if !computations.is_empty() => computations.len() - 1,
        None => bail!("module has no computations"),
    };
    Ok(HloModule { name: module_name, computations, entry })
}

fn parse_computation(name: String, body: &[(usize, String)]) -> Result<Computation> {
    let mut insts: Vec<Inst> = Vec::with_capacity(body.len());
    let mut index = BTreeMap::new();
    let mut params: Vec<(usize, usize)> = Vec::new(); // (param number, inst idx)
    let mut root = None;
    for (lineno, line) in body {
        let inst = parse_inst(line)
            .with_context(|| format!("computation {name}, line {lineno}: {line:?}"))?;
        let i = insts.len();
        if inst.opcode == "parameter" {
            let n: usize = inst
                .payload
                .as_deref()
                .unwrap_or("")
                .trim()
                .parse()
                .map_err(|_| anyhow!("line {lineno}: bad parameter index in {line:?}"))?;
            params.push((n, i));
        }
        if inst.is_root {
            root = Some(i);
        }
        if index.insert(inst.name.clone(), i).is_some() {
            bail!(
                "computation {name}, line {lineno}: duplicate instruction name %{}",
                inst.name
            );
        }
        insts.push(inst);
    }
    params.sort();
    for (want, (got, _)) in params.iter().enumerate() {
        if *got != want {
            bail!("computation {name}: parameter numbers are not 0..n");
        }
    }
    let params: Vec<usize> = params.into_iter().map(|(_, i)| i).collect();
    let root = match root {
        Some(r) => r,
        // some printers omit ROOT on single-instruction bodies; fall back
        // to the last instruction
        None if !insts.is_empty() => insts.len() - 1,
        None => bail!("computation {name} is empty"),
    };
    Ok(Computation { name, insts, index, params, root })
}

fn parse_inst(line: &str) -> Result<Inst> {
    let (is_root, rest) = match line.strip_prefix("ROOT ") {
        Some(r) => (true, r.trim()),
        None => (false, line),
    };
    let eq = rest.find(" = ").ok_or_else(|| anyhow!("no `=` in instruction"))?;
    let name = rest[..eq].trim().trim_start_matches('%').to_string();
    let rhs = rest[eq + 3..].trim();

    let (shape, used) = parse_shape(rhs)?;
    let rhs = rhs[used..].trim_start();

    let open = rhs.find('(').ok_or_else(|| anyhow!("no operand list"))?;
    let opcode = rhs[..open].trim().to_string();
    let close = matching_paren(rhs, open)?;
    let operand_str = &rhs[open + 1..close];
    let attr_str = rhs[close + 1..].trim_start_matches(',').trim();

    let mut operands = Vec::new();
    let mut payload = None;
    if opcode == "constant" || opcode == "parameter" {
        payload = Some(operand_str.trim().to_string());
    } else {
        for piece in split_top_level(operand_str) {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let tok = piece
                .rsplit(' ')
                .next()
                .ok_or_else(|| anyhow!("empty operand in {line:?}"))?;
            if !tok.starts_with('%') {
                bail!("operand {piece:?} has no %name");
            }
            operands.push(tok.trim_start_matches('%').to_string());
        }
    }

    let mut attrs = BTreeMap::new();
    for piece in split_top_level(attr_str) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        if let Some((k, v)) = piece.split_once('=') {
            attrs.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    Ok(Inst { name, shape, opcode, operands, payload, attrs, is_root })
}

/// Parse a shape prefix of `s`; returns the shape and bytes consumed
/// (including any `{layout}` suffix).
pub fn parse_shape(s: &str) -> Result<(Shape, usize)> {
    let b = s.as_bytes();
    if b.first() == Some(&b'(') {
        // tuple shape
        let close = matching_paren(s, 0)?;
        let inner = &s[1..close];
        let mut parts = Vec::new();
        for piece in split_top_level(inner) {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (sh, used) = parse_shape(piece)?;
            if !piece[used..].trim().is_empty() {
                bail!("trailing text in tuple shape element {piece:?}");
            }
            parts.push(sh);
        }
        return Ok((Shape::Tuple(parts), close + 1));
    }
    let open = s
        .find('[')
        .ok_or_else(|| anyhow!("shape {s:?} has no `[`"))?;
    let dtype = match &s[..open] {
        "f32" => DType::F32,
        "s32" | "u32" => DType::S32,
        "pred" => DType::Pred,
        other => bail!("unsupported element type {other:?}"),
    };
    let close = s[open..]
        .find(']')
        .map(|i| i + open)
        .ok_or_else(|| anyhow!("shape {s:?} has no `]`"))?;
    let mut dims = Vec::new();
    for d in s[open + 1..close].split(',') {
        let d = d.trim();
        if d.is_empty() {
            continue;
        }
        dims.push(
            d.parse::<usize>()
                .map_err(|_| anyhow!("bad dimension {d:?} in shape {s:?}"))?,
        );
    }
    // optional layout suffix `{1,0}`
    let mut used = close + 1;
    if s[used..].starts_with('{') {
        let lclose = s[used..]
            .find('}')
            .ok_or_else(|| anyhow!("unterminated layout in {s:?}"))?;
        used += lclose + 1;
    }
    Ok((Shape::Array { dtype, dims }, used))
}

/// Index of the `)` matching the `(` at `open`.
fn matching_paren(s: &str, open: usize) -> Result<usize> {
    let mut depth = 0usize;
    for (i, c) in s.char_indices().skip(open) {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok(i);
                }
            }
            _ => {}
        }
    }
    bail!("unbalanced parentheses in {s:?}")
}

/// Split on commas at nesting depth zero (w.r.t. `()`, `{}`, `[]`).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// `{1, 2, 3}` (or `{}`) to a usize list.
fn parse_brace_list(raw: &str) -> Result<Vec<usize>> {
    let t = raw.trim();
    let inner = t
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .ok_or_else(|| anyhow!("expected {{...}}, got {raw:?}"))?;
    let mut out = Vec::new();
    for p in inner.split(',') {
        let p = p.trim();
        if p.is_empty() {
            continue;
        }
        out.push(p.parse::<usize>().map_err(|_| anyhow!("bad entry {p:?} in {raw:?}"))?);
    }
    Ok(out)
}

/// Parse a constant payload (`3.5`, `{1, 2}`, `{{1,2},{3,4}}`) into a flat
/// number list; nesting must match the declared shape's element count,
/// which the caller checks.
pub fn parse_literal_numbers(raw: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in raw
        .split(|c: char| c == '{' || c == '}' || c == ',' || c.is_whitespace())
        .filter(|t| !t.is_empty())
    {
        let v = match tok {
            "inf" => f64::INFINITY,
            "-inf" => f64::NEG_INFINITY,
            "true" => 1.0,
            "false" => 0.0,
            _ => tok
                .parse::<f64>()
                .map_err(|_| anyhow!("bad literal token {tok:?}"))?,
        };
        out.push(v);
    }
    Ok(out)
}

/// Parse `[0:2], [0:128]` / `[0:24:2]` slice attribute text.
pub fn parse_slice_ranges(raw: &str) -> Result<Vec<(usize, usize, usize)>> {
    let t = raw.trim();
    let inner = t
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .unwrap_or(t);
    let mut out = Vec::new();
    for piece in split_top_level(inner) {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        let body = piece
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .ok_or_else(|| anyhow!("bad slice range {piece:?}"))?;
        let parts: Vec<&str> = body.split(':').collect();
        let parse = |s: &str| -> Result<usize> {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad slice bound {s:?}"))
        };
        match parts.len() {
            2 => out.push((parse(parts[0])?, parse(parts[1])?, 1)),
            3 => out.push((parse(parts[0])?, parse(parts[1])?, parse(parts[2])?)),
            _ => bail!("bad slice range {piece:?}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_shapes() {
        let (s, used) = parse_shape("f32[2,3]{1,0} rest").unwrap();
        assert_eq!(s, Shape::f32(&[2, 3]));
        assert_eq!(used, "f32[2,3]{1,0}".len());
        let (s, _) = parse_shape("f32[]").unwrap();
        assert_eq!(s, Shape::f32(&[]));
        let (s, _) = parse_shape("s32[8]").unwrap();
        assert_eq!(s, Shape::s32(&[8]));
        let (s, used) = parse_shape("(f32[2]{0}, s32[])").unwrap();
        assert_eq!(s, Shape::Tuple(vec![Shape::f32(&[2]), Shape::s32(&[])]));
        assert_eq!(used, "(f32[2]{0}, s32[])".len());
        assert!(parse_shape("f64[2]").is_err());
    }

    #[test]
    fn parses_instruction_forms() {
        let i = parse_inst("%p0 = f32[2,3]{1,0} parameter(0)").unwrap();
        assert_eq!(i.opcode, "parameter");
        assert_eq!(i.payload.as_deref(), Some("0"));
        assert!(!i.is_root);

        let i = parse_inst("%c = f32[] constant(1.5)").unwrap();
        assert_eq!(i.payload.as_deref(), Some("1.5"));

        let i = parse_inst("%c2 = f32[3]{0} constant({1, 2, 3})").unwrap();
        assert_eq!(parse_literal_numbers(i.payload.as_deref().unwrap()).unwrap(), vec![
            1.0, 2.0, 3.0
        ]);

        let i = parse_inst(
            "ROOT %add.3 = f32[2,3]{1,0} add(f32[2,3]{1,0} %p0, f32[2,3]{1,0} %b.2)",
        )
        .unwrap();
        assert!(i.is_root);
        assert_eq!(i.operands, vec!["p0", "b.2"]);

        let i = parse_inst(
            "%r = f32[2]{0} reduce(f32[2,3] %x, f32[] %c), dimensions={1}, to_apply=%red_add",
        )
        .unwrap();
        assert_eq!(i.attr_dims("dimensions").unwrap(), vec![1]);
        assert_eq!(i.attr_str("to_apply").unwrap(), "%red_add");

        let i = parse_inst(
            "%d = f32[8,24,128] dot(f32[8,24,64] %a, f32[64,128] %b), \
             lhs_contracting_dims={2}, rhs_contracting_dims={0}, metadata={op_type=\"dot\"}",
        )
        .unwrap();
        assert_eq!(i.attr_dims("lhs_contracting_dims").unwrap(), vec![2]);
        assert_eq!(i.attr_dims_or("lhs_batch_dims", &[]).unwrap(), Vec::<usize>::new());

        let i = parse_inst("%s = f32[1,3]{1,0} slice(f32[5,3] %x), slice={[0:1], [0:3]}")
            .unwrap();
        assert_eq!(parse_slice_ranges(i.attr_str("slice").unwrap()).unwrap(), vec![
            (0, 1, 1),
            (0, 3, 1)
        ]);
    }

    #[test]
    fn parses_whole_module() {
        let text = "\
HloModule test_mod

%red_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[2,3]) -> (f32[2]) {
  %p0 = f32[2,3]{1,0} parameter(0)
  %c = f32[] constant(0)
  %r = f32[2]{0} reduce(f32[2,3]{1,0} %p0, f32[] %c), dimensions={1}, to_apply=%red_add
  ROOT %t = (f32[2]{0}) tuple(f32[2]{0} %r)
}
";
        let m = parse_module(text).unwrap();
        assert_eq!(m.name, "test_mod");
        assert_eq!(m.computations.len(), 2);
        let e = m.entry();
        assert_eq!(e.name, "main");
        assert_eq!(e.params.len(), 1);
        assert_eq!(e.insts[e.root].opcode, "tuple");
        assert_eq!(m.entry_param_shapes()[0], &Shape::f32(&[2, 3]));
        let red = m.computation("red_add").unwrap();
        assert_eq!(red.insts[red.root].opcode, "add");
        assert!(m.computation("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_module("not hlo at all").is_err());
        assert!(parse_inst("%x = f32[2] add(").is_err());
        assert!(parse_inst("just text").is_err());
    }

    // -- malformed-input regressions: every rejection names its location --

    #[test]
    fn truncated_module_names_the_open_computation() {
        let text = "\
HloModule broken

ENTRY %main (p0: f32[2]) -> f32[2] {
  %p0 = f32[2] parameter(0)
";
        let err = format!("{:#}", parse_module(text).unwrap_err());
        assert!(err.contains("truncated module"), "{err}");
        assert!(err.contains("%main"), "{err}");
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn unknown_dtype_names_computation_and_line() {
        let text = "\
HloModule broken

ENTRY %main (p0: f32[2]) -> f32[2] {
  %p0 = f32[2] parameter(0)
  ROOT %r = q7[2] negate(f32[2] %p0)
}
";
        let err = format!("{:#}", parse_module(text).unwrap_err());
        assert!(err.contains("unsupported element type"), "{err}");
        assert!(err.contains("computation main"), "{err}");
        assert!(err.contains("line 5"), "{err}");
    }

    #[test]
    fn malformed_attribute_list_names_the_line() {
        // unbalanced operand/attribute structure fails at parse time...
        let text = "\
HloModule broken

ENTRY %main (p0: f32[2]) -> f32[2] {
  ROOT %r = f32[2] negate(f32[2] %p0
}
";
        let err = format!("{:#}", parse_module(text).unwrap_err());
        assert!(err.contains("unbalanced parentheses"), "{err}");
        assert!(err.contains("line 4"), "{err}");
        // ...while a syntactically fine but semantically bad attribute
        // parses here and is rejected by the static verifier (TQ106)
        let text = "\
HloModule broken

ENTRY %main (p0: f32[2]) -> f32[2,2] {
  %p0 = f32[2] parameter(0)
  ROOT %b = f32[2,2] broadcast(f32[2] %p0), dimensions={1,x}
}
";
        let m = parse_module(text).unwrap();
        let diags = super::super::verify_module(&m);
        assert!(diags.iter().any(|d| d.code == "TQ106"), "{diags:?}");
    }

    #[test]
    fn duplicate_instruction_name_names_computation_and_line() {
        let text = "\
HloModule broken

ENTRY %main (p0: f32[2]) -> f32[2] {
  %p0 = f32[2] parameter(0)
  %x = f32[2] negate(f32[2] %p0)
  %x = f32[2] negate(f32[2] %p0)
  ROOT %r = f32[2] negate(f32[2] %x)
}
";
        let err = format!("{:#}", parse_module(text).unwrap_err());
        assert!(err.contains("duplicate instruction name %x"), "{err}");
        assert!(err.contains("computation main"), "{err}");
        assert!(err.contains("line 6"), "{err}");
    }
}
