//! Once-per-module execution planning for the host interpreter.
//!
//! [`Plan::build`] lowers an entry computation into a flat step list that
//! the hot path replays without any per-execution name resolution:
//!
//! * **Operand resolution** — operand names become env slot indices at
//!   build time (the naive engine does a hashmap lookup per operand per
//!   execution).
//! * **Constant materialisation** — `constant` literals are parsed once
//!   and borrowed by every execution.
//! * **Borrowed parameters** — the env is a vector of `Slot`s, a
//!   `Cow`-style cell that lets parameter tensors be *borrowed* from the
//!   caller instead of cloned per execution, which is what makes
//!   `Runtime::run_batch`'s shared static inputs zero-copy per item.
//! * **Liveness** — each step lists the slots whose last consumer it is;
//!   intermediates are dropped as soon as their final consumer ran
//!   instead of staying live until the root.
//! * **Elementwise fusion** — chains of same-shape elementwise ops
//!   (binary/unary arithmetic, `clamp`, `select`, f32 `compare`, and
//!   splat/row/column `broadcast`s feeding them) collapse into a single
//!   pass over the data: one register program evaluated per element, with
//!   stores only for values observable outside the fused group.
//!
//! Numerical contract: every fused kernel calls the *same* scalar
//! functions as the naive engine (`BinOp::f32`, `UnOp::f32`,
//! `cmp_f32`, the `max(lo).min(hi)` clamp), preds are encoded as exact
//! 1.0/0.0, and `dot` uses `interp::dot_general_fast` whose every path
//! accumulates in ascending-k order from 0.0 — so planned results are
//! bit-identical to the naive interpreter by construction, not by
//! tolerance. `tests/determinism.rs` pins this across engines and thread
//! counts.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use super::interp::{self, check_shape, cmp_f32, BinOp, CmpDir, Combinator, GatherSpec, UnOp};
use super::parser::{parse_slice_ranges, Computation, HloModule};
use super::{DType, Shape, Value};

// ---------------------------------------------------------------------------
// plan data model
// ---------------------------------------------------------------------------

/// A compiled execution plan for a module's ENTRY computation.
/// Immutable and `Send + Sync`: built once, shared across workers.
pub struct Plan {
    n_args: usize,
    n_slots: usize,
    /// (env slot, caller argument index, declared shape)
    params: Vec<(usize, usize, Shape)>,
    /// (env slot, materialised literal)
    consts: Vec<(usize, Value)>,
    steps: Vec<Step>,
    outputs: Vec<OutSpec>,
    root_is_tuple: bool,
}

struct OutSpec {
    slot: usize,
    shape: Shape,
}

struct Step {
    /// instruction name (first member's, for fused groups) — error context
    name: String,
    kind: StepKind,
    /// slots whose last use is this step; emptied right after it runs
    frees: Vec<usize>,
}

enum StepKind {
    Plain { out: usize, shape: Shape, operands: Vec<usize>, op: OpStep },
    Fused(Fused),
}

/// One non-fused instruction with its attributes parsed at build time.
enum OpStep {
    Broadcast { dims: Vec<usize>, map: Vec<usize> },
    Reshape { dims: Vec<usize>, want: usize },
    Transpose { perm: Vec<usize> },
    Slice { ranges: Vec<(usize, usize, usize)> },
    Concat { dim: usize },
    Dot { lb: Vec<usize>, rb: Vec<usize>, lc: Vec<usize>, rc: Vec<usize> },
    Binary { op: String },
    Unary { op: String },
    Clamp,
    Select,
    Compare { dir: String },
    Convert { to: DType },
    Iota { dims: Vec<usize>, along: usize, dtype: DType },
    Reduce { rdims: Vec<usize>, comb: Combinator },
    Tuple,
    Gte { index: usize },
    Gather { spec: GatherSpec },
    /// kept so a module the naive engine would reject at eval time fails
    /// at the same point (execution), with the same message
    Unsupported { opcode: String },
}

/// How a fused load walks its source buffer as the element index `i`
/// sweeps the group's output space: `Full` = `src[i]`, `Splat` =
/// `src[0]`, `Mod(m)` = `src[i % m]` (row-vector broadcast over the
/// trailing axis), `Div(d)` = `src[i / d]` (per-row scalar broadcast).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Pat {
    Full,
    Splat,
    Mod(usize),
    Div(usize),
}

#[derive(Debug, Clone, Copy)]
struct Load {
    slot: usize,
    pat: Pat,
    /// pred sources decode to exact 1.0 / 0.0
    pred: bool,
}

/// One register of the per-element program. Indices refer to earlier
/// registers, so a single left-to-right sweep evaluates the whole group.
#[derive(Debug, Clone, Copy)]
enum Node {
    Load(usize),
    Bin(BinOp, usize, usize),
    Un(UnOp, usize),
    /// max(lo).min(hi), same scalar sequence as `interp::clamp_value`
    Clamp(usize, usize, usize),
    Cmp(CmpDir, usize, usize),
    Sel(usize, usize, usize),
}

struct Store {
    node: usize,
    slot: usize,
    dims: Vec<usize>,
    /// re-encode the 1.0/0.0 register as `Value::Pred` (exact)
    pred: bool,
}

struct Fused {
    n: usize,
    loads: Vec<Load>,
    nodes: Vec<Node>,
    stores: Vec<Store>,
}

/// `Cow`-style env cell: parameters and constants are borrowed,
/// intermediates owned, dead slots empty.
enum Slot<'a> {
    Empty,
    Ref(&'a Value),
    Own(Value),
}

impl Slot<'_> {
    fn get(&self) -> Option<&Value> {
        match *self {
            Slot::Empty => None,
            Slot::Ref(v) => Some(v),
            Slot::Own(ref v) => Some(v),
        }
    }
}

#[derive(Clone, Copy)]
enum Src<'v> {
    F32(&'v [f32]),
    Pred(&'v [bool]),
}

// ---------------------------------------------------------------------------
// builder
// ---------------------------------------------------------------------------

/// Operand of a fused candidate: an earlier member's register, or a load
/// from an env slot with an access pattern.
#[derive(Clone, Copy)]
enum ORef {
    Member(usize),
    Load(usize, Pat, bool),
}

#[derive(Clone)]
enum MKind {
    Bin(BinOp, ORef, ORef),
    Un(UnOp, ORef),
    Clamp(ORef, ORef, ORef),
    Sel(ORef, ORef, ORef),
    Cmp(CmpDir, ORef, ORef),
    Bcast(ORef),
}

#[derive(Clone)]
struct Member {
    idx: usize,
    pred_out: bool,
    kind: MKind,
}

struct Builder<'m> {
    module: &'m HloModule,
    comp: &'m Computation,
    /// per instruction: operand env slots (resolved names)
    ops: Vec<Vec<usize>>,
    /// per slot: instruction indices that consume it
    uses: Vec<Vec<usize>>,
    is_output: Vec<bool>,
    prefilled: Vec<bool>,
    root_skipped: bool,
    steps: Vec<Step>,
    members: Vec<Member>,
    run_map: HashMap<usize, usize>,
    run_od: Vec<usize>,
}

impl Plan {
    /// Lower `module`'s ENTRY computation into an execution plan.
    ///
    /// Build is total for any module the naive engine can *evaluate*;
    /// structural errors the naive engine would only hit at eval time
    /// (unknown operands, bad attributes, malformed literals) surface
    /// here instead, so callers can fall back to the naive engine.
    ///
    /// Build starts with the static verifier
    /// ([`crate::hlo::verify`](fn@crate::hlo::verify)),
    /// so a plan only ever exists for a shape/dtype-consistent module —
    /// the per-step shape checks in [`Plan::execute`] are debug-only.
    pub fn build(module: &HloModule) -> Result<Plan> {
        super::verify::verify(module).context("planning")?;
        let comp = module.entry();
        let n = comp.insts.len();

        // -- operand name -> slot resolution (once, ever) --
        let mut ops: Vec<Vec<usize>> = Vec::with_capacity(n);
        for (i, inst) in comp.insts.iter().enumerate() {
            let mut v = Vec::with_capacity(inst.operands.len());
            for name in &inst.operands {
                let &s = comp
                    .index
                    .get(name)
                    .ok_or_else(|| anyhow!("%{}: unknown operand %{name}", inst.name))?;
                if s >= i {
                    bail!("%{}: operand %{name} not defined before use", inst.name);
                }
                v.push(s);
            }
            ops.push(v);
        }
        let mut uses: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, o) in ops.iter().enumerate() {
            for &s in o {
                uses[s].push(i);
            }
        }

        // -- outputs: a root tuple is decomposed into its operand slots so
        // the tuple itself is never materialised --
        let root_inst = &comp.insts[comp.root];
        let root_is_tuple = root_inst.opcode == "tuple";
        let mut outputs = Vec::new();
        if root_is_tuple {
            let Shape::Tuple(part_shapes) = &root_inst.shape else {
                bail!("%{}: tuple root with non-tuple shape", root_inst.name);
            };
            if part_shapes.len() != ops[comp.root].len() {
                bail!(
                    "%{}: tuple shape arity {} != operand count {}",
                    root_inst.name,
                    part_shapes.len(),
                    ops[comp.root].len()
                );
            }
            for (&slot, sh) in ops[comp.root].iter().zip(part_shapes) {
                outputs.push(OutSpec { slot, shape: sh.clone() });
            }
        } else {
            outputs.push(OutSpec { slot: comp.root, shape: root_inst.shape.clone() });
        }
        let mut is_output = vec![false; n];
        for o in &outputs {
            is_output[o.slot] = true;
        }

        // -- prefill: parameters are borrowed, constants materialised once --
        let mut params = Vec::new();
        let mut consts = Vec::new();
        let mut prefilled = vec![false; n];
        for (i, inst) in comp.insts.iter().enumerate() {
            match inst.opcode.as_str() {
                "parameter" => {
                    let ai: usize = inst
                        .payload
                        .as_deref()
                        .unwrap_or("")
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("%{}: bad parameter payload", inst.name))?;
                    if ai >= comp.params.len() {
                        bail!("parameter({ai}) out of range");
                    }
                    params.push((i, ai, inst.shape.clone()));
                    prefilled[i] = true;
                }
                "constant" => {
                    let v = interp::constant_value(inst)
                        .with_context(|| format!("in %{} = constant(..)", inst.name))?;
                    consts.push((i, v));
                    prefilled[i] = true;
                }
                _ => {}
            }
        }

        // the root tuple instruction itself is skipped unless something
        // downstream consumes the tuple value
        let root_skipped = root_is_tuple && uses[comp.root].is_empty();

        let mut b = Builder {
            module,
            comp,
            ops,
            uses,
            is_output,
            prefilled,
            root_skipped,
            steps: Vec::new(),
            members: Vec::new(),
            run_map: HashMap::new(),
            run_od: Vec::new(),
        };
        b.scan()?;
        let mut steps = b.steps;

        // -- liveness: last step touching each slot; outputs pinned live --
        let mut last = vec![0usize; n];
        for (si, step) in steps.iter().enumerate() {
            match &step.kind {
                StepKind::Plain { out, operands, .. } => {
                    for &s in operands {
                        last[s] = si;
                    }
                    last[*out] = si;
                }
                StepKind::Fused(f) => {
                    for ld in &f.loads {
                        last[ld.slot] = si;
                    }
                    for st in &f.stores {
                        last[st.slot] = si;
                    }
                }
            }
        }
        for o in &outputs {
            last[o.slot] = usize::MAX;
        }
        for (s, &si) in last.iter().enumerate() {
            if si < steps.len() {
                steps[si].frees.push(s);
            }
        }

        Ok(Plan {
            n_args: comp.params.len(),
            n_slots: n,
            params,
            consts,
            steps,
            outputs,
            root_is_tuple,
        })
    }

    /// Number of steps the hot loop replays.
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Number of fused elementwise groups in the plan.
    pub fn n_fused_groups(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Fused(_)))
            .count()
    }
}

impl Builder<'_> {
    fn scan(&mut self) -> Result<()> {
        for i in 0..self.comp.insts.len() {
            if self.prefilled[i] {
                continue;
            }
            if self.root_skipped && i == self.comp.root {
                continue;
            }
            let inst = &self.comp.insts[i];
            if !self.members.is_empty() {
                let od = self.run_od.clone();
                if let Some(kind) = self.classify(i, &od, true) {
                    self.push_member(i, kind);
                    continue;
                }
                // Hoist-through: a step whose operands are disjoint from
                // the open run can execute *before* it, so emitting it now
                // does not flush the run. Keeps e.g. a scalar enable
                // `compare` mid QDQ-chain from splitting the chain's fused
                // group. Bigger fusable work (n > run's n) flushes instead
                // so it can seed its own run.
                let touches = self.ops[i].iter().any(|s| self.run_map.contains_key(s));
                let run_n: usize = self.run_od.iter().product();
                let standalone = match inst.shape.dims() {
                    Ok(d) => {
                        let d = d.to_vec();
                        self.classify(i, &d, false).is_some()
                    }
                    Err(_) => false,
                };
                if !touches && (!standalone || inst.shape.elems() <= run_n) {
                    let step = self.plain_step(i)?;
                    self.steps.push(step);
                    continue;
                }
                self.flush()?;
            }
            // run is empty here: seed a new one or emit a plain step
            let seeded = match inst.shape.dims() {
                Ok(d) => {
                    let d = d.to_vec();
                    match self.classify(i, &d, false) {
                        Some(kind) => {
                            self.run_od = d;
                            self.push_member(i, kind);
                            true
                        }
                        None => false,
                    }
                }
                Err(_) => false,
            };
            if !seeded {
                let step = self.plain_step(i)?;
                self.steps.push(step);
            }
        }
        self.flush()
    }

    fn push_member(&mut self, i: usize, kind: MKind) {
        let pred_out = matches!(
            self.comp.insts[i].shape,
            Shape::Array { dtype: DType::Pred, .. }
        );
        self.run_map.insert(i, self.members.len());
        self.members.push(Member { idx: i, pred_out, kind });
    }

    /// Can instruction `i` join a fused run with output dims `od`?
    /// `in_run` selects whether operands may reference current members.
    /// Conservative by design: anything not provably equivalent to the
    /// naive evaluation falls back to a plain step.
    fn classify(&self, i: usize, od: &[usize], in_run: bool) -> Option<MKind> {
        let inst = &self.comp.insts[i];
        let ops = &self.ops[i];
        let (odt, odims) = match &inst.shape {
            Shape::Array { dtype, dims } => (*dtype, dims),
            Shape::Tuple(_) => return None,
        };
        if odims[..] != *od {
            return None;
        }
        let f32_full = |s: usize| -> Option<ORef> {
            if in_run {
                if let Some(&mi) = self.run_map.get(&s) {
                    return (!self.members[mi].pred_out).then_some(ORef::Member(mi));
                }
            }
            let sh = &self.comp.insts[s].shape;
            (sh.dtype().ok()? == DType::F32 && sh.dims().ok()? == od)
                .then_some(ORef::Load(s, Pat::Full, false))
        };
        // HLO clamp allows scalar bounds (see `interp::at_f32`)
        let f32_or_splat = |s: usize| -> Option<ORef> {
            if let Some(r) = f32_full(s) {
                return Some(r);
            }
            let sh = &self.comp.insts[s].shape;
            (sh.dtype().ok()? == DType::F32 && sh.elems() == 1)
                .then_some(ORef::Load(s, Pat::Splat, false))
        };
        let pred_in = |s: usize| -> Option<ORef> {
            if in_run {
                if let Some(&mi) = self.run_map.get(&s) {
                    return self.members[mi].pred_out.then_some(ORef::Member(mi));
                }
            }
            let sh = &self.comp.insts[s].shape;
            if sh.dtype().ok()? != DType::Pred {
                return None;
            }
            if sh.dims().ok()? == od {
                Some(ORef::Load(s, Pat::Full, true))
            } else if sh.elems() == 1 {
                Some(ORef::Load(s, Pat::Splat, true))
            } else {
                None
            }
        };
        match inst.opcode.as_str() {
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power"
                if odt == DType::F32 && ops.len() == 2 =>
            {
                Some(MKind::Bin(
                    BinOp::parse(&inst.opcode)?,
                    f32_full(ops[0])?,
                    f32_full(ops[1])?,
                ))
            }
            "exp" | "exponential" | "tanh" | "logistic" | "rsqrt" | "sqrt" | "log" | "negate"
            | "abs" | "floor" | "ceil" | "round-nearest-afz"
                if odt == DType::F32 && ops.len() == 1 =>
            {
                Some(MKind::Un(UnOp::parse(&inst.opcode)?, f32_full(ops[0])?))
            }
            "clamp" if odt == DType::F32 && ops.len() == 3 => Some(MKind::Clamp(
                f32_or_splat(ops[0])?,
                f32_full(ops[1])?,
                f32_or_splat(ops[2])?,
            )),
            "select" if odt == DType::F32 && ops.len() == 3 => Some(MKind::Sel(
                pred_in(ops[0])?,
                f32_full(ops[1])?,
                f32_full(ops[2])?,
            )),
            "compare" if odt == DType::Pred && ops.len() == 2 => {
                let dir = CmpDir::parse(inst.attrs.get("direction")?.trim())?;
                Some(MKind::Cmp(dir, f32_full(ops[0])?, f32_full(ops[1])?))
            }
            "broadcast" if ops.len() == 1 => {
                let s = ops[0];
                if in_run && self.run_map.contains_key(&s) {
                    return None;
                }
                let sh = &self.comp.insts[s].shape;
                let idims = sh.dims().ok()?;
                let idt = sh.dtype().ok()?;
                if idt != odt || !matches!(odt, DType::F32 | DType::Pred) {
                    return None;
                }
                let map = inst.attr_dims_or("dimensions", &[]).ok()?;
                if map.len() != idims.len() {
                    return None;
                }
                for (k, &d) in map.iter().enumerate() {
                    if d >= od.len() || od[d] != idims[k] {
                        return None;
                    }
                }
                let n_in: usize = idims.iter().product();
                let identity = map.iter().enumerate().all(|(k, &d)| d == k);
                let pat = if n_in == 1 {
                    Pat::Splat
                } else if map.len() == 1 && !od.is_empty() && map[0] == od.len() - 1 {
                    Pat::Mod(od[od.len() - 1])
                } else if identity && map.len() + 1 == od.len() {
                    Pat::Div(od[od.len() - 1])
                } else if identity && map.len() == od.len() {
                    Pat::Full
                } else {
                    return None;
                };
                Some(MKind::Bcast(ORef::Load(s, pat, odt == DType::Pred)))
            }
            _ => None,
        }
    }

    /// Close the open run: a single member becomes a plain step, two or
    /// more become one fused group.
    fn flush(&mut self) -> Result<()> {
        if self.members.is_empty() {
            return Ok(());
        }
        let members = std::mem::take(&mut self.members);
        self.run_map.clear();
        if members.len() == 1 {
            let step = self.plain_step(members[0].idx)?;
            self.steps.push(step);
            return Ok(());
        }
        let n: usize = self.run_od.iter().product();
        let mut loads: Vec<Load> = Vec::new();
        let mut nodes: Vec<Node> = Vec::new();
        let mut load_ix: HashMap<(usize, Pat, bool), usize> = HashMap::new();
        let mut reg_of: Vec<usize> = Vec::with_capacity(members.len());
        fn reg(
            r: ORef,
            reg_of: &[usize],
            loads: &mut Vec<Load>,
            nodes: &mut Vec<Node>,
            load_ix: &mut HashMap<(usize, Pat, bool), usize>,
        ) -> usize {
            match r {
                ORef::Member(mi) => reg_of[mi],
                ORef::Load(slot, pat, pred) => {
                    *load_ix.entry((slot, pat, pred)).or_insert_with(|| {
                        loads.push(Load { slot, pat, pred });
                        nodes.push(Node::Load(loads.len() - 1));
                        nodes.len() - 1
                    })
                }
            }
        }
        for m in &members {
            let node = match m.kind {
                MKind::Bin(op, a, b) => {
                    let ra = reg(a, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rb = reg(b, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    nodes.push(Node::Bin(op, ra, rb));
                    nodes.len() - 1
                }
                MKind::Un(op, x) => {
                    let rx = reg(x, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    nodes.push(Node::Un(op, rx));
                    nodes.len() - 1
                }
                MKind::Clamp(lo, x, hi) => {
                    let rl = reg(lo, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rx = reg(x, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rh = reg(hi, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    nodes.push(Node::Clamp(rl, rx, rh));
                    nodes.len() - 1
                }
                MKind::Sel(p, t, f) => {
                    let rp = reg(p, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rt = reg(t, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rf = reg(f, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    nodes.push(Node::Sel(rp, rt, rf));
                    nodes.len() - 1
                }
                MKind::Cmp(dir, a, b) => {
                    let ra = reg(a, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    let rb = reg(b, &reg_of, &mut loads, &mut nodes, &mut load_ix);
                    nodes.push(Node::Cmp(dir, ra, rb));
                    nodes.len() - 1
                }
                MKind::Bcast(l) => reg(l, &reg_of, &mut loads, &mut nodes, &mut load_ix),
            };
            reg_of.push(node);
        }
        // store only what is observable outside the group
        let in_group: std::collections::HashSet<usize> =
            members.iter().map(|m| m.idx).collect();
        let mut stores = Vec::new();
        for (mi, m) in members.iter().enumerate() {
            let external = self.is_output[m.idx]
                || self.uses[m.idx].iter().any(|u| !in_group.contains(u));
            if external {
                stores.push(Store {
                    node: reg_of[mi],
                    slot: m.idx,
                    dims: self.comp.insts[m.idx].shape.dims()?.to_vec(),
                    pred: m.pred_out,
                });
            }
        }
        self.steps.push(Step {
            name: self.comp.insts[members[0].idx].name.clone(),
            kind: StepKind::Fused(Fused { n, loads, nodes, stores }),
            frees: Vec::new(),
        });
        Ok(())
    }

    /// Lower one instruction to a non-fused step, parsing its attributes
    /// now so execution never touches the attr map.
    fn plain_step(&self, i: usize) -> Result<Step> {
        let inst = &self.comp.insts[i];
        let operands = self.ops[i].clone();
        let need = |k: usize| -> Result<()> {
            if operands.len() < k {
                bail!("%{}: missing operand {}", inst.name, operands.len());
            }
            Ok(())
        };
        let op = match inst.opcode.as_str() {
            "broadcast" => {
                need(1)?;
                OpStep::Broadcast {
                    dims: inst.shape.dims()?.to_vec(),
                    map: inst.attr_dims_or("dimensions", &[])?,
                }
            }
            "reshape" => {
                need(1)?;
                let dims = inst.shape.dims()?.to_vec();
                let want = dims.iter().product();
                OpStep::Reshape { dims, want }
            }
            "transpose" => {
                need(1)?;
                OpStep::Transpose { perm: inst.attr_dims("dimensions")? }
            }
            "slice" => {
                need(1)?;
                OpStep::Slice { ranges: parse_slice_ranges(inst.attr_str("slice")?)? }
            }
            "concatenate" => {
                let dim = *inst
                    .attr_dims("dimensions")?
                    .first()
                    .ok_or_else(|| anyhow!("concatenate without dimension"))?;
                OpStep::Concat { dim }
            }
            "dot" | "dot-general" => {
                need(2)?;
                OpStep::Dot {
                    lb: inst.attr_dims_or("lhs_batch_dims", &[])?,
                    rb: inst.attr_dims_or("rhs_batch_dims", &[])?,
                    lc: inst.attr_dims_or("lhs_contracting_dims", &[])?,
                    rc: inst.attr_dims_or("rhs_contracting_dims", &[])?,
                }
            }
            "add" | "subtract" | "multiply" | "divide" | "maximum" | "minimum" | "power" => {
                need(2)?;
                OpStep::Binary { op: inst.opcode.clone() }
            }
            "exp" | "exponential" | "tanh" | "logistic" | "rsqrt" | "sqrt" | "log" | "negate"
            | "abs" | "floor" | "ceil" | "round-nearest-afz" => {
                need(1)?;
                OpStep::Unary { op: inst.opcode.clone() }
            }
            "clamp" => {
                need(3)?;
                OpStep::Clamp
            }
            "select" => {
                need(3)?;
                OpStep::Select
            }
            "compare" => {
                need(2)?;
                OpStep::Compare { dir: inst.attr_str("direction")?.to_string() }
            }
            "convert" => {
                need(1)?;
                OpStep::Convert { to: inst.shape.dtype()? }
            }
            "iota" => OpStep::Iota {
                dims: inst.shape.dims()?.to_vec(),
                along: inst.attr_usize("iota_dimension")?,
                dtype: inst.shape.dtype()?,
            },
            "reduce" => {
                need(2)?;
                let apply = inst.attr_str("to_apply")?.trim_start_matches('%');
                OpStep::Reduce {
                    rdims: inst.attr_dims("dimensions")?,
                    comb: interp::combinator_of(self.module, apply)?,
                }
            }
            "tuple" => OpStep::Tuple,
            "get-tuple-element" => {
                need(1)?;
                OpStep::Gte { index: inst.attr_usize("index")? }
            }
            "gather" => {
                need(2)?;
                OpStep::Gather { spec: GatherSpec::from_inst(inst)? }
            }
            other => OpStep::Unsupported { opcode: other.to_string() },
        };
        Ok(Step {
            name: inst.name.clone(),
            kind: StepKind::Plain { out: i, shape: inst.shape.clone(), operands, op },
            frees: Vec::new(),
        })
    }
}

// ---------------------------------------------------------------------------
// execution
// ---------------------------------------------------------------------------

impl Plan {
    /// Execute the plan on borrowed inputs. Parameter tensors are never
    /// cloned into the env — the naive engine's per-execution clone in
    /// its `parameter` arm is the single biggest per-item cost
    /// `run_batch` pays for shared static weights.
    pub fn execute(&self, inputs: &[&Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.n_args {
            bail!(
                "plan: {} arguments given, wants {}",
                inputs.len(),
                self.n_args
            );
        }
        let mut env: Vec<Slot> = (0..self.n_slots).map(|_| Slot::Empty).collect();
        for (slot, ai, shape) in &self.params {
            let v = inputs[*ai];
            if v.len() != shape.elems() {
                bail!(
                    "parameter({ai}): argument has {} elements, shape wants {}",
                    v.len(),
                    shape.elems()
                );
            }
            check_shape(shape, v).with_context(|| format!("parameter({ai})"))?;
            env[*slot] = Slot::Ref(v);
        }
        for (slot, v) in &self.consts {
            env[*slot] = Slot::Ref(v);
        }
        for step in &self.steps {
            run_step(step, &mut env).with_context(|| format!("in %{}", step.name))?;
        }
        // outputs: take owned values out of the env, cloning only when a
        // slot repeats or is still borrowed
        let mut res: Vec<Value> = Vec::with_capacity(self.outputs.len());
        for (k, o) in self.outputs.iter().enumerate() {
            let repeats_later = self.outputs[k + 1..].iter().any(|o2| o2.slot == o.slot);
            let v = if repeats_later {
                env[o.slot]
                    .get()
                    .cloned()
                    .ok_or_else(|| anyhow!("output {k}: slot not evaluated"))?
            } else {
                match std::mem::replace(&mut env[o.slot], Slot::Empty) {
                    Slot::Own(v) => v,
                    Slot::Ref(v) => v.clone(),
                    Slot::Empty => bail!("output {k}: slot not evaluated"),
                }
            };
            // proven statically at build time (verify); debug-only re-check
            if cfg!(debug_assertions) {
                check_shape(&o.shape, &v).with_context(|| format!("output {k}"))?;
            }
            res.push(v);
        }
        if self.root_is_tuple {
            Ok(res)
        } else {
            // mirror `interpret_refs`: a non-tuple root that still
            // evaluates to a tuple value is flattened
            match res.pop() {
                Some(Value::Tuple(parts)) => Ok(parts),
                Some(v) => Ok(vec![v]),
                None => Ok(Vec::new()),
            }
        }
    }
}

fn run_step<'a>(step: &'a Step, env: &mut [Slot<'a>]) -> Result<()> {
    match &step.kind {
        StepKind::Plain { out, shape, operands, op } => {
            // reshape of a dying owned value is a metadata-only retag
            if let OpStep::Reshape { dims, want } = op {
                let a = operands[0];
                if step.frees.contains(&a) && matches!(env[a], Slot::Own(_)) {
                    let Slot::Own(v) = std::mem::replace(&mut env[a], Slot::Empty) else {
                        unreachable!()
                    };
                    if v.len() != *want {
                        bail!("reshape: {} elements cannot view as {dims:?}", v.len());
                    }
                    let v = interp::with_dims(v, dims.clone());
                    if cfg!(debug_assertions) {
                        check_shape(shape, &v)?;
                    }
                    env[*out] = Slot::Own(v);
                    for &s in &step.frees {
                        env[s] = Slot::Empty;
                    }
                    return Ok(());
                }
            }
            let v = {
                let vals: Vec<&Value> = operands
                    .iter()
                    .map(|&s| {
                        env[s]
                            .get()
                            .ok_or_else(|| anyhow!("operand slot {s} not evaluated"))
                    })
                    .collect::<Result<_>>()?;
                eval_plain(op, &vals)?
            };
            if cfg!(debug_assertions) {
                check_shape(shape, &v)?;
            }
            env[*out] = Slot::Own(v);
            for &s in &step.frees {
                env[s] = Slot::Empty;
            }
            Ok(())
        }
        StepKind::Fused(f) => {
            let mut out_bufs: Vec<Vec<f32>> =
                f.stores.iter().map(|_| Vec::with_capacity(f.n)).collect();
            {
                let mut srcs: Vec<Src> = Vec::with_capacity(f.loads.len());
                for ld in &f.loads {
                    let v = env[ld.slot]
                        .get()
                        .ok_or_else(|| anyhow!("fused load: slot {} not evaluated", ld.slot))?;
                    let src = if ld.pred {
                        Src::Pred(v.preds()?)
                    } else {
                        Src::F32(v.f32s()?)
                    };
                    let len = match src {
                        Src::F32(s) => s.len(),
                        Src::Pred(s) => s.len(),
                    };
                    // length each pattern demands to cover indices 0..n
                    let short = f.n > 0
                        && match ld.pat {
                            Pat::Full => len < f.n,
                            Pat::Splat => len < 1,
                            Pat::Mod(m) => len < m,
                            Pat::Div(d) => len.saturating_mul(d) < f.n,
                        };
                    if short {
                        bail!(
                            "fused load of slot {}: operand has {len} elements (pattern {:?}, n {})",
                            ld.slot,
                            ld.pat,
                            f.n
                        );
                    }
                    srcs.push(src);
                }
                let mut regs = vec![0.0f32; f.nodes.len()];
                for i in 0..f.n {
                    for (j, node) in f.nodes.iter().enumerate() {
                        let v = match *node {
                            Node::Load(l) => {
                                let idx = match f.loads[l].pat {
                                    Pat::Full => i,
                                    Pat::Splat => 0,
                                    Pat::Mod(m) => i % m,
                                    Pat::Div(d) => i / d,
                                };
                                match srcs[l] {
                                    Src::F32(s) => s[idx],
                                    Src::Pred(s) => {
                                        if s[idx] {
                                            1.0
                                        } else {
                                            0.0
                                        }
                                    }
                                }
                            }
                            Node::Bin(op, a, b) => op.f32(regs[a], regs[b]),
                            Node::Un(op, x) => op.f32(regs[x]),
                            Node::Clamp(lo, x, hi) => regs[x].max(regs[lo]).min(regs[hi]),
                            Node::Cmp(dir, a, b) => {
                                if cmp_f32(dir, regs[a], regs[b]) {
                                    1.0
                                } else {
                                    0.0
                                }
                            }
                            Node::Sel(p, t, fl) => {
                                if regs[p] != 0.0 {
                                    regs[t]
                                } else {
                                    regs[fl]
                                }
                            }
                        };
                        regs[j] = v;
                    }
                    for (buf, st) in out_bufs.iter_mut().zip(&f.stores) {
                        buf.push(regs[st.node]);
                    }
                }
            }
            for (st, buf) in f.stores.iter().zip(out_bufs) {
                let v = if st.pred {
                    Value::Pred {
                        dims: st.dims.clone(),
                        data: buf.iter().map(|&x| x != 0.0).collect(),
                    }
                } else {
                    Value::F32 { dims: st.dims.clone(), data: buf }
                };
                env[st.slot] = Slot::Own(v);
            }
            for &s in &step.frees {
                env[s] = Slot::Empty;
            }
            Ok(())
        }
    }
}

fn eval_plain(op: &OpStep, vals: &[&Value]) -> Result<Value> {
    match op {
        OpStep::Broadcast { dims, map } => interp::broadcast_value(vals[0], dims, map),
        OpStep::Reshape { dims, want } => {
            if vals[0].len() != *want {
                bail!("reshape: {} elements cannot view as {dims:?}", vals[0].len());
            }
            Ok(interp::with_dims(vals[0].clone(), dims.clone()))
        }
        OpStep::Transpose { perm } => interp::transpose_value(vals[0], perm),
        OpStep::Slice { ranges } => interp::slice_value(vals[0], ranges),
        OpStep::Concat { dim } => interp::concat_values(vals, *dim),
        OpStep::Dot { lb, rb, lc, rc } => {
            interp::dot_general_fast(vals[0], vals[1], lb, rb, lc, rc)
        }
        OpStep::Binary { op } => interp::binary(op, vals[0], vals[1]),
        OpStep::Unary { op } => interp::unary(op, vals[0]),
        OpStep::Clamp => interp::clamp_value(vals[0], vals[1], vals[2]),
        OpStep::Select => interp::select_value(vals[0], vals[1], vals[2]),
        OpStep::Compare { dir } => interp::compare_value(dir, vals[0], vals[1]),
        OpStep::Convert { to } => interp::convert_value(vals[0], *to),
        OpStep::Iota { dims, along, dtype } => interp::iota_value(dims, *along, *dtype),
        OpStep::Reduce { rdims, comb } => interp::reduce_value(vals[0], vals[1], rdims, *comb),
        OpStep::Tuple => Ok(Value::Tuple(vals.iter().map(|&v| v.clone()).collect())),
        OpStep::Gte { index } => match vals[0] {
            Value::Tuple(parts) => parts
                .get(*index)
                .cloned()
                .ok_or_else(|| anyhow!("tuple index {index} out of range")),
            _ => bail!("get-tuple-element on non-tuple"),
        },
        OpStep::Gather { spec } => interp::gather_value(spec, vals[0], vals[1]),
        OpStep::Unsupported { opcode } => bail!("unsupported opcode {opcode:?}"),
    }
}

/// Bitwise output comparison: f32 lanes via `to_bits`, so NaN payloads
/// and signed zeros count too. Test-only, shared with `interp`'s golden
/// suite so every golden doubles as a plan-vs-naive identity check.
#[cfg(test)]
pub(crate) fn assert_bits_eq(a: &[Value], b: &[Value]) {
    assert_eq!(a.len(), b.len(), "output arity differs");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        bits_eq_one(x, y, &format!("output {k}"));
    }
}

#[cfg(test)]
fn bits_eq_one(x: &Value, y: &Value, at: &str) {
    assert_eq!(x.dims(), y.dims(), "{at}: dims differ");
    match (x, y) {
        (Value::F32 { data: a, .. }, Value::F32 { data: b, .. }) => {
            assert_eq!(a.len(), b.len(), "{at}: length differs");
            for (i, (u, v)) in a.iter().zip(b).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{at}[{i}]: {u} vs {v} differ bitwise");
            }
        }
        (Value::S32 { data: a, .. }, Value::S32 { data: b, .. }) => {
            assert_eq!(a, b, "{at}: s32 differs")
        }
        (Value::Pred { data: a, .. }, Value::Pred { data: b, .. }) => {
            assert_eq!(a, b, "{at}: pred differs")
        }
        (Value::Tuple(a), Value::Tuple(b)) => {
            assert_eq!(a.len(), b.len(), "{at}: tuple arity differs");
            for (i, (u, v)) in a.iter().zip(b).enumerate() {
                bits_eq_one(u, v, &format!("{at}.{i}"));
            }
        }
        _ => panic!("{at}: dtype differs"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parse_module;

    fn module(params: &[&str], body: &[&str]) -> HloModule {
        let mut text = String::from("HloModule t\n\n");
        text.push_str(
            "%red_add (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %r = f32[] add(f32[] %a, f32[] %b)\n}\n\n",
        );
        text.push_str(
            "%red_max (a: f32[], b: f32[]) -> f32[] {\n  %a = f32[] parameter(0)\n  \
             %b = f32[] parameter(1)\n  ROOT %r = f32[] maximum(f32[] %a, f32[] %b)\n}\n\n",
        );
        text.push_str("ENTRY %main () -> f32[] {\n");
        for p in params {
            text.push_str("  ");
            text.push_str(p);
            text.push('\n');
        }
        for b in body {
            text.push_str("  ");
            text.push_str(b);
            text.push('\n');
        }
        text.push_str("}\n");
        parse_module(&text).unwrap()
    }

    /// Run both engines and demand agreement: same outputs (bitwise) when
    /// the naive engine succeeds, an error from the planned side too when
    /// it fails. Returns the naive result either way.
    fn run_both(params: &[&str], body: &[&str], inputs: &[Value]) -> Result<Vec<Value>> {
        let m = module(params, body);
        let naive = crate::hlo::interpret(&m, inputs);
        let plan = match Plan::build(&m) {
            Ok(p) => p,
            Err(e) => {
                assert!(
                    naive.is_err(),
                    "plan build failed but naive engine ran: {e:#}"
                );
                return naive;
            }
        };
        let refs: Vec<&Value> = inputs.iter().collect();
        match (naive, plan.execute(&refs)) {
            (Ok(a), Ok(b)) => {
                assert_bits_eq(&a, &b);
                Ok(a)
            }
            (Err(e), Err(_)) => Err(e),
            (Ok(_), Err(e)) => panic!("planned engine failed where naive succeeded: {e:#}"),
            (Err(e), Ok(_)) => panic!("planned engine succeeded where naive failed: {e:#}"),
        }
    }

    fn f32v(dims: &[usize], data: &[f32]) -> Value {
        Value::F32 { dims: dims.to_vec(), data: data.to_vec() }
    }

    fn s32v(dims: &[usize], data: &[i32]) -> Value {
        Value::S32 { dims: dims.to_vec(), data: data.to_vec() }
    }

    /// Deterministic pseudo-random f32s (no RNG dependency).
    fn lcg(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) * 4.0 - 2.0
            })
            .collect()
    }

    #[test]
    fn qdq_chain_fuses_into_one_group_with_hoisted_compare() {
        // A fake-quant site: divide -> round -> clamp -> multiply with a
        // scalar enable compare hoisted through the run and a pred splat
        // broadcast feeding the final select. The whole chain must be ONE
        // fused group plus the hoisted scalar compare.
        let params = &["%x = f32[64] parameter(0)"];
        let body = &[
            "%s = f32[] constant(0.05)",
            "%sb = f32[64] broadcast(f32[] %s), dimensions={}",
            "%d = f32[64] divide(f32[64] %x, f32[64] %sb)",
            "%r = f32[64] round-nearest-afz(f32[64] %d)",
            "%lo = f32[] constant(-128)",
            "%hi = f32[] constant(127)",
            "%c = f32[64] clamp(f32[] %lo, f32[64] %r, f32[] %hi)",
            "%q = f32[64] multiply(f32[64] %c, f32[64] %sb)",
            "%thr = f32[] constant(0)",
            "%en = pred[] compare(f32[] %s, f32[] %thr), direction=GT",
            "%enb = pred[64] broadcast(pred[] %en), dimensions={}",
            "ROOT %out = f32[64] select(pred[64] %enb, f32[64] %q, f32[64] %x)",
        ];
        let x = f32v(&[64], &lcg(64, 7));
        let m = module(params, body);
        let plan = Plan::build(&m).unwrap();
        assert_eq!(plan.n_fused_groups(), 1, "QDQ chain should be one fused group");
        // fused group + hoisted scalar compare = 2 steps
        assert_eq!(plan.n_steps(), 2, "expected [hoisted compare, fused group]");
        run_both(params, body, &[x]).unwrap();
    }

    #[test]
    fn broadcast_patterns_match_naive() {
        // row (Mod), column (Div), splat, and a non-fusable middle-dims
        // map that must fall back to a plain step — all bit-identical.
        run_both(
            &["%r = f32[3] parameter(0)", "%x = f32[2,3] parameter(1)"],
            &[
                "%b = f32[2,3] broadcast(f32[3] %r), dimensions={1}",
                "ROOT %o = f32[2,3] add(f32[2,3] %x, f32[2,3] %b)",
            ],
            &[f32v(&[3], &lcg(3, 1)), f32v(&[2, 3], &lcg(6, 2))],
        )
        .unwrap();
        run_both(
            &["%c = f32[2] parameter(0)", "%x = f32[2,3] parameter(1)"],
            &[
                "%b = f32[2,3] broadcast(f32[2] %c), dimensions={0}",
                "ROOT %o = f32[2,3] multiply(f32[2,3] %x, f32[2,3] %b)",
            ],
            &[f32v(&[2], &lcg(2, 3)), f32v(&[2, 3], &lcg(6, 4))],
        )
        .unwrap();
        run_both(
            &["%s = f32[] parameter(0)", "%x = f32[2,3] parameter(1)"],
            &[
                "%b = f32[2,3] broadcast(f32[] %s), dimensions={}",
                "ROOT %o = f32[2,3] subtract(f32[2,3] %x, f32[2,3] %b)",
            ],
            &[f32v(&[], &[0.5]), f32v(&[2, 3], &lcg(6, 5))],
        )
        .unwrap();
        run_both(
            &["%m = f32[3,4] parameter(0)", "%x = f32[2,3,4] parameter(1)"],
            &[
                "%b = f32[2,3,4] broadcast(f32[3,4] %m), dimensions={1,2}",
                "ROOT %o = f32[2,3,4] add(f32[2,3,4] %x, f32[2,3,4] %b)",
            ],
            &[f32v(&[3, 4], &lcg(12, 6)), f32v(&[2, 3, 4], &lcg(24, 7))],
        )
        .unwrap();
    }

    #[test]
    fn fused_intermediate_consumed_outside_group_is_stored() {
        // %a is consumed both inside the fused run (by %b) and by the
        // root tuple — the store-externality rule must materialise it.
        let out = run_both(
            &["%x = f32[8] parameter(0)"],
            &[
                "%a = f32[8] exp(f32[8] %x)",
                "%b = f32[8] add(f32[8] %a, f32[8] %x)",
                "ROOT %t = (f32[8], f32[8]) tuple(f32[8] %a, f32[8] %b)",
            ],
            &[f32v(&[8], &lcg(8, 11))],
        )
        .unwrap();
        assert_eq!(out.len(), 2);
        let a = out[0].f32s().unwrap();
        let b = out[1].f32s().unwrap();
        for i in 0..8 {
            assert!((a[i] - b[i]).abs() > 0.0 || a[i] == b[i]);
        }
    }

    #[test]
    fn dot_fast_paths_bit_identical_to_naive_kernel() {
        // ikj streaming (Case A), contiguous-slices (Case B) and the
        // generic layout all agree bitwise with the naive kernel.
        let a = Value::F32 { dims: vec![4, 9], data: lcg(36, 21) };
        let b_kn = Value::F32 { dims: vec![9, 5], data: lcg(45, 22) };
        let b_nk = Value::F32 { dims: vec![5, 9], data: lcg(45, 23) };
        // Case A: lhs [M,K] x rhs [K,N], contracting {1}x{0}
        let naive = interp::dot_general(&a, &b_kn, &[], &[], &[1], &[0]).unwrap();
        let fast = interp::dot_general_fast(&a, &b_kn, &[], &[], &[1], &[0]).unwrap();
        assert_bits_eq(&[naive], &[fast]);
        // Case B: lhs [M,K] x rhs [N,K], contracting {1}x{1}
        let naive = interp::dot_general(&a, &b_nk, &[], &[], &[1], &[1]).unwrap();
        let fast = interp::dot_general_fast(&a, &b_nk, &[], &[], &[1], &[1]).unwrap();
        assert_bits_eq(&[naive], &[fast]);
        // generic: transposed lhs [K,M], contracting {0}x{0}
        let at = Value::F32 { dims: vec![9, 4], data: lcg(36, 24) };
        let naive = interp::dot_general(&at, &b_kn, &[], &[], &[0], &[0]).unwrap();
        let fast = interp::dot_general_fast(&at, &b_kn, &[], &[], &[0], &[0]).unwrap();
        assert_bits_eq(&[naive], &[fast]);
        // batched Case A: [B,M,K] x [B,K,N]
        let ab = Value::F32 { dims: vec![2, 3, 7], data: lcg(42, 25) };
        let bb = Value::F32 { dims: vec![2, 7, 4], data: lcg(56, 26) };
        let naive = interp::dot_general(&ab, &bb, &[0], &[0], &[2], &[1]).unwrap();
        let fast = interp::dot_general_fast(&ab, &bb, &[0], &[0], &[2], &[1]).unwrap();
        assert_bits_eq(&[naive], &[fast]);
        // degenerate K=1 (fixed_stride returns None -> generic path)
        let a1 = Value::F32 { dims: vec![3, 1], data: lcg(3, 27) };
        let b1 = Value::F32 { dims: vec![1, 2], data: lcg(2, 28) };
        let naive = interp::dot_general(&a1, &b1, &[], &[], &[1], &[0]).unwrap();
        let fast = interp::dot_general_fast(&a1, &b1, &[], &[], &[1], &[0]).unwrap();
        assert_bits_eq(&[naive], &[fast]);
    }

    #[test]
    fn logistic_matches_naive_incl_extremes() {
        // the gated-attention sigmoid: both engines share the stable
        // two-branch kernel, so agreement must be bitwise — including the
        // saturating tails, signed zero, NaN and ±inf
        let params = &["%x = f32[10] parameter(0)"];
        let body = &["ROOT %g = f32[10] logistic(f32[10] %x)"];
        let x = [
            f32::NEG_INFINITY,
            -100.0,
            -1.0,
            -0.0,
            0.0,
            1.0,
            100.0,
            f32::INFINITY,
            f32::NAN,
            0.5,
        ];
        let out = run_both(params, body, &[f32v(&[10], &x)]).unwrap();
        let g = out[0].f32s().unwrap();
        assert_eq!(g[0], 0.0, "logistic(-inf)");
        assert_eq!(g[7], 1.0, "logistic(+inf)");
        assert!(g[8].is_nan(), "logistic(NaN)");
        assert_eq!(g[3], 0.5, "logistic(0)");
        assert_eq!(g[4], 0.5);
        // strictly inside (0,1) and monotone on the finite ramp
        assert!(g[1] > 0.0 && g[1] < g[2] && g[2] < g[3] && g[5] < g[6] && g[6] <= 1.0);
        // random sweep through the fused-kernel path too
        let params = &["%x = f32[64] parameter(0)", "%y = f32[64] parameter(1)"];
        let body = &[
            "%g = f32[64] logistic(f32[64] %x)",
            "ROOT %o = f32[64] multiply(f32[64] %g, f32[64] %y)",
        ];
        run_both(params, body, &[f32v(&[64], &lcg(64, 31)), f32v(&[64], &lcg(64, 32))])
            .unwrap();
    }

    #[test]
    fn clipped_softmax_clamp_fragment_matches_naive() {
        // the clipped-softmax epilogue exactly as the fixture lowers it:
        // clamp(0, (zeta-gamma)*p + gamma, 1) with zeta=1.003,
        // gamma=-0.003 — probabilities below ~0.003/1.006 clip to exactly
        // 0, above ~1.003/1.006 to exactly 1
        let params = &["%p = f32[8] parameter(0)"];
        let body = &[
            "%sc = f32[] constant(1.006)",
            "%scb = f32[8] broadcast(f32[] %sc), dimensions={}",
            "%m = f32[8] multiply(f32[8] %p, f32[8] %scb)",
            "%ga = f32[] constant(-0.003)",
            "%gab = f32[8] broadcast(f32[] %ga), dimensions={}",
            "%sh = f32[8] add(f32[8] %m, f32[8] %gab)",
            "%lo = f32[] constant(0)",
            "%hi = f32[] constant(1)",
            "ROOT %c = f32[8] clamp(f32[] %lo, f32[8] %sh, f32[] %hi)",
        ];
        let p = [0.0, 0.001, 0.01, 0.5, 0.99, 0.999, 1.0, 0.25];
        let out = run_both(params, body, &[f32v(&[8], &p)]).unwrap();
        let c = out[0].f32s().unwrap();
        assert_eq!(c[0], 0.0, "p=0 clips to exactly 0");
        assert_eq!(c[1], 0.0, "p below gamma crossover clips to 0");
        assert_eq!(c[6], 1.0, "p=1 clips to exactly 1");
        assert_eq!(c[5], 1.0, "p above zeta crossover clips to 1");
        assert!(c[3] > 0.0 && c[3] < 1.0, "mid prob stays strict interior");
        for (i, v) in c.iter().enumerate() {
            assert!((0.0..=1.0).contains(v), "clamp range violated at {i}: {v}");
        }
        // NaN / ±inf through the same clamp path: the engines must agree
        // bitwise on whatever the propagation semantics produce
        let weird = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -1.0, 2.0, 0.5, -0.0, 1.0];
        let out = run_both(params, body, &[f32v(&[8], &weird)]).unwrap();
        let c = out[0].f32s().unwrap();
        assert_eq!(c[1], 1.0, "+inf clips to 1");
        assert_eq!(c[2], 0.0, "-inf clips to 0");
        assert_eq!(c[3], 0.0, "below-range input clips to 0");
        assert_eq!(c[4], 1.0, "above-range input clips to 1");
    }

    #[test]
    fn dot_inside_plan_matches_naive_end_to_end() {
        run_both(
            &["%a = f32[4,9] parameter(0)", "%b = f32[9,5] parameter(1)"],
            &[
                "%d = f32[4,5] dot(f32[4,9] %a, f32[9,5] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}",
                "ROOT %o = f32[4,5] tanh(f32[4,5] %d)",
            ],
            &[
                Value::F32 { dims: vec![4, 9], data: lcg(36, 31) },
                Value::F32 { dims: vec![9, 5], data: lcg(45, 32) },
            ],
        )
        .unwrap();
    }

    #[test]
    fn nan_compare_directions_fused_and_plain() {
        // NaN makes every direction false except NE (XLA float compare);
        // pred outputs of a fused group are stored exactly.
        let x = f32v(&[4], &[1.0, f32::NAN, 3.0, f32::NAN]);
        let y = f32v(&[4], &[1.0, 2.0, f32::NAN, f32::NAN]);
        let out = run_both(
            &["%x = f32[4] parameter(0)", "%y = f32[4] parameter(1)"],
            &[
                "%eq = pred[4] compare(f32[4] %x, f32[4] %y), direction=EQ",
                "%ne = pred[4] compare(f32[4] %x, f32[4] %y), direction=NE",
                "%lt = pred[4] compare(f32[4] %x, f32[4] %y), direction=LT",
                "%ge = pred[4] compare(f32[4] %x, f32[4] %y), direction=GE",
                "ROOT %t = (pred[4], pred[4], pred[4], pred[4]) tuple(pred[4] %eq, pred[4] %ne, pred[4] %lt, pred[4] %ge)",
            ],
            &[x, y],
        )
        .unwrap();
        assert_eq!(out[0].preds().unwrap(), &[true, false, false, false]);
        assert_eq!(out[1].preds().unwrap(), &[false, true, true, true]);
        assert_eq!(out[2].preds().unwrap(), &[false, false, false, false]);
        assert_eq!(out[3].preds().unwrap(), &[true, false, false, false]);
    }

    #[test]
    fn nan_propagates_through_select_and_clamp() {
        let x = f32v(&[4], &[f32::NAN, -5.0, 0.5, 9.0]);
        let out = run_both(
            &["%x = f32[4] parameter(0)"],
            &[
                "%lo = f32[] constant(-1)",
                "%hi = f32[] constant(1)",
                "%c = f32[4] clamp(f32[] %lo, f32[4] %x, f32[] %hi)",
                "%z = f32[] constant(0)",
                "%zb = f32[4] broadcast(f32[] %z), dimensions={}",
                "%p = pred[4] compare(f32[4] %x, f32[4] %zb), direction=GT",
                "ROOT %s = f32[4] select(pred[4] %p, f32[4] %x, f32[4] %c)",
            ],
            &[x],
        )
        .unwrap();
        let got = out[0].f32s().unwrap();
        // NaN > 0 is false -> select picks the clamped branch; clamp of
        // NaN under max/min keeps the bound chain's result.
        assert_eq!(got[1], -1.0);
        assert_eq!(got[2], 0.5);
        assert_eq!(got[3], 1.0);
    }

    #[test]
    fn s32_ops_stay_plain_and_divide_errors_are_loud() {
        let out = run_both(
            &["%a = s32[3] parameter(0)", "%b = s32[3] parameter(1)"],
            &["ROOT %d = s32[3] divide(s32[3] %a, s32[3] %b)"],
            &[s32v(&[3], &[9, -8, 7]), s32v(&[3], &[3, 2, -1])],
        )
        .unwrap();
        assert_eq!(out[0].i32s().unwrap(), &[3, -4, -7]);
        // division by zero: an error from BOTH engines, not an abort
        let err = run_both(
            &["%a = s32[1] parameter(0)", "%b = s32[1] parameter(1)"],
            &["ROOT %d = s32[1] divide(s32[1] %a, s32[1] %b)"],
            &[s32v(&[1], &[5]), s32v(&[1], &[0])],
        );
        assert!(err.is_err());
        // i32::MIN / -1 overflows: also an error, not an abort
        let err = run_both(
            &["%a = s32[1] parameter(0)", "%b = s32[1] parameter(1)"],
            &["ROOT %d = s32[1] divide(s32[1] %a, s32[1] %b)"],
            &[s32v(&[1], &[i32::MIN]), s32v(&[1], &[-1])],
        );
        assert!(err.is_err());
    }

    #[test]
    fn softmax_composed_matches_naive_with_nan_row() {
        // softmax(x) over the last axis built from primitives, with one
        // row poisoned by NaN: both engines must agree bitwise and the
        // poisoned row must come out all-NaN (0/0 at the divide).
        let params = &["%x = f32[2,4] parameter(0)"];
        let body = &[
            "%init_max = f32[] constant(-3.402823e38)",
            "%mx = f32[2] reduce(f32[2,4] %x, f32[] %init_max), dimensions={1}, to_apply=%red_max",
            "%mxb = f32[2,4] broadcast(f32[2] %mx), dimensions={0}",
            "%sh = f32[2,4] subtract(f32[2,4] %x, f32[2,4] %mxb)",
            "%e = f32[2,4] exp(f32[2,4] %sh)",
            "%zero = f32[] constant(0)",
            "%sum = f32[2] reduce(f32[2,4] %e, f32[] %zero), dimensions={1}, to_apply=%red_add",
            "%sumb = f32[2,4] broadcast(f32[2] %sum), dimensions={0}",
            "ROOT %sm = f32[2,4] divide(f32[2,4] %e, f32[2,4] %sumb)",
        ];
        let clean = f32v(&[2, 4], &[0.1, 0.2, 0.3, 0.4, 1.0, 2.0, 3.0, 4.0]);
        let out = run_both(params, body, &[clean]).unwrap();
        let sm = out[0].f32s().unwrap();
        let s0: f32 = sm[..4].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-5);

        let poisoned = f32v(&[2, 4], &[0.1, f32::NAN, 0.3, 0.4, 1.0, 2.0, 3.0, 4.0]);
        let out = run_both(params, body, &[poisoned]).unwrap();
        let sm = out[0].f32s().unwrap();
        assert!(sm[..4].iter().all(|v| v.is_nan()), "poisoned row must be all-NaN");
        assert!(sm[4..].iter().all(|v| !v.is_nan()), "clean row stays finite");
    }

    #[test]
    fn plain_ops_roundtrip_through_plan() {
        run_both(
            &["%x = f32[2,3] parameter(0)"],
            &[
                "%t = f32[3,2] transpose(f32[2,3] %x), dimensions={1,0}",
                "%r = f32[6] reshape(f32[3,2] %t)",
                "%s = f32[3] slice(f32[6] %r), slice={[0:6:2]}",
                "%c = f32[9] concatenate(f32[6] %r, f32[3] %s), dimensions={0}",
                "ROOT %o = f32[9] negate(f32[9] %c)",
            ],
            &[f32v(&[2, 3], &lcg(6, 41))],
        )
        .unwrap();
        run_both(
            &[],
            &[
                "%i = s32[2,3] iota(), iota_dimension=1",
                "ROOT %f = f32[2,3] convert(s32[2,3] %i)",
            ],
            &[],
        )
        .unwrap();
    }

    #[test]
    fn gather_through_plan_matches_naive() {
        run_both(
            &["%tbl = f32[5,3] parameter(0)", "%ids = s32[2] parameter(1)"],
            &[
                "ROOT %g = f32[2,3] gather(f32[5,3] %tbl, s32[2] %ids), \
                 offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, \
                 index_vector_dim=1, slice_sizes={1,3}",
            ],
            &[f32v(&[5, 3], &lcg(15, 51)), s32v(&[2], &[4, 1])],
        )
        .unwrap();
    }

    #[test]
    fn malformed_root_tuple_element_rejected_by_both() {
        // declared tuple element dims disagree with the computed value:
        // both engines must fail loudly.
        let err = run_both(
            &["%x = f32[4] parameter(0)"],
            &[
                "%a = f32[4] exp(f32[4] %x)",
                "ROOT %t = (f32[2]) tuple(f32[4] %a)",
            ],
            &[f32v(&[4], &lcg(4, 61))],
        );
        assert!(err.is_err(), "mis-declared tuple element must be rejected");
    }

    #[test]
    fn reshape_retags_in_place_and_borrowed_params_clone() {
        // reshape of a dying intermediate takes the in-place path;
        // reshape of a borrowed parameter must clone. Both bit-match.
        run_both(
            &["%x = f32[6] parameter(0)"],
            &[
                "%a = f32[6] add(f32[6] %x, f32[6] %x)",
                "%r = f32[2,3] reshape(f32[6] %a)",
                "%rx = f32[2,3] reshape(f32[6] %x)",
                "ROOT %o = f32[2,3] multiply(f32[2,3] %r, f32[2,3] %rx)",
            ],
            &[f32v(&[6], &lcg(6, 71))],
        )
        .unwrap();
    }

    #[test]
    fn non_tuple_root_and_param_passthrough() {
        // root is a plain op
        run_both(
            &["%x = f32[3] parameter(0)"],
            &["ROOT %o = f32[3] sqrt(f32[3] %x)"],
            &[f32v(&[3], &[4.0, 9.0, 16.0])],
        )
        .unwrap();
        // root is a parameter (prefilled slot as output)
        run_both(
            &["%x = f32[3] parameter(0)"],
            &["ROOT %o = f32[3] abs(f32[3] %x)"],
            &[f32v(&[3], &[-1.0, 2.0, -3.0])],
        )
        .unwrap();
    }

    #[test]
    fn liveness_frees_every_intermediate() {
        // every non-output step slot must appear in exactly one frees
        // list; outputs in none.
        let m = module(
            &["%x = f32[8] parameter(0)"],
            &[
                "%a = f32[8] exp(f32[8] %x)",
                "%s = f32[] constant(0)",
                "%sb = f32[8] broadcast(f32[] %s), dimensions={}",
                "%d = f32[2,4] reshape(f32[8] %a)",
                "%t = f32[4,2] transpose(f32[2,4] %d), dimensions={1,0}",
                "%r = f32[8] reshape(f32[4,2] %t)",
                "ROOT %o = f32[8] add(f32[8] %r, f32[8] %sb)",
            ],
        );
        let plan = Plan::build(&m).unwrap();
        let mut freed: Vec<usize> = plan.steps.iter().flat_map(|s| s.frees.clone()).collect();
        freed.sort_unstable();
        let before = freed.len();
        freed.dedup();
        assert_eq!(before, freed.len(), "slot freed twice");
        for o in &plan.outputs {
            assert!(!freed.contains(&o.slot), "output slot must stay live");
        }
        // the plan executes correctly after all that liveness machinery
        let x = f32v(&[8], &lcg(8, 81));
        let refs: Vec<&Value> = [&x].to_vec();
        let got = plan.execute(&refs).unwrap();
        let want = crate::hlo::interpret(&m, &[x]).unwrap();
        assert_bits_eq(&want, &got);
    }

    #[test]
    fn repeated_output_slot_clones() {
        let out = run_both(
            &["%x = f32[2] parameter(0)"],
            &[
                "%a = f32[2] exp(f32[2] %x)",
                "ROOT %t = (f32[2], f32[2]) tuple(f32[2] %a, f32[2] %a)",
            ],
            &[f32v(&[2], &[0.0, 1.0])],
        )
        .unwrap();
        assert_bits_eq(&[out[0].clone()], &[out[1].clone()]);
    }
}
