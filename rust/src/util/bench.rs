//! Micro-benchmark harness (criterion is not in the offline crate
//! snapshot). Warmup + timed samples + robust statistics, printed in a
//! criterion-like one-line format and optionally appended to a CSV so the
//! repro scripts can collect results.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// optional throughput items/second (set via `Bencher::throughput`)
    pub items_per_sec: Option<f64>,
}

pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
    items: Option<u64>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 10,
            items: None,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_samples: 5,
            items: None,
        }
    }

    /// Declare that each iteration processes `n` items (for throughput).
    pub fn throughput(mut self, n: u64) -> Self {
        self.items = Some(n);
        self
    }

    /// Run `f` repeatedly and report timing statistics.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // warmup
        let t0 = Instant::now();
        let mut warm_iters = 0u64;
        while t0.elapsed() < self.warmup || warm_iters < 1 {
            f();
            warm_iters += 1;
        }
        // choose batch size so one sample is ~1ms..50ms
        let per_iter = t0.elapsed().as_nanos() as f64 / warm_iters as f64;
        let batch = ((1_000_000.0 / per_iter).ceil() as u64).clamp(1, 1_000_000);

        let mut samples: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure || samples.len() < self.min_samples {
            let s = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(s.elapsed().as_nanos() as f64 / batch as f64);
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples: n,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            min_ns: samples[0],
            items_per_sec: self.items.map(|i| i as f64 * 1e9 / mean),
        };
        println!("{}", format_stats(&stats));
        stats
    }
}

pub fn format_stats(s: &BenchStats) -> String {
    let fmt = |ns: f64| -> String {
        if ns < 1_000.0 {
            format!("{ns:.0} ns")
        } else if ns < 1_000_000.0 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    };
    let tp = s
        .items_per_sec
        .map(|t| format!("  [{:.1} items/s]", t))
        .unwrap_or_default();
    format!(
        "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  ({} samples){}",
        s.name,
        fmt(s.mean_ns),
        fmt(s.p50_ns),
        fmt(s.p95_ns),
        s.samples,
        tp
    )
}

/// Append one line of CSV (creating a header when the file is new).
pub fn append_csv(path: &str, s: &BenchStats) -> std::io::Result<()> {
    use std::io::Write;
    let new = !std::path::Path::new(path).exists();
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    if new {
        writeln!(f, "name,samples,mean_ns,p50_ns,p95_ns,min_ns,items_per_sec")?;
    }
    writeln!(
        f,
        "{},{},{:.1},{:.1},{:.1},{:.1},{}",
        s.name,
        s.samples,
        s.mean_ns,
        s.p50_ns,
        s.p95_ns,
        s.min_ns,
        s.items_per_sec.map(|t| format!("{t:.1}")).unwrap_or_default()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let s = b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p95_ns * 1.001);
        assert!(s.samples >= 5);
    }

    #[test]
    fn throughput_reported() {
        let b = Bencher::quick().throughput(100);
        let s = b.bench("tp", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.items_per_sec.unwrap() > 0.0);
    }
}
