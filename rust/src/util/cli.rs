//! Tiny CLI argument parser (clap is not in the offline crate snapshot).
//!
//! Supports `bin <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (first item = subcommand).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut it = items.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn parse_env() -> Result<Args> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table1 --tasks cola,sst2 --seeds 5 --quick");
        assert_eq!(a.subcommand, "table1");
        assert_eq!(a.get("tasks"), Some("cola,sst2"));
        assert_eq!(a.get_usize("seeds", 1).unwrap(), 5);
        assert!(a.flag("quick"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("run --lr=3e-5 --out=dir/x");
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 3e-5);
        assert_eq!(a.get("out"), Some("dir/x"));
    }

    #[test]
    fn positional_args() {
        let a = parse("eval ckpt.bin --bits 8 extra");
        assert_eq!(a.positional, vec!["ckpt.bin", "extra"]);
        assert_eq!(a.get_usize("bits", 0).unwrap(), 8);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("mode", "full"), "full");
        assert_eq!(a.get_f32("lr", 1e-3).unwrap(), 1e-3);
    }
}
