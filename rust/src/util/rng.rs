//! Seeded PRNG (SplitMix64 + xoshiro256**) with the sampling helpers the
//! pipeline needs. The `rand` crate is not in the offline snapshot; this is
//! deliberately tiny and fully deterministic so every experiment is
//! reproducible from its seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], gauss: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(g) = self.gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss = Some(v * m);
                return u * m;
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.03, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var {m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct_unique() {
        let mut r = Rng::new(8);
        let v = r.choose_distinct(20, 5);
        assert_eq!(v.len(), 5);
        let mut s = v.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 5);
    }
}
