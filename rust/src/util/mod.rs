//! Shared substrates: JSON codec, seeded RNG, CLI parsing, bench harness,
//! property-test driver, and the data-parallel thread pool. These stand in
//! for serde_json / rand / clap / criterion / proptest / rayon, which are
//! not available in the offline crate snapshot (see Cargo.toml note).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
