//! Minimal JSON codec (parser + writer).
//!
//! serde_json is not available in this environment's offline crate
//! snapshot, so the manifest/golden/report plumbing uses this hand-rolled
//! implementation. It supports the full JSON grammar except `\u` surrogate
//! pairs outside the BMP (not needed by our manifests).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Exact non-negative integer accessor. Values at or beyond 2^53 are
    /// rejected rather than silently rounded — the parser stores numbers
    /// as f64, so larger integers may already have lost precision.
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer");
        }
        if n >= 9007199254740992.0 {
            bail!("integer {n} too large for exact f64 representation");
        }
        Ok(n as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer (canonical: objects emit keys in sorted order) --------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let n = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(n).ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // raw UTF-8 passthrough: find the full char
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number {s:?}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

/// Build an object from (key, value) pairs — the one object-literal
/// helper shared by every in-crate serializer (spec, fixture manifest).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn bool_and_u64_accessors() {
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
        assert!(!Json::parse("false").unwrap().as_bool().unwrap());
        assert!(Json::parse("1").unwrap().as_bool().is_err());
        assert_eq!(Json::parse("97").unwrap().as_u64().unwrap(), 97);
        assert!(Json::parse("\"x\"").unwrap().as_u64().is_err());
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert!(Json::parse("2.5").unwrap().as_u64().is_err());
        // beyond 2^53 the parser's f64 may already be inexact: reject
        assert!(Json::parse("9007199254740993").unwrap().as_u64().is_err());
        assert_eq!(
            Json::parse("9007199254740991").unwrap().as_u64().unwrap(),
            9007199254740991
        );
    }

    #[test]
    fn numeric_vectors() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn big_manifest_like() {
        let s = r#"{"artifacts": {"fwd": {"inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}]}}}"#;
        let v = Json::parse(s).unwrap();
        let inp = v.get("artifacts").unwrap().get("fwd").unwrap().get("inputs").unwrap();
        assert_eq!(
            inp.as_arr().unwrap()[0].get("shape").unwrap().as_usize_vec().unwrap(),
            vec![2, 3]
        );
    }
}
