//! Mini property-testing driver (proptest is not in the offline crate
//! snapshot). Runs a property over many seeded random cases and, on
//! failure, reports the seed so the case can be replayed exactly.
//!
//! ```ignore
//! prop_check("quant roundtrip", 200, |rng| {
//!     let x = rng.uniform(-10.0, 10.0);
//!     prop_assert(x.abs() <= 10.0, format!("x={x}"))
//! });
//! ```

use super::rng::Rng;

pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` over `cases` seeded RNGs; panics (with the failing seed) on
/// the first failure. Seeds derive from a fixed base so CI is stable, and
/// can be overridden with TQ_PROP_SEED for replay.
pub fn prop_check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base: u64 = std::env::var("TQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let replay = std::env::var("TQ_PROP_SEED").is_ok();
    let n = if replay { 1 } else { cases };
    for i in 0..n {
        let seed = base.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed at case {i} (replay with TQ_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Random float vector.
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check("count", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay with TQ_PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop_check("fail", 10, |rng| {
            prop_assert(rng.f32() < -1.0, "always fails")
        });
    }
}
