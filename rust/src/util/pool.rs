//! Dependency-free data-parallel thread pool on **persistent worker
//! threads** (job queue + condvar; rayon is not in the offline crate
//! snapshot). Workers are spawned once per [`Pool`] and live until the
//! last clone is dropped, so the executable hot loop (calibrate/eval
//! batches, sweep cells) pays no per-call spawn cost.
//!
//! Design rules, enforced by the determinism test suite (tests/
//! determinism.rs):
//!
//! * **Deterministic partitioning.** Work is split into contiguous,
//!   index-addressed chunks; every output lands in a caller-visible slot
//!   keyed by its input index. Scheduling order can vary, results cannot.
//! * **Bit-identical math.** The pool never changes *what* is computed per
//!   chunk — only which thread computes it — so `n = 1` and
//!   `n = available_parallelism()` produce bit-identical floats as long as
//!   the per-chunk computation itself is serial.
//! * **Serial fallback.** `Pool::new(1)` (and degenerate inputs) run on
//!   the calling thread with zero spawns and zero queue traffic, so the
//!   pool can be threaded through cold paths for free.
//! * **No deadlock on nested use.** The submitting thread always helps
//!   drain its own batch, so a batch submitted from *inside* a pool job
//!   (e.g. a sweep cell whose inner eval is itself batch-parallel)
//!   completes even when every worker is busy — nested submissions
//!   degrade to inline execution instead of deadlocking.
//! * **Panics cannot hang the queue.** A panicking job is caught on the
//!   worker, the batch still drains, and the payload is re-thrown on the
//!   submitting thread — so callers see an ordinary panic (catchable with
//!   `std::panic::catch_unwind`) and the pool stays usable.
//!
//! The worker count defaults to `std::thread::available_parallelism()` and
//! can be pinned with the `TQ_THREADS` environment variable (handy for
//! benchmarking serial vs parallel and for CI determinism runs).
//! `Pool::global()` is the shared persistent instance every hot path uses
//! by default.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A queued unit of work. Jobs are erased to `'static` when enqueued; the
/// borrow they actually carry is kept alive by [`Pool::exec_batch`]
/// blocking until the whole batch has finished (the same guarantee
/// `std::thread::scope` provides).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A job that may borrow the submitting stack frame.
type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

struct BatchState {
    /// jobs submitted and not yet finished (started or queued)
    pending: usize,
    /// first panic payload caught while running a job of this batch
    panic: Option<Box<dyn std::any::Any + Send>>,
}

/// One fork-join submission: the not-yet-started jobs plus completion
/// tracking. Shared between the submitting thread (which participates)
/// and the persistent workers.
struct Batch {
    queue: Mutex<VecDeque<Job>>,
    state: Mutex<BatchState>,
    done: Condvar,
}

impl Batch {
    fn new(jobs: VecDeque<Job>) -> Batch {
        let n = jobs.len();
        Batch {
            queue: Mutex::new(jobs),
            state: Mutex::new(BatchState { pending: n, panic: None }),
            done: Condvar::new(),
        }
    }

    fn pop(&self) -> Option<Job> {
        self.queue.lock().expect("pool batch queue").pop_front()
    }

    /// Run one job. Panics are caught so a panicking job can never hang
    /// the queue: the first payload is stashed and re-thrown on the
    /// submitting thread once the batch has fully drained.
    fn run_one(&self, job: Job) {
        let res = catch_unwind(AssertUnwindSafe(job));
        let mut st = self.state.lock().expect("pool batch state");
        st.pending -= 1;
        if let Err(p) = res {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        if st.pending == 0 {
            self.done.notify_all();
        }
    }
}

struct Injector {
    batches: VecDeque<Arc<Batch>>,
    shutdown: bool,
}

struct Core {
    injector: Mutex<Injector>,
    work: Condvar,
}

fn worker_loop(core: &Core) {
    let mut inj = core.injector.lock().expect("pool injector");
    loop {
        // claim one job from the oldest batch that still has queued work,
        // removing exhausted batches (their stragglers are tracked by
        // each batch's own `pending` count) as we go
        let mut found: Option<(Arc<Batch>, Job)> = None;
        while found.is_none() {
            let Some(front) = inj.batches.front() else { break };
            let batch = front.clone();
            match batch.pop() {
                Some(job) => found = Some((batch, job)),
                None => {
                    inj.batches.pop_front();
                }
            }
        }
        match found {
            Some((batch, job)) => {
                drop(inj);
                batch.run_one(job);
                inj = core.injector.lock().expect("pool injector");
            }
            None if inj.shutdown => return,
            None => inj = core.work.wait(inj).expect("pool injector"),
        }
    }
}

/// Owns the worker threads: dropping the last `Pool` clone signals
/// shutdown and joins them.
struct Workers {
    core: Arc<Core>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for Workers {
    fn drop(&mut self) {
        self.core.injector.lock().expect("pool injector").shutdown = true;
        self.core.work.notify_all();
        for h in self.handles.lock().expect("pool worker handles").drain(..) {
            let _ = h.join();
        }
    }
}

/// A chunked fork-join pool over persistent workers. Clones share the
/// same worker set; `Pool::new(1)` spawns nothing and runs everything
/// inline.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    /// `None` for the serial pool: no workers, no queue.
    workers: Option<Arc<Workers>>,
}

impl fmt::Debug for Pool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Pool({} threads, persistent)", self.threads)
    }
}

impl Pool {
    /// Spawn a pool with `threads` total runners. The submitting thread
    /// participates in every batch, so `threads - 1` persistent workers
    /// are spawned.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        if threads == 1 {
            return Pool { threads, workers: None };
        }
        let core = Arc::new(Core {
            injector: Mutex::new(Injector { batches: VecDeque::new(), shutdown: false }),
            work: Condvar::new(),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let core = core.clone();
                std::thread::Builder::new()
                    .name(format!("tq-pool-{i}"))
                    .spawn(move || worker_loop(&core))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool {
            threads,
            workers: Some(Arc::new(Workers { core, handles: Mutex::new(handles) })),
        }
    }

    /// One worker: every operation runs inline on the calling thread.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Process-wide persistent pool (TQ_THREADS override, else
    /// available_parallelism). Shared by every hot path that does not get
    /// an explicit pool.
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool::new(default_threads()))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fork-join primitive every public method builds on: enqueue `jobs`
    /// for the workers, help drain them on the calling thread, and return
    /// once every job has finished. Because the caller always
    /// participates, a batch submitted from inside a pool job completes
    /// even when all workers are busy with outer jobs — there is no
    /// deadlock by construction. The first caught panic (if any) is
    /// re-thrown here after the batch has drained.
    fn exec_batch<'env>(&self, jobs: Vec<ScopedJob<'env>>) {
        if jobs.is_empty() {
            return;
        }
        let jobs: VecDeque<Job> = jobs
            .into_iter()
            // SAFETY: the job may borrow `'env` state from the caller.
            // This function blocks until `pending == 0`, i.e. until every
            // job has run to completion, so no borrow outlives this call
            // — the lifetime erasure is never observable.
            .map(|j| unsafe { std::mem::transmute::<ScopedJob<'env>, Job>(j) })
            .collect();
        let batch = Arc::new(Batch::new(jobs));
        if let Some(w) = &self.workers {
            let mut inj = w.core.injector.lock().expect("pool injector");
            inj.batches.push_back(batch.clone());
            drop(inj);
            w.core.work.notify_all();
        }
        // participate: drain our own batch so progress never depends on a
        // free worker
        while let Some(job) = batch.pop() {
            batch.run_one(job);
        }
        let mut st = batch.state.lock().expect("pool batch state");
        while st.pending > 0 {
            st = batch.done.wait(st).expect("pool batch state");
        }
        if let Some(p) = st.panic.take() {
            drop(st);
            resume_unwind(p);
        }
    }

    /// Run `f(chunk_index, chunk)` over contiguous chunks of `data` of
    /// length `chunk_len` (the final chunk may be shorter), distributing
    /// chunks across workers.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let n_chunks = data.len().div_ceil(chunk_len);
        if self.threads <= 1 || n_chunks <= 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        let chunks: Vec<(usize, &mut [T])> =
            data.chunks_mut(chunk_len).enumerate().collect();
        let per = chunks.len().div_ceil(self.threads);
        let f = &f;
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(self.threads);
        let mut it = chunks.into_iter();
        loop {
            let group: Vec<(usize, &mut [T])> = it.by_ref().take(per).collect();
            if group.is_empty() {
                break;
            }
            jobs.push(Box::new(move || {
                for (i, c) in group {
                    f(i, c);
                }
            }));
        }
        self.exec_batch(jobs);
    }

    /// Map `f(index, item)` over `items`, preserving input order in the
    /// returned vector.
    pub fn par_map<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let per = items.len().div_ceil(self.threads);
        let total = items.len();
        let slots: Mutex<Vec<Option<U>>> =
            Mutex::new(std::iter::repeat_with(|| None).take(total).collect());
        {
            let f = &f;
            let slots = &slots;
            let jobs: Vec<ScopedJob<'_>> = items
                .chunks(per)
                .enumerate()
                .map(|(gi, group)| {
                    Box::new(move || {
                        let base = gi * per;
                        let out: Vec<U> = group
                            .iter()
                            .enumerate()
                            .map(|(j, t)| f(base + j, t))
                            .collect();
                        store_group(slots, base, out);
                    }) as ScopedJob<'_>
                })
                .collect();
            self.exec_batch(jobs);
        }
        take_slots(slots)
    }

    /// Like [`Pool::par_map`] but with mutable access to each item.
    pub fn par_iter_mut<T, U, F>(&self, items: &mut [T], f: F) -> Vec<U>
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut T) -> U + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let per = items.len().div_ceil(self.threads);
        let total = items.len();
        let slots: Mutex<Vec<Option<U>>> =
            Mutex::new(std::iter::repeat_with(|| None).take(total).collect());
        {
            let f = &f;
            let slots = &slots;
            let jobs: Vec<ScopedJob<'_>> = items
                .chunks_mut(per)
                .enumerate()
                .map(|(gi, group)| {
                    Box::new(move || {
                        let base = gi * per;
                        let out: Vec<U> = group
                            .iter_mut()
                            .enumerate()
                            .map(|(j, t)| f(base + j, t))
                            .collect();
                        store_group(slots, base, out);
                    }) as ScopedJob<'_>
                })
                .collect();
            self.exec_batch(jobs);
        }
        take_slots(slots)
    }

    /// Execute heterogeneous jobs with dynamic scheduling (one queue entry
    /// per job); results come back in submission order. This is the sweep
    /// engine's and `Runtime::run_batch`'s entry point.
    pub fn run<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        let total = jobs.len();
        if self.threads <= 1 || total <= 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let slots: Mutex<Vec<Option<R>>> =
            Mutex::new(std::iter::repeat_with(|| None).take(total).collect());
        {
            let slots = &slots;
            let boxed: Vec<ScopedJob<'_>> = jobs
                .into_iter()
                .enumerate()
                .map(|(i, job)| {
                    Box::new(move || {
                        let r = job();
                        store_group(slots, i, vec![r]);
                    }) as ScopedJob<'_>
                })
                .collect();
            self.exec_batch(boxed);
        }
        take_slots(slots)
    }
}

fn default_threads() -> usize {
    std::env::var("TQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Write one contiguous group of results into the slots, keyed by input
/// index. This is the single place results land — par_map, par_iter_mut
/// and run all route through it, so the index-addressed determinism
/// contract lives in one function.
fn store_group<U>(slots: &Mutex<Vec<Option<U>>>, base: usize, out: Vec<U>) {
    let mut s = slots.lock().expect("pool result slots");
    for (j, u) in out.into_iter().enumerate() {
        s[base + j] = Some(u);
    }
}

/// Unwrap the index-addressed result slots into input order.
fn take_slots<U>(slots: Mutex<Vec<Option<U>>>) -> Vec<U> {
    slots
        .into_inner()
        .expect("pool result slots")
        .into_iter()
        .map(|o| o.expect("pool worker result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        for threads in [1, 2, 7] {
            let pool = Pool::new(threads);
            let items: Vec<usize> = (0..100).collect();
            let out = pool.par_map(&items, |i, &x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_chunks_mut_covers_every_element() {
        for threads in [1, 3, 8] {
            let pool = Pool::new(threads);
            let mut data = vec![0u32; 1000];
            pool.par_chunks_mut(&mut data, 17, |ci, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (ci * 17 + j) as u32 + 1;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32 + 1);
            }
        }
    }

    #[test]
    fn par_iter_mut_indexes_correctly() {
        let pool = Pool::new(4);
        let mut items: Vec<usize> = vec![0; 57];
        let echoes = pool.par_iter_mut(&mut items, |i, slot| {
            *slot = i + 1;
            i
        });
        assert_eq!(echoes, (0..57).collect::<Vec<_>>());
        assert_eq!(items, (1..=57).collect::<Vec<_>>());
    }

    #[test]
    fn run_returns_in_submission_order() {
        let pool = Pool::new(4);
        let jobs: Vec<_> = (0..20)
            .map(|i| {
                move || {
                    if i % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_pool_never_spawns() {
        let pool = Pool::serial();
        assert_eq!(pool.threads(), 1);
        assert!(pool.workers.is_none(), "serial pool must not hold workers");
        let out = pool.par_map(&[1, 2, 3], |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(pool.par_map(&empty, |_, &x: &i32| x).is_empty());
        let none: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(pool.run(none).is_empty());
    }

    #[test]
    fn global_pool_exists() {
        assert!(Pool::global().threads() >= 1);
    }

    #[test]
    fn workers_are_reused_across_many_calls() {
        // the persistent pool must survive thousands of small batches
        // without respawning (a respawn bug would blow the thread limit
        // or deadlock); clone shares the same worker set
        let pool = Pool::new(3);
        let alias = pool.clone();
        for round in 0..500 {
            let items: Vec<usize> = (0..8).collect();
            let out = alias.par_map(&items, |_, &x| x + round);
            assert_eq!(out, (0..8).map(|x| x + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // outer jobs saturate every runner, then each submits an inner
        // batch to the SAME pool; caller participation must drain it
        let pool = Pool::new(4);
        let outer: Vec<_> = (0..8)
            .map(|i| {
                let pool = pool.clone();
                move || {
                    let items: Vec<usize> = (0..16).collect();
                    let inner = pool.par_map(&items, |_, &x| x * x);
                    inner.iter().sum::<usize>() + i
                }
            })
            .collect();
        let want: usize = (0..16).map(|x: usize| x * x).sum();
        let out = pool.run(outer);
        assert_eq!(out, (0..8).map(|i| want + i).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_surfaces_and_pool_survives() {
        let pool = Pool::new(4);
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || {
                    if i == 11 {
                        panic!("boom from job {i}");
                    }
                    i
                }
            })
            .collect();
        let res = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // the queue is not hung: the same pool keeps working
        let out = pool.run((0..32).map(|i| move || i * 3).collect::<Vec<_>>());
        assert_eq!(out, (0..32).map(|i| i * 3).collect::<Vec<_>>());
    }
}
