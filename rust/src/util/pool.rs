//! Dependency-free data-parallel thread pool (std::thread::scope + mpsc
//! channels; rayon is not in the offline crate snapshot).
//!
//! Design rules, enforced by the determinism test suite (tests/
//! determinism.rs):
//!
//! * **Deterministic partitioning.** Work is split into contiguous,
//!   index-addressed chunks; every output lands in a caller-visible slot
//!   keyed by its input index. Scheduling order can vary, results cannot.
//! * **Bit-identical math.** The pool never changes *what* is computed per
//!   chunk — only which thread computes it — so `n = 1` and
//!   `n = available_parallelism()` produce bit-identical floats as long as
//!   the per-chunk computation itself is serial.
//! * **Serial fallback.** `Pool::new(1)` (and degenerate inputs) run on
//!   the calling thread with zero spawns, so the pool can be threaded
//!   through cold paths for free.
//!
//! The worker count defaults to `std::thread::available_parallelism()` and
//! can be pinned with the `TQ_THREADS` environment variable (handy for
//! benchmarking serial vs parallel and for CI determinism runs).

use std::sync::mpsc;
use std::sync::{Mutex, OnceLock};

/// A chunked fork-join pool. Cheap to construct: threads are scoped per
/// call, so a `Pool` is just a worker-count policy.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// One worker: every operation runs inline on the calling thread.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Process-wide default pool (TQ_THREADS override, else
    /// available_parallelism).
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool::new(default_threads()))
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk_index, chunk)` over contiguous chunks of `data` of
    /// length `chunk_len` (the final chunk may be shorter), distributing
    /// chunks across workers.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let n_chunks = data.len().div_ceil(chunk_len);
        if self.threads <= 1 || n_chunks <= 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(i, c);
            }
            return;
        }
        let mut chunks: Vec<(usize, &mut [T])> =
            data.chunks_mut(chunk_len).enumerate().collect();
        let per = chunks.len().div_ceil(self.threads);
        std::thread::scope(|s| {
            for group in chunks.chunks_mut(per) {
                let f = &f;
                s.spawn(move || {
                    for (i, c) in group.iter_mut() {
                        f(*i, &mut **c);
                    }
                });
            }
        });
    }

    /// Map `f(index, item)` over `items`, preserving input order in the
    /// returned vector.
    pub fn par_map<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let per = items.len().div_ceil(self.threads);
        let (tx, rx) = mpsc::channel::<(usize, Vec<U>)>();
        std::thread::scope(|s| {
            for (gi, group) in items.chunks(per).enumerate() {
                let tx = tx.clone();
                let f = &f;
                s.spawn(move || {
                    let base = gi * per;
                    let out: Vec<U> =
                        group.iter().enumerate().map(|(j, t)| f(base + j, t)).collect();
                    let _ = tx.send((base, out));
                });
            }
        });
        drop(tx);
        collect_slots(rx, items.len())
    }

    /// Like [`Pool::par_map`] but with mutable access to each item.
    pub fn par_iter_mut<T, U, F>(&self, items: &mut [T], f: F) -> Vec<U>
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut T) -> U + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let per = items.len().div_ceil(self.threads);
        let total = items.len();
        let (tx, rx) = mpsc::channel::<(usize, Vec<U>)>();
        std::thread::scope(|s| {
            for (gi, group) in items.chunks_mut(per).enumerate() {
                let tx = tx.clone();
                let f = &f;
                s.spawn(move || {
                    let base = gi * per;
                    let out: Vec<U> = group
                        .iter_mut()
                        .enumerate()
                        .map(|(j, t)| f(base + j, t))
                        .collect();
                    let _ = tx.send((base, out));
                });
            }
        });
        drop(tx);
        collect_slots(rx, total)
    }

    /// Execute heterogeneous jobs with dynamic (work-stealing-ish queue)
    /// scheduling; results come back in submission order. This is the
    /// sweep engine's entry point: one job per experiment configuration.
    pub fn run<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send,
        F: FnOnce() -> R + Send,
    {
        let total = jobs.len();
        let n = self.threads.min(total.max(1));
        if n <= 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        // LIFO pop keeps the queue a plain Vec; result order is restored
        // by index, so scheduling order is irrelevant to the caller.
        let queue = Mutex::new(jobs.into_iter().enumerate().collect::<Vec<_>>());
        let (tx, rx) = mpsc::channel::<(usize, Vec<R>)>();
        std::thread::scope(|s| {
            for _ in 0..n {
                let tx = tx.clone();
                let queue = &queue;
                s.spawn(move || loop {
                    let job = queue.lock().expect("pool queue").pop();
                    match job {
                        Some((i, j)) => {
                            let _ = tx.send((i, vec![j()]));
                        }
                        None => break,
                    }
                });
            }
        });
        drop(tx);
        collect_slots(rx, total)
    }
}

fn default_threads() -> usize {
    std::env::var("TQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Reassemble worker results into input order.
fn collect_slots<U>(rx: mpsc::Receiver<(usize, Vec<U>)>, total: usize) -> Vec<U> {
    let mut slots: Vec<Option<U>> = std::iter::repeat_with(|| None).take(total).collect();
    for (base, out) in rx {
        for (j, u) in out.into_iter().enumerate() {
            slots[base + j] = Some(u);
        }
    }
    slots.into_iter().map(|o| o.expect("pool worker result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        for threads in [1, 2, 7] {
            let pool = Pool::new(threads);
            let items: Vec<usize> = (0..100).collect();
            let out = pool.par_map(&items, |i, &x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_chunks_mut_covers_every_element() {
        for threads in [1, 3, 8] {
            let pool = Pool::new(threads);
            let mut data = vec![0u32; 1000];
            pool.par_chunks_mut(&mut data, 17, |ci, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (ci * 17 + j) as u32 + 1;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32 + 1);
            }
        }
    }

    #[test]
    fn par_iter_mut_indexes_correctly() {
        let pool = Pool::new(4);
        let mut items: Vec<usize> = vec![0; 57];
        let echoes = pool.par_iter_mut(&mut items, |i, slot| {
            *slot = i + 1;
            i
        });
        assert_eq!(echoes, (0..57).collect::<Vec<_>>());
        assert_eq!(items, (1..=57).collect::<Vec<_>>());
    }

    #[test]
    fn run_returns_in_submission_order() {
        let pool = Pool::new(4);
        let jobs: Vec<_> = (0..20)
            .map(|i| {
                move || {
                    if i % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.run(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_pool_never_spawns() {
        // indirectly: results must match and nothing panics on n=1
        let pool = Pool::serial();
        assert_eq!(pool.threads(), 1);
        let out = pool.par_map(&[1, 2, 3], |_, &x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(pool.par_map(&empty, |_, &x: &i32| x).is_empty());
        let none: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![];
        assert!(pool.run(none).is_empty());
    }

    #[test]
    fn global_pool_exists() {
        assert!(Pool::global().threads() >= 1);
    }
}
