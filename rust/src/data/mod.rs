//! Synthetic GLUE benchmark (DESIGN.md §2 substitution).
//!
//! Eight sequence tasks mirroring the GLUE suite's structure: single- vs
//! paired-sentence inputs, 2/3-way classification and regression, and the
//! matching metrics. Each task is a deterministic generative rule over a
//! 512-token vocabulary, chosen to be learnable by a small encoder but not
//! trivially linear (counting, co-occurrence and cross-segment matching).
//!
//! Sequence layout matches BERT fine-tuning:
//!     [CLS] s1 ... [SEP]            (single-sentence tasks)
//!     [CLS] s1 ... [SEP] s2 ... [SEP] [PAD]*   (paired tasks)

use anyhow::{bail, Result};

use crate::util::rng::Rng;

pub const PAD_ID: i32 = 0;
pub const CLS_ID: i32 = 1;
pub const SEP_ID: i32 = 2;
/// 64-token vocabulary (matches python/compile/model.py): small enough
/// that the synthetic rules generalise from 2048 training examples.
pub const VOCAB: i32 = 64;
/// first ordinary (non-special) token id
pub const TOK0: i32 = 3;

/// One tokenised example.
#[derive(Debug, Clone)]
pub struct Example {
    pub ids: Vec<i32>,
    pub token_type: Vec<i32>,
    pub mask: Vec<f32>,
    /// class label (classification tasks)
    pub label: usize,
    /// regression target in [0, 1] (stsb only)
    pub target: f32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Classification(usize),
    Regression,
}

#[derive(Debug, Clone, Copy)]
pub struct TaskSpec {
    pub name: &'static str,
    pub kind: TaskKind,
    pub paired: bool,
    pub train_size: usize,
    pub dev_size: usize,
}

/// The eight tasks, mirroring GLUE's ordering in the paper's tables.
pub const TASKS: [TaskSpec; 8] = [
    TaskSpec { name: "cola", kind: TaskKind::Classification(2), paired: false, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "sst2", kind: TaskKind::Classification(2), paired: false, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "mrpc", kind: TaskKind::Classification(2), paired: true, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "stsb", kind: TaskKind::Regression, paired: true, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "qqp", kind: TaskKind::Classification(2), paired: true, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "mnli", kind: TaskKind::Classification(3), paired: true, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "qnli", kind: TaskKind::Classification(2), paired: true, train_size: 2048, dev_size: 512 },
    TaskSpec { name: "rte", kind: TaskKind::Classification(2), paired: true, train_size: 2048, dev_size: 512 },
];

pub fn task_spec(name: &str) -> Result<TaskSpec> {
    TASKS
        .iter()
        .find(|t| t.name == name)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("unknown task {name:?}"))
}

/// A generated dataset split.
#[derive(Debug, Clone)]
pub struct Split {
    pub examples: Vec<Example>,
}

/// Pack raw token segments into the BERT layout of length `seq`.
fn pack(seq: usize, s1: &[i32], s2: Option<&[i32]>) -> Example {
    let mut ids = Vec::with_capacity(seq);
    let mut tt = Vec::with_capacity(seq);
    ids.push(CLS_ID);
    tt.push(0);
    for &t in s1 {
        ids.push(t);
        tt.push(0);
    }
    ids.push(SEP_ID);
    tt.push(0);
    if let Some(s2) = s2 {
        for &t in s2 {
            ids.push(t);
            tt.push(1);
        }
        ids.push(SEP_ID);
        tt.push(1);
    }
    ids.truncate(seq);
    tt.truncate(seq);
    let real = ids.len();
    let mut mask = vec![1.0f32; real];
    while ids.len() < seq {
        ids.push(PAD_ID);
        tt.push(if s2.is_some() { 1 } else { 0 });
        mask.push(0.0);
    }
    Example { ids, token_type: tt, mask, label: 0, target: 0.0 }
}

fn rand_seg(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.range(TOK0 as usize, VOCAB as usize) as i32).collect()
}

/// Token "polarity" used by sst2-like rules: low half negative, high half
/// positive.
fn polarity(t: i32) -> i32 {
    if t < (TOK0 + (VOCAB - TOK0) / 2) {
        -1
    } else {
        1
    }
}

fn overlap_fraction(a: &[i32], b: &[i32]) -> f32 {
    if a.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    for t in a {
        if b.contains(t) {
            hits += 1;
        }
    }
    hits as f32 / a.len() as f32
}

/// Generate one example for `task`. `seq` is the model's max sequence.
pub fn gen_example(task: &TaskSpec, seq: usize, rng: &mut Rng) -> Result<Example> {
    let body = seq.saturating_sub(3); // [CLS] + 2x[SEP] budget for pairs
    match task.name {
        // CoLA-like "grammaticality": a sentence is acceptable iff it
        // contains no adjacent *descending* pair with gap > VOCAB/2
        // (an order-sensitive rule).
        "cola" => {
            const GAP: i32 = VOCAB / 2;
            let len = rng.range(8, body.min(24));
            let mut s = rand_seg(rng, len);
            let make_bad = rng.bool(0.5);
            if make_bad {
                let i = rng.below(len.saturating_sub(1).max(1));
                s[i] = VOCAB - 1 - rng.below(8) as i32;
                s[i + 1] = TOK0 + rng.below(8) as i32;
            } else {
                // repair: sort any violating pairs
                for i in 0..len - 1 {
                    if s[i] - s[i + 1] > GAP {
                        s.swap(i, i + 1);
                    }
                }
            }
            let viol = s.windows(2).any(|w| w[0] - w[1] > GAP);
            let mut ex = pack(seq, &s, None);
            ex.label = usize::from(!viol);
            Ok(ex)
        }
        // SST-2-like sentiment: label = sign of summed token polarity.
        "sst2" => {
            let len = rng.range(8, body.min(30));
            let pos = rng.bool(0.5);
            let s: Vec<i32> = (0..len)
                .map(|_| {
                    let want_pos = if rng.bool(0.8) { pos } else { !pos };
                    let half = (VOCAB - TOK0) / 2;
                    if want_pos {
                        TOK0 + half + rng.below(half as usize) as i32
                    } else {
                        TOK0 + rng.below(half as usize) as i32
                    }
                })
                .collect();
            let score: i32 = s.iter().map(|&t| polarity(t)).sum();
            let mut ex = pack(seq, &s, None);
            ex.label = usize::from(score > 0);
            Ok(ex)
        }
        // MRPC-like paraphrase: s2 is a shuffled/perturbed copy (label 1)
        // or an unrelated segment (label 0).
        "mrpc" | "qqp" => {
            let len = rng.range(6, (body / 2).min(20));
            let s1 = rand_seg(rng, len);
            let paraphrase = rng.bool(0.5);
            let s2 = if paraphrase {
                let mut c = s1.clone();
                rng.shuffle(&mut c);
                // small perturbation for qqp (near-duplicate detection)
                if task.name == "qqp" && rng.bool(0.5) {
                    let i = rng.below(c.len());
                    c[i] = rng.range(TOK0 as usize, VOCAB as usize) as i32;
                }
                c
            } else {
                rand_seg(rng, len)
            };
            let thresh = if task.name == "qqp" { 0.8 } else { 0.5 };
            let mut ex = pack(seq, &s1, Some(&s2));
            ex.label = usize::from(overlap_fraction(&s1, &s2) >= thresh);
            Ok(ex)
        }
        // STS-B-like similarity regression: target = token overlap in [0,1].
        "stsb" => {
            let len = rng.range(6, (body / 2).min(20));
            let s1 = rand_seg(rng, len);
            let keep = rng.below(len + 1);
            let mut s2 = s1.clone();
            let replace_idx = rng.choose_distinct(len, len - keep);
            for i in replace_idx {
                s2[i] = rng.range(TOK0 as usize, VOCAB as usize) as i32;
            }
            rng.shuffle(&mut s2);
            let mut ex = pack(seq, &s1, Some(&s2));
            ex.target = overlap_fraction(&s1, &s2);
            Ok(ex)
        }
        // MNLI-like 3-way: marker token m in s1; entail iff m appears in
        // s2, contradiction iff the "negated" marker m^1 appears, neutral
        // otherwise.
        "mnli" => {
            let len = rng.range(6, (body / 2).min(20));
            let mut s1 = rand_seg(rng, len);
            let marker = (TOK0 as usize + 2 * rng.below(((VOCAB - TOK0) / 2) as usize)) as i32;
            s1[rng.below(len)] = marker;
            let mut s2 = rand_seg(rng, len);
            // scrub accidental markers
            for t in s2.iter_mut() {
                if *t == marker || *t == marker + 1 {
                    *t = TOK0;
                }
            }
            let label = rng.below(3);
            match label {
                0 => s2[rng.below(len)] = marker,     // entailment
                1 => s2[rng.below(len)] = marker + 1, // contradiction
                _ => {}                               // neutral
            }
            let mut ex = pack(seq, &s1, Some(&s2));
            ex.label = label;
            Ok(ex)
        }
        // QNLI-like: the "question" asks for token q (first token of s1);
        // answerable iff q+7 occurs in s2.
        "qnli" => {
            let len = rng.range(6, (body / 2).min(20));
            let mut s1 = rand_seg(rng, len);
            let q = rng.range(TOK0 as usize, (VOCAB - 8) as usize) as i32;
            s1[0] = q;
            let mut s2 = rand_seg(rng, len);
            for t in s2.iter_mut() {
                if *t == q + 7 {
                    *t = TOK0;
                }
            }
            let ans = rng.bool(0.5);
            if ans {
                let i = rng.below(len);
                s2[i] = q + 7;
            }
            let mut ex = pack(seq, &s1, Some(&s2));
            ex.label = usize::from(ans);
            Ok(ex)
        }
        // RTE-like binary entailment: entail iff >= 2 of the 3 marked
        // premise tokens re-occur in s2.
        "rte" => {
            let len = rng.range(8, (body / 2).min(20));
            let s1 = rand_seg(rng, len);
            let marks: Vec<i32> = (0..3).map(|i| s1[i]).collect();
            let mut s2 = rand_seg(rng, len);
            for t in s2.iter_mut() {
                if marks.contains(t) {
                    *t = TOK0;
                }
            }
            let n_present = rng.below(4); // 0..3
            let slots = rng.choose_distinct(len, n_present);
            for (j, &slot) in slots.iter().enumerate() {
                s2[slot] = marks[j % 3];
            }
            let present = marks.iter().filter(|m| s2.contains(m)).count();
            let mut ex = pack(seq, &s1, Some(&s2));
            ex.label = usize::from(present >= 2);
            Ok(ex)
        }
        other => bail!("unknown task {other:?}"),
    }
}

/// Deterministic dataset: train/dev splits from disjoint seed streams.
pub fn make_split(task: &TaskSpec, seq: usize, n: usize, seed: u64) -> Result<Split> {
    let mut rng = Rng::new(seed);
    let mut examples = Vec::with_capacity(n);
    for _ in 0..n {
        examples.push(gen_example(task, seq, &mut rng)?);
    }
    Ok(Split { examples })
}

pub fn train_split(task: &TaskSpec, seq: usize) -> Result<Split> {
    make_split(task, seq, task.train_size, 0x7121_0000 ^ hash_name(task.name))
}

pub fn dev_split(task: &TaskSpec, seq: usize) -> Result<Split> {
    make_split(task, seq, task.dev_size, 0xDE10_0000 ^ hash_name(task.name))
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(1469598103934665603u64, |h, b| {
        (h ^ b as u64).wrapping_mul(1099511628211)
    })
}

/// Fixed seed of the token-id → pixel-patch codebook. One constant so the
/// generator-side rasterisation (if any) and the coordinator's
/// [`pixels_for_ids`] can never drift apart.
pub const PIXEL_CODEBOOK_SEED: u64 = 0x9121_0007;

/// Deterministic pixel codebook for the ViT frontend: one `patch_dim`-long
/// row of uniform [-1, 1] pixels per vocabulary id. The ViT fixture has no
/// tokenizer — the same synthetic examples drive both architectures, and
/// this fixed map rasterises each token id into one image patch, so every
/// task/dataset/metric stays shared.
pub fn pixel_codebook(patch_dim: usize) -> Vec<f32> {
    let mut rng = Rng::new(PIXEL_CODEBOOK_SEED);
    (0..VOCAB as usize * patch_dim).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

/// Rasterise flat token ids into flat pixels (`ids.len() * patch_dim`)
/// through [`pixel_codebook`]. Ids outside the vocabulary (never produced
/// by the generators) wrap rather than panic.
pub fn pixels_for_ids(ids: &[i32], patch_dim: usize) -> Vec<f32> {
    let book = pixel_codebook(patch_dim);
    let mut out = Vec::with_capacity(ids.len() * patch_dim);
    for &id in ids {
        let row = id.rem_euclid(VOCAB) as usize * patch_dim;
        out.extend_from_slice(&book[row..row + patch_dim]);
    }
    out
}

/// Batch of examples flattened for the runtime.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: Vec<i32>,
    pub token_type: Vec<i32>,
    pub mask: Vec<f32>,
    pub labels_cls: Vec<i32>,
    pub labels_reg: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    /// number of genuine examples; rows `real..batch` are PAD padding
    pub real: usize,
}

/// Assemble `examples[start..start+b]` into a flat batch for a
/// fixed-batch executable. A final partial batch is padded with PAD-token
/// rows (mask all-zero, labels zero) up to `b`; it used to wrap around to
/// the head of the split instead, which silently duplicated leading
/// examples into every consumer that trusts the label vectors —
/// `real` tells consumers how many rows to score.
pub fn make_batch(split: &Split, start: usize, b: usize, seq: usize) -> Batch {
    let n = split.examples.len();
    let real = n.saturating_sub(start).min(b);
    let mut out = Batch {
        ids: Vec::with_capacity(b * seq),
        token_type: Vec::with_capacity(b * seq),
        mask: Vec::with_capacity(b * seq),
        labels_cls: Vec::with_capacity(b),
        labels_reg: Vec::with_capacity(b),
        batch: b,
        seq,
        real,
    };
    for i in 0..real {
        let ex = &split.examples[start + i];
        out.ids.extend_from_slice(&ex.ids);
        out.token_type.extend_from_slice(&ex.token_type);
        out.mask.extend_from_slice(&ex.mask);
        out.labels_cls.push(ex.label as i32);
        out.labels_reg.push(ex.target);
    }
    for _ in real..b {
        out.ids.resize(out.ids.len() + seq, PAD_ID);
        out.token_type.resize(out.token_type.len() + seq, 0);
        out.mask.resize(out.mask.len() + seq, 0.0);
        out.labels_cls.push(0);
        out.labels_reg.push(0.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEQ: usize = 64;

    #[test]
    fn all_tasks_generate_valid_examples() {
        for task in &TASKS {
            let split = make_split(task, SEQ, 64, 42).unwrap();
            for ex in &split.examples {
                assert_eq!(ex.ids.len(), SEQ);
                assert_eq!(ex.token_type.len(), SEQ);
                assert_eq!(ex.mask.len(), SEQ);
                assert_eq!(ex.ids[0], CLS_ID);
                assert!(ex.ids.iter().filter(|&&t| t == SEP_ID).count() >= 1);
                // mask is a prefix of ones
                let ones = ex.mask.iter().filter(|&&m| m == 1.0).count();
                assert!(ex.mask[..ones].iter().all(|&m| m == 1.0));
                assert!(ex.mask[ones..].iter().all(|&m| m == 0.0));
                // padding only where mask = 0
                for (i, &id) in ex.ids.iter().enumerate() {
                    if ex.mask[i] == 1.0 {
                        assert_ne!(id, PAD_ID, "real token is PAD at {i}");
                    } else {
                        assert_eq!(id, PAD_ID);
                    }
                }
                match task.kind {
                    TaskKind::Classification(n) => assert!(ex.label < n),
                    TaskKind::Regression => {
                        assert!((0.0..=1.0).contains(&ex.target))
                    }
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let t = task_spec("mnli").unwrap();
        let a = make_split(&t, SEQ, 16, 7).unwrap();
        let b = make_split(&t, SEQ, 16, 7).unwrap();
        for (x, y) in a.examples.iter().zip(&b.examples) {
            assert_eq!(x.ids, y.ids);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn train_dev_disjoint_streams() {
        let t = task_spec("sst2").unwrap();
        let tr = train_split(&t, SEQ).unwrap();
        let dv = dev_split(&t, SEQ).unwrap();
        assert_ne!(tr.examples[0].ids, dv.examples[0].ids);
        assert_eq!(tr.examples.len(), t.train_size);
        assert_eq!(dv.examples.len(), t.dev_size);
    }

    #[test]
    fn labels_reasonably_balanced() {
        for task in &TASKS {
            if task.name == "stsb" {
                continue;
            }
            let n_cls = match task.kind {
                TaskKind::Classification(n) => n,
                _ => unreachable!(),
            };
            let split = make_split(task, SEQ, 512, 3).unwrap();
            let mut counts = vec![0usize; n_cls];
            for ex in &split.examples {
                counts[ex.label] += 1;
            }
            for (c, &count) in counts.iter().enumerate() {
                assert!(
                    count > 512 / n_cls / 4,
                    "{}: class {c} has only {count}/512",
                    task.name
                );
            }
        }
    }

    #[test]
    fn stsb_targets_spread() {
        let t = task_spec("stsb").unwrap();
        let split = make_split(&t, SEQ, 256, 5).unwrap();
        let lo = split.examples.iter().filter(|e| e.target < 0.3).count();
        let hi = split.examples.iter().filter(|e| e.target > 0.7).count();
        assert!(lo > 20 && hi > 20, "targets degenerate: lo={lo} hi={hi}");
    }

    #[test]
    fn batch_assembly_and_tail_padding() {
        let t = task_spec("rte").unwrap();
        let split = make_split(&t, SEQ, 10, 1).unwrap();
        // full batch: all rows real
        let full = make_batch(&split, 0, 8, SEQ);
        assert_eq!(full.real, 8);
        assert_eq!(full.ids.len(), 8 * SEQ);
        assert_eq!(&full.ids[0..SEQ], &split.examples[0].ids[..]);
        // tail batch: 2 real rows, 2 PAD rows — the old wraparound
        // duplicated examples 0 and 1 here, double-counting them in any
        // consumer that trusts the label vectors
        let b = make_batch(&split, 8, 4, SEQ);
        assert_eq!(b.ids.len(), 4 * SEQ);
        assert_eq!(b.labels_cls.len(), 4);
        assert_eq!(b.real, 2);
        assert_eq!(&b.ids[0..SEQ], &split.examples[8].ids[..]);
        assert_eq!(&b.ids[SEQ..2 * SEQ], &split.examples[9].ids[..]);
        for row in 2..4 {
            assert!(b.ids[row * SEQ..(row + 1) * SEQ].iter().all(|&id| id == PAD_ID));
            assert!(b.mask[row * SEQ..(row + 1) * SEQ].iter().all(|&m| m == 0.0));
            assert_eq!(b.labels_cls[row], 0);
            assert_eq!(b.labels_reg[row], 0.0);
        }
        // start past the end: a fully padded batch, zero real rows
        let past = make_batch(&split, 12, 4, SEQ);
        assert_eq!(past.real, 0);
        assert!(past.ids.iter().all(|&id| id == PAD_ID));
    }

    #[test]
    fn pixel_codebook_is_deterministic_and_bounded() {
        let pd = 16;
        let a = pixel_codebook(pd);
        assert_eq!(a.len(), VOCAB as usize * pd);
        assert_eq!(a, pixel_codebook(pd));
        assert!(a.iter().all(|x| (-1.0..=1.0).contains(x)));
        // distinct ids map to distinct patches
        assert_ne!(&a[0..pd], &a[pd..2 * pd]);
        // rasterisation = per-id codebook lookup, wrapping out-of-range ids
        let px = pixels_for_ids(&[CLS_ID, PAD_ID, VOCAB + CLS_ID], pd);
        assert_eq!(px.len(), 3 * pd);
        let row = |id: i32| &a[id as usize * pd..(id as usize + 1) * pd];
        assert_eq!(&px[0..pd], row(CLS_ID));
        assert_eq!(&px[pd..2 * pd], row(PAD_ID));
        assert_eq!(&px[2 * pd..], row(CLS_ID));
    }

    #[test]
    fn paired_tasks_have_two_segments() {
        for task in TASKS.iter().filter(|t| t.paired) {
            let split = make_split(task, SEQ, 8, 2).unwrap();
            for ex in &split.examples {
                assert!(
                    ex.token_type.iter().any(|&t| t == 1),
                    "{} lacks segment 1",
                    task.name
                );
                assert_eq!(ex.ids.iter().filter(|&&t| t == SEP_ID).count(), 2);
            }
        }
    }
}
