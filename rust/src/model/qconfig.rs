//! Quantization policy: per-site activation settings + weight settings,
//! and their compilation into the flat runtime tensors (act_scales,
//! act_zps, act_cfg) the HLO executables consume (DESIGN.md §3).
//!
//! This is where the paper's configurations become data:
//!   * W8A8 per-tensor PTQ        -> all sites 8-bit PerTensor
//!   * leave-one-out ablation     -> `enabled = false` on a site family
//!   * mixed precision (Table 4)  -> 16-bit on selected sites
//!   * PEG ± permutation (Table 5)-> PerEmbeddingGroup granularity
//!   * QAT                        -> scales learned in-graph, assembled here
//!     for initialisation

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::quant::{
    peg::{lane_qparams, site_groups},
    qparams_from_range, Estimator, Granularity, QGrid, QParams, RangeMethod,
};
use crate::quant::estimators::{mse_search_groups_pool, mse_search_pool, RangeTracker};
use crate::model::manifest::ModelInfo;
use crate::util::pool::Pool;

/// Per-site activation quantizer configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteCfg {
    pub bits: u32,
    pub granularity: Granularity,
    /// how the final range(s) are derived from tracked statistics
    pub range_method: RangeMethod,
    pub enabled: bool,
}

impl Default for SiteCfg {
    fn default() -> Self {
        SiteCfg {
            bits: 8,
            granularity: Granularity::PerTensor,
            range_method: RangeMethod::Auto,
            enabled: true,
        }
    }
}

/// Weight quantizer configuration (applied Rust-side on parameter tensors).
#[derive(Debug, Clone, PartialEq)]
pub struct WeightCfg {
    pub bits: u32,
    pub estimator: Estimator,
    /// Q-BERT-style group-wise per-channel quantization (None = per-tensor)
    pub per_channel_groups: Option<usize>,
    pub enabled: bool,
}

impl Default for WeightCfg {
    fn default() -> Self {
        WeightCfg { bits: 8, estimator: Estimator::CurrentMinMax, per_channel_groups: None, enabled: true }
    }
}

/// Full activation policy over a model's sites.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPolicy {
    /// default config for sites not in `overrides`
    pub default: SiteCfg,
    pub overrides: BTreeMap<String, SiteCfg>,
    pub weights: WeightCfg,
    /// per-weight-name overrides (e.g. 2-bit embeddings)
    pub weight_overrides: BTreeMap<String, WeightCfg>,
}

impl QuantPolicy {
    /// Everything FP32 (baseline).
    pub fn fp32() -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { enabled: false, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { enabled: false, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Uniform W{wb}A{ab} per-tensor policy (the paper's W8A8 baseline).
    pub fn uniform(wb: u32, ab: u32) -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { bits: ab, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { bits: wb, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    pub fn site_cfg(&self, site: &str) -> &SiteCfg {
        self.overrides.get(site).unwrap_or(&self.default)
    }

    pub fn weight_cfg(&self, name: &str) -> &WeightCfg {
        self.weight_overrides.get(name).unwrap_or(&self.weights)
    }

    /// Override a set of sites (by exact name).
    pub fn with_sites(mut self, sites: &[&str], cfg: SiteCfg) -> QuantPolicy {
        for s in sites {
            self.overrides.insert(s.to_string(), cfg.clone());
        }
        self
    }

    /// Override every site whose name ends with `suffix` across layers
    /// (e.g. "res2_sum" hits layer0..N) — used by the Table 2 ablations
    /// and the PEG "only FFN" configurations.
    pub fn with_site_family(mut self, info: &ModelInfo, suffix: &str, cfg: SiteCfg) -> QuantPolicy {
        for s in &info.sites {
            if s.name.ends_with(suffix) {
                self.overrides.insert(s.name.clone(), cfg.clone());
            }
        }
        self
    }
}

/// The flat tensors the executables take, plus bookkeeping for reports.
#[derive(Debug, Clone)]
pub struct ActQuantTensors {
    pub scales: Vec<f32>,
    pub zps: Vec<f32>,
    /// (n_sites, 3) row-major [qmin, qmax, enable]
    pub cfg: Vec<f32>,
    /// per-site chosen permutation (only when PEG+permute), for reporting
    pub permutations: BTreeMap<String, Vec<usize>>,
}

/// Resolve one site's per-lane parameters from its calibrated tracker:
/// the granularity defines the parameter-sharing groups (PEG permutation
/// included), the range method defines how each group's range is chosen
/// (tracked bounds vs MSE grid search). Returns the per-lane params plus
/// the lane permutation used (identity unless range-permuted PEG).
///
/// This is the *single* quantizer-site resolution path: the activation
/// assembly ([`assemble_act_tensors_pool`]) and the sweep's offline
/// substrate both route through it, so a `(granularity, range_method)`
/// pair means the same thing on every surface.
pub fn site_lane_params_pool(
    tracker: &RangeTracker,
    cfg: &SiteCfg,
    grid: QGrid,
    pool: &Pool,
) -> Result<(Vec<QParams>, Vec<usize>)> {
    let (lo, hi) = tracker.lane_ranges();
    let d = lo.len();
    // K beyond the site's lane count is a misconfigured spec, not a
    // request for per-embedding: fail loudly here (the one resolution
    // path) instead of letting site_groups' library-level clamp silently
    // reinterpret it — the same contract the sweep CLI enforces for
    // --groups
    if let Granularity::PerEmbeddingGroup { k, .. } = &cfg.granularity {
        if *k > d {
            bail!(
                "granularity group:{k} exceeds this site's {d} lanes — use \
                 per_embedding or a smaller K"
            );
        }
    }
    let identity: Vec<usize> = (0..d).collect();
    match cfg.range_method {
        RangeMethod::Auto => match &cfg.granularity {
            // pre-range_method behaviour: per-tensor sites follow the
            // calibration estimator (MSE kind -> tensor grid search),
            // grouped sites use tracked lane bounds
            Granularity::PerTensor => {
                let (tlo, thi) = tracker.tensor_range_pool(grid, pool);
                Ok((vec![qparams_from_range(tlo, thi, grid); d], identity))
            }
            g => lane_qparams(&lo, &hi, g, grid),
        },
        RangeMethod::CurrentMinMax => lane_qparams(&lo, &hi, &cfg.granularity, grid),
        RangeMethod::MseTensor => {
            if cfg.granularity != Granularity::PerTensor {
                bail!(
                    "range_method mse_tensor requires per_tensor granularity \
                     (got {:?}) — use mse_group for grouped sites",
                    cfg.granularity
                );
            }
            let (tlo, thi) = if tracker.kind == Estimator::Mse {
                // the MSE estimator already retains a value reservoir
                tracker.tensor_range_pool(grid, pool)
            } else {
                let Some((rows, _)) = tracker.row_samples() else {
                    bail!(
                        "range_method mse_tensor under a non-MSE estimator needs \
                         retained samples: build the tracker with \
                         with_row_samples() (the spec pipeline does this for you)"
                    );
                };
                let tlo = lo.iter().copied().fold(f32::INFINITY, f32::min).min(0.0);
                let thi = hi.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(0.0);
                mse_search_pool(rows, tlo, thi, grid, pool)
            };
            Ok((vec![qparams_from_range(tlo, thi, grid); d], identity))
        }
        RangeMethod::MsePerGroup => {
            let Some((rows, _)) = tracker.row_samples() else {
                bail!(
                    "range_method mse_group needs per-lane samples: build the \
                     tracker with with_row_samples() (the spec pipeline does \
                     this for mse_group sites automatically)"
                );
            };
            let (groups, order) = site_groups(&lo, &hi, &cfg.granularity)?;
            let ranges =
                mse_search_groups_pool(rows, tracker.lanes(), &groups, &lo, &hi, grid, pool);
            let mut params = vec![QParams { scale: 1.0, zero_point: 0.0 }; d];
            for (members, (glo, ghi)) in groups.iter().zip(ranges) {
                let p = qparams_from_range(glo, ghi, grid);
                for &j in members {
                    params[j] = p;
                }
            }
            Ok((params, order))
        }
    }
}

/// Compile per-site range statistics + policy into runtime tensors.
///
/// `trackers` maps site name -> calibrated RangeTracker (per-lane stats).
pub fn assemble_act_tensors(
    info: &ModelInfo,
    policy: &QuantPolicy,
    trackers: &BTreeMap<String, RangeTracker>,
) -> Result<ActQuantTensors> {
    assemble_act_tensors_pool(info, policy, trackers, Pool::global())
}

/// Pool-explicit [`assemble_act_tensors`]: per-site resolution goes
/// through [`site_lane_params_pool`], whose MSE searches fan out on
/// `pool` with results reassembled in a fixed order — bit-identical for
/// any worker count.
pub fn assemble_act_tensors_pool(
    info: &ModelInfo,
    policy: &QuantPolicy,
    trackers: &BTreeMap<String, RangeTracker>,
    pool: &Pool,
) -> Result<ActQuantTensors> {
    let mut scales = vec![1.0f32; info.total_scale_lanes];
    let mut zps = vec![0.0f32; info.total_scale_lanes];
    let mut cfg = Vec::with_capacity(info.sites.len() * 3);
    let mut permutations = BTreeMap::new();

    for site in &info.sites {
        let sc = policy.site_cfg(&site.name);
        let grid = QGrid::asymmetric(sc.bits);
        cfg.extend_from_slice(&[grid.qmin, grid.qmax, if sc.enabled { 1.0 } else { 0.0 }]);
        if !sc.enabled {
            continue;
        }
        let tracker = match trackers.get(&site.name) {
            Some(t) => t,
            // unobserved site (e.g. quick tests): harmless wide default
            None => {
                for l in 0..site.channels {
                    scales[site.offset + l] = 1.0;
                    zps[site.offset + l] = 0.0;
                }
                continue;
            }
        };
        // scalar sites cannot be grouped: resolve them per-tensor so a
        // grouped default policy still applies cleanly everywhere
        let (params, perm) = if site.channels == 1 {
            let scalar = SiteCfg { granularity: Granularity::PerTensor, ..sc.clone() };
            site_lane_params_pool(tracker, &scalar, grid, pool)?
        } else {
            site_lane_params_pool(tracker, sc, grid, pool)?
        };
        if site.channels > 1
            && matches!(sc.granularity, Granularity::PerEmbeddingGroup { permute: true, .. })
        {
            permutations.insert(site.name.clone(), perm);
        }
        for (l, p) in params.iter().enumerate() {
            scales[site.offset + l] = p.scale;
            zps[site.offset + l] = p.zero_point;
        }
    }
    Ok(ActQuantTensors { scales, zps, cfg, permutations })
}

/// The paper's activation-quantizer count for mixed-precision accounting
/// ("36 out of 161 activation quantizers", Table 4 footnote).
pub fn count_sites_at_bits(info: &ModelInfo, policy: &QuantPolicy, bits: u32) -> usize {
    info.sites
        .iter()
        .filter(|s| {
            let c = policy.site_cfg(&s.name);
            c.enabled && c.bits == bits
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;
    use crate::quant::Estimator;
    use crate::tensor::Tensor;

    fn calibrated_trackers(info: &ModelInfo) -> BTreeMap<String, RangeTracker> {
        let mut out = BTreeMap::new();
        for s in &info.sites {
            let mut tr = RangeTracker::new(Estimator::CurrentMinMax, s.channels);
            let t = Tensor::from_fn(&[4, s.channels], |i| (i % 7) as f32 - 3.0);
            tr.observe(&t).unwrap();
            out.insert(s.name.clone(), tr);
        }
        out
    }

    #[test]
    fn assemble_shapes_and_enables() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let policy = QuantPolicy::uniform(8, 8);
        let t = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        assert_eq!(t.scales.len(), info.total_scale_lanes);
        assert_eq!(t.cfg.len(), info.sites.len() * 3);
        assert!(t.cfg.chunks(3).all(|c| c[2] == 1.0));

        let fp32 = QuantPolicy::fp32();
        let t2 = assemble_act_tensors(&info, &fp32, &trackers).unwrap();
        assert!(t2.cfg.chunks(3).all(|c| c[2] == 0.0));
    }

    #[test]
    fn per_tensor_scales_uniform_across_lanes() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let t = assemble_act_tensors(&info, &QuantPolicy::uniform(8, 8), &trackers).unwrap();
        let s = info.site("embed_sum").unwrap();
        let lanes = &t.scales[s.offset..s.offset + s.channels];
        assert!(lanes.iter().all(|&x| x == lanes[0]));
    }

    #[test]
    fn mixed_precision_override() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let policy = QuantPolicy::uniform(8, 8).with_site_family(
            &info,
            "res2_sum",
            SiteCfg { bits: 16, ..Default::default() },
        );
        let t = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        let idx = info.site_index("layer0.res2_sum").unwrap();
        assert_eq!(t.cfg[idx * 3 + 1], 65535.0);
        assert_eq!(count_sites_at_bits(&info, &policy, 16), 1);
        assert_eq!(count_sites_at_bits(&info, &policy, 8), info.sites.len() - 1);
    }

    #[test]
    fn peg_granularity_writes_group_scales() {
        let info = tiny_model_info();
        // make one site have an outlier lane
        let mut trackers = calibrated_trackers(&info);
        let s = info.site("layer0.res2_sum").unwrap().clone();
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, s.channels);
        // lane 3 swings ±50, the others ±0.5 (sign alternates across rows)
        let t = Tensor::from_fn(&[2, s.channels], |i| {
            let sign = if i / s.channels == 0 { 1.0 } else { -1.0 };
            sign * if i % s.channels == 3 { 50.0 } else { 0.5 }
        });
        tr.observe(&t).unwrap();
        trackers.insert(s.name.clone(), tr);

        let policy = QuantPolicy::uniform(8, 8).with_sites(
            &["layer0.res2_sum"],
            SiteCfg {
                granularity: Granularity::PerEmbeddingGroup { k: 4, permute: true },
                ..Default::default()
            },
        );
        let out = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        let lanes = &out.scales[s.offset..s.offset + s.channels];
        // K=4 over 8 lanes => groups of 2: the outlier lane (3) plus its
        // one group-mate get a large scale, the remaining 6 stay tight
        assert!(lanes[3] > 0.1, "{lanes:?}");
        let tight = lanes.iter().filter(|&&v| v < 0.01).count();
        assert_eq!(tight, 6, "{lanes:?}");
        assert!(out.permutations.contains_key("layer0.res2_sum"));
    }

    #[test]
    fn unobserved_site_gets_safe_defaults() {
        let info = tiny_model_info();
        let trackers = BTreeMap::new();
        let t = assemble_act_tensors(&info, &QuantPolicy::uniform(8, 8), &trackers).unwrap();
        assert!(t.scales.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn range_method_current_skips_the_mse_search() {
        // an Mse-kind tracker with one outlier among thousands of small
        // values: Auto runs the grid search (clips), CurrentMinMax must
        // keep the raw tracked bounds
        let mut rng = crate::util::rng::Rng::new(1);
        let mut tr = RangeTracker::new(Estimator::Mse, 1);
        let mut data: Vec<f32> = (0..4096).map(|_| rng.uniform(0.0, 1.0)).collect();
        data[7] = 10.0;
        tr.observe(&Tensor::new(vec![4096], data).unwrap()).unwrap();
        let grid = QGrid::asymmetric(4);
        let pool = Pool::serial();
        let auto = SiteCfg::default();
        let raw = SiteCfg { range_method: RangeMethod::CurrentMinMax, ..Default::default() };
        let (pa, _) = site_lane_params_pool(&tr, &auto, grid, &pool).unwrap();
        let (pr, _) = site_lane_params_pool(&tr, &raw, grid, &pool).unwrap();
        // raw covers the outlier: scale ~ 10/15; auto clips well below
        assert!(pr[0].scale > 0.5, "raw scale {}", pr[0].scale);
        assert!(pa[0].scale < pr[0].scale * 0.6, "auto did not clip: {}", pa[0].scale);
    }

    #[test]
    fn mse_tensor_rejects_grouped_granularity_and_wants_samples() {
        let tr = RangeTracker::new(Estimator::CurrentMinMax, 4);
        let grid = QGrid::asymmetric(8);
        let pool = Pool::serial();
        let grouped = SiteCfg {
            granularity: Granularity::PerEmbeddingGroup { k: 2, permute: false },
            range_method: RangeMethod::MseTensor,
            ..Default::default()
        };
        assert!(site_lane_params_pool(&tr, &grouped, grid, &pool).is_err());
        // per-tensor granularity but no retained samples under a non-MSE
        // estimator: a clear error, not a silent fallback
        let tensor = SiteCfg { range_method: RangeMethod::MseTensor, ..Default::default() };
        let err = site_lane_params_pool(&tr, &tensor, grid, &pool).unwrap_err();
        assert!(err.to_string().contains("with_row_samples"), "{err}");
        let mse_group = SiteCfg { range_method: RangeMethod::MsePerGroup, ..Default::default() };
        assert!(site_lane_params_pool(&tr, &mse_group, grid, &pool).is_err());
        // K beyond the site's lanes is a spec error at this layer, not a
        // silent per-embedding clamp (site_groups clamps only as a
        // library-level never-panic guarantee)
        let oversized = SiteCfg {
            granularity: Granularity::PerEmbeddingGroup { k: 99, permute: true },
            ..Default::default()
        };
        let err = site_lane_params_pool(&tr, &oversized, grid, &pool).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn mse_group_assembles_per_group_searched_scales() {
        let info = tiny_model_info();
        let s = info.site("layer0.res2_sum").unwrap().clone();
        let d = s.channels;
        let mut rng = crate::util::rng::Rng::new(7);
        // every lane ~U(0,1); lane 3 has one +10 spike (paper §3's
        // range-vs-precision trade-off at 4 bits: clipping the spike is
        // MSE-optimal, min-max keeps it)
        let mut data = Vec::with_capacity(2000 * d);
        for row in 0..2000 {
            for lane in 0..d {
                if lane == 3 && row == 100 {
                    data.push(10.0);
                } else {
                    data.push(rng.uniform(0.0, 1.0));
                }
            }
        }
        let spiky = Tensor::new(vec![2000, d], data).unwrap();
        let mut trackers = BTreeMap::new();
        for site in &info.sites {
            let mut tr =
                RangeTracker::new(Estimator::CurrentMinMax, site.channels).with_row_samples();
            if site.name == s.name {
                tr.observe(&spiky).unwrap();
            } else {
                tr.observe(&Tensor::from_fn(&[4, site.channels], |i| (i % 5) as f32 - 2.0))
                    .unwrap();
            }
            trackers.insert(site.name.clone(), tr);
        }

        let site_cfg = |method: RangeMethod| SiteCfg {
            bits: 4,
            granularity: Granularity::PerEmbeddingGroup { k: 4, permute: true },
            range_method: method,
            enabled: true,
        };
        let policy = |method: RangeMethod| {
            QuantPolicy::uniform(8, 8).with_sites(&[s.name.as_str()], site_cfg(method))
        };
        let searched =
            assemble_act_tensors(&info, &policy(RangeMethod::MsePerGroup), &trackers).unwrap();
        let raw =
            assemble_act_tensors(&info, &policy(RangeMethod::CurrentMinMax), &trackers)
                .unwrap();
        let mm = raw.scales[s.offset + 3];
        let ms = searched.scales[s.offset + 3];
        // min-max keeps the spike (scale ~ 10/15); the searched group clips
        assert!(mm > 0.5, "min-max scale {mm}");
        assert!(ms < mm * 0.6, "searched {ms} !< min-max {mm}");
        assert!(ms > 0.05, "searched scale collapsed: {ms}");
        assert!(searched.permutations.contains_key(&s.name));
        // the spike-free groups are untouched by the spike either way
        let other_max = (0..d)
            .filter(|&j| searched.scales[s.offset + j] != ms)
            .map(|j| searched.scales[s.offset + j])
            .fold(0.0f32, f32::max);
        assert!(other_max < 0.2, "tight groups polluted: {other_max}");
    }
}
