//! Quantization policy: per-site activation settings + weight settings,
//! and their compilation into the flat runtime tensors (act_scales,
//! act_zps, act_cfg) the HLO executables consume (DESIGN.md §3).
//!
//! This is where the paper's configurations become data:
//!   * W8A8 per-tensor PTQ        -> all sites 8-bit PerTensor
//!   * leave-one-out ablation     -> `enabled = false` on a site family
//!   * mixed precision (Table 4)  -> 16-bit on selected sites
//!   * PEG ± permutation (Table 5)-> PerEmbeddingGroup granularity
//!   * QAT                        -> scales learned in-graph, assembled here
//!     for initialisation

use std::collections::BTreeMap;

use anyhow::Result;

use crate::quant::{
    peg::lane_qparams, qparams_from_range, Estimator, Granularity, QGrid, QParams,
};
use crate::quant::estimators::RangeTracker;
use crate::model::manifest::ModelInfo;

/// Per-site activation quantizer configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteCfg {
    pub bits: u32,
    pub granularity: Granularity,
    pub enabled: bool,
}

impl Default for SiteCfg {
    fn default() -> Self {
        SiteCfg { bits: 8, granularity: Granularity::PerTensor, enabled: true }
    }
}

/// Weight quantizer configuration (applied Rust-side on parameter tensors).
#[derive(Debug, Clone, PartialEq)]
pub struct WeightCfg {
    pub bits: u32,
    pub estimator: Estimator,
    /// Q-BERT-style group-wise per-channel quantization (None = per-tensor)
    pub per_channel_groups: Option<usize>,
    pub enabled: bool,
}

impl Default for WeightCfg {
    fn default() -> Self {
        WeightCfg { bits: 8, estimator: Estimator::CurrentMinMax, per_channel_groups: None, enabled: true }
    }
}

/// Full activation policy over a model's sites.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPolicy {
    /// default config for sites not in `overrides`
    pub default: SiteCfg,
    pub overrides: BTreeMap<String, SiteCfg>,
    pub weights: WeightCfg,
    /// per-weight-name overrides (e.g. 2-bit embeddings)
    pub weight_overrides: BTreeMap<String, WeightCfg>,
}

impl QuantPolicy {
    /// Everything FP32 (baseline).
    pub fn fp32() -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { enabled: false, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { enabled: false, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    /// Uniform W{wb}A{ab} per-tensor policy (the paper's W8A8 baseline).
    pub fn uniform(wb: u32, ab: u32) -> QuantPolicy {
        QuantPolicy {
            default: SiteCfg { bits: ab, ..Default::default() },
            overrides: BTreeMap::new(),
            weights: WeightCfg { bits: wb, ..Default::default() },
            weight_overrides: BTreeMap::new(),
        }
    }

    pub fn site_cfg(&self, site: &str) -> &SiteCfg {
        self.overrides.get(site).unwrap_or(&self.default)
    }

    pub fn weight_cfg(&self, name: &str) -> &WeightCfg {
        self.weight_overrides.get(name).unwrap_or(&self.weights)
    }

    /// Override a set of sites (by exact name).
    pub fn with_sites(mut self, sites: &[&str], cfg: SiteCfg) -> QuantPolicy {
        for s in sites {
            self.overrides.insert(s.to_string(), cfg.clone());
        }
        self
    }

    /// Override every site whose name ends with `suffix` across layers
    /// (e.g. "res2_sum" hits layer0..N) — used by the Table 2 ablations
    /// and the PEG "only FFN" configurations.
    pub fn with_site_family(mut self, info: &ModelInfo, suffix: &str, cfg: SiteCfg) -> QuantPolicy {
        for s in &info.sites {
            if s.name.ends_with(suffix) {
                self.overrides.insert(s.name.clone(), cfg.clone());
            }
        }
        self
    }
}

/// The flat tensors the executables take, plus bookkeeping for reports.
#[derive(Debug, Clone)]
pub struct ActQuantTensors {
    pub scales: Vec<f32>,
    pub zps: Vec<f32>,
    /// (n_sites, 3) row-major [qmin, qmax, enable]
    pub cfg: Vec<f32>,
    /// per-site chosen permutation (only when PEG+permute), for reporting
    pub permutations: BTreeMap<String, Vec<usize>>,
}

/// Compile per-site range statistics + policy into runtime tensors.
///
/// `trackers` maps site name -> calibrated RangeTracker (per-lane stats).
pub fn assemble_act_tensors(
    info: &ModelInfo,
    policy: &QuantPolicy,
    trackers: &BTreeMap<String, RangeTracker>,
) -> Result<ActQuantTensors> {
    let mut scales = vec![1.0f32; info.total_scale_lanes];
    let mut zps = vec![0.0f32; info.total_scale_lanes];
    let mut cfg = Vec::with_capacity(info.sites.len() * 3);
    let mut permutations = BTreeMap::new();

    for site in &info.sites {
        let sc = policy.site_cfg(&site.name);
        let grid = QGrid::asymmetric(sc.bits);
        cfg.extend_from_slice(&[grid.qmin, grid.qmax, if sc.enabled { 1.0 } else { 0.0 }]);
        if !sc.enabled {
            continue;
        }
        let tracker = match trackers.get(&site.name) {
            Some(t) => t,
            // unobserved site (e.g. quick tests): harmless wide default
            None => {
                for l in 0..site.channels {
                    scales[site.offset + l] = 1.0;
                    zps[site.offset + l] = 0.0;
                }
                continue;
            }
        };
        let params: Vec<QParams> = if site.channels == 1 {
            let (lo, hi) = tracker.tensor_range(grid);
            vec![qparams_from_range(lo, hi, grid)]
        } else {
            match &sc.granularity {
                Granularity::PerTensor => {
                    let (lo, hi) = tracker.tensor_range(grid);
                    vec![qparams_from_range(lo, hi, grid); site.channels]
                }
                g => {
                    let (lo, hi) = tracker.lane_ranges();
                    let (params, perm) = lane_qparams(&lo, &hi, g, grid)?;
                    if matches!(g, Granularity::PerEmbeddingGroup { permute: true, .. }) {
                        permutations.insert(site.name.clone(), perm);
                    }
                    params
                }
            }
        };
        for (l, p) in params.iter().enumerate() {
            scales[site.offset + l] = p.scale;
            zps[site.offset + l] = p.zero_point;
        }
    }
    Ok(ActQuantTensors { scales, zps, cfg, permutations })
}

/// The paper's activation-quantizer count for mixed-precision accounting
/// ("36 out of 161 activation quantizers", Table 4 footnote).
pub fn count_sites_at_bits(info: &ModelInfo, policy: &QuantPolicy, bits: u32) -> usize {
    info.sites
        .iter()
        .filter(|s| {
            let c = policy.site_cfg(&s.name);
            c.enabled && c.bits == bits
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;
    use crate::quant::Estimator;
    use crate::tensor::Tensor;

    fn calibrated_trackers(info: &ModelInfo) -> BTreeMap<String, RangeTracker> {
        let mut out = BTreeMap::new();
        for s in &info.sites {
            let mut tr = RangeTracker::new(Estimator::CurrentMinMax, s.channels);
            let t = Tensor::from_fn(&[4, s.channels], |i| (i % 7) as f32 - 3.0);
            tr.observe(&t).unwrap();
            out.insert(s.name.clone(), tr);
        }
        out
    }

    #[test]
    fn assemble_shapes_and_enables() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let policy = QuantPolicy::uniform(8, 8);
        let t = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        assert_eq!(t.scales.len(), info.total_scale_lanes);
        assert_eq!(t.cfg.len(), info.sites.len() * 3);
        assert!(t.cfg.chunks(3).all(|c| c[2] == 1.0));

        let fp32 = QuantPolicy::fp32();
        let t2 = assemble_act_tensors(&info, &fp32, &trackers).unwrap();
        assert!(t2.cfg.chunks(3).all(|c| c[2] == 0.0));
    }

    #[test]
    fn per_tensor_scales_uniform_across_lanes() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let t = assemble_act_tensors(&info, &QuantPolicy::uniform(8, 8), &trackers).unwrap();
        let s = info.site("embed_sum").unwrap();
        let lanes = &t.scales[s.offset..s.offset + s.channels];
        assert!(lanes.iter().all(|&x| x == lanes[0]));
    }

    #[test]
    fn mixed_precision_override() {
        let info = tiny_model_info();
        let trackers = calibrated_trackers(&info);
        let policy = QuantPolicy::uniform(8, 8).with_site_family(
            &info,
            "res2_sum",
            SiteCfg { bits: 16, ..Default::default() },
        );
        let t = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        let idx = info.site_index("layer0.res2_sum").unwrap();
        assert_eq!(t.cfg[idx * 3 + 1], 65535.0);
        assert_eq!(count_sites_at_bits(&info, &policy, 16), 1);
        assert_eq!(count_sites_at_bits(&info, &policy, 8), info.sites.len() - 1);
    }

    #[test]
    fn peg_granularity_writes_group_scales() {
        let info = tiny_model_info();
        // make one site have an outlier lane
        let mut trackers = calibrated_trackers(&info);
        let s = info.site("layer0.res2_sum").unwrap().clone();
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, s.channels);
        // lane 3 swings ±50, the others ±0.5 (sign alternates across rows)
        let t = Tensor::from_fn(&[2, s.channels], |i| {
            let sign = if i / s.channels == 0 { 1.0 } else { -1.0 };
            sign * if i % s.channels == 3 { 50.0 } else { 0.5 }
        });
        tr.observe(&t).unwrap();
        trackers.insert(s.name.clone(), tr);

        let policy = QuantPolicy::uniform(8, 8).with_sites(
            &["layer0.res2_sum"],
            SiteCfg {
                bits: 8,
                granularity: Granularity::PerEmbeddingGroup { k: 4, permute: true },
                enabled: true,
            },
        );
        let out = assemble_act_tensors(&info, &policy, &trackers).unwrap();
        let lanes = &out.scales[s.offset..s.offset + s.channels];
        // K=4 over 8 lanes => groups of 2: the outlier lane (3) plus its
        // one group-mate get a large scale, the remaining 6 stay tight
        assert!(lanes[3] > 0.1, "{lanes:?}");
        let tight = lanes.iter().filter(|&&v| v < 0.01).count();
        assert_eq!(tight, 6, "{lanes:?}");
        assert!(out.permutations.contains_key("layer0.res2_sum"));
    }

    #[test]
    fn unobserved_site_gets_safe_defaults() {
        let info = tiny_model_info();
        let trackers = BTreeMap::new();
        let t = assemble_act_tensors(&info, &QuantPolicy::uniform(8, 8), &trackers).unwrap();
        assert!(t.scales.iter().all(|&s| s == 1.0));
    }
}
