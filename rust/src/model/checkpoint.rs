//! Checkpoint I/O: a simple self-describing binary tensor container
//! (safetensors-like, but dependency-free).
//!
//! Layout (little-endian):
//!     magic "TQCKPT01"
//!     u32 tensor count
//!     per tensor: u32 name_len, name bytes, u32 ndim, u64 dims...,
//!                 f32 data...

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Result};

use super::Params;
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"TQCKPT01";

pub fn save(params: &Params, path: impl AsRef<Path>) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.tensors.len() as u32).to_le_bytes())?;
    for (name, t) in params.names.iter().zip(&params.tensors) {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk copy of the f32 payload
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Params> {
    let mut f = std::io::BufReader::new(std::fs::File::open(&path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: bad checkpoint magic", path.as_ref().display());
    }
    let count = read_u32(&mut f)? as usize;
    let mut names = Vec::with_capacity(count);
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > 8 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
        };
        f.read_exact(bytes)?;
        names.push(String::from_utf8(name)?);
        tensors.push(Tensor::new(shape, data)?);
    }
    Ok(Params { names, tensors })
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;

    #[test]
    fn roundtrip() {
        let info = tiny_model_info();
        let p = Params::init(&info, 33);
        let dir = std::env::temp_dir().join("tq_ckpt_test");
        let path = dir.join("a.ckpt");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(p.names, q.names);
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("tq_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_truncated() {
        let info = tiny_model_info();
        let p = Params::init(&info, 1);
        let dir = std::env::temp_dir().join("tq_ckpt_trunc");
        let path = dir.join("t.ckpt");
        save(&p, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
