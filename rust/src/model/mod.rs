//! Model topology metadata (mirroring python/compile/model.py via the AOT
//! manifest), parameter store, checkpoint I/O, and assembly of the runtime
//! quantization-policy tensors.

pub mod checkpoint;
pub mod manifest;
pub mod qconfig;

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;
use manifest::ModelInfo;

/// Ordered parameter store (order == the executable input signature).
#[derive(Debug, Clone)]
pub struct Params {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// Seeded initialisation mirroring L2's `init_params` (biases zero,
    /// LayerNorm gains one, weights N(0, 0.02)).
    ///
    /// Note: the values intentionally do NOT need to match jax's init —
    /// training runs entirely through the HLO train-step executables, so
    /// any sane init works; determinism per seed is what matters.
    pub fn init(info: &ModelInfo, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        for p in &info.params {
            names.push(p.name.clone());
            let t = if p.name.ends_with(".b") {
                Tensor::zeros(&p.shape)
            } else if p.name.ends_with(".g") {
                Tensor::full(&p.shape, 1.0)
            } else {
                Tensor::randn(&p.shape, 0.02, &mut rng)
            };
            tensors.push(t);
        }
        Params { names, tensors }
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("no param {name:?}"))
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        Ok(&self.tensors[self.index_of(name)?])
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = self.index_of(name)?;
        Ok(&mut self.tensors[i])
    }

    pub fn zeros_like(&self) -> Params {
        Params {
            names: self.names.clone(),
            tensors: self.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect(),
        }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Model size in bytes at a given storage layout: `weight_bits` for
    /// matmul weights, `embed_bits` for the token-embedding table, 32-bit
    /// for everything else (biases, LayerNorm). Used for the paper's
    /// Table 7 "memory reduction" column.
    pub fn size_bytes(&self, info: &ModelInfo, weight_bits: u32, embed_bits: u32) -> usize {
        let mut bits = 0usize;
        for (n, t) in self.names.iter().zip(&self.tensors) {
            let b = if n == "embed.tok" {
                embed_bits as usize
            } else if info.wq.iter().any(|w| w == n) {
                weight_bits as usize
            } else {
                32
            };
            bits += t.len() * b;
        }
        bits / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::tests::tiny_model_info;

    #[test]
    fn init_is_deterministic_and_typed() {
        let info = tiny_model_info();
        let a = Params::init(&info, 7);
        let b = Params::init(&info, 7);
        let c = Params::init(&info, 8);
        assert_eq!(a.tensors[0].data(), b.tensors[0].data());
        assert_ne!(a.get("embed.tok").unwrap().data(), c.get("embed.tok").unwrap().data());
        // biases zero, gains one
        assert!(a.get("embed.ln.b").unwrap().data().iter().all(|&x| x == 0.0));
        assert!(a.get("embed.ln.g").unwrap().data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn size_accounting() {
        let info = tiny_model_info();
        let p = Params::init(&info, 1);
        let fp32 = p.size_bytes(&info, 32, 32);
        let w8 = p.size_bytes(&info, 8, 8);
        assert_eq!(fp32, p.num_params() * 4);
        assert!(w8 < fp32);
    }
}
