//! AOT manifest: the machine-readable contract between L2 (aot.py) and the
//! Rust runtime — artifact input/output signatures, model topology
//! (parameter & quantizer-site specs), and golden test vectors.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

impl ArtifactSig {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output {name:?}", self.name))
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SiteSpec {
    pub name: String,
    /// lanes this site contributes to the flat act_scales vector (d, d_ff
    /// or 1 for scalar-granularity sites)
    pub channels: usize,
    /// offset of the first lane
    pub offset: usize,
}

/// Model architecture family. The discriminant decides the data-input
/// contract of the forward/diag executables (token ids + type ids + mask
/// for BERT; a flat pixel-patch tensor for ViT) and which
/// per-architecture fields [`ArchParams`] carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Architecture {
    Bert,
    Vit,
}

impl Architecture {
    pub fn name(self) -> &'static str {
        match self {
            Architecture::Bert => "bert",
            Architecture::Vit => "vit",
        }
    }

    pub fn parse(s: &str) -> Result<Architecture> {
        match s {
            "bert" => Ok(Architecture::Bert),
            "vit" => Ok(Architecture::Vit),
            other => Err(anyhow!("unknown architecture {other:?} (bert|vit)")),
        }
    }
}

/// Attention-block variant within an architecture family. The follow-up
/// paper's outlier-free designs are graph-level changes to the attention
/// block, orthogonal to the frontend (`Architecture`): clipped softmax
/// stretches the probabilities to `(ζ−γ)·softmax(x)+γ` and clamps to
/// [0,1] so heads can emit exact zeros; gated attention multiplies the
/// per-head context by a learned sigmoid gate `G(x)` so heads can switch
/// themselves off. `Vanilla` is the absent-tag default everywhere
/// (manifests, specs), keeping pre-variant artifacts and spec_ids stable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum AttnVariant {
    #[default]
    Vanilla,
    ClippedSoftmax,
    Gated,
}

impl AttnVariant {
    pub fn name(self) -> &'static str {
        match self {
            AttnVariant::Vanilla => "vanilla",
            AttnVariant::ClippedSoftmax => "clipped_softmax",
            AttnVariant::Gated => "gated",
        }
    }

    /// Short tag used in artifact / model / checkpoint names (empty for
    /// vanilla, whose names predate the variant axis).
    pub fn tag(self) -> &'static str {
        match self {
            AttnVariant::Vanilla => "",
            AttnVariant::ClippedSoftmax => "csoft",
            AttnVariant::Gated => "gate",
        }
    }

    pub fn parse(s: &str) -> Result<AttnVariant> {
        match s {
            "vanilla" => Ok(AttnVariant::Vanilla),
            "clipped_softmax" => Ok(AttnVariant::ClippedSoftmax),
            "gated" => Ok(AttnVariant::Gated),
            other => Err(anyhow!(
                "unknown attention variant {other:?} (vanilla|clipped_softmax|gated)"
            )),
        }
    }
}

/// Family prefix used inside artifact and checkpoint names
/// (`fwd_{prefix}{head}_b{n}`, `{prefix}{task}.ckpt`). BERT-vanilla names
/// predate both axes and stay unprefixed; ViT keeps its `vit_` prefix;
/// variant families append their tag.
pub fn family_prefix(arch: Architecture, variant: AttnVariant) -> String {
    let tag = variant.tag();
    match (arch, tag.is_empty()) {
        (Architecture::Bert, true) => String::new(),
        (Architecture::Bert, false) => format!("{tag}_"),
        (Architecture::Vit, true) => "vit_".to_string(),
        (Architecture::Vit, false) => format!("vit_{tag}_"),
    }
}

/// Manifest model-row name for a family. Vanilla rows keep their legacy
/// names ("base"/"base_reg", "vit"/"vit_reg"); variant rows are
/// "bert_csoft", "vit_gate_reg", etc.
pub fn model_name(arch: Architecture, variant: AttnVariant, regression: bool) -> String {
    let stem = match (arch, variant) {
        (Architecture::Bert, AttnVariant::Vanilla) => "base".to_string(),
        (Architecture::Vit, AttnVariant::Vanilla) => "vit".to_string(),
        (Architecture::Bert, v) => format!("bert_{}", v.tag()),
        (Architecture::Vit, v) => format!("vit_{}", v.tag()),
    };
    if regression {
        format!("{stem}_reg")
    } else {
        stem
    }
}

/// Architecture-specific model descriptor fields. BERT models carry the
/// special token ids its input/diagnostic paths key on; ViT models carry
/// the patch geometry (`seq = (img/patch)^2`, patch vectors of length
/// `patch*patch`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArchParams {
    Bert { pad_id: i32, cls_id: i32, sep_id: i32 },
    Vit { patch: usize, img: usize },
}

impl ArchParams {
    pub fn architecture(&self) -> Architecture {
        match self {
            ArchParams::Bert { .. } => Architecture::Bert,
            ArchParams::Vit { .. } => Architecture::Vit,
        }
    }

    pub fn pad_id(&self) -> Option<i32> {
        match self {
            ArchParams::Bert { pad_id, .. } => Some(*pad_id),
            ArchParams::Vit { .. } => None,
        }
    }

    pub fn cls_id(&self) -> Option<i32> {
        match self {
            ArchParams::Bert { cls_id, .. } => Some(*cls_id),
            ArchParams::Vit { .. } => None,
        }
    }

    pub fn sep_id(&self) -> Option<i32> {
        match self {
            ArchParams::Bert { sep_id, .. } => Some(*sep_id),
            ArchParams::Vit { .. } => None,
        }
    }

    pub fn patch(&self) -> Option<usize> {
        match self {
            ArchParams::Bert { .. } => None,
            ArchParams::Vit { patch, .. } => Some(*patch),
        }
    }

    pub fn img(&self) -> Option<usize> {
        match self {
            ArchParams::Bert { .. } => None,
            ArchParams::Vit { img, .. } => Some(*img),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub heads: usize,
    pub layers: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub n_out: usize,
    pub outlier_dims: Vec<usize>,
    pub arch: ArchParams,
    /// attention-block variant; `Vanilla` when the manifest carries no
    /// "variant" key (pre-variant manifests stay loadable unchanged)
    pub variant: AttnVariant,
}

impl ModelConfig {
    pub fn architecture(&self) -> Architecture {
        self.arch.architecture()
    }

    /// Length of one flattened input patch vector (ViT only).
    pub fn patch_dim(&self) -> Option<usize> {
        self.arch.patch().map(|p| p * p)
    }
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub config: ModelConfig,
    pub params: Vec<ParamSpec>,
    pub sites: Vec<SiteSpec>,
    pub total_scale_lanes: usize,
    /// weight tensors with (QAT-learnable) per-tensor quantizers
    pub wq: Vec<String>,
}

impl ModelInfo {
    pub fn site(&self, name: &str) -> Result<&SiteSpec> {
        self.sites
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("no site {name:?}"))
    }

    pub fn site_index(&self, name: &str) -> Result<usize> {
        self.sites
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("no site {name:?}"))
    }
}

/// Golden fake-quant vectors emitted by aot.py for bit-exact cross-layer
/// testing of the Rust quantization simulation.
#[derive(Debug, Clone)]
pub struct GoldenFakeQuant {
    pub x: Vec<f32>,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
    pub qmin: f32,
    pub qmax: f32,
    pub rows: usize,
    pub cols: usize,
    pub out: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSig>,
    pub models: BTreeMap<String, ModelInfo>,
    pub golden_fake_quant: Option<GoldenFakeQuant>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSig {
                    name: name.clone(),
                    file: dir.join(a.get("file")?.as_str()?),
                    inputs: parse_sigs(a.get("inputs")?)?,
                    outputs: parse_sigs(a.get("outputs")?)?,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in v.get("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(m)?);
        }
        let golden_fake_quant = match v.opt("golden").and_then(|g| g.opt("fake_quant")) {
            Some(g) => Some(GoldenFakeQuant {
                x: g.get("x")?.as_f32_vec()?,
                scale: g.get("scale")?.as_f32_vec()?,
                zp: g.get("zp")?.as_f32_vec()?,
                qmin: g.get("qmin")?.as_f64()? as f32,
                qmax: g.get("qmax")?.as_f64()? as f32,
                rows: g.get("rows")?.as_usize()?,
                cols: g.get("cols")?.as_usize()?,
                out: g.get("out")?.as_f32_vec()?,
            }),
            None => None,
        };
        Ok(Manifest { artifacts, models, golden_fake_quant, dir })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name:?} in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model {name:?} in manifest"))
    }
}

fn parse_sigs(v: &Json) -> Result<Vec<TensorSig>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t.get("shape")?.as_usize_vec()?,
                dtype: t.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

fn parse_model(m: &Json) -> Result<ModelInfo> {
    let c = m.get("config")?;
    // "architecture" is optional and defaults to "bert": manifests written
    // before the discriminant existed stay loadable unchanged
    let arch_name = match c.opt("architecture") {
        Some(v) => Architecture::parse(v.as_str()?)?,
        None => Architecture::Bert,
    };
    let arch = match arch_name {
        Architecture::Bert => ArchParams::Bert {
            pad_id: c.get("pad_id")?.as_f64()? as i32,
            cls_id: c.get("cls_id")?.as_f64()? as i32,
            sep_id: c.get("sep_id")?.as_f64()? as i32,
        },
        Architecture::Vit => ArchParams::Vit {
            patch: c.get("patch")?.as_usize()?,
            img: c.get("img")?.as_usize()?,
        },
    };
    // "variant" is optional like "architecture": absent reads as vanilla
    let variant = match c.opt("variant") {
        Some(v) => AttnVariant::parse(v.as_str()?)?,
        None => AttnVariant::Vanilla,
    };
    let config = ModelConfig {
        name: c.get("name")?.as_str()?.to_string(),
        vocab: c.get("vocab")?.as_usize()?,
        d: c.get("d")?.as_usize()?,
        heads: c.get("heads")?.as_usize()?,
        layers: c.get("layers")?.as_usize()?,
        d_ff: c.get("d_ff")?.as_usize()?,
        seq: c.get("seq")?.as_usize()?,
        n_out: c.get("n_out")?.as_usize()?,
        outlier_dims: c.get("outlier_dims")?.as_usize_vec()?,
        arch,
        variant,
    };
    let params = m
        .get("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p.get("shape")?.as_usize_vec()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let sites = m
        .get("sites")?
        .as_arr()?
        .iter()
        .map(|s| {
            Ok(SiteSpec {
                name: s.get("name")?.as_str()?.to_string(),
                channels: s.get("channels")?.as_usize()?,
                offset: s.get("offset")?.as_usize()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let wq = m
        .get("wq")?
        .as_arr()?
        .iter()
        .map(|s| Ok(s.as_str()?.to_string()))
        .collect::<Result<Vec<_>>>()?;
    Ok(ModelInfo {
        config,
        params,
        sites,
        total_scale_lanes: m.get("total_scale_lanes")?.as_usize()?,
        wq,
    })
}

#[cfg(test)]
pub mod tests {
    use super::*;

    /// A small hand-built ModelInfo for unit tests that don't need the real
    /// manifest on disk.
    pub fn tiny_model_info() -> ModelInfo {
        let d = 8;
        let mut sites = Vec::new();
        let mut off = 0;
        for (name, c) in [("embed_sum", d), ("layer0.res2_sum", d), ("head_out", 1)] {
            sites.push(SiteSpec { name: name.into(), channels: c, offset: off });
            off += c;
        }
        ModelInfo {
            config: ModelConfig {
                name: "tiny".into(),
                vocab: 16,
                d,
                heads: 2,
                layers: 1,
                d_ff: 16,
                seq: 8,
                n_out: 3,
                outlier_dims: vec![1],
                arch: ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
                variant: AttnVariant::Vanilla,
            },
            params: vec![
                ParamSpec { name: "embed.tok".into(), shape: vec![16, d] },
                ParamSpec { name: "embed.ln.g".into(), shape: vec![d] },
                ParamSpec { name: "embed.ln.b".into(), shape: vec![d] },
                ParamSpec { name: "layer0.ffn1.w".into(), shape: vec![d, 16] },
            ],
            sites,
            total_scale_lanes: off,
            wq: vec!["embed.tok".into(), "layer0.ffn1.w".into()],
        }
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "artifacts": {"fwd": {"file": "fwd.hlo.txt",
            "inputs": [{"name": "x", "shape": [2], "dtype": "f32"}],
            "outputs": [{"name": "y", "shape": [], "dtype": "f32"}]}},
          "models": {"tiny": {
            "config": {"name": "tiny", "vocab": 16, "d": 8, "heads": 2,
                       "layers": 1, "d_ff": 16, "seq": 8, "n_out": 3,
                       "outlier_dims": [1], "pad_id": 0, "cls_id": 1,
                       "sep_id": 2, "mask_bias": -30.0},
            "params": [{"name": "embed.tok", "shape": [16, 8]}],
            "sites": [{"name": "embed_sum", "channels": 8, "offset": 0}],
            "total_scale_lanes": 8,
            "wq": ["embed.tok"],
            "adam": {"b1": 0.9, "b2": 0.999, "eps": 1e-8}}},
          "golden": {"fake_quant": {"x": [1.0], "scale": [0.5], "zp": [0],
            "qmin": 0, "qmax": 255, "rows": 1, "cols": 1, "out": [1.0]}}
        }"#;
        let m = Manifest::parse(text, PathBuf::from("/tmp/a")).unwrap();
        let a = m.artifact("fwd").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2]);
        assert_eq!(a.file, PathBuf::from("/tmp/a/fwd.hlo.txt"));
        let info = m.model("tiny").unwrap();
        assert_eq!(info.config.d, 8);
        assert_eq!(info.site("embed_sum").unwrap().channels, 8);
        // no "architecture" key: pre-discriminant manifests default to BERT
        assert_eq!(info.config.architecture(), Architecture::Bert);
        // no "variant" key: pre-variant manifests default to vanilla
        assert_eq!(info.config.variant, AttnVariant::Vanilla);
        assert_eq!(info.config.arch.sep_id(), Some(2));
        assert_eq!(info.config.arch.patch(), None);
        assert!(m.golden_fake_quant.is_some());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn parses_vit_model_architecture() {
        let text = r#"{
          "artifacts": {},
          "models": {"vit": {
            "config": {"name": "vit", "architecture": "vit", "vocab": 64,
                       "d": 8, "heads": 2, "layers": 1, "d_ff": 16,
                       "seq": 16, "n_out": 3, "outlier_dims": [1],
                       "patch": 4, "img": 16},
            "params": [{"name": "embed.patch.w", "shape": [16, 8]}],
            "sites": [{"name": "embed_sum", "channels": 8, "offset": 0}],
            "total_scale_lanes": 8,
            "wq": ["embed.patch.w"]}}
        }"#;
        let m = Manifest::parse(text, PathBuf::from("/tmp/a")).unwrap();
        let info = m.model("vit").unwrap();
        assert_eq!(info.config.architecture(), Architecture::Vit);
        assert_eq!(info.config.arch, ArchParams::Vit { patch: 4, img: 16 });
        assert_eq!(info.config.patch_dim(), Some(16));
        assert_eq!(info.config.arch.pad_id(), None);
        // seq must be consistent with the patch grid
        assert_eq!(info.config.seq, (16 / 4) * (16 / 4));
        // an unknown architecture name is an error, not a silent default
        assert!(Architecture::parse("rnn").is_err());
    }

    #[test]
    fn parses_attention_variant() {
        let model = |variant_line: &str| {
            format!(
                r#"{{
              "artifacts": {{}},
              "models": {{"m": {{
                "config": {{"name": "m", "vocab": 16, "d": 8, "heads": 2,
                           "layers": 1, "d_ff": 16, "seq": 8, "n_out": 3,
                           "outlier_dims": [], "pad_id": 0, "cls_id": 1,
                           "sep_id": 2{variant_line}}},
                "params": [], "sites": [], "total_scale_lanes": 0,
                "wq": []}}}}
            }}"#
            )
        };
        let parse = |line: &str| {
            Manifest::parse(&model(line), PathBuf::from("/tmp/a"))
                .map(|m| m.model("m").unwrap().config.variant)
        };
        assert_eq!(parse("").unwrap(), AttnVariant::Vanilla);
        assert_eq!(parse(r#", "variant": "vanilla""#).unwrap(), AttnVariant::Vanilla);
        assert_eq!(
            parse(r#", "variant": "clipped_softmax""#).unwrap(),
            AttnVariant::ClippedSoftmax
        );
        assert_eq!(parse(r#", "variant": "gated""#).unwrap(), AttnVariant::Gated);
        // typo'd tags are an error, not a silent vanilla
        assert!(parse(r#", "variant": "clipped""#).is_err());
        // name <-> parse round trip, and the tag contract names are stable
        for v in [AttnVariant::Vanilla, AttnVariant::ClippedSoftmax, AttnVariant::Gated] {
            assert_eq!(AttnVariant::parse(v.name()).unwrap(), v);
        }
        assert_eq!(AttnVariant::Vanilla.tag(), "");
        assert_eq!(AttnVariant::ClippedSoftmax.tag(), "csoft");
        assert_eq!(AttnVariant::Gated.tag(), "gate");
        // naming contracts: vanilla families keep their legacy names
        assert_eq!(family_prefix(Architecture::Bert, AttnVariant::Vanilla), "");
        assert_eq!(family_prefix(Architecture::Bert, AttnVariant::Gated), "gate_");
        assert_eq!(family_prefix(Architecture::Vit, AttnVariant::Vanilla), "vit_");
        assert_eq!(family_prefix(Architecture::Vit, AttnVariant::ClippedSoftmax), "vit_csoft_");
        assert_eq!(model_name(Architecture::Bert, AttnVariant::Vanilla, false), "base");
        assert_eq!(model_name(Architecture::Bert, AttnVariant::Vanilla, true), "base_reg");
        assert_eq!(model_name(Architecture::Vit, AttnVariant::Vanilla, false), "vit");
        assert_eq!(model_name(Architecture::Bert, AttnVariant::ClippedSoftmax, false), "bert_csoft");
        assert_eq!(model_name(Architecture::Vit, AttnVariant::Gated, true), "vit_gate_reg");
    }

    #[test]
    fn input_output_index() {
        let a = ArtifactSig {
            name: "t".into(),
            file: PathBuf::new(),
            inputs: vec![
                TensorSig { name: "a".into(), shape: vec![], dtype: "f32".into() },
                TensorSig { name: "b".into(), shape: vec![], dtype: "i32".into() },
            ],
            outputs: vec![TensorSig { name: "y".into(), shape: vec![], dtype: "f32".into() }],
        };
        assert_eq!(a.input_index("b").unwrap(), 1);
        assert!(a.input_index("z").is_err());
        assert_eq!(a.output_index("y").unwrap(), 0);
    }
}
