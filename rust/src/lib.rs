//! # tq — Efficient Transformer Quantization (EMNLP 2021) reproduction
//!
//! Three-layer Rust + JAX + Pallas system reproducing Bondarenko, Nagel &
//! Blankevoort, *"Understanding and Overcoming the Challenges of Efficient
//! Transformer Quantization"* (EMNLP 2021).
//!
//! * **L1** (`python/compile/kernels/`): Pallas fake-quant / PEG-matmul /
//!   LayerNorm kernels, verified against pure-jnp oracles.
//! * **L2** (`python/compile/model.py`): BERT-style encoder with
//!   runtime-parameterised quantizers, AOT-lowered to HLO text.
//! * **L3** (this crate): the quantization pipeline — calibration, range
//!   estimation, PEG grouping with range-based permutation, mixed
//!   precision, AdaRound, QAT driving, synthetic-GLUE evaluation and the
//!   paper's experiment reproductions — executing the AOT artifacts via
//!   the PJRT CPU client (`xla` crate) or, when no PJRT backend is
//!   available, the in-repo HLO interpreter (`crate::hlo`). Python never
//!   runs at request time.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod hlo;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod spec;
pub mod tensor;
pub mod util;
