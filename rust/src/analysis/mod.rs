//! Static analysis over quantization specs and lowered graphs
//! (DESIGN.md §13).
//!
//! [`crate::hlo::verify`](mod@crate::hlo::verify) answers "is this
//! module well-formed?"; this
//! layer answers "is this *quantization configuration* of a well-formed
//! module going to silently hurt accuracy?" — the hazards the paper
//! traces to specific graph sites (residual-sum outliers, §3) or to
//! spec/topology mismatches that the runtime only surfaces deep inside a
//! calibration run, if at all.

pub mod lint;
pub mod outliers;

pub use lint::{cmd_lint, lint_graph, lint_policy, lint_spec_rules, Diag, Severity};
pub use outliers::{cmd_diag, outlier_stats, SiteAccum, SiteStats};
