//! Quantization-hazard linter: `repro lint` (DESIGN.md §13).
//!
//! Two passes share one diagnostic surface:
//!
//!   * **artifact verification** — every module in the manifest must
//!     parse and pass the static verifier
//!     ([`crate::hlo::verify`](mod@crate::hlo::verify));
//!     those findings keep their TQ1xx codes (TQ100 = parse error).
//!   * **spec linting** — each quantization spec is checked against each
//!     model topology and its lowered forward graph for the hazards
//!     below (TQ0xx).
//!
//! | code  | severity | meaning |
//! |-------|----------|---------|
//! | TQ001 | deny | residual add consumes an unquantized activation |
//! | TQ002 | deny | hard-coded clamp bounds != declared bit-width grid |
//! | TQ003 | warn | spec rule matches no site in this topology (dead) |
//! | TQ004 | warn | spec rule fully shadowed by later rules |
//! | TQ005 | warn | overlapping rules with identical configs (redundant) |
//! | TQ006 | deny | PEG group count K invalid for the site's lane count |
//! | TQ007 | deny | `mse_tensor` range method on grouped granularity |
//! | TQ008 | deny | fake-quant wiring mismatch (cfg row / lane slice) |
//!
//! TQ001 is the paper's central failure mode (§3): the residual sums
//! carry the outlier activations, and a quantized residual sum fed by an
//! *unquantized* producer means calibration never saw the tensor the
//! deployed kernel will actually quantize. The graph pass therefore
//! recognises every fake-quant block structurally (the QDQ pattern
//! [`crate::hlo::fixture`] lowers) instead of trusting site metadata.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::hlo::parser::{parse_literal_numbers, parse_slice_ranges, Computation};
use crate::hlo::{parse_module, verify_module, DType, HloModule, Shape};
use crate::model::manifest::{Manifest, ModelInfo};
use crate::model::qconfig::QuantPolicy;
use crate::quant::{Granularity, QGrid, RangeMethod};
use crate::spec::{presets, PolicySpec, QuantSpec};
use crate::util::cli::Args;
use crate::util::json::{obj, Json};

/// Finding severity: `Deny` makes `repro lint` exit non-zero, `Warn` is
/// advisory (dead-rule visibility, redundant layering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warn,
    Deny,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// One lint finding with a stable diagnostic code.
#[derive(Debug, Clone)]
pub struct Diag {
    pub code: &'static str,
    pub severity: Severity,
    /// where: `spec/model: site` or `artifact/%computation/%instruction`
    pub loc: String,
    pub msg: String,
}

impl Diag {
    fn deny(code: &'static str, loc: impl Into<String>, msg: impl Into<String>) -> Diag {
        Diag { code, severity: Severity::Deny, loc: loc.into(), msg: msg.into() }
    }

    fn warn(code: &'static str, loc: impl Into<String>, msg: impl Into<String>) -> Diag {
        Diag { code, severity: Severity::Warn, loc: loc.into(), msg: msg.into() }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("code", Json::Str(self.code.to_string())),
            ("severity", Json::Str(self.severity.name().to_string())),
            ("loc", Json::Str(self.loc.clone())),
            ("msg", Json::Str(self.msg.clone())),
        ])
    }
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [{}] {}: {}", self.severity.name(), self.code, self.loc, self.msg)
    }
}

// ---------------------------------------------------------------------------
// rule-level lints (TQ003-TQ005): spec vs topology, before resolution
// ---------------------------------------------------------------------------

/// Lint a spec's site rules against one model topology: dead rules
/// (TQ003), fully shadowed rules (TQ004), redundant identical overlaps
/// (TQ005). All warn-level — `resolve` installs them silently either
/// way, which is exactly why they need surfacing.
pub fn lint_spec_rules(spec: &PolicySpec, info: &ModelInfo) -> Vec<Diag> {
    let matched: Vec<Vec<String>> =
        spec.rules.iter().map(|r| r.select.matching_sites(info)).collect();
    // later rules win per site, mirroring resolve()'s insert order
    let mut owner: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, m) in matched.iter().enumerate() {
        for s in m {
            owner.insert(s.as_str(), i);
        }
    }
    let mut out = Vec::new();
    for (i, (rule, m)) in spec.rules.iter().zip(&matched).enumerate() {
        let loc = format!("rule #{i} ({})", rule.select.describe());
        if m.is_empty() {
            out.push(Diag::warn(
                "TQ003",
                loc,
                "matches no site in this topology (dead rule) — a typo'd site \
                 name silently leaves the site at the spec default",
            ));
        } else if m.iter().all(|s| owner.get(s.as_str()) != Some(&i)) {
            out.push(Diag::warn(
                "TQ004",
                loc,
                format!(
                    "every matched site (e.g. {}) is overridden by a later rule — \
                     this rule has no effect (fully shadowed)",
                    m[0]
                ),
            ));
        }
    }
    for i in 0..spec.rules.len() {
        for j in (i + 1)..spec.rules.len() {
            if spec.rules[i].cfg != spec.rules[j].cfg {
                // broad-then-specific layering with *different* configs is
                // the idiomatic spec style; only identical configs are noise
                continue;
            }
            if let Some(shared) = matched[i].iter().find(|s| matched[j].contains(*s)) {
                out.push(Diag::warn(
                    "TQ005",
                    format!("rule #{j} ({})", spec.rules[j].select.describe()),
                    format!(
                        "duplicates rule #{i} ({}) with an identical config on \
                         shared site {shared} (redundant overlap)",
                        spec.rules[i].select.describe()
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// resolved-policy lints (TQ006-TQ007): per-site config vs site geometry
// ---------------------------------------------------------------------------

/// Lint a resolved policy against the sites it will configure: PEG K vs
/// lane count (TQ006) and range-method/granularity contradictions
/// (TQ007). Both deny — assembly ([`crate::model::qconfig`]) rejects
/// them too, but only deep inside a calibration run.
pub fn lint_policy(policy: &QuantPolicy, info: &ModelInfo) -> Vec<Diag> {
    let mut out = Vec::new();
    for site in &info.sites {
        let cfg = policy.site_cfg(&site.name);
        if !cfg.enabled {
            continue;
        }
        let loc = format!("site {}", site.name);
        if let Granularity::PerEmbeddingGroup { k, .. } = &cfg.granularity {
            if *k == 0 {
                out.push(Diag::deny("TQ006", loc.clone(), "per-embedding-group K must be >= 1"));
            } else if *k > site.channels {
                out.push(Diag::deny(
                    "TQ006",
                    loc.clone(),
                    format!(
                        "K={k} exceeds the site's {} lane(s) — assembly will \
                         reject this spec (use per_embedding or a smaller K)",
                        site.channels
                    ),
                ));
            }
        }
        if cfg.range_method == RangeMethod::MseTensor
            && cfg.granularity != Granularity::PerTensor
        {
            out.push(Diag::deny(
                "TQ007",
                loc,
                format!(
                    "range_method mse_tensor requires per_tensor granularity \
                     (got {:?}) — use mse_group for grouped sites",
                    cfg.granularity
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// graph lints (TQ001, TQ002, TQ008): the lowered QDQ blocks themselves
// ---------------------------------------------------------------------------

/// Where a traced graph value ultimately comes from.
#[derive(Debug, Clone, PartialEq)]
enum Src {
    /// a scalar f32 constant (possibly broadcast)
    Const(f32),
    /// a rectangular window of entry parameter `param`: per-dim
    /// `[lo, hi)` after composing the stride-1 slice chain
    Window { param: usize, ranges: Vec<(usize, usize)> },
    Opaque,
}

fn inst_idx(c: &Computation, name: &str) -> Option<usize> {
    c.index.get(name).copied()
}

/// Walk a value upward through broadcasts/reshapes, composing
/// consecutive stride-1 slices, until a parameter or scalar constant.
/// Anything else (or a reshape *between* parameter and slice, which
/// would scramble the window coordinates) is `Opaque`.
fn trace(c: &Computation, start: usize) -> Src {
    let mut i = start;
    // accumulated window in the coordinates of inst `i`'s output; the
    // first (outermost) slice seeds it, deeper slices shift it
    let mut acc: Option<Vec<(usize, usize)>> = None;
    // def-before-use makes cycles impossible; the bound is belt-and-braces
    for _ in 0..64 {
        let inst = &c.insts[i];
        match inst.opcode.as_str() {
            "broadcast" | "reshape" => {
                if inst.opcode == "reshape" && acc.is_some() {
                    return Src::Opaque;
                }
                match inst.operands.first().and_then(|n| inst_idx(c, n)) {
                    Some(j) => i = j,
                    None => return Src::Opaque,
                }
            }
            "slice" => {
                let Ok(raw) = inst.attr_str("slice") else { return Src::Opaque };
                let Ok(ranges) = parse_slice_ranges(raw) else { return Src::Opaque };
                if ranges.iter().any(|&(_, _, st)| st != 1) {
                    return Src::Opaque;
                }
                let win: Vec<(usize, usize)> = match &acc {
                    None => ranges.iter().map(|&(lo, hi, _)| (lo, hi)).collect(),
                    Some(outer) => {
                        if outer.len() != ranges.len() {
                            return Src::Opaque;
                        }
                        outer
                            .iter()
                            .zip(&ranges)
                            .map(|(&(olo, ohi), &(ilo, _, _))| (ilo + olo, ilo + ohi))
                            .collect()
                    }
                };
                acc = Some(win);
                match inst.operands.first().and_then(|n| inst_idx(c, n)) {
                    Some(j) => i = j,
                    None => return Src::Opaque,
                }
            }
            "parameter" => {
                let Some(p) =
                    inst.payload.as_deref().and_then(|s| s.trim().parse::<usize>().ok())
                else {
                    return Src::Opaque;
                };
                let ranges = match acc {
                    Some(r) => r,
                    None => match &inst.shape {
                        Shape::Array { dims, .. } => dims.iter().map(|&d| (0, d)).collect(),
                        Shape::Tuple(_) => return Src::Opaque,
                    },
                };
                return Src::Window { param: p, ranges };
            }
            "constant" => {
                if acc.is_some() {
                    return Src::Opaque;
                }
                let Some(payload) = inst.payload.as_deref() else { return Src::Opaque };
                let Ok(nums) = parse_literal_numbers(payload) else { return Src::Opaque };
                return match nums[..] {
                    [v] => Src::Const(v as f32),
                    _ => Src::Opaque,
                };
            }
            _ => return Src::Opaque,
        }
    }
    Src::Opaque
}

/// One fake-quant block recognised in a lowered graph.
struct FqMatch {
    /// pre-quant activation instruction (the QDQ input `x`)
    input: usize,
    /// final `select(enable, dq, x)` instruction, when found
    output: Option<usize>,
    /// index into `info.sites`, when identifiable
    site: Option<usize>,
}

/// Lint one lowered forward graph against a resolved policy: recognise
/// every QDQ block `clamp(qmin, round(x / s) + z, qmax)` structurally,
/// check its wiring against the site table (TQ008), hard-coded bounds
/// against the declared grid (TQ002), and — the paper's §3 hazard — that
/// every enabled residual-sum site quantizes an add of *quantized*
/// operands (TQ001).
pub fn lint_graph(m: &HloModule, info: &ModelInfo, policy: &QuantPolicy) -> Result<Vec<Diag>> {
    let c = m.entry();
    let n_sites = info.sites.len();
    let total = info.total_scale_lanes;

    // locate the (act_scales, act_zps, act_cfg) parameter triple:
    // act_cfg is the [n_sites, 3] f32 parameter immediately preceded by
    // the two [total] lane vectors (build_forward's layout). Train-step
    // graphs interleave the Adam moment vectors with the quantizer state
    // (act_scales, m_scales, v_scales, act_zps, act_cfg —
    // build_train_step's layout), so when the two slots before act_zps
    // are *also* [total] lane vectors the scale source sits four back.
    let dims_of = |pi: usize| -> Option<&[usize]> {
        match &c.insts[c.params[pi]].shape {
            Shape::Array { dtype: DType::F32, dims } => Some(dims.as_slice()),
            _ => None,
        }
    };
    let mut cfg_param = None;
    for pi in 2..c.params.len() {
        if dims_of(pi).is_some_and(|d| *d == [n_sites, 3])
            && dims_of(pi - 1).is_some_and(|d| *d == [total])
            && dims_of(pi - 2).is_some_and(|d| *d == [total])
        {
            cfg_param = Some(pi);
            break;
        }
    }
    let Some(cfg_p) = cfg_param else {
        bail!(
            "module {}: no (act_scales[{total}], act_zps[{total}], \
             act_cfg[{n_sites}x3]) parameter triple — not a quantized forward \
             graph for model {}",
            m.name,
            info.config.name
        );
    };
    let zps_p = cfg_p - 1;
    let scales_p = if cfg_p >= 4
        && dims_of(cfg_p - 3).is_some_and(|d| *d == [total])
        && dims_of(cfg_p - 4).is_some_and(|d| *d == [total])
    {
        cfg_p - 4
    } else {
        cfg_p - 2
    };

    // consumer index, for walking clamp -> subtract -> multiply -> select
    let mut uses: Vec<Vec<usize>> = vec![Vec::new(); c.insts.len()];
    for (i, inst) in c.insts.iter().enumerate() {
        for opn in &inst.operands {
            if let Some(j) = inst_idx(c, opn) {
                uses[j].push(i);
            }
        }
    }

    let mut diags = Vec::new();
    let mut fq: Vec<FqMatch> = Vec::new();
    for (ci, inst) in c.insts.iter().enumerate() {
        if inst.opcode != "clamp" || inst.operands.len() != 3 {
            continue;
        }
        let (Some(lo_i), Some(mid_i), Some(hi_i)) = (
            inst_idx(c, &inst.operands[0]),
            inst_idx(c, &inst.operands[1]),
            inst_idx(c, &inst.operands[2]),
        ) else {
            continue;
        };
        // structural gate: the clamped value must be round(x / s) + z
        let mid = &c.insts[mid_i];
        if mid.opcode != "add" || mid.operands.len() != 2 {
            continue;
        }
        let mid_ops: Vec<usize> =
            mid.operands.iter().filter_map(|n| inst_idx(c, n)).collect();
        if mid_ops.len() != 2 {
            continue;
        }
        let Some(rp) =
            mid_ops.iter().position(|&j| c.insts[j].opcode == "round-nearest-afz")
        else {
            continue;
        };
        let zb_i = mid_ops[1 - rp];
        let Some(div_i) =
            c.insts[mid_ops[rp]].operands.first().and_then(|n| inst_idx(c, n))
        else {
            continue;
        };
        let div = &c.insts[div_i];
        if div.opcode != "divide" || div.operands.len() != 2 {
            continue;
        }
        let (Some(x_i), Some(sb_i)) =
            (inst_idx(c, &div.operands[0]), inst_idx(c, &div.operands[1]))
        else {
            continue;
        };
        let loc = format!("{}/%{}/%{}", m.name, c.name, inst.name);

        let lo = trace(c, lo_i);
        let hi = trace(c, hi_i);
        let sb = trace(c, sb_i);
        let zb = trace(c, zb_i);

        // identify the site from the act_cfg row the bounds read
        let mut site: Option<usize> = None;
        if let (
            Src::Window { param: p1, ranges: r1 },
            Src::Window { param: p2, ranges: r2 },
        ) = (&lo, &hi)
        {
            if *p1 == cfg_p && *p2 == cfg_p {
                let cell = |r: &[(usize, usize)]| -> Option<(usize, usize)> {
                    (r.len() == 2 && r[0].1 == r[0].0 + 1 && r[1].1 == r[1].0 + 1)
                        .then(|| (r[0].0, r[1].0))
                };
                match (cell(r1), cell(r2)) {
                    (Some((row_lo, col_lo)), Some((row_hi, col_hi))) => {
                        if row_lo != row_hi {
                            diags.push(Diag::deny(
                                "TQ008",
                                loc.clone(),
                                format!(
                                    "clamp bounds read different act_cfg rows \
                                     ({row_lo} vs {row_hi})"
                                ),
                            ));
                        } else if (col_lo, col_hi) != (0, 1) {
                            diags.push(Diag::deny(
                                "TQ008",
                                loc.clone(),
                                format!(
                                    "clamp bounds read act_cfg columns \
                                     ({col_lo}, {col_hi}); the row layout is \
                                     [qmin, qmax, enable] = columns (0, 1)"
                                ),
                            ));
                        } else if row_lo >= n_sites {
                            diags.push(Diag::deny(
                                "TQ008",
                                loc.clone(),
                                format!(
                                    "act_cfg row {row_lo} out of range for \
                                     {n_sites} sites"
                                ),
                            ));
                        } else {
                            site = Some(row_lo);
                        }
                    }
                    _ => diags.push(Diag::deny(
                        "TQ008",
                        loc.clone(),
                        "clamp bounds are non-scalar act_cfg windows",
                    )),
                }
            }
        }

        let lanes = |s: &Src, p: usize| -> Option<(usize, usize)> {
            match s {
                Src::Window { param, ranges } if *param == p && ranges.len() == 1 => {
                    Some(ranges[0])
                }
                _ => None,
            }
        };
        let s_lanes = lanes(&sb, scales_p);
        let z_lanes = lanes(&zb, zps_p);
        if site.is_none() {
            // hard-coded-bounds blocks: identify the site from its scale
            // lane window instead
            site = s_lanes.and_then(|(slo, shi)| {
                info.sites
                    .iter()
                    .position(|s| s.offset == slo && s.offset + s.channels == shi)
            });
        }

        if let Some(k) = site {
            let ss = &info.sites[k];
            let want = (ss.offset, ss.offset + ss.channels);
            if let Some(sl) = s_lanes {
                if sl != want {
                    diags.push(Diag::deny(
                        "TQ008",
                        loc.clone(),
                        format!(
                            "site {} (act_cfg row {k}) reads act_scales[{}..{}) \
                             but owns lanes [{}..{})",
                            ss.name, sl.0, sl.1, want.0, want.1
                        ),
                    ));
                }
            }
            if let Some(zl) = z_lanes {
                if zl != want {
                    diags.push(Diag::deny(
                        "TQ008",
                        loc.clone(),
                        format!(
                            "site {} reads act_zps[{}..{}) but owns lanes \
                             [{}..{})",
                            ss.name, zl.0, zl.1, want.0, want.1
                        ),
                    ));
                }
            }
            let cfg = policy.site_cfg(&ss.name);
            if cfg.enabled {
                if let (Src::Const(a), Src::Const(b)) = (&lo, &hi) {
                    let grid = QGrid::asymmetric(cfg.bits);
                    if *a != grid.qmin || *b != grid.qmax {
                        diags.push(Diag::deny(
                            "TQ002",
                            loc.clone(),
                            format!(
                                "site {}: hard-coded clamp bounds [{a}, {b}] are \
                                 inconsistent with the declared {}-bit \
                                 asymmetric grid [{}, {}]",
                                ss.name, cfg.bits, grid.qmin, grid.qmax
                            ),
                        ));
                    }
                }
            }
        }

        // find the QDQ output: clamp -> subtract -> multiply -> select
        // with the multiply on the enabled branch and x on the bypass
        let mut output = None;
        'find: for &sub_i in &uses[ci] {
            if c.insts[sub_i].opcode != "subtract" {
                continue;
            }
            for &mul_i in &uses[sub_i] {
                if c.insts[mul_i].opcode != "multiply" {
                    continue;
                }
                for &sel_i in &uses[mul_i] {
                    let sel = &c.insts[sel_i];
                    if sel.opcode == "select"
                        && sel.operands.len() == 3
                        && inst_idx(c, &sel.operands[1]) == Some(mul_i)
                        && inst_idx(c, &sel.operands[2]) == Some(x_i)
                    {
                        output = Some(sel_i);
                        break 'find;
                    }
                }
            }
        }
        fq.push(FqMatch { input: x_i, output, site });
    }

    // ---- TQ001: enabled residual-sum sites must quantize an add of
    // quantized operands. embed_sum also ends in `_sum` but its input add
    // legitimately consumes raw gather outputs, so only the true residual
    // connections (res1/res2) are checked.
    let mut out_site: BTreeMap<usize, usize> = BTreeMap::new();
    for f in &fq {
        if let (Some(o), Some(s)) = (f.output, f.site) {
            out_site.insert(o, s);
        }
    }
    let passthrough = |mut j: usize| -> usize {
        for _ in 0..16 {
            let inst = &c.insts[j];
            if !matches!(inst.opcode.as_str(), "reshape" | "transpose") {
                break;
            }
            match inst.operands.first().and_then(|n| inst_idx(c, n)) {
                Some(k) => j = k,
                None => break,
            }
        }
        j
    };
    for f in &fq {
        let Some(k) = f.site else { continue };
        let name = info.sites[k].name.as_str();
        if !(name.ends_with("res1_sum") || name.ends_with("res2_sum")) {
            continue;
        }
        if !policy.site_cfg(name).enabled {
            continue;
        }
        let loc = format!("{}/%{}/site {}", m.name, c.name, name);
        let add = &c.insts[f.input];
        if add.opcode != "add" {
            diags.push(Diag::deny(
                "TQ001",
                loc.clone(),
                format!(
                    "residual site quantizes %{} ({}) instead of the residual add",
                    add.name, add.opcode
                ),
            ));
            continue;
        }
        for opn in &add.operands {
            let Some(j0) = inst_idx(c, opn) else { continue };
            match out_site.get(&passthrough(j0)) {
                Some(&src) if policy.site_cfg(&info.sites[src].name).enabled => {}
                Some(&src) => diags.push(Diag::deny(
                    "TQ001",
                    loc.clone(),
                    format!(
                        "residual add consumes %{opn} from disabled site {} — an \
                         unquantized activation flows into a quantized residual \
                         sum (the paper's §3 outlier path); enable the producer \
                         site or disable {name}",
                        info.sites[src].name
                    ),
                )),
                None => diags.push(Diag::deny(
                    "TQ001",
                    loc.clone(),
                    format!(
                        "residual add consumes %{opn}, which is not the output \
                         of any fake-quant site — calibration never sees the \
                         tensor this quantizer will clamp"
                    ),
                )),
            }
        }
    }
    Ok(diags)
}

// ---------------------------------------------------------------------------
// CLI driver
// ---------------------------------------------------------------------------

/// `repro lint [--artifacts DIR] [--spec FILE | --preset NAME] [--json]`
///
/// Pass 1 parses and statically verifies every artifact in the manifest
/// (TQ100-TQ107, all deny). Pass 2 lints each spec (default: every
/// preset) against each model topology and its batch-1 forward graph.
/// Exits non-zero iff any deny-level finding.
pub fn cmd_lint(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(dir).with_context(|| {
        format!("loading {dir}/manifest.json — run `repro gen-artifacts` first")
    })?;

    let mut diags: Vec<Diag> = Vec::new();

    // ---- pass 1: every shipped artifact must parse and verify
    for (name, sig) in &manifest.artifacts {
        let text = match std::fs::read_to_string(&sig.file) {
            Ok(t) => t,
            Err(e) => {
                diags.push(Diag::deny(
                    "TQ100",
                    name.clone(),
                    format!("cannot read {:?}: {e}", sig.file),
                ));
                continue;
            }
        };
        match parse_module(&text) {
            Err(e) => {
                diags.push(Diag::deny("TQ100", name.clone(), format!("parse error: {e:#}")))
            }
            Ok(module) => {
                for v in verify_module(&module) {
                    diags.push(Diag::deny(
                        v.code,
                        format!("{name}/%{}/%{}", v.comp, v.inst),
                        v.msg,
                    ));
                }
            }
        }
    }

    // ---- pass 2: spec hazards against each model + its forward graph
    let specs: Vec<QuantSpec> = if let Some(path) = args.get("spec") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("cannot read spec {path:?}"))?;
        vec![QuantSpec::parse(&text)?]
    } else if let Some(name) = args.get("preset") {
        vec![presets::preset(name)?]
    } else {
        let mut v = Vec::new();
        for n in presets::preset_names() {
            v.push(presets::preset(n)?);
        }
        // presets are all vanilla-BERT; add a W8A8 cell per attention
        // variant family so the default no-deny gate also walks the
        // clipped-softmax / gated forward + diag graphs
        use crate::model::manifest::{Architecture, AttnVariant};
        for arch in [Architecture::Bert, Architecture::Vit] {
            for variant in [AttnVariant::ClippedSoftmax, AttnVariant::Gated] {
                let name = format!("w8a8_{}_{}", arch.name(), variant.tag());
                v.push(
                    presets::preset("w8a8")?
                        .named(&name)
                        .with_architecture(arch)
                        .with_variant(variant),
                );
            }
        }
        v
    };

    // every quantized graph shipped per model: batch-1 forward, diagnostic
    // forward, and (vanilla BERT only — no other train graphs yet) the QAT
    // train-step. fp32 train graphs carry no quantizer triple and are
    // covered by pass 1 alone. The attention-variant families (clipped
    // softmax / gated) ship forward + diag per head, like ViT.
    let graph_arts: [(&str, &[&str]); 12] = [
        ("base", &["fwd_cls_b1", "diag_cls_b1", "train_qat_cls_b16"]),
        ("base_reg", &["fwd_reg_b1", "diag_reg_b1", "train_qat_reg_b16"]),
        ("vit", &["fwd_vit_cls_b1", "diag_vit_cls_b1"]),
        ("vit_reg", &["fwd_vit_reg_b1", "diag_vit_reg_b1"]),
        ("bert_csoft", &["fwd_csoft_cls_b1", "diag_csoft_cls_b1"]),
        ("bert_csoft_reg", &["fwd_csoft_reg_b1", "diag_csoft_reg_b1"]),
        ("bert_gate", &["fwd_gate_cls_b1", "diag_gate_cls_b1"]),
        ("bert_gate_reg", &["fwd_gate_reg_b1", "diag_gate_reg_b1"]),
        ("vit_csoft", &["fwd_vit_csoft_cls_b1", "diag_vit_csoft_cls_b1"]),
        ("vit_csoft_reg", &["fwd_vit_csoft_reg_b1", "diag_vit_csoft_reg_b1"]),
        ("vit_gate", &["fwd_vit_gate_cls_b1", "diag_vit_gate_cls_b1"]),
        ("vit_gate_reg", &["fwd_vit_gate_reg_b1", "diag_vit_gate_reg_b1"]),
    ];
    let mut graphs: BTreeMap<&str, Vec<HloModule>> = BTreeMap::new();
    for (model, arts) in graph_arts {
        for art in arts {
            if let Ok(sig) = manifest.artifact(art) {
                let text = std::fs::read_to_string(&sig.file)
                    .with_context(|| format!("reading {art}"))?;
                // a parse failure is already a TQ100 from pass 1; don't
                // also die
                if let Ok(m) = parse_module(&text) {
                    graphs.entry(model).or_default().push(m);
                }
            }
        }
    }

    for spec in &specs {
        for (model, info) in &manifest.models {
            // a spec only ever runs against its own (architecture,
            // variant) family's models/graphs — cross-family lints would
            // flag site tables the spec never touches
            if spec.architecture != info.config.architecture()
                || spec.variant != info.config.variant
            {
                continue;
            }
            let prefix = format!("{}/{model}", spec.name);
            let mut local = lint_spec_rules(&spec.policy, info);
            let policy = spec.policy.resolve(info);
            local.extend(lint_policy(&policy, info));
            for m in graphs.get(model.as_str()).map_or(&[][..], Vec::as_slice) {
                local.extend(
                    lint_graph(m, info, &policy)
                        .with_context(|| format!("linting {prefix}/{}", m.name))?,
                );
            }
            for mut d in local {
                d.loc = format!("{prefix}: {}", d.loc);
                diags.push(d);
            }
        }
    }

    diags.sort_by(|a, b| {
        b.severity
            .cmp(&a.severity)
            .then_with(|| a.code.cmp(b.code))
            .then_with(|| a.loc.cmp(&b.loc))
    });
    let n_deny = diags.iter().filter(|d| d.severity == Severity::Deny).count();
    if args.flag("json") {
        let arr = Json::Arr(diags.iter().map(Diag::to_json).collect());
        println!("{arr}");
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    eprintln!(
        "lint: {} artifact(s), {} spec(s) x {} model(s) checked — {} finding(s), {} deny",
        manifest.artifacts.len(),
        specs.len(),
        manifest.models.len(),
        diags.len(),
        n_deny
    );
    if n_deny > 0 {
        bail!("lint failed: {n_deny} deny-level finding(s)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::builder::{GraphBuilder, Op};
    use crate::hlo::fixture::{build_forward, model_info, vit_config, FixtureConfig};
    use crate::model::manifest::{ArchParams, ModelConfig, ModelInfo, SiteSpec};
    use crate::model::qconfig::SiteCfg;
    use crate::spec::{SiteRule, SiteSelector};
    use crate::util::rng::Rng;

    fn info_with(sites: &[(&str, usize)]) -> ModelInfo {
        let mut specs = Vec::new();
        let mut off = 0;
        for (name, c) in sites {
            specs.push(SiteSpec { name: name.to_string(), channels: *c, offset: off });
            off += c;
        }
        ModelInfo {
            config: ModelConfig {
                name: "mini".into(),
                vocab: 16,
                d: 8,
                heads: 2,
                layers: 2,
                d_ff: 16,
                seq: 4,
                n_out: 3,
                outlier_dims: vec![1],
                arch: ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
            },
            params: Vec::new(),
            sites: specs,
            total_scale_lanes: off,
            wq: Vec::new(),
        }
    }

    fn rule(select: SiteSelector, bits: u32) -> SiteRule {
        SiteRule { select, cfg: SiteCfg { bits, ..Default::default() } }
    }

    fn codes(diags: &[Diag]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    // ---- TQ003/TQ004/TQ005 -------------------------------------------------

    #[test]
    fn dead_rule_is_tq003() {
        let info = info_with(&[("layer0.res2_sum", 8)]);
        let mut spec = PolicySpec::uniform(8, 8);
        spec.rules.push(rule(SiteSelector::Exact("no_such_site".into()), 16));
        let d = lint_spec_rules(&spec, &info);
        assert_eq!(codes(&d), ["TQ003"]);
        assert_eq!(d[0].severity, Severity::Warn);
    }

    #[test]
    fn fully_shadowed_rule_is_tq004() {
        let info = info_with(&[("layer0.res2_sum", 8)]);
        let mut spec = PolicySpec::uniform(8, 8);
        spec.rules.push(rule(SiteSelector::Family("res2_sum".into()), 16));
        spec.rules.push(rule(SiteSelector::Exact("layer0.res2_sum".into()), 12));
        let d = lint_spec_rules(&spec, &info);
        assert_eq!(codes(&d), ["TQ004"]);
        assert!(d[0].loc.contains("rule #0"), "{}", d[0].loc);
    }

    #[test]
    fn identical_config_overlap_is_tq005() {
        let info = info_with(&[("layer0.res2_sum", 8), ("layer1.res2_sum", 8)]);
        let mut spec = PolicySpec::uniform(8, 8);
        spec.rules.push(rule(SiteSelector::Family("res2_sum".into()), 16));
        spec.rules.push(rule(SiteSelector::Exact("layer1.res2_sum".into()), 16));
        let d = lint_spec_rules(&spec, &info);
        // rule #1 re-installs an identical config -> redundant, but NOT
        // fully shadowed (it still owns layer1)
        assert_eq!(codes(&d), ["TQ005"]);
    }

    #[test]
    fn broad_then_specific_layering_is_clean() {
        // the idiomatic mixed-precision shape: broad family rule, then a
        // *different* config on one member — no findings
        let info = info_with(&[("layer0.res2_sum", 8), ("layer1.res2_sum", 8)]);
        let mut spec = PolicySpec::uniform(8, 8);
        spec.rules.push(rule(SiteSelector::Family("res2_sum".into()), 16));
        spec.rules.push(rule(SiteSelector::Exact("layer1.res2_sum".into()), 12));
        assert!(lint_spec_rules(&spec, &info).is_empty());
    }

    // ---- TQ006/TQ007 -------------------------------------------------------

    #[test]
    fn peg_k_hazards_are_tq006() {
        let info = info_with(&[("layer0.res2_sum", 8)]);
        let mut policy = PolicySpec::uniform(8, 8).resolve(&info);
        let peg = |k| SiteCfg {
            granularity: Granularity::PerEmbeddingGroup { k, permute: true },
            ..Default::default()
        };
        policy.overrides.insert("layer0.res2_sum".into(), peg(16));
        assert_eq!(codes(&lint_policy(&policy, &info)), ["TQ006"]);
        policy.overrides.insert("layer0.res2_sum".into(), peg(0));
        assert_eq!(codes(&lint_policy(&policy, &info)), ["TQ006"]);
        policy.overrides.insert("layer0.res2_sum".into(), peg(4));
        assert!(lint_policy(&policy, &info).is_empty());
        // disabled sites are never checked
        policy
            .overrides
            .insert("layer0.res2_sum".into(), SiteCfg { enabled: false, ..peg(16) });
        assert!(lint_policy(&policy, &info).is_empty());
    }

    #[test]
    fn mse_tensor_on_grouped_site_is_tq007() {
        let info = info_with(&[("layer0.res2_sum", 8)]);
        let mut policy = PolicySpec::uniform(8, 8).resolve(&info);
        policy.overrides.insert(
            "layer0.res2_sum".into(),
            SiteCfg {
                granularity: Granularity::PerEmbedding,
                range_method: RangeMethod::MseTensor,
                ..Default::default()
            },
        );
        assert_eq!(codes(&lint_policy(&policy, &info)), ["TQ007"]);
    }

    // ---- graph lints -------------------------------------------------------

    /// Mirror of the fixture's QDQ lowering (SiteQuant::apply) for
    /// hand-built graphs. `bounds`: None = read the act_cfg row (the
    /// correct wiring); Some((lo, hi)) = hard-coded constants.
    #[allow(clippy::too_many_arguments)]
    fn qdq(
        g: &mut GraphBuilder,
        x: &Op,
        idx: usize,
        offset: usize,
        channels: usize,
        scales: &Op,
        zps: &Op,
        cfg: &Op,
        bounds: Option<(f32, f32)>,
    ) -> Op {
        let dims = x.dims.clone();
        let rank = dims.len();
        let s = g.slice(scales, &[(offset, offset + channels)]).unwrap();
        let z = g.slice(zps, &[(offset, offset + channels)]).unwrap();
        let sb = g.broadcast(&s, &dims, &[rank - 1]).unwrap();
        let zb = g.broadcast(&z, &dims, &[rank - 1]).unwrap();
        let row = g.slice(cfg, &[(idx, idx + 1), (0, 3)]).unwrap();
        let cell = |g: &mut GraphBuilder, j: usize| -> Op {
            let c = g.slice(&row, &[(0, 1), (j, j + 1)]).unwrap();
            g.reshape(&c, &[]).unwrap()
        };
        let (qmin_b, qmax_b) = match bounds {
            None => {
                let qmin = cell(g, 0);
                let qmax = cell(g, 1);
                (g.splat(&qmin, &dims).unwrap(), g.splat(&qmax, &dims).unwrap())
            }
            Some((lo, hi)) => {
                let lo = g.const_f32(lo);
                let hi = g.const_f32(hi);
                (g.splat(&lo, &dims).unwrap(), g.splat(&hi, &dims).unwrap())
            }
        };
        let enable = cell(g, 2);
        let t = g.div(x, &sb).unwrap();
        let r = g.round(&t);
        let q = g.add(&r, &zb).unwrap();
        let qc = g.clamp(&qmin_b, &q, &qmax_b);
        let c1 = g.sub(&qc, &zb).unwrap();
        let dq = g.mul(&c1, &sb).unwrap();
        let half = g.const_f32(0.5);
        let pred = g.compare("GT", &enable, &half).unwrap();
        let pred_b = g.splat(&pred, &dims).unwrap();
        g.select(&pred_b, &dq, x).unwrap()
    }

    /// Three-site residual scaffold: x -> q0; tanh -> q1; add(q0, q1) -> q2
    /// (q2 is `layer0.res1_sum`). `quantize_producer` = false drops q1 and
    /// feeds the raw tanh into the residual add.
    fn residual_module(quantize_producer: bool, bounds: Option<(f32, f32)>) -> HloModule {
        let mut g = GraphBuilder::new("mini_fwd");
        let x = g.param(DType::F32, &[2, 8]);
        let scales = g.param(DType::F32, &[24]);
        let zps = g.param(DType::F32, &[24]);
        let cfg = g.param(DType::F32, &[3, 3]);
        let q0 = qdq(&mut g, &x, 0, 0, 8, &scales, &zps, &cfg, None);
        let t = g.tanh(&q0);
        let prod = if quantize_producer {
            qdq(&mut g, &t, 1, 8, 8, &scales, &zps, &cfg, None)
        } else {
            t
        };
        let res = g.add(&q0, &prod).unwrap();
        let out = qdq(&mut g, &res, 2, 16, 8, &scales, &zps, &cfg, bounds);
        let text = g.finish(&[out]);
        parse_module(&text).unwrap()
    }

    fn residual_info() -> ModelInfo {
        info_with(&[("embed_ln_out", 8), ("layer0.attn_out", 8), ("layer0.res1_sum", 8)])
    }

    #[test]
    fn clean_residual_graph_lints_clean() {
        let m = residual_module(true, None);
        let info = residual_info();
        let policy = PolicySpec::uniform(8, 8).resolve(&info);
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn unquantized_residual_operand_is_tq001() {
        let m = residual_module(false, None);
        let info = residual_info();
        let policy = PolicySpec::uniform(8, 8).resolve(&info);
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert_eq!(codes(&d), ["TQ001"], "{d:?}");
        assert!(d[0].msg.contains("not the output of any fake-quant site"), "{}", d[0].msg);
    }

    #[test]
    fn disabled_producer_site_is_tq001() {
        let m = residual_module(true, None);
        let info = residual_info();
        let mut policy = PolicySpec::uniform(8, 8).resolve(&info);
        policy
            .overrides
            .insert("layer0.attn_out".into(), SiteCfg { enabled: false, ..Default::default() });
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert_eq!(codes(&d), ["TQ001"], "{d:?}");
        assert!(d[0].msg.contains("disabled site layer0.attn_out"), "{}", d[0].msg);
        // disabling the residual site itself silences the check
        policy
            .overrides
            .insert("layer0.res1_sum".into(), SiteCfg { enabled: false, ..Default::default() });
        assert!(lint_graph(&m, &info, &policy).unwrap().is_empty());
    }

    #[test]
    fn hardcoded_clamp_bounds_off_grid_is_tq002() {
        // bounds [0, 100] on a declared 8-bit site (grid [0, 255])
        let m = residual_module(true, Some((0.0, 100.0)));
        let info = residual_info();
        let policy = PolicySpec::uniform(8, 8).resolve(&info);
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert_eq!(codes(&d), ["TQ002"], "{d:?}");
        // bounds that match the declared grid are fine
        let ok = residual_module(true, Some((0.0, 255.0)));
        assert!(lint_graph(&ok, &info, &policy).unwrap().is_empty());
        // ... and a disabled site's bounds are never judged
        let mut off = PolicySpec::uniform(8, 8).resolve(&info);
        off.overrides
            .insert("layer0.res1_sum".into(), SiteCfg { enabled: false, ..Default::default() });
        assert!(lint_graph(&m, &info, &off).unwrap().is_empty());
    }

    #[test]
    fn mismatched_cfg_wiring_is_tq008() {
        // qmin from row 0, qmax from row 1: not a coherent site read
        let mut g = GraphBuilder::new("bad_fwd");
        let x = g.param(DType::F32, &[2, 8]);
        let scales = g.param(DType::F32, &[8]);
        let zps = g.param(DType::F32, &[8]);
        let cfg = g.param(DType::F32, &[1, 3]);
        let dims = vec![2usize, 8];
        let s = g.slice(&scales, &[(0, 8)]).unwrap();
        let z = g.slice(&zps, &[(0, 8)]).unwrap();
        let sb = g.broadcast(&s, &dims, &[1]).unwrap();
        let zb = g.broadcast(&z, &dims, &[1]).unwrap();
        let r0 = g.slice(&cfg, &[(0, 1), (0, 1)]).unwrap();
        let qmin = g.reshape(&r0, &[]).unwrap();
        // wrong column for qmax: reads `enable` instead
        let r1 = g.slice(&cfg, &[(0, 1), (2, 3)]).unwrap();
        let qmax = g.reshape(&r1, &[]).unwrap();
        let qmin_b = g.splat(&qmin, &dims).unwrap();
        let qmax_b = g.splat(&qmax, &dims).unwrap();
        let t = g.div(&x, &sb).unwrap();
        let r = g.round(&t);
        let q = g.add(&r, &zb).unwrap();
        let qc = g.clamp(&qmin_b, &q, &qmax_b);
        let c1 = g.sub(&qc, &zb).unwrap();
        let dq = g.mul(&c1, &sb).unwrap();
        let half = g.const_f32(0.5);
        let en = g.slice(&cfg, &[(0, 1), (2, 3)]).unwrap();
        let en = g.reshape(&en, &[]).unwrap();
        let pred = g.compare("GT", &en, &half).unwrap();
        let pred_b = g.splat(&pred, &dims).unwrap();
        let out = g.select(&pred_b, &dq, &x).unwrap();
        let m = parse_module(&g.finish(&[out])).unwrap();
        let info = info_with(&[("embed_ln_out", 8)]);
        let policy = PolicySpec::uniform(8, 8).resolve(&info);
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert_eq!(codes(&d), ["TQ008"], "{d:?}");
        assert!(d[0].msg.contains("columns"), "{}", d[0].msg);
    }

    // ---- the real fixture lowering, across randomized topologies -----------

    #[test]
    fn fixture_forward_graphs_lint_clean_across_topologies() {
        // property check: for randomized (d, heads, layers, seq, variant),
        // the fixture lowering verifies AND lints clean under a fully
        // quantized policy — i.e. every residual site's operands really
        // are quantized, at every size and for every attention variant
        use crate::model::manifest::AttnVariant;
        let mut rng = Rng::new(0xC0FFEE);
        for trial in 0..6 {
            let heads = [1, 2, 4][rng.below(3)];
            let d = heads * (2 + rng.below(3));
            // cycle rather than sample so all three variants are
            // guaranteed to be exercised
            let variant = [
                AttnVariant::Vanilla,
                AttnVariant::ClippedSoftmax,
                AttnVariant::Gated,
            ][trial % 3];
            let cfg = FixtureConfig {
                name: format!("prop{trial}"),
                vocab: 8 + rng.below(8),
                d,
                heads,
                layers: 1 + rng.below(3),
                d_ff: 2 * d,
                seq: 3 + rng.below(4),
                n_out: 2,
                outlier_dims: vec![0],
                arch: ArchParams::Bert { pad_id: 0, cls_id: 1, sep_id: 2 },
                variant,
            };
            let art = build_forward(&cfg, 1, false, &cfg.name).unwrap();
            let m = parse_module(&art.text).unwrap();
            crate::hlo::verify(&m).unwrap();
            let info = model_info(&cfg);
            for spec in [PolicySpec::uniform(8, 8), PolicySpec::acts_only(8)] {
                let policy = spec.resolve(&info);
                let d = lint_graph(&m, &info, &policy).unwrap();
                assert!(d.is_empty(), "cfg {:?}: {d:?}", cfg.name);
            }
        }
    }

    #[test]
    fn vit_forward_and_diag_graphs_lint_clean() {
        // the ViT frontend's lowering carries the same quantizer triple
        // and residual wiring contract as BERT: both shipped graph kinds
        // lint clean under fully-quantized policies
        let vit = vit_config();
        let info = model_info(&vit);
        for (name, taps) in [("fwd_vit_cls_b1", false), ("diag_vit_cls_b1", true)] {
            let art = build_forward(&vit, 1, taps, name).unwrap();
            let m = parse_module(&art.text).unwrap();
            crate::hlo::verify(&m).unwrap();
            for spec in [PolicySpec::uniform(8, 8), PolicySpec::acts_only(8)] {
                let policy = spec.resolve(&info);
                let d = lint_graph(&m, &info, &policy).unwrap();
                assert!(d.is_empty(), "{name}: {d:?}");
            }
        }
    }

    #[test]
    fn qat_train_step_graph_lints_clean() {
        // the train-step layout interleaves Adam moments with the
        // quantizer state (act_scales, m_scales, v_scales, act_zps,
        // act_cfg): the triple detector must still find the true scale
        // source four slots back, and the QDQ/residual checks must hold
        let base = crate::hlo::fixture::base_config();
        let art = crate::hlo::train_graph::build_train_step(
            &base,
            false,
            true,
            16,
            "train_qat_cls_b16",
        )
        .unwrap();
        let m = parse_module(&art.text).unwrap();
        let info = model_info(&base);
        let policy = PolicySpec::uniform(8, 8).resolve(&info);
        let d = lint_graph(&m, &info, &policy).unwrap();
        assert!(d.is_empty(), "{d:?}");
        // the fp32 twin has no quantizer triple and must be rejected, not
        // silently half-linted
        let fp = crate::hlo::train_graph::build_train_step(
            &base,
            false,
            false,
            16,
            "train_fp32_cls_b16",
        )
        .unwrap();
        let m = parse_module(&fp.text).unwrap();
        let err = lint_graph(&m, &info, &policy).unwrap_err();
        assert!(err.to_string().contains("parameter triple"), "{err:#}");
    }

    #[test]
    fn all_presets_lint_clean_on_fixture_topology() {
        // `repro lint`'s deny gate over the preset registry, minus the
        // on-disk manifest: every preset x the fixture base topology
        let base = crate::hlo::fixture::base_config();
        let art = build_forward(&base, 1, false, "fwd_cls_b1").unwrap();
        let m = parse_module(&art.text).unwrap();
        let info = model_info(&base);
        for name in presets::preset_names() {
            let spec = presets::preset(name).unwrap();
            let mut d = lint_spec_rules(&spec.policy, &info);
            let policy = spec.policy.resolve(&info);
            d.extend(lint_policy(&policy, &info));
            d.extend(lint_graph(&m, &info, &policy).unwrap());
            let denies: Vec<&Diag> =
                d.iter().filter(|x| x.severity == Severity::Deny).collect();
            assert!(denies.is_empty(), "preset {name}: {denies:?}");
        }
    }
}
