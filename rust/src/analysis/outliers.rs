//! Outlier observability (DESIGN.md §15): per-site activation statistics
//! quantifying the paper's Fig. 2 problem — a few embedding dimensions
//! carry structural outliers that blow up per-tensor quantization ranges
//! — and the follow-up's fix: clipped-softmax / gated-attention variants
//! whose activations stay near-Gaussian.
//!
//! Three statistics per tap site, streamed over the sequences of a
//! [`DiagRun`]:
//! * **∞-norm** — max |x|; the quantity a per-tensor min-max range must
//!   cover, so it is the direct cost of an outlier.
//! * **kurtosis** — m₄/m₂² of the whole tap; ≈3 for Gaussian
//!   activations, ≫3 when a few lanes carry heavy tails.
//! * **top-lane share** — the largest single embedding lane's fraction
//!   of the tap's total energy (Σx² per lane); ≈1/d when energy is
//!   spread, ≈1/k when k outlier lanes dominate.
//!
//! Determinism contract: the accumulator keeps raw power sums (n, Σx,
//! Σx², Σx³, Σx⁴ in f64) and folds elements in strict tensor order, so a
//! streamed run is *bit-identical* to a one-shot pass over the
//! concatenated taps (property-tested below), and `repro diag
//! --outliers` output is bit-identical at any `TQ_THREADS` setting
//! (tap collection already reassembles in sequence order —
//! tests/determinism.rs).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::diagnostics::{collect_taps_var, DiagRun};
use crate::coordinator::experiments::load_ckpt_var;
use crate::coordinator::Ctx;
use crate::model::manifest::{model_name, Architecture, AttnVariant};
use crate::report::{write_file, Table};
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::json::{obj, Json};

/// Streaming per-site statistics accumulator. Observations fold in
/// strict element order with f64 power sums, so streaming N tensors and
/// one-shotting their concatenation produce bit-identical results.
#[derive(Debug, Clone, Default)]
pub struct SiteAccum {
    n: u64,
    s1: f64,
    s2: f64,
    s3: f64,
    s4: f64,
    /// max |x| under `f32::total_cmp` — NaN taps surface as a NaN
    /// ∞-norm deterministically instead of being silently dropped
    inf_norm: f32,
    /// per-embedding-lane Σx² (lane = index modulo the last dim)
    lane_sq: Vec<f64>,
}

/// Finished statistics for one tap site.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteStats {
    /// elements observed
    pub n: u64,
    pub mean: f64,
    /// max |x| (NaN if the tap contained NaN)
    pub inf_norm: f32,
    /// m₄/m₂² (0.0 for empty/constant/non-finite taps)
    pub kurtosis: f64,
    /// largest lane's share of total Σx² energy (0.0 when energy is 0)
    pub top_share: f64,
    /// index of that lane
    pub top_lane: usize,
}

impl SiteAccum {
    pub fn new() -> SiteAccum {
        SiteAccum::default()
    }

    /// Fold one tap tensor in. The lane count is fixed by the first
    /// observation (the site's embedding dim); later tensors must match.
    pub fn observe(&mut self, t: &Tensor) -> Result<()> {
        let lanes = t.last_dim();
        if lanes == 0 {
            bail!("outlier accumulator: tensor with zero-length last dim");
        }
        if self.lane_sq.is_empty() {
            self.lane_sq = vec![0.0; lanes];
        } else if self.lane_sq.len() != lanes {
            bail!(
                "outlier accumulator: lane count changed ({} -> {lanes})",
                self.lane_sq.len()
            );
        }
        for (i, &x) in t.data().iter().enumerate() {
            let a = x.abs();
            if a.total_cmp(&self.inf_norm) == std::cmp::Ordering::Greater {
                self.inf_norm = a;
            }
            let x = x as f64;
            let x2 = x * x;
            self.s1 += x;
            self.s2 += x2;
            self.s3 += x2 * x;
            self.s4 += x2 * x2;
            self.lane_sq[i % lanes] += x2;
            self.n += 1;
        }
        Ok(())
    }

    /// Central moments from the raw power sums. Degenerate inputs
    /// (empty, constant, NaN/inf sums) yield kurtosis 0.0, never a
    /// panic — the ∞-norm still flags non-finite taps.
    pub fn stats(&self) -> SiteStats {
        if self.n == 0 {
            return SiteStats {
                n: 0,
                mean: 0.0,
                inf_norm: self.inf_norm,
                kurtosis: 0.0,
                top_share: 0.0,
                top_lane: 0,
            };
        }
        let n = self.n as f64;
        let mean = self.s1 / n;
        let m2 = self.s2 / n - mean * mean;
        let m4 = self.s4 / n - 4.0 * mean * self.s3 / n + 6.0 * mean * mean * self.s2 / n
            - 3.0 * mean * mean * mean * mean;
        let kurtosis = if m2 > 0.0 && m2.is_finite() && m4.is_finite() {
            m4 / (m2 * m2)
        } else {
            0.0
        };
        let total: f64 = self.lane_sq.iter().sum();
        let (top_lane, top) = self
            .lane_sq
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, &v)| (i, v))
            .unwrap_or((0, 0.0));
        let top_share = if total > 0.0 && total.is_finite() { top / total } else { 0.0 };
        SiteStats { n: self.n, mean, inf_norm: self.inf_norm, kurtosis, top_share, top_lane }
    }
}

/// Per-site statistics over every sequence of a diag run, streamed in
/// sequence order then site order (both fixed), keyed by site name.
pub fn outlier_stats(run: &DiagRun) -> Result<BTreeMap<String, SiteStats>> {
    let mut accums: BTreeMap<String, SiteAccum> = BTreeMap::new();
    for taps in &run.per_seq {
        for (site, t) in taps {
            accums.entry(site.clone()).or_default().observe(t)?;
        }
    }
    Ok(accums.into_iter().map(|(s, a)| (s, a.stats())).collect())
}

/// One model family's outlier profile: the per-site stats plus the
/// headline maxima the CI gate compares across variants.
pub struct FamilyStats {
    pub arch: Architecture,
    pub variant: AttnVariant,
    pub model: String,
    pub sites: BTreeMap<String, SiteStats>,
}

impl FamilyStats {
    /// Largest per-site kurtosis (NaN-safe: degenerate sites are 0.0).
    pub fn max_kurtosis(&self) -> f64 {
        self.sites.values().map(|s| s.kurtosis).fold(0.0, f64::max)
    }

    /// Largest per-site ∞-norm under `total_cmp` (NaN sorts above +inf,
    /// so a NaN tap anywhere is visible here).
    pub fn max_inf_norm(&self) -> f32 {
        self.sites.values().map(|s| s.inf_norm).fold(0.0f32, |a, b| {
            if b.total_cmp(&a) == std::cmp::Ordering::Greater {
                b
            } else {
                a
            }
        })
    }

    fn to_json(&self) -> Json {
        let sites: BTreeMap<String, Json> = self
            .sites
            .iter()
            .map(|(name, s)| {
                (
                    name.clone(),
                    obj(vec![
                        ("n", Json::Num(s.n as f64)),
                        ("mean", Json::Num(s.mean)),
                        ("inf_norm", json_f64(s.inf_norm as f64)),
                        ("kurtosis", json_f64(s.kurtosis)),
                        ("top_share", Json::Num(s.top_share)),
                        ("top_lane", Json::Num(s.top_lane as f64)),
                    ]),
                )
            })
            .collect();
        obj(vec![
            ("arch", Json::Str(self.arch.name().to_string())),
            ("variant", Json::Str(self.variant.name().to_string())),
            ("model", Json::Str(self.model.clone())),
            ("max_kurtosis", json_f64(self.max_kurtosis())),
            ("max_inf_norm", json_f64(self.max_inf_norm() as f64)),
            ("sites", Json::Obj(sites)),
        ])
    }
}

/// JSON has no NaN/inf literal; encode them as null so `--json` output
/// stays machine-parseable even for degenerate taps.
fn json_f64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Collect the outlier profile of one (architecture, variant) family.
pub fn family_stats(
    ctx: &Ctx,
    task: &crate::data::TaskSpec,
    arch: Architecture,
    variant: AttnVariant,
    n_seqs: usize,
) -> Result<FamilyStats> {
    let params = load_ckpt_var(ctx, task, arch, variant)?;
    let run = collect_taps_var(ctx, task, arch, variant, &params, n_seqs)?;
    Ok(FamilyStats {
        arch,
        variant,
        model: model_name(arch, variant, false),
        sites: outlier_stats(&run)?,
    })
}

/// `repro diag --outliers [--json]`: the Fig. 2 comparison as a command —
/// per-site ∞-norm / kurtosis / top-lane share for the vanilla model
/// next to the clipped-softmax and gated-attention variants, per
/// architecture. Table + CSV by default, a single JSON object with
/// `--json` (CI parses it and gates on vanilla kurtosis > variant
/// kurtosis). Deterministic at any thread count.
pub fn cmd_diag(ctx: &Ctx, args: &Args) -> Result<()> {
    if !args.flag("outliers") {
        bail!("repro diag: unknown mode — the outlier pass is `repro diag --outliers [--json]`");
    }
    let task = ctx.task(args.get_or("task", "sst2"))?;
    let n_seqs = args.get_usize("seqs", 16)?.max(1);
    let archs: Vec<Architecture> = match args.get("arch") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(Architecture::parse)
            .collect::<Result<_>>()?,
        None => vec![Architecture::Bert, Architecture::Vit],
    };
    let variants: Vec<AttnVariant> = match args.get("variants") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(AttnVariant::parse)
            .collect::<Result<_>>()?,
        None => vec![AttnVariant::Vanilla, AttnVariant::ClippedSoftmax, AttnVariant::Gated],
    };

    let mut families = Vec::new();
    for &arch in &archs {
        for &variant in &variants {
            families.push(family_stats(ctx, &task, arch, variant, n_seqs)?);
        }
    }

    if args.flag("json") {
        let out = obj(vec![
            ("task", Json::Str(task.name.to_string())),
            ("n_seqs", Json::Num(n_seqs as f64)),
            (
                "families",
                Json::Arr(families.iter().map(|f| f.to_json()).collect()),
            ),
        ]);
        println!("{out}");
        return Ok(());
    }

    let mut table = Table::new(
        &format!("outlier diagnostics (task {}, {n_seqs} seqs)", task.name),
        &["model", "site", "inf_norm", "kurtosis", "top_share", "top_lane"],
    );
    for f in &families {
        for (site, s) in &f.sites {
            table.row(vec![
                f.model.clone(),
                site.clone(),
                format!("{:.4}", s.inf_norm),
                format!("{:.2}", s.kurtosis),
                format!("{:.4}", s.top_share),
                format!("{}", s.top_lane),
            ]);
        }
    }
    print!("{}", table.to_console());
    let mut summary = Table::new(
        "per-family maxima (the Fig. 2 gap: vanilla >> variants)",
        &["model", "max_inf_norm", "max_kurtosis"],
    );
    for f in &families {
        summary.row(vec![
            f.model.clone(),
            format!("{:.4}", f.max_inf_norm()),
            format!("{:.2}", f.max_kurtosis()),
        ]);
    }
    print!("{}", summary.to_console());
    write_file(ctx.results_dir.join("diag_outliers.csv"), &table.to_csv())?;
    println!("wrote {}", ctx.results_dir.join("diag_outliers.csv").display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tensor(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape.to_vec(), data).unwrap()
    }

    fn rand_tensors(rng: &mut Rng, n: usize, lanes: usize) -> Vec<Tensor> {
        (0..n)
            .map(|_| {
                let rows = rng.range(1, 5);
                let data: Vec<f32> = (0..rows * lanes)
                    .map(|_| rng.normal_f32(0.0, 1.0 + 4.0 * rng.f32()))
                    .collect();
                tensor(&[1, rows, lanes], data)
            })
            .collect()
    }

    /// The determinism contract: streaming tensor-by-tensor equals a
    /// one-shot pass over the concatenation, bit for bit.
    #[test]
    fn streaming_equals_one_shot_bitwise() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let lanes = rng.range(2, 9);
            let parts = rand_tensors(&mut rng, rng.range(1, 6), lanes);

            let mut streamed = SiteAccum::new();
            for p in &parts {
                streamed.observe(p).unwrap();
            }

            let mut all: Vec<f32> = Vec::new();
            for p in &parts {
                all.extend_from_slice(p.data());
            }
            let rows = all.len() / lanes;
            let mut one_shot = SiteAccum::new();
            one_shot.observe(&tensor(&[rows, lanes], all)).unwrap();

            let (a, b) = (streamed.stats(), one_shot.stats());
            assert_eq!(a.n, b.n, "seed {seed}");
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "seed {seed}");
            assert_eq!(a.inf_norm.to_bits(), b.inf_norm.to_bits(), "seed {seed}");
            assert_eq!(a.kurtosis.to_bits(), b.kurtosis.to_bits(), "seed {seed}");
            assert_eq!(a.top_share.to_bits(), b.top_share.to_bits(), "seed {seed}");
            assert_eq!(a.top_lane, b.top_lane, "seed {seed}");
        }
    }

    #[test]
    fn known_values() {
        // constant tensor: zero variance -> kurtosis 0 by convention
        let mut c = SiteAccum::new();
        c.observe(&tensor(&[2, 2], vec![3.0; 4])).unwrap();
        let s = c.stats();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.inf_norm, 3.0);
        assert_eq!(s.kurtosis, 0.0);
        // two lanes, all energy in lane 1
        let mut a = SiteAccum::new();
        a.observe(&tensor(&[2, 2], vec![0.0, 2.0, 0.0, -2.0])).unwrap();
        let s = a.stats();
        assert_eq!(s.top_lane, 1);
        assert_eq!(s.top_share, 1.0);
        assert_eq!(s.inf_norm, 2.0);
        // symmetric two-point distribution {±1}: kurtosis exactly 1
        let mut b = SiteAccum::new();
        b.observe(&tensor(&[2, 2], vec![1.0, -1.0, -1.0, 1.0])).unwrap();
        assert_eq!(b.stats().kurtosis, 1.0);
        assert_eq!(b.stats().mean, 0.0);
    }

    #[test]
    fn gaussian_kurtosis_is_near_three_and_outliers_inflate_it() {
        let mut rng = Rng::new(7);
        let lanes = 64;
        let clean: Vec<f32> = (0..200 * lanes).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut base = SiteAccum::new();
        base.observe(&tensor(&[200, lanes], clean.clone())).unwrap();
        let k0 = base.stats().kurtosis;
        assert!((k0 - 3.0).abs() < 0.5, "gaussian kurtosis {k0}");

        // inflate one lane the way the fixture's outlier install does
        let mut spiked = clean;
        for row in 0..200 {
            spiked[row * lanes + 17] += 20.0;
        }
        let mut hot = SiteAccum::new();
        hot.observe(&tensor(&[200, lanes], spiked)).unwrap();
        let s = hot.stats();
        assert!(s.kurtosis > 10.0, "outlier kurtosis {}", s.kurtosis);
        assert!(s.kurtosis > k0 * 3.0);
        assert!(s.inf_norm > 15.0);
        assert_eq!(s.top_lane, 17);
        assert!(s.top_share > 0.5, "top share {}", s.top_share);
    }

    #[test]
    fn nan_and_inf_are_deterministic_not_panics() {
        let mut a = SiteAccum::new();
        a.observe(&tensor(&[1, 4], vec![1.0, f32::NAN, 2.0, -3.0])).unwrap();
        let s = a.stats();
        assert!(s.inf_norm.is_nan(), "NaN must surface in the inf-norm");
        assert_eq!(s.kurtosis, 0.0, "NaN power sums collapse to the 0.0 convention");
        // deterministic: same input, same bits
        let mut b = SiteAccum::new();
        b.observe(&tensor(&[1, 4], vec![1.0, f32::NAN, 2.0, -3.0])).unwrap();
        assert_eq!(s.inf_norm.to_bits(), b.stats().inf_norm.to_bits());

        let mut c = SiteAccum::new();
        c.observe(&tensor(&[1, 2], vec![f32::INFINITY, 0.0])).unwrap();
        let s = c.stats();
        assert_eq!(s.inf_norm, f32::INFINITY);
        assert_eq!(s.kurtosis, 0.0);
        assert_eq!(s.top_share, 0.0, "infinite energy yields no finite share");
    }

    #[test]
    fn accumulator_rejects_lane_mismatch_and_empty() {
        let mut a = SiteAccum::new();
        a.observe(&tensor(&[1, 4], vec![0.0; 4])).unwrap();
        assert!(a.observe(&tensor(&[1, 3], vec![0.0; 3])).is_err());
        assert_eq!(SiteAccum::new().stats().n, 0);
    }

    #[test]
    fn outlier_stats_covers_every_site() {
        let mut per_seq = Vec::new();
        for i in 0..3 {
            let mut m = BTreeMap::new();
            m.insert("a".to_string(), tensor(&[1, 2, 4], vec![i as f32; 8]));
            m.insert("b".to_string(), tensor(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]));
            per_seq.push(m);
        }
        let run = DiagRun { per_seq, examples: Vec::new() };
        let stats = outlier_stats(&run).unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats["a"].n, 24);
        assert_eq!(stats["b"].n, 12);
        assert_eq!(stats["b"].inf_norm, 4.0);
        assert_eq!(stats["b"].top_lane, 3);
    }
}
